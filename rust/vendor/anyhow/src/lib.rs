//! Minimal, dependency-free shim of the `anyhow` error-handling API,
//! covering exactly the surface this workspace uses:
//!
//! * [`Error`] — message + boxed cause chain, `Send + Sync`
//! * [`Result`] — `Result<T, Error>` alias with default type parameter
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result<T, E:
//!   Into<Error>>` (including `Result<T, Error>` itself) and `Option<T>`
//! * `anyhow!`, `bail!`, `ensure!` macros
//! * blanket `From<E: std::error::Error + Send + Sync + 'static>` so `?`
//!   converts std errors
//!
//! `Display` prints the outermost message; the alternate form (`{:#}`)
//! appends the cause chain (`msg: cause: cause`), matching how the CLI
//! reports errors. `Debug` shows the chain on separate lines like the
//! upstream crate. Like upstream, [`Error`] deliberately does **not**
//! implement `std::error::Error` (that would conflict with the blanket
//! `From`).

use std::fmt;

/// Error with an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result<T, anyhow::Error>` with the upstream default-parameter shape.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error {
            msg: m.to_string(),
            source: None,
        }
    }

    /// Wrap `self` as the cause of a new outer message.
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        Error {
            msg: c.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur.msg.as_str())
        })
    }
}

fn from_std(e: &(dyn std::error::Error + 'static)) -> Error {
    Error {
        msg: e.to_string(),
        source: e.source().map(|s| Box::new(from_std(s))),
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        from_std(&e)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for msg in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            for msg in self.chain().skip(1) {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

/// Attach context to errors (and turn `None` into an error).
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
    }

    #[test]
    fn context_wraps_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        // context on an already-anyhow Result (the Into<Error> reflexive case)
        let r2: Result<()> = Err(e);
        let e2 = r2.with_context(|| format!("round {}", 3)).unwrap_err();
        assert_eq!(format!("{e2:#}"), "round 3: reading manifest: gone");
        // Option -> Result
        let n: Option<usize> = None;
        assert_eq!(format!("{}", n.context("layers").unwrap_err()), "layers");
        assert_eq!(Some(5).context("layers").unwrap(), 5);
    }

    #[test]
    fn macros_format_and_return() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x > 1, "x too small: {x}");
            ensure!(x < 100);
            if x == 13 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(0).unwrap_err()), "x too small: 0");
        assert!(format!("{}", f(200).unwrap_err()).contains("x < 100"));
        assert_eq!(format!("{}", f(13).unwrap_err()), "unlucky 13");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn error_is_send_sync_debug() {
        fn assert_bounds<T: Send + Sync + std::fmt::Debug>(_t: &T) {}
        let e = anyhow!("a").context("b");
        assert_bounds(&e);
        let d = format!("{e:?}");
        assert!(d.contains('b') && d.contains("Caused by") && d.contains('a'));
    }
}
