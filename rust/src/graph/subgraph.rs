//! Client-side subgraph with halo expansion and pruning.
//!
//! Each federated client owns the vertices its partition assigned to it.
//! During setup it discovers its **pull candidates** (remote in-neighbours
//! of local vertices, via the embedding server's cross-edge directory) and
//! expands its local subgraph with the retained subset according to the
//! configured pruning policy (paper §4.1):
//!
//! * `None`      — retain all (EmbC / E)
//! * `Retention(i)` — uniform random, at most `i` remote in-neighbours per
//!   local vertex (P_i; P_0 ≡ default federated GNN, P_∞ ≡ E)
//! * `TopFrac`   — retain only the global top-f% of pull candidates by a
//!   supplied score (scored graph pruning, OPG / OPG_R / OPG_B / OPG_D)
//!
//! **Push nodes** are computed across clients after expansion: client k
//! pushes exactly the local vertices some other client retained as a pull
//! node — pruning on the consumer side shrinks the producer's push set,
//! which is how the paper's Fig 10 embedding counts fall with P_i.

use std::collections::HashMap;

use super::csr::Graph;
use super::partition::Partition;
use crate::util::rng::Rng;

/// Reference to a vertex inside a client's expanded subgraph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeRef {
    /// Index into `ClientSubgraph::local`.
    Local(u32),
    /// Index into `ClientSubgraph::remote`.
    Remote(u32),
}

/// Pruning policy applied during subgraph expansion.
#[derive(Clone, Debug)]
pub enum Prune {
    /// Keep every remote in-neighbour (E / EmbC).
    None,
    /// Uniform random retention limit per local vertex (P_i).
    Retention(usize),
    /// Keep the global top-`frac` of pull candidates ranked by `scores`
    /// (higher = better). Scores are keyed by global vertex id.
    TopFrac { frac: f64, scores: HashMap<u32, f32> },
}

#[derive(Clone, Debug)]
pub struct ClientSubgraph {
    pub client_id: usize,
    /// Global ids of local vertices (sorted ascending).
    pub local: Vec<u32>,
    /// Global ids of retained remote (pull) vertices.
    pub remote: Vec<u32>,
    global_to_local: HashMap<u32, u32>,
    global_to_remote: HashMap<u32, u32>,
    /// Per local vertex: local in-neighbours (indices into `local`).
    pub in_local: Vec<Vec<u32>>,
    /// Per local vertex: retained remote in-neighbours (indices into `remote`).
    pub in_remote: Vec<Vec<u32>>,
    /// Local indices of training vertices owned by this client.
    pub train_local: Vec<u32>,
    /// Global ids of local vertices some other client pulls (filled by
    /// `build_all` after every client's retention is known).
    pub push_nodes: Vec<u32>,
    /// Pull candidates before pruning (for Fig 2a / Fig 10 stats).
    pub pull_candidates: usize,
}

impl ClientSubgraph {
    pub fn n_local(&self) -> usize {
        self.local.len()
    }

    pub fn n_remote(&self) -> usize {
        self.remote.len()
    }

    pub fn local_index(&self, global: u32) -> Option<u32> {
        self.global_to_local.get(&global).copied()
    }

    pub fn remote_index(&self, global: u32) -> Option<u32> {
        self.global_to_remote.get(&global).copied()
    }

    /// Count of in-neighbours (local + retained remote) of a local vertex.
    pub fn in_degree(&self, lidx: u32) -> usize {
        self.in_local[lidx as usize].len() + self.in_remote[lidx as usize].len()
    }

    /// Fraction of local vertices with at least one retained remote
    /// in-neighbour (the paper's "% remote vertices" Fig 2a numerator is
    /// the remote side; this is the boundary-local view used in tests).
    pub fn boundary_fraction(&self) -> f64 {
        if self.local.is_empty() {
            return 0.0;
        }
        let b = self
            .in_remote
            .iter()
            .filter(|r| !r.is_empty())
            .count();
        b as f64 / self.local.len() as f64
    }
}

/// Build one client's expanded subgraph (push sets not yet known).
fn build_one(
    g: &Graph,
    part: &Partition,
    client_id: usize,
    prune: &Prune,
    seed: u64,
) -> ClientSubgraph {
    let mut rng = Rng::new(seed, 0x5B6 + client_id as u64);
    let local: Vec<u32> = (0..g.n as u32)
        .filter(|&v| part.assign[v as usize] == client_id as u32)
        .collect();
    let global_to_local: HashMap<u32, u32> = local
        .iter()
        .enumerate()
        .map(|(i, &v)| (v, i as u32))
        .collect();

    // Collect remote in-neighbours per local vertex (global ids).
    let mut remote_per_local: Vec<Vec<u32>> = Vec::with_capacity(local.len());
    let mut in_local: Vec<Vec<u32>> = Vec::with_capacity(local.len());
    let mut candidate_set = std::collections::HashSet::new();
    for &v in &local {
        let mut loc = Vec::new();
        let mut rem = Vec::new();
        for &u in g.inc.neighbors(v) {
            if part.assign[u as usize] == client_id as u32 {
                loc.push(global_to_local[&u]);
            } else {
                rem.push(u);
                candidate_set.insert(u);
            }
        }
        in_local.push(loc);
        remote_per_local.push(rem);
    }
    let pull_candidates = candidate_set.len();

    // Apply pruning to the remote edge lists.
    match prune {
        Prune::None => {}
        Prune::Retention(limit) => {
            for rem in remote_per_local.iter_mut() {
                if rem.len() > *limit {
                    let keep = rng.sample_indices(rem.len(), *limit);
                    let mut kept: Vec<u32> = keep.iter().map(|&i| rem[i]).collect();
                    kept.sort_unstable();
                    *rem = kept;
                }
            }
        }
        Prune::TopFrac { frac, scores } => {
            // Rank unique candidates by score; retain top-frac set.
            let mut cand: Vec<u32> = candidate_set.iter().copied().collect();
            cand.sort_unstable();
            cand.sort_by(|a, b| {
                let sa = scores.get(a).copied().unwrap_or(0.0);
                let sb = scores.get(b).copied().unwrap_or(0.0);
                sb.partial_cmp(&sa).unwrap().then(a.cmp(b))
            });
            let keep_n = ((cand.len() as f64) * frac).ceil() as usize;
            let keep: std::collections::HashSet<u32> =
                cand.into_iter().take(keep_n).collect();
            for rem in remote_per_local.iter_mut() {
                rem.retain(|v| keep.contains(v));
            }
        }
    }

    // Re-index retained remote vertices.
    let mut remote: Vec<u32> = remote_per_local
        .iter()
        .flat_map(|r| r.iter().copied())
        .collect::<std::collections::HashSet<u32>>()
        .into_iter()
        .collect();
    remote.sort_unstable();
    let global_to_remote: HashMap<u32, u32> = remote
        .iter()
        .enumerate()
        .map(|(i, &v)| (v, i as u32))
        .collect();
    let in_remote: Vec<Vec<u32>> = remote_per_local
        .iter()
        .map(|rem| rem.iter().map(|v| global_to_remote[v]).collect())
        .collect();

    let train_local: Vec<u32> = g
        .train_nodes
        .iter()
        .filter_map(|v| global_to_local.get(v).copied())
        .collect();

    ClientSubgraph {
        client_id,
        local,
        remote,
        global_to_local,
        global_to_remote,
        in_local,
        in_remote,
        train_local,
        push_nodes: Vec::new(),
        pull_candidates,
    }
}

/// Build every client's subgraph and resolve cross-client push sets:
/// client k's push nodes = union over k' != k of (k''s retained remote set
/// ∩ k's locals).
pub fn build_all(
    g: &Graph,
    part: &Partition,
    prune: &Prune,
    seed: u64,
) -> Vec<ClientSubgraph> {
    let prunes = vec![prune.clone(); part.k];
    build_all_per_client(g, part, &prunes, seed)
}

/// Like [`build_all`] but with a per-client pruning policy (scored pruning
/// uses client-specific frequency scores, paper §4.1.2).
pub fn build_all_per_client(
    g: &Graph,
    part: &Partition,
    prunes: &[Prune],
    seed: u64,
) -> Vec<ClientSubgraph> {
    assert_eq!(prunes.len(), part.k);
    let mut subs: Vec<ClientSubgraph> = (0..part.k)
        .map(|c| build_one(g, part, c, &prunes[c], seed))
        .collect();
    // owner lookup
    let mut push_sets: Vec<std::collections::HashSet<u32>> =
        vec![std::collections::HashSet::new(); part.k];
    for sub in &subs {
        for &r in &sub.remote {
            let owner = part.assign[r as usize] as usize;
            debug_assert_ne!(owner, sub.client_id);
            push_sets[owner].insert(r);
        }
    }
    for (c, sub) in subs.iter_mut().enumerate() {
        let mut p: Vec<u32> = push_sets[c].iter().copied().collect();
        p.sort_unstable();
        sub.push_nodes = p;
    }
    subs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny;
    use crate::graph::partition::metis_lite;

    fn setup(prune: &Prune) -> (Graph, Vec<ClientSubgraph>) {
        let g = tiny(11);
        let part = metis_lite(&g, 4, 2);
        let subs = build_all(&g, &part, prune, 5);
        (g, subs)
    }

    #[test]
    fn locals_cover_graph_exactly() {
        let (g, subs) = setup(&Prune::None);
        let total: usize = subs.iter().map(|s| s.n_local()).sum();
        assert_eq!(total, g.n);
        let mut seen = std::collections::HashSet::new();
        for s in &subs {
            for &v in &s.local {
                assert!(seen.insert(v));
            }
        }
    }

    #[test]
    fn remote_nodes_are_actually_remote_and_edges_exist() {
        let (g, subs) = setup(&Prune::None);
        for s in &subs {
            let locals: std::collections::HashSet<u32> = s.local.iter().copied().collect();
            for &r in &s.remote {
                assert!(!locals.contains(&r));
            }
            // every in_remote edge corresponds to a real graph edge
            for (li, rems) in s.in_remote.iter().enumerate() {
                let v = s.local[li];
                let gin: std::collections::HashSet<u32> =
                    g.inc.neighbors(v).iter().copied().collect();
                for &ri in rems {
                    assert!(gin.contains(&s.remote[ri as usize]));
                }
            }
        }
    }

    #[test]
    fn retention_limit_enforced() {
        for limit in [0usize, 1, 2, 4] {
            let (_, subs) = setup(&Prune::Retention(limit));
            for s in &subs {
                for rems in &s.in_remote {
                    assert!(rems.len() <= limit, "{} > {}", rems.len(), limit);
                }
                if limit == 0 {
                    assert_eq!(s.n_remote(), 0);
                    assert!(s.push_nodes.is_empty());
                }
            }
        }
    }

    #[test]
    fn retention_inf_equals_none() {
        let (_, a) = setup(&Prune::None);
        let (_, b) = setup(&Prune::Retention(usize::MAX));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.remote, y.remote);
            assert_eq!(x.push_nodes, y.push_nodes);
        }
    }

    #[test]
    fn top_frac_prunes_to_fraction() {
        let (_, full) = setup(&Prune::None);
        // score = global id (deterministic): top 25% keeps highest ids
        let mut scores = HashMap::new();
        for s in &full {
            for &r in &s.remote {
                scores.insert(r, r as f32);
            }
        }
        let (_, pruned) = setup(&Prune::TopFrac { frac: 0.25, scores });
        for (f, p) in full.iter().zip(&pruned) {
            assert!(p.n_remote() <= (f.n_remote() as f64 * 0.25).ceil() as usize + 1);
            // retained ids must be the top-scoring ones
            if p.n_remote() > 0 && f.n_remote() > 4 {
                let min_kept = *p.remote.iter().min().unwrap();
                let dropped_higher = f
                    .remote
                    .iter()
                    .filter(|&&r| r > min_kept && !p.remote.contains(&r))
                    .count();
                assert_eq!(dropped_higher, 0);
            }
        }
    }

    #[test]
    fn push_sets_mirror_pull_sets() {
        let (g, subs) = setup(&Prune::Retention(2));
        // every remote of client c must appear in its owner's push set
        for s in &subs {
            for &r in &s.remote {
                let owner = subs
                    .iter()
                    .position(|o| o.local_index(r).is_some())
                    .expect("owner exists");
                assert!(subs[owner].push_nodes.contains(&r));
            }
        }
        // every push node must be pulled by someone
        for s in &subs {
            for &p in &s.push_nodes {
                let pulled = subs
                    .iter()
                    .any(|o| o.client_id != s.client_id && o.remote.contains(&p));
                assert!(pulled);
            }
        }
        let _ = g;
    }

    #[test]
    fn train_locals_are_train_vertices() {
        let (g, subs) = setup(&Prune::None);
        let train: std::collections::HashSet<u32> = g.train_nodes.iter().copied().collect();
        let total: usize = subs.iter().map(|s| s.train_local.len()).sum();
        assert_eq!(total, g.train_nodes.len());
        for s in &subs {
            for &t in &s.train_local {
                assert!(train.contains(&s.local[t as usize]));
            }
        }
    }
}
