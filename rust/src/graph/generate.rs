//! Synthetic graph generator standing in for the paper's OGBN/Reddit
//! datasets (unavailable offline; see DESIGN.md §3 substitutions).
//!
//! The generator plants `communities` groups, assigns each vertex a label
//! from its community, wires edges with probability `homophily` inside the
//! community (preferentially toward community hubs) and otherwise across
//! the whole graph, and synthesizes features as *weak noisy projections* of
//! the label embedding:
//!
//! `x_u = signal * e(label_u) + sqrt(1 - signal^2) * noise`
//!
//! With a small `signal`, feature-only prediction is weak while
//! neighbourhood aggregation (mostly same-community neighbours) averages
//! the noise away — so a GNN beats an MLP, dropping cross-client
//! neighbours hurts (the paper's D-vs-E gap), and the hurt grows with
//! density, reproducing the Reddit ≫ Arxiv sensitivity ordering.

use super::csr::{Csr, Graph};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct GenParams {
    pub n: usize,
    pub avg_degree: f64,
    pub communities: usize,
    pub classes: usize,
    pub feat_dim: usize,
    /// Probability an edge stays inside the community.
    pub homophily: f64,
    /// Power-law skew of hub popularity (higher = more skewed).
    pub hub_alpha: f64,
    /// Feature signal strength in [0, 1].
    pub signal: f64,
    /// Strength of the per-community (class-irrelevant) bias direction
    /// added to every member's features. Within a silo, neighbours share
    /// the bias so local aggregation cannot cancel it; remote neighbours
    /// from sibling communities of the same class can — this is what makes
    /// cross-client embeddings carry irrecoverable signal (the paper's
    /// D-vs-E accuracy gap; silos in real federations are distribution-
    /// shifted in exactly this way).
    pub community_bias: f64,
    pub train_frac: f64,
    pub test_frac: f64,
    pub seed: u64,
}

impl Default for GenParams {
    fn default() -> Self {
        Self {
            n: 1000,
            avg_degree: 8.0,
            communities: 8,
            classes: 8,
            feat_dim: 32,
            homophily: 0.8,
            hub_alpha: 1.6,
            signal: 0.35,
            community_bias: 0.0,
            train_frac: 0.5,
            test_frac: 0.2,
            seed: 1,
        }
    }
}

/// Random unit vectors, one per class, shared across the dataset.
fn class_embeddings(classes: usize, dim: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
    (0..classes)
        .map(|_| {
            let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
            v.iter_mut().for_each(|x| *x /= norm);
            v
        })
        .collect()
}

pub fn generate(p: &GenParams) -> Graph {
    assert!(p.n > 0 && p.communities > 0 && p.classes > 0);
    let mut rng = Rng::new(p.seed, 0xFEED);

    // --- community assignment: contiguous balanced blocks, then shuffled
    // ids so partitioners can't trivially exploit vertex order.
    let mut comm = vec![0u32; p.n];
    for (v, c) in comm.iter_mut().enumerate() {
        *c = (v * p.communities / p.n) as u32;
    }
    let mut perm: Vec<u32> = (0..p.n as u32).collect();
    rng.shuffle(&mut perm);
    let mut comm_of = vec![0u32; p.n];
    for (orig, &newid) in perm.iter().enumerate() {
        comm_of[newid as usize] = comm[orig];
    }

    // Index vertices per community for intra-community targeting.
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); p.communities];
    for v in 0..p.n as u32 {
        members[comm_of[v as usize] as usize].push(v);
    }

    // --- edges: per-vertex out-degree ~ 1 + powerlaw with the requested
    // mean; targets preferential within community, uniform-ish across.
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity((p.n as f64 * p.avg_degree) as usize);
    let mut seen = std::collections::HashSet::new();
    for v in 0..p.n as u32 {
        // degree: mixture keeps a fat tail but matches the mean
        let base = p.avg_degree.max(1.0);
        let deg = if rng.chance(0.9) {
            1 + rng.below((base * 1.6) as usize + 1)
        } else {
            // hub: up to ~8x mean
            1 + rng.below((base * 8.0) as usize + 1)
        };
        seen.clear();
        let my = comm_of[v as usize] as usize;
        for _ in 0..deg {
            let intra = rng.chance(p.homophily);
            let t = if intra {
                // half uniform within the community, half toward community
                // hubs — keeps typical vertices' IN-neighbourhoods
                // homophilous (pure hub-targeting would concentrate all
                // intra in-edges on a few hubs and let the cross-community
                // edges dominate everyone else's in-degree).
                let m = &members[my];
                if rng.chance(0.5) {
                    m[rng.below(m.len())]
                } else {
                    m[rng.powerlaw(m.len(), p.hub_alpha)]
                }
            } else {
                // cross-community edges prefer global hubs too, so the
                // noise edges concentrate instead of polluting every
                // vertex's in-neighbourhood uniformly.
                rng.powerlaw(p.n, 1.3) as u32
            };
            if t != v && seen.insert(t) {
                edges.push((v, t));
            }
        }
    }

    let out = Csr::from_edges(p.n, &edges);
    let inc = out.reversed(p.n);

    // --- labels & features
    let class_emb = class_embeddings(p.classes, p.feat_dim, &mut rng);
    let comm_bias = class_embeddings(p.communities, p.feat_dim, &mut rng);
    let mut labels = vec![0u16; p.n];
    let mut features = vec![0f32; p.n * p.feat_dim];
    let s = p.signal as f32;
    let cb = p.community_bias as f32;
    let noise_scale = (1.0 - (p.signal * p.signal)).max(0.0).sqrt() as f32;
    for v in 0..p.n {
        let label = (comm_of[v] as usize * p.classes / p.communities) as u16;
        labels[v] = label;
        let e = &class_emb[label as usize];
        let b = &comm_bias[comm_of[v] as usize];
        let row = &mut features[v * p.feat_dim..(v + 1) * p.feat_dim];
        for (j, x) in row.iter_mut().enumerate() {
            *x = s * e[j] + cb * b[j] + noise_scale * rng.normal() as f32;
        }
    }

    // --- train/test split (disjoint)
    let mut order: Vec<u32> = (0..p.n as u32).collect();
    rng.shuffle(&mut order);
    let n_train = ((p.n as f64) * p.train_frac) as usize;
    let n_test = ((p.n as f64) * p.test_frac) as usize;
    let train_nodes = order[..n_train].to_vec();
    let test_nodes = order[n_train..(n_train + n_test).min(p.n)].to_vec();

    let g = Graph {
        n: p.n,
        out,
        inc,
        feat_dim: p.feat_dim,
        classes: p.classes,
        features,
        labels,
        train_nodes,
        test_nodes,
    };
    debug_assert!(g.validate().is_ok(), "{:?}", g.validate());
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_valid_graph() {
        let g = generate(&GenParams::default());
        g.validate().unwrap();
        assert_eq!(g.n, 1000);
        assert!(g.avg_in_degree() > 3.0, "deg={}", g.avg_in_degree());
    }

    #[test]
    fn deterministic_for_same_seed() {
        let a = generate(&GenParams::default());
        let b = generate(&GenParams::default());
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.out.targets, b.out.targets);
        assert_eq!(a.features, b.features);
        let c = generate(&GenParams {
            seed: 2,
            ..GenParams::default()
        });
        assert_ne!(a.out.targets, c.out.targets);
    }

    #[test]
    fn homophily_shapes_edges() {
        let p = GenParams {
            n: 4000,
            homophily: 0.95,
            ..GenParams::default()
        };
        let g = generate(&p);
        let mut intra = 0usize;
        let mut total = 0usize;
        for v in 0..g.n as u32 {
            for &t in g.out.neighbors(v) {
                total += 1;
                if g.labels[v as usize] == g.labels[t as usize] {
                    intra += 1;
                }
            }
        }
        let frac = intra as f64 / total as f64;
        assert!(frac > 0.8, "intra fraction {frac}");
    }

    #[test]
    fn splits_disjoint_and_sized() {
        let g = generate(&GenParams::default());
        let train: std::collections::HashSet<_> = g.train_nodes.iter().collect();
        assert!(g.test_nodes.iter().all(|v| !train.contains(v)));
        assert!((g.train_nodes.len() as f64 - 500.0).abs() < 2.0);
        assert!((g.test_nodes.len() as f64 - 200.0).abs() < 2.0);
    }

    #[test]
    fn features_correlate_with_labels() {
        // Mean feature of same-label vertices should align with the class
        // embedding better than chance: check intra-class cosine > 0.
        let g = generate(&GenParams {
            n: 2000,
            signal: 0.5,
            ..GenParams::default()
        });
        let d = g.feat_dim;
        let mut class_mean = vec![vec![0f32; d]; g.classes];
        let mut counts = vec![0f32; g.classes];
        for v in 0..g.n {
            let l = g.labels[v] as usize;
            counts[l] += 1.0;
            for j in 0..d {
                class_mean[l][j] += g.features[v * d + j];
            }
        }
        for (l, m) in class_mean.iter_mut().enumerate() {
            m.iter_mut().for_each(|x| *x /= counts[l].max(1.0));
        }
        // class means should be separated: average pairwise cosine << self norm
        let norm = |v: &[f32]| v.iter().map(|x| x * x).sum::<f32>().sqrt();
        let mut self_norms = 0.0;
        for m in &class_mean {
            self_norms += norm(m);
        }
        assert!(self_norms / g.classes as f32 > 0.1);
    }
}
