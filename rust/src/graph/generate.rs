//! Synthetic graph generator standing in for the paper's OGBN/Reddit
//! datasets (unavailable offline; see DESIGN.md §3 substitutions).
//!
//! The generator plants `communities` groups, assigns each vertex a label
//! from its community, wires edges with probability `homophily` inside the
//! community (preferentially toward community hubs) and otherwise across
//! the whole graph, and synthesizes features as *weak noisy projections* of
//! the label embedding:
//!
//! `x_u = signal * e(label_u) + sqrt(1 - signal^2) * noise`
//!
//! With a small `signal`, feature-only prediction is weak while
//! neighbourhood aggregation (mostly same-community neighbours) averages
//! the noise away — so a GNN beats an MLP, dropping cross-client
//! neighbours hurts (the paper's D-vs-E gap), and the hurt grows with
//! density, reproducing the Reddit ≫ Arxiv sensitivity ordering.

use std::path::Path;

use anyhow::{ensure, Result};

use super::csr::{Csr, Graph};
use crate::storage::format::EdgeScatter;
use crate::storage::{GraphFileInfo, GraphFileWriter};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct GenParams {
    pub n: usize,
    pub avg_degree: f64,
    pub communities: usize,
    pub classes: usize,
    pub feat_dim: usize,
    /// Probability an edge stays inside the community.
    pub homophily: f64,
    /// Power-law skew of hub popularity (higher = more skewed).
    pub hub_alpha: f64,
    /// Feature signal strength in [0, 1].
    pub signal: f64,
    /// Strength of the per-community (class-irrelevant) bias direction
    /// added to every member's features. Within a silo, neighbours share
    /// the bias so local aggregation cannot cancel it; remote neighbours
    /// from sibling communities of the same class can — this is what makes
    /// cross-client embeddings carry irrecoverable signal (the paper's
    /// D-vs-E accuracy gap; silos in real federations are distribution-
    /// shifted in exactly this way).
    pub community_bias: f64,
    pub train_frac: f64,
    pub test_frac: f64,
    pub seed: u64,
}

impl Default for GenParams {
    fn default() -> Self {
        Self {
            n: 1000,
            avg_degree: 8.0,
            communities: 8,
            classes: 8,
            feat_dim: 32,
            homophily: 0.8,
            hub_alpha: 1.6,
            signal: 0.35,
            community_bias: 0.0,
            train_frac: 0.5,
            test_frac: 0.2,
            seed: 1,
        }
    }
}

/// Random unit vectors, one per class, shared across the dataset.
fn class_embeddings(classes: usize, dim: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
    (0..classes)
        .map(|_| {
            let mut v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-6);
            v.iter_mut().for_each(|x| *x /= norm);
            v
        })
        .collect()
}

/// Community layout shared by both generation paths: contiguous balanced
/// blocks, then shuffled ids so partitioners can't trivially exploit
/// vertex order, plus the per-community member index used for
/// intra-community targeting.
struct Communities {
    comm_of: Vec<u32>,
    members: Vec<Vec<u32>>,
}

fn community_setup(p: &GenParams, rng: &mut Rng) -> Communities {
    let mut comm = vec![0u32; p.n];
    for (v, c) in comm.iter_mut().enumerate() {
        *c = (v * p.communities / p.n) as u32;
    }
    let mut perm: Vec<u32> = (0..p.n as u32).collect();
    rng.shuffle(&mut perm);
    let mut comm_of = vec![0u32; p.n];
    for (orig, &newid) in perm.iter().enumerate() {
        comm_of[newid as usize] = comm[orig];
    }
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); p.communities];
    for v in 0..p.n as u32 {
        members[comm_of[v as usize] as usize].push(v);
    }
    Communities { comm_of, members }
}

/// Drive the per-vertex degree + target draws, invoking `emit(src, dst)`
/// for every surviving (deduplicated, loop-free) edge in ascending
/// source order. [`generate`] and [`generate_to_file`] run this exact
/// code, so their rng consumption — and therefore the emitted edge
/// sequence — is identical.
fn emit_edges(
    p: &GenParams,
    comm: &Communities,
    rng: &mut Rng,
    mut emit: impl FnMut(u32, u32) -> Result<()>,
) -> Result<()> {
    let mut seen = std::collections::HashSet::new();
    for v in 0..p.n as u32 {
        // degree: mixture keeps a fat tail but matches the mean
        let base = p.avg_degree.max(1.0);
        let deg = if rng.chance(0.9) {
            1 + rng.below((base * 1.6) as usize + 1)
        } else {
            // hub: up to ~8x mean
            1 + rng.below((base * 8.0) as usize + 1)
        };
        seen.clear();
        let my = comm.comm_of[v as usize] as usize;
        for _ in 0..deg {
            let intra = rng.chance(p.homophily);
            let t = if intra {
                // half uniform within the community, half toward community
                // hubs — keeps typical vertices' IN-neighbourhoods
                // homophilous (pure hub-targeting would concentrate all
                // intra in-edges on a few hubs and let the cross-community
                // edges dominate everyone else's in-degree).
                let m = &comm.members[my];
                if rng.chance(0.5) {
                    m[rng.below(m.len())]
                } else {
                    m[rng.powerlaw(m.len(), p.hub_alpha)]
                }
            } else {
                // cross-community edges prefer global hubs too, so the
                // noise edges concentrate instead of polluting every
                // vertex's in-neighbourhood uniformly.
                rng.powerlaw(p.n, 1.3) as u32
            };
            if t != v && seen.insert(t) {
                emit(v, t)?;
            }
        }
    }
    Ok(())
}

fn label_of(comm: u32, p: &GenParams) -> u16 {
    (comm as usize * p.classes / p.communities) as u16
}

fn fill_feature_row(
    row: &mut [f32],
    class_emb: &[f32],
    comm_bias: &[f32],
    s: f32,
    cb: f32,
    noise_scale: f32,
    rng: &mut Rng,
) {
    for (j, x) in row.iter_mut().enumerate() {
        *x = s * class_emb[j] + cb * comm_bias[j] + noise_scale * rng.normal() as f32;
    }
}

/// Disjoint train/test split over a shuffled vertex order.
fn train_test_split(p: &GenParams, rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
    let mut order: Vec<u32> = (0..p.n as u32).collect();
    rng.shuffle(&mut order);
    let n_train = ((p.n as f64) * p.train_frac) as usize;
    let n_test = ((p.n as f64) * p.test_frac) as usize;
    let train_nodes = order[..n_train].to_vec();
    let test_nodes = order[n_train..(n_train + n_test).min(p.n)].to_vec();
    (train_nodes, test_nodes)
}

pub fn generate(p: &GenParams) -> Graph {
    assert!(p.n > 0 && p.communities > 0 && p.classes > 0);
    let mut rng = Rng::new(p.seed, 0xFEED);
    let comm = community_setup(p, &mut rng);

    // --- edges: per-vertex out-degree ~ 1 + powerlaw with the requested
    // mean; targets preferential within community, uniform-ish across.
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity((p.n as f64 * p.avg_degree) as usize);
    emit_edges(p, &comm, &mut rng, |v, t| {
        edges.push((v, t));
        Ok(())
    })
    .expect("in-RAM edge emission cannot fail");

    let out = Csr::from_edges(p.n, &edges);
    let inc = out.reversed();

    // --- labels & features
    let class_emb = class_embeddings(p.classes, p.feat_dim, &mut rng);
    let comm_bias = class_embeddings(p.communities, p.feat_dim, &mut rng);
    let mut labels = vec![0u16; p.n];
    let mut features = vec![0f32; p.n * p.feat_dim];
    let s = p.signal as f32;
    let cb = p.community_bias as f32;
    let noise_scale = (1.0 - (p.signal * p.signal)).max(0.0).sqrt() as f32;
    for v in 0..p.n {
        let label = label_of(comm.comm_of[v], p);
        labels[v] = label;
        let row = &mut features[v * p.feat_dim..(v + 1) * p.feat_dim];
        fill_feature_row(
            row,
            &class_emb[label as usize],
            &comm_bias[comm.comm_of[v] as usize],
            s,
            cb,
            noise_scale,
            &mut rng,
        );
    }

    // --- train/test split (disjoint)
    let (train_nodes, test_nodes) = train_test_split(p, &mut rng);

    let g = Graph {
        n: p.n,
        out,
        inc,
        feat_dim: p.feat_dim,
        classes: p.classes,
        features: features.into(),
        labels: labels.into(),
        train_nodes,
        test_nodes,
    };
    debug_assert!(g.validate().is_ok(), "{:?}", g.validate());
    g
}

/// Exclusive prefix sum of per-vertex degrees into CSR offsets.
fn prefix_sum(degs: &[u32]) -> Result<Vec<u32>> {
    let mut offsets = Vec::with_capacity(degs.len() + 1);
    let mut acc = 0u64;
    offsets.push(0u32);
    for &d in degs {
        acc += d as u64;
        ensure!(
            acc <= u32::MAX as u64,
            "edge count {acc} exceeds the u32 offset format"
        );
        offsets.push(acc as u32);
    }
    Ok(offsets)
}

/// Stream a synthetic graph straight into a `GraphFile` at `path`
/// without ever materializing the edge list or feature matrix in RAM
/// (DESIGN.md §13.1). Pass 1 replays the edge draws on a cloned rng to
/// count degrees; pass 2 re-draws the same edges with the main rng,
/// writing out-targets sequentially (emission is source-ordered, which
/// *is* out-CSR order) while scattering `(dst, src)` pairs through the
/// external-memory [`EdgeScatter`] for the incoming direction. Features
/// are synthesized one row at a time into the features section. The
/// resulting file is bit-identical to `write_graph_file` over
/// [`generate`] with the same params.
pub fn generate_to_file(p: &GenParams, path: &Path) -> Result<GraphFileInfo> {
    ensure!(
        p.n > 0 && p.communities > 0 && p.classes > 0,
        "degenerate GenParams (n/communities/classes must be positive)"
    );
    let mut rng = Rng::new(p.seed, 0xFEED);
    let comm = community_setup(p, &mut rng);

    // --- pass 1: count final (post-dedup) degrees on a cloned rng.
    let mut out_deg = vec![0u32; p.n];
    let mut in_deg = vec![0u32; p.n];
    emit_edges(p, &comm, &mut rng.clone(), |v, t| {
        out_deg[v as usize] += 1;
        in_deg[t as usize] += 1;
        Ok(())
    })?;
    let out_offsets = prefix_sum(&out_deg)?;
    let in_offsets = prefix_sum(&in_deg)?;
    drop(out_deg);
    drop(in_deg);
    let m = *out_offsets.last().expect("n+1 offsets") as usize;

    let n_train = ((p.n as f64) * p.train_frac) as usize;
    let n_test = ((p.n as f64) * p.test_frac) as usize;
    let test_len = (n_train + n_test).min(p.n) - n_train;

    let mut w = GraphFileWriter::create(path, p.n, m, p.feat_dim, p.classes, n_train, test_len)?;
    w.section_u32s(0, &out_offsets)?;
    drop(out_offsets);

    // --- pass 2: re-draw the same edges with the main rng.
    let mut scatter = EdgeScatter::new(in_offsets.clone(), 64 << 20);
    w.begin_section(1)?;
    {
        let mut buf: Vec<u32> = Vec::with_capacity(4096);
        emit_edges(p, &comm, &mut rng, |v, t| {
            buf.push(t);
            if buf.len() >= 4096 {
                w.put_u32s(&buf)?;
                buf.clear();
            }
            scatter.push(t, v)
        })?;
        w.put_u32s(&buf)?;
    }
    w.end_section()?;

    w.section_u32s(2, &in_offsets)?;
    drop(in_offsets);
    w.begin_section(3)?;
    scatter.finalize(&mut |chunk| w.put_u32s(chunk))?;
    w.end_section()?;

    // --- labels & features, one row in RAM at a time.
    let class_emb = class_embeddings(p.classes, p.feat_dim, &mut rng);
    let comm_bias = class_embeddings(p.communities, p.feat_dim, &mut rng);
    let s = p.signal as f32;
    let cb = p.community_bias as f32;
    let noise_scale = (1.0 - (p.signal * p.signal)).max(0.0).sqrt() as f32;
    let mut labels: Vec<u16> = Vec::with_capacity(p.n);
    let mut row = vec![0f32; p.feat_dim];
    w.begin_section(4)?;
    for v in 0..p.n {
        let label = label_of(comm.comm_of[v], p);
        labels.push(label);
        fill_feature_row(
            &mut row,
            &class_emb[label as usize],
            &comm_bias[comm.comm_of[v] as usize],
            s,
            cb,
            noise_scale,
            &mut rng,
        );
        w.put_f32s(&row)?;
    }
    w.end_section()?;
    w.begin_section(5)?;
    w.put_u16s(&labels)?;
    w.end_section()?;

    let (train_nodes, test_nodes) = train_test_split(p, &mut rng);
    w.section_u32s(6, &train_nodes)?;
    w.section_u32s(7, &test_nodes)?;
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_valid_graph() {
        let g = generate(&GenParams::default());
        g.validate().unwrap();
        assert_eq!(g.n, 1000);
        assert!(g.avg_in_degree() > 3.0, "deg={}", g.avg_in_degree());
    }

    #[test]
    fn deterministic_for_same_seed() {
        let a = generate(&GenParams::default());
        let b = generate(&GenParams::default());
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.out.targets, b.out.targets);
        assert_eq!(a.features, b.features);
        let c = generate(&GenParams {
            seed: 2,
            ..GenParams::default()
        });
        assert_ne!(a.out.targets, c.out.targets);
    }

    #[test]
    fn homophily_shapes_edges() {
        let p = GenParams {
            n: 4000,
            homophily: 0.95,
            ..GenParams::default()
        };
        let g = generate(&p);
        let mut intra = 0usize;
        let mut total = 0usize;
        for v in 0..g.n as u32 {
            for &t in g.out.neighbors(v) {
                total += 1;
                if g.labels[v as usize] == g.labels[t as usize] {
                    intra += 1;
                }
            }
        }
        let frac = intra as f64 / total as f64;
        assert!(frac > 0.8, "intra fraction {frac}");
    }

    #[test]
    fn splits_disjoint_and_sized() {
        let g = generate(&GenParams::default());
        let train: std::collections::HashSet<_> = g.train_nodes.iter().collect();
        assert!(g.test_nodes.iter().all(|v| !train.contains(v)));
        assert!((g.train_nodes.len() as f64 - 500.0).abs() < 2.0);
        assert!((g.test_nodes.len() as f64 - 200.0).abs() < 2.0);
    }

    #[test]
    fn streamed_generation_matches_in_memory_bit_exactly() {
        let p = GenParams {
            n: 500,
            community_bias: 0.3,
            ..GenParams::default()
        };
        let g = generate(&p);
        let path =
            std::env::temp_dir().join(format!("optimes-gen-stream-{}.graph", std::process::id()));
        let info = generate_to_file(&p, &path).unwrap();
        assert_eq!(info.m, g.out.m());
        let h = crate::storage::load_graph_file(&path, crate::storage::GraphBackend::Ram).unwrap();
        assert_eq!(g.out.offsets, h.out.offsets);
        assert_eq!(g.out.targets, h.out.targets);
        assert_eq!(g.inc.offsets, h.inc.offsets);
        assert_eq!(g.inc.targets, h.inc.targets);
        assert_eq!(g.features, h.features);
        assert_eq!(g.labels, h.labels);
        assert_eq!(g.train_nodes, h.train_nodes);
        assert_eq!(g.test_nodes, h.test_nodes);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn features_correlate_with_labels() {
        // Mean feature of same-label vertices should align with the class
        // embedding better than chance: check intra-class cosine > 0.
        let g = generate(&GenParams {
            n: 2000,
            signal: 0.5,
            ..GenParams::default()
        });
        let d = g.feat_dim;
        let mut class_mean = vec![vec![0f32; d]; g.classes];
        let mut counts = vec![0f32; g.classes];
        for v in 0..g.n {
            let l = g.labels[v] as usize;
            counts[l] += 1.0;
            for j in 0..d {
                class_mean[l][j] += g.features[v * d + j];
            }
        }
        for (l, m) in class_mean.iter_mut().enumerate() {
            m.iter_mut().for_each(|x| *x /= counts[l].max(1.0));
        }
        // class means should be separated: average pairwise cosine << self norm
        let norm = |v: &[f32]| v.iter().map(|x| x * x).sum::<f32>().sqrt();
        let mut self_norms = 0.0;
        for m in &class_mean {
            self_norms += norm(m);
        }
        assert!(self_norms / g.classes as f32 > 0.1);
    }
}
