//! k-way balanced edge-cut partitioner (METIS stand-in; see DESIGN.md §3).
//!
//! Algorithm ("METIS-lite"):
//! 1. seed k parts with spread-out vertices (greedy max-distance seeding
//!    over BFS layers),
//! 2. grow parts greedily: repeatedly assign the highest-gain (most
//!    internal edges) frontier vertex to the smallest eligible part,
//!    respecting a balance cap `ceil(n/k) * (1 + slack)`,
//! 3. one boundary-refinement sweep: move a vertex to the neighbouring
//!    part with the largest cut-gain if balance allows.
//!
//! Also provides a hash partitioner (maximum-cut baseline used in
//! ablations, mirroring "random partitioning" comparisons).

use super::csr::Graph;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Partition {
    pub k: usize,
    /// part id per vertex
    pub assign: Vec<u32>,
}

impl Partition {
    pub fn sizes(&self) -> Vec<usize> {
        let mut s = vec![0usize; self.k];
        for &p in &self.assign {
            s[p as usize] += 1;
        }
        s
    }

    /// Fraction of (directed) edges whose endpoints live in different parts.
    pub fn cut_fraction(&self, g: &Graph) -> f64 {
        let mut cut = 0usize;
        for v in 0..g.n as u32 {
            for &t in g.out.neighbors(v) {
                if self.assign[v as usize] != self.assign[t as usize] {
                    cut += 1;
                }
            }
        }
        cut as f64 / g.out.m().max(1) as f64
    }

    pub fn imbalance(&self) -> f64 {
        let sizes = self.sizes();
        let max = *sizes.iter().max().unwrap_or(&0) as f64;
        let avg = self.assign.len() as f64 / self.k as f64;
        if avg == 0.0 {
            0.0
        } else {
            max / avg
        }
    }
}

/// Hash partitioner: uniform random assignment (worst-case edge cut).
/// Delegates to the streaming implementation — same rng stream, same
/// assignments as ever.
pub fn hash_partition(g: &Graph, k: usize, seed: u64) -> Partition {
    crate::storage::hash_partition_n(g.n, k, seed)
}

/// Which partitioner a session uses to split the graph across clients
/// (env `OPTIMES_PARTITIONER`, CLI `run --partitioner`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PartitionerKind {
    /// In-RAM balanced greedy edge-cut (the default; needs the CSR).
    #[default]
    Metis,
    /// Uniform random (max-cut baseline; streaming, needs only n).
    Hash,
    /// Linear deterministic greedy (streaming edge-cut; one adjacency
    /// pass, works straight off a `GraphFile`).
    Ldg,
}

impl PartitionerKind {
    pub fn parse(s: &str) -> anyhow::Result<PartitionerKind> {
        match s {
            "metis" => Ok(PartitionerKind::Metis),
            "hash" => Ok(PartitionerKind::Hash),
            "ldg" => Ok(PartitionerKind::Ldg),
            other => anyhow::bail!("unknown partitioner {other:?} (expected metis|hash|ldg)"),
        }
    }

    /// Resolve from `OPTIMES_PARTITIONER` (default `metis`). Panics on
    /// an unparseable value rather than silently falling back.
    pub fn from_env() -> PartitionerKind {
        match std::env::var("OPTIMES_PARTITIONER") {
            Ok(v) => PartitionerKind::parse(&v).expect("OPTIMES_PARTITIONER"),
            Err(_) => PartitionerKind::Metis,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PartitionerKind::Metis => "metis",
            PartitionerKind::Hash => "hash",
            PartitionerKind::Ldg => "ldg",
        }
    }

    /// Run this partitioner over a loaded graph (either backend).
    pub fn partition(&self, g: &Graph, k: usize, seed: u64) -> Partition {
        match self {
            PartitionerKind::Metis => metis_lite(g, k, seed),
            PartitionerKind::Hash => hash_partition(g, k, seed),
            PartitionerKind::Ldg => crate::storage::ldg_partition_graph(g, k, seed)
                .expect("ldg over a validated in-RAM graph cannot fail"),
        }
    }
}

/// Balanced greedy edge-cut partitioner.
pub fn metis_lite(g: &Graph, k: usize, seed: u64) -> Partition {
    assert!(k >= 1 && g.n >= k);
    let n = g.n;
    let cap = (n + k - 1) / k + (n / k / 20).max(1); // ~5% slack
    let mut rng = Rng::new(seed, 0x4D45);
    const UNASSIGNED: u32 = u32::MAX;
    let mut assign = vec![UNASSIGNED; n];
    let mut sizes = vec![0usize; k];

    // --- seeding: first seed random, others greedily far (BFS distance)
    let mut seeds = Vec::with_capacity(k);
    seeds.push(rng.below(n) as u32);
    let mut dist = vec![u32::MAX; n];
    for _ in 1..k {
        // multi-source BFS from current seeds over undirected adjacency
        for d in dist.iter_mut() {
            *d = u32::MAX;
        }
        let mut queue = std::collections::VecDeque::new();
        for &s in &seeds {
            dist[s as usize] = 0;
            queue.push_back(s);
        }
        let mut far = seeds[0];
        while let Some(v) = queue.pop_front() {
            far = v;
            let dv = dist[v as usize];
            for &t in g.out.neighbors(v).iter().chain(g.inc.neighbors(v)) {
                if dist[t as usize] == u32::MAX {
                    dist[t as usize] = dv + 1;
                    queue.push_back(t);
                }
            }
        }
        // prefer an unreached vertex (disconnected component), else farthest
        let next = (0..n as u32)
            .find(|&v| dist[v as usize] == u32::MAX && !seeds.contains(&v))
            .unwrap_or(far);
        seeds.push(next);
    }
    for (p, &s) in seeds.iter().enumerate() {
        assign[s as usize] = p as u32;
        sizes[p] += 1;
    }

    // --- greedy growth: each part keeps a frontier; rotate over parts
    // (smallest first) claiming the frontier vertex with max internal gain.
    let mut frontiers: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (p, &s) in seeds.iter().enumerate() {
        for &t in g.out.neighbors(s).iter().chain(g.inc.neighbors(s)) {
            frontiers[p].push(t);
        }
    }
    let mut assigned = k;
    let mut stall = 0usize;
    while assigned < n {
        // pick the smallest non-full part
        let p = (0..k)
            .filter(|&p| sizes[p] < cap)
            .min_by_key(|&p| sizes[p])
            .unwrap_or(0);
        // best frontier vertex for p by internal-edge gain
        let mut best: Option<(usize, i64)> = None; // (frontier idx, gain)
        let flen = frontiers[p].len();
        let scan = flen.min(64); // bounded scan keeps growth near-linear
        for probe in 0..scan {
            let i = flen - 1 - probe;
            let v = frontiers[p][i];
            if assign[v as usize] != UNASSIGNED {
                continue;
            }
            let gain = g
                .out
                .neighbors(v)
                .iter()
                .chain(g.inc.neighbors(v))
                .filter(|&&t| assign[t as usize] == p as u32)
                .count() as i64;
            if best.map(|(_, bg)| gain > bg).unwrap_or(true) {
                best = Some((i, gain));
            }
        }
        let v = match best {
            Some((i, _)) => frontiers[p].swap_remove(i),
            None => {
                // frontier exhausted/stale: pull a random unassigned vertex
                stall += 1;
                let mut v = rng.below(n) as u32;
                let mut tries = 0;
                while assign[v as usize] != UNASSIGNED && tries < 64 {
                    v = rng.below(n) as u32;
                    tries += 1;
                }
                if assign[v as usize] != UNASSIGNED {
                    match (0..n as u32).find(|&u| assign[u as usize] == UNASSIGNED) {
                        Some(u) => u,
                        None => break,
                    }
                } else {
                    v
                }
            }
        };
        if assign[v as usize] != UNASSIGNED {
            continue;
        }
        assign[v as usize] = p as u32;
        sizes[p] += 1;
        assigned += 1;
        for &t in g.out.neighbors(v).iter().chain(g.inc.neighbors(v)) {
            if assign[t as usize] == UNASSIGNED {
                frontiers[p].push(t);
            }
        }
        if stall > n * 4 {
            break; // safety: should not happen
        }
    }
    // any leftovers (disconnected) -> smallest part
    for v in 0..n {
        if assign[v] == UNASSIGNED {
            let p = (0..k).min_by_key(|&p| sizes[p]).unwrap();
            assign[v] = p as u32;
            sizes[p] += 1;
        }
    }

    // --- refinement sweep
    let mut part = Partition { k, assign };
    refine(g, &mut part, cap);
    part
}

/// One boundary refinement sweep: move vertices to the neighbouring part
/// with maximal cut gain when balance allows.
fn refine(g: &Graph, part: &mut Partition, cap: usize) {
    let k = part.k;
    let mut sizes = part.sizes();
    let mut counts = vec![0i64; k];
    for v in 0..g.n as u32 {
        let cur = part.assign[v as usize] as usize;
        if sizes[cur] <= 1 {
            continue;
        }
        for c in counts.iter_mut() {
            *c = 0;
        }
        let mut boundary = false;
        for &t in g.out.neighbors(v).iter().chain(g.inc.neighbors(v)) {
            let tp = part.assign[t as usize] as usize;
            counts[tp] += 1;
            if tp != cur {
                boundary = true;
            }
        }
        if !boundary {
            continue;
        }
        let (best, best_cnt) = counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(i, &c)| (i, c))
            .unwrap();
        if best != cur && best_cnt > counts[cur] && sizes[best] < cap {
            part.assign[v as usize] = best as u32;
            sizes[cur] -= 1;
            sizes[best] += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny;

    #[test]
    fn metis_lite_balanced_and_better_than_hash() {
        let g = tiny(1);
        for k in [2, 4] {
            let p = metis_lite(&g, k, 7);
            assert_eq!(p.assign.len(), g.n);
            assert!(p.imbalance() < 1.25, "imbalance {}", p.imbalance());
            let h = hash_partition(&g, k, 7);
            assert!(
                p.cut_fraction(&g) < h.cut_fraction(&g),
                "metis_lite {} vs hash {}",
                p.cut_fraction(&g),
                h.cut_fraction(&g)
            );
        }
    }

    #[test]
    fn all_parts_nonempty() {
        let g = tiny(2);
        for k in [2, 3, 4, 8] {
            let p = metis_lite(&g, k, 3);
            let sizes = p.sizes();
            assert_eq!(sizes.len(), k);
            assert!(sizes.iter().all(|&s| s > 0), "{sizes:?}");
            assert_eq!(sizes.iter().sum::<usize>(), g.n);
        }
    }

    #[test]
    fn hash_partition_is_roughly_uniform() {
        let g = tiny(3);
        let p = hash_partition(&g, 4, 5);
        for s in p.sizes() {
            assert!(s > g.n / 8, "size {s}");
        }
    }

    #[test]
    fn k_equals_one() {
        let g = tiny(4);
        let p = metis_lite(&g, 1, 1);
        assert!(p.sizes() == vec![g.n]);
        assert_eq!(p.cut_fraction(&g), 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let g = tiny(5);
        let a = metis_lite(&g, 4, 9);
        let b = metis_lite(&g, 4, 9);
        assert_eq!(a.assign, b.assign);
    }
}
