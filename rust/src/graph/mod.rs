//! Graph substrates: CSR storage, synthetic dataset generation, balanced
//! partitioning, client subgraph expansion + pruning, remote-aware
//! neighbourhood sampling, and vertex scoring.

pub mod csr;
pub mod datasets;
pub mod generate;
pub mod partition;
pub mod sampler;
pub mod scoring;
pub mod subgraph;

pub use csr::{Csr, Graph};
pub use partition::{Partition, PartitionerKind};
pub use sampler::{BlockDims, Blocks, SampledNode, Sampler};
pub use subgraph::{ClientSubgraph, NodeRef, Prune};
