//! Directed CSR graph substrate.
//!
//! GNN aggregation in this system follows the paper's convention: a vertex
//! aggregates over its **in-neighbours** (`d_G` in the frequency-score
//! definition is the shortest-path distance along in-edges), so the CSR
//! keeps both directions: `out` for push-set discovery and partition
//! quality, `inc` for sampling and scoring.
//!
//! Bulk arrays are [`Slab`]s: heap `Vec`s for generated graphs (the `ram`
//! backend) or typed views into a mapped `GraphFile` (the `mmap` backend,
//! DESIGN.md §13). `Slab` derefs to `[T]`, so consumers are agnostic.

use crate::storage::Slab;

/// Compressed sparse row adjacency (one direction).
#[derive(Clone, Debug, Default)]
pub struct Csr {
    pub offsets: Slab<u32>,
    pub targets: Slab<u32>,
}

impl Csr {
    pub fn n(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    pub fn m(&self) -> usize {
        self.targets.len()
    }

    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        let lo = self.offsets[v as usize] as usize;
        let hi = self.offsets[v as usize + 1] as usize;
        &self.targets[lo..hi]
    }

    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// Build from (src, dst) pairs. Duplicate edges are preserved; callers
    /// that need simple graphs deduplicate beforehand.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Self {
        let mut counts = vec![0u32; n + 1];
        for &(s, _) in edges {
            counts[s as usize + 1] += 1;
        }
        for i in 0..n {
            counts[i + 1] += counts[i];
        }
        let offsets = counts.clone();
        let mut cursor = counts;
        let mut targets = vec![0u32; edges.len()];
        for &(s, d) in edges {
            let pos = cursor[s as usize];
            targets[pos as usize] = d;
            cursor[s as usize] += 1;
        }
        Self {
            offsets: offsets.into(),
            targets: targets.into(),
        }
    }

    /// Reverse every edge (out-CSR -> in-CSR and vice versa).
    pub fn reversed(&self) -> Self {
        let n = self.n();
        let mut edges = Vec::with_capacity(self.m());
        for v in 0..n as u32 {
            for &u in self.neighbors(v) {
                edges.push((u, v));
            }
        }
        Self::from_edges(n, &edges)
    }
}

/// A full labelled graph dataset: topology + features + task split.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub n: usize,
    /// Out-edges: `out.neighbors(v)` = vertices v points at.
    pub out: Csr,
    /// In-edges: `inc.neighbors(v)` = vertices pointing at v (aggregated).
    pub inc: Csr,
    pub feat_dim: usize,
    pub classes: usize,
    /// Row-major `[n, feat_dim]`.
    pub features: Slab<f32>,
    pub labels: Slab<u16>,
    pub train_nodes: Vec<u32>,
    pub test_nodes: Vec<u32>,
}

impl Graph {
    pub fn feature(&self, v: u32) -> &[f32] {
        let d = self.feat_dim;
        &self.features[v as usize * d..(v as usize + 1) * d]
    }

    pub fn avg_in_degree(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.inc.m() as f64 / self.n as f64
        }
    }

    /// True when bulk arrays are served from mapped `GraphFile` pages.
    pub fn is_mapped(&self) -> bool {
        self.out.targets.is_mapped()
    }

    /// Structural sanity check used by tests, the generator, and every
    /// load path (both backends route through it on entry; the mmap
    /// opener additionally verifies section checksums via a streaming
    /// read *before* mapping, see `storage::format`).
    pub fn validate(&self) -> Result<(), String> {
        if self.out.n() != self.n || self.inc.n() != self.n {
            return Err("csr size mismatch".into());
        }
        if self.out.m() != self.inc.m() {
            return Err("edge count mismatch between directions".into());
        }
        if self.features.len() != self.n * self.feat_dim {
            return Err("feature matrix size mismatch".into());
        }
        if self.labels.len() != self.n {
            return Err("label vector size mismatch".into());
        }
        for &v in self.out.targets.iter().chain(self.inc.targets.iter()) {
            if v as usize >= self.n {
                return Err(format!("edge target {v} out of range"));
            }
        }
        for &l in self.labels.iter() {
            if l as usize >= self.classes {
                return Err(format!("label {l} out of range"));
            }
        }
        for &v in self.train_nodes.iter().chain(self.test_nodes.iter()) {
            if v as usize >= self.n {
                return Err(format!("split vertex {v} out of range"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Csr {
        // 0->1, 0->2, 1->2, 2->0
        Csr::from_edges(3, &[(0, 1), (0, 2), (1, 2), (2, 0)])
    }

    #[test]
    fn from_edges_builds_correct_adjacency() {
        let g = tiny();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 4);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(1), &[2]);
        assert_eq!(g.neighbors(2), &[0]);
        assert_eq!(g.degree(0), 2);
    }

    #[test]
    fn reversed_swaps_directions() {
        let g = tiny();
        let r = g.reversed();
        assert_eq!(r.m(), 4);
        let mut n2: Vec<u32> = r.neighbors(2).to_vec();
        n2.sort_unstable();
        assert_eq!(n2, vec![0, 1]);
        assert_eq!(r.neighbors(0), &[2]);
        // double reverse is identity up to per-vertex ordering
        let rr = r.reversed();
        for v in 0..3u32 {
            let mut a = g.neighbors(v).to_vec();
            let mut b = rr.neighbors(v).to_vec();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn empty_graph() {
        let g = Csr::from_edges(0, &[]);
        assert_eq!(g.n(), 0);
        assert_eq!(g.m(), 0);
    }

    #[test]
    fn isolated_vertices() {
        let g = Csr::from_edges(5, &[(4, 0)]);
        assert_eq!(g.neighbors(2), &[] as &[u32]);
        assert_eq!(g.neighbors(4), &[0]);
    }
}
