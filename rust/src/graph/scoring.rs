//! Vertex scoring strategies for pruning and prefetching (paper §4.1.2,
//! §4.3, and the Fig 11 ablation).
//!
//! * **Frequency score** — S(v) = |{x ∈ T : v ∈ N_L(x)}| / |T|: the
//!   fraction of labelled training vertices whose L-hop in-neighbourhood
//!   (within the client's expanded subgraph) contains pull node v.
//!   Computed by exact BFS from a sampled subset of train vertices
//!   (sampled-exact; the BFS is bounded by the subgraph size so this is
//!   cheap even on dense graphs).
//! * **Degree centrality** — normalized total degree of a vertex, computed
//!   by its owner (every edge incident to a local vertex is locally known).
//! * **Bridge centrality** — approximate betweenness (Brandes with sampled
//!   sources, undirected local subgraph) × bridging coefficient
//!   `(1/d(v)) / Σ_{u∈N(v)} 1/d(u)` (paper ref [12]).
//!
//! Centrality scores are computed per-owner and "exchanged in the
//! pre-training phase" (paper §4.1.2): callers collect the per-owner maps
//! and hand them to `Prune::TopFrac`.

use std::collections::HashMap;

use super::csr::Graph;
use super::partition::Partition;
use super::subgraph::ClientSubgraph;
use crate::util::rng::Rng;

/// Frequency score per retained remote (pull) node of `sub`.
///
/// Returns a vector aligned with `sub.remote`.
pub fn frequency_scores(
    sub: &ClientSubgraph,
    layers: usize,
    max_sources: usize,
    seed: u64,
) -> Vec<f32> {
    let n_local = sub.n_local();
    let n_remote = sub.n_remote();
    if n_remote == 0 {
        return Vec::new();
    }
    let mut rng = Rng::new(seed, 0xF5E0 + sub.client_id as u64);
    let sources: Vec<u32> = if sub.train_local.len() <= max_sources {
        sub.train_local.clone()
    } else {
        rng.sample_indices(sub.train_local.len(), max_sources)
            .into_iter()
            .map(|i| sub.train_local[i])
            .collect()
    };
    if sources.is_empty() {
        return vec![0.0; n_remote];
    }

    let mut hits = vec![0u32; n_remote];
    // stamp-based visited sets (no clearing between sources)
    let mut seen_local = vec![0u32; n_local];
    let mut seen_remote = vec![0u32; n_remote];
    let mut frontier: Vec<u32> = Vec::new();
    let mut next: Vec<u32> = Vec::new();

    for (si, &src) in sources.iter().enumerate() {
        let stamp = si as u32 + 1;
        frontier.clear();
        frontier.push(src);
        seen_local[src as usize] = stamp;
        for _hop in 0..layers {
            next.clear();
            for &v in &frontier {
                for &u in &sub.in_local[v as usize] {
                    if seen_local[u as usize] != stamp {
                        seen_local[u as usize] = stamp;
                        next.push(u);
                    }
                }
                for &r in &sub.in_remote[v as usize] {
                    if seen_remote[r as usize] != stamp {
                        seen_remote[r as usize] = stamp;
                        hits[r as usize] += 1;
                        // remote vertices are terminal: not added to frontier
                    }
                }
            }
            std::mem::swap(&mut frontier, &mut next);
            if frontier.is_empty() {
                break;
            }
        }
    }
    let denom = sources.len() as f32;
    hits.iter().map(|&h| h as f32 / denom).collect()
}

/// Frequency scores keyed by global vertex id (for `Prune::TopFrac`).
pub fn frequency_scores_global(
    sub: &ClientSubgraph,
    layers: usize,
    max_sources: usize,
    seed: u64,
) -> HashMap<u32, f32> {
    frequency_scores(sub, layers, max_sources, seed)
        .into_iter()
        .enumerate()
        .map(|(i, s)| (sub.remote[i], s))
        .collect()
}

/// Normalized total degree per global vertex (owner-computable).
pub fn degree_scores(g: &Graph) -> Vec<f32> {
    let max_deg = (0..g.n as u32)
        .map(|v| g.out.degree(v) + g.inc.degree(v))
        .max()
        .unwrap_or(1)
        .max(1) as f32;
    (0..g.n as u32)
        .map(|v| (g.out.degree(v) + g.inc.degree(v)) as f32 / max_deg)
        .collect()
}

/// Approximate bridge centrality of the vertices owned by `client`,
/// computed on the client's *local* (undirected) subgraph only: Brandes
/// betweenness from `samples` sampled sources, times the bridging
/// coefficient. Keyed by global vertex id.
pub fn bridge_scores_local(
    g: &Graph,
    part: &Partition,
    client: usize,
    samples: usize,
    seed: u64,
) -> HashMap<u32, f32> {
    // local undirected adjacency
    let local: Vec<u32> = (0..g.n as u32)
        .filter(|&v| part.assign[v as usize] == client as u32)
        .collect();
    let idx: HashMap<u32, u32> = local
        .iter()
        .enumerate()
        .map(|(i, &v)| (v, i as u32))
        .collect();
    let n = local.len();
    let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
    for (i, &v) in local.iter().enumerate() {
        for &u in g.out.neighbors(v).iter().chain(g.inc.neighbors(v)) {
            if let Some(&j) = idx.get(&u) {
                if j as usize != i {
                    adj[i].push(j);
                }
            }
        }
        adj[i].sort_unstable();
        adj[i].dedup();
    }

    // Brandes from sampled sources (unweighted).
    let mut rng = Rng::new(seed, 0xB21D + client as u64);
    let sources: Vec<usize> = if n <= samples {
        (0..n).collect()
    } else {
        rng.sample_indices(n, samples)
    };
    let mut bc = vec![0f64; n];
    let mut dist = vec![-1i32; n];
    let mut sigma = vec![0f64; n];
    let mut delta = vec![0f64; n];
    let mut preds: Vec<Vec<u32>> = vec![Vec::new(); n];
    for &s in &sources {
        for v in 0..n {
            dist[v] = -1;
            sigma[v] = 0.0;
            delta[v] = 0.0;
            preds[v].clear();
        }
        dist[s] = 0;
        sigma[s] = 1.0;
        let mut order: Vec<u32> = Vec::with_capacity(n);
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(s as u32);
        while let Some(v) = queue.pop_front() {
            order.push(v);
            for &w in &adj[v as usize] {
                if dist[w as usize] < 0 {
                    dist[w as usize] = dist[v as usize] + 1;
                    queue.push_back(w);
                }
                if dist[w as usize] == dist[v as usize] + 1 {
                    sigma[w as usize] += sigma[v as usize];
                    preds[w as usize].push(v);
                }
            }
        }
        for &w in order.iter().rev() {
            for &v in &preds[w as usize] {
                delta[v as usize] += sigma[v as usize] / sigma[w as usize].max(1e-12)
                    * (1.0 + delta[w as usize]);
            }
            if w as usize != s {
                bc[w as usize] += delta[w as usize];
            }
        }
    }
    let max_bc = bc.iter().cloned().fold(1e-12, f64::max);

    // bridging coefficient
    let mut out = HashMap::with_capacity(n);
    for (i, &v) in local.iter().enumerate() {
        let d = adj[i].len().max(1) as f64;
        let denom: f64 = adj[i]
            .iter()
            .map(|&u| 1.0 / adj[u as usize].len().max(1) as f64)
            .sum::<f64>()
            .max(1e-12);
        let bridging = (1.0 / d) / denom;
        out.insert(v, ((bc[i] / max_bc) * bridging) as f32);
    }
    out
}

/// Degree scores restricted to a client's local vertices, keyed by global
/// id (the "exchanged" form used by D25).
pub fn degree_scores_local(g: &Graph, part: &Partition, client: usize) -> HashMap<u32, f32> {
    let all = degree_scores(g);
    (0..g.n as u32)
        .filter(|&v| part.assign[v as usize] == client as u32)
        .map(|v| (v, all[v as usize]))
        .collect()
}

/// Merge per-owner score maps into one directory (pre-training exchange).
pub fn merge_scores(maps: Vec<HashMap<u32, f32>>) -> HashMap<u32, f32> {
    let mut out = HashMap::new();
    for m in maps {
        out.extend(m);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny;
    use crate::graph::partition::metis_lite;
    use crate::graph::subgraph::{build_all, Prune};

    fn setup() -> (Graph, Partition, Vec<ClientSubgraph>) {
        let g = tiny(31);
        let part = metis_lite(&g, 4, 2);
        let subs = build_all(&g, &part, &Prune::None, 5);
        (g, part, subs)
    }

    #[test]
    fn frequency_scores_in_unit_range_and_nonzero() {
        let (_, _, subs) = setup();
        for sub in &subs {
            let s = frequency_scores(sub, 3, 256, 7);
            assert_eq!(s.len(), sub.n_remote());
            assert!(s.iter().all(|&x| (0.0..=1.0).contains(&x)));
            if sub.n_remote() > 10 {
                assert!(s.iter().any(|&x| x > 0.0), "all-zero scores");
            }
        }
    }

    #[test]
    fn frequency_score_monotone_in_reachability() {
        // A remote neighbour of MANY train vertices must outscore a remote
        // vertex adjacent to none of them. Construct via direct checks:
        let (_, _, subs) = setup();
        let sub = subs
            .iter()
            .max_by_key(|s| s.n_remote())
            .unwrap();
        let scores = frequency_scores(sub, 3, 512, 7);
        // remote with highest direct-edge count to train vertices
        let train_set: std::collections::HashSet<u32> =
            sub.train_local.iter().copied().collect();
        let mut direct = vec![0usize; sub.n_remote()];
        for (li, rems) in sub.in_remote.iter().enumerate() {
            if train_set.contains(&(li as u32)) {
                for &r in rems {
                    direct[r as usize] += 1;
                }
            }
        }
        let best = (0..direct.len()).max_by_key(|&i| direct[i]).unwrap();
        if direct[best] >= 3 {
            let zero_direct = (0..direct.len()).find(|&i| direct[i] == 0);
            if let Some(z) = zero_direct {
                assert!(
                    scores[best] >= scores[z],
                    "high-direct {} < zero-direct {}",
                    scores[best],
                    scores[z]
                );
            }
        }
    }

    #[test]
    fn frequency_scores_deterministic() {
        let (_, _, subs) = setup();
        let a = frequency_scores(&subs[0], 3, 128, 9);
        let b = frequency_scores(&subs[0], 3, 128, 9);
        assert_eq!(a, b);
    }

    #[test]
    fn degree_scores_normalized() {
        let (g, _, _) = setup();
        let s = degree_scores(&g);
        assert_eq!(s.len(), g.n);
        assert!(s.iter().all(|&x| (0.0..=1.0).contains(&x)));
        assert!(s.iter().any(|&x| x == 1.0));
    }

    #[test]
    fn bridge_scores_cover_local_vertices() {
        let (g, part, _) = setup();
        let m = bridge_scores_local(&g, &part, 0, 64, 3);
        let locals = part.assign.iter().filter(|&&p| p == 0).count();
        assert_eq!(m.len(), locals);
        assert!(m.values().all(|&x| x >= 0.0 && x.is_finite()));
        assert!(m.values().any(|&x| x > 0.0));
    }

    #[test]
    fn merge_scores_combines_owners() {
        let (g, part, _) = setup();
        let merged = merge_scores(
            (0..4)
                .map(|c| degree_scores_local(&g, &part, c))
                .collect(),
        );
        assert_eq!(merged.len(), g.n);
    }
}
