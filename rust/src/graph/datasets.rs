//! Scaled-down dataset presets mirroring the paper's Table 1.
//!
//! Absolute sizes are ~1000x smaller than the originals (the testbed is a
//! single CPU host), but the properties the evaluation depends on are
//! matched *relatively*: density ordering (Reddit ≫ Products > Papers >
//! Arxiv), default partition counts (Papers on 8 clients, others on 4),
//! train-vertex fractions, and per-epoch minibatch-count ordering (Arxiv's
//! tiny batch size in the paper => many RPCs per epoch).

use super::csr::Graph;
use super::generate::{generate, GenParams};
use crate::storage::{GraphBackend, GraphStore};

/// Re-home a freshly generated graph onto the env-selected backend
/// (`OPTIMES_GRAPH_BACKEND`). A failed adoption is a hard error — a
/// silent fall-back to `ram` would fake backend parity in CI.
fn adopt_env(g: Graph) -> Graph {
    GraphStore::adopt(g, GraphBackend::from_env()).expect("adopt graph onto OPTIMES_GRAPH_BACKEND")
}

#[derive(Clone, Debug)]
pub struct DatasetPreset {
    pub name: &'static str,
    /// Matching paper dataset (for tables).
    pub paper_name: &'static str,
    pub gen: GenParams,
    /// Default client count (paper: 4, Papers: 8).
    pub default_clients: usize,
    /// Minibatches per local epoch (reproduces the paper's relative batch
    /// counts given the fixed AOT batch size of 32).
    pub epoch_batches: usize,
    /// Paper's measured stats, echoed in the Table-1 bench for reference.
    pub paper_v: &'static str,
    pub paper_e: &'static str,
    pub paper_avg_deg: f64,
}

/// Presets for the four evaluation graphs.
pub fn presets() -> Vec<DatasetPreset> {
    vec![
        DatasetPreset {
            name: "arxiv-s",
            paper_name: "Arxiv",
            gen: GenParams {
                n: 17_000,
                avg_degree: 6.9,
                communities: 32,
                classes: 16,
                feat_dim: 32,
                homophily: 0.82,
                hub_alpha: 1.6,
                signal: 0.60,
                community_bias: 0.30,
                train_frac: 0.54,
                test_frac: 0.15,
                seed: 0xA12,
            },
            default_clients: 4,
            epoch_batches: 96,
            paper_v: "169K",
            paper_e: "1.2M",
            paper_avg_deg: 6.9,
        },
        DatasetPreset {
            name: "reddit-s",
            paper_name: "Reddit",
            gen: GenParams {
                n: 23_000,
                avg_degree: 50.0,
                communities: 64,
                classes: 16,
                feat_dim: 32,
                homophily: 0.68,
                hub_alpha: 1.8,
                signal: 0.50,
                community_bias: 0.55,
                train_frac: 0.66,
                test_frac: 0.15,
                seed: 0x8EDD,
            },
            default_clients: 4,
            epoch_batches: 24,
            paper_v: "233K",
            paper_e: "114.9M",
            paper_avg_deg: 492.0,
        },
        DatasetPreset {
            name: "products-s",
            paper_name: "Products",
            gen: GenParams {
                n: 48_000,
                avg_degree: 25.0,
                communities: 48,
                classes: 16,
                feat_dim: 32,
                homophily: 0.76,
                hub_alpha: 1.7,
                signal: 0.55,
                community_bias: 0.45,
                train_frac: 0.08,
                test_frac: 0.10,
                seed: 0x9800,
            },
            default_clients: 4,
            epoch_batches: 20,
            paper_v: "2.5M",
            paper_e: "123.7M",
            paper_avg_deg: 50.5,
        },
        DatasetPreset {
            name: "papers-s",
            paper_name: "Papers",
            gen: GenParams {
                n: 96_000,
                avg_degree: 14.5,
                communities: 64,
                classes: 16,
                feat_dim: 32,
                homophily: 0.82,
                hub_alpha: 1.6,
                signal: 0.58,
                community_bias: 0.35,
                train_frac: 0.04,
                test_frac: 0.06,
                seed: 0x9A9E,
            },
            default_clients: 8,
            epoch_batches: 10,
            paper_v: "111M",
            paper_e: "1.62B",
            paper_avg_deg: 14.5,
        },
    ]
}

pub fn preset(name: &str) -> Option<DatasetPreset> {
    presets().into_iter().find(|p| p.name == name)
}

/// Generate (or retrieve) the graph for a preset, optionally shrunk by
/// `scale` for fast tests/benches (scale=4 => n/4 vertices).
pub fn load(name: &str, scale: usize) -> Option<(DatasetPreset, Graph)> {
    let mut p = preset(name)?;
    if scale > 1 {
        p.gen.n /= scale;
        p.epoch_batches = (p.epoch_batches / scale).max(2);
    }
    let g = adopt_env(generate(&p.gen));
    Some((p, g))
}

/// A tiny dataset for unit/integration tests (fast to generate and train).
pub fn tiny(seed: u64) -> Graph {
    adopt_env(generate(&GenParams {
        n: 600,
        avg_degree: 10.0,
        communities: 4,
        classes: 4,
        feat_dim: 32,
        homophily: 0.85,
        hub_alpha: 1.5,
        signal: 0.65,
        community_bias: 0.4,
        train_frac: 0.5,
        test_frac: 0.25,
        seed,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_generate_and_validate() {
        for p in presets() {
            // shrink for test speed
            let (_, g) = load(p.name, 8).unwrap();
            g.validate().unwrap();
            assert!(g.n > 1000, "{} too small", p.name);
        }
    }

    #[test]
    fn density_ordering_matches_paper() {
        // reddit-s must be the densest; arxiv-s the sparsest.
        let degs: Vec<(String, f64)> = ["arxiv-s", "reddit-s", "products-s", "papers-s"]
            .iter()
            .map(|n| {
                let (_, g) = load(n, 8).unwrap();
                (n.to_string(), g.avg_in_degree())
            })
            .collect();
        let get = |n: &str| degs.iter().find(|(x, _)| x == n).unwrap().1;
        assert!(get("reddit-s") > get("products-s"));
        assert!(get("products-s") > get("papers-s"));
        assert!(get("papers-s") > get("arxiv-s"));
    }

    #[test]
    fn unknown_preset_is_none() {
        assert!(preset("nope").is_none());
    }

    #[test]
    fn tiny_is_fast_and_valid() {
        let g = tiny(3);
        g.validate().unwrap();
        assert_eq!(g.n, 600);
    }
}
