//! Remote-aware neighbourhood sampler producing fixed-shape padded blocks
//! (the AOT contract described in `python/compile/config.py`).
//!
//! Paper §3.2.2 sampling rules, enforced here:
//! 1. only local vertices at the root level (training targets),
//! 2. a remote vertex sampled at hop `l <= L-1` does not grow further
//!    (its child slots are padding),
//! 3. no remote vertices at the deepest sampled hop (their `h^0` raw
//!    features are never available).
//!
//! Block layout: nested level arrays where `level_{d+1}` is `level_d`
//! followed by every level-d row's K (padded) sampled children. This makes
//! the gather adjacency *constant* for a given geometry — child `j` of row
//! `i` always sits at `s_d + i*K + j` — so the i32 adjacency tensors are
//! computed once per geometry and shared across every minibatch (a
//! meaningful hot-path win; see EXPERIMENTS.md §Perf).

use super::csr::Graph;
use super::subgraph::ClientSubgraph;
use crate::util::rng::Rng;

/// Static block geometry (mirrors `ModelConfig` in Python / the manifest).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockDims {
    pub layers: usize,
    pub fanout: usize,
    pub batch: usize,
    pub feat: usize,
    pub hidden: usize,
    pub classes: usize,
    pub push_batch: usize,
}

impl BlockDims {
    /// Rows in the level-`d` array for a root width of `width`.
    pub fn level_size_for(&self, width: usize, d: usize) -> usize {
        width * (self.fanout + 1).pow(d as u32)
    }

    pub fn level_size(&self, d: usize) -> usize {
        self.level_size_for(self.batch, d)
    }

    pub fn embed_level_size(&self, d: usize) -> usize {
        self.level_size_for(self.push_batch, d)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SampledNode {
    /// Index into the client's `local` table.
    Local(u32),
    /// Index into the client's `remote` (pull node) table.
    Remote(u32),
    Pad,
}

/// One sampled, padded computation graph.
#[derive(Clone, Debug)]
pub struct Blocks {
    pub dims: BlockDims,
    /// Number of GNN hops sampled (L for train/eval, L-1 for embed).
    pub depth: usize,
    /// Root width (batch for train, push_batch for embed).
    pub width: usize,
    /// `levels[d]` has `level_size_for(width, d)` entries, `d` in `0..=depth`.
    pub levels: Vec<Vec<SampledNode>>,
    /// `msk[d]` is row-major `[s_d, K]` validity of sampled child slots.
    pub msk: Vec<Vec<f32>>,
}

/// Shared, geometry-constant gather adjacency: computed once per client
/// geometry and refcounted into every `Batch` (sampler → trainer → engine)
/// instead of being deep-cloned per minibatch (EXPERIMENTS.md §Perf).
pub type SharedAdj = std::sync::Arc<[Vec<i32>]>;

/// The constant gather adjacency for a geometry: `adj[d][i*K + j] =
/// s_d + i*K + j` (child rows follow the parent level's prefix copy).
pub fn static_adj(dims: &BlockDims, width: usize, depth: usize) -> SharedAdj {
    let k = dims.fanout;
    (0..depth)
        .map(|d| {
            let s_d = dims.level_size_for(width, d);
            (0..s_d * k).map(|e| (s_d + e) as i32).collect()
        })
        .collect::<Vec<Vec<i32>>>()
        .into()
}

pub struct Sampler {
    pub dims: BlockDims,
    rng: Rng,
    local_only: bool,
}

impl Sampler {
    pub fn new(dims: BlockDims, seed: u64, stream: u64) -> Self {
        Self {
            dims,
            rng: Rng::new(seed, stream ^ 0x5A4D31),
            local_only: false,
        }
    }

    /// Raw rng state for session checkpointing. At round boundaries the
    /// stream state is the sampler's only mutable state (`local_only` is
    /// save/restored inside [`sample_embed_local`](Sampler::sample_embed_local)).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Restore a checkpointed [`rng_state`](Sampler::rng_state).
    pub fn set_rng_state(&mut self, s: [u64; 4]) {
        self.rng = Rng::from_state(s);
    }

    /// Sample a training/eval batch rooted at `targets` (local indices,
    /// at most `dims.batch`; short batches are padded).
    pub fn sample_batch(&mut self, sub: &ClientSubgraph, targets: &[u32]) -> Blocks {
        self.sample(sub, targets, self.dims.batch, self.dims.layers)
    }

    /// Sample an embed (push) batch of depth L-1 rooted at push nodes.
    pub fn sample_embed(&mut self, sub: &ClientSubgraph, push_local: &[u32]) -> Blocks {
        self.sample(sub, push_local, self.dims.push_batch, self.dims.layers - 1)
    }

    /// Embed sampling restricted to local vertices only — used by the
    /// pre-training round, which runs on the *unexpanded* local subgraph
    /// (paper §3.2.1).
    pub fn sample_embed_local(&mut self, sub: &ClientSubgraph, push_local: &[u32]) -> Blocks {
        let saved = self.local_only;
        self.local_only = true;
        let b = self.sample(sub, push_local, self.dims.push_batch, self.dims.layers - 1);
        self.local_only = saved;
        b
    }

    fn sample(
        &mut self,
        sub: &ClientSubgraph,
        roots: &[u32],
        width: usize,
        depth: usize,
    ) -> Blocks {
        assert!(roots.len() <= width, "{} roots > width {}", roots.len(), width);
        let k = self.dims.fanout;
        let mut levels: Vec<Vec<SampledNode>> = Vec::with_capacity(depth + 1);
        let mut msks: Vec<Vec<f32>> = Vec::with_capacity(depth);

        let mut level0: Vec<SampledNode> =
            roots.iter().map(|&l| SampledNode::Local(l)).collect();
        level0.resize(width, SampledNode::Pad);
        levels.push(level0);

        for d in 0..depth {
            let parent = &levels[d];
            let s_d = parent.len();
            let mut children = Vec::with_capacity(s_d * k);
            let mut msk = vec![0f32; s_d * k];
            let deepest = d + 1 == depth;
            for (i, node) in parent.iter().enumerate() {
                match *node {
                    SampledNode::Local(l) => {
                        let loc = &sub.in_local[l as usize];
                        let rem = &sub.in_remote[l as usize];
                        let pop = if deepest || self.local_only {
                            loc.len() // rule 3: no remote at the last hop
                        } else {
                            loc.len() + rem.len()
                        };
                        let take = pop.min(k);
                        if take > 0 {
                            let picks = self.rng.sample_indices(pop, take);
                            for (j, &pi) in picks.iter().enumerate() {
                                let child = if pi < loc.len() {
                                    SampledNode::Local(loc[pi])
                                } else {
                                    SampledNode::Remote(rem[pi - loc.len()])
                                };
                                children.push(child);
                                msk[i * k + j] = 1.0;
                            }
                        }
                        for _ in take..k {
                            children.push(SampledNode::Pad);
                        }
                    }
                    // rule 2: remote subtrees never grow; pads have no kids
                    SampledNode::Remote(_) | SampledNode::Pad => {
                        for _ in 0..k {
                            children.push(SampledNode::Pad);
                        }
                    }
                }
            }
            let mut next = parent.clone();
            next.extend(children);
            levels.push(next);
            msks.push(msk);
        }

        Blocks {
            dims: self.dims,
            depth,
            width,
            levels,
            msk: msks,
        }
    }
}

impl Blocks {
    /// Fill the deepest-level feature tensor `[s_depth, F]` (row-major).
    /// Remote and pad rows are zeroed.
    pub fn fill_x(&self, sub: &ClientSubgraph, g: &Graph, out: &mut [f32]) {
        let f = self.dims.feat;
        let deepest = &self.levels[self.depth];
        assert_eq!(out.len(), deepest.len() * f);
        for (i, node) in deepest.iter().enumerate() {
            let row = &mut out[i * f..(i + 1) * f];
            match *node {
                SampledNode::Local(l) => {
                    row.copy_from_slice(g.feature(sub.local[l as usize]));
                }
                _ => row.fill(0.0),
            }
        }
    }

    /// Remote-row mask for a level: 1.0 where the row is a remote vertex.
    pub fn fill_rmask(&self, level: usize, out: &mut [f32]) {
        let lvl = &self.levels[level];
        assert_eq!(out.len(), lvl.len());
        for (i, node) in lvl.iter().enumerate() {
            out[i] = if matches!(node, SampledNode::Remote(_)) {
                1.0
            } else {
                0.0
            };
        }
    }

    /// Iterate `(row, remote_index)` pairs of a level (for cache fills).
    pub fn remote_rows(&self, level: usize) -> impl Iterator<Item = (usize, u32)> + '_ {
        self.levels[level]
            .iter()
            .enumerate()
            .filter_map(|(i, n)| match n {
                SampledNode::Remote(r) => Some((i, *r)),
                _ => None,
            })
    }

    /// Distinct remote indices appearing anywhere in the sampled blocks,
    /// with the deepest hop distance they appear at (1-based layer whose
    /// cached embedding they need is `depth - hop_level`).
    pub fn used_remotes(&self) -> Vec<u32> {
        let mut set = std::collections::HashSet::new();
        for lvl in &self.levels {
            for n in lvl {
                if let SampledNode::Remote(r) = n {
                    set.insert(*r);
                }
            }
        }
        let mut v: Vec<u32> = set.into_iter().collect();
        v.sort_unstable();
        v
    }

    /// Labels + label mask for the root level (training targets).
    pub fn fill_labels(
        &self,
        sub: &ClientSubgraph,
        g: &Graph,
        labels: &mut [i32],
        lmask: &mut [f32],
    ) {
        let roots = &self.levels[0];
        assert_eq!(labels.len(), roots.len());
        for (i, node) in roots.iter().enumerate() {
            match *node {
                SampledNode::Local(l) => {
                    labels[i] = g.labels[sub.local[l as usize] as usize] as i32;
                    lmask[i] = 1.0;
                }
                _ => {
                    labels[i] = 0;
                    lmask[i] = 0.0;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny;
    use crate::graph::partition::metis_lite;
    use crate::graph::subgraph::{build_all, Prune};

    fn dims() -> BlockDims {
        BlockDims {
            layers: 3,
            fanout: 5,
            batch: 8,
            feat: 32,
            hidden: 32,
            classes: 4,
            push_batch: 6,
        }
    }

    fn setup() -> (Graph, Vec<ClientSubgraph>) {
        let g = tiny(21);
        let part = metis_lite(&g, 4, 2);
        let subs = build_all(&g, &part, &Prune::None, 5);
        (g, subs)
    }

    use crate::graph::csr::Graph;

    #[test]
    fn level_sizes_match_contract() {
        let (_, subs) = setup();
        let sub = &subs[0];
        let mut s = Sampler::new(dims(), 1, 0);
        let targets: Vec<u32> = sub.train_local.iter().copied().take(8).collect();
        let b = s.sample_batch(sub, &targets);
        assert_eq!(b.levels.len(), 4);
        for d in 0..=3 {
            assert_eq!(b.levels[d].len(), dims().level_size_for(8, d));
        }
        for d in 0..3 {
            assert_eq!(b.msk[d].len(), dims().level_size_for(8, d) * 5);
        }
    }

    #[test]
    fn prefix_property_holds() {
        let (_, subs) = setup();
        let sub = &subs[1];
        let mut s = Sampler::new(dims(), 2, 0);
        let targets: Vec<u32> = sub.train_local.iter().copied().take(8).collect();
        let b = s.sample_batch(sub, &targets);
        for d in 0..b.depth {
            let parent = &b.levels[d];
            let child = &b.levels[d + 1];
            assert_eq!(&child[..parent.len()], &parent[..]);
        }
    }

    #[test]
    fn no_remote_at_deepest_level_and_no_remote_children() {
        let (_, subs) = setup();
        for sub in &subs {
            let mut s = Sampler::new(dims(), 3, sub.client_id as u64);
            let targets: Vec<u32> = sub.train_local.iter().copied().take(8).collect();
            if targets.is_empty() {
                continue;
            }
            let b = s.sample_batch(sub, &targets);
            // rule 3: deepest new rows contain no remote
            let deepest = &b.levels[b.depth];
            let prefix = b.levels[b.depth - 1].len();
            for n in &deepest[prefix..] {
                assert!(!matches!(n, SampledNode::Remote(_)));
            }
            // rule 2: children slots of remote/pad parents are masked out
            let k = 5;
            for d in 0..b.depth {
                for (i, parent) in b.levels[d].iter().enumerate() {
                    if !matches!(parent, SampledNode::Local(_)) {
                        for j in 0..k {
                            assert_eq!(b.msk[d][i * k + j], 0.0);
                            let child = &b.levels[d + 1][b.levels[d].len() + i * k + j];
                            assert_eq!(*child, SampledNode::Pad);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn mask_matches_valid_children_and_edges_are_real() {
        let (_, subs) = setup();
        let sub = &subs[0];
        let mut s = Sampler::new(dims(), 4, 0);
        let targets: Vec<u32> = sub.train_local.iter().copied().take(8).collect();
        let b = s.sample_batch(sub, &targets);
        let k = 5;
        for d in 0..b.depth {
            for (i, parent) in b.levels[d].iter().enumerate() {
                if let SampledNode::Local(l) = parent {
                    let loc = &sub.in_local[*l as usize];
                    let rem = &sub.in_remote[*l as usize];
                    for j in 0..k {
                        let child = &b.levels[d + 1][b.levels[d].len() + i * k + j];
                        let m = b.msk[d][i * k + j];
                        match child {
                            SampledNode::Local(c) => {
                                assert_eq!(m, 1.0);
                                assert!(loc.contains(c));
                            }
                            SampledNode::Remote(c) => {
                                assert_eq!(m, 1.0);
                                assert!(rem.contains(c));
                            }
                            SampledNode::Pad => assert_eq!(m, 0.0),
                        }
                    }
                    // no duplicate children (sampling w/o replacement)
                    let kids: Vec<_> = (0..k)
                        .map(|j| b.levels[d + 1][b.levels[d].len() + i * k + j])
                        .filter(|c| !matches!(c, SampledNode::Pad))
                        .collect();
                    let uniq: std::collections::HashSet<_> = kids
                        .iter()
                        .map(|c| format!("{c:?}"))
                        .collect();
                    assert_eq!(uniq.len(), kids.len());
                }
            }
        }
    }

    #[test]
    fn static_adj_points_at_child_rows() {
        let d = dims();
        let adj = static_adj(&d, 8, 3);
        assert_eq!(adj.len(), 3);
        for lvl in 0..3 {
            let s_d = d.level_size_for(8, lvl);
            assert_eq!(adj[lvl].len(), s_d * d.fanout);
            for (e, &idx) in adj[lvl].iter().enumerate() {
                assert_eq!(idx as usize, s_d + e);
                assert!((idx as usize) < d.level_size_for(8, lvl + 1));
            }
        }
    }

    #[test]
    fn fill_helpers_produce_consistent_tensors() {
        let (g, subs) = setup();
        let sub = &subs[2];
        let mut s = Sampler::new(dims(), 5, 0);
        let targets: Vec<u32> = sub.train_local.iter().copied().take(5).collect();
        let b = s.sample_batch(sub, &targets);
        let sL = b.levels[b.depth].len();
        let mut x = vec![0f32; sL * 32];
        b.fill_x(sub, &g, &mut x);
        // local rows match graph features, pads are zero
        for (i, n) in b.levels[b.depth].iter().enumerate() {
            match n {
                SampledNode::Local(l) => {
                    assert_eq!(&x[i * 32..(i + 1) * 32], g.feature(sub.local[*l as usize]));
                }
                _ => assert!(x[i * 32..(i + 1) * 32].iter().all(|&v| v == 0.0)),
            }
        }
        let mut labels = vec![0i32; 8];
        let mut lmask = vec![0f32; 8];
        b.fill_labels(sub, &g, &mut labels, &mut lmask);
        assert_eq!(lmask.iter().filter(|&&m| m == 1.0).count(), 5);
        for i in 5..8 {
            assert_eq!(lmask[i], 0.0);
        }
        // rmask consistent with used_remotes
        let mut rm = vec![0f32; b.levels[1].len()];
        b.fill_rmask(1, &mut rm);
        let remotes_in_level: usize = rm.iter().map(|&v| v as usize).sum();
        assert_eq!(
            remotes_in_level,
            b.levels[1]
                .iter()
                .filter(|n| matches!(n, SampledNode::Remote(_)))
                .count()
        );
    }

    #[test]
    fn embed_sampling_has_depth_l_minus_1() {
        let (_, subs) = setup();
        let sub = &subs[0];
        let mut s = Sampler::new(dims(), 6, 0);
        let push: Vec<u32> = sub
            .push_nodes
            .iter()
            .filter_map(|gid| sub.local_index(*gid))
            .take(6)
            .collect();
        let b = s.sample_embed(sub, &push);
        assert_eq!(b.depth, 2);
        assert_eq!(b.levels.len(), 3);
        assert_eq!(b.levels[0].len(), 6);
        assert_eq!(b.levels[2].len(), dims().embed_level_size(2));
    }
}
