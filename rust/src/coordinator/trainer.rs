//! The client-side round lifecycle: pull phase, ε training epochs (with
//! OPP on-demand pulls), and the push phase — optionally overlapped with
//! the final epoch (paper §3.2.2, §4.2, §4.3).
//!
//! With an [`AsyncStoreHandle`] attached ([`run_round_pipelined`]), the
//! overlap is *real*: the ε−k push RPC is handed to a background worker
//! and its ticket joined at round end, and a round's initial pull can be
//! served from a [`PendingPull`] prefetch issued while the previous
//! round was still aggregating ([`issue_prefetch`]). Measured wall times
//! of the hidden work land in
//! [`OverlapMetrics`](super::metrics::OverlapMetrics), next to the
//! virtual-time model (DESIGN.md §7, §9).
//!
//! Batch assembly goes through a reusable per-client [`BatchScratch`]
//! arena: after the first minibatch, assembly performs no heap allocation
//! (buffers are resized in place) and the geometry-constant adjacency is
//! shared by refcount ([`SharedAdj`]) instead of deep-cloned (DESIGN.md
//! §3, EXPERIMENTS.md §Perf).

use std::sync::Arc;

use anyhow::{ensure, Result};

use super::client::{Client, EmbCache};
use super::metrics::{CacheStats, ClientRoundMetrics, RpcRecord};
use super::pipeline::{AsyncStoreHandle, PendingPull, PushTicket};
use super::store::EmbeddingStore;
use super::strategy::Strategy;
use crate::graph::sampler::{Blocks, Sampler, SharedAdj};
use crate::graph::{ClientSubgraph, Graph};
use crate::obs;
use crate::runtime::{Batch, ModelState, StepEngine};
use crate::util::Stopwatch;

/// Everything the session driver needs to compose virtual round time.
#[derive(Clone, Debug, Default)]
pub struct RoundOutcome {
    pub metrics: ClientRoundMetrics,
    /// Measured wall time per epoch (compute; includes engine contention).
    pub epoch_times: Vec<f64>,
    /// Virtual push total (embed compute + transfer), regardless of
    /// whether it was overlapped.
    pub push_total: f64,
    pub overlapped: bool,
}

/// Reusable batch-assembly arena. Owns one [`Batch`] whose buffers are
/// resized in place on every [`assemble`](BatchScratch::assemble) call, so
/// the per-minibatch hot path allocates nothing once the buffers have
/// grown to the geometry's steady-state sizes.
///
/// Remote rows absent from the cache contribute zero embeddings (only
/// possible for OPP pre-pull misses, which are pulled on demand before
/// assembly, or for push-embed computation with stale/missing entries);
/// each assembly counts them into `last_lookups`/`last_misses` so the
/// round metrics can surface the miss rate instead of silently losing
/// accuracy.
#[derive(Debug, Default)]
pub struct BatchScratch {
    batch: Batch,
    /// Remote-cache lookups performed by the most recent `assemble`.
    pub last_lookups: usize,
    /// Of those, rows that were missing (zero-filled).
    pub last_misses: usize,
}

impl BatchScratch {
    /// Cache stats of the most recent `assemble`.
    pub fn last_stats(&self) -> CacheStats {
        CacheStats {
            lookups: self.last_lookups,
            misses: self.last_misses,
        }
    }

    /// Assemble a [`Batch`] from sampled blocks + the client's cache into
    /// the internal arena, reusing every buffer. The returned reference is
    /// valid until the next `assemble` call.
    pub fn assemble(
        &mut self,
        blocks: &Blocks,
        sub: &ClientSubgraph,
        cache: &EmbCache,
        g: &Graph,
        adj: &SharedAdj,
        with_labels: bool,
    ) -> &Batch {
        let dims = blocks.dims;
        let depth = blocks.depth;
        let b = &mut self.batch;
        b.depth = depth;
        b.width = blocks.width;
        if !Arc::ptr_eq(&b.adj, adj) {
            b.adj = Arc::clone(adj);
        }
        b.msk.clone_from(&blocks.msk);

        let s_deep = blocks.levels[depth].len();
        b.x.resize(s_deep * dims.feat, 0.0);
        blocks.fill_x(sub, g, &mut b.x);

        let n_sub = depth.min(dims.layers) - 1;
        resize_nested(&mut b.rmask, n_sub);
        resize_nested(&mut b.cache, n_sub);
        let mut lookups = 0usize;
        let mut misses = 0usize;
        for l in 1..=n_sub {
            let lvl = depth - l;
            let s = blocks.levels[lvl].len();
            let rm = &mut b.rmask[l - 1];
            rm.resize(s, 0.0);
            blocks.fill_rmask(lvl, rm);
            let ct = &mut b.cache[l - 1];
            ct.clear();
            ct.resize(s * dims.hidden, 0.0);
            for (row, ridx) in blocks.remote_rows(lvl) {
                lookups += 1;
                if cache.is_present(ridx) {
                    ct[row * dims.hidden..(row + 1) * dims.hidden]
                        .copy_from_slice(cache.row(l, ridx));
                } else {
                    misses += 1;
                }
            }
        }

        if with_labels {
            b.labels.resize(blocks.width, 0);
            b.lmask.resize(blocks.width, 0.0);
            blocks.fill_labels(sub, g, &mut b.labels, &mut b.lmask);
        } else {
            b.labels.clear();
            b.lmask.clear();
        }
        self.last_lookups = lookups;
        self.last_misses = misses;
        &self.batch
    }
}

/// Truncate/grow a nested buffer list without dropping inner capacity.
fn resize_nested<T>(v: &mut Vec<Vec<T>>, n: usize) {
    v.truncate(n);
    while v.len() < n {
        v.push(Vec::new());
    }
}

/// Allocating convenience wrapper over [`BatchScratch::assemble`] for
/// callers outside the hot loop (benches, tests, one-off assemblies).
pub fn assemble_batch(
    blocks: &Blocks,
    sub: &ClientSubgraph,
    cache: &EmbCache,
    g: &Graph,
    adj: &SharedAdj,
    with_labels: bool,
) -> Batch {
    let mut scratch = BatchScratch::default();
    scratch.assemble(blocks, sub, cache, g, adj, with_labels).clone()
}

/// Compute the h^1..h^{L-1} push rows for `push_local` (the push-embed
/// forward pass). Returns (measured embed-compute seconds, per-layer
/// row-major rows aligned with `push_local`, cache stats over the embed
/// assemblies). `local_only` selects the pre-training sampling mode.
#[allow(clippy::too_many_arguments)]
fn compute_push_layers(
    sub: &ClientSubgraph,
    cache: &EmbCache,
    state: &ModelState,
    engine: &Arc<dyn StepEngine>,
    sampler: &mut Sampler,
    adj_embed: &SharedAdj,
    push_local: &[u32],
    g: &Graph,
    local_only: bool,
) -> Result<(f64, Vec<Vec<f32>>, CacheStats)> {
    let dims = sampler.dims;
    let h = dims.hidden;
    let n_layers = dims.layers - 1;
    let sw = Stopwatch::start();
    let mut sp = obs::span("trainer", "push_embed");
    sp.push_attr("rows", push_local.len());
    let mut scratch = BatchScratch::default();
    let mut stats = CacheStats::default();
    let mut per_layer: Vec<Vec<f32>> = (0..n_layers)
        .map(|_| Vec::with_capacity(push_local.len() * h))
        .collect();
    for chunk in push_local.chunks(dims.push_batch) {
        let blocks = if local_only {
            sampler.sample_embed_local(sub, chunk)
        } else {
            sampler.sample_embed(sub, chunk)
        };
        let batch = scratch.assemble(&blocks, sub, cache, g, adj_embed, false);
        let outs = engine.embed(state, batch)?;
        stats.add(scratch.last_stats());
        ensure!(outs.len() == n_layers, "embed returned {} layers", outs.len());
        for (l, rows) in outs.iter().enumerate() {
            per_layer[l].extend_from_slice(&rows[..chunk.len() * h]);
        }
    }
    Ok((sw.secs(), per_layer, stats))
}

/// Compute h^1..h^{L-1} for the client's push nodes and push them to the
/// embedding store in one batched RPC. Returns (embed-compute seconds,
/// push RPC record, cache stats over the embed assemblies). `local_only`
/// selects the pre-training sampling mode.
#[allow(clippy::too_many_arguments)]
pub fn compute_and_push(
    sub: &ClientSubgraph,
    cache: &EmbCache,
    state: &ModelState,
    engine: &Arc<dyn StepEngine>,
    store: &dyn EmbeddingStore,
    sampler: &mut Sampler,
    adj_embed: &SharedAdj,
    push_local: &[u32],
    push_globals: &[u32],
    g: &Graph,
    local_only: bool,
) -> Result<(f64, Option<RpcRecord>, CacheStats)> {
    if push_local.is_empty() {
        return Ok((0.0, None, CacheStats::default()));
    }
    let (compute, per_layer, stats) = compute_push_layers(
        sub, cache, state, engine, sampler, adj_embed, push_local, g, local_only,
    )?;
    let rec = store.push(push_globals, &per_layer)?;
    Ok((compute, Some(rec), stats))
}

/// Pre-training round (paper §3.2.1): embeddings for every push node are
/// computed on the unexpanded local subgraph and pushed, so round 1 pulls
/// never cold-start.
pub fn pretrain_push(
    client: &mut Client,
    g: &Graph,
    engine: &Arc<dyn StepEngine>,
    store: &dyn EmbeddingStore,
) -> Result<()> {
    let (_, _rec, _stats) = compute_and_push(
        &client.sub,
        &client.cache,
        &client.state,
        engine,
        store,
        &mut client.sampler,
        &client.adj_embed,
        &client.push_local,
        &client.push_globals,
        g,
        true,
    )?;
    Ok(())
}

/// Run one full client round with default staleness (push the ε-1 state,
/// overlapping the final epoch — the paper's configuration).
pub fn run_round(
    client: &mut Client,
    g: &Graph,
    strategy: &Strategy,
    engine: &Arc<dyn StepEngine>,
    store: &dyn EmbeddingStore,
    epochs: usize,
    lr: f32,
) -> Result<RoundOutcome> {
    run_round_stale(client, g, strategy, engine, store, epochs, lr, 1)
}

/// Run one full client round. `overlap_stale = k` pushes the state from
/// epoch ε-k and overlaps the transfer with the remaining k epochs (the
/// paper's §1 "different staleness configurations in overlapping
/// communication"; k=1 is the published configuration). Returns phase
/// metrics + epoch timings; the session composes virtual round time.
///
/// This entry point runs without the async pipeline (the overlap is
/// carried by a scoped thread and modeled in virtual time);
/// [`run_round_pipelined`] is the superset that makes it real.
#[allow(clippy::too_many_arguments)]
pub fn run_round_stale(
    client: &mut Client,
    g: &Graph,
    strategy: &Strategy,
    engine: &Arc<dyn StepEngine>,
    store: &dyn EmbeddingStore,
    epochs: usize,
    lr: f32,
    overlap_stale: usize,
) -> Result<RoundOutcome> {
    run_round_pipelined(client, g, strategy, engine, store, epochs, lr, overlap_stale, None)
}

/// The push pipeline's state after the overlap window: either a
/// synchronous push already completed on the scoped thread, or an async
/// ticket still (possibly) in flight on the store handle's workers.
enum PushJob {
    Sync(f64, Option<RpcRecord>, CacheStats),
    Async(f64, PushTicket, CacheStats),
}

/// [`run_round_stale`] with an optional [`AsyncStoreHandle`]. When the
/// handle is present (`--pipeline on`):
///
/// * the ε−k push RPC is submitted to the handle's background workers as
///   soon as its embeddings are computed and its ticket is joined at
///   round end, so the store I/O truly runs under the remaining epochs
///   (measured in [`OverlapMetrics`](super::metrics::OverlapMetrics)
///   `push_wall` / `push_wait`);
/// * the initial pull is served from the client's [`PendingPull`]
///   prefetch when one matching this round's pull set is waiting (issued
///   by [`issue_prefetch`] while the previous round aggregated), paying
///   only the residual `pull_wait`.
///
/// Pipelining changes *when* wall time is spent, never values: the
/// virtual phase accounting and the accuracy trajectory are identical to
/// the unpipelined round for a fixed seed (`tests/store_parity.rs`).
#[allow(clippy::too_many_arguments)]
pub fn run_round_pipelined(
    client: &mut Client,
    g: &Graph,
    strategy: &Strategy,
    engine: &Arc<dyn StepEngine>,
    store: &dyn EmbeddingStore,
    epochs: usize,
    lr: f32,
    overlap_stale: usize,
    pipeline: Option<&AsyncStoreHandle>,
) -> Result<RoundOutcome> {
    let dims = client.dims;
    let stale = overlap_stale.clamp(1, epochs.saturating_sub(1).max(1));
    let mut out = RoundOutcome {
        metrics: ClientRoundMetrics {
            client: client.id,
            ..Default::default()
        },
        overlapped: strategy.overlap_push && epochs >= 2,
        ..Default::default()
    };
    // take any waiting prefetch before the pull set can change below
    let pending = client.pending_pull.take();
    client.resample_dynamic_prune();

    // ---- pull phase ------------------------------------------------------
    client.cache.invalidate_all();
    let sharing = strategy.share_embeddings && client.sub.n_remote() > 0;
    if sharing {
        let rows: Vec<u32> = if strategy.prefetch.is_some() {
            client.prefetch_rows.clone()
        } else {
            client.active_remote_rows()
        };
        if !rows.is_empty() {
            let globals: Vec<u32> = rows.iter().map(|&r| client.sub.remote[r as usize]).collect();
            let mut pull_span = obs::span("trainer", "pull");
            pull_span.push_attr("client", client.id);
            pull_span.push_attr("rows", globals.len());
            let rec = match pending.and_then(|p| p.into_matching(&globals)) {
                Some(ticket) => {
                    // the RPC ran while the previous round aggregated /
                    // the previous client pushed; only the residual wait
                    // is a real stall
                    let join_sw = Stopwatch::start();
                    let done = ticket.wait()?;
                    out.metrics.overlap.pipelined = true;
                    out.metrics.overlap.pull_wall += done.wall;
                    out.metrics.overlap.pull_wait += join_sw.secs();
                    out.metrics.overlap.pull_bytes += done.rec.bytes;
                    out.metrics.overlap.store_epoch =
                        out.metrics.overlap.store_epoch.max(done.epoch);
                    client.pull_buf = done.rows;
                    done.rec
                }
                None => store.pull_into(&globals, false, &mut client.pull_buf)?,
            };
            client.cache.insert(&rows, &client.pull_buf);
            out.metrics.phases.pull += rec.time;
            out.metrics.embeddings_pulled += rec.rows;
            out.metrics.rpcs.push(rec);
        }
    }

    // ---- pre-generate target lists so the epoch loop borrows cleanly ----
    let target_lists: Vec<Vec<Vec<u32>>> = (0..epochs)
        .map(|_| {
            (0..client.epoch_batches)
                .map(|_| client.next_targets(dims.batch))
                .collect()
        })
        .collect();

    // ---- epochs (push of the ε-k state overlaps the last k epochs) ------
    let mut loss_acc = 0f64;
    let mut loss_n = 0usize;
    let mut push_result: Option<(f64, Option<RpcRecord>, CacheStats)> = None;
    let do_overlap = out.overlapped && sharing && !client.push_local.is_empty();
    // epoch index at which the push snapshot is taken / thread launched
    let overlap_at = epochs.saturating_sub(stale);

    // head epochs: run normally
    for targets in target_lists.iter().take(if do_overlap { overlap_at } else { epochs }) {
        let Client {
            sub,
            sampler,
            cache,
            state,
            adj_train,
            scratch,
            pull_buf,
            ..
        } = client;
        let mut ctx = EpochCtx {
            sub,
            sampler,
            cache,
            state,
            adj_train,
            scratch,
            pull_buf,
        };
        let (el, et) = run_epoch(&mut ctx, g, strategy, engine, store, targets, lr, &mut out)?;
        loss_acc += el;
        loss_n += targets.len();
        out.epoch_times.push(et);
    }

    // tail epochs: overlapped with the concurrent stale push
    if do_overlap {
        // snapshot ε-k state + cache; push concurrently with the remaining
        // epochs (stale by k epochs — paper §4.2 with k=1).
        let state_snap = client.state.clone();
        let cache_snap = client.cache.clone();
        let mut push_sampler =
            Sampler::new(dims, 0x9051 ^ client.id as u64, client.state.t as u64);
        let adj_embed = client.adj_embed.clone();
        let push_local = client.push_local.clone();
        let push_globals = client.push_globals.clone();
        // split-borrow the client so the push thread can share `sub`
        // while the epoch loop mutates sampler/cache/state
        let Client {
            sub,
            sampler,
            cache,
            state,
            adj_train,
            scratch,
            pull_buf,
            ..
        } = client;
        let mut ctx = EpochCtx {
            sub,
            sampler,
            cache,
            state,
            adj_train,
            scratch,
            pull_buf,
        };
        let sub_ref: &ClientSubgraph = ctx.sub;
        let overlap_sw = Stopwatch::start();
        let (epoch_res, push_res, epochs_wall) = std::thread::scope(|s| {
            let push_handle = s.spawn(move || -> Result<PushJob> {
                let (compute, per_layer, stats) = compute_push_layers(
                    sub_ref,
                    &cache_snap,
                    &state_snap,
                    engine,
                    &mut push_sampler,
                    &adj_embed,
                    &push_local,
                    g,
                    false,
                )?;
                Ok(match pipeline {
                    // hand the RPC to the async plane; its ticket is
                    // joined at round end, after the tail epochs
                    Some(handle) => {
                        PushJob::Async(compute, handle.push_async(push_globals, per_layer), stats)
                    }
                    None => {
                        let rec = store.push(&push_globals, &per_layer)?;
                        PushJob::Sync(compute, Some(rec), stats)
                    }
                })
            });
            let mut results = Vec::new();
            for targets in target_lists.iter().skip(overlap_at) {
                results.push((
                    run_epoch(&mut ctx, g, strategy, engine, store, targets, lr, &mut out),
                    targets.len(),
                ));
            }
            let epochs_wall = overlap_sw.secs();
            (results, push_handle.join().expect("push thread"), epochs_wall)
        });
        let scope_wall = overlap_sw.secs();
        for (res, n) in epoch_res {
            let (el, et) = res?;
            loss_acc += el;
            loss_n += n;
            out.epoch_times.push(et);
        }
        match push_res? {
            PushJob::Sync(compute, rec, stats) => {
                push_result = Some((compute, rec, stats));
            }
            PushJob::Async(compute, ticket, stats) => {
                let join_sw = Stopwatch::start();
                let done = ticket.wait()?;
                let ov = &mut out.metrics.overlap;
                ov.pipelined = true;
                // real work of the push pipeline vs. the stall the round
                // actually paid for it: the overhang of the embed-compute
                // thread past the tail epochs plus the ticket join
                ov.push_wall += compute + done.wall;
                ov.push_wait += (scope_wall - epochs_wall).max(0.0) + join_sw.secs();
                ov.push_bytes += done.rec.bytes;
                ov.store_epoch = ov.store_epoch.max(done.epoch);
                push_result = Some((compute, Some(done.rec), stats));
            }
        }
    }

    // ---- push phase (synchronous when not overlapped) --------------------
    if sharing && !client.push_local.is_empty() && push_result.is_none() {
        let mut push_sampler =
            Sampler::new(dims, 0x9052 ^ client.id as u64, client.state.t as u64);
        push_result = Some(compute_and_push(
            &client.sub,
            &client.cache,
            &client.state,
            engine,
            store,
            &mut push_sampler,
            &client.adj_embed,
            &client.push_local,
            &client.push_globals,
            g,
            false,
        )?);
    }

    if let Some((compute, rec, push_stats)) = push_result {
        let comm = rec.as_ref().map(|r| r.time).unwrap_or(0.0);
        out.push_total = compute + comm;
        out.metrics.cache.add(push_stats);
        if let Some(r) = rec {
            out.metrics.embeddings_pushed += r.rows;
            out.metrics.rpcs.push(r);
        }
    }
    // The visible push stack: the part not hidden under the last k
    // overlapped epochs (paper Fig 7 semantics; k=1 default).
    let tail_time: f64 = out
        .epoch_times
        .iter()
        .rev()
        .take(stale)
        .sum();
    if out.overlapped {
        let visible = (out.push_total - tail_time).max(0.0);
        out.metrics.phases.push = visible;
        out.metrics.phases.push_hidden = out.push_total - visible;
    } else {
        out.metrics.phases.push = out.push_total;
    }
    // measured overlap summary (real wall clock, recorded next to the §7
    // virtual model): pipeline work minus the stall actually paid for it
    if out.metrics.overlap.pipelined {
        let ov = &mut out.metrics.overlap;
        ov.overlap_saved = (ov.push_wall - ov.push_wait).max(0.0)
            + (ov.pull_wall - ov.pull_wait).max(0.0);
        if let Some(handle) = pipeline {
            ov.queue_peak = handle.peak_queue_depth();
        }
    }
    out.metrics.phases.train = out.epoch_times.iter().sum();
    out.metrics.train_loss = if loss_n > 0 {
        (loss_acc / loss_n as f64) as f32
    } else {
        0.0
    };
    Ok(out)
}

/// Issue the *next* initial pull of `client` on the async plane, if its
/// pull set is statically known (dynamic per-round pruning re-samples
/// the set at round start, so those rounds pull synchronously). Returns
/// the pending ticket to park on the client.
///
/// Value-safety contract (DESIGN.md §9): call this only once the store
/// already holds exactly what the client's next synchronous pull would
/// read — i.e. after the preceding client's push ticket is joined
/// (sequential mode) or after every client's round completed (parallel
/// mode / round boundary). Under that contract the prefetched rows are
/// bit-identical to an unpipelined pull and accuracy parity holds.
pub fn issue_prefetch(
    client: &Client,
    strategy: &Strategy,
    handle: &AsyncStoreHandle,
) -> Option<PendingPull> {
    if !strategy.share_embeddings || strategy.dynamic_prune || client.sub.n_remote() == 0 {
        return None;
    }
    let rows: Vec<u32> = if strategy.prefetch.is_some() {
        client.prefetch_rows.clone()
    } else {
        client.active_remote_rows()
    };
    if rows.is_empty() {
        return None;
    }
    let globals: Vec<u32> = rows.iter().map(|&r| client.sub.remote[r as usize]).collect();
    let ticket = handle.prefetch(globals.clone(), false);
    Some(PendingPull { globals, ticket })
}

/// Disjoint mutable parts of a client used by the epoch loop (lets the
/// overlapped push thread share `&sub` while the epoch mutates the rest).
struct EpochCtx<'a> {
    sub: &'a ClientSubgraph,
    sampler: &'a mut Sampler,
    cache: &'a mut EmbCache,
    state: &'a mut ModelState,
    adj_train: &'a SharedAdj,
    scratch: &'a mut BatchScratch,
    pull_buf: &'a mut Vec<Vec<f32>>,
}

/// One local epoch. Returns (summed batch loss, measured epoch seconds).
#[allow(clippy::too_many_arguments)]
fn run_epoch(
    ctx: &mut EpochCtx<'_>,
    g: &Graph,
    strategy: &Strategy,
    engine: &Arc<dyn StepEngine>,
    store: &dyn EmbeddingStore,
    targets: &[Vec<u32>],
    lr: f32,
    out: &mut RoundOutcome,
) -> Result<(f64, f64)> {
    let sw = Stopwatch::start();
    let mut epoch_span = obs::span("trainer", "epoch");
    epoch_span.push_attr("client", out.metrics.client);
    let mut loss = 0f64;
    for batch_targets in targets {
        if batch_targets.is_empty() {
            continue;
        }
        let _batch_span = obs::span("trainer", "batch");
        let blocks = ctx.sampler.sample_batch(ctx.sub, batch_targets);
        // OPP: pull missing used remotes on demand — at most one batched
        // RPC per minibatch (paper §4.3).
        if strategy.prefetch.is_some() {
            let used = blocks.used_remotes();
            let missing = ctx.cache.missing_of(&used);
            if !missing.is_empty() {
                let mut dyn_span = obs::span("trainer", "dyn_pull");
                dyn_span.push_attr("rows", missing.len());
                let globals: Vec<u32> = missing
                    .iter()
                    .map(|&r| ctx.sub.remote[r as usize])
                    .collect();
                let rec = store.pull_into(&globals, true, ctx.pull_buf)?;
                ctx.cache.insert(&missing, &*ctx.pull_buf);
                out.metrics.phases.dyn_pull += rec.time;
                out.metrics.embeddings_pulled += rec.rows;
                out.metrics.rpcs.push(rec);
            }
        } else if strategy.share_embeddings {
            debug_assert!(
                ctx.cache.missing_of(&blocks.used_remotes()).is_empty(),
                "non-prefetch strategy must have pulled everything"
            );
        }
        let batch = ctx
            .scratch
            .assemble(&blocks, ctx.sub, ctx.cache, g, ctx.adj_train, true);
        let stats = engine.train_step(ctx.state, batch, lr)?;
        out.metrics.cache.lookups += ctx.scratch.last_lookups;
        out.metrics.cache.misses += ctx.scratch.last_misses;
        loss += stats.loss as f64;
    }
    Ok((loss, sw.secs()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::embedding_server::EmbeddingServer;
    use crate::coordinator::netsim::NetConfig;
    use crate::graph::datasets::tiny;
    use crate::graph::partition::metis_lite;
    use crate::graph::subgraph::{build_all, Prune};
    use crate::runtime::manifest::{ModelGeom, ModelKind};
    use crate::runtime::RefEngine;

    fn engine() -> Arc<dyn StepEngine> {
        Arc::new(RefEngine::new(ModelGeom {
            model: ModelKind::Gc,
            layers: 3,
            feat: 32,
            hidden: 8,
            classes: 4,
            batch: 4,
            fanout: 3,
            push_batch: 4,
        }))
    }

    fn setup(prune: &Prune) -> (Graph, Vec<Client>, Arc<dyn StepEngine>, EmbeddingServer) {
        let g = tiny(61);
        let part = metis_lite(&g, 4, 2);
        let subs = build_all(&g, &part, prune, 5);
        let eng = engine();
        let server = EmbeddingServer::new(2, 8, NetConfig::default());
        let clients: Vec<Client> = subs
            .into_iter()
            .map(|s| {
                let mut c = Client::new(s, &eng, 3, 11);
                c.state = ModelState::init(eng.geom(), 1);
                let n = c.sub.n_remote();
                c.set_scores((0..n).map(|i| i as f32).collect(), Some(0.25));
                c
            })
            .collect();
        (g, clients, eng, server)
    }

    #[test]
    fn pretrain_populates_server() {
        let (g, mut clients, eng, server) = setup(&Prune::None);
        for c in clients.iter_mut() {
            pretrain_push(c, &g, &eng, &server).unwrap();
        }
        let total_push: usize = clients.iter().map(|c| c.push_globals.len()).sum();
        assert_eq!(server.stored_nodes(), total_push);
        assert!(total_push > 0);
    }

    #[test]
    fn e_round_pulls_everything_and_pushes() {
        let (g, mut clients, eng, server) = setup(&Prune::None);
        for c in clients.iter_mut() {
            pretrain_push(c, &g, &eng, &server).unwrap();
        }
        let strat = Strategy::e();
        let c = &mut clients[0];
        let out = run_round(c, &g, &strat, &eng, &server, 2, 0.01).unwrap();
        assert_eq!(out.metrics.embeddings_pulled, c.sub.n_remote());
        assert_eq!(out.metrics.embeddings_pushed, c.push_globals.len());
        assert_eq!(out.epoch_times.len(), 2);
        assert!(out.metrics.phases.pull > 0.0);
        assert!(out.push_total > 0.0);
        assert!(!out.overlapped);
        assert_eq!(out.metrics.phases.push, out.push_total);
        assert_eq!(c.cache.present_count(), c.sub.n_remote());
        // E pulls everything up front: training assemblies never miss
        // (push-embed assemblies may see zero lookups or hits only).
        assert_eq!(out.metrics.cache.misses, 0);
    }

    #[test]
    fn d_round_exchanges_nothing() {
        let (g, mut clients, eng, server) = setup(&Prune::Retention(0));
        let strat = Strategy::d();
        let out = run_round(&mut clients[0], &g, &strat, &eng, &server, 2, 0.01).unwrap();
        assert_eq!(out.metrics.embeddings_pulled, 0);
        assert_eq!(out.metrics.embeddings_pushed, 0);
        assert_eq!(out.metrics.phases.pull, 0.0);
        assert_eq!(out.push_total, 0.0);
        let (pulls, pushes) = server.rpc_counts();
        assert_eq!((pulls, pushes), (0, 0));
    }

    #[test]
    fn opp_prefetches_then_pulls_on_demand() {
        let (g, mut clients, eng, server) = setup(&Prune::None);
        for c in clients.iter_mut() {
            pretrain_push(c, &g, &eng, &server).unwrap();
        }
        let strat = Strategy::opp();
        let c = &mut clients[0];
        let prefetch_n = c.prefetch_rows.len();
        let out = run_round(c, &g, &strat, &eng, &server, 2, 0.01).unwrap();
        // initial pull fetched exactly the prefetch set
        let first = out
            .metrics
            .rpcs
            .iter()
            .find(|r| r.kind == crate::coordinator::metrics::RpcKind::Pull);
        if prefetch_n > 0 {
            assert_eq!(first.unwrap().rows, prefetch_n);
        }
        // on-demand RPCs <= minibatch count
        let dyn_calls = out
            .metrics
            .rpcs
            .iter()
            .filter(|r| r.kind == crate::coordinator::metrics::RpcKind::PullOnDemand)
            .count();
        assert!(dyn_calls <= 2 * 3, "dyn_calls={dyn_calls}");
        // every remote the round used is now cached
        assert!(c.cache.present_count() >= prefetch_n);
        // OPP pulls used remotes on demand pre-assembly: training batches
        // never assemble with a missing row
        assert_eq!(out.metrics.cache.misses, 0);
        assert!(out.metrics.cache.lookups > 0 || c.sub.n_remote() == 0);
    }

    #[test]
    fn misses_are_counted_when_cache_is_cold() {
        // Assemble directly against an empty cache: every remote row in
        // the blocks must be counted as a miss (the silent zero-fill is
        // now observable).
        let (g, clients, _eng, _server) = setup(&Prune::None);
        let c = clients
            .iter()
            .max_by_key(|c| c.sub.n_remote())
            .expect("clients");
        let mut sampler = Sampler::new(c.dims, 77, 0);
        let targets: Vec<u32> = c.sub.train_local.iter().copied().take(c.dims.batch).collect();
        if targets.is_empty() {
            return;
        }
        let mut scratch = BatchScratch::default();
        let mut total_remote = 0;
        for _ in 0..8 {
            let blocks = sampler.sample_batch(&c.sub, &targets);
            let n_remote: usize = (1..c.dims.layers)
                .map(|l| blocks.remote_rows(blocks.depth - l).count())
                .sum();
            total_remote += n_remote;
            scratch.assemble(&blocks, &c.sub, &c.cache, &g, &c.adj_train, true);
            assert_eq!(scratch.last_lookups, n_remote);
            assert_eq!(scratch.last_misses, n_remote);
        }
        assert!(total_remote > 0, "test graph sampled no remotes");
    }

    #[test]
    fn scratch_assembly_matches_allocating_assembly() {
        let (g, clients, _eng, _server) = setup(&Prune::None);
        let c = &clients[0];
        let mut sampler = Sampler::new(c.dims, 9, 1);
        let targets: Vec<u32> = c.sub.train_local.iter().copied().take(c.dims.batch).collect();
        let mut scratch = BatchScratch::default();
        for i in 0..5 {
            let blocks = sampler.sample_batch(&c.sub, &targets);
            let fresh = assemble_batch(&blocks, &c.sub, &c.cache, &g, &c.adj_train, i % 2 == 0);
            let reused = scratch.assemble(&blocks, &c.sub, &c.cache, &g, &c.adj_train, i % 2 == 0);
            assert_eq!(fresh.depth, reused.depth);
            assert_eq!(fresh.width, reused.width);
            assert_eq!(fresh.x, reused.x);
            assert!(Arc::ptr_eq(&fresh.adj, &reused.adj));
            assert_eq!(fresh.msk, reused.msk);
            assert_eq!(fresh.rmask, reused.rmask);
            assert_eq!(fresh.cache, reused.cache);
            assert_eq!(fresh.labels, reused.labels);
            assert_eq!(fresh.lmask, reused.lmask);
        }
    }

    #[test]
    fn overlap_hides_push_inside_last_epoch() {
        let (g, mut clients, eng, server) = setup(&Prune::None);
        for c in clients.iter_mut() {
            pretrain_push(c, &g, &eng, &server).unwrap();
        }
        let strat = Strategy::o();
        let c = &mut clients[1];
        let out = run_round(c, &g, &strat, &eng, &server, 3, 0.01).unwrap();
        assert!(out.overlapped);
        assert!(out.push_total > 0.0);
        // visible + hidden == total
        let p = out.metrics.phases;
        assert!((p.push + p.push_hidden - out.push_total).abs() < 1e-9);
        // model still updated by the final epoch
        assert!(c.state.t >= 3.0);
    }

    #[test]
    fn pipelined_round_records_real_overlap() {
        let (g, mut clients, eng, server) = setup(&Prune::None);
        for c in clients.iter_mut() {
            pretrain_push(c, &g, &eng, &server).unwrap();
        }
        let store: Arc<dyn EmbeddingStore> = Arc::new(server);
        let handle = AsyncStoreHandle::new(Arc::clone(&store));
        let c = &mut clients[1];
        let out = run_round_pipelined(
            c, &g, &Strategy::o(), &eng, store.as_ref(), 3, 0.01, 1, Some(&handle),
        )
        .unwrap();
        assert!(out.overlapped);
        let ov = out.metrics.overlap;
        assert!(ov.pipelined, "async push consumed no ticket");
        assert!(ov.push_wall > 0.0);
        assert!(ov.overlap_saved >= 0.0);
        assert!(ov.queue_peak >= 1);
        // the virtual model is untouched by the pipeline
        let p = out.metrics.phases;
        assert!((p.push + p.push_hidden - out.push_total).abs() < 1e-9);
        // model still updated by the final epoch
        assert!(c.state.t >= 3.0);
    }

    #[test]
    fn prefetch_ticket_is_consumed_by_next_round() {
        let (g, mut clients, eng, server) = setup(&Prune::None);
        for c in clients.iter_mut() {
            pretrain_push(c, &g, &eng, &server).unwrap();
        }
        let store: Arc<dyn EmbeddingStore> = Arc::new(server);
        let handle = AsyncStoreHandle::new(Arc::clone(&store));
        let c = &mut clients[0];
        let pending = issue_prefetch(c, &Strategy::e(), &handle);
        c.pending_pull = pending;
        assert!(c.pending_pull.is_some(), "static pull set must prefetch");
        let out = run_round_pipelined(
            c, &g, &Strategy::e(), &eng, store.as_ref(), 2, 0.01, 1, Some(&handle),
        )
        .unwrap();
        assert!(c.pending_pull.is_none(), "ticket must be consumed");
        let ov = out.metrics.overlap;
        assert!(ov.pipelined, "prefetched pull consumed no ticket");
        assert!(ov.pull_wall > 0.0);
        // pull accounting identical to the synchronous path
        assert_eq!(out.metrics.embeddings_pulled, c.sub.n_remote());
        assert!(out.metrics.phases.pull > 0.0);
        // D never prefetches (nothing shared)
        assert!(issue_prefetch(c, &Strategy::d(), &handle).is_none());
    }

    #[test]
    fn stale_push_uses_penultimate_state() {
        // With overlap, pushed embeddings are computed from the ε-1 state:
        // verify the server content differs from a post-final-epoch push.
        let (g, mut clients, eng, _) = setup(&Prune::None);
        let server_a = EmbeddingServer::new(2, 8, NetConfig::default());
        let server_b = EmbeddingServer::new(2, 8, NetConfig::default());
        for c in clients.iter_mut() {
            pretrain_push(c, &g, &eng, &server_a).unwrap();
            pretrain_push(c, &g, &eng, &server_b).unwrap();
        }
        let c = &mut clients[0];
        if c.push_globals.is_empty() {
            return;
        }
        let node = c.push_globals[0];
        let snapshot = c.state.clone();
        run_round(c, &g, &Strategy::o(), &eng, &server_a, 3, 0.05).unwrap();
        // replay without overlap from the same initial state
        c.state = snapshot;
        c.cache.invalidate_all();
        run_round(c, &g, &Strategy::e(), &eng, &server_b, 3, 0.05).unwrap();
        let (a, _) = server_a.pull(&[node], false);
        let (b, _) = server_b.pull(&[node], false);
        // same node, different model states -> different embeddings
        // (identical would mean the overlap pushed post-final state)
        assert_ne!(a[0], b[0]);
    }
}
