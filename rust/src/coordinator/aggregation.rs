//! Aggregation server: FedAvg over client models + global validation on a
//! held-out test set (paper §3.2.3).

use std::sync::Arc;

use anyhow::Result;

use crate::graph::sampler::{static_adj, Sampler};
use crate::graph::{Graph, Partition, Prune};
use crate::runtime::{Batch, ModelState, StepEngine, StepStats};

/// FedAvg: weighted average of client parameter vectors. Optimizer state
/// stays client-local (standard FedAvg aggregates parameters only).
pub fn fedavg(clients: &[(&ModelState, f64)]) -> Vec<Vec<f32>> {
    assert!(!clients.is_empty());
    let total_w: f64 = clients.iter().map(|(_, w)| *w).sum();
    let total_w = if total_w <= 0.0 {
        clients.len() as f64
    } else {
        total_w
    };
    let shapes: Vec<usize> = clients[0].0.params.iter().map(|p| p.len()).collect();
    let mut out: Vec<Vec<f32>> = shapes.iter().map(|&n| vec![0f32; n]).collect();
    for (state, w) in clients {
        let w = (*w / total_w) as f32;
        for (acc, p) in out.iter_mut().zip(&state.params) {
            for (a, &v) in acc.iter_mut().zip(p) {
                *a += w * v;
            }
        }
    }
    out
}

/// Global validation set: fixed pre-sampled eval batches over the full
/// graph (the aggregation server holds the held-out test set; remote
/// masks are zero since it sees every vertex).
pub struct Validator {
    batches: Vec<Batch>,
}

impl Validator {
    pub fn new(
        g: &Graph,
        engine: &Arc<dyn StepEngine>,
        max_batches: usize,
        seed: u64,
    ) -> Self {
        let geom = *engine.geom();
        let dims = geom.dims();
        // A single "client" owning the whole graph: partition with k=1.
        let part = Partition {
            k: 1,
            assign: vec![0u32; g.n],
        };
        let subs = crate::graph::subgraph::build_all(g, &part, &Prune::None, seed);
        let sub = &subs[0];
        let mut sampler = Sampler::new(dims, seed, 0xE7A1);
        let adj = static_adj(&dims, dims.batch, dims.layers);
        let b = dims.batch;
        let mut batches = Vec::new();
        let mut test_locals: Vec<u32> = g
            .test_nodes
            .iter()
            .filter_map(|v| sub.local_index(*v))
            .collect();
        test_locals.truncate(max_batches * b);
        for chunk in test_locals.chunks(b) {
            let blocks = sampler.sample_batch(sub, chunk);
            let depth = blocks.depth;
            let s_deep = blocks.levels[depth].len();
            let mut x = vec![0f32; s_deep * dims.feat];
            blocks.fill_x(sub, g, &mut x);
            let mut labels = vec![0i32; b];
            let mut lmask = vec![0f32; b];
            blocks.fill_labels(sub, g, &mut labels, &mut lmask);
            // no remote vertices: rmask/cache all zero
            let rmask: Vec<Vec<f32>> = (1..dims.layers)
                .map(|l| vec![0f32; blocks.levels[depth - l].len()])
                .collect();
            let cache: Vec<Vec<f32>> = (1..dims.layers)
                .map(|l| vec![0f32; blocks.levels[depth - l].len() * dims.hidden])
                .collect();
            batches.push(Batch {
                depth,
                width: b,
                x,
                adj: adj.clone(),
                msk: blocks.msk.clone(),
                rmask,
                cache,
                labels,
                lmask,
            });
        }
        Self { batches }
    }

    /// Evaluate a (global) model; returns (accuracy, mean loss).
    pub fn evaluate(
        &self,
        engine: &Arc<dyn StepEngine>,
        params: &[Vec<f32>],
    ) -> Result<(f64, f64)> {
        let geom = *engine.geom();
        let mut state = ModelState::zeros(&geom);
        state.params = params.to_vec();
        let mut correct = 0f64;
        let mut total = 0f64;
        let mut loss_sum = 0f64;
        for b in &self.batches {
            let s: StepStats = engine.evaluate(&state, b)?;
            correct += s.correct as f64;
            total += s.total as f64;
            loss_sum += (s.loss * s.total) as f64;
        }
        if total == 0.0 {
            return Ok((0.0, 0.0));
        }
        Ok((correct / total, loss_sum / total))
    }

    pub fn n_batches(&self) -> usize {
        self.batches.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny;
    use crate::runtime::manifest::{ModelGeom, ModelKind};
    use crate::runtime::RefEngine;

    fn engine() -> Arc<dyn StepEngine> {
        Arc::new(RefEngine::new(ModelGeom {
            model: ModelKind::Gc,
            layers: 3,
            feat: 32,
            hidden: 16,
            classes: 4,
            batch: 8,
            fanout: 3,
            push_batch: 8,
        }))
    }

    #[test]
    fn fedavg_weighted_mean() {
        let geom = ModelGeom {
            model: ModelKind::Gc,
            layers: 3,
            feat: 4,
            hidden: 4,
            classes: 2,
            batch: 2,
            fanout: 2,
            push_batch: 2,
        };
        let mut a = ModelState::zeros(&geom);
        let mut b = ModelState::zeros(&geom);
        for p in a.params.iter_mut() {
            p.iter_mut().for_each(|v| *v = 1.0);
        }
        for p in b.params.iter_mut() {
            p.iter_mut().for_each(|v| *v = 3.0);
        }
        let avg = fedavg(&[(&a, 1.0), (&b, 1.0)]);
        assert!(avg.iter().flatten().all(|&v| (v - 2.0).abs() < 1e-6));
        let weighted = fedavg(&[(&a, 3.0), (&b, 1.0)]);
        assert!(weighted.iter().flatten().all(|&v| (v - 1.5).abs() < 1e-6));
    }

    #[test]
    fn validator_counts_test_vertices() {
        let g = tiny(41);
        let eng = engine();
        let v = Validator::new(&g, &eng, 4, 7);
        assert!(v.n_batches() >= 1 && v.n_batches() <= 4);
        let st = ModelState::init(eng.geom(), 1);
        let (acc, loss) = v.evaluate(&eng, &st.params).unwrap();
        assert!((0.0..=1.0).contains(&acc));
        assert!(loss.is_finite() && loss > 0.0);
    }

    #[test]
    fn trained_model_beats_random_on_validation() {
        // quick sanity: accuracy of an untrained model ~ 1/classes.
        let g = tiny(43);
        let eng = engine();
        let v = Validator::new(&g, &eng, 6, 9);
        let st = ModelState::init(eng.geom(), 2);
        let (acc, _) = v.evaluate(&eng, &st.params).unwrap();
        assert!(acc < 0.6, "untrained acc suspiciously high: {acc}");
    }
}
