//! Aggregation server: model aggregation over client states + global
//! validation on a held-out test set (paper §3.2.3).
//!
//! Aggregation is a pluggable seam: the session calls an [`Aggregator`]
//! trait object, so the paper's weighted FedAvg ([`FedAvg`]) can be
//! swapped for robust variants ([`UniformAvg`], [`TrimmedMean`]) without
//! touching the round loop.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::graph::sampler::{static_adj, Sampler};
use crate::graph::{Graph, Partition, Prune};
use crate::runtime::{Batch, ModelState, StepEngine, StepStats};

/// Combines the clients' post-round model states into the next global
/// parameter set. `clients` pairs each state with its aggregation weight
/// (the session passes local-training-set sizes).
pub trait Aggregator: Send + Sync {
    /// Short name for reports / `optimes info` ("fedavg", "trimmed2", ...).
    fn name(&self) -> String;

    fn aggregate(&self, clients: &[(&ModelState, f64)]) -> Vec<Vec<f32>>;
}

/// The paper's aggregation: example-count-weighted FedAvg.
pub struct FedAvg;

impl Aggregator for FedAvg {
    fn name(&self) -> String {
        "fedavg".into()
    }

    fn aggregate(&self, clients: &[(&ModelState, f64)]) -> Vec<Vec<f32>> {
        fedavg(clients)
    }
}

/// Unweighted mean — every client counts equally regardless of how much
/// local data it holds.
pub struct UniformAvg;

impl Aggregator for UniformAvg {
    fn name(&self) -> String {
        "uniform".into()
    }

    fn aggregate(&self, clients: &[(&ModelState, f64)]) -> Vec<Vec<f32>> {
        let uniform: Vec<(&ModelState, f64)> = clients.iter().map(|(s, _)| (*s, 1.0)).collect();
        fedavg(&uniform)
    }
}

/// Coordinate-wise trimmed mean: per parameter, drop the `trim` lowest
/// and `trim` highest client values and average the rest (robust to
/// stragglers/outliers; weights are ignored). Falls back to the plain
/// mean when `2*trim >= n`.
pub struct TrimmedMean {
    pub trim: usize,
}

impl Aggregator for TrimmedMean {
    fn name(&self) -> String {
        format!("trimmed{}", self.trim)
    }

    fn aggregate(&self, clients: &[(&ModelState, f64)]) -> Vec<Vec<f32>> {
        assert!(!clients.is_empty());
        let n = clients.len();
        let trim = if 2 * self.trim >= n { 0 } else { self.trim };
        let keep = (n - 2 * trim) as f32;
        let shapes: Vec<usize> = clients[0].0.params.iter().map(|p| p.len()).collect();
        let mut out: Vec<Vec<f32>> = shapes.iter().map(|&m| vec![0f32; m]).collect();
        let mut vals = vec![0f32; n];
        for (t, acc) in out.iter_mut().enumerate() {
            for (j, a) in acc.iter_mut().enumerate() {
                for (slot, (state, _)) in vals.iter_mut().zip(clients) {
                    *slot = state.params[t][j];
                }
                vals.sort_by(|x, y| x.partial_cmp(y).expect("finite params"));
                *a = vals[trim..n - trim].iter().sum::<f32>() / keep;
            }
        }
        out
    }
}

/// Parse a CLI aggregator spec: `fedavg` | `uniform` | `trimmed[:k]`
/// (`trimmed` alone trims 1 from each tail).
pub fn parse_aggregator(s: &str) -> Result<Arc<dyn Aggregator>> {
    let lower = s.to_ascii_lowercase();
    if lower == "fedavg" {
        return Ok(Arc::new(FedAvg));
    }
    if lower == "uniform" {
        return Ok(Arc::new(UniformAvg));
    }
    if let Some(rest) = lower.strip_prefix("trimmed") {
        let core = rest.strip_prefix(':').unwrap_or(rest);
        if core.is_empty() {
            return Ok(Arc::new(TrimmedMean { trim: 1 }));
        }
        if let Ok(trim) = core.parse::<usize>() {
            return Ok(Arc::new(TrimmedMean { trim }));
        }
    }
    bail!("unknown aggregator {s:?} (expected fedavg | uniform | trimmed[:k])")
}

/// FedAvg: weighted average of client parameter vectors. Optimizer state
/// stays client-local (standard FedAvg aggregates parameters only).
pub fn fedavg(clients: &[(&ModelState, f64)]) -> Vec<Vec<f32>> {
    assert!(!clients.is_empty());
    let total_w: f64 = clients.iter().map(|(_, w)| *w).sum();
    let total_w = if total_w <= 0.0 {
        clients.len() as f64
    } else {
        total_w
    };
    let shapes: Vec<usize> = clients[0].0.params.iter().map(|p| p.len()).collect();
    let mut out: Vec<Vec<f32>> = shapes.iter().map(|&n| vec![0f32; n]).collect();
    for (state, w) in clients {
        let w = (*w / total_w) as f32;
        for (acc, p) in out.iter_mut().zip(&state.params) {
            for (a, &v) in acc.iter_mut().zip(p) {
                *a += w * v;
            }
        }
    }
    out
}

/// Global validation set: fixed pre-sampled eval batches over the full
/// graph (the aggregation server holds the held-out test set; remote
/// masks are zero since it sees every vertex).
pub struct Validator {
    batches: Vec<Batch>,
}

impl Validator {
    pub fn new(
        g: &Graph,
        engine: &Arc<dyn StepEngine>,
        max_batches: usize,
        seed: u64,
    ) -> Self {
        let geom = *engine.geom();
        let dims = geom.dims();
        // A single "client" owning the whole graph: partition with k=1.
        let part = Partition {
            k: 1,
            assign: vec![0u32; g.n],
        };
        let subs = crate::graph::subgraph::build_all(g, &part, &Prune::None, seed);
        let sub = &subs[0];
        let mut sampler = Sampler::new(dims, seed, 0xE7A1);
        let adj = static_adj(&dims, dims.batch, dims.layers);
        let b = dims.batch;
        let mut batches = Vec::new();
        let mut test_locals: Vec<u32> = g
            .test_nodes
            .iter()
            .filter_map(|v| sub.local_index(*v))
            .collect();
        test_locals.truncate(max_batches * b);
        for chunk in test_locals.chunks(b) {
            let blocks = sampler.sample_batch(sub, chunk);
            let depth = blocks.depth;
            let s_deep = blocks.levels[depth].len();
            let mut x = vec![0f32; s_deep * dims.feat];
            blocks.fill_x(sub, g, &mut x);
            let mut labels = vec![0i32; b];
            let mut lmask = vec![0f32; b];
            blocks.fill_labels(sub, g, &mut labels, &mut lmask);
            // no remote vertices: rmask/cache all zero
            let rmask: Vec<Vec<f32>> = (1..dims.layers)
                .map(|l| vec![0f32; blocks.levels[depth - l].len()])
                .collect();
            let cache: Vec<Vec<f32>> = (1..dims.layers)
                .map(|l| vec![0f32; blocks.levels[depth - l].len() * dims.hidden])
                .collect();
            batches.push(Batch {
                depth,
                width: b,
                x,
                adj: adj.clone(),
                msk: blocks.msk.clone(),
                rmask,
                cache,
                labels,
                lmask,
            });
        }
        Self { batches }
    }

    /// Evaluate a (global) model; returns (accuracy, mean loss).
    pub fn evaluate(
        &self,
        engine: &Arc<dyn StepEngine>,
        params: &[Vec<f32>],
    ) -> Result<(f64, f64)> {
        let geom = *engine.geom();
        let mut state = ModelState::zeros(&geom);
        state.params = params.to_vec();
        let mut correct = 0f64;
        let mut total = 0f64;
        let mut loss_sum = 0f64;
        for b in &self.batches {
            let s: StepStats = engine.evaluate(&state, b)?;
            correct += s.correct as f64;
            total += s.total as f64;
            loss_sum += (s.loss * s.total) as f64;
        }
        if total == 0.0 {
            return Ok((0.0, 0.0));
        }
        Ok((correct / total, loss_sum / total))
    }

    pub fn n_batches(&self) -> usize {
        self.batches.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny;
    use crate::runtime::manifest::{ModelGeom, ModelKind};
    use crate::runtime::RefEngine;

    fn engine() -> Arc<dyn StepEngine> {
        Arc::new(RefEngine::new(ModelGeom {
            model: ModelKind::Gc,
            layers: 3,
            feat: 32,
            hidden: 16,
            classes: 4,
            batch: 8,
            fanout: 3,
            push_batch: 8,
        }))
    }

    fn const_state(geom: &ModelGeom, v: f32) -> ModelState {
        let mut s = ModelState::zeros(geom);
        for p in s.params.iter_mut() {
            p.iter_mut().for_each(|x| *x = v);
        }
        s
    }

    fn small_geom() -> ModelGeom {
        ModelGeom {
            model: ModelKind::Gc,
            layers: 3,
            feat: 4,
            hidden: 4,
            classes: 2,
            batch: 2,
            fanout: 2,
            push_batch: 2,
        }
    }

    #[test]
    fn fedavg_weighted_mean() {
        let geom = small_geom();
        let a = const_state(&geom, 1.0);
        let b = const_state(&geom, 3.0);
        let avg = fedavg(&[(&a, 1.0), (&b, 1.0)]);
        assert!(avg.iter().flatten().all(|&v| (v - 2.0).abs() < 1e-6));
        let weighted = fedavg(&[(&a, 3.0), (&b, 1.0)]);
        assert!(weighted.iter().flatten().all(|&v| (v - 1.5).abs() < 1e-6));
    }

    #[test]
    fn uniform_aggregator_ignores_weights() {
        let geom = small_geom();
        let a = const_state(&geom, 1.0);
        let b = const_state(&geom, 3.0);
        // heavily skewed weights: FedAvg leans to `a`, uniform does not
        let clients = [(&a, 100.0), (&b, 1.0)];
        let fed = FedAvg.aggregate(&clients);
        let uni = UniformAvg.aggregate(&clients);
        assert!(fed.iter().flatten().all(|&v| v < 1.1));
        assert!(uni.iter().flatten().all(|&v| (v - 2.0).abs() < 1e-6));
        assert_eq!(FedAvg.name(), "fedavg");
        assert_eq!(UniformAvg.name(), "uniform");
    }

    #[test]
    fn trimmed_mean_resists_outlier_client() {
        let geom = small_geom();
        let honest: Vec<ModelState> =
            [1.0, 2.0, 3.0].iter().map(|&v| const_state(&geom, v)).collect();
        let outlier = const_state(&geom, 1e6);
        let clients: Vec<(&ModelState, f64)> = honest
            .iter()
            .chain(std::iter::once(&outlier))
            .map(|s| (s, 1.0))
            .collect();
        let t = TrimmedMean { trim: 1 }.aggregate(&clients);
        // trims 1e6 and 1.0, averages {2, 3}
        assert!(t.iter().flatten().all(|&v| (v - 2.5).abs() < 1e-6));
        // over-trimming falls back to the plain mean
        let two = [(&honest[0], 1.0), (&honest[1], 1.0)];
        let fallback = TrimmedMean { trim: 5 }.aggregate(&two);
        assert!(fallback.iter().flatten().all(|&v| (v - 1.5).abs() < 1e-6));
        assert_eq!(TrimmedMean { trim: 2 }.name(), "trimmed2");
    }

    #[test]
    fn aggregator_spec_parses() {
        assert_eq!(parse_aggregator("fedavg").unwrap().name(), "fedavg");
        assert_eq!(parse_aggregator("UNIFORM").unwrap().name(), "uniform");
        assert_eq!(parse_aggregator("trimmed").unwrap().name(), "trimmed1");
        assert_eq!(parse_aggregator("trimmed:2").unwrap().name(), "trimmed2");
        assert_eq!(parse_aggregator("trimmed3").unwrap().name(), "trimmed3");
        let err = parse_aggregator("median").unwrap_err().to_string();
        assert!(err.contains("fedavg"), "{err}");
    }

    #[test]
    fn validator_counts_test_vertices() {
        let g = tiny(41);
        let eng = engine();
        let v = Validator::new(&g, &eng, 4, 7);
        assert!(v.n_batches() >= 1 && v.n_batches() <= 4);
        let st = ModelState::init(eng.geom(), 1);
        let (acc, loss) = v.evaluate(&eng, &st.params).unwrap();
        assert!((0.0..=1.0).contains(&acc));
        assert!(loss.is_finite() && loss > 0.0);
    }

    #[test]
    fn trained_model_beats_random_on_validation() {
        // quick sanity: accuracy of an untrained model ~ 1/classes.
        let g = tiny(43);
        let eng = engine();
        let v = Validator::new(&g, &eng, 6, 9);
        let st = ModelState::init(eng.geom(), 2);
        let (acc, _) = v.evaluate(&eng, &st.params).unwrap();
        assert!(acc < 0.6, "untrained acc suspiciously high: {acc}");
    }
}
