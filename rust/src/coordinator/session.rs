//! Session driver: the full federated lifecycle of Fig 3 / Fig 5 —
//! partition, pre-training round, then `rounds` iterations of
//! {broadcast global model → pull → ε local epochs → push → FedAvg →
//! global validation} across all clients, with virtual-time round
//! accounting (DESIGN.md §7).

use std::sync::Arc;

use anyhow::{Context, Result};

use super::aggregation::{fedavg, Validator};
use super::client::Client;
use super::embedding_server::EmbeddingServer;
use super::metrics::{PhaseTimes, RoundMetrics, SessionMetrics};
use super::netsim::NetConfig;
use super::strategy::{ScoreKind, Strategy};
use super::trainer::pretrain_push;
use crate::graph::partition::metis_lite;
use crate::graph::scoring;
use crate::graph::subgraph::{build_all_per_client, Prune};
use crate::graph::{Graph, Partition};
use crate::runtime::{ModelState, StepEngine};
use crate::util::rng::Rng;
use crate::util::Stopwatch;

#[derive(Clone, Debug)]
pub struct SessionConfig {
    pub dataset: String,
    pub clients: usize,
    pub strategy: Strategy,
    pub rounds: usize,
    /// Local epochs per round (paper: ε = 3).
    pub epochs: usize,
    pub lr: f32,
    /// Minibatches per local epoch.
    pub epoch_batches: usize,
    /// Global-validation batches (fixed across rounds).
    pub eval_batches: usize,
    pub seed: u64,
    pub net: NetConfig,
    /// Run client rounds on parallel threads (true = deployment-like;
    /// false = deterministic timing for ablations).
    pub parallel_clients: bool,
    /// Staleness k for the push overlap: push the state from epoch ε-k,
    /// overlapping the last k epochs (paper default k=1; §1 mentions the
    /// staleness-configuration ablation).
    pub overlap_stale: usize,
    /// Reset client Adam moments when the global model is broadcast
    /// (FedAvg resets the loss surface; stale moments from the
    /// pre-aggregation parameters are destructive).
    pub reset_opt_each_round: bool,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            dataset: "tiny".into(),
            clients: 4,
            strategy: Strategy::e(),
            rounds: 10,
            epochs: 3,
            lr: 0.003,
            epoch_batches: 8,
            eval_batches: 8,
            seed: 42,
            net: NetConfig::default(),
            parallel_clients: true,
            overlap_stale: 1,
            reset_opt_each_round: true,
        }
    }
}

/// Per-remote-index scores for a client under a [`ScoreKind`].
fn client_scores(
    kind: ScoreKind,
    sub: &crate::graph::ClientSubgraph,
    layers: usize,
    merged: &std::collections::HashMap<u32, f32>,
    seed: u64,
) -> Vec<f32> {
    match kind {
        ScoreKind::Frequency => scoring::frequency_scores(sub, layers, 768, seed),
        ScoreKind::Random => {
            let mut rng = Rng::new(seed, 0x5C02E + sub.client_id as u64);
            (0..sub.n_remote()).map(|_| rng.f32()).collect()
        }
        ScoreKind::Degree | ScoreKind::Bridge => sub
            .remote
            .iter()
            .map(|gid| merged.get(gid).copied().unwrap_or(0.0))
            .collect(),
    }
}

/// Owner-side centrality maps, exchanged in pre-training (paper §4.1.2).
fn merged_centrality(
    kind: ScoreKind,
    g: &Graph,
    part: &Partition,
    seed: u64,
) -> std::collections::HashMap<u32, f32> {
    match kind {
        ScoreKind::Degree => scoring::merge_scores(
            (0..part.k)
                .map(|c| scoring::degree_scores_local(g, part, c))
                .collect(),
        ),
        ScoreKind::Bridge => scoring::merge_scores(
            (0..part.k)
                .map(|c| scoring::bridge_scores_local(g, part, c, 48, seed))
                .collect(),
        ),
        _ => std::collections::HashMap::new(),
    }
}

pub fn run_session(
    g: &Graph,
    cfg: &SessionConfig,
    engine: Arc<dyn StepEngine>,
) -> Result<SessionMetrics> {
    let geom = *engine.geom();
    let strat = &cfg.strategy;
    let part = metis_lite(g, cfg.clients, cfg.seed);

    // ---- subgraph expansion + pruning ------------------------------------
    let base_prune = match strat.retention {
        // dynamic pruning expands un-pruned and re-samples per round
        Some(_) if strat.dynamic_prune => Prune::None,
        Some(i) => Prune::Retention(i),
        None => Prune::None,
    };
    let prunes: Vec<Prune> = if let Some(sp) = strat.scored_prune {
        // two-phase: expand un-scored first, score, then re-expand with
        // the per-client top-f% (offline pre-training work, §4.1.2)
        let probe = build_all_per_client(g, &part, &vec![base_prune.clone(); part.k], cfg.seed);
        let merged = merged_centrality(sp.score, g, &part, cfg.seed);
        probe
            .iter()
            .map(|sub| {
                let scores = client_scores(sp.score, sub, geom.layers, &merged, cfg.seed);
                let map: std::collections::HashMap<u32, f32> = sub
                    .remote
                    .iter()
                    .zip(&scores)
                    .map(|(gid, s)| (*gid, *s))
                    .collect();
                Prune::TopFrac {
                    frac: sp.top_frac,
                    scores: map,
                }
            })
            .collect()
    } else {
        vec![base_prune; part.k]
    };
    let subs = build_all_per_client(g, &part, &prunes, cfg.seed);
    let pull_candidates: usize = subs.iter().map(|s| s.pull_candidates).sum();
    let retained_remotes: usize = subs.iter().map(|s| s.n_remote()).sum();

    // ---- infrastructure ---------------------------------------------------
    let server = EmbeddingServer::new(geom.layers - 1, geom.hidden, cfg.net);
    let validator = Validator::new(g, &engine, cfg.eval_batches, cfg.seed ^ 0xEA);
    let mut global = ModelState::init(&geom, cfg.seed).params;

    let mut clients: Vec<Client> = subs
        .into_iter()
        .map(|sub| {
            let mut c = Client::new(sub, &engine, cfg.epoch_batches, cfg.seed);
            c.state.params = global.clone();
            if let (true, Some(limit)) = (strat.dynamic_prune, strat.retention) {
                c.enable_dynamic_prune(limit);
            }
            c
        })
        .collect();

    // OPP prefetch scores on the *final* (possibly pruned) subgraphs.
    if let Some(pf) = strat.prefetch {
        let merged = merged_centrality(pf.score, g, &part, cfg.seed);
        for c in clients.iter_mut() {
            let scores = client_scores(pf.score, &c.sub, geom.layers, &merged, cfg.seed);
            c.set_scores(scores, Some(pf.top_frac));
        }
    }

    // ---- pre-training round (§3.2.1) --------------------------------------
    if strat.share_embeddings {
        for c in clients.iter_mut() {
            pretrain_push(c, g, &engine, &server).context("pretrain push")?;
        }
    }

    // ---- federated rounds --------------------------------------------------
    let mut metrics = SessionMetrics {
        strategy: strat.name.clone(),
        dataset: cfg.dataset.clone(),
        n_clients: cfg.clients,
        pull_candidates,
        retained_remotes,
        ..Default::default()
    };

    for round in 0..cfg.rounds {
        // broadcast the global model
        for c in clients.iter_mut() {
            c.state.params = global.clone();
            if cfg.reset_opt_each_round {
                for m in c.state.m.iter_mut() {
                    m.iter_mut().for_each(|v| *v = 0.0);
                }
                for v in c.state.v.iter_mut() {
                    v.iter_mut().for_each(|x| *x = 0.0);
                }
                c.state.t = 0.0;
            }
        }
        // run every client's local round
        let outcomes: Vec<super::trainer::RoundOutcome> = if cfg.parallel_clients {
            let engine_ref = &engine;
            let server_ref = &server;
            let results: Vec<Result<super::trainer::RoundOutcome>> =
                std::thread::scope(|s| {
                    let handles: Vec<_> = clients
                        .iter_mut()
                        .map(|c| {
                            s.spawn(move || {
                                super::trainer::run_round_stale(
                                    c,
                                    g,
                                    strat,
                                    engine_ref,
                                    server_ref,
                                    cfg.epochs,
                                    cfg.lr,
                                    cfg.overlap_stale,
                                )
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("client thread"))
                        .collect()
                });
            results.into_iter().collect::<Result<Vec<_>>>()?
        } else {
            let mut outs = Vec::with_capacity(clients.len());
            for c in clients.iter_mut() {
                outs.push(super::trainer::run_round_stale(
                    c,
                    g,
                    strat,
                    &engine,
                    &server,
                    cfg.epochs,
                    cfg.lr,
                    cfg.overlap_stale,
                )?);
            }
            outs
        };

        // aggregate
        let agg_sw = Stopwatch::start();
        let weighted: Vec<(&ModelState, f64)> = clients
            .iter()
            .map(|c| (&c.state, c.sub.train_local.len().max(1) as f64))
            .collect();
        global = fedavg(&weighted);
        let (acc, val_loss) = validator.evaluate(&engine, &global)?;
        let agg_time = agg_sw.secs();

        // compose round metrics (virtual time; DESIGN.md §7)
        let mut rm = RoundMetrics {
            round,
            accuracy: acc,
            val_loss,
            ..Default::default()
        };
        let mut worst = 0f64;
        let mut mean = PhaseTimes::default();
        for o in &outcomes {
            let t = o.metrics.phases.total();
            if t >= worst {
                worst = t;
                rm.critical = o.metrics.phases;
            }
            mean.pull += o.metrics.phases.pull;
            mean.train += o.metrics.phases.train;
            mean.dyn_pull += o.metrics.phases.dyn_pull;
            mean.push += o.metrics.phases.push;
            mean.push_hidden += o.metrics.phases.push_hidden;
            rm.clients.push(o.metrics.clone());
        }
        let n = outcomes.len().max(1) as f64;
        mean.pull /= n;
        mean.train /= n;
        mean.dyn_pull /= n;
        mean.push /= n;
        mean.push_hidden /= n;
        rm.mean_phases = mean;
        rm.round_time = worst + agg_time + cfg.net.params_time(global.iter().map(|p| p.len()).sum());
        metrics.rounds.push(rm);

        if round == 0 {
            metrics.server_embeddings = server.stored_nodes();
        }
    }
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny;
    use crate::runtime::manifest::{ModelGeom, ModelKind};
    use crate::runtime::RefEngine;

    fn engine() -> Arc<dyn StepEngine> {
        Arc::new(RefEngine::new(ModelGeom {
            model: ModelKind::Gc,
            layers: 3,
            feat: 32,
            hidden: 16,
            classes: 4,
            batch: 8,
            fanout: 3,
            push_batch: 8,
        }))
    }

    fn cfg(strategy: Strategy, rounds: usize) -> SessionConfig {
        SessionConfig {
            strategy,
            rounds,
            epochs: 2,
            epoch_batches: 4,
            eval_batches: 4,
            parallel_clients: false,
            ..Default::default()
        }
    }

    #[test]
    fn session_runs_and_learns_e() {
        let g = tiny(71);
        let m = run_session(&g, &cfg(Strategy::e(), 8), engine()).unwrap();
        assert_eq!(m.rounds.len(), 8);
        // should comfortably beat 1/classes = 0.25 on the planted task
        assert!(
            m.peak_accuracy() > 0.4,
            "peak accuracy {}",
            m.peak_accuracy()
        );
        assert!(m.server_embeddings > 0);
        assert!(m.median_round_time() > 0.0);
        // every round pulled + pushed
        for r in &m.rounds {
            assert!(r.mean_phases.pull > 0.0);
            assert!(r.mean_phases.push > 0.0);
            assert!(r.mean_phases.train > 0.0);
        }
    }

    #[test]
    fn d_has_no_comm_and_lower_accuracy_than_e() {
        let g = tiny(73);
        let e = run_session(&g, &cfg(Strategy::e(), 10), engine()).unwrap();
        let d = run_session(&g, &cfg(Strategy::d(), 10), engine()).unwrap();
        for r in &d.rounds {
            assert_eq!(r.mean_phases.pull, 0.0);
            assert_eq!(r.mean_phases.push, 0.0);
        }
        assert_eq!(d.server_embeddings, 0);
        // D's rounds must be faster (no comm)
        assert!(d.median_round_time() < e.median_round_time());
    }

    #[test]
    fn all_ladder_strategies_run() {
        let g = tiny(75);
        for s in Strategy::ladder() {
            let name = s.name.clone();
            let m = run_session(&g, &cfg(s, 3), engine())
                .unwrap_or_else(|e| panic!("strategy {name}: {e}"));
            assert_eq!(m.rounds.len(), 3, "{name}");
            assert!(m.rounds.iter().all(|r| r.accuracy.is_finite()));
        }
    }

    #[test]
    fn retention_shrinks_server_footprint() {
        let g = tiny(77);
        let e = run_session(&g, &cfg(Strategy::e(), 2), engine()).unwrap();
        let p2 = run_session(&g, &cfg(Strategy::parse("P2").unwrap(), 2), engine()).unwrap();
        let p0 = run_session(&g, &cfg(Strategy::parse("P0").unwrap(), 2), engine()).unwrap();
        assert!(p2.server_embeddings < e.server_embeddings);
        assert_eq!(p0.server_embeddings, 0);
        assert!(p2.retained_remotes < e.retained_remotes);
    }

    #[test]
    fn opg_prunes_to_top_fraction() {
        let g = tiny(79);
        let e = run_session(&g, &cfg(Strategy::e(), 2), engine()).unwrap();
        let opg = run_session(&g, &cfg(Strategy::opg(), 2), engine()).unwrap();
        assert!(
            (opg.retained_remotes as f64) < 0.5 * e.retained_remotes as f64,
            "opg {} vs e {}",
            opg.retained_remotes,
            e.retained_remotes
        );
    }

    #[test]
    fn opp_round_time_contains_dyn_pull() {
        let g = tiny(81);
        let m = run_session(&g, &cfg(Strategy::opp(), 3), engine()).unwrap();
        let any_dyn = m
            .rounds
            .iter()
            .any(|r| r.mean_phases.dyn_pull > 0.0);
        assert!(any_dyn, "OPP never pulled on demand");
    }

    #[test]
    fn parallel_and_sequential_agree_on_structure() {
        let g = tiny(83);
        let mut c = cfg(Strategy::op(), 2);
        c.parallel_clients = true;
        let m = run_session(&g, &c, engine()).unwrap();
        assert_eq!(m.rounds.len(), 2);
        assert_eq!(m.rounds[0].clients.len(), 4);
    }
}
