//! Session driver: the full federated lifecycle of Fig 3 / Fig 5 as a
//! composable API. [`SessionBuilder`] wires the seams (embedding store
//! backend, [`Aggregator`], [`RoundObserver`]) and runs the offline
//! phases (partition → prune/score); the resulting [`Session`] exposes
//! the online phases explicitly — [`pretrain`](Session::pretrain), then
//! [`run_round`](Session::run_round) per federated round of {broadcast
//! global model → pull → ε local epochs → push → aggregate → global
//! validation}, with virtual-time round accounting (DESIGN.md §7, §8).
//!
//! [`run_session`] is the one-call convenience wrapper (in-process
//! store, FedAvg, no observer) that every bench and test drives.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use super::aggregation::{Aggregator, FedAvg, Validator};
use super::checkpoint::{
    checkpoint_from_env, graph_fingerprint, restore_snapshot, CheckpointBundle, CheckpointConfig,
    ClientCheckpoint, MetricsCheckpoint,
};
use super::client::Client;
use super::embedding_server::EmbeddingServer;
use super::lifecycle::{ChurnEvent, ChurnKind, Membership, RunState};
use super::metrics::{PhaseTimes, RoundMetrics, SessionMetrics};
use super::netsim::NetConfig;
use super::pipeline::{pipeline_default, AsyncStoreHandle};
use super::resilience::SnapshotStore;
use super::rounds::{
    round_policy_default, staleness_default, RoundPolicy, RoundPolicySpec, StalenessWeighted,
};
use super::store::EmbeddingStore;
use super::strategy::{ScoreKind, Strategy};
use super::trainer::{self, pretrain_push};
use crate::graph::scoring;
use crate::graph::subgraph::{build_all_per_client, Prune};
use crate::graph::{ClientSubgraph, Graph, Partition, PartitionerKind};
use crate::obs;
use crate::runtime::{ModelState, StepEngine};
use crate::util::Stopwatch;

pub use super::lifecycle::ChurnSpec;

#[derive(Clone, Debug)]
pub struct SessionConfig {
    pub dataset: String,
    pub clients: usize,
    pub strategy: Strategy,
    pub rounds: usize,
    /// Local epochs per round (paper: ε = 3).
    pub epochs: usize,
    pub lr: f32,
    /// Minibatches per local epoch.
    pub epoch_batches: usize,
    /// Global-validation batches (fixed across rounds).
    pub eval_batches: usize,
    pub seed: u64,
    pub net: NetConfig,
    /// Run client rounds on parallel threads (true = deployment-like;
    /// false = deterministic timing for ablations).
    pub parallel_clients: bool,
    /// Staleness k for the push overlap: push the state from epoch ε-k,
    /// overlapping the last k epochs (paper default k=1; §1 mentions the
    /// staleness-configuration ablation).
    pub overlap_stale: usize,
    /// Reset client Adam moments when the global model is broadcast
    /// (FedAvg resets the loss surface; stale moments from the
    /// pre-aggregation parameters are destructive).
    pub reset_opt_each_round: bool,
    /// Run the embedding plane through the asynchronous pipeline
    /// ([`AsyncStoreHandle`], DESIGN.md §9): the ε−k push RPC truly runs
    /// under the tail epochs and initial pulls are prefetched across
    /// round boundaries. Results are bit-identical either way; only wall
    /// clock changes. Default: on (`OPTIMES_PIPELINE=off` / `run
    /// --pipeline off` disables).
    pub pipeline: bool,
    /// Round-advancement policy (DESIGN.md §12): the synchronous barrier
    /// (default), a quorum with bounded slack, or a virtual-time
    /// deadline. Non-sync policies only bite when
    /// [`NetConfig::client_latency`] injects per-client report delays;
    /// with zero delays every policy degenerates to the sync barrier
    /// bit-exactly. Default from `OPTIMES_ROUND_POLICY` / `run
    /// --round-policy`.
    pub round_policy: RoundPolicySpec,
    /// Bounded-staleness window S for non-sync policies: late updates up
    /// to S rounds old fold into the next aggregation with decaying
    /// weight; older ones are dropped and counted. Default from
    /// `OPTIMES_STALENESS` / `run --staleness`.
    pub staleness: usize,
    /// How the graph is split across clients: the in-RAM `metis_lite`
    /// (default), the `hash` max-cut baseline, or the streaming `ldg`
    /// greedy pass (DESIGN.md §13.3). Default from `OPTIMES_PARTITIONER`
    /// / `run --partitioner`.
    pub partitioner: PartitionerKind,
    /// Scripted elastic-membership schedule (DESIGN.md §14): client
    /// joins/departures applied deterministically at round boundaries.
    /// Empty (the default) leaves every curve bit-identical to a session
    /// without the churn plane. Default from `OPTIMES_CHURN` / `run
    /// --churn`.
    pub churn: ChurnSpec,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            dataset: "tiny".into(),
            clients: 4,
            strategy: Strategy::e(),
            rounds: 10,
            epochs: 3,
            lr: 0.003,
            epoch_batches: 8,
            eval_batches: 8,
            seed: 42,
            net: NetConfig::default(),
            parallel_clients: true,
            overlap_stale: 1,
            reset_opt_each_round: true,
            pipeline: pipeline_default(),
            round_policy: round_policy_default(),
            staleness: staleness_default(),
            partitioner: PartitionerKind::from_env(),
            churn: ChurnSpec::from_env(),
        }
    }
}

/// Lifecycle phase markers delivered to a [`RoundObserver`] as each
/// phase *starts*.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionPhase {
    /// Graph partitioning across clients.
    Partition,
    /// Subgraph expansion, pruning, and score exchange (offline work).
    PruneScore,
    /// Pre-training push round (paper §3.2.1).
    Pretrain,
    /// Federated rounds begin.
    Rounds,
}

/// Streaming callbacks over a session's lifecycle, so the CLI and the
/// figure harness observe per-round metrics as they happen instead of
/// scraping [`SessionMetrics`] afterwards. All methods default to no-ops.
pub trait RoundObserver {
    fn on_phase(&mut self, _phase: SessionPhase) {}
    /// A federated round finished (aggregation + validation included).
    fn on_round(&mut self, _round: &RoundMetrics) {}
    /// The session completed all planned rounds.
    fn on_complete(&mut self, _metrics: &SessionMetrics) {}
}

/// Default observer: ignores everything.
pub struct NullObserver;

impl RoundObserver for NullObserver {}

/// Per-remote-index scores for a client under a [`ScoreKind`].
fn client_scores(
    kind: ScoreKind,
    sub: &crate::graph::ClientSubgraph,
    layers: usize,
    merged: &std::collections::HashMap<u32, f32>,
    seed: u64,
) -> Vec<f32> {
    match kind {
        ScoreKind::Frequency => scoring::frequency_scores(sub, layers, 768, seed),
        ScoreKind::Random => {
            let mut rng = crate::util::rng::Rng::new(seed, 0x5C02E + sub.client_id as u64);
            (0..sub.n_remote()).map(|_| rng.f32()).collect()
        }
        ScoreKind::Degree | ScoreKind::Bridge => sub
            .remote
            .iter()
            .map(|gid| merged.get(gid).copied().unwrap_or(0.0))
            .collect(),
    }
}

/// Owner-side centrality maps, exchanged in pre-training (paper §4.1.2).
fn merged_centrality(
    kind: ScoreKind,
    g: &Graph,
    part: &Partition,
    seed: u64,
) -> std::collections::HashMap<u32, f32> {
    match kind {
        ScoreKind::Degree => scoring::merge_scores(
            (0..part.k)
                .map(|c| scoring::degree_scores_local(g, part, c))
                .collect(),
        ),
        ScoreKind::Bridge => scoring::merge_scores(
            (0..part.k)
                .map(|c| scoring::bridge_scores_local(g, part, c, 48, seed))
                .collect(),
        ),
        _ => std::collections::HashMap::new(),
    }
}

/// Per-partition prune specs for the current partition. Pure function of
/// `(g, part, strat, seed)`, so the offline build and every post-churn
/// plane rebuild produce identical specs for untouched partitions.
fn compute_prunes(
    g: &Graph,
    part: &Partition,
    strat: &Strategy,
    layers: usize,
    seed: u64,
) -> Vec<Prune> {
    let base_prune = match strat.retention {
        // dynamic pruning expands un-pruned and re-samples per round
        Some(_) if strat.dynamic_prune => Prune::None,
        Some(i) => Prune::Retention(i),
        None => Prune::None,
    };
    if let Some(sp) = strat.scored_prune {
        // two-phase: expand un-scored first, score, then re-expand with
        // the per-client top-f% (offline pre-training work, §4.1.2)
        let probe = build_all_per_client(g, part, &vec![base_prune.clone(); part.k], seed);
        let merged = merged_centrality(sp.score, g, part, seed);
        probe
            .iter()
            .map(|sub| {
                let scores = client_scores(sp.score, sub, layers, &merged, seed);
                let map: std::collections::HashMap<u32, f32> = sub
                    .remote
                    .iter()
                    .zip(&scores)
                    .map(|(gid, s)| (*gid, *s))
                    .collect();
                Prune::TopFrac {
                    frac: sp.top_frac,
                    scores: map,
                }
            })
            .collect()
    } else {
        vec![base_prune; part.k]
    }
}

/// Structural equality of two client subgraphs, deciding whether a
/// surviving client's plane can be reused across a membership change.
/// `ignore_in_remote` is set under dynamic pruning, where the retained
/// in-neighbour subsets are re-sampled every round anyway (the full
/// candidate lists are a pure function of `local`/`remote`).
fn same_sub(a: &ClientSubgraph, b: &ClientSubgraph, ignore_in_remote: bool) -> bool {
    a.client_id == b.client_id
        && a.local == b.local
        && a.remote == b.remote
        && a.train_local == b.train_local
        && a.in_local == b.in_local
        && (ignore_in_remote || a.in_remote == b.in_remote)
        && a.push_nodes == b.push_nodes
        && a.pull_candidates == b.pull_candidates
}

/// Configures the pluggable seams of a federated session and runs its
/// offline phases. Defaults: fresh in-process slab store, [`FedAvg`],
/// no observer.
pub struct SessionBuilder {
    cfg: SessionConfig,
    store: Option<Arc<dyn EmbeddingStore>>,
    aggregator: Arc<dyn Aggregator>,
    observer: Box<dyn RoundObserver>,
    /// Checkpoint every N completed rounds into this directory
    /// (DESIGN.md §14). Default from `OPTIMES_CHECKPOINT` (`DIR` or
    /// `DIR:EVERY`).
    checkpoint: Option<(PathBuf, usize)>,
    /// Resume from the bundle in this directory instead of starting at
    /// round 0.
    resume_from: Option<PathBuf>,
}

impl SessionBuilder {
    pub fn new(cfg: SessionConfig) -> Self {
        Self {
            cfg,
            store: None,
            aggregator: Arc::new(FedAvg),
            observer: Box::new(NullObserver),
            checkpoint: checkpoint_from_env(),
            resume_from: None,
        }
    }

    /// Checkpoint the whole session into `dir` every `every` completed
    /// rounds (and at the final round). `every == 0` means every round.
    pub fn checkpoints(mut self, dir: impl Into<PathBuf>, every: usize) -> Self {
        self.checkpoint = Some((dir.into(), every.max(1)));
        self
    }

    /// Resume from the checkpoint bundle in `dir`. The builder's config
    /// must describe the same session (dataset, strategy, seed, policy,
    /// partitioner, client count, graph) — every mismatch is a loud
    /// build error, never a silent partial resume.
    pub fn resume(mut self, dir: impl Into<PathBuf>) -> Self {
        self.resume_from = Some(dir.into());
        self
    }

    /// Use an explicit embedding-plane backend (TCP client, sharded
    /// compound, pre-warmed in-process server, ...).
    pub fn store(mut self, store: Arc<dyn EmbeddingStore>) -> Self {
        self.store = Some(store);
        self
    }

    /// Replace the aggregation rule (default: weighted FedAvg).
    pub fn aggregator(mut self, aggregator: Arc<dyn Aggregator>) -> Self {
        self.aggregator = aggregator;
        self
    }

    /// Attach a streaming observer for phase/round callbacks.
    pub fn observer(mut self, observer: Box<dyn RoundObserver>) -> Self {
        self.observer = observer;
        self
    }

    /// Run the offline phases (partition → subgraph expansion/prune →
    /// scoring) and assemble the session infrastructure.
    pub fn build<'g>(self, g: &'g Graph, engine: Arc<dyn StepEngine>) -> Result<Session<'g>> {
        let SessionBuilder {
            cfg,
            store,
            aggregator,
            mut observer,
            checkpoint,
            resume_from,
        } = self;
        let bundle = match &resume_from {
            Some(dir) => Some(CheckpointBundle::load(dir)?),
            None => None,
        };
        let graph_fp = graph_fingerprint(g);
        // Round-policy seam (DESIGN.md §12): non-sync policies get the
        // staleness decorator so late clients fold into later
        // aggregations. Sync keeps the bare aggregator — bit-parity with
        // the pre-seam session loop is structural, not incidental.
        let policy = cfg.round_policy.build();
        let (aggregator, stale) = if cfg.round_policy.is_sync() {
            (aggregator, None)
        } else {
            let sw = Arc::new(StalenessWeighted::new(aggregator, cfg.staleness));
            (Arc::clone(&sw) as Arc<dyn Aggregator>, Some(sw))
        };
        let geom = *engine.geom();
        let strat = &cfg.strategy;

        // Resume identity gate: every divergence between the bundle and
        // this builder's world is a loud error *before* any state is
        // applied — never a silent partial resume (DESIGN.md §14).
        if let Some(b) = &bundle {
            let c = &b.config;
            ensure!(
                c.graph_fingerprint == graph_fp,
                "checkpoint graph fingerprint {:#018x} does not match this graph's {:#018x} — \
                 resume against the same dataset (and scale) the checkpoint was written from",
                c.graph_fingerprint,
                graph_fp
            );
            for (what, ckpt, ours) in [
                ("dataset", c.dataset.as_str(), cfg.dataset.as_str()),
                ("strategy", c.strategy.as_str(), strat.name.as_str()),
                ("round policy", c.policy.as_str(), &cfg.round_policy.name()),
                ("partitioner", c.partitioner.as_str(), cfg.partitioner.name()),
                ("model", c.model.as_str(), geom.model.as_str()),
                ("churn schedule", c.churn.as_str(), &cfg.churn.spec_string()),
            ] {
                ensure!(
                    ckpt == ours,
                    "checkpoint was written with {what} \"{ckpt}\" but this session uses \
                     \"{ours}\""
                );
            }
            ensure!(
                c.seed == cfg.seed,
                "checkpoint was written with seed {} but this session uses {}",
                c.seed,
                cfg.seed
            );
            ensure!(
                c.clients == cfg.clients,
                "checkpoint started from {} clients but this session is configured for {}",
                c.clients,
                cfg.clients
            );
            ensure!(
                c.staleness == cfg.staleness,
                "checkpoint was written with staleness window {} but this session uses {}",
                c.staleness,
                cfg.staleness
            );
            ensure!(
                c.fanout == geom.fanout,
                "checkpoint was written with fanout {} but this engine samples {}",
                c.fanout,
                geom.fanout
            );
            for (what, ckpt, ours) in [
                ("epochs", c.epochs, cfg.epochs),
                ("epoch batches", c.epoch_batches, cfg.epoch_batches),
                ("eval batches", c.eval_batches, cfg.eval_batches),
            ] {
                ensure!(
                    ckpt == ours,
                    "checkpoint was written with {what} {ckpt} but this session uses {ours}"
                );
            }
            ensure!(
                c.lr.to_bits() == cfg.lr.to_bits(),
                "checkpoint was written with lr {} but this session uses {}",
                c.lr,
                cfg.lr
            );
        }

        // ---- partition -----------------------------------------------------
        observer.on_phase(SessionPhase::Partition);
        let mut part = cfg.partitioner.partition(g, cfg.clients, cfg.seed);
        let mut membership = Membership::new(cfg.clients);
        if let Some(b) = &bundle {
            // replay the churn ledger verbatim onto the fresh round-0
            // partition: the resumed membership + assignment match the
            // killed session exactly, without recomputing any heuristic
            for change in b.ledger.iter().cloned() {
                membership.apply(&mut part, change);
            }
        }

        // ---- subgraph expansion + pruning + scoring ------------------------
        observer.on_phase(SessionPhase::PruneScore);
        let prunes = compute_prunes(g, &part, strat, geom.layers, cfg.seed);
        let subs = build_all_per_client(g, &part, &prunes, cfg.seed);
        let active_subs: Vec<ClientSubgraph> = subs
            .into_iter()
            .filter(|s| membership.is_active(s.client_id))
            .collect();
        let pull_candidates: usize = active_subs.iter().map(|s| s.pull_candidates).sum();
        let retained_remotes: usize = active_subs.iter().map(|s| s.n_remote()).sum();

        // ---- infrastructure ------------------------------------------------
        let store: Arc<dyn EmbeddingStore> = store.unwrap_or_else(|| {
            Arc::new(EmbeddingServer::new(geom.layers - 1, geom.hidden, cfg.net))
        });
        ensure!(
            store.n_layers() == geom.layers - 1 && store.hidden() == geom.hidden,
            "embedding store geometry {}x{} does not match engine geometry {}x{} \
             (layers-1 x hidden)",
            store.n_layers(),
            store.hidden(),
            geom.layers - 1,
            geom.hidden
        );
        if let Some(b) = &bundle {
            ensure!(
                b.config.codec == store.codec(),
                "checkpoint was written through wire codec \"{}\" but this session's store \
                 speaks \"{}\" — a mismatched codec would silently diverge",
                b.config.codec,
                store.codec()
            );
        }
        // Checkpointing rides on the snapshot decorator (DESIGN.md §10):
        // it mirrors pushes, so a bundle can dump the live embedding
        // plane; on resume the dump replays *through* the plane's own
        // codec, re-quantizing identically to the original pushes.
        let (store, snapshot): (Arc<dyn EmbeddingStore>, Option<Arc<SnapshotStore>>) =
            if checkpoint.is_some() || bundle.is_some() {
                let snap = match &bundle {
                    Some(b) => Arc::new(restore_snapshot(&b.snapshot, store)?),
                    None => Arc::new(SnapshotStore::new(store)),
                };
                (Arc::clone(&snap) as Arc<dyn EmbeddingStore>, Some(snap))
            } else {
                (store, None)
            };
        let validator = Validator::new(g, &engine, cfg.eval_batches, cfg.seed ^ 0xEA);
        let mut global = ModelState::init(&geom, cfg.seed).params;

        let mut clients: Vec<Client> = active_subs
            .into_iter()
            .map(|sub| {
                let mut c = Client::new(sub, &engine, cfg.epoch_batches, cfg.seed);
                c.state.params = global.clone();
                if let (true, Some(limit)) = (strat.dynamic_prune, strat.retention) {
                    c.enable_dynamic_prune(limit);
                }
                c
            })
            .collect();

        // OPP prefetch scores on the *final* (possibly pruned) subgraphs.
        if let Some(pf) = strat.prefetch {
            let merged = merged_centrality(pf.score, g, &part, cfg.seed);
            for c in clients.iter_mut() {
                let scores = client_scores(pf.score, &c.sub, geom.layers, &merged, cfg.seed);
                c.set_scores(scores, Some(pf.top_frac));
            }
        }

        let mut metrics = SessionMetrics {
            strategy: strat.name.clone(),
            dataset: cfg.dataset.clone(),
            n_clients: cfg.clients,
            pull_candidates,
            retained_remotes,
            store_backend: store.describe(),
            wire_codec: store.codec(),
            pipelined: cfg.pipeline,
            round_policy: cfg.round_policy.name(),
            ..Default::default()
        };

        // ---- resume: overwrite every resumable piece from the bundle -------
        let mut delay_clock = 0.0;
        let mut pretrained = false;
        if let Some(b) = bundle {
            ensure!(
                b.pending.is_empty() || stale.is_some(),
                "checkpoint holds {} pending stale updates but round policy \"{}\" has no \
                 staleness plane",
                b.pending.len(),
                cfg.round_policy.name()
            );
            ensure!(
                b.clients.len() == clients.len(),
                "checkpoint holds {} active clients but the replayed membership has {}",
                b.clients.len(),
                clients.len()
            );
            ensure!(
                global.iter().map(Vec::len).eq(b.global.iter().map(Vec::len)),
                "checkpoint global model shape does not match the engine geometry"
            );
            global = b.global;
            for ck in b.clients {
                let c = clients
                    .iter_mut()
                    .find(|c| c.id == ck.id)
                    .with_context(|| {
                        format!("checkpoint client {} is not active in the replayed membership", ck.id)
                    })?;
                ensure!(
                    c.state.params.iter().map(Vec::len).eq(ck.state.params.iter().map(Vec::len)),
                    "checkpoint client {} model shape does not match the engine geometry",
                    ck.id
                );
                ensure!(
                    c.train_order.len() == ck.train_order.len(),
                    "checkpoint client {} has {} training vertices but the rebuilt plane has {}",
                    ck.id,
                    ck.train_order.len(),
                    c.train_order.len()
                );
                c.rng = crate::util::rng::Rng::from_state(ck.rng);
                c.sampler.set_rng_state(ck.sampler_rng);
                c.train_cursor = ck.train_cursor;
                c.train_order = ck.train_order;
                c.scores = ck.scores;
                c.prefetch_rows = ck.prefetch_rows;
                c.state = ck.state;
            }
            if let Some(sw) = &stale {
                sw.import_pending(b.pending, b.dropped_total);
            }
            b.metrics.apply(&mut metrics);
            delay_clock = b.delay_clock;
            pretrained = b.pretrained;
        }

        // the async pipeline layer over the chosen backend (DESIGN.md §9);
        // workers sized so every parallel client can keep one push in
        // flight while prefetches drain (sequential rounds use at most 2)
        let pipeline = if cfg.pipeline {
            let workers = if cfg.parallel_clients {
                cfg.clients + 1
            } else {
                2
            };
            Some(Arc::new(AsyncStoreHandle::with_workers(Arc::clone(&store), workers)))
        } else {
            None
        };

        let run_state = if pretrained {
            RunState::Rounds
        } else {
            RunState::Warmup
        };
        Ok(Session {
            g,
            cfg,
            engine,
            store,
            pipeline,
            aggregator,
            policy,
            stale,
            delay_clock,
            observer,
            validator,
            part,
            membership,
            run_state,
            snapshot,
            checkpoint,
            graph_fp,
            clients,
            global,
            metrics,
            pretrained,
        })
    }
}

/// A built federated session: drive it phase by phase
/// ([`pretrain`](Session::pretrain), [`run_round`](Session::run_round))
/// or all at once ([`run`](Session::run)).
pub struct Session<'g> {
    g: &'g Graph,
    cfg: SessionConfig,
    engine: Arc<dyn StepEngine>,
    store: Arc<dyn EmbeddingStore>,
    /// Async pipeline over `store` (`cfg.pipeline`); `None` runs every
    /// store call synchronously on the round's own threads.
    pipeline: Option<Arc<AsyncStoreHandle>>,
    aggregator: Arc<dyn Aggregator>,
    /// Round-advancement policy (DESIGN.md §12); plans each round's
    /// barrier release from the injected per-client delays.
    policy: Arc<dyn RoundPolicy>,
    /// The staleness decorator wrapped around `aggregator` under non-sync
    /// policies (`None` ⇒ sync; the aggregator is the bare one).
    stale: Option<Arc<StalenessWeighted>>,
    /// Virtual clock of barrier releases: Σ of each round's release time.
    /// Purely delay-derived, so deterministic; late updates are stamped
    /// against it to decide which later round they (virtually) reach.
    delay_clock: f64,
    observer: Box<dyn RoundObserver>,
    validator: Validator,
    /// Current vertex→partition assignment; mutated incrementally by the
    /// membership ledger (DESIGN.md §14), never re-partitioned wholesale.
    part: Partition,
    /// Active-client ledger: joins/departures recorded at round
    /// boundaries, replayable for checkpoint resume.
    membership: Membership,
    /// Explicit run-state machine: warmup → rounds → cooldown.
    run_state: RunState,
    /// The snapshot decorator wrapped around `store` when checkpointing
    /// (or resuming); `None` means neither was requested.
    snapshot: Option<Arc<SnapshotStore>>,
    /// Checkpoint directory + cadence in completed rounds.
    checkpoint: Option<(PathBuf, usize)>,
    /// Structural fingerprint of `g`, stamped into every bundle.
    graph_fp: u64,
    clients: Vec<Client>,
    global: Vec<Vec<f32>>,
    metrics: SessionMetrics,
    pretrained: bool,
}

impl Session<'_> {
    /// Pre-training round (§3.2.1): every client computes and pushes its
    /// boundary embeddings so round-1 pulls never cold-start. Idempotent;
    /// [`run_round`](Session::run_round) calls it automatically.
    pub fn pretrain(&mut self) -> Result<()> {
        if self.pretrained {
            return Ok(());
        }
        self.pretrained = true;
        if self.cfg.strategy.share_embeddings {
            self.observer.on_phase(SessionPhase::Pretrain);
            let _sp = obs::span("session", "pretrain");
            let store_ref: &dyn EmbeddingStore = self.store.as_ref();
            for c in self.clients.iter_mut() {
                pretrain_push(c, self.g, &self.engine, store_ref).context("pretrain push")?;
            }
        }
        self.run_state = RunState::Rounds;
        Ok(())
    }

    /// Where the session is in its lifecycle (warmup → rounds →
    /// cooldown).
    pub fn run_state(&self) -> RunState {
        self.run_state
    }

    /// The membership ledger (active set + recorded churn history).
    pub fn membership(&self) -> &Membership {
        &self.membership
    }

    /// Current vertex→partition assignment.
    pub fn partition(&self) -> &Partition {
        &self.part
    }

    /// Stable ids of the currently active clients, ascending.
    pub fn active_clients(&self) -> Vec<usize> {
        self.clients.iter().map(|c| c.id).collect()
    }

    /// Rounds completed so far.
    pub fn completed_rounds(&self) -> usize {
        self.metrics.rounds.len()
    }

    /// Rounds the config plans in total.
    pub fn planned_rounds(&self) -> usize {
        self.cfg.rounds
    }

    /// Metrics accumulated so far.
    pub fn metrics(&self) -> &SessionMetrics {
        &self.metrics
    }

    /// One federated round: broadcast → client local rounds → aggregate →
    /// global validation. Returns the round's composed metrics.
    pub fn run_round(&mut self) -> Result<&RoundMetrics> {
        if !self.pretrained {
            self.pretrain()?;
        }
        let round = self.metrics.rounds.len();
        if round == 0 {
            self.observer.on_phase(SessionPhase::Rounds);
        }
        let mut round_span = obs::span("session", "round");
        round_span.push_attr("round", round);

        // scripted membership changes land at this round boundary, before
        // any of the round's randomness is drawn (DESIGN.md §14)
        self.apply_churn(round)?;

        // injected per-client report delays → the round policy's plan.
        // Delays are deterministic per (stable client id, round) — keyed
        // by id, not position, so surviving clients keep their delay
        // streams across churn — and the policy is a pure function of
        // them, so membership (and hence the accuracy curve) is
        // bit-reproducible (DESIGN.md §12).
        let delays: Vec<f64> = match self.cfg.net.client_latency {
            Some(l) => self.clients.iter().map(|c| l.sample(c.id, round)).collect(),
            None => vec![0.0; self.clients.len()],
        };
        let plan = self.policy.plan(&delays);

        // broadcast the global model
        {
            let _sp = obs::span("session", "broadcast");
            for c in self.clients.iter_mut() {
                c.state.params = self.global.clone();
                if self.cfg.reset_opt_each_round {
                    for m in c.state.m.iter_mut() {
                        m.iter_mut().for_each(|v| *v = 0.0);
                    }
                    for v in c.state.v.iter_mut() {
                        v.iter_mut().for_each(|x| *x = 0.0);
                    }
                    c.state.t = 0.0;
                }
            }
        }

        // run every client's local round
        let clients_span = obs::span("session", "clients");
        let pipe = self.pipeline.as_deref();
        let outcomes: Vec<trainer::RoundOutcome> = if self.cfg.parallel_clients {
            let engine_ref = &self.engine;
            let store_ref: &dyn EmbeddingStore = self.store.as_ref();
            let g = self.g;
            let strat = &self.cfg.strategy;
            let (epochs, lr, stale) = (self.cfg.epochs, self.cfg.lr, self.cfg.overlap_stale);
            let results: Vec<Result<trainer::RoundOutcome>> = std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .clients
                    .iter_mut()
                    .map(|c| {
                        s.spawn(move || {
                            trainer::run_round_pipelined(
                                c, g, strat, engine_ref, store_ref, epochs, lr, stale, pipe,
                            )
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("client thread"))
                    .collect()
            });
            results.into_iter().collect::<Result<Vec<_>>>()?
        } else {
            let store_ref: &dyn EmbeddingStore = self.store.as_ref();
            let n = self.clients.len();
            let mut outs = Vec::with_capacity(n);
            for i in 0..n {
                outs.push(trainer::run_round_pipelined(
                    &mut self.clients[i],
                    self.g,
                    &self.cfg.strategy,
                    &self.engine,
                    store_ref,
                    self.cfg.epochs,
                    self.cfg.lr,
                    self.cfg.overlap_stale,
                    pipe,
                )?);
                // client i's push ticket is joined, so the store now holds
                // exactly what client i+1's synchronous initial pull would
                // read — fly that pull ahead of its round (DESIGN.md §9)
                if let Some(handle) = pipe {
                    if i + 1 < n {
                        let next = &mut self.clients[i + 1];
                        let prefetch = trainer::issue_prefetch(next, &self.cfg.strategy, handle);
                        next.pending_pull = prefetch;
                    }
                }
            }
            outs
        };
        drop(clients_span);

        // pipeline: every push of this round is joined, so next-round
        // pulls read their final values — issue them now and let the RPCs
        // overlap aggregation, validation, and the model broadcast. In
        // sequential mode only client 0's next pull sees exactly this
        // state (later clients also see same-round pushes of earlier
        // ones, and were prefetched inside the loop above).
        if let Some(handle) = self.pipeline.as_deref() {
            if round + 1 < self.cfg.rounds {
                if self.cfg.parallel_clients {
                    for c in self.clients.iter_mut() {
                        let prefetch = trainer::issue_prefetch(c, &self.cfg.strategy, handle);
                        c.pending_pull = prefetch;
                    }
                } else if let Some(c) = self.clients.first_mut() {
                    let prefetch = trainer::issue_prefetch(c, &self.cfg.strategy, handle);
                    c.pending_pull = prefetch;
                }
            }
        }

        // aggregate + validate. Only on-time clients enter this round's
        // aggregation directly; late ones are deferred to the staleness
        // decorator, stamped with their virtual arrival on the delay
        // clock (a late update can never fold into its own round, since
        // its delay exceeds the release it missed).
        let clock_start = self.delay_clock;
        self.delay_clock += plan.release;
        let agg_sw = Stopwatch::start();
        let weighted: Vec<(&ModelState, f64)> = self
            .clients
            .iter()
            .enumerate()
            .filter(|(i, _)| plan.on_time[*i])
            .map(|(_, c)| (&c.state, c.sub.train_local.len().max(1) as f64))
            .collect();
        if let Some(stale) = &self.stale {
            stale.begin_round(round, self.delay_clock);
            for (i, c) in self.clients.iter().enumerate() {
                if !plan.on_time[i] {
                    stale.defer(
                        c.state.clone(),
                        c.sub.train_local.len().max(1) as f64,
                        round,
                        clock_start + delays[i],
                    );
                }
            }
        }
        {
            let _sp = obs::span("session", "aggregate");
            self.global = self.aggregator.aggregate(&weighted);
        }
        let val_span = obs::span("session", "validate");
        let (acc, val_loss) = self.validator.evaluate(&self.engine, &self.global)?;
        drop(val_span);
        let agg_time = agg_sw.secs();

        // compose round metrics (virtual time; DESIGN.md §7)
        let mut rm = RoundMetrics {
            round,
            accuracy: acc,
            val_loss,
            active_clients: self.clients.iter().map(|c| c.id).collect(),
            ..Default::default()
        };
        let mut worst = 0f64;
        let mut mean = PhaseTimes::default();
        for (i, o) in outcomes.iter().enumerate() {
            let t = o.metrics.phases.total();
            // the critical path is the slowest *on-time* client — a
            // straggler the policy released without does not stall the
            // round (its delay is charged to a later fold instead)
            if plan.on_time[i] && t >= worst {
                worst = t;
                rm.critical = o.metrics.phases;
            }
            mean.pull += o.metrics.phases.pull;
            mean.train += o.metrics.phases.train;
            mean.dyn_pull += o.metrics.phases.dyn_pull;
            mean.push += o.metrics.phases.push;
            mean.push_hidden += o.metrics.phases.push_hidden;
            let mut cm = o.metrics.clone();
            cm.injected_latency = delays[i];
            rm.clients.push(cm);
        }
        let n = outcomes.len().max(1) as f64;
        mean.pull /= n;
        mean.train /= n;
        mean.dyn_pull /= n;
        mean.push /= n;
        mean.push_hidden /= n;
        rm.mean_phases = mean;
        rm.round_time = worst
            + plan.release
            + agg_time
            + self
                .cfg
                .net
                .params_time(self.global.iter().map(|p| p.len()).sum());
        rm.quorum_wait = plan.quorum_wait;
        rm.stragglers_late = plan.stragglers();
        if let Some(stale) = &self.stale {
            let f = stale.last_fold();
            rm.stale_folded = f.folded;
            rm.stale_weight_applied = f.weight_applied;
            rm.stragglers_dropped = f.dropped;
        }

        // store health: occupancy at round 0 (the paper's "embeddings
        // maintained" marker), cumulative failovers + routing epoch every
        // round — a replicated plane riding out a dead shard shows up
        // here instead of corrupting the curve (DESIGN.md §10)
        let st = self.store.stats().context("store stats")?;
        if round == 0 {
            self.metrics.server_embeddings = st.nodes;
        }
        rm.failovers = st.failovers;
        // wire meters (cumulative, like the failover gauge): encoded
        // bytes per round next to the raw-f32 baseline (DESIGN.md §11)
        rm.bytes_tx = st.bytes_tx;
        rm.bytes_rx = st.bytes_rx;
        self.metrics.bytes_raw_tx = st.raw_tx;
        self.metrics.bytes_raw_rx = st.raw_rx;
        self.metrics.store_epoch = st.epoch;
        self.observer.on_round(&rm);
        self.metrics.rounds.push(rm);

        // whole-session checkpoint at the round boundary (DESIGN.md §14):
        // every push is joined and the in-flight prefetch is
        // value-transparent, so the bundle captures the complete state
        if let Some((dir, every)) = self.checkpoint.clone() {
            let done = self.metrics.rounds.len();
            if done % every == 0 || done == self.cfg.rounds {
                let mut sp = obs::span("session", "checkpoint");
                sp.push_attr("round", done - 1);
                self.write_checkpoint(&dir)
                    .with_context(|| format!("checkpoint after round {}", done - 1))?;
            }
        }
        Ok(self.metrics.rounds.last().expect("round just pushed"))
    }

    /// Apply this round boundary's scripted membership events and rebuild
    /// the affected per-client planes. A boundary without events is a
    /// strict no-op (zero-churn bit-parity is structural).
    fn apply_churn(&mut self, round: usize) -> Result<()> {
        let events: Vec<ChurnEvent> = self
            .cfg
            .churn
            .events_at(round)
            .into_iter()
            .cloned()
            .collect();
        if events.is_empty() {
            return Ok(());
        }
        for ev in &events {
            match ev.kind {
                ChurnKind::Leave { client } => {
                    obs::event(
                        "session",
                        "churn_leave",
                        vec![("round", round.to_string()), ("client", client.to_string())],
                    );
                    self.membership
                        .record_leave(self.g, &mut self.part, round, client)
                        .with_context(|| format!("churn before round {round}"))?;
                }
                ChurnKind::Join => {
                    obs::event("session", "churn_join", vec![("round", round.to_string())]);
                    self.membership
                        .record_join(self.g, &mut self.part, round)
                        .with_context(|| format!("churn before round {round}"))?;
                }
            }
        }
        self.rebuild_planes()
    }

    /// Rebuild per-client planes after a membership change. Clients whose
    /// subgraph is structurally unchanged keep everything (RNG streams,
    /// optimizer state, caches); affected ones are rebuilt from the
    /// updated partition, re-scored, and re-push their boundary
    /// embeddings so nobody pulls a hole.
    fn rebuild_planes(&mut self) -> Result<()> {
        let geom = *self.engine.geom();
        let strat = self.cfg.strategy.clone();
        let prunes = compute_prunes(self.g, &self.part, &strat, geom.layers, self.cfg.seed);
        let subs = build_all_per_client(self.g, &self.part, &prunes, self.cfg.seed);
        let merged = strat
            .prefetch
            .map(|pf| merged_centrality(pf.score, self.g, &self.part, self.cfg.seed));
        let dynamic = strat.dynamic_prune && strat.retention.is_some();
        let mut old: std::collections::HashMap<usize, Client> =
            std::mem::take(&mut self.clients)
                .into_iter()
                .map(|c| (c.id, c))
                .collect();
        let mut pull_candidates = 0;
        let mut retained_remotes = 0;
        let mut next = Vec::new();
        for sub in subs {
            let id = sub.client_id;
            if !self.membership.is_active(id) {
                continue; // departed partition: empty shell, owns nothing
            }
            pull_candidates += sub.pull_candidates;
            retained_remotes += sub.n_remote();
            let kept = old.remove(&id).filter(|c| same_sub(&c.sub, &sub, dynamic));
            let mut c = match kept {
                Some(c) => c,
                None => {
                    let mut c =
                        Client::new(sub, &self.engine, self.cfg.epoch_batches, self.cfg.seed);
                    c.state.params = self.global.clone();
                    if let (true, Some(limit)) = (strat.dynamic_prune, strat.retention) {
                        c.enable_dynamic_prune(limit);
                    }
                    if let (Some(pf), Some(m)) = (strat.prefetch, merged.as_ref()) {
                        let scores = client_scores(pf.score, &c.sub, geom.layers, m, self.cfg.seed);
                        c.set_scores(scores, Some(pf.top_frac));
                    }
                    if strat.share_embeddings {
                        // re-assigned boundary vertices must be on the
                        // server before any survivor pulls them
                        pretrain_push(&mut c, self.g, &self.engine, self.store.as_ref())
                            .context("post-churn pretrain push")?;
                    }
                    c
                }
            };
            // prefetches issued before the boundary read pre-churn store
            // state; drop them so every client re-pulls synchronously
            c.pending_pull = None;
            next.push(c);
        }
        self.clients = next;
        self.metrics.pull_candidates = pull_candidates;
        self.metrics.retained_remotes = retained_remotes;
        Ok(())
    }

    /// Serialize the complete resumable state into `dir`.
    fn write_checkpoint(&self, dir: &Path) -> Result<()> {
        let snap = self
            .snapshot
            .as_ref()
            .context("checkpointing requires the snapshot plane (set up at build)")?;
        let mut snapshot = Vec::new();
        snap.dump(&mut snapshot).context("dump embedding snapshot")?;
        let (pending, dropped_total) = match &self.stale {
            Some(sw) => sw.export_pending(),
            None => (Vec::new(), 0),
        };
        let bundle = CheckpointBundle {
            config: CheckpointConfig {
                dataset: self.cfg.dataset.clone(),
                strategy: self.cfg.strategy.name.clone(),
                policy: self.cfg.round_policy.name(),
                partitioner: self.cfg.partitioner.name().to_string(),
                codec: self.store.codec(),
                model: self.engine.geom().model.as_str().to_string(),
                fanout: self.engine.geom().fanout,
                churn: self.cfg.churn.spec_string(),
                seed: self.cfg.seed,
                clients: self.cfg.clients,
                rounds: self.cfg.rounds,
                epochs: self.cfg.epochs,
                epoch_batches: self.cfg.epoch_batches,
                eval_batches: self.cfg.eval_batches,
                lr: self.cfg.lr,
                staleness: self.cfg.staleness,
                pipeline: self.cfg.pipeline,
                graph_fingerprint: self.graph_fp,
            },
            completed_rounds: self.metrics.rounds.len(),
            delay_clock: self.delay_clock,
            pretrained: self.pretrained,
            global: self.global.clone(),
            clients: self
                .clients
                .iter()
                .map(|c| ClientCheckpoint {
                    id: c.id,
                    rng: c.rng.state(),
                    sampler_rng: c.sampler.rng_state(),
                    train_cursor: c.train_cursor,
                    train_order: c.train_order.clone(),
                    scores: c.scores.clone(),
                    prefetch_rows: c.prefetch_rows.clone(),
                    state: c.state.clone(),
                })
                .collect(),
            ledger: self.membership.ledger().to_vec(),
            pending,
            dropped_total,
            metrics: MetricsCheckpoint::from_metrics(&self.metrics),
            snapshot,
        };
        bundle.save(dir)?;
        Ok(())
    }

    /// Drive every remaining phase and return the session metrics.
    pub fn run(mut self) -> Result<SessionMetrics> {
        self.pretrain()?;
        while self.completed_rounds() < self.planned_rounds() {
            self.run_round()?;
        }
        Ok(self.finish())
    }

    /// Stop here (even mid-session) and hand back the metrics. Flushes
    /// the global tracer, so any traced run — including a test suite
    /// under `OPTIMES_TRACE` — leaves a valid timeline behind.
    pub fn finish(mut self) -> SessionMetrics {
        self.run_state = RunState::Cooldown;
        self.observer.on_complete(&self.metrics);
        obs::flush();
        self.metrics
    }
}

/// One-call convenience wrapper: in-process embedding store, FedAvg
/// aggregation, no observer — the configuration every figure, bench,
/// and test drives by default.
pub fn run_session(
    g: &Graph,
    cfg: &SessionConfig,
    engine: Arc<dyn StepEngine>,
) -> Result<SessionMetrics> {
    SessionBuilder::new(cfg.clone()).build(g, engine)?.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::aggregation::TrimmedMean;
    use crate::graph::datasets::tiny;
    use crate::runtime::manifest::{ModelGeom, ModelKind};
    use crate::runtime::RefEngine;
    use std::cell::RefCell;
    use std::rc::Rc;

    fn engine() -> Arc<dyn StepEngine> {
        Arc::new(RefEngine::new(ModelGeom {
            model: ModelKind::Gc,
            layers: 3,
            feat: 32,
            hidden: 16,
            classes: 4,
            batch: 8,
            fanout: 3,
            push_batch: 8,
        }))
    }

    fn cfg(strategy: Strategy, rounds: usize) -> SessionConfig {
        SessionConfig {
            strategy,
            rounds,
            epochs: 2,
            epoch_batches: 4,
            eval_batches: 4,
            parallel_clients: false,
            ..Default::default()
        }
    }

    #[test]
    fn session_runs_and_learns_e() {
        let g = tiny(71);
        let m = run_session(&g, &cfg(Strategy::e(), 8), engine()).unwrap();
        assert_eq!(m.rounds.len(), 8);
        // should comfortably beat 1/classes = 0.25 on the planted task
        assert!(
            m.peak_accuracy() > 0.4,
            "peak accuracy {}",
            m.peak_accuracy()
        );
        assert!(m.server_embeddings > 0);
        assert!(m.median_round_time() > 0.0);
        assert_eq!(m.store_backend, "in-process");
        // the wire meters see the exchange (raw plane: encoded == raw)
        assert_eq!(m.wire_codec, "raw");
        assert!(m.total_bytes_tx() > 0 && m.total_bytes_rx() > 0);
        assert_eq!(m.bytes_raw_tx, m.total_bytes_tx());
        assert!((m.wire_ratio() - 1.0).abs() < 1e-9);
        // cumulative, like the failover gauge
        assert!(m.rounds[0].bytes_tx <= m.rounds.last().unwrap().bytes_tx);
        // every round pulled + pushed
        for r in &m.rounds {
            assert!(r.mean_phases.pull > 0.0);
            assert!(r.mean_phases.push > 0.0);
            assert!(r.mean_phases.train > 0.0);
        }
    }

    #[test]
    fn d_has_no_comm_and_lower_accuracy_than_e() {
        let g = tiny(73);
        let e = run_session(&g, &cfg(Strategy::e(), 10), engine()).unwrap();
        let d = run_session(&g, &cfg(Strategy::d(), 10), engine()).unwrap();
        for r in &d.rounds {
            assert_eq!(r.mean_phases.pull, 0.0);
            assert_eq!(r.mean_phases.push, 0.0);
        }
        assert_eq!(d.server_embeddings, 0);
        // D's rounds must be faster (no comm)
        assert!(d.median_round_time() < e.median_round_time());
    }

    #[test]
    fn all_ladder_strategies_run() {
        let g = tiny(75);
        for s in Strategy::ladder() {
            let name = s.name.clone();
            let m = run_session(&g, &cfg(s, 3), engine())
                .unwrap_or_else(|e| panic!("strategy {name}: {e}"));
            assert_eq!(m.rounds.len(), 3, "{name}");
            assert!(m.rounds.iter().all(|r| r.accuracy.is_finite()));
        }
    }

    #[test]
    fn retention_shrinks_server_footprint() {
        let g = tiny(77);
        let e = run_session(&g, &cfg(Strategy::e(), 2), engine()).unwrap();
        let p2 = run_session(&g, &cfg(Strategy::parse("P2").unwrap(), 2), engine()).unwrap();
        let p0 = run_session(&g, &cfg(Strategy::parse("P0").unwrap(), 2), engine()).unwrap();
        assert!(p2.server_embeddings < e.server_embeddings);
        assert_eq!(p0.server_embeddings, 0);
        assert!(p2.retained_remotes < e.retained_remotes);
    }

    #[test]
    fn opg_prunes_to_top_fraction() {
        let g = tiny(79);
        let e = run_session(&g, &cfg(Strategy::e(), 2), engine()).unwrap();
        let opg = run_session(&g, &cfg(Strategy::opg(), 2), engine()).unwrap();
        assert!(
            (opg.retained_remotes as f64) < 0.5 * e.retained_remotes as f64,
            "opg {} vs e {}",
            opg.retained_remotes,
            e.retained_remotes
        );
    }

    #[test]
    fn opp_round_time_contains_dyn_pull() {
        let g = tiny(81);
        let m = run_session(&g, &cfg(Strategy::opp(), 3), engine()).unwrap();
        let any_dyn = m
            .rounds
            .iter()
            .any(|r| r.mean_phases.dyn_pull > 0.0);
        assert!(any_dyn, "OPP never pulled on demand");
    }

    #[test]
    fn parallel_and_sequential_agree_on_structure() {
        let g = tiny(83);
        let mut c = cfg(Strategy::op(), 2);
        c.parallel_clients = true;
        let m = run_session(&g, &c, engine()).unwrap();
        assert_eq!(m.rounds.len(), 2);
        assert_eq!(m.rounds[0].clients.len(), 4);
    }

    // ---- the builder seams ------------------------------------------------

    #[derive(Default)]
    struct Recorded {
        phases: Vec<SessionPhase>,
        rounds: Vec<usize>,
        completed: bool,
    }

    struct Recorder(Rc<RefCell<Recorded>>);

    impl RoundObserver for Recorder {
        fn on_phase(&mut self, phase: SessionPhase) {
            self.0.borrow_mut().phases.push(phase);
        }

        fn on_round(&mut self, round: &RoundMetrics) {
            self.0.borrow_mut().rounds.push(round.round);
        }

        fn on_complete(&mut self, _metrics: &SessionMetrics) {
            self.0.borrow_mut().completed = true;
        }
    }

    #[test]
    fn observer_streams_phases_and_rounds() {
        let g = tiny(85);
        let rec = Rc::new(RefCell::new(Recorded::default()));
        let m = SessionBuilder::new(cfg(Strategy::e(), 3))
            .observer(Box::new(Recorder(Rc::clone(&rec))))
            .build(&g, engine())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(m.rounds.len(), 3);
        let r = rec.borrow();
        assert_eq!(
            r.phases,
            vec![
                SessionPhase::Partition,
                SessionPhase::PruneScore,
                SessionPhase::Pretrain,
                SessionPhase::Rounds
            ]
        );
        assert_eq!(r.rounds, vec![0, 1, 2]);
        assert!(r.completed);
    }

    #[test]
    fn builder_matches_run_session_exactly() {
        let g = tiny(87);
        let a = run_session(&g, &cfg(Strategy::opp(), 3), engine()).unwrap();
        let b = SessionBuilder::new(cfg(Strategy::opp(), 3))
            .build(&g, engine())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(a.accuracies(), b.accuracies());
        assert_eq!(a.server_embeddings, b.server_embeddings);
    }

    #[test]
    fn phase_driving_matches_run() {
        let g = tiny(89);
        let a = run_session(&g, &cfg(Strategy::e(), 3), engine()).unwrap();
        let mut session = SessionBuilder::new(cfg(Strategy::e(), 3))
            .build(&g, engine())
            .unwrap();
        session.pretrain().unwrap();
        session.pretrain().unwrap(); // idempotent
        while session.completed_rounds() < session.planned_rounds() {
            let r = session.run_round().unwrap();
            assert!(r.accuracy.is_finite());
        }
        let b = session.finish();
        assert_eq!(a.accuracies(), b.accuracies());
    }

    #[test]
    fn trimmed_mean_session_learns() {
        let g = tiny(91);
        let m = SessionBuilder::new(cfg(Strategy::e(), 8))
            .aggregator(Arc::new(TrimmedMean { trim: 1 }))
            .build(&g, engine())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(m.rounds.len(), 8);
        assert!(
            m.peak_accuracy() > 0.4,
            "trimmed-mean session failed to learn: {}",
            m.peak_accuracy()
        );
    }

    #[test]
    fn mismatched_store_geometry_rejected() {
        let g = tiny(93);
        let wrong: Arc<dyn EmbeddingStore> =
            Arc::new(EmbeddingServer::new(2, 99, NetConfig::default()));
        let err = SessionBuilder::new(cfg(Strategy::e(), 1))
            .store(wrong)
            .build(&g, engine())
            .err()
            .expect("geometry mismatch must fail build");
        assert!(format!("{err:#}").contains("geometry"), "{err:#}");
    }
}
