//! Round/session metrics: per-phase virtual-time breakdowns (Fig 7/9/10),
//! RPC histograms (Fig 12), convergence traces (Fig 8), and the paper's
//! time-to-accuracy metric.

use crate::util::json::{Json, JsonObj};
use crate::util::stats;

/// What one RPC to the embedding server did (for Fig 12 analysis).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RpcRecord {
    pub kind: RpcKind,
    pub rows: usize,
    pub bytes: usize,
    /// Virtual service time (netsim + measured in-memory time).
    pub time: f64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RpcKind {
    Pull,
    PullOnDemand,
    Push,
}

/// Per-client, per-round phase breakdown (seconds, virtual time).
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseTimes {
    /// Initial pull (batch prefetch for OPP, everything otherwise).
    pub pull: f64,
    /// Sum of training epochs (compute), excluding on-demand pulls.
    pub train: f64,
    /// On-demand pull time spent inside training (OPP; the paper's
    /// hatched blue stack).
    pub dyn_pull: f64,
    /// Push phase: embed compute + transfer (the part NOT hidden by
    /// overlap; see `ClientRoundMetrics::round_time`).
    pub push: f64,
    /// Push work that was hidden under the final epoch (for reporting).
    pub push_hidden: f64,
}

impl PhaseTimes {
    pub fn total(&self) -> f64 {
        self.pull + self.train + self.dyn_pull + self.push
    }
}

/// Embedding-cache lookup accounting over one scope (an epoch's batch
/// assemblies, a push pass, a whole round). A *miss* is a remote row whose
/// cached embedding was absent at batch-assembly time and therefore
/// contributed a silent zero embedding — previously invisible accuracy
/// loss, now observable as a staleness/miss rate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Remote rows whose cached embedding was needed.
    pub lookups: usize,
    /// Of those, rows that were absent and substituted with zeros.
    pub misses: usize,
}

impl CacheStats {
    pub fn add(&mut self, other: CacheStats) {
        self.lookups += other.lookups;
        self.misses += other.misses;
    }

    pub fn miss_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.misses as f64 / self.lookups as f64
        }
    }
}

/// Per-backend exponentially-weighted moving average of observed pull
/// latencies, used by
/// [`ShardedStore`](super::store::ShardedStore) to order replica reads
/// fastest-first (DESIGN.md §15). Lock-free: each backend's estimate is
/// an `AtomicU64` holding `f64` bits, folded with a CAS loop so
/// concurrent pull groups never serialize on a mutex.
#[derive(Debug)]
pub struct ReplicaLatency {
    slots: Vec<std::sync::atomic::AtomicU64>,
}

impl ReplicaLatency {
    /// Smoothing factor: one sample moves the estimate 30% of the way,
    /// so a replica that suddenly slows is demoted within a few pulls
    /// while a single hiccup doesn't thrash the ordering.
    pub const ALPHA: f64 = 0.3;

    /// Sentinel bits for "no sample yet" (an impossible NaN pattern for
    /// a recorded latency, which is always finite and non-negative).
    const EMPTY: u64 = u64::MAX;

    pub fn new(n_backends: usize) -> Self {
        ReplicaLatency {
            slots: (0..n_backends)
                .map(|_| std::sync::atomic::AtomicU64::new(Self::EMPTY))
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Fold one observed latency (seconds) into a backend's estimate.
    /// Out-of-range backends and non-finite/negative samples are ignored
    /// rather than panicking — the tracker is advisory, never on the
    /// correctness path.
    pub fn record(&self, backend: usize, secs: f64) {
        if !secs.is_finite() || secs < 0.0 {
            return;
        }
        let Some(slot) = self.slots.get(backend) else {
            return;
        };
        let mut cur = slot.load(std::sync::atomic::Ordering::Relaxed);
        loop {
            let next = if cur == Self::EMPTY {
                secs
            } else {
                Self::ALPHA * secs + (1.0 - Self::ALPHA) * f64::from_bits(cur)
            };
            match slot.compare_exchange_weak(
                cur,
                next.to_bits(),
                std::sync::atomic::Ordering::Relaxed,
                std::sync::atomic::Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }

    /// Current estimate for a backend (None until its first sample).
    pub fn get(&self, backend: usize) -> Option<f64> {
        let bits = self
            .slots
            .get(backend)?
            .load(std::sync::atomic::Ordering::Relaxed);
        if bits == Self::EMPTY {
            None
        } else {
            Some(f64::from_bits(bits))
        }
    }

    /// Reorder an owner list fastest-measured-first. The sort is stable
    /// and unmeasured backends rank as `+inf`, so owners without a
    /// sample keep their original (primary-first) relative order at the
    /// back — a cold tracker reproduces the historical
    /// primary-then-failover behavior exactly.
    pub fn sorted(&self, owners: &[u32]) -> Vec<u32> {
        let mut out = owners.to_vec();
        out.sort_by(|&a, &b| {
            let ka = self.get(a as usize).unwrap_or(f64::INFINITY);
            let kb = self.get(b as usize).unwrap_or(f64::INFINITY);
            ka.partial_cmp(&kb).unwrap_or(std::cmp::Ordering::Equal)
        });
        out
    }
}

/// *Measured* wall-clock overlap accounting for the asynchronous
/// pipeline (`--pipeline on`), recorded **next to** the virtual-time
/// model of [`PhaseTimes`] (DESIGN.md §9): the virtual model says how
/// much push work the overlap *should* hide; these fields say how much
/// real wall time it actually hid.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OverlapMetrics {
    /// At least one pipelined ticket (push or prefetch) was consumed.
    pub pipelined: bool,
    /// Measured wall of the async push pipeline: embed compute plus
    /// queue wait plus store I/O, issue to completion.
    pub push_wall: f64,
    /// Measured stall actually paid joining the push ticket at round
    /// end (the part of `push_wall` that was *not* hidden).
    pub push_wait: f64,
    /// Measured wall of prefetched initial pulls (issue → completion).
    pub pull_wall: f64,
    /// Measured stall actually paid joining the prefetch ticket at the
    /// start of the pull phase.
    pub pull_wait: f64,
    /// Measured work that truly ran under training/aggregation:
    /// `max(0, push_wall − push_wait) + max(0, pull_wall − pull_wait)`.
    pub overlap_saved: f64,
    /// Encoded wire bytes the consumed push tickets carried
    /// (`PushDone::rec.bytes` — metered under the active codec, so the
    /// pipeline's traffic responds to `--wire-codec`; DESIGN.md §11).
    pub push_bytes: usize,
    /// Encoded wire bytes the consumed prefetch tickets carried.
    pub pull_bytes: usize,
    /// Peak async-queue depth observed on the session's store handle.
    pub queue_peak: usize,
    /// Newest routing epoch observed at the issue of any consumed
    /// ticket (`PushDone::epoch` / `PullDone::epoch`): after a
    /// mid-session
    /// [`ShardedStore::rebalance`](super::store::ShardedStore::rebalance),
    /// this shows the pipeline landing on the new generation. 0 for
    /// unsharded backends (and over TCP, where the epoch is reported via
    /// `stats` instead).
    pub store_epoch: u64,
}

impl OverlapMetrics {
    pub fn add(&mut self, o: &OverlapMetrics) {
        self.pipelined |= o.pipelined;
        self.push_wall += o.push_wall;
        self.push_wait += o.push_wait;
        self.pull_wall += o.pull_wall;
        self.pull_wait += o.pull_wait;
        self.overlap_saved += o.overlap_saved;
        self.push_bytes += o.push_bytes;
        self.pull_bytes += o.pull_bytes;
        self.queue_peak = self.queue_peak.max(o.queue_peak);
        self.store_epoch = self.store_epoch.max(o.store_epoch);
    }

    /// The canonical JSON shape of these fields, shared by every report
    /// path (session JSON, cache round-trip, bench sections, figures).
    pub fn to_json(&self) -> JsonObj {
        let mut o = JsonObj::new();
        o.set("pipelined", self.pipelined)
            .set("push_wall", self.push_wall)
            .set("push_wait", self.push_wait)
            .set("pull_wall", self.pull_wall)
            .set("pull_wait", self.pull_wait)
            .set("overlap_saved", self.overlap_saved)
            .set("push_bytes", self.push_bytes)
            .set("pull_bytes", self.pull_bytes)
            .set("queue_peak", self.queue_peak)
            .set("store_epoch", self.store_epoch);
        o
    }
}

/// One client's contribution to a round.
#[derive(Clone, Debug, Default)]
pub struct ClientRoundMetrics {
    pub client: usize,
    pub phases: PhaseTimes,
    pub rpcs: Vec<RpcRecord>,
    pub embeddings_pulled: usize,
    pub embeddings_pushed: usize,
    /// Remote-embedding cache lookups/misses across the round's batch
    /// assemblies (training epochs + push-embed computation).
    pub cache: CacheStats,
    /// Measured pipeline overlap (zeros when the round ran without the
    /// async pipeline).
    pub overlap: OverlapMetrics,
    pub train_loss: f32,
    /// Injected virtual report delay (seconds) this round
    /// ([`ClientLatency`](super::netsim::ClientLatency); 0 when no
    /// latency model is configured).
    pub injected_latency: f64,
}

/// One federated round, aggregated across clients.
#[derive(Clone, Debug, Default)]
pub struct RoundMetrics {
    pub round: usize,
    /// Virtual round time = max over clients + aggregation/validation.
    pub round_time: f64,
    /// Phase breakdown of the slowest (critical-path) client.
    pub critical: PhaseTimes,
    /// Mean phase breakdown across clients (plotted in Fig 7-style bars).
    pub mean_phases: PhaseTimes,
    pub clients: Vec<ClientRoundMetrics>,
    /// Stable ids of the clients active this round, ascending. Under
    /// elastic membership (DESIGN.md §14) this varies round to round;
    /// per-client fields are keyed by these ids, never by position.
    pub active_clients: Vec<usize>,
    /// Global test accuracy after aggregation.
    pub accuracy: f64,
    pub val_loss: f64,
    /// Cumulative store failover/retry events observed by round end
    /// ([`StoreStats::failovers`](super::store::StoreStats)): replica
    /// failovers and tolerated partial pushes absorbed by the embedding
    /// plane without corrupting the round.
    pub failovers: usize,
    /// Cumulative encoded embedding-payload bytes pushed through the
    /// wire by round end ([`StoreStats::bytes_tx`](super::store::StoreStats)
    /// — metered under the active codec; DESIGN.md §11).
    pub bytes_tx: usize,
    /// Cumulative encoded embedding-payload bytes pulled by round end.
    pub bytes_rx: usize,
    /// Slack the round policy actually spent waiting past the bare quorum
    /// (virtual seconds; 0 for sync/deadline policies — DESIGN.md §12).
    pub quorum_wait: f64,
    /// Clients that missed this round's barrier release and were deferred
    /// to a later aggregation.
    pub stragglers_late: usize,
    /// Deferred updates dropped at this round's aggregation for exceeding
    /// the staleness bound.
    pub stragglers_dropped: usize,
    /// Deferred updates folded into this round's aggregation.
    pub stale_folded: usize,
    /// Sum of the staleness decay factors applied to folded updates
    /// (each in `(0, 1]`).
    pub stale_weight_applied: f64,
}

/// Full session trace + derived paper metrics.
#[derive(Clone, Debug, Default)]
pub struct SessionMetrics {
    pub strategy: String,
    pub dataset: String,
    /// Embedding-plane backend the session ran against
    /// ("in-process", "tcp(host:port)", "sharded(4 shards ...)").
    pub store_backend: String,
    /// Whether the session ran with the asynchronous store pipeline
    /// (`--pipeline on`, DESIGN.md §9).
    pub pipelined: bool,
    /// Wire codec the embedding plane ran under (`raw` unless
    /// `--wire-codec` selected a compression plane; DESIGN.md §11).
    pub wire_codec: String,
    /// Last routing epoch the store reported (0 until a
    /// mid-session rebalance bumps it; DESIGN.md §10).
    pub store_epoch: u64,
    /// Round-advancement policy the session ran under ("sync",
    /// "quorum:K[:SLACK]", "deadline:SECS"; DESIGN.md §12).
    pub round_policy: String,
    /// Raw-f32 equivalent of the session's push traffic (including
    /// delta-elided rows) — the denominator-free half of the
    /// compression ratio; see [`wire_ratio`](SessionMetrics::wire_ratio).
    pub bytes_raw_tx: usize,
    /// Raw-f32 equivalent of the pull traffic.
    pub bytes_raw_rx: usize,
    pub rounds: Vec<RoundMetrics>,
    /// Embeddings resident at the server after the first full round.
    pub server_embeddings: usize,
    /// Total pull candidates & retained remotes (Fig 2a).
    pub pull_candidates: usize,
    pub retained_remotes: usize,
    pub n_clients: usize,
}

impl SessionMetrics {
    pub fn accuracies(&self) -> Vec<f64> {
        self.rounds.iter().map(|r| r.accuracy).collect()
    }

    /// 5-round moving average, as the paper plots convergence.
    pub fn smoothed_accuracies(&self) -> Vec<f64> {
        stats::moving_average(&self.accuracies(), 5)
    }

    pub fn peak_accuracy(&self) -> f64 {
        self.smoothed_accuracies()
            .into_iter()
            .fold(0.0, f64::max)
    }

    pub fn median_round_time(&self) -> f64 {
        stats::median(
            &self
                .rounds
                .iter()
                .map(|r| r.round_time)
                .collect::<Vec<_>>(),
        )
    }

    /// Median per-phase breakdown across rounds (mean-of-clients phases).
    pub fn median_phases(&self) -> PhaseTimes {
        let get = |f: fn(&PhaseTimes) -> f64| {
            stats::median(
                &self
                    .rounds
                    .iter()
                    .map(|r| f(&r.mean_phases))
                    .collect::<Vec<_>>(),
            )
        };
        PhaseTimes {
            pull: get(|p| p.pull),
            train: get(|p| p.train),
            dyn_pull: get(|p| p.dyn_pull),
            push: get(|p| p.push),
            push_hidden: get(|p| p.push_hidden),
        }
    }

    /// Cumulative virtual time until the smoothed accuracy first reaches
    /// `target`. The paper's TTA metric (None = never reached).
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        let smooth = self.smoothed_accuracies();
        let mut elapsed = 0.0;
        for (r, &acc) in self.rounds.iter().zip(&smooth) {
            elapsed += r.round_time;
            if acc >= target {
                return Some(elapsed);
            }
        }
        None
    }

    /// Total store failover/retry events the session absorbed (the
    /// per-round counter is cumulative, so this is the last round's
    /// value; 0 for a fault-free run).
    pub fn total_failovers(&self) -> usize {
        self.rounds.last().map(|r| r.failovers).unwrap_or(0)
    }

    /// Encoded embedding-payload bytes the session pushed over the wire
    /// (the per-round counter is cumulative; last round's value).
    pub fn total_bytes_tx(&self) -> usize {
        self.rounds.last().map(|r| r.bytes_tx).unwrap_or(0)
    }

    /// Encoded embedding-payload bytes the session pulled.
    pub fn total_bytes_rx(&self) -> usize {
        self.rounds.last().map(|r| r.bytes_rx).unwrap_or(0)
    }

    /// Compression ratio vs raw f32 across both directions
    /// (`raw / encoded`; 1.0 for an idle plane, > 1 when a codec or
    /// delta layer saved bytes). Always finite — a plane whose delta
    /// layer elided everything is priced against a one-byte floor, the
    /// same convention as
    /// [`StoreStats::compression_ratio`](super::store::StoreStats::compression_ratio),
    /// so the JSON report never degrades an infinite ratio into a
    /// misleading sentinel.
    pub fn wire_ratio(&self) -> f64 {
        let enc = self.total_bytes_tx() + self.total_bytes_rx();
        let raw = self.bytes_raw_tx + self.bytes_raw_rx;
        if raw == 0 && enc == 0 {
            1.0
        } else {
            raw as f64 / enc.max(1) as f64
        }
    }

    /// Total client-rounds that missed their barrier release
    /// (per-round counts summed; 0 under the sync policy).
    pub fn total_stragglers_late(&self) -> usize {
        self.rounds.iter().map(|r| r.stragglers_late).sum()
    }

    /// Total deferred updates dropped for exceeding the staleness bound.
    pub fn total_stragglers_dropped(&self) -> usize {
        self.rounds.iter().map(|r| r.stragglers_dropped).sum()
    }

    /// Total deferred updates folded into later aggregations.
    pub fn total_stale_folded(&self) -> usize {
        self.rounds.iter().map(|r| r.stale_folded).sum()
    }

    /// Total staleness decay weight applied across all folded updates.
    pub fn total_stale_weight(&self) -> f64 {
        self.rounds.iter().map(|r| r.stale_weight_applied).sum()
    }

    /// Total virtual time spent in quorum slack windows.
    pub fn total_quorum_wait(&self) -> f64 {
        self.rounds.iter().map(|r| r.quorum_wait).sum()
    }

    /// Aggregate *measured* pipeline overlap across every client round
    /// (all-zero when the session ran `--pipeline off`). Wall/wait
    /// fields are summed; `queue_peak` is the maximum observed.
    pub fn overlap_stats(&self) -> OverlapMetrics {
        let mut total = OverlapMetrics::default();
        for r in &self.rounds {
            for c in &r.clients {
                total.add(&c.overlap);
            }
        }
        total
    }

    /// Aggregate remote-embedding cache stats across every client round.
    pub fn cache_stats(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for r in &self.rounds {
            for c in &r.clients {
                total.add(c.cache);
            }
        }
        total
    }

    /// All RPC records of a kind across the session (Fig 12 violins).
    pub fn rpcs(&self, kind: RpcKind) -> Vec<RpcRecord> {
        self.rounds
            .iter()
            .flat_map(|r| r.clients.iter())
            .flat_map(|c| c.rpcs.iter())
            .filter(|r| r.kind == kind)
            .copied()
            .collect()
    }

    pub fn total_time(&self) -> f64 {
        self.rounds.iter().map(|r| r.round_time).sum()
    }

    /// JSON report blob for `reports/*.json`.
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.set("strategy", self.strategy.as_str());
        o.set("dataset", self.dataset.as_str());
        o.set("store_backend", self.store_backend.as_str());
        o.set("n_clients", self.n_clients);
        o.set("peak_accuracy", self.peak_accuracy());
        o.set("median_round_time", self.median_round_time());
        o.set("server_embeddings", self.server_embeddings);
        o.set("pull_candidates", self.pull_candidates);
        o.set("retained_remotes", self.retained_remotes);
        let cs = self.cache_stats();
        o.set("cache_lookups", cs.lookups);
        o.set("cache_misses", cs.misses);
        o.set("cache_miss_rate", cs.miss_rate());
        o.set("accuracies", self.accuracies());
        o.set(
            "round_times",
            self.rounds.iter().map(|r| r.round_time).collect::<Vec<_>>(),
        );
        let p = self.median_phases();
        let mut ph = JsonObj::new();
        ph.set("pull", p.pull)
            .set("train", p.train)
            .set("dyn_pull", p.dyn_pull)
            .set("push", p.push)
            .set("push_hidden", p.push_hidden);
        o.set("median_phases", ph);
        o.set("pipelined", self.pipelined);
        o.set("store_epoch", self.store_epoch);
        o.set("failovers", self.total_failovers());
        // the wire-compression plane (DESIGN.md §11), next to the
        // resilience health it composes with
        o.set("wire_codec", self.wire_codec.as_str());
        o.set("bytes_tx", self.total_bytes_tx());
        o.set("bytes_rx", self.total_bytes_rx());
        o.set("bytes_raw_tx", self.bytes_raw_tx);
        o.set("bytes_raw_rx", self.bytes_raw_rx);
        o.set("wire_ratio", self.wire_ratio());
        o.set("overlap", self.overlap_stats().to_json());
        // straggler-tolerant round advancement (DESIGN.md §12)
        o.set("round_policy", self.round_policy.as_str());
        o.set("stragglers_late", self.total_stragglers_late());
        o.set("stragglers_dropped", self.total_stragglers_dropped());
        o.set("stale_folded", self.total_stale_folded());
        o.set("stale_weight_applied", self.total_stale_weight());
        o.set("quorum_wait", self.total_quorum_wait());
        Json::Obj(o)
    }
}

/// The paper's target-accuracy convention: 1% under the minimum peak
/// accuracy across the strategies being compared.
pub fn paper_target_accuracy(sessions: &[&SessionMetrics]) -> f64 {
    let min_peak = sessions
        .iter()
        .map(|s| s.peak_accuracy())
        .fold(f64::INFINITY, f64::min);
    (min_peak - 0.01).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_session(times: &[f64], accs: &[f64]) -> SessionMetrics {
        SessionMetrics {
            strategy: "E".into(),
            dataset: "tiny".into(),
            rounds: times
                .iter()
                .zip(accs)
                .enumerate()
                .map(|(i, (&t, &a))| RoundMetrics {
                    round: i,
                    round_time: t,
                    accuracy: a,
                    ..Default::default()
                })
                .collect(),
            ..Default::default()
        }
    }

    #[test]
    fn tta_accumulates_round_times() {
        let s = fake_session(&[2.0, 2.0, 2.0, 2.0], &[0.1, 0.5, 0.8, 0.8]);
        // moving-average(5) rises slowly: [.1,.3,.466,.55]
        let t = s.time_to_accuracy(0.45).unwrap();
        assert!((t - 6.0).abs() < 1e-9, "{t}");
        assert!(s.time_to_accuracy(0.9).is_none());
    }

    #[test]
    fn peak_is_smoothed_max() {
        let s = fake_session(&[1.0; 6], &[0.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
        // raw max 1.0 but smoothed max is 0.5
        assert!(s.peak_accuracy() < 0.6);
    }

    #[test]
    fn paper_target_uses_min_peak() {
        let a = fake_session(&[1.0; 3], &[0.7, 0.7, 0.7]);
        let b = fake_session(&[1.0; 3], &[0.9, 0.9, 0.9]);
        let t = paper_target_accuracy(&[&a, &b]);
        assert!((t - 0.69).abs() < 1e-6, "{t}");
    }

    #[test]
    fn replica_latency_cold_tracker_preserves_owner_order() {
        let lat = ReplicaLatency::new(3);
        assert_eq!(lat.sorted(&[2, 0, 1]), vec![2, 0, 1]);
        assert_eq!(lat.get(0), None);
    }

    #[test]
    fn replica_latency_sorts_measured_fastest_first() {
        let lat = ReplicaLatency::new(3);
        lat.record(0, 0.020);
        lat.record(2, 0.001);
        // backend 1 is unmeasured: it ranks +inf, behind both samples
        assert_eq!(lat.sorted(&[0, 1, 2]), vec![2, 0, 1]);
    }

    #[test]
    fn replica_latency_ewma_converges_and_rejects_junk() {
        let lat = ReplicaLatency::new(1);
        lat.record(0, 0.010);
        assert!((lat.get(0).unwrap() - 0.010).abs() < 1e-12);
        lat.record(0, 0.030);
        // 0.3 * 0.030 + 0.7 * 0.010 = 0.016
        assert!((lat.get(0).unwrap() - 0.016).abs() < 1e-12);
        lat.record(0, f64::NAN);
        lat.record(0, -1.0);
        lat.record(7, 0.5); // out of range: ignored, no panic
        assert!((lat.get(0).unwrap() - 0.016).abs() < 1e-12);
    }

    #[test]
    fn json_report_parses() {
        let s = fake_session(&[1.0, 2.0], &[0.3, 0.4]);
        let j = s.to_json();
        let text = j.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.at("strategy").as_str(), Some("E"));
        assert_eq!(back.at("round_times").idx(1).as_f64(), Some(2.0));
    }
}
