//! Wire codec for the embedding plane: little-endian, length-delimited
//! primitives shared by the client and server sides of
//! [`net_transport`](super::net_transport).
//!
//! This is the single place where numbers meet bytes. Every conversion
//! goes through `to_le_bytes` / `from_le_bytes`, so the encoding is
//! little-endian *by construction* on every target — big-endian hosts
//! interoperate with little-endian ones, and there is no `unsafe`
//! slice transmutation anywhere on the wire path. Bulk f32/u32 payloads
//! are staged through a fixed stack buffer so the hot path stays
//! allocation-free and I/O happens in 4 KiB writes; on little-endian
//! targets the per-element `to_le_bytes` loop compiles down to plain
//! memory copies.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

/// Elements staged per chunk (4 KiB of payload for 4-byte scalars).
const CHUNK: usize = 1024;

/// Hard ceiling on wire-declared element counts: a corrupt or hostile
/// length prefix must not drive a multi-gigabyte allocation.
pub const MAX_WIRE_ELEMS: usize = 50_000_000;

pub fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes()).context("write u32")
}

pub fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes()).context("write u64")
}

pub fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("read u32")?;
    Ok(u32::from_le_bytes(b))
}

pub fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("read u64")?;
    Ok(u64::from_le_bytes(b))
}

/// Write a f32 slice as packed LE rows (bit-exact: NaN payloads and
/// signed zeros survive the trip).
pub fn write_f32s(w: &mut impl Write, data: &[f32]) -> Result<()> {
    let mut buf = [0u8; CHUNK * 4];
    for chunk in data.chunks(CHUNK) {
        let bytes = &mut buf[..chunk.len() * 4];
        for (b, v) in bytes.chunks_exact_mut(4).zip(chunk) {
            b.copy_from_slice(&v.to_le_bytes());
        }
        w.write_all(bytes).context("write f32 payload")?;
    }
    Ok(())
}

/// Read exactly `n` packed LE f32 values into `out` (cleared first,
/// capacity reused across calls).
pub fn read_f32s_into(r: &mut impl Read, n: usize, out: &mut Vec<f32>) -> Result<()> {
    if n > MAX_WIRE_ELEMS {
        bail!("absurd f32 payload length {n}");
    }
    out.clear();
    out.reserve(n);
    let mut buf = [0u8; CHUNK * 4];
    let mut left = n;
    while left > 0 {
        let take = left.min(CHUNK);
        let bytes = &mut buf[..take * 4];
        r.read_exact(bytes).context("read f32 payload")?;
        out.extend(
            bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().expect("4-byte chunk"))),
        );
        left -= take;
    }
    Ok(())
}

/// Allocating wrapper over [`read_f32s_into`].
pub fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    read_f32s_into(r, n, &mut out)?;
    Ok(out)
}

/// Hard ceiling on raw byte payload lengths declared on the wire
/// (matches [`MAX_WIRE_ELEMS`] f32s).
pub const MAX_WIRE_BYTES: usize = MAX_WIRE_ELEMS * 4;

/// Read exactly `n` raw bytes into `out` (cleared first, capacity
/// reused). Used for codec-encoded row payloads, whose length both
/// sides derive from the row count and the negotiated codec's
/// `bytes_per_row` (DESIGN.md §11).
pub fn read_bytes_into(r: &mut impl Read, n: usize, out: &mut Vec<u8>) -> Result<()> {
    if n > MAX_WIRE_BYTES {
        bail!("absurd byte payload length {n}");
    }
    out.clear();
    out.resize(n, 0);
    r.read_exact(out).context("read byte payload")?;
    Ok(())
}

/// Write a u32 slice as packed LE values (no length prefix — callers
/// frame with [`write_u32`]).
pub fn write_u32s(w: &mut impl Write, data: &[u32]) -> Result<()> {
    let mut buf = [0u8; CHUNK * 4];
    for chunk in data.chunks(CHUNK) {
        let bytes = &mut buf[..chunk.len() * 4];
        for (b, v) in bytes.chunks_exact_mut(4).zip(chunk) {
            b.copy_from_slice(&v.to_le_bytes());
        }
        w.write_all(bytes).context("write u32 payload")?;
    }
    Ok(())
}

/// Read exactly `n` packed LE u32 values.
pub fn read_u32s(r: &mut impl Read, n: usize) -> Result<Vec<u32>> {
    if n > MAX_WIRE_ELEMS {
        bail!("absurd u32 payload length {n}");
    }
    let mut out = Vec::with_capacity(n);
    let mut buf = [0u8; CHUNK * 4];
    let mut left = n;
    while left > 0 {
        let take = left.min(CHUNK);
        let bytes = &mut buf[..take * 4];
        r.read_exact(bytes).context("read u32 payload")?;
        out.extend(
            bytes
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes(b.try_into().expect("4-byte chunk"))),
        );
        left -= take;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u32_u64_roundtrip() {
        let mut buf = Vec::new();
        write_u32(&mut buf, 0).unwrap();
        write_u32(&mut buf, u32::MAX).unwrap();
        write_u64(&mut buf, 0xDEAD_BEEF_CAFE_F00D).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_u32(&mut r).unwrap(), 0);
        assert_eq!(read_u32(&mut r).unwrap(), u32::MAX);
        assert_eq!(read_u64(&mut r).unwrap(), 0xDEAD_BEEF_CAFE_F00D);
    }

    #[test]
    fn f32_payload_is_bit_exact() {
        // values straddling several chunk boundaries, plus the bit
        // patterns a numeric cast would destroy
        let mut data: Vec<f32> = (0..3 * CHUNK + 7).map(|i| i as f32 * 0.25 - 100.0).collect();
        data.push(f32::NEG_INFINITY);
        data.push(-0.0);
        data.push(f32::from_bits(0x7FC0_1234)); // NaN with payload
        let mut buf = Vec::new();
        write_f32s(&mut buf, &data).unwrap();
        assert_eq!(buf.len(), data.len() * 4);
        let back = read_f32s(&mut &buf[..], data.len()).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&data), bits(&back));
    }

    #[test]
    fn f32_read_reuses_buffer() {
        let data = vec![1.5f32; 10];
        let mut buf = Vec::new();
        write_f32s(&mut buf, &data).unwrap();
        let mut out = vec![9.9f32; 500]; // dirty, oversized
        read_f32s_into(&mut &buf[..], 10, &mut out).unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn u32_payload_roundtrip_across_chunks() {
        let data: Vec<u32> = (0..2 * CHUNK as u32 + 3)
            .map(|i| i.wrapping_mul(2654435761))
            .collect();
        let mut buf = Vec::new();
        write_u32s(&mut buf, &data).unwrap();
        let back = read_u32s(&mut &buf[..], data.len()).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn absurd_lengths_rejected() {
        let empty: &[u8] = &[];
        assert!(read_u32s(&mut &empty[..], MAX_WIRE_ELEMS + 1).is_err());
        let mut out = Vec::new();
        assert!(read_f32s_into(&mut &empty[..], MAX_WIRE_ELEMS + 1, &mut out).is_err());
        let mut bytes = Vec::new();
        assert!(read_bytes_into(&mut &empty[..], MAX_WIRE_BYTES + 1, &mut bytes).is_err());
    }

    #[test]
    fn byte_payload_roundtrip_reuses_buffer() {
        let data: Vec<u8> = (0..=255).collect();
        let mut out = vec![7u8; 9]; // dirty, wrongly sized
        read_bytes_into(&mut &data[..], 256, &mut out).unwrap();
        assert_eq!(out, data);
        // truncated stream errors
        assert!(read_bytes_into(&mut &data[..10], 11, &mut out).is_err());
    }

    #[test]
    fn truncated_payload_errors() {
        let data = vec![1.0f32; 8];
        let mut buf = Vec::new();
        write_f32s(&mut buf, &data).unwrap();
        let short = &buf[..buf.len() - 1];
        assert!(read_f32s(&mut &short[..], 8).is_err());
    }
}
