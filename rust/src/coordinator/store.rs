//! The transport-agnostic embedding plane: [`EmbeddingStore`] is the
//! narrow trait every consumer of remote embeddings (trainer, session,
//! harness, CLI) programs against, with three implementations —
//!
//! * the in-process slab [`EmbeddingServer`] (default; zero transport),
//! * [`TcpEmbeddingStore`] speaking the wire protocol of
//!   `net_transport.rs` against a standalone `optimes serve` process
//!   (the paper's deployment shape: a separate Redis-style store reached
//!   over the network by all clients, §5.1),
//! * [`ShardedStore`] hash-partitioning vertex ids across N backends of
//!   either kind (scale-out of the embedding plane itself).
//!
//! Every call is batched (one logical RPC per pull/push phase) and
//! `Send + Sync`, so parallel clients share one `Arc<dyn EmbeddingStore>`
//! exactly as they previously shared `&EmbeddingServer`.
//!
//! [`EmbeddingServer`]: super::embedding_server::EmbeddingServer
//! [`TcpEmbeddingStore`]: super::net_transport::TcpEmbeddingStore

use std::sync::Arc;

use anyhow::{ensure, Result};

use super::embedding_server::EmbeddingServer;
use super::metrics::{RpcKind, RpcRecord};
use super::netsim::NetConfig;
use crate::util::pool;

/// Aggregate store occupancy, as reported by `stats` RPCs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Unique vertices stored (any layer).
    pub nodes: usize,
    /// Total embedding rows across layers.
    pub rows: usize,
}

/// A store of per-vertex hidden embeddings `h^1..h^{L-1}`, keyed by
/// global vertex id, with one logical DB per layer (paper §5.1).
///
/// # Contract shared by all impls
///
/// * `push` upserts `per_layer[l]` as row-major `[nodes.len(), hidden]`.
/// * `pull_into` resizes `out` to one `[nodes.len(), hidden]` tensor per
///   layer (reusing capacity) and zero-fills rows of never-pushed nodes.
/// * Values round-trip bit-exactly; a session run against any backend
///   follows the same accuracy trajectory for the same seed
///   (`tests/store_parity.rs`).
/// * Returned [`RpcRecord`]s carry the backend's notion of service time
///   (modeled virtual time in-process, measured wall time over TCP).
///
/// # Thread safety
///
/// Every impl is `Send + Sync` and every method takes `&self`: parallel
/// clients — and the async pipeline's background workers
/// ([`AsyncStoreHandle`](super::pipeline::AsyncStoreHandle)) — share one
/// `Arc<dyn EmbeddingStore>` and may issue concurrent batched calls.
/// Concurrent upserts of *disjoint* node sets (the federated case: each
/// client pushes only nodes it owns) commute; concurrent upserts of the
/// same node last-write-win per shard. A pull that races a push may
/// observe either version of a row, never a torn one (rows are written
/// under a per-shard lock in-process and within one frame over TCP).
///
/// # Geometry handshake
///
/// `n_layers`/`hidden` are fixed at construction. Consumers must agree:
/// the session builder rejects a store whose geometry differs from the
/// engine's at `build` time, and [`TcpEmbeddingStore::connect`] performs
/// an empty-pull handshake so a mismatched remote daemon fails at
/// connect, not mid-round.
///
/// # Error semantics
///
/// In-process calls are infallible (geometry violations panic — they are
/// caller bugs). Transport-backed calls return `Err` for connection and
/// protocol failures after one transparent reconnect-and-retry (all ops
/// are idempotent upserts/reads, so the retry is safe); a deterministic
/// server-side rejection surfaces with both attempts in the error chain.
///
/// Sessions additionally assume the store holds *no rows for their
/// graph* when they start (the in-process default is constructed fresh
/// per session). A long-lived remote daemon reused across sessions
/// serves rows pushed by earlier ones where the contract promises
/// zeros — restart the daemon (or run one daemon per session) when
/// cross-backend reproducibility matters.
///
/// [`TcpEmbeddingStore::connect`]: super::net_transport::TcpEmbeddingStore::connect
pub trait EmbeddingStore: Send + Sync {
    /// Number of hidden-layer DBs (L-1 for an L-layer GNN).
    fn n_layers(&self) -> usize;

    /// Embedding row width.
    fn hidden(&self) -> usize;

    /// Batched upsert of all layers for `nodes`.
    fn push(&self, nodes: &[u32], per_layer: &[Vec<f32>]) -> Result<RpcRecord>;

    /// Batched pull of all layers for `nodes` into a caller buffer.
    fn pull_into(&self, nodes: &[u32], on_demand: bool, out: &mut Vec<Vec<f32>>)
        -> Result<RpcRecord>;

    /// Allocating wrapper over [`pull_into`](EmbeddingStore::pull_into).
    fn pull(&self, nodes: &[u32], on_demand: bool) -> Result<(Vec<Vec<f32>>, RpcRecord)> {
        let mut out = Vec::new();
        let rec = self.pull_into(nodes, on_demand, &mut out)?;
        Ok((out, rec))
    }

    /// Occupancy counters (the paper's "embeddings maintained" marker).
    fn stats(&self) -> Result<StoreStats>;

    /// Human-readable backend descriptor for `optimes info` / reports,
    /// e.g. `in-process`, `tcp(127.0.0.1:7070)`, `sharded(4 shards ...)`.
    fn describe(&self) -> String;
}

/// Hash-partitions vertex ids across N child stores. Pushes and pulls
/// fan out as one batched sub-RPC per shard that owns at least one of
/// the requested ids; when more than one shard participates, the
/// sub-RPCs *execute concurrently* (scoped threads, one per shard), and
/// the record accounts them accordingly (`time = max over shards`,
/// `bytes = sum`). Results are position-scattered into the caller's
/// buffers, so the merged output is independent of shard completion
/// order — sharding never changes values.
///
/// Shard hashing: the owning shard of a vertex is
/// `splitmix64(id) % n_shards` (an avalanche hash, so dense id ranges
/// spread evenly regardless of shard count). The mapping is stable for a
/// fixed shard count; resizing the shard set re-homes ids and requires a
/// fresh store.
pub struct ShardedStore {
    backends: Vec<Arc<dyn EmbeddingStore>>,
    n_layers: usize,
    hidden: usize,
}

impl ShardedStore {
    /// Build over existing backends; all must share one geometry.
    pub fn new(backends: Vec<Arc<dyn EmbeddingStore>>) -> Result<Self> {
        ensure!(!backends.is_empty(), "sharded store needs at least one backend");
        let (n_layers, hidden) = (backends[0].n_layers(), backends[0].hidden());
        for (i, b) in backends.iter().enumerate() {
            ensure!(
                b.n_layers() == n_layers && b.hidden() == hidden,
                "shard {i} geometry {}x{} != shard 0 geometry {n_layers}x{hidden}",
                b.n_layers(),
                b.hidden()
            );
        }
        Ok(Self {
            backends,
            n_layers,
            hidden,
        })
    }

    /// Convenience: N in-process slab servers (single-host scale-out).
    pub fn in_process(shards: usize, n_layers: usize, hidden: usize, net: NetConfig) -> Self {
        let backends: Vec<Arc<dyn EmbeddingStore>> = (0..shards.max(1))
            .map(|_| {
                Arc::new(EmbeddingServer::new(n_layers, hidden, net)) as Arc<dyn EmbeddingStore>
            })
            .collect();
        Self::new(backends).expect("uniform in-process shards")
    }

    pub fn n_shards(&self) -> usize {
        self.backends.len()
    }

    /// Owning shard of a vertex id (splitmix-style avalanche so dense id
    /// ranges spread evenly regardless of shard count).
    fn shard_of(&self, node: u32) -> usize {
        let mut x = node as u64 ^ 0x9E37_79B9_7F4A_7C15;
        x ^= x >> 33;
        x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
        x ^= x >> 33;
        (x % self.backends.len() as u64) as usize
    }

    /// `groups[shard]` = positions into `nodes` owned by that shard.
    fn group(&self, nodes: &[u32]) -> Vec<Vec<usize>> {
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.backends.len()];
        for (i, &node) in nodes.iter().enumerate() {
            groups[self.shard_of(node)].push(i);
        }
        groups
    }
}

impl EmbeddingStore for ShardedStore {
    fn n_layers(&self) -> usize {
        self.n_layers
    }

    fn hidden(&self) -> usize {
        self.hidden
    }

    fn push(&self, nodes: &[u32], per_layer: &[Vec<f32>]) -> Result<RpcRecord> {
        ensure!(
            per_layer.len() == self.n_layers,
            "push layer count {} != {}",
            per_layer.len(),
            self.n_layers
        );
        let h = self.hidden;
        let mut rec = RpcRecord {
            kind: RpcKind::Push,
            rows: nodes.len(),
            bytes: 0,
            time: 0.0,
        };
        // slice the batch per owning shard...
        let mut jobs: Vec<(usize, Vec<u32>, Vec<Vec<f32>>)> = Vec::new();
        for (sid, group) in self.group(nodes).iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let sub_nodes: Vec<u32> = group.iter().map(|&i| nodes[i]).collect();
            let sub_layers: Vec<Vec<f32>> = per_layer
                .iter()
                .map(|rows| {
                    let mut v = Vec::with_capacity(group.len() * h);
                    for &i in group {
                        v.extend_from_slice(&rows[i * h..(i + 1) * h]);
                    }
                    v
                })
                .collect();
            jobs.push((sid, sub_nodes, sub_layers));
        }
        // ...and fan the sub-RPCs out concurrently (one scoped worker per
        // shard); upserts of disjoint id sets commute, so concurrency
        // never changes the stored values
        let results: Vec<Result<RpcRecord>> = if jobs.len() > 1 {
            pool::parallel_map(&jobs, jobs.len(), |_, (sid, sub_nodes, sub_layers)| {
                self.backends[*sid].push(sub_nodes, sub_layers)
            })
        } else {
            jobs.iter()
                .map(|(sid, n, l)| self.backends[*sid].push(n, l))
                .collect()
        };
        for r in results {
            let r = r?;
            rec.bytes += r.bytes;
            rec.time = rec.time.max(r.time);
        }
        Ok(rec)
    }

    fn pull_into(
        &self,
        nodes: &[u32],
        on_demand: bool,
        out: &mut Vec<Vec<f32>>,
    ) -> Result<RpcRecord> {
        let h = self.hidden;
        out.truncate(self.n_layers);
        out.resize_with(self.n_layers, Vec::new);
        for rows in out.iter_mut() {
            rows.clear();
            rows.resize(nodes.len() * h, 0.0);
        }
        let mut rec = RpcRecord {
            kind: if on_demand {
                RpcKind::PullOnDemand
            } else {
                RpcKind::Pull
            },
            rows: nodes.len(),
            bytes: 0,
            time: 0.0,
        };
        let groups = self.group(nodes);
        let jobs: Vec<(usize, Vec<u32>)> = groups
            .iter()
            .enumerate()
            .filter(|(_, group)| !group.is_empty())
            .map(|(sid, group)| (sid, group.iter().map(|&i| nodes[i]).collect()))
            .collect();
        // concurrent sub-pulls into per-shard buffers; the scatter below
        // writes disjoint row positions, so completion order is invisible
        let results: Vec<Result<(usize, Vec<Vec<f32>>, RpcRecord)>> = if jobs.len() > 1 {
            pool::parallel_map(&jobs, jobs.len(), |_, (sid, sub_nodes)| {
                let mut buf = Vec::new();
                let r = self.backends[*sid].pull_into(sub_nodes, on_demand, &mut buf)?;
                Ok((*sid, buf, r))
            })
        } else {
            jobs.iter()
                .map(|(sid, sub_nodes)| {
                    let mut buf = Vec::new();
                    let r = self.backends[*sid].pull_into(sub_nodes, on_demand, &mut buf)?;
                    Ok((*sid, buf, r))
                })
                .collect()
        };
        for res in results {
            let (sid, shard_buf, r) = res?;
            let group = &groups[sid];
            for (layer, rows) in out.iter_mut().zip(&shard_buf) {
                for (j, &i) in group.iter().enumerate() {
                    layer[i * h..(i + 1) * h].copy_from_slice(&rows[j * h..(j + 1) * h]);
                }
            }
            rec.bytes += r.bytes;
            rec.time = rec.time.max(r.time);
        }
        Ok(rec)
    }

    fn stats(&self) -> Result<StoreStats> {
        let mut total = StoreStats::default();
        for b in &self.backends {
            let s = b.stats()?;
            total.nodes += s.nodes;
            total.rows += s.rows;
        }
        Ok(total)
    }

    fn describe(&self) -> String {
        format!(
            "sharded({} shards over {})",
            self.backends.len(),
            self.backends[0].describe()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(nodes: &[u32], h: usize, salt: f32) -> Vec<f32> {
        nodes
            .iter()
            .flat_map(|&n| (0..h).map(move |j| n as f32 * 10.0 + j as f32 + salt))
            .collect()
    }

    fn dyn_server(h: usize) -> Arc<dyn EmbeddingStore> {
        Arc::new(EmbeddingServer::new(2, h, NetConfig::default()))
    }

    #[test]
    fn sharded_matches_single_backend() {
        let h = 4;
        let single = dyn_server(h);
        let sharded = ShardedStore::in_process(4, 2, h, NetConfig::default());
        assert_eq!(sharded.n_shards(), 4);
        let nodes: Vec<u32> = (0..257).collect();
        let l1 = rows(&nodes, h, 0.0);
        let l2 = rows(&nodes, h, 0.5);
        single.push(&nodes, &[l1.clone(), l2.clone()]).unwrap();
        sharded.push(&nodes, &[l1, l2]).unwrap();

        // mixed order + a missing node must agree exactly
        let query = [250u32, 3, 99_999, 0, 128];
        let (a, _) = single.pull(&query, false).unwrap();
        let (b, rec) = sharded.pull(&query, false).unwrap();
        assert_eq!(a, b);
        assert_eq!(rec.rows, query.len());
        assert!(rec.time > 0.0);

        // occupancy sums across shards to the single-backend total
        let sa = single.stats().unwrap();
        let sb = sharded.stats().unwrap();
        assert_eq!(sa, sb);
        assert_eq!(sa.nodes, 257);
        assert_eq!(sa.rows, 514);
    }

    #[test]
    fn sharding_spreads_dense_id_ranges() {
        let sharded = ShardedStore::in_process(4, 2, 4, NetConfig::default());
        let nodes: Vec<u32> = (0..4000).collect();
        let groups = sharded.group(&nodes);
        for (sid, g) in groups.iter().enumerate() {
            let frac = g.len() as f64 / nodes.len() as f64;
            assert!(
                (0.15..=0.35).contains(&frac),
                "shard {sid} holds {:.2} of a dense range",
                frac
            );
        }
    }

    #[test]
    fn pull_into_reuses_dirty_buffer() {
        let h = 4;
        let sharded = ShardedStore::in_process(3, 2, h, NetConfig::default());
        let nodes = [7u32, 21];
        sharded
            .push(&nodes, &[rows(&nodes, h, 0.0), rows(&nodes, h, 1.0)])
            .unwrap();
        let mut buf = vec![vec![9.9f32; 1], vec![9.9f32; 77], vec![9.9f32; 5]];
        sharded.pull_into(&[21, 5, 7], false, &mut buf).unwrap();
        assert_eq!(buf.len(), 2);
        assert_eq!(buf[0].len(), 3 * h);
        assert_eq!(&buf[0][0..h], &rows(&[21], h, 0.0)[..]);
        assert!(buf[0][h..2 * h].iter().all(|&v| v == 0.0)); // node 5 missing
        assert_eq!(&buf[0][2 * h..3 * h], &rows(&[7], h, 0.0)[..]);
        assert_eq!(&buf[1][0..h], &rows(&[21], h, 1.0)[..]);
    }

    #[test]
    fn geometry_mismatch_rejected() {
        let a: Arc<dyn EmbeddingStore> = Arc::new(EmbeddingServer::new(2, 4, NetConfig::default()));
        let b: Arc<dyn EmbeddingStore> = Arc::new(EmbeddingServer::new(2, 8, NetConfig::default()));
        assert!(ShardedStore::new(vec![a, b]).is_err());
        assert!(ShardedStore::new(Vec::new()).is_err());
    }

    #[test]
    fn empty_batches_are_noops() {
        let sharded = ShardedStore::in_process(4, 2, 4, NetConfig::default());
        let rec = sharded.push(&[], &[Vec::new(), Vec::new()]).unwrap();
        assert_eq!((rec.rows, rec.bytes), (0, 0));
        let (got, rec) = sharded.pull(&[], true).unwrap();
        assert_eq!(rec.kind, RpcKind::PullOnDemand);
        assert_eq!(got.len(), 2);
        assert!(got.iter().all(|l| l.is_empty()));
        assert_eq!(sharded.stats().unwrap(), StoreStats::default());
    }
}
