//! The transport-agnostic embedding plane: [`EmbeddingStore`] is the
//! narrow trait every consumer of remote embeddings (trainer, session,
//! harness, CLI) programs against, with these implementations —
//!
//! * the in-process slab [`EmbeddingServer`] (default; zero transport),
//! * [`TcpEmbeddingStore`] speaking the wire protocol of
//!   `net_transport.rs` against a standalone `optimes serve` process
//!   (the paper's deployment shape: a separate Redis-style store reached
//!   over the network by all clients, §5.1),
//! * [`ShardedStore`] routing vertex ids across N backends of either
//!   kind through an explicit, replication-aware [`ShardMap`]
//!   (scale-out *and* fault tolerance of the embedding plane itself),
//! * the [`resilience`](super::resilience) decorators
//!   ([`FaultStore`](super::resilience::FaultStore) injecting
//!   deterministic failures, [`SnapshotStore`](super::resilience::SnapshotStore)
//!   adding dump/restore persistence) wrapping any of the above.
//!
//! Every call is batched (one logical RPC per pull/push phase) and
//! `Send + Sync`, so parallel clients share one `Arc<dyn EmbeddingStore>`
//! exactly as they previously shared `&EmbeddingServer`.
//!
//! [`EmbeddingServer`]: super::embedding_server::EmbeddingServer
//! [`TcpEmbeddingStore`]: super::net_transport::TcpEmbeddingStore

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{ensure, Result};

use super::embedding_server::EmbeddingServer;
use super::metrics::{ReplicaLatency, RpcKind, RpcRecord};
use super::netsim::NetConfig;
use crate::obs;
use crate::util::pool;

/// Read-routing policy of [`ShardedStore::pull_into`]: which owner a
/// replicated read tries first (DESIGN.md §15).
///
/// Selection only reorders the *already-filtered* effective owner list —
/// quarantined owners are excluded before ordering, and failover still
/// walks the rest of the list on error. Because pushes land on **every**
/// owner of a row, all healthy owners hold bit-identical bytes: the
/// policy changes which socket serves a read, never the values, so
/// accuracy curves are bit-identical under either policy
/// (`tests/store_parity.rs`, `tests/service.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReplicaSelect {
    /// Always try owners in map order (primary first, then replicas) —
    /// the historical primary-then-failover rule.
    Primary,
    /// Order owners by their EWMA observed pull latency, fastest first
    /// ([`ReplicaLatency`]). Owners without a sample keep their map
    /// order behind the measured ones, so a cold tracker degenerates to
    /// `Primary` exactly.
    #[default]
    Fastest,
}

impl ReplicaSelect {
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "primary" => Ok(ReplicaSelect::Primary),
            "fastest" => Ok(ReplicaSelect::Fastest),
            other => anyhow::bail!(
                "unknown replica-select policy {other:?} (expected primary|fastest)"
            ),
        }
    }

    /// `OPTIMES_REPLICA_SELECT` (`--replica-select`); default `fastest`.
    /// A malformed value falls back to the default rather than panicking
    /// mid-construction — the CLI validates the spelling up front.
    pub fn from_env() -> Self {
        std::env::var("OPTIMES_REPLICA_SELECT")
            .ok()
            .and_then(|v| Self::parse(&v).ok())
            .unwrap_or_default()
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReplicaSelect::Primary => "primary",
            ReplicaSelect::Fastest => "fastest",
        }
    }
}

/// Failed pull attempts fold into the tracker as their elapsed time
/// scaled by this penalty (floored at [`FAIL_FLOOR_SECS`]), so an owner
/// that errors instantly still drifts behind its healthy peers instead
/// of being retried first forever.
const FAIL_PENALTY: f64 = 4.0;
const FAIL_FLOOR_SECS: f64 = 1e-6;

/// Aggregate store health, as reported by `stats` RPCs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Unique vertices stored (any layer).
    pub nodes: usize,
    /// Total embedding rows across layers.
    pub rows: usize,
    /// Cumulative failover/retry events the store absorbed: replica
    /// failovers and tolerated partial pushes in [`ShardedStore`],
    /// reconnect-retries in `TcpEmbeddingStore`. Zero for stores with
    /// nothing to fail over to.
    pub failovers: usize,
    /// Routing epoch of the store's shard map (bumped by every
    /// [`ShardedStore::rebalance`]; 0 for unsharded backends).
    pub epoch: u64,
    /// Encoded embedding-payload bytes moved client→server (push
    /// payloads under the active wire codec; framing/id overhead is
    /// accounted in RPC records, not here). DESIGN.md §11.
    pub bytes_tx: usize,
    /// Encoded embedding-payload bytes moved server→client (pulls).
    pub bytes_rx: usize,
    /// Raw-f32 equivalent of the same push traffic — what the payloads
    /// would have cost uncompressed, *including* rows a delta layer
    /// elided — so `raw_tx / bytes_tx` is the compression ratio.
    pub raw_tx: usize,
    /// Raw-f32 equivalent of the pull traffic.
    pub raw_rx: usize,
}

impl StoreStats {
    /// Raw-equivalent bytes over encoded bytes, both directions
    /// combined: 1.0 for an idle or uncompressed plane. Always finite —
    /// when a delta layer elided *everything*, the encoded total is
    /// floored at one byte (so the ratio stays JSON-representable and
    /// monotone instead of jumping to infinity).
    pub fn compression_ratio(&self) -> f64 {
        let enc = self.bytes_tx + self.bytes_rx;
        let raw = self.raw_tx + self.raw_rx;
        if raw == 0 && enc == 0 {
            1.0
        } else {
            raw as f64 / enc.max(1) as f64
        }
    }
}

/// A store of per-vertex hidden embeddings `h^1..h^{L-1}`, keyed by
/// global vertex id, with one logical DB per layer (paper §5.1).
///
/// # Contract shared by all impls
///
/// * `push` upserts `per_layer[l]` as row-major `[nodes.len(), hidden]`.
/// * `pull_into` resizes `out` to one `[nodes.len(), hidden]` tensor per
///   layer (reusing capacity) and zero-fills rows of never-pushed nodes.
/// * Values round-trip bit-exactly; a session run against any backend
///   follows the same accuracy trajectory for the same seed
///   (`tests/store_parity.rs`).
/// * Returned [`RpcRecord`]s carry the backend's notion of service time
///   (modeled virtual time in-process, measured wall time over TCP).
///
/// # Thread safety
///
/// Every impl is `Send + Sync` and every method takes `&self`: parallel
/// clients — and the async pipeline's background workers
/// ([`AsyncStoreHandle`](super::pipeline::AsyncStoreHandle)) — share one
/// `Arc<dyn EmbeddingStore>` and may issue concurrent batched calls.
/// Concurrent upserts of *disjoint* node sets (the federated case: each
/// client pushes only nodes it owns) commute; concurrent upserts of the
/// same node last-write-win per shard. A pull that races a push may
/// observe either version of a row, never a torn one (rows are written
/// under a per-shard lock in-process and within one frame over TCP).
///
/// # Geometry handshake
///
/// `n_layers`/`hidden` are fixed at construction. Consumers must agree:
/// the session builder rejects a store whose geometry differs from the
/// engine's at `build` time, and [`TcpEmbeddingStore::connect`] performs
/// an empty-pull handshake so a mismatched remote daemon fails at
/// connect, not mid-round.
///
/// # Error semantics
///
/// In-process calls are infallible (geometry violations panic — they are
/// caller bugs). Transport-backed calls return `Err` for connection and
/// protocol failures after one transparent reconnect-and-retry (all ops
/// are idempotent upserts/reads, so the retry is safe); a deterministic
/// server-side rejection surfaces with both attempts in the error chain.
/// A replicated [`ShardedStore`] additionally absorbs up to R per-replica
/// failures per row before surfacing an error (see its docs); absorbed
/// failures are counted in [`StoreStats::failovers`].
///
/// Sessions additionally assume the store holds *no rows for their
/// graph* when they start (the in-process default is constructed fresh
/// per session). A long-lived remote daemon reused across sessions
/// serves rows pushed by earlier ones where the contract promises
/// zeros — restart the daemon (or run one daemon per session) when
/// cross-backend reproducibility matters.
///
/// [`TcpEmbeddingStore::connect`]: super::net_transport::TcpEmbeddingStore::connect
pub trait EmbeddingStore: Send + Sync {
    /// Number of hidden-layer DBs (L-1 for an L-layer GNN).
    fn n_layers(&self) -> usize;

    /// Embedding row width.
    fn hidden(&self) -> usize;

    /// Batched upsert of all layers for `nodes`.
    fn push(&self, nodes: &[u32], per_layer: &[Vec<f32>]) -> Result<RpcRecord>;

    /// Batched pull of all layers for `nodes` into a caller buffer.
    fn pull_into(&self, nodes: &[u32], on_demand: bool, out: &mut Vec<Vec<f32>>)
        -> Result<RpcRecord>;

    /// Allocating wrapper over [`pull_into`](EmbeddingStore::pull_into).
    fn pull(&self, nodes: &[u32], on_demand: bool) -> Result<(Vec<Vec<f32>>, RpcRecord)> {
        let mut out = Vec::new();
        let rec = self.pull_into(nodes, on_demand, &mut out)?;
        Ok((out, rec))
    }

    /// Occupancy counters (the paper's "embeddings maintained" marker)
    /// plus resilience health ([`StoreStats::failovers`] /
    /// [`StoreStats::epoch`]).
    fn stats(&self) -> Result<StoreStats>;

    /// Current routing epoch: which generation of the shard map calls
    /// against this store land on. Bumped by every
    /// [`ShardedStore::rebalance`]; 0 for backends without a router.
    /// Decorators forward to their inner store; the TCP client reports 0
    /// locally (the remote epoch travels in [`stats`](EmbeddingStore::stats)
    /// instead — `epoch()` must stay cheap enough for the pipeline to
    /// stamp every ticket).
    fn epoch(&self) -> u64 {
        0
    }

    /// Name of the wire codec this store's payloads travel under
    /// (`raw` unless a codec layer is active — the `CodecStore`
    /// decorator, a negotiated TCP connection, or a delta combinator;
    /// DESIGN.md §11). Routers report their backends' codec; decorators
    /// forward.
    fn codec(&self) -> String {
        "raw".into()
    }

    /// Human-readable backend descriptor for `optimes info` / reports,
    /// e.g. `in-process`, `tcp(127.0.0.1:7070)`, `sharded(4 shards ...)`.
    fn describe(&self) -> String;
}

/// Default bucket count of [`ShardMap::uniform`]: routing granularity of
/// the rebalancer (rows move bucket-at-a-time). A multiple of the common
/// shard counts so the uniform map's primary assignment matches the old
/// bare `hash % n_shards` distribution.
pub const SHARD_MAP_BUCKETS: usize = 64;

/// Avalanche hash of a vertex id (splitmix-style finalizer), so dense id
/// ranges spread evenly over buckets regardless of bucket count.
fn splitmix_hash(node: u32) -> u64 {
    let mut x = node as u64 ^ 0x9E37_79B9_7F4A_7C15;
    x ^= x >> 33;
    x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    x ^= x >> 33;
    x
}

/// Explicit, versioned routing table of the embedding plane: vertex id →
/// hash bucket → owner backends (primary first, then R replicas).
///
/// * Buckets are the unit of ownership and of migration: a
///   [`ShardedStore::rebalance`] moves rows bucket-at-a-time between
///   backends, touching exactly the buckets whose owner *set* changed.
/// * Every bucket has the same owner count (`replicas + 1`), owners are
///   distinct, and the first owner is the read-preference primary —
///   reads fail over left-to-right through the rest.
/// * `epoch` versions the map: the router bumps it on every installed
///   rebalance, and pipeline tickets record the epoch their RPC executed
///   under ([`PushDone::epoch`](super::pipeline::PushDone)).
///
/// The map itself is plain data — cheap to clone, compare, and diff
/// ([`changed_buckets`](ShardMap::changed_buckets)); the router holds the
/// installed copy behind a lock.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardMap {
    epoch: u64,
    n_backends: usize,
    replicas: usize,
    /// `owners[bucket]` = distinct backend ids, primary first.
    owners: Vec<Vec<u32>>,
}

impl ShardMap {
    /// The uniform map: [`SHARD_MAP_BUCKETS`] buckets (at least one per
    /// backend), bucket `b` owned by backends `b, b+1, .., b+R` (mod N).
    /// With `replicas = 0` the primary assignment reduces to the classic
    /// `hash % n_backends` partition for the common power-of-two shard
    /// counts.
    pub fn uniform(n_backends: usize, replicas: usize) -> Result<Self> {
        ensure!(n_backends > 0, "shard map needs at least one backend");
        ensure!(
            replicas < n_backends,
            "{replicas} replica(s) need at least {} backends, have {n_backends}",
            replicas + 1
        );
        let buckets = SHARD_MAP_BUCKETS.max(n_backends);
        let owners = (0..buckets)
            .map(|b| (0..=replicas).map(|k| ((b + k) % n_backends) as u32).collect())
            .collect();
        Ok(Self {
            epoch: 0,
            n_backends,
            replicas,
            owners,
        })
    }

    /// Build from an explicit per-bucket owner assignment (primary
    /// first). Every bucket must list the same number of distinct,
    /// in-range owners — the uniform replication factor is inferred.
    pub fn from_owners(owners: Vec<Vec<u32>>, n_backends: usize) -> Result<Self> {
        ensure!(n_backends > 0, "shard map needs at least one backend");
        ensure!(!owners.is_empty(), "shard map needs at least one bucket");
        let width = owners[0].len();
        ensure!(width > 0, "bucket 0 has no owners");
        for (b, os) in owners.iter().enumerate() {
            ensure!(
                os.len() == width,
                "bucket {b} has {} owner(s), bucket 0 has {width} \
                 (the replication factor must be uniform)",
                os.len()
            );
            for (k, &o) in os.iter().enumerate() {
                ensure!(
                    (o as usize) < n_backends,
                    "bucket {b} owner {o} out of range ({n_backends} backends)"
                );
                ensure!(!os[..k].contains(&o), "bucket {b} lists backend {o} twice");
            }
        }
        Ok(Self {
            epoch: 0,
            n_backends,
            replicas: width - 1,
            owners,
        })
    }

    /// Derive the map that removes `backend` from every owner set,
    /// substituting (deterministically) the first backend in ring order
    /// after the excluded one that is not already an owner. This is the
    /// "route around a dead shard" half of the rejoin protocol
    /// (DESIGN.md §10): rebalance to `excluding(k)`, and later rebalance
    /// back to re-admit the restarted shard.
    pub fn excluding(&self, backend: usize) -> Result<Self> {
        ensure!(
            backend < self.n_backends,
            "backend {backend} out of range ({} backends)",
            self.n_backends
        );
        ensure!(
            self.replicas + 2 <= self.n_backends,
            "cannot exclude backend {backend}: every owner set already \
             uses {} of {} backends",
            self.replicas + 1,
            self.n_backends
        );
        let owners = self
            .owners
            .iter()
            .map(|os| {
                if !os.contains(&(backend as u32)) {
                    return os.clone();
                }
                let mut out: Vec<u32> =
                    os.iter().copied().filter(|&o| o != backend as u32).collect();
                let mut cand = (backend + 1) % self.n_backends;
                while cand == backend || out.contains(&(cand as u32)) {
                    cand = (cand + 1) % self.n_backends;
                }
                out.push(cand as u32);
                out
            })
            .collect();
        Self::from_owners(owners, self.n_backends)
    }

    /// Version of this map as installed in a router (0 for maps built by
    /// hand; assigned by [`ShardedStore::rebalance`]).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn n_backends(&self) -> usize {
        self.n_backends
    }

    /// Extra copies per row beyond the primary.
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    pub fn n_buckets(&self) -> usize {
        self.owners.len()
    }

    /// Hash bucket of a vertex id (stable for a fixed bucket count).
    pub fn bucket_of(&self, node: u32) -> usize {
        (splitmix_hash(node) % self.owners.len() as u64) as usize
    }

    /// Owner backends of a vertex, primary first.
    pub fn owners_of(&self, node: u32) -> &[u32] {
        &self.owners[self.bucket_of(node)]
    }

    /// Owner backends of a bucket, primary first.
    pub fn owners_of_bucket(&self, bucket: usize) -> &[u32] {
        &self.owners[bucket]
    }

    /// Read-preference primary of a vertex.
    pub fn primary_of(&self, node: u32) -> usize {
        self.owners_of(node)[0] as usize
    }

    /// Replica backends of a vertex (owners minus the primary).
    pub fn replicas_of(&self, node: u32) -> &[u32] {
        &self.owners_of(node)[1..]
    }

    /// Buckets whose owner *set* differs between the two maps — exactly
    /// the buckets a rebalance between them must migrate. (A pure
    /// primary-order change is not a data move, only a read-preference
    /// change.) Panics if the maps have different bucket counts — they
    /// are not comparable (a caller bug, like a geometry violation).
    pub fn changed_buckets(&self, other: &ShardMap) -> Vec<usize> {
        assert_eq!(
            self.n_buckets(),
            other.n_buckets(),
            "maps with different bucket counts are not comparable"
        );
        self.owners
            .iter()
            .zip(&other.owners)
            .enumerate()
            .filter(|(_, (a, b))| !same_owner_set(a, b))
            .map(|(i, _)| i)
            .collect()
    }
}

/// Owner-set equality (order-insensitive; owner lists are short).
fn same_owner_set(a: &[u32], b: &[u32]) -> bool {
    a.len() == b.len() && a.iter().all(|o| b.contains(o))
}

/// The canonical `describe()` string of a sharded deployment. Shared
/// with the harness's `store_desc` so `optimes info` and the backend
/// recorded in session reports can never drift apart.
pub fn sharded_desc(shards: usize, inner: &str, replicas: usize) -> String {
    if replicas == 0 {
        format!("sharded({shards} shards over {inner})")
    } else {
        format!(
            "sharded({shards} shards over {inner}, {replicas} replica{})",
            if replicas == 1 { "" } else { "s" }
        )
    }
}

/// What one [`ShardedStore::rebalance`] did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RebalanceReport {
    /// Epoch of the installed map (previous epoch + 1).
    pub epoch: u64,
    /// Buckets whose owner set changed.
    pub buckets_changed: usize,
    /// Node-rows copied to newly-added owners (`Σ bucket_rows × added
    /// owners`); rows already resident on retained owners don't move.
    pub rows_copied: usize,
    /// Retained owners that had been quarantined by a missed push and
    /// were re-copied in place (counted per owner per bucket) — so
    /// rebalancing onto the *unchanged* map is a repair operation.
    pub owners_repaired: usize,
}

/// Per-bucket router state: the logical occupancy (which ids were ever
/// successfully pushed) plus the quarantine set of owners that missed a
/// push. Occupancy is the source of truth for `stats` (replicas must
/// not double-count) and the migration set of `rebalance`; quarantined
/// owners never serve reads until a rebalance repairs them.
#[derive(Default)]
struct BucketState {
    ids: HashSet<u32>,
    /// Owners that failed at least one push for this bucket: they may
    /// hold an incomplete copy, so reads skip them (DESIGN.md §10).
    stale: HashSet<u32>,
}

/// Installed routing state: the current map plus per-bucket state.
struct Routing {
    map: ShardMap,
    buckets: Vec<Mutex<BucketState>>,
}

/// Routes vertex ids across N child stores through a replication-aware
/// [`ShardMap`]. Pushes fan out to *every* owner of a row (primary + R
/// replicas) as one batched sub-RPC per backend; pulls read each row's
/// primary and fail over left-to-right through its replicas on error.
/// When more than one backend participates, the sub-RPCs *execute
/// concurrently* (scoped threads, one per sub-RPC), and the record
/// accounts them accordingly (`time = max`, `bytes = sum`). Results are
/// position-scattered into the caller's buffers, so the merged output is
/// independent of completion order — sharding never changes values.
///
/// # Fault tolerance
///
/// A push sub-RPC failure is absorbed as long as every row still landed
/// on at least one owner (so with R replicas, up to R whole-backend
/// failures per row); a pull falls back replica-by-replica. Every
/// absorbed failure increments the failover counter surfaced in
/// [`StoreStats::failovers`]. Only when *all* owners of some row fail
/// does the call return `Err`.
///
/// An owner that misses a push is **quarantined** for the touched
/// buckets: it keeps receiving subsequent pushes but never serves
/// reads again until a [`rebalance`](ShardedStore::rebalance) re-copies
/// it (so a transient fault can never surface stale or zero rows — the
/// complete replica serves instead). If *every* owner of a bucket has
/// missed a push, reads on that bucket refuse loudly rather than guess.
/// Because pushes replicate synchronously and reads only ever come from
/// owners with a complete copy, failover never changes values — a
/// session's accuracy curve under injected faults matches the
/// fault-free curve exactly (`tests/fault_tolerance.rs`).
///
/// # Rebalancing
///
/// [`rebalance`](ShardedStore::rebalance) migrates to a new map online:
/// it copies each changed bucket's rows from a live old owner to the
/// newly-added owners, then atomically installs the map with a bumped
/// epoch. The router's lock drains in-flight calls first and holds new
/// ones out, so every RPC — including queued pipeline tickets from
/// [`AsyncStoreHandle`](super::pipeline::AsyncStoreHandle) — executes
/// entirely under one map generation (DESIGN.md §10). Rows on owners
/// that *lost* a bucket are left in place but never read again (the
/// trait has no delete); a re-admitted backend is brought current by the
/// rebalance that re-adds it.
///
/// # Occupancy caveat
///
/// `stats` reports the *logical* occupancy observed by this router
/// (replicas are not double-counted). A fresh router constructed over
/// already-warm backends reports 0 until rows are pushed through it —
/// the same cross-session caveat as the trait's error-semantics note.
pub struct ShardedStore {
    backends: Vec<Arc<dyn EmbeddingStore>>,
    n_layers: usize,
    hidden: usize,
    routing: RwLock<Routing>,
    failovers: AtomicUsize,
    /// Per-backend EWMA pull latency feeding [`ReplicaSelect::Fastest`].
    latency: ReplicaLatency,
    select: ReplicaSelect,
}

impl ShardedStore {
    /// Build over existing backends with the uniform unreplicated map
    /// (the classic hash partition); all backends must share one
    /// geometry.
    pub fn new(backends: Vec<Arc<dyn EmbeddingStore>>) -> Result<Self> {
        ensure!(!backends.is_empty(), "sharded store needs at least one backend");
        let map = ShardMap::uniform(backends.len(), 0)?;
        Self::with_map(backends, map)
    }

    /// Build with R replicas per row (uniform map): every row lives on
    /// R+1 distinct backends and the store tolerates R whole-backend
    /// failures per row.
    pub fn replicated(backends: Vec<Arc<dyn EmbeddingStore>>, replicas: usize) -> Result<Self> {
        ensure!(!backends.is_empty(), "sharded store needs at least one backend");
        let map = ShardMap::uniform(backends.len(), replicas)?;
        Self::with_map(backends, map)
    }

    /// Build with an explicit routing table.
    pub fn with_map(backends: Vec<Arc<dyn EmbeddingStore>>, map: ShardMap) -> Result<Self> {
        ensure!(!backends.is_empty(), "sharded store needs at least one backend");
        ensure!(
            map.n_backends() == backends.len(),
            "shard map covers {} backend(s), store has {}",
            map.n_backends(),
            backends.len()
        );
        let (n_layers, hidden) = (backends[0].n_layers(), backends[0].hidden());
        for (i, b) in backends.iter().enumerate() {
            ensure!(
                b.n_layers() == n_layers && b.hidden() == hidden,
                "shard {i} geometry {}x{} != shard 0 geometry {n_layers}x{hidden}",
                b.n_layers(),
                b.hidden()
            );
        }
        let buckets = (0..map.n_buckets()).map(|_| Mutex::new(BucketState::default())).collect();
        let latency = ReplicaLatency::new(backends.len());
        Ok(Self {
            backends,
            n_layers,
            hidden,
            routing: RwLock::new(Routing { map, buckets }),
            failovers: AtomicUsize::new(0),
            latency,
            select: ReplicaSelect::from_env(),
        })
    }

    /// Override the read-routing policy (constructors default to
    /// [`ReplicaSelect::from_env`]).
    pub fn with_replica_select(mut self, select: ReplicaSelect) -> Self {
        self.select = select;
        self
    }

    /// The active read-routing policy.
    pub fn replica_select(&self) -> ReplicaSelect {
        self.select
    }

    /// Current EWMA pull-latency estimate of a backend (None until the
    /// first read touches it). Observability for `loadgen`/tests.
    pub fn observed_latency(&self, backend: usize) -> Option<f64> {
        self.latency.get(backend)
    }

    /// Convenience: N in-process slab servers, no replication
    /// (single-host scale-out).
    pub fn in_process(shards: usize, n_layers: usize, hidden: usize, net: NetConfig) -> Self {
        let backends: Vec<Arc<dyn EmbeddingStore>> = (0..shards.max(1))
            .map(|_| {
                Arc::new(EmbeddingServer::new(n_layers, hidden, net)) as Arc<dyn EmbeddingStore>
            })
            .collect();
        Self::new(backends).expect("uniform in-process shards")
    }

    /// Convenience: N in-process slab servers with R replicas per row.
    pub fn in_process_replicated(
        shards: usize,
        replicas: usize,
        n_layers: usize,
        hidden: usize,
        net: NetConfig,
    ) -> Result<Self> {
        let backends: Vec<Arc<dyn EmbeddingStore>> = (0..shards.max(1))
            .map(|_| {
                Arc::new(EmbeddingServer::new(n_layers, hidden, net)) as Arc<dyn EmbeddingStore>
            })
            .collect();
        Self::replicated(backends, replicas)
    }

    pub fn n_shards(&self) -> usize {
        self.backends.len()
    }

    /// Replication factor of the installed map.
    pub fn replicas(&self) -> usize {
        self.routing.read().unwrap().map.replicas()
    }

    /// Snapshot of the installed routing table.
    pub fn map(&self) -> ShardMap {
        self.routing.read().unwrap().map.clone()
    }

    /// Failover/partial-failure events absorbed so far.
    pub fn failovers(&self) -> usize {
        self.failovers.load(Ordering::Relaxed)
    }

    /// Pull `sub_nodes` trying each owner in read-preference order —
    /// map order under [`ReplicaSelect::Primary`], measured-fastest
    /// first under [`ReplicaSelect::Fastest`] — and return the first
    /// success. Every attempt's wall time feeds the per-backend EWMA
    /// (failures at a penalty), so routing adapts to slow or flapping
    /// owners within a few pulls. Absorbed failures are counted into
    /// the failover gauge.
    fn pull_one_group(
        &self,
        owners: &[u32],
        sub_nodes: &[u32],
        on_demand: bool,
    ) -> Result<(Vec<Vec<f32>>, RpcRecord)> {
        let ordered: Vec<u32> = match self.select {
            ReplicaSelect::Primary => owners.to_vec(),
            ReplicaSelect::Fastest => self.latency.sorted(owners),
        };
        let mut fails = 0usize;
        let mut last: Option<anyhow::Error> = None;
        for &b in &ordered {
            let mut buf = Vec::new();
            let t0 = std::time::Instant::now();
            match self.backends[b as usize].pull_into(sub_nodes, on_demand, &mut buf) {
                Ok(rec) => {
                    self.latency.record(b as usize, t0.elapsed().as_secs_f64());
                    if fails > 0 {
                        self.failovers.fetch_add(fails, Ordering::Relaxed);
                    }
                    return Ok((buf, rec));
                }
                Err(e) => {
                    self.latency.record(
                        b as usize,
                        t0.elapsed().as_secs_f64().max(FAIL_FLOOR_SECS) * FAIL_PENALTY,
                    );
                    fails += 1;
                    last = Some(e);
                }
            }
        }
        // every owner failed: nothing was absorbed, so the gauge (which
        // counts failures the plane *rode out*) is left untouched
        Err(last
            .expect("owner lists are never empty")
            .context(format!("pull failed on all {} owner(s)", owners.len())))
    }

    /// Migrate to `new_map` online and install it under a bumped epoch.
    ///
    /// For every bucket whose owner *set* changed, the bucket's rows
    /// (per this router's logical occupancy) are read from a live old
    /// owner holding a complete copy (never a quarantined one; with
    /// failover, so the migration itself routes around a dead shard)
    /// and pushed to each newly-added owner. Retained owners that were
    /// quarantined by a missed push are re-copied the same way — so
    /// **rebalancing onto the unchanged map is the repair operation**
    /// that lifts a bucket's quarantine. The whole operation holds the
    /// routing lock exclusively: concurrent pushes/pulls and queued
    /// pipeline tickets either complete before the migration starts or
    /// run entirely under the new map — no RPC ever straddles
    /// generations. Returns what moved.
    pub fn rebalance(&self, new_map: ShardMap) -> Result<RebalanceReport> {
        let mut sp = obs::span("store", "rebalance");
        let mut routing = self.routing.write().unwrap();
        ensure!(
            new_map.n_backends() == self.backends.len(),
            "rebalance map covers {} backend(s), store has {}",
            new_map.n_backends(),
            self.backends.len()
        );
        ensure!(
            new_map.n_buckets() == routing.map.n_buckets(),
            "rebalance map has {} buckets, installed map has {} \
             (the bucket count is fixed at construction)",
            new_map.n_buckets(),
            routing.map.n_buckets()
        );
        let mut report = RebalanceReport {
            epoch: routing.map.epoch() + 1,
            ..Default::default()
        };
        for b in 0..routing.map.n_buckets() {
            let old = routing.map.owners_of_bucket(b);
            let new = new_map.owners_of_bucket(b);
            if !same_owner_set(old, new) {
                report.buckets_changed += 1;
            }
            let (mut ids, stale) = {
                let state = routing.buckets[b].lock().unwrap();
                let ids: Vec<u32> = state.ids.iter().copied().collect();
                let stale: Vec<u32> = state.stale.iter().copied().collect();
                (ids, stale)
            };
            // copy targets: owners joining the bucket, plus retained
            // owners quarantined by a missed push (the repair path)
            let added: Vec<u32> = new.iter().copied().filter(|o| !old.contains(o)).collect();
            let repaired: Vec<u32> = new
                .iter()
                .copied()
                .filter(|o| stale.contains(o) && !added.contains(o))
                .collect();
            if !ids.is_empty() && !(added.is_empty() && repaired.is_empty()) {
                ids.sort_unstable();
                // migration sources: old owners with a complete copy
                let sources: Vec<u32> =
                    old.iter().copied().filter(|o| !stale.contains(o)).collect();
                ensure!(
                    !sources.is_empty(),
                    "rebalance: bucket {b} has no owner with a complete copy"
                );
                let (buf, _) = self.pull_one_group(&sources, &ids, false).map_err(|e| {
                    e.context(format!("rebalance: reading bucket {b} from its old owners"))
                })?;
                for &t in added.iter().chain(&repaired) {
                    self.backends[t as usize].push(&ids, &buf).map_err(|e| {
                        e.context(format!("rebalance: copying bucket {b} to backend {t}"))
                    })?;
                }
                report.rows_copied += ids.len() * added.len();
                report.owners_repaired += repaired.len();
            }
        }
        // Atomic install: only once *every* bucket migrated do the
        // quarantines lift and the map switch. A failed migration above
        // returns with the old map and all stale marks intact, so a
        // half-rebalanced router never reads a not-yet-repaired owner.
        for state in routing.buckets.iter() {
            state.lock().unwrap().stale.clear();
        }
        let mut installed = new_map;
        installed.epoch = report.epoch;
        routing.map = installed;
        sp.push_attr("epoch", report.epoch);
        sp.push_attr("buckets_changed", report.buckets_changed);
        sp.push_attr("rows_copied", report.rows_copied);
        Ok(report)
    }
}

impl EmbeddingStore for ShardedStore {
    fn n_layers(&self) -> usize {
        self.n_layers
    }

    fn hidden(&self) -> usize {
        self.hidden
    }

    fn push(&self, nodes: &[u32], per_layer: &[Vec<f32>]) -> Result<RpcRecord> {
        ensure!(
            per_layer.len() == self.n_layers,
            "push layer count {} != {}",
            per_layer.len(),
            self.n_layers
        );
        let h = self.hidden;
        let mut rec = RpcRecord {
            kind: RpcKind::Push,
            rows: nodes.len(),
            bytes: 0,
            time: 0.0,
        };
        if nodes.is_empty() {
            return Ok(rec);
        }
        let mut sp = obs::span("store", "push_fanout");
        sp.push_attr("rows", nodes.len());
        let routing = self.routing.read().unwrap();
        // slice the batch per owning backend (a row appears once per
        // owner: primary + R replicas)...
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.backends.len()];
        for (i, &node) in nodes.iter().enumerate() {
            for &b in routing.map.owners_of(node) {
                groups[b as usize].push(i);
            }
        }
        let mut jobs: Vec<(usize, Vec<u32>, Vec<Vec<f32>>)> = Vec::new();
        for (bid, group) in groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let sub_nodes: Vec<u32> = group.iter().map(|&i| nodes[i]).collect();
            let sub_layers: Vec<Vec<f32>> = per_layer
                .iter()
                .map(|rows| {
                    let mut v = Vec::with_capacity(group.len() * h);
                    for &i in group {
                        v.extend_from_slice(&rows[i * h..(i + 1) * h]);
                    }
                    v
                })
                .collect();
            jobs.push((bid, sub_nodes, sub_layers));
        }
        // ...and fan the sub-RPCs out concurrently (one scoped worker per
        // backend); upserts of disjoint id sets commute, so concurrency
        // never changes the stored values
        let results: Vec<Result<RpcRecord>> = if jobs.len() > 1 {
            pool::parallel_map(&jobs, jobs.len(), |_, (bid, sub_nodes, sub_layers)| {
                self.backends[*bid].push(sub_nodes, sub_layers)
            })
        } else {
            jobs.iter()
                .map(|(bid, n, l)| self.backends[*bid].push(n, l))
                .collect()
        };
        // tolerate up to R whole-backend failures per row: the push
        // succeeds iff every row landed on at least one owner
        let mut dead: Vec<usize> = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for ((bid, _, _), r) in jobs.iter().zip(results) {
            match r {
                Ok(sub) => {
                    rec.bytes += sub.bytes;
                    rec.time = rec.time.max(sub.time);
                }
                Err(e) => {
                    dead.push(*bid);
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if !dead.is_empty() {
            for &node in nodes {
                let owners = routing.map.owners_of(node);
                if owners.iter().all(|&b| dead.contains(&(b as usize))) {
                    return Err(first_err
                        .take()
                        .expect("a failed sub-push recorded its error")
                        .context(format!("push lost node {node}: every owner failed")));
                }
            }
            self.failovers.fetch_add(dead.len(), Ordering::Relaxed);
        }
        // logical occupancy: every row is now durable on >= 1 owner.
        // Owners that failed this push are quarantined for the touched
        // buckets — they may hold an incomplete copy, so reads skip
        // them until a rebalance re-copies them (DESIGN.md §10).
        let mut per_bucket: HashMap<usize, Vec<u32>> = HashMap::new();
        for &node in nodes {
            per_bucket.entry(routing.map.bucket_of(node)).or_default().push(node);
        }
        for (b, ids) in per_bucket {
            let mut state = routing.buckets[b].lock().unwrap();
            for &o in routing.map.owners_of_bucket(b) {
                if dead.contains(&(o as usize)) {
                    state.stale.insert(o);
                }
            }
            for id in ids {
                state.ids.insert(id);
            }
        }
        Ok(rec)
    }

    fn pull_into(
        &self,
        nodes: &[u32],
        on_demand: bool,
        out: &mut Vec<Vec<f32>>,
    ) -> Result<RpcRecord> {
        let h = self.hidden;
        out.truncate(self.n_layers);
        out.resize_with(self.n_layers, Vec::new);
        for rows in out.iter_mut() {
            rows.clear();
            rows.resize(nodes.len() * h, 0.0);
        }
        let mut rec = RpcRecord {
            kind: if on_demand {
                RpcKind::PullOnDemand
            } else {
                RpcKind::Pull
            },
            rows: nodes.len(),
            bytes: 0,
            time: 0.0,
        };
        if nodes.is_empty() {
            return Ok(rec);
        }
        let mut sp = obs::span("store", "pull_fanout");
        sp.push_attr("rows", nodes.len());
        let routing = self.routing.read().unwrap();
        // the *effective* owner list of every touched bucket: the map's
        // owners minus any quarantined ones, so a replica that missed a
        // push never serves reads. A bucket with no complete replica
        // left refuses loudly rather than serving stale or zero rows.
        let mut effective: HashMap<usize, Vec<u32>> = HashMap::new();
        for &node in nodes {
            let b = routing.map.bucket_of(node);
            if effective.contains_key(&b) {
                continue;
            }
            let state = routing.buckets[b].lock().unwrap();
            let owners: Vec<u32> = routing
                .map
                .owners_of_bucket(b)
                .iter()
                .copied()
                .filter(|o| !state.stale.contains(o))
                .collect();
            drop(state);
            ensure!(
                !owners.is_empty(),
                "bucket {b}: every replica missed a push and is quarantined \
                 (rebalance to repair before reading)"
            );
            effective.insert(b, owners);
        }
        // group positions by effective owner list: rows sharing owners
        // share one sub-RPC (for the uniform fault-free map this is the
        // classic per-primary grouping)
        let mut by_owners: HashMap<&[u32], Vec<usize>> = HashMap::new();
        for (i, &node) in nodes.iter().enumerate() {
            let owners = &effective[&routing.map.bucket_of(node)];
            by_owners.entry(owners.as_slice()).or_default().push(i);
        }
        let jobs: Vec<(Vec<u32>, Vec<usize>, Vec<u32>)> = by_owners
            .into_iter()
            .map(|(owners, group)| {
                let sub_nodes: Vec<u32> = group.iter().map(|&i| nodes[i]).collect();
                (owners.to_vec(), group, sub_nodes)
            })
            .collect();
        // concurrent sub-pulls (each failing over through its replicas)
        // into per-group buffers; the scatter below writes disjoint row
        // positions, so completion order is invisible
        let results: Vec<Result<(Vec<Vec<f32>>, RpcRecord)>> = if jobs.len() > 1 {
            pool::parallel_map(&jobs, jobs.len(), |_, (owners, _, sub_nodes)| {
                self.pull_one_group(owners, sub_nodes, on_demand)
            })
        } else {
            jobs.iter()
                .map(|(owners, _, sub_nodes)| self.pull_one_group(owners, sub_nodes, on_demand))
                .collect()
        };
        for ((_, group, _), res) in jobs.iter().zip(results) {
            let (shard_buf, sub) = res?;
            for (layer, rows) in out.iter_mut().zip(&shard_buf) {
                for (j, &i) in group.iter().enumerate() {
                    layer[i * h..(i + 1) * h].copy_from_slice(&rows[j * h..(j + 1) * h]);
                }
            }
            rec.bytes += sub.bytes;
            rec.time = rec.time.max(sub.time);
        }
        Ok(rec)
    }

    fn stats(&self) -> Result<StoreStats> {
        let routing = self.routing.read().unwrap();
        let nodes: usize = routing.buckets.iter().map(|s| s.lock().unwrap().ids.len()).sum();
        // wire meters: sum what every backend actually moved (replicas
        // genuinely cost bytes, so they are *not* deduplicated here).
        // A backend that is currently refusing its control plane (a
        // dead TCP daemon) contributes nothing rather than failing the
        // whole observability call.
        let (mut bytes_tx, mut bytes_rx, mut raw_tx, mut raw_rx) = (0, 0, 0, 0);
        for b in &self.backends {
            if let Ok(s) = b.stats() {
                bytes_tx += s.bytes_tx;
                bytes_rx += s.bytes_rx;
                raw_tx += s.raw_tx;
                raw_rx += s.raw_rx;
            }
        }
        Ok(StoreStats {
            nodes,
            rows: nodes * self.n_layers,
            failovers: self.failovers.load(Ordering::Relaxed),
            epoch: routing.map.epoch(),
            bytes_tx,
            bytes_rx,
            raw_tx,
            raw_rx,
        })
    }

    fn epoch(&self) -> u64 {
        self.routing.read().unwrap().map.epoch()
    }

    fn codec(&self) -> String {
        self.backends[0].codec()
    }

    fn describe(&self) -> String {
        sharded_desc(self.backends.len(), &self.backends[0].describe(), self.replicas())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(nodes: &[u32], h: usize, salt: f32) -> Vec<f32> {
        nodes
            .iter()
            .flat_map(|&n| (0..h).map(move |j| n as f32 * 10.0 + j as f32 + salt))
            .collect()
    }

    fn dyn_server(h: usize) -> Arc<dyn EmbeddingStore> {
        Arc::new(EmbeddingServer::new(2, h, NetConfig::default()))
    }

    fn servers(n: usize, h: usize) -> Vec<Arc<dyn EmbeddingStore>> {
        (0..n).map(|_| dyn_server(h)).collect()
    }

    #[test]
    fn sharded_matches_single_backend() {
        let h = 4;
        let single = dyn_server(h);
        let sharded = ShardedStore::in_process(4, 2, h, NetConfig::default());
        assert_eq!(sharded.n_shards(), 4);
        assert_eq!(sharded.replicas(), 0);
        let nodes: Vec<u32> = (0..257).collect();
        let l1 = rows(&nodes, h, 0.0);
        let l2 = rows(&nodes, h, 0.5);
        single.push(&nodes, &[l1.clone(), l2.clone()]).unwrap();
        sharded.push(&nodes, &[l1, l2]).unwrap();

        // mixed order + a missing node must agree exactly
        let query = [250u32, 3, 99_999, 0, 128];
        let (a, _) = single.pull(&query, false).unwrap();
        let (b, rec) = sharded.pull(&query, false).unwrap();
        assert_eq!(a, b);
        assert_eq!(rec.rows, query.len());
        assert!(rec.time > 0.0);

        // occupancy agrees with the single-backend total
        let sa = single.stats().unwrap();
        let sb = sharded.stats().unwrap();
        assert_eq!(sa, sb);
        assert_eq!(sa.nodes, 257);
        assert_eq!(sa.rows, 514);
    }

    #[test]
    fn sharding_spreads_dense_id_ranges() {
        let map = ShardMap::uniform(4, 0).unwrap();
        let mut counts = vec![0usize; 4];
        for v in 0..4000u32 {
            counts[map.primary_of(v)] += 1;
        }
        for (sid, c) in counts.iter().enumerate() {
            let frac = *c as f64 / 4000.0;
            assert!(
                (0.15..=0.35).contains(&frac),
                "shard {sid} owns {frac:.2} of a dense range"
            );
        }
    }

    #[test]
    fn pull_into_reuses_dirty_buffer() {
        let h = 4;
        let sharded = ShardedStore::in_process(3, 2, h, NetConfig::default());
        let nodes = [7u32, 21];
        sharded
            .push(&nodes, &[rows(&nodes, h, 0.0), rows(&nodes, h, 1.0)])
            .unwrap();
        let mut buf = vec![vec![9.9f32; 1], vec![9.9f32; 77], vec![9.9f32; 5]];
        sharded.pull_into(&[21, 5, 7], false, &mut buf).unwrap();
        assert_eq!(buf.len(), 2);
        assert_eq!(buf[0].len(), 3 * h);
        assert_eq!(&buf[0][0..h], &rows(&[21], h, 0.0)[..]);
        assert!(buf[0][h..2 * h].iter().all(|&v| v == 0.0)); // node 5 missing
        assert_eq!(&buf[0][2 * h..3 * h], &rows(&[7], h, 0.0)[..]);
        assert_eq!(&buf[1][0..h], &rows(&[21], h, 1.0)[..]);
    }

    #[test]
    fn constructor_error_paths() {
        // geometry mismatch between backends
        let a: Arc<dyn EmbeddingStore> = Arc::new(EmbeddingServer::new(2, 4, NetConfig::default()));
        let b: Arc<dyn EmbeddingStore> = Arc::new(EmbeddingServer::new(2, 8, NetConfig::default()));
        let err = ShardedStore::new(vec![a, b]).err().expect("geometry mismatch");
        assert!(format!("{err:#}").contains("geometry"), "{err:#}");
        // no backends at all
        let err = ShardedStore::new(Vec::new()).err().expect("empty backends");
        assert!(format!("{err:#}").contains("at least one backend"), "{err:#}");
        assert!(ShardedStore::replicated(Vec::new(), 1).is_err());
        // more replicas than spare backends
        let err = ShardedStore::replicated(servers(2, 4), 2).err().expect("replica overflow");
        assert!(format!("{err:#}").contains("replica"), "{err:#}");
        assert!(ShardedStore::in_process_replicated(2, 2, 2, 4, NetConfig::default()).is_err());
        // map sized for a different backend count
        let map = ShardMap::uniform(3, 1).unwrap();
        let err = ShardedStore::with_map(servers(2, 4), map).err().expect("map size mismatch");
        assert!(format!("{err:#}").contains("backend"), "{err:#}");
        // malformed explicit maps
        assert!(ShardMap::uniform(0, 0).is_err());
        assert!(ShardMap::from_owners(Vec::new(), 2).is_err());
        assert!(ShardMap::from_owners(vec![vec![]], 2).is_err());
        assert!(ShardMap::from_owners(vec![vec![0], vec![0, 1]], 2).is_err()); // ragged
        assert!(ShardMap::from_owners(vec![vec![2]], 2).is_err()); // out of range
        assert!(ShardMap::from_owners(vec![vec![0, 0]], 2).is_err()); // duplicate
        // rebalance with a foreign bucket count
        let store = ShardedStore::in_process(2, 2, 4, NetConfig::default());
        let foreign = ShardMap::from_owners(vec![vec![0], vec![1]], 2).unwrap();
        let err = store.rebalance(foreign).err().expect("bucket count mismatch");
        assert!(format!("{err:#}").contains("bucket"), "{err:#}");
        // excluding a backend when every backend is an owner
        let full = ShardMap::uniform(2, 1).unwrap();
        assert!(full.excluding(0).is_err());
        assert!(ShardMap::uniform(3, 1).unwrap().excluding(7).is_err());
    }

    #[test]
    fn empty_batches_are_noops() {
        let sharded = ShardedStore::in_process(4, 2, 4, NetConfig::default());
        let rec = sharded.push(&[], &[Vec::new(), Vec::new()]).unwrap();
        assert_eq!((rec.rows, rec.bytes), (0, 0));
        let (got, rec) = sharded.pull(&[], true).unwrap();
        assert_eq!(rec.kind, RpcKind::PullOnDemand);
        assert_eq!(got.len(), 2);
        assert!(got.iter().all(|l| l.is_empty()));
        assert_eq!(sharded.stats().unwrap(), StoreStats::default());
    }

    #[test]
    fn replicated_push_lands_on_every_owner() {
        let h = 4;
        let backends = servers(3, h);
        let store = ShardedStore::replicated(backends.clone(), 1).unwrap();
        let nodes: Vec<u32> = (0..100).collect();
        let l1 = rows(&nodes, h, 0.0);
        let l2 = rows(&nodes, h, 0.5);
        store.push(&nodes, &[l1.clone(), l2]).unwrap();
        // logical stats count each node once despite two physical copies
        let st = store.stats().unwrap();
        assert_eq!((st.nodes, st.rows, st.failovers, st.epoch), (100, 200, 0, 0));
        let map = store.map();
        for &node in &nodes {
            let want = rows(&[node], h, 0.0);
            for &owner in map.owners_of(node) {
                let (got, _) = backends[owner as usize].pull(&[node], false).unwrap();
                assert_eq!(got[0], want, "node {node} missing on owner {owner}");
            }
            // and on nobody else
            for b in 0..3u32 {
                if !map.owners_of(node).contains(&b) {
                    let (got, _) = backends[b as usize].pull(&[node], false).unwrap();
                    assert!(got[0].iter().all(|&v| v == 0.0));
                }
            }
        }
    }

    #[test]
    fn rebalance_moves_rows_and_bumps_epoch() {
        let h = 4;
        let backends = servers(4, h);
        let store = ShardedStore::replicated(backends.clone(), 1).unwrap();
        let nodes: Vec<u32> = (0..200).collect();
        store
            .push(&nodes, &[rows(&nodes, h, 0.0), rows(&nodes, h, 1.0)])
            .unwrap();
        let before = store.stats().unwrap();

        let old_map = store.map();
        let new_map = old_map.excluding(2).unwrap();
        let changed = old_map.changed_buckets(&new_map);
        let report = store.rebalance(new_map.clone()).unwrap();
        assert_eq!(report.epoch, 1);
        assert_eq!(store.epoch(), 1);
        assert_eq!(report.buckets_changed, changed.len());
        assert!(report.rows_copied > 0);

        // no row lost, none double-counted
        let after = store.stats().unwrap();
        assert_eq!((before.nodes, before.rows), (after.nodes, after.rows));
        assert_eq!(after.epoch, 1);
        // every row readable with its original values, and present on
        // every owner of the *new* map
        let installed = store.map();
        for &node in &nodes {
            let (got, _) = store.pull(&[node], false).unwrap();
            assert_eq!(got[0], rows(&[node], h, 0.0));
            assert!(!installed.owners_of(node).contains(&2), "node {node} still routed to 2");
            for &owner in installed.owners_of(node) {
                let (copy, _) = backends[owner as usize].pull(&[node], false).unwrap();
                assert_eq!(copy[0], rows(&[node], h, 0.0));
            }
        }
    }

    #[test]
    fn rebalance_of_empty_store_only_bumps_epoch() {
        let store = ShardedStore::in_process_replicated(4, 1, 2, 4, NetConfig::default()).unwrap();
        let new_map = store.map().excluding(0).unwrap();
        let report = store.rebalance(new_map).unwrap();
        assert_eq!(report.epoch, 1);
        assert_eq!(report.rows_copied, 0);
        assert_eq!(report.owners_repaired, 0);
        assert!(report.buckets_changed > 0);
        assert_eq!(store.stats().unwrap().nodes, 0);
    }

    #[test]
    fn transient_push_failure_quarantines_the_stale_owner() {
        use crate::coordinator::resilience::FaultStore;
        let h = 4;
        // 2 backends, R=1: every bucket is owned by both
        let slabs: Vec<Arc<EmbeddingServer>> = (0..2)
            .map(|_| Arc::new(EmbeddingServer::new(2, h, NetConfig::default())))
            .collect();
        let faulted = FaultStore::new(
            Arc::clone(&slabs[0]) as Arc<dyn EmbeddingStore>,
            "shard0",
            Vec::new(),
        );
        let handle = faulted.handle();
        let backends: Vec<Arc<dyn EmbeddingStore>> = vec![
            Arc::new(faulted),
            Arc::clone(&slabs[1]) as Arc<dyn EmbeddingStore>,
        ];
        let store = ShardedStore::replicated(backends, 1).unwrap();
        let nodes: Vec<u32> = (0..64).collect();
        store
            .push(&nodes, &[rows(&nodes, h, 0.0), rows(&nodes, h, 1.0)])
            .unwrap();

        // shard 0 misses the second push entirely: tolerated, quarantined
        handle.set_blackout(true);
        store
            .push(&nodes, &[rows(&nodes, h, 5.0), rows(&nodes, h, 6.0)])
            .unwrap();
        assert!(store.failovers() > 0);
        handle.set_blackout(false);

        // reads must come from the complete replica (shard 1), never the
        // revived-but-stale shard 0 — fresh values, bit-exact
        let (got, _) = store.pull(&nodes, false).unwrap();
        assert_eq!(got[0], rows(&nodes, h, 5.0));
        assert_eq!(got[1], rows(&nodes, h, 6.0));

        // rebalancing onto the SAME map is the repair: shard 0 gets
        // re-copied and the quarantine lifts
        let report = store.rebalance(store.map()).unwrap();
        assert_eq!(report.buckets_changed, 0);
        assert!(report.owners_repaired > 0);
        let (direct, _) = slabs[0].pull(&nodes, false);
        assert_eq!(direct[0], rows(&nodes, h, 5.0), "repair left shard 0 stale");
        let (got, _) = store.pull(&nodes, false).unwrap();
        assert_eq!(got[0], rows(&nodes, h, 5.0));
        assert_eq!(store.stats().unwrap().nodes, 64);
    }

    #[test]
    fn bucket_with_no_complete_replica_refuses_reads_loudly() {
        use crate::coordinator::resilience::FaultStore;
        let h = 4;
        let mk = || -> Arc<dyn EmbeddingStore> {
            Arc::new(EmbeddingServer::new(2, h, NetConfig::default()))
        };
        let f0 = FaultStore::new(mk(), "shard0", Vec::new());
        let f1 = FaultStore::new(mk(), "shard1", Vec::new());
        let (h0, h1) = (f0.handle(), f1.handle());
        let store = ShardedStore::replicated(vec![Arc::new(f0), Arc::new(f1)], 1).unwrap();
        let nodes: Vec<u32> = (0..32).collect();
        store
            .push(&nodes, &[rows(&nodes, h, 0.0), rows(&nodes, h, 1.0)])
            .unwrap();
        // two disjoint transient failures exceed the R=1 fault budget
        h0.set_blackout(true);
        store
            .push(&nodes, &[rows(&nodes, h, 2.0), rows(&nodes, h, 3.0)])
            .unwrap();
        h0.set_blackout(false);
        h1.set_blackout(true);
        store
            .push(&nodes, &[rows(&nodes, h, 4.0), rows(&nodes, h, 5.0)])
            .unwrap();
        h1.set_blackout(false);
        // no owner is guaranteed complete: reads refuse instead of
        // silently serving possibly-stale rows
        let err = store
            .pull(&nodes, false)
            .err()
            .expect("quarantined bucket must not serve");
        assert!(format!("{err:#}").contains("quarantine"), "{err:#}");
        // and the same-map rebalance has no complete source either
        assert!(store.rebalance(store.map()).is_err());
    }

    #[test]
    fn shard_map_uniform_owner_sets_are_valid() {
        for n in 1..6usize {
            for r in 0..n {
                let map = ShardMap::uniform(n, r).unwrap();
                assert_eq!(map.replicas(), r);
                assert!(map.n_buckets() >= n);
                for v in 0..500u32 {
                    let owners = map.owners_of(v);
                    assert_eq!(owners.len(), r + 1);
                    assert_eq!(owners[0] as usize, map.primary_of(v));
                    assert!(!map.replicas_of(v).contains(&owners[0]));
                    let mut sorted: Vec<u32> = owners.to_vec();
                    sorted.sort_unstable();
                    sorted.dedup();
                    assert_eq!(sorted.len(), r + 1, "duplicate owners for {v}");
                }
            }
        }
    }

    #[test]
    fn shard_map_excluding_reroutes_deterministically() {
        let map = ShardMap::uniform(4, 1).unwrap();
        let ex = map.excluding(1).unwrap();
        assert_eq!(ex.replicas(), 1);
        for b in 0..map.n_buckets() {
            assert!(!ex.owners_of_bucket(b).contains(&1));
            // buckets that never listed backend 1 are untouched
            if !map.owners_of_bucket(b).contains(&1) {
                assert_eq!(map.owners_of_bucket(b), ex.owners_of_bucket(b));
            }
        }
        // deterministic: same derivation twice
        assert_eq!(ex, map.excluding(1).unwrap());
        // changed buckets are exactly those that listed backend 1
        let changed = map.changed_buckets(&ex);
        let expect: Vec<usize> = (0..map.n_buckets())
            .filter(|&b| map.owners_of_bucket(b).contains(&1))
            .collect();
        assert_eq!(changed, expect);
    }

    #[test]
    fn replica_select_parse_and_env_default() {
        assert_eq!(ReplicaSelect::parse("primary").unwrap(), ReplicaSelect::Primary);
        assert_eq!(ReplicaSelect::parse(" Fastest ").unwrap(), ReplicaSelect::Fastest);
        assert!(ReplicaSelect::parse("turbo").is_err());
        assert_eq!(ReplicaSelect::default(), ReplicaSelect::Fastest);
        assert_eq!(ReplicaSelect::Fastest.name(), "fastest");
    }

    #[test]
    fn latency_aware_selection_routes_reads_off_the_slow_replica() {
        use crate::coordinator::resilience::{Fault, FaultStore};
        let h = 4;
        // 2 backends, R=1: every bucket is owned by both. Backend 0
        // really sleeps 20 ms per RPC; backend 1 is an unwrapped slab.
        let slow = FaultStore::new(
            dyn_server(h),
            "slow",
            vec![Fault::DelayEvery { every: 1, secs: 0.02 }],
        )
        .with_real_delays();
        let handle = slow.handle();
        let backends: Vec<Arc<dyn EmbeddingStore>> = vec![Arc::new(slow), dyn_server(h)];
        let store = ShardedStore::replicated(backends, 1)
            .unwrap()
            .with_replica_select(ReplicaSelect::Fastest);
        let nodes: Vec<u32> = (0..64).collect();
        store
            .push(&nodes, &[rows(&nodes, h, 0.0), rows(&nodes, h, 1.0)])
            .unwrap();
        // warmup pulls teach the tracker both backends' latencies (the
        // buckets whose primary is 0 pay the 20 ms delay once or twice)
        for _ in 0..3 {
            store.pull(&nodes, false).unwrap();
        }
        assert!(
            store.observed_latency(0).unwrap() > store.observed_latency(1).unwrap(),
            "tracker must rank the delayed backend slower"
        );
        // measurement window: pulls only (pushes fan out to all owners
        // by design, so only reads are selectable)
        let before = handle.calls();
        for _ in 0..10 {
            let (got, _) = store.pull(&nodes, false).unwrap();
            assert_eq!(got[0], rows(&nodes, h, 0.0)); // values never change
        }
        assert_eq!(
            handle.calls(),
            before,
            "fastest-first selection must stop reading the slow backend"
        );
    }

    #[test]
    fn primary_select_ignores_latency_measurements() {
        use crate::coordinator::resilience::{Fault, FaultStore};
        let h = 4;
        let slow = FaultStore::new(
            dyn_server(h),
            "slow",
            vec![Fault::DelayEvery { every: 1, secs: 0.005 }],
        )
        .with_real_delays();
        let handle = slow.handle();
        let backends: Vec<Arc<dyn EmbeddingStore>> = vec![Arc::new(slow), dyn_server(h)];
        let store = ShardedStore::replicated(backends, 1)
            .unwrap()
            .with_replica_select(ReplicaSelect::Primary);
        let nodes: Vec<u32> = (0..64).collect();
        store
            .push(&nodes, &[rows(&nodes, h, 0.0), rows(&nodes, h, 1.0)])
            .unwrap();
        let after_push = handle.calls();
        for _ in 0..5 {
            store.pull(&nodes, false).unwrap();
        }
        // under the historical policy the slow backend keeps serving the
        // buckets it is primary for, no matter what the tracker measured
        assert!(
            handle.calls() > after_push,
            "primary selection must keep reading map-order primaries"
        );
    }
}
