//! Round-advancement policies and bounded-staleness aggregation.
//!
//! Every round the session collects one virtual *report delay* per client
//! (injected by [`crate::coordinator::netsim::ClientLatency`]; zero when no
//! latency model is configured) and asks the configured [`RoundPolicy`] when
//! to release the barrier. The policy returns a [`RoundPlan`]: the virtual
//! release time plus an on-time mask over clients.
//!
//! Three policies are provided:
//!
//! - [`Synchronous`] — today's hard barrier. Waits for every client, so the
//!   release time is the slowest report. This is the bit-parity oracle: with
//!   zero injected latency every other policy degenerates to it.
//! - [`Quorum`] — advance once `k` of `n` clients have reported, then grant a
//!   bounded `slack` window for the tail (the opportunistic-witness shape).
//! - [`Deadline`] — advance when a fixed virtual-time budget expires,
//!   dropping whoever has not reported (but never advancing before at least
//!   one client has).
//!
//! Clients that miss the release are *not* discarded silently: the
//! [`StalenessWeighted`] decorator wraps the session's
//! [`Aggregator`](crate::coordinator::aggregation::Aggregator) and folds
//! late updates into the first aggregation after they (virtually) arrive,
//! scaled by [`staleness_weight`] — a decaying factor in `(0, 1]` — and
//! drops (and counts) anything more than `max_stale` rounds old.
//!
//! Determinism: policies only ever see *injected* delays, never measured
//! wall-clock time, so membership decisions (and therefore accuracy curves)
//! are bit-reproducible regardless of host load or thread scheduling.

use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::aggregation::Aggregator;
use crate::runtime::ModelState;

/// What a [`RoundPolicy`] decided for one round, given per-client report
/// delays.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundPlan {
    /// Virtual time (seconds after the round's compute finishes) at which the
    /// barrier releases. Charged to the round's wall time.
    pub release: f64,
    /// `on_time[i]` — did client `i` report at or before `release`?
    pub on_time: Vec<bool>,
    /// Extra virtual time spent waiting beyond the bare quorum (the slack
    /// actually consumed). Zero for sync and deadline policies.
    pub quorum_wait: f64,
}

impl RoundPlan {
    /// Number of clients that made the barrier.
    pub fn n_on_time(&self) -> usize {
        self.on_time.iter().filter(|&&b| b).count()
    }

    /// Number of clients that missed the barrier this round.
    pub fn stragglers(&self) -> usize {
        self.on_time.len() - self.n_on_time()
    }
}

/// Decides, from deterministic per-client report delays, when a round's
/// barrier releases and which clients make it.
pub trait RoundPolicy: Send + Sync {
    /// Human-readable policy name (used in metrics and reports).
    fn name(&self) -> String;

    /// Plan one round. `delays[i]` is the virtual delay after which client
    /// `i`'s update is available. Implementations must be pure functions of
    /// `delays` (no clocks, no randomness) so runs stay reproducible.
    fn plan(&self, delays: &[f64]) -> RoundPlan;
}

/// Today's hard barrier: wait for every client.
#[derive(Clone, Copy, Debug, Default)]
pub struct Synchronous;

impl RoundPolicy for Synchronous {
    fn name(&self) -> String {
        "sync".to_string()
    }

    fn plan(&self, delays: &[f64]) -> RoundPlan {
        let release = delays.iter().copied().fold(0.0, f64::max);
        RoundPlan {
            release,
            on_time: vec![true; delays.len()],
            quorum_wait: 0.0,
        }
    }
}

/// Advance once `k` clients have reported, then wait up to `slack` extra
/// virtual seconds for the tail (never longer than the slowest client).
#[derive(Clone, Copy, Debug)]
pub struct Quorum {
    /// Number of reports required before the slack window opens. Clamped to
    /// `[1, n]` at plan time.
    pub k: usize,
    /// Bounded grace window (virtual seconds) granted after the k-th report.
    pub slack: f64,
}

impl RoundPolicy for Quorum {
    fn name(&self) -> String {
        format!("quorum:{}:{}", self.k, self.slack)
    }

    fn plan(&self, delays: &[f64]) -> RoundPlan {
        let n = delays.len();
        if n == 0 {
            return RoundPlan { release: 0.0, on_time: Vec::new(), quorum_wait: 0.0 };
        }
        let mut sorted = delays.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let t_max = sorted[n - 1];
        let k = self.k.clamp(1, n);
        let t_k = sorted[k - 1];
        let release = (t_k + self.slack.max(0.0)).min(t_max);
        let on_time = delays.iter().map(|&d| d <= release).collect();
        RoundPlan { release, on_time, quorum_wait: release - t_k }
    }
}

/// Advance when a fixed virtual-time budget expires. Never releases before
/// the fastest client has reported (an empty aggregation is useless) and
/// never waits past the slowest.
#[derive(Clone, Copy, Debug)]
pub struct Deadline {
    /// Virtual seconds granted per round for reports to arrive.
    pub budget: f64,
}

impl RoundPolicy for Deadline {
    fn name(&self) -> String {
        format!("deadline:{}", self.budget)
    }

    fn plan(&self, delays: &[f64]) -> RoundPlan {
        let n = delays.len();
        if n == 0 {
            return RoundPlan { release: 0.0, on_time: Vec::new(), quorum_wait: 0.0 };
        }
        let t_max = delays.iter().copied().fold(f64::MIN, f64::max);
        let t_min = delays.iter().copied().fold(f64::MAX, f64::min);
        let release = self.budget.min(t_max).max(t_min);
        let on_time = delays.iter().map(|&d| d <= release).collect();
        RoundPlan { release, on_time, quorum_wait: 0.0 }
    }
}

/// Parsed, serializable form of a round policy — what [`SessionConfig`]
/// carries. Grammar: `sync | quorum:K[:SLACK] | deadline:SECS`.
///
/// [`SessionConfig`]: crate::coordinator::session::SessionConfig
#[derive(Clone, Debug, PartialEq, Default)]
pub enum RoundPolicySpec {
    /// Hard barrier (the default).
    #[default]
    Sync,
    /// Quorum of `k` reports plus a bounded slack window.
    Quorum {
        /// Reports required before the slack window opens.
        k: usize,
        /// Grace window (virtual seconds) after the k-th report.
        slack: f64,
    },
    /// Fixed virtual-time budget per round.
    Deadline {
        /// Virtual seconds granted per round.
        budget: f64,
    },
}

impl RoundPolicySpec {
    /// Parse `sync | quorum:K[:SLACK] | deadline:SECS` (case-insensitive).
    pub fn parse(s: &str) -> Result<Self> {
        let lower = s.trim().to_ascii_lowercase();
        let mut parts = lower.split(':');
        let kind = parts.next().unwrap_or("");
        let spec = match kind {
            "sync" => {
                if parts.next().is_some() {
                    bail!("round policy \"sync\" takes no arguments (got {s:?})");
                }
                RoundPolicySpec::Sync
            }
            "quorum" => {
                let k: usize = parts
                    .next()
                    .with_context(|| format!("round policy {s:?}: quorum requires K"))?
                    .parse()
                    .with_context(|| format!("round policy {s:?}: bad quorum K"))?;
                if k == 0 {
                    bail!("round policy {s:?}: quorum K must be >= 1");
                }
                let slack: f64 = match parts.next() {
                    Some(t) => t
                        .parse()
                        .with_context(|| format!("round policy {s:?}: bad slack seconds"))?,
                    None => 0.0,
                };
                if !slack.is_finite() || slack < 0.0 {
                    bail!("round policy {s:?}: slack must be finite and >= 0");
                }
                if parts.next().is_some() {
                    bail!("round policy {s:?}: too many fields for quorum:K[:SLACK]");
                }
                RoundPolicySpec::Quorum { k, slack }
            }
            "deadline" => {
                let budget: f64 = parts
                    .next()
                    .with_context(|| format!("round policy {s:?}: deadline requires SECS"))?
                    .parse()
                    .with_context(|| format!("round policy {s:?}: bad deadline seconds"))?;
                if !budget.is_finite() || budget < 0.0 {
                    bail!("round policy {s:?}: deadline must be finite and >= 0");
                }
                if parts.next().is_some() {
                    bail!("round policy {s:?}: too many fields for deadline:SECS");
                }
                RoundPolicySpec::Deadline { budget }
            }
            _ => bail!(
                "unknown round policy {s:?} (expected sync | quorum:K[:SLACK] | deadline:SECS)"
            ),
        };
        Ok(spec)
    }

    /// Canonical name, also the value of the `round_policy` metrics field.
    pub fn name(&self) -> String {
        match self {
            RoundPolicySpec::Sync => "sync".to_string(),
            RoundPolicySpec::Quorum { k, slack } => {
                if *slack == 0.0 {
                    format!("quorum:{k}")
                } else {
                    format!("quorum:{k}:{slack}")
                }
            }
            RoundPolicySpec::Deadline { budget } => format!("deadline:{budget}"),
        }
    }

    /// True for the hard barrier (no staleness machinery is installed).
    pub fn is_sync(&self) -> bool {
        matches!(self, RoundPolicySpec::Sync)
    }

    /// Instantiate the policy object the session loop consults.
    pub fn build(&self) -> Arc<dyn RoundPolicy> {
        match *self {
            RoundPolicySpec::Sync => Arc::new(Synchronous),
            RoundPolicySpec::Quorum { k, slack } => Arc::new(Quorum { k, slack }),
            RoundPolicySpec::Deadline { budget } => Arc::new(Deadline { budget }),
        }
    }
}

/// Round policy from `OPTIMES_ROUND_POLICY` (default: `sync`). Unparseable
/// values warn to stderr and fall back to the synchronous barrier.
pub fn round_policy_default() -> RoundPolicySpec {
    match std::env::var("OPTIMES_ROUND_POLICY") {
        Ok(v) if !v.is_empty() => match RoundPolicySpec::parse(&v) {
            Ok(spec) => spec,
            Err(e) => {
                crate::log!(Warn, "OPTIMES_ROUND_POLICY={v:?} invalid ({e:#}); using sync");
                RoundPolicySpec::Sync
            }
        },
        _ => RoundPolicySpec::Sync,
    }
}

/// Staleness bound from `OPTIMES_STALENESS` (default: 2 rounds).
pub fn staleness_default() -> usize {
    match std::env::var("OPTIMES_STALENESS") {
        Ok(v) if !v.is_empty() => match v.parse() {
            Ok(s) => s,
            Err(_) => {
                crate::log!(Warn, "OPTIMES_STALENESS={v:?} is not an integer; using 2");
                2
            }
        },
        _ => 2,
    }
}

/// Per-round-of-staleness decay applied by [`StalenessWeighted`].
pub const DEFAULT_STALENESS_DECAY: f64 = 0.5;

/// Weight multiplier for an update `staleness` rounds old: `decay^staleness`,
/// in `(0, 1]` for `decay` in `(0, 1]` and monotone non-increasing in the
/// staleness.
pub fn staleness_weight(staleness: usize, decay: f64) -> f64 {
    decay.powi(staleness as i32)
}

/// What one aggregation did with pending late updates.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StaleFold {
    /// Late updates folded into this aggregation.
    pub folded: usize,
    /// Sum of the decay factors applied (each in `(0, 1]`).
    pub weight_applied: f64,
    /// Late updates dropped for exceeding the staleness bound.
    pub dropped: usize,
}

struct PendingUpdate {
    state: ModelState,
    weight: f64,
    round: usize,
    arrival: f64,
}

/// Serializable copy of one deferred late update — what a session
/// checkpoint records so a resumed run folds exactly the same tail
/// (`coordinator/checkpoint.rs`).
#[derive(Clone, Debug)]
pub struct PendingSnapshot {
    /// The late client's model state at its round.
    pub state: ModelState,
    /// Aggregation weight before staleness decay.
    pub weight: f64,
    /// Round the update was produced in.
    pub round: usize,
    /// Absolute delay-clock time the update (virtually) arrives.
    pub arrival: f64,
}

#[derive(Default)]
struct StaleState {
    pending: Vec<PendingUpdate>,
    round: usize,
    now: f64,
    last: StaleFold,
    dropped_total: usize,
}

/// Decorator over any [`Aggregator`]: folds late client updates (deferred by
/// the session when a [`RoundPolicy`] advances without them) into the next
/// aggregation after their virtual arrival, down-weighted by
/// [`staleness_weight`], and drops anything more than `max_stale` rounds old.
///
/// With no pending updates this is a pure pass-through — wrapping a sync run
/// (which never defers) cannot change its results.
pub struct StalenessWeighted {
    inner: Arc<dyn Aggregator>,
    max_stale: usize,
    decay: f64,
    state: Mutex<StaleState>,
}

impl StalenessWeighted {
    /// Wrap `inner` with the default decay ([`DEFAULT_STALENESS_DECAY`]).
    pub fn new(inner: Arc<dyn Aggregator>, max_stale: usize) -> Self {
        Self::with_decay(inner, max_stale, DEFAULT_STALENESS_DECAY)
    }

    /// Wrap `inner` with an explicit per-round decay in `(0, 1]`.
    pub fn with_decay(inner: Arc<dyn Aggregator>, max_stale: usize, decay: f64) -> Self {
        Self { inner, max_stale, decay, state: Mutex::new(StaleState::default()) }
    }

    /// Tell the decorator which round is about to aggregate and what the
    /// virtual clock reads at its barrier release.
    pub fn begin_round(&self, round: usize, now: f64) {
        let mut st = self.state.lock().unwrap();
        st.round = round;
        st.now = now;
    }

    /// Defer a late client update: it was produced in `round` and (virtually)
    /// arrives at absolute delay-clock time `arrival`.
    pub fn defer(&self, state: ModelState, weight: f64, round: usize, arrival: f64) {
        let mut st = self.state.lock().unwrap();
        st.pending.push(PendingUpdate { state, weight, round, arrival });
    }

    /// What the most recent aggregation did with late updates.
    pub fn last_fold(&self) -> StaleFold {
        self.state.lock().unwrap().last
    }

    /// Late updates currently queued (arrived or not).
    pub fn pending_len(&self) -> usize {
        self.state.lock().unwrap().pending.len()
    }

    /// Total updates dropped over the session for exceeding `max_stale`.
    pub fn dropped_total(&self) -> usize {
        self.state.lock().unwrap().dropped_total
    }

    /// Export the deferred-update queue and cumulative drop counter for
    /// a session checkpoint (in defer order).
    pub fn export_pending(&self) -> (Vec<PendingSnapshot>, usize) {
        let st = self.state.lock().unwrap();
        let pending = st
            .pending
            .iter()
            .map(|p| PendingSnapshot {
                state: p.state.clone(),
                weight: p.weight,
                round: p.round,
                arrival: p.arrival,
            })
            .collect();
        (pending, st.dropped_total)
    }

    /// Replace the queue and drop counter with checkpointed state
    /// (discards anything currently pending).
    pub fn import_pending(&self, pending: Vec<PendingSnapshot>, dropped_total: usize) {
        let mut st = self.state.lock().unwrap();
        st.pending = pending
            .into_iter()
            .map(|p| PendingUpdate {
                state: p.state,
                weight: p.weight,
                round: p.round,
                arrival: p.arrival,
            })
            .collect();
        st.dropped_total = dropped_total;
    }
}

impl Aggregator for StalenessWeighted {
    fn name(&self) -> String {
        format!("stale{}({})", self.max_stale, self.inner.name())
    }

    fn aggregate(&self, clients: &[(&ModelState, f64)]) -> Vec<Vec<f32>> {
        let mut st = self.state.lock().unwrap();
        let now = st.now;
        let round = st.round;
        let (arrived, keep): (Vec<_>, Vec<_>) =
            st.pending.drain(..).partition(|p| p.arrival <= now + 1e-12);
        st.pending = keep;
        let mut fold = StaleFold::default();
        let mut scaled: Vec<(ModelState, f64)> = Vec::with_capacity(arrived.len());
        for p in arrived {
            let s = round.saturating_sub(p.round);
            if s > self.max_stale {
                fold.dropped += 1;
                continue;
            }
            let factor = staleness_weight(s, self.decay);
            fold.folded += 1;
            fold.weight_applied += factor;
            scaled.push((p.state, p.weight * factor));
        }
        st.last = fold;
        st.dropped_total += fold.dropped;
        let mut all: Vec<(&ModelState, f64)> = clients.to_vec();
        all.extend(scaled.iter().map(|(s, w)| (s, *w)));
        self.inner.aggregate(&all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::aggregation::FedAvg;
    use crate::runtime::{ModelGeom, ModelKind};

    fn small_geom() -> ModelGeom {
        ModelGeom {
            model: ModelKind::Gc,
            layers: 2,
            feat: 2,
            hidden: 2,
            classes: 2,
            batch: 2,
            fanout: 2,
            push_batch: 2,
        }
    }

    fn const_state(v: f32) -> ModelState {
        let mut s = ModelState::zeros(&small_geom());
        for p in s.params.iter_mut() {
            for x in p.iter_mut() {
                *x = v;
            }
        }
        s
    }

    #[test]
    fn parse_grammar_round_trips() {
        assert_eq!(RoundPolicySpec::parse("sync").unwrap(), RoundPolicySpec::Sync);
        assert_eq!(
            RoundPolicySpec::parse("quorum:3").unwrap(),
            RoundPolicySpec::Quorum { k: 3, slack: 0.0 }
        );
        assert_eq!(
            RoundPolicySpec::parse("QUORUM:4:0.25").unwrap(),
            RoundPolicySpec::Quorum { k: 4, slack: 0.25 }
        );
        assert_eq!(
            RoundPolicySpec::parse("deadline:1.5").unwrap(),
            RoundPolicySpec::Deadline { budget: 1.5 }
        );
        for bad in [
            "", "nope", "quorum", "quorum:0", "quorum:x", "quorum:2:-1", "quorum:2:0.1:9",
            "deadline", "deadline:-3", "deadline:inf", "sync:1",
        ] {
            assert!(RoundPolicySpec::parse(bad).is_err(), "accepted {bad:?}");
        }
        assert_eq!(RoundPolicySpec::parse("quorum:3").unwrap().name(), "quorum:3");
        assert_eq!(
            RoundPolicySpec::parse("quorum:3:0.5").unwrap().name(),
            "quorum:3:0.5"
        );
        assert_eq!(RoundPolicySpec::parse("deadline:2").unwrap().name(), "deadline:2");
    }

    #[test]
    fn sync_waits_for_the_slowest() {
        let plan = Synchronous.plan(&[0.3, 0.1, 0.7, 0.2]);
        assert_eq!(plan.release, 0.7);
        assert_eq!(plan.n_on_time(), 4);
        assert_eq!(plan.stragglers(), 0);
        assert_eq!(plan.quorum_wait, 0.0);
    }

    #[test]
    fn quorum_releases_after_kth_report_plus_slack() {
        let delays = [0.1, 0.9, 0.2, 5.0];
        let plan = Quorum { k: 2, slack: 0.0 }.plan(&delays);
        assert_eq!(plan.release, 0.2);
        assert_eq!(plan.on_time, vec![true, false, true, false]);
        assert_eq!(plan.quorum_wait, 0.0);

        // Slack lets the 0.9 client squeak in; quorum_wait records it.
        let plan = Quorum { k: 2, slack: 1.0 }.plan(&delays);
        assert_eq!(plan.release, 1.2);
        assert_eq!(plan.on_time, vec![true, true, true, false]);
        assert!((plan.quorum_wait - 1.0).abs() < 1e-12);

        // Slack never extends past the slowest client.
        let plan = Quorum { k: 3, slack: 100.0 }.plan(&delays);
        assert_eq!(plan.release, 5.0);
        assert_eq!(plan.n_on_time(), 4);
    }

    #[test]
    fn quorum_k_n_is_sync_and_empty_is_safe() {
        let delays = [0.4, 0.2, 0.8];
        assert_eq!(Quorum { k: 3, slack: 0.3 }.plan(&delays), Synchronous.plan(&delays));
        let empty = Quorum { k: 3, slack: 0.3 }.plan(&[]);
        assert_eq!(empty.on_time.len(), 0);
        assert_eq!(empty.release, 0.0);
    }

    #[test]
    fn deadline_drops_the_tail_but_keeps_someone() {
        let delays = [0.1, 0.9, 2.0];
        let plan = Deadline { budget: 1.0 }.plan(&delays);
        assert_eq!(plan.release, 1.0);
        assert_eq!(plan.on_time, vec![true, true, false]);

        // Budget below the fastest client still admits that client.
        let plan = Deadline { budget: 0.01 }.plan(&delays);
        assert_eq!(plan.release, 0.1);
        assert_eq!(plan.n_on_time(), 1);

        // Budget above the slowest is clipped to it.
        let plan = Deadline { budget: 10.0 }.plan(&delays);
        assert_eq!(plan.release, 2.0);
        assert_eq!(plan.n_on_time(), 3);
    }

    #[test]
    fn staleness_weight_decays() {
        assert_eq!(staleness_weight(0, 0.5), 1.0);
        assert_eq!(staleness_weight(1, 0.5), 0.5);
        assert_eq!(staleness_weight(2, 0.5), 0.25);
    }

    #[test]
    fn stale_updates_fold_with_decayed_weight() {
        let agg = StalenessWeighted::new(Arc::new(FedAvg), 2);
        // Round 4: a round-3 update (staleness 1) arrived at t=1.0.
        agg.defer(const_state(3.0), 1.0, 3, 1.0);
        agg.begin_round(4, 2.0);
        let on_time = const_state(1.0);
        let out = agg.aggregate(&[(&on_time, 1.0)]);
        // FedAvg: (1*1.0 + 0.5*3.0) / 1.5 = 5/3.
        for p in &out {
            for &x in p {
                assert!((x - 5.0 / 3.0).abs() < 1e-6, "got {x}");
            }
        }
        let fold = agg.last_fold();
        assert_eq!(fold.folded, 1);
        assert_eq!(fold.dropped, 0);
        assert!((fold.weight_applied - 0.5).abs() < 1e-12);
        assert_eq!(agg.pending_len(), 0);
    }

    #[test]
    fn not_yet_arrived_updates_stay_pending() {
        let agg = StalenessWeighted::new(Arc::new(FedAvg), 2);
        agg.defer(const_state(9.0), 1.0, 3, 10.0);
        agg.begin_round(4, 2.0);
        let on_time = const_state(1.0);
        let out = agg.aggregate(&[(&on_time, 1.0)]);
        for p in &out {
            for &x in p {
                assert!((x - 1.0).abs() < 1e-6, "pending update leaked: {x}");
            }
        }
        assert_eq!(agg.last_fold(), StaleFold::default());
        assert_eq!(agg.pending_len(), 1);
    }

    #[test]
    fn too_stale_updates_are_dropped_and_counted() {
        let agg = StalenessWeighted::new(Arc::new(FedAvg), 1);
        agg.defer(const_state(9.0), 1.0, 1, 0.5);
        agg.begin_round(4, 2.0); // staleness 3 > max_stale 1
        let on_time = const_state(1.0);
        let out = agg.aggregate(&[(&on_time, 1.0)]);
        for p in &out {
            for &x in p {
                assert!((x - 1.0).abs() < 1e-6, "dropped update leaked: {x}");
            }
        }
        let fold = agg.last_fold();
        assert_eq!(fold.folded, 0);
        assert_eq!(fold.dropped, 1);
        assert_eq!(agg.dropped_total(), 1);
    }

    #[test]
    fn empty_pending_is_pure_passthrough() {
        let inner: Arc<dyn Aggregator> = Arc::new(FedAvg);
        let agg = StalenessWeighted::new(Arc::clone(&inner), 2);
        agg.begin_round(1, 0.0);
        let a = const_state(1.0);
        let b = const_state(2.0);
        let direct = inner.aggregate(&[(&a, 2.0), (&b, 1.0)]);
        let wrapped = agg.aggregate(&[(&a, 2.0), (&b, 1.0)]);
        assert_eq!(direct, wrapped);
    }
}
