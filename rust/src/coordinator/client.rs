//! Client state: expanded subgraph, local embedding cache, local model,
//! sampler streams, and the prefetch bookkeeping for OPP.

use std::sync::Arc;

use super::pipeline::PendingPull;
use super::trainer::BatchScratch;
use crate::graph::sampler::{static_adj, Sampler, SharedAdj};
use crate::graph::{BlockDims, ClientSubgraph};
use crate::runtime::{ModelState, StepEngine};
use crate::util::rng::Rng;

/// Per-client cache of remote embeddings (`h^1..h^{L-1}` per pull node),
/// dense-indexed by the subgraph's remote index. Presence is per node
/// (a pull RPC always fetches all layers for a node, like the paper's
/// per-layer Redis DBs read in one pipelined batch).
#[derive(Clone, Debug)]
pub struct EmbCache {
    pub hidden: usize,
    /// L-1 hidden layers.
    pub n_layers: usize,
    data: Vec<Vec<f32>>,
    present: Vec<bool>,
}

impl EmbCache {
    pub fn new(n_layers: usize, hidden: usize, n_remote: usize) -> Self {
        Self {
            hidden,
            n_layers,
            data: (0..n_layers).map(|_| vec![0f32; n_remote * hidden]).collect(),
            present: vec![false; n_remote],
        }
    }

    pub fn n_remote(&self) -> usize {
        self.present.len()
    }

    /// Mark everything stale (start of a round — embeddings must be
    /// re-pulled fresh, matching EmbC semantics).
    pub fn invalidate_all(&mut self) {
        self.present.iter_mut().for_each(|p| *p = false);
    }

    /// Store pulled rows: `per_layer[l]` is row-major `[idxs.len(), H]`.
    pub fn insert(&mut self, idxs: &[u32], per_layer: &[Vec<f32>]) {
        let h = self.hidden;
        for (l, rows) in per_layer.iter().enumerate() {
            debug_assert_eq!(rows.len(), idxs.len() * h);
            for (i, &r) in idxs.iter().enumerate() {
                self.data[l][r as usize * h..(r as usize + 1) * h]
                    .copy_from_slice(&rows[i * h..(i + 1) * h]);
            }
        }
        for &r in idxs {
            self.present[r as usize] = true;
        }
    }

    #[inline]
    pub fn is_present(&self, r: u32) -> bool {
        self.present[r as usize]
    }

    /// Row for hidden layer `l` (1-based) of remote index `r`.
    #[inline]
    pub fn row(&self, l: usize, r: u32) -> &[f32] {
        let h = self.hidden;
        &self.data[l - 1][r as usize * h..(r as usize + 1) * h]
    }

    /// Subset of `used` not currently cached.
    pub fn missing_of(&self, used: &[u32]) -> Vec<u32> {
        used.iter()
            .copied()
            .filter(|&r| !self.present[r as usize])
            .collect()
    }

    pub fn present_count(&self) -> usize {
        self.present.iter().filter(|&&p| p).count()
    }
}

/// One federated client.
pub struct Client {
    pub id: usize,
    pub sub: ClientSubgraph,
    pub cache: EmbCache,
    pub sampler: Sampler,
    pub state: ModelState,
    pub dims: BlockDims,
    /// Local indices of push nodes (aligned with `push_globals`).
    pub push_local: Vec<u32>,
    pub push_globals: Vec<u32>,
    /// Frequency (or ablation) score per remote index.
    pub scores: Vec<f32>,
    /// Remote indices to prefetch at round start (top-x% by score), OPP.
    pub prefetch_rows: Vec<u32>,
    /// Constant gather adjacency for train and embed geometries, shared
    /// by refcount into every assembled batch.
    pub adj_train: SharedAdj,
    pub adj_embed: SharedAdj,
    /// Reusable batch-assembly arena (zero-alloc steady state).
    pub scratch: BatchScratch,
    /// Reusable buffer for batched embedding pulls (`pull_into`).
    pub pull_buf: Vec<Vec<f32>>,
    /// In-flight prefetch of this client's next initial pull, parked by
    /// the session between rounds (`--pipeline on`; DESIGN.md §9) and
    /// consumed — or discarded, if the pull set changed — by the next
    /// `run_round_pipelined` call.
    pub pending_pull: Option<PendingPull>,
    pub epoch_batches: usize,
    pub(crate) train_cursor: usize,
    pub(crate) train_order: Vec<u32>,
    pub(crate) rng: Rng,
    /// Dynamic re-pruning (paper §1 "static versus dynamic graph
    /// pruning" ablation): when set, the retained remote in-neighbour
    /// subsets are re-sampled from the full candidate lists at every
    /// round start instead of once offline.
    dynamic_retention: Option<usize>,
    full_in_remote: Vec<Vec<u32>>,
}

impl Client {
    pub fn new(
        sub: ClientSubgraph,
        engine: &Arc<dyn StepEngine>,
        epoch_batches: usize,
        seed: u64,
    ) -> Self {
        let geom = *engine.geom();
        let dims = geom.dims();
        let id = sub.client_id;
        let cache = EmbCache::new(geom.layers - 1, geom.hidden, sub.n_remote());
        let push_globals = sub.push_nodes.clone();
        let push_local: Vec<u32> = push_globals
            .iter()
            .map(|g| sub.local_index(*g).expect("push node is local"))
            .collect();
        let mut rng = Rng::new(seed, 0xC11E57 + id as u64);
        let mut train_order = sub.train_local.clone();
        rng.shuffle(&mut train_order);
        Self {
            sampler: Sampler::new(dims, seed, id as u64),
            cache,
            state: ModelState::zeros(&geom),
            dims,
            push_local,
            push_globals,
            scores: Vec::new(),
            prefetch_rows: Vec::new(),
            adj_train: static_adj(&dims, dims.batch, dims.layers),
            adj_embed: static_adj(&dims, dims.push_batch, dims.layers - 1),
            scratch: BatchScratch::default(),
            pull_buf: Vec::new(),
            pending_pull: None,
            epoch_batches,
            train_cursor: 0,
            train_order,
            sub,
            id,
            rng,
            dynamic_retention: None,
            full_in_remote: Vec::new(),
        }
    }

    /// Switch to dynamic per-round re-pruning with the given retention
    /// limit. Must be called on a client built WITHOUT static pruning
    /// (the full candidate lists are snapshotted here).
    pub fn enable_dynamic_prune(&mut self, limit: usize) {
        self.full_in_remote = self.sub.in_remote.clone();
        self.dynamic_retention = Some(limit);
    }

    /// Re-sample the retained remote subsets for this round (no-op for
    /// static pruning).
    pub fn resample_dynamic_prune(&mut self) {
        let Some(limit) = self.dynamic_retention else {
            return;
        };
        for (dst, full) in self.sub.in_remote.iter_mut().zip(&self.full_in_remote) {
            if full.len() <= limit {
                dst.clone_from(full);
            } else {
                let keep = self.rng.sample_indices(full.len(), limit);
                let mut kept: Vec<u32> = keep.iter().map(|&i| full[i]).collect();
                kept.sort_unstable();
                *dst = kept;
            }
        }
    }

    /// Remote rows to pull this round: the active (possibly re-sampled)
    /// subset under dynamic pruning, everything otherwise.
    pub fn active_remote_rows(&self) -> Vec<u32> {
        if self.dynamic_retention.is_none() {
            return self.all_remote_rows();
        }
        let mut set = std::collections::HashSet::new();
        for rems in &self.sub.in_remote {
            set.extend(rems.iter().copied());
        }
        let mut v: Vec<u32> = set.into_iter().collect();
        v.sort_unstable();
        v
    }

    /// Install per-remote scores and derive the top-`frac` prefetch set.
    pub fn set_scores(&mut self, scores: Vec<f32>, prefetch_frac: Option<f64>) {
        assert_eq!(scores.len(), self.sub.n_remote());
        if let Some(frac) = prefetch_frac {
            let mut order: Vec<u32> = (0..scores.len() as u32).collect();
            order.sort_by(|&a, &b| {
                scores[b as usize]
                    .partial_cmp(&scores[a as usize])
                    .unwrap()
                    .then(a.cmp(&b))
            });
            let keep = ((scores.len() as f64) * frac).round() as usize;
            self.prefetch_rows = order[..keep.min(order.len())].to_vec();
            self.prefetch_rows.sort_unstable();
        }
        self.scores = scores;
    }

    /// Next batch of training targets (wraps + reshuffles per epoch pass).
    pub fn next_targets(&mut self, batch: usize) -> Vec<u32> {
        if self.train_order.is_empty() {
            return Vec::new();
        }
        let mut out = Vec::with_capacity(batch);
        for _ in 0..batch.min(self.train_order.len()) {
            if self.train_cursor >= self.train_order.len() {
                self.train_cursor = 0;
                let mut order = std::mem::take(&mut self.train_order);
                self.rng.shuffle(&mut order);
                self.train_order = order;
            }
            out.push(self.train_order[self.train_cursor]);
            self.train_cursor += 1;
        }
        out
    }

    /// All remote indices (the default pull set for non-prefetch
    /// strategies).
    pub fn all_remote_rows(&self) -> Vec<u32> {
        (0..self.sub.n_remote() as u32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny;
    use crate::graph::partition::metis_lite;
    use crate::graph::subgraph::{build_all, Prune};
    use crate::runtime::manifest::{ModelGeom, ModelKind};
    use crate::runtime::RefEngine;

    fn engine() -> Arc<dyn StepEngine> {
        Arc::new(RefEngine::new(ModelGeom {
            model: ModelKind::Gc,
            layers: 3,
            feat: 32,
            hidden: 8,
            classes: 4,
            batch: 4,
            fanout: 3,
            push_batch: 4,
        }))
    }

    fn client() -> Client {
        let g = tiny(51);
        let part = metis_lite(&g, 4, 2);
        let subs = build_all(&g, &part, &Prune::None, 5);
        Client::new(subs.into_iter().next().unwrap(), &engine(), 4, 9)
    }

    #[test]
    fn cache_roundtrip_and_invalidate() {
        let mut c = EmbCache::new(2, 4, 10);
        assert_eq!(c.missing_of(&[1, 2, 3]), vec![1, 2, 3]);
        c.insert(&[2, 5], &[vec![1.0; 8], vec![2.0; 8]]);
        assert!(c.is_present(2) && c.is_present(5) && !c.is_present(3));
        assert_eq!(c.row(1, 2), &[1.0; 4]);
        assert_eq!(c.row(2, 5), &[2.0; 4]);
        assert_eq!(c.missing_of(&[2, 3, 5]), vec![3]);
        assert_eq!(c.present_count(), 2);
        c.invalidate_all();
        assert_eq!(c.present_count(), 0);
    }

    #[test]
    fn next_targets_cycles_all_train_vertices() {
        let mut c = client();
        let n = c.sub.train_local.len();
        let mut seen = std::collections::HashSet::new();
        let mut fetched = 0;
        while fetched < n {
            for t in c.next_targets(4) {
                seen.insert(t);
                fetched += 1;
            }
        }
        assert_eq!(seen.len(), n.min(fetched));
    }

    #[test]
    fn prefetch_set_is_top_scoring() {
        let mut c = client();
        let n = c.sub.n_remote();
        if n < 8 {
            return;
        }
        // score = remote index value
        let scores: Vec<f32> = (0..n).map(|i| i as f32).collect();
        c.set_scores(scores, Some(0.25));
        let keep = ((n as f64) * 0.25).round() as usize;
        assert_eq!(c.prefetch_rows.len(), keep);
        // top-scoring = highest indices
        let min_kept = *c.prefetch_rows.iter().min().unwrap() as usize;
        assert!(min_kept >= n - keep - 1);
    }

    #[test]
    fn push_locals_align_with_globals() {
        let c = client();
        for (l, g) in c.push_local.iter().zip(&c.push_globals) {
            assert_eq!(c.sub.local[*l as usize], *g);
        }
    }
}
