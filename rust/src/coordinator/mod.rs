//! The L3 coordinator — the paper's system contribution: federated round
//! orchestration with a transport-agnostic embedding plane
//! ([`EmbeddingStore`]: in-process slab / TCP / sharded), a real
//! asynchronous push/pull pipeline over it ([`AsyncStoreHandle`],
//! DESIGN.md §9), a replication-aware router with online rebalancing and
//! deterministic fault injection ([`ShardMap`] / [`FaultStore`],
//! DESIGN.md §10), push-overlap, pruning, scored prefetching (OptimES
//! strategies D/E/O/P/OP/OPP/OPG), straggler-tolerant round advancement
//! with bounded-staleness aggregation ([`RoundPolicy`] /
//! [`StalenessWeighted`], DESIGN.md §12), and a composable session API
//! ([`SessionBuilder`] with pluggable [`Aggregator`] and
//! [`RoundObserver`] seams).

pub mod aggregation;
pub mod checkpoint;
pub mod client;
pub mod codec;
pub mod embedding_server;
pub mod lifecycle;
pub mod metrics;
pub mod net_transport;
pub mod netsim;
pub mod pipeline;
pub mod resilience;
pub mod rounds;
pub mod session;
pub mod store;
pub mod strategy;
pub mod tenant;
pub mod trainer;

pub use aggregation::{fedavg, Aggregator, FedAvg, TrimmedMean, UniformAvg, Validator};
pub use checkpoint::{
    checkpoint_from_env, checkpoint_path, graph_fingerprint, parse_checkpoint_spec,
    CheckpointBundle, CheckpointConfig, ClientCheckpoint, CHECKPOINT_FILE,
};
pub use client::{Client, EmbCache};
pub use lifecycle::{
    depart, join_split, ChurnEvent, ChurnKind, ChurnSpec, Membership, MembershipChange,
    MembershipKind, RunState,
};
pub use embedding_server::EmbeddingServer;
pub use metrics::{OverlapMetrics, PhaseTimes, ReplicaLatency, RoundMetrics, SessionMetrics};
pub use net_transport::{
    DaemonConfig, DaemonStats, EmbServerDaemon, RemoteEmbClient, TcpEmbeddingStore, STATUS_BUSY,
    STATUS_OK,
};
pub use netsim::{client_latency_default, ClientLatency, NetConfig};
pub use pipeline::{
    pipeline_default, AsyncStoreHandle, PendingPull, PullDone, PullTicket, PushDone, PushTicket,
    ThrottledStore, Ticket,
};
pub use rounds::{
    round_policy_default, staleness_default, staleness_weight, Deadline, PendingSnapshot, Quorum,
    RoundPlan, RoundPolicy, RoundPolicySpec, StaleFold, StalenessWeighted, Synchronous,
};
pub use session::{
    run_session, NullObserver, RoundObserver, Session, SessionBuilder, SessionConfig,
    SessionPhase,
};
pub use resilience::{Fault, FaultHandle, FaultSpec, FaultStore, SnapshotStore};
pub use store::{
    sharded_desc, EmbeddingStore, RebalanceReport, ReplicaSelect, ShardMap, ShardedStore,
    StoreStats,
};
pub use strategy::{ParseStrategyError, ScoreKind, Strategy};
pub use tenant::{
    validate_tenant_name, TenantRegistry, TenantStore, MAX_TENANTS, TENANT_NODE_LIMIT,
};
