//! The L3 coordinator — the paper's system contribution: federated round
//! orchestration with an embedding server, push-overlap, pruning, and
//! scored prefetching (OptimES strategies D/E/O/P/OP/OPP/OPG).

pub mod aggregation;
pub mod client;
pub mod embedding_server;
pub mod metrics;
pub mod net_transport;
pub mod netsim;
pub mod session;
pub mod strategy;
pub mod trainer;

pub use client::{Client, EmbCache};
pub use embedding_server::EmbeddingServer;
pub use metrics::{PhaseTimes, RoundMetrics, SessionMetrics};
pub use netsim::NetConfig;
pub use session::{run_session, SessionConfig};
pub use strategy::{ScoreKind, Strategy};
