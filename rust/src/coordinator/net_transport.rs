//! TCP transport for the embedding plane: lets the store run as a
//! separate process (the paper deploys it as a Redis server on the
//! aggregation host, reached over 1 Gbps Ethernet by all clients).
//!
//! Wire protocol (little-endian, length-delimited; all numeric encoding
//! via the safe [`codec`](super::codec) helpers):
//!
//! ```text
//! request  := op:u8 payload
//!   op=1 PULL   payload := n:u32 node_id*n
//!   op=2 PUSH   payload := n:u32 node_id*n layers:u32 (row-payload)*layers
//!   op=3 STATS  payload := (empty)
//!   op=4 CODEC  payload := len:u32 name:utf8*len     (wire-codec handshake)
//!   op=5 TENANT payload := len:u32 name:utf8*len     (namespace handshake)
//!   op=6 STATSX payload := (empty)                   (metrics exposition)
//! response := status:u8 payload          (status 0 = ok, 0xB5 = BUSY)
//!   PULL   -> layers:u32 hidden:u32 (row-payload)*layers
//!   PUSH   -> (empty)
//!   STATS  -> stored_nodes:u64 stored_rows:u64 failovers:u64 epoch:u64
//!             bytes_tx:u64 bytes_rx:u64 raw_tx:u64 raw_rx:u64
//!   CODEC  -> (empty)
//!   TENANT -> (empty)
//!   STATSX -> len:u32 text:utf8*len      (Prometheus-style exposition)
//! ```
//!
//! A `row-payload` is `n` rows encoded under the **connection codec** —
//! exactly `n * bytes_per_row` bytes, so no extra framing is needed.
//! Every connection starts on the raw-f32 codec (byte-compatible with
//! the pre-codec protocol); a CODEC handshake switches all subsequent
//! frames on that connection to the named [`RowCodec`] (DESIGN.md §11).
//! The server decodes pushes before storing (it always holds densified
//! f32 rows) and encodes pull responses on the way out, so lossy codecs
//! shape values identically to the in-process
//! [`CodecStore`](crate::wire::CodecStore) round-trip.
//!
//! All transfers are *batched* — one frame per pull/push phase, mirroring
//! the Redis pipelining the paper uses to amortize RPC overheads (§5.1).
//!
//! A TENANT handshake rebinds the connection to that session's
//! namespace on the daemon's shared store ([`TenantRegistry`],
//! DESIGN.md §15): one daemon hosts many concurrent federated sessions
//! with isolated rows and per-tenant STATS. The daemon also applies
//! **admission control**: past `--max-conns` a new connection is
//! answered with one loud [`STATUS_BUSY`] byte instead of being
//! silently served, and past `--max-inflight` a data-plane request is
//! shed the same way — clients surface both as a named `BUSY` error,
//! never a hang.
//!
//! Three pieces live here: [`EmbServerDaemon`] serves any
//! `Arc<dyn EmbeddingStore>` (in-process slab or a sharded compound) over
//! a listening socket; [`RemoteEmbClient`] is one connection speaking the
//! protocol; [`TcpEmbeddingStore`] wraps a reconnecting connection pool
//! behind the [`EmbeddingStore`] trait so sessions are transport-blind.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::codec;
use super::metrics::{RpcKind, RpcRecord};
use super::store::{EmbeddingStore, StoreStats};
use super::tenant::{TenantRegistry, MAX_TENANT_NAME};
use crate::obs;
use crate::wire::{CodecKind, RowCodec};

const OP_PULL: u8 = 1;
const OP_PUSH: u8 = 2;
const OP_STATS: u8 = 3;
const OP_CODEC: u8 = 4;
const OP_TENANT: u8 = 5;
const OP_STATSX: u8 = 6;

/// Response status: request served.
pub const STATUS_OK: u8 = 0;

/// Response status: rejected by admission control (connection cap or
/// in-flight cap). Deliberately far from 0/1 so a desynced stream is
/// unlikely to fake it.
pub const STATUS_BUSY: u8 = 0xB5;

/// Longest codec name a CODEC handshake may declare.
const MAX_CODEC_NAME: usize = 64;

/// Longest STATSX exposition a client will accept (a desynced stream
/// must not provoke a giant allocation).
const MAX_EXPOSITION: usize = 1 << 24;

fn read_ids(r: &mut impl Read) -> Result<Vec<u32>> {
    let n = codec::read_u32(r)? as usize;
    codec::read_u32s(r, n)
}

/// Admission-control limits of an [`EmbServerDaemon`] (`--max-conns` /
/// `--max-inflight`; 0 = unlimited, the historical behavior).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DaemonConfig {
    /// Connections served simultaneously; one past the cap is answered
    /// with a single [`STATUS_BUSY`] byte and closed.
    pub max_conns: usize,
    /// Data-plane requests (pull/push) executing simultaneously across
    /// all connections; excess requests are shed with [`STATUS_BUSY`].
    pub max_inflight: usize,
}

/// Live service counters of an [`EmbServerDaemon`]
/// ([`stats`](EmbServerDaemon::stats)).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DaemonStats {
    /// Connections currently holding an admission slot.
    pub live_conns: usize,
    /// Highest simultaneous admitted-connection count observed.
    pub peak_conns: usize,
    /// Connections ever admitted.
    pub total_conns: usize,
    /// Connections refused at the `max_conns` cap.
    pub rejected_conns: usize,
    /// Data-plane requests executing right now.
    pub inflight: usize,
    /// Highest simultaneous in-flight request count observed.
    pub peak_inflight: usize,
    /// Requests shed at the `max_inflight` cap.
    pub rejected_requests: usize,
    /// Handler threads alive (admitted + rejection handlers) — the
    /// accept loop's sweep keeps this bounded under churn.
    pub handler_threads: usize,
    /// Tenant namespaces registered via the TENANT handshake.
    pub tenants: usize,
}

/// State shared between the daemon handle, its accept loop, and every
/// handler thread: admission config, gauges, the tenant directory, and
/// the daemon's metrics registry (per-daemon, not the process global,
/// so co-located daemons in one test process never share cells).
struct DaemonShared {
    config: DaemonConfig,
    live_conns: AtomicUsize,
    peak_conns: AtomicUsize,
    total_conns: AtomicUsize,
    rejected_conns: AtomicUsize,
    inflight: AtomicUsize,
    peak_inflight: AtomicUsize,
    rejected_requests: AtomicUsize,
    handler_threads: AtomicUsize,
    tenants: TenantRegistry,
    registry: obs::Registry,
    /// Server-side RPC latency histograms (ns), cached out of the
    /// registry so the hot path never touches the registry lock.
    rpc_pull_ns: Arc<obs::Histogram>,
    rpc_push_ns: Arc<obs::Histogram>,
}

impl DaemonShared {
    fn stats(&self) -> DaemonStats {
        DaemonStats {
            live_conns: self.live_conns.load(Ordering::SeqCst),
            peak_conns: self.peak_conns.load(Ordering::SeqCst),
            total_conns: self.total_conns.load(Ordering::SeqCst),
            rejected_conns: self.rejected_conns.load(Ordering::SeqCst),
            inflight: self.inflight.load(Ordering::SeqCst),
            peak_inflight: self.peak_inflight.load(Ordering::SeqCst),
            rejected_requests: self.rejected_requests.load(Ordering::SeqCst),
            handler_threads: self.handler_threads.load(Ordering::SeqCst),
            tenants: self.tenants.len(),
        }
    }

    /// Render the daemon's metrics as a Prometheus-style text exposition
    /// (wire op=6, `optimes stats`, and the `serve` stats line). Service
    /// gauges and store occupancy are refreshed from their live sources
    /// at scrape time; the RPC latency histograms accumulate in place.
    fn exposition(&self) -> String {
        let s = self.stats();
        let r = &self.registry;
        r.gauge("optimes_daemon_live_conns").set(s.live_conns as i64);
        r.gauge("optimes_daemon_peak_conns").set(s.peak_conns as i64);
        r.gauge("optimes_daemon_total_conns").set(s.total_conns as i64);
        r.gauge("optimes_daemon_rejected_conns")
            .set(s.rejected_conns as i64);
        r.gauge("optimes_daemon_inflight").set(s.inflight as i64);
        r.gauge("optimes_daemon_peak_inflight")
            .set(s.peak_inflight as i64);
        r.gauge("optimes_daemon_rejected_requests")
            .set(s.rejected_requests as i64);
        r.gauge("optimes_daemon_handler_threads")
            .set(s.handler_threads as i64);
        r.gauge("optimes_daemon_tenants").set(s.tenants as i64);
        if let Ok(st) = self.tenants.base().stats() {
            r.gauge("optimes_store_nodes").set(st.nodes as i64);
            r.gauge("optimes_store_rows").set(st.rows as i64);
            r.gauge("optimes_store_failovers").set(st.failovers as i64);
            r.gauge("optimes_store_epoch").set(st.epoch as i64);
            r.gauge("optimes_store_bytes_tx").set(st.bytes_tx as i64);
            r.gauge("optimes_store_bytes_rx").set(st.bytes_rx as i64);
        }
        for name in self.tenants.names() {
            if let Ok(rows) = self
                .tenants
                .resolve(&name)
                .and_then(|t| t.stats())
                .map(|st| st.rows)
            {
                r.gauge(&format!("optimes_tenant_rows{{tenant=\"{name}\"}}"))
                    .set(rows as i64);
            }
        }
        r.render()
    }
}

/// RAII admission slot of one connection: acquired in the accept loop,
/// released (even on handler panic) when the handler finishes.
struct ConnSlot(Arc<DaemonShared>);

impl ConnSlot {
    fn acquire(shared: &Arc<DaemonShared>) -> Option<ConnSlot> {
        let max = shared.config.max_conns;
        let n = shared.live_conns.fetch_add(1, Ordering::SeqCst) + 1;
        if max > 0 && n > max {
            shared.live_conns.fetch_sub(1, Ordering::SeqCst);
            return None;
        }
        shared.peak_conns.fetch_max(n, Ordering::SeqCst);
        shared.total_conns.fetch_add(1, Ordering::SeqCst);
        Some(ConnSlot(Arc::clone(shared)))
    }
}

impl Drop for ConnSlot {
    fn drop(&mut self) {
        self.0.live_conns.fetch_sub(1, Ordering::SeqCst);
    }
}

/// RAII lease on the daemon-wide in-flight gauge: one per executing
/// data-plane request, bounded by `max_inflight`.
struct ReqSlot(Arc<DaemonShared>);

impl ReqSlot {
    fn acquire(shared: &Arc<DaemonShared>) -> Option<ReqSlot> {
        let max = shared.config.max_inflight;
        let n = shared.inflight.fetch_add(1, Ordering::SeqCst) + 1;
        if max > 0 && n > max {
            shared.inflight.fetch_sub(1, Ordering::SeqCst);
            return None;
        }
        shared.peak_inflight.fetch_max(n, Ordering::SeqCst);
        Some(ReqSlot(Arc::clone(shared)))
    }
}

impl Drop for ReqSlot {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Daemon serving an embedding store over TCP: accepts connections until
/// `stop` is raised, one service thread per client, with bounded
/// admission ([`DaemonConfig`]) and a finished-handler sweep every
/// accept iteration so connect/disconnect churn never accumulates dead
/// `JoinHandle`s (DESIGN.md §15).
pub struct EmbServerDaemon {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    shared: Arc<DaemonShared>,
}

impl EmbServerDaemon {
    /// Serve with no admission limits (the historical default).
    pub fn start(store: Arc<dyn EmbeddingStore>, bind: impl ToSocketAddrs) -> Result<Self> {
        Self::start_with(store, bind, DaemonConfig::default())
    }

    /// [`start`](Self::start) with admission-control limits.
    pub fn start_with(
        store: Arc<dyn EmbeddingStore>,
        bind: impl ToSocketAddrs,
        config: DaemonConfig,
    ) -> Result<Self> {
        let listener = TcpListener::bind(bind).context("bind")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let registry = obs::Registry::new();
        let rpc_pull_ns = registry.histogram("optimes_daemon_rpc_pull_ns");
        let rpc_push_ns = registry.histogram("optimes_daemon_rpc_push_ns");
        let shared = Arc::new(DaemonShared {
            config,
            live_conns: AtomicUsize::new(0),
            peak_conns: AtomicUsize::new(0),
            total_conns: AtomicUsize::new(0),
            rejected_conns: AtomicUsize::new(0),
            inflight: AtomicUsize::new(0),
            peak_inflight: AtomicUsize::new(0),
            rejected_requests: AtomicUsize::new(0),
            handler_threads: AtomicUsize::new(0),
            tenants: TenantRegistry::new(store),
            registry,
            rpc_pull_ns,
            rpc_push_ns,
        });
        let shared2 = Arc::clone(&shared);
        let accept_thread = std::thread::Builder::new()
            .name("emb-server-accept".into())
            .spawn(move || {
                let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    // reap finished handlers every iteration: without
                    // this sweep the handle list grows without bound
                    // under connect/disconnect churn (each handle pins
                    // its thread's stack until joined)
                    let mut live = Vec::with_capacity(conns.len());
                    for c in conns.drain(..) {
                        if c.is_finished() {
                            let _ = c.join();
                        } else {
                            live.push(c);
                        }
                    }
                    conns = live;
                    shared2.handler_threads.store(conns.len(), Ordering::SeqCst);
                    match listener.accept() {
                        Ok((stream, _)) => {
                            stream.set_nodelay(true).ok();
                            stream.set_nonblocking(false).ok();
                            // bounded reads so service threads can notice
                            // the stop flag even with idle clients attached
                            stream
                                .set_read_timeout(Some(std::time::Duration::from_millis(100)))
                                .ok();
                            let stop = Arc::clone(&stop2);
                            match ConnSlot::acquire(&shared2) {
                                Some(slot) => {
                                    let base = shared2.tenants.base();
                                    let shared = Arc::clone(&shared2);
                                    conns.push(std::thread::spawn(move || {
                                        let _slot = slot;
                                        let _ = serve_conn(base, &shared, stream, stop);
                                    }));
                                }
                                None => {
                                    // over the connection cap: a handler
                                    // still spawns (swept like any other)
                                    // but only to deliver the BUSY verdict
                                    shared2.rejected_conns.fetch_add(1, Ordering::SeqCst);
                                    conns.push(std::thread::spawn(move || {
                                        reject_conn(stream, &stop);
                                    }));
                                }
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
                shared2.handler_threads.store(0, Ordering::SeqCst);
            })?;
        Ok(Self {
            addr,
            stop,
            accept_thread: Some(accept_thread),
            shared,
        })
    }

    /// Admission-control limits this daemon runs under.
    pub fn config(&self) -> DaemonConfig {
        self.shared.config
    }

    /// Live service counters: connections, in-flight requests,
    /// rejections, handler threads, registered tenants.
    pub fn stats(&self) -> DaemonStats {
        self.shared.stats()
    }

    /// Prometheus-style text exposition of the daemon's metrics — the
    /// same text wire op=6 `STATSX` serves (DESIGN.md §16.2): service
    /// gauges, base-store occupancy/bytes, per-tenant rows, and the
    /// server-side RPC latency summaries.
    pub fn exposition(&self) -> String {
        self.shared.exposition()
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Discard inbound bytes until the peer closes (or a ~2 s deadline
/// passes). Used after writing a rejection byte: closing the socket
/// immediately with unread inbound data can send an RST, and TCP
/// discards undelivered outbound data on reset — the loud BUSY would
/// surface at the client as a silent connection error instead of a
/// named rejection.
fn drain_conn(stream: &TcpStream, stop: &AtomicBool) {
    stream
        .set_read_timeout(Some(std::time::Duration::from_millis(100)))
        .ok();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
    let mut sink = [0u8; 4096];
    let mut s = stream;
    loop {
        match s.read(&mut sink) {
            Ok(0) => return, // peer saw the verdict and hung up
            Ok(_) => {}      // discard whatever request was in flight
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {}
            Err(_) => return,
        }
        if stop.load(Ordering::Relaxed) || std::time::Instant::now() >= deadline {
            return;
        }
    }
}

/// Handler for a connection refused at the `max_conns` cap: one loud
/// [`STATUS_BUSY`] byte, then drain until the client has read it.
fn reject_conn(stream: TcpStream, stop: &AtomicBool) {
    if (&stream).write_all(&[STATUS_BUSY]).is_err() {
        return;
    }
    let _ = (&stream).flush();
    drain_conn(&stream, stop);
}

impl Drop for EmbServerDaemon {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Serve one client connection until EOF or daemon stop. `base` is the
/// daemon's root store; a TENANT handshake rebinds `store` to that
/// tenant's namespaced view for the rest of the connection.
fn serve_conn(
    base: Arc<dyn EmbeddingStore>,
    shared: &Arc<DaemonShared>,
    stream: TcpStream,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let mut store: Arc<dyn EmbeddingStore> = base;
    let mut r = std::io::BufReader::new(stream.try_clone()?);
    let mut w = std::io::BufWriter::new(stream.try_clone()?);
    // per-connection pull buffer: steady-state pulls allocate nothing
    let mut pull_buf: Vec<Vec<f32>> = Vec::new();
    // connection wire codec (raw until a CODEC handshake switches it)
    // plus reusable encode/decode scratch
    let mut wire_codec: Arc<dyn RowCodec> = CodecKind::Raw.build();
    let mut enc_buf: Vec<u8> = Vec::new();
    loop {
        let mut op = [0u8; 1];
        match r.read_exact(&mut op) {
            Ok(()) => {
                // a frame has started: switch to blocking reads for its body
                stream.set_read_timeout(None).ok();
            }
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        // shed data-plane work (pull/push) over the in-flight cap with
        // a loud BUSY; control ops (stats/statsx/codec/tenant) always
        // pass — a scrape must work precisely when the daemon is busy
        let _req = if matches!(op[0], OP_PULL | OP_PUSH) {
            match ReqSlot::acquire(shared) {
                Some(slot) => Some(slot),
                None => {
                    shared.rejected_requests.fetch_add(1, Ordering::SeqCst);
                    w.write_all(&[STATUS_BUSY])?;
                    w.flush()?;
                    drain_conn(&stream, &stop);
                    return Ok(());
                }
            }
        } else {
            None
        };
        match op[0] {
            OP_PULL => {
                let t0 = std::time::Instant::now();
                let mut sp = obs::span("net", "rpc_pull");
                let nodes = read_ids(&mut r)?;
                sp.push_attr("rows", nodes.len());
                store.pull_into(&nodes, false, &mut pull_buf)?;
                w.write_all(&[STATUS_OK])?;
                codec::write_u32(&mut w, pull_buf.len() as u32)?;
                codec::write_u32(&mut w, store.hidden() as u32)?;
                if wire_codec.is_identity() {
                    for rows in &pull_buf {
                        codec::write_f32s(&mut w, rows)?;
                    }
                } else {
                    for rows in &pull_buf {
                        wire_codec.encode_rows(rows, store.hidden(), &mut enc_buf);
                        w.write_all(&enc_buf).context("write encoded pull payload")?;
                    }
                }
                shared.rpc_pull_ns.record_secs(t0.elapsed().as_secs_f64());
            }
            OP_PUSH => {
                let t0 = std::time::Instant::now();
                let mut sp = obs::span("net", "rpc_push");
                let nodes = read_ids(&mut r)?;
                sp.push_attr("rows", nodes.len());
                let layers = codec::read_u32(&mut r)? as usize;
                if layers != store.n_layers() {
                    bail!("push layer count {layers} != {}", store.n_layers());
                }
                let h = store.hidden();
                let mut per_layer = Vec::with_capacity(layers);
                if wire_codec.is_identity() {
                    for _ in 0..layers {
                        per_layer.push(codec::read_f32s(&mut r, nodes.len() * h)?);
                    }
                } else {
                    // densify: the store always holds decoded f32 rows
                    let bpr = wire_codec.bytes_per_row(h);
                    for _ in 0..layers {
                        codec::read_bytes_into(&mut r, nodes.len() * bpr, &mut enc_buf)?;
                        let mut rows = Vec::new();
                        wire_codec.decode_rows(&enc_buf, nodes.len(), h, &mut rows)?;
                        per_layer.push(rows);
                    }
                }
                store.push(&nodes, &per_layer)?;
                w.write_all(&[STATUS_OK])?;
                shared.rpc_push_ns.record_secs(t0.elapsed().as_secs_f64());
            }
            OP_STATS => {
                let stats = store.stats()?;
                w.write_all(&[STATUS_OK])?;
                codec::write_u64(&mut w, stats.nodes as u64)?;
                codec::write_u64(&mut w, stats.rows as u64)?;
                codec::write_u64(&mut w, stats.failovers as u64)?;
                codec::write_u64(&mut w, stats.epoch)?;
                codec::write_u64(&mut w, stats.bytes_tx as u64)?;
                codec::write_u64(&mut w, stats.bytes_rx as u64)?;
                codec::write_u64(&mut w, stats.raw_tx as u64)?;
                codec::write_u64(&mut w, stats.raw_rx as u64)?;
            }
            OP_CODEC => {
                let len = codec::read_u32(&mut r)? as usize;
                if len > MAX_CODEC_NAME {
                    bail!("absurd codec name length {len}");
                }
                let mut name = vec![0u8; len];
                r.read_exact(&mut name).context("read codec name")?;
                let name = std::str::from_utf8(&name).context("codec name utf8")?;
                // a bad name drops the connection (the client surfaces
                // the failed handshake at connect time, not mid-round)
                wire_codec = CodecKind::parse(name)?.build();
                w.write_all(&[STATUS_OK])?;
            }
            OP_STATSX => {
                let text = shared.exposition();
                w.write_all(&[STATUS_OK])?;
                codec::write_u32(&mut w, text.len() as u32)?;
                w.write_all(text.as_bytes()).context("write exposition")?;
            }
            OP_TENANT => {
                let len = codec::read_u32(&mut r)? as usize;
                if len > MAX_TENANT_NAME {
                    bail!("absurd tenant name length {len}");
                }
                let mut name = vec![0u8; len];
                r.read_exact(&mut name).context("read tenant name")?;
                let name = std::str::from_utf8(&name).context("tenant name utf8")?;
                // rebind this connection to the tenant's namespaced
                // view; a bad name drops the connection (surfaced at
                // the client as a failed handshake)
                store = shared.tenants.resolve(name)?;
                w.write_all(&[STATUS_OK])?;
            }
            other => bail!("unknown op {other}"),
        }
        w.flush()?;
        stream
            .set_read_timeout(Some(std::time::Duration::from_millis(100)))
            .ok();
    }
}

/// One connection speaking the wire protocol. API mirrors the store
/// trait; RPC records carry the *measured* wall time (the network is
/// real here, no cost model).
pub struct RemoteEmbClient {
    r: std::io::BufReader<TcpStream>,
    w: std::io::BufWriter<TcpStream>,
    pub hidden: usize,
    pub n_layers: usize,
    /// Connection wire codec (negotiated at connect; raw by default).
    wire_codec: Arc<dyn RowCodec>,
    /// Reusable encode/decode scratch for non-raw codecs.
    enc_buf: Vec<u8>,
}

impl RemoteEmbClient {
    pub fn connect(addr: impl ToSocketAddrs, n_layers: usize, hidden: usize) -> Result<Self> {
        Self::connect_with_codec(addr, n_layers, hidden, &CodecKind::Raw)
    }

    /// Connect and negotiate `kind` as this connection's wire codec
    /// (the CODEC handshake is skipped for raw — byte-compatible with
    /// pre-codec daemons).
    pub fn connect_with_codec(
        addr: impl ToSocketAddrs,
        n_layers: usize,
        hidden: usize,
        kind: &CodecKind,
    ) -> Result<Self> {
        Self::connect_opts(addr, n_layers, hidden, kind, None)
    }

    /// [`connect_with_codec`](Self::connect_with_codec) plus an optional
    /// TENANT handshake binding this connection to a namespaced session
    /// on a shared daemon.
    pub fn connect_opts(
        addr: impl ToSocketAddrs,
        n_layers: usize,
        hidden: usize,
        kind: &CodecKind,
        tenant: Option<&str>,
    ) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_nodelay(true).ok();
        let mut client = Self {
            r: std::io::BufReader::new(stream.try_clone()?),
            w: std::io::BufWriter::new(stream),
            hidden,
            n_layers,
            wire_codec: kind.build(),
            enc_buf: Vec::new(),
        };
        if !client.wire_codec.is_identity() {
            client
                .negotiate()
                .with_context(|| format!("negotiating wire codec {}", kind.name()))?;
        }
        if let Some(t) = tenant {
            client
                .negotiate_tenant(t)
                .with_context(|| format!("negotiating tenant {t:?}"))?;
        }
        Ok(client)
    }

    /// Send the CODEC handshake for this connection's codec.
    fn negotiate(&mut self) -> Result<()> {
        let name = self.wire_codec.name();
        self.w.write_all(&[OP_CODEC])?;
        codec::write_u32(&mut self.w, name.len() as u32)?;
        self.w.write_all(name.as_bytes())?;
        self.w.flush()?;
        self.check_status()
    }

    /// Send the TENANT handshake binding the connection to a namespace.
    fn negotiate_tenant(&mut self, name: &str) -> Result<()> {
        super::tenant::validate_tenant_name(name)?;
        self.w.write_all(&[OP_TENANT])?;
        codec::write_u32(&mut self.w, name.len() as u32)?;
        self.w.write_all(name.as_bytes())?;
        self.w.flush()?;
        self.check_status()
    }

    /// Encoded payload bytes per row under this connection's codec.
    pub fn bytes_per_row(&self) -> usize {
        self.wire_codec.bytes_per_row(self.hidden)
    }

    fn check_status(&mut self) -> Result<()> {
        let mut st = [0u8; 1];
        self.r.read_exact(&mut st)?;
        match st[0] {
            STATUS_OK => Ok(()),
            STATUS_BUSY => bail!(
                "server BUSY: connection or request rejected by admission control \
                 (raise --max-conns/--max-inflight or retry later)"
            ),
            other => bail!("server error status {other}"),
        }
    }

    /// Batched pull of all layers for `nodes` into a caller buffer.
    pub fn pull_into(
        &mut self,
        nodes: &[u32],
        on_demand: bool,
        out: &mut Vec<Vec<f32>>,
    ) -> Result<RpcRecord> {
        let t0 = std::time::Instant::now();
        self.w.write_all(&[OP_PULL])?;
        codec::write_u32(&mut self.w, nodes.len() as u32)?;
        codec::write_u32s(&mut self.w, nodes)?;
        self.w.flush()?;
        self.check_status()?;
        let layers = codec::read_u32(&mut self.r)? as usize;
        let hidden = codec::read_u32(&mut self.r)? as usize;
        if layers != self.n_layers {
            bail!("server layer count {layers} != client {}", self.n_layers);
        }
        if hidden != self.hidden {
            bail!("server hidden {hidden} != client {}", self.hidden);
        }
        out.truncate(layers);
        out.resize_with(layers, Vec::new);
        if self.wire_codec.is_identity() {
            for rows in out.iter_mut() {
                codec::read_f32s_into(&mut self.r, nodes.len() * hidden, rows)?;
            }
        } else {
            let bpr = self.wire_codec.bytes_per_row(hidden);
            for rows in out.iter_mut() {
                codec::read_bytes_into(&mut self.r, nodes.len() * bpr, &mut self.enc_buf)?;
                self.wire_codec.decode_rows(&self.enc_buf, nodes.len(), hidden, rows)?;
            }
        }
        let payload = nodes.len() * layers * (self.bytes_per_row() + 4);
        Ok(RpcRecord {
            kind: if on_demand {
                RpcKind::PullOnDemand
            } else {
                RpcKind::Pull
            },
            rows: nodes.len(),
            bytes: payload,
            time: t0.elapsed().as_secs_f64(),
        })
    }

    /// Allocating wrapper over [`RemoteEmbClient::pull_into`].
    pub fn pull(&mut self, nodes: &[u32]) -> Result<(Vec<Vec<f32>>, RpcRecord)> {
        let mut out = Vec::new();
        let rec = self.pull_into(nodes, false, &mut out)?;
        Ok((out, rec))
    }

    pub fn push(&mut self, nodes: &[u32], per_layer: &[Vec<f32>]) -> Result<RpcRecord> {
        let t0 = std::time::Instant::now();
        self.w.write_all(&[OP_PUSH])?;
        codec::write_u32(&mut self.w, nodes.len() as u32)?;
        codec::write_u32s(&mut self.w, nodes)?;
        codec::write_u32(&mut self.w, per_layer.len() as u32)?;
        if self.wire_codec.is_identity() {
            for rows in per_layer {
                codec::write_f32s(&mut self.w, rows)?;
            }
        } else {
            for rows in per_layer {
                self.wire_codec.encode_rows(rows, self.hidden, &mut self.enc_buf);
                self.w.write_all(&self.enc_buf).context("write encoded push payload")?;
            }
        }
        self.w.flush()?;
        self.check_status()?;
        let payload = nodes.len() * per_layer.len() * (self.bytes_per_row() + 4);
        Ok(RpcRecord {
            kind: RpcKind::Push,
            rows: nodes.len(),
            bytes: payload,
            time: t0.elapsed().as_secs_f64(),
        })
    }

    /// Scrape the daemon's metrics exposition (wire op=6 `STATSX`):
    /// Prometheus-style text, parseable with
    /// [`obs::parse_exposition`](crate::obs::parse_exposition). Works on
    /// any connection — geometry is irrelevant, so a stats-only client
    /// may connect with zero layers/hidden.
    pub fn statsx(&mut self) -> Result<String> {
        self.w.write_all(&[OP_STATSX])?;
        self.w.flush()?;
        self.check_status()?;
        let len = codec::read_u32(&mut self.r)? as usize;
        if len > MAX_EXPOSITION {
            bail!("absurd exposition length {len}");
        }
        let mut buf = vec![0u8; len];
        self.r.read_exact(&mut buf).context("read exposition")?;
        String::from_utf8(buf).context("exposition utf8")
    }

    /// Full remote [`StoreStats`] (occupancy + failovers + routing
    /// epoch) — so a daemon fronting a replicated sharded compound
    /// reports its resilience health over the wire.
    pub fn stats(&mut self) -> Result<StoreStats> {
        self.w.write_all(&[OP_STATS])?;
        self.w.flush()?;
        self.check_status()?;
        Ok(StoreStats {
            nodes: codec::read_u64(&mut self.r)? as usize,
            rows: codec::read_u64(&mut self.r)? as usize,
            failovers: codec::read_u64(&mut self.r)? as usize,
            epoch: codec::read_u64(&mut self.r)?,
            bytes_tx: codec::read_u64(&mut self.r)? as usize,
            bytes_rx: codec::read_u64(&mut self.r)? as usize,
            raw_tx: codec::read_u64(&mut self.r)? as usize,
            raw_rx: codec::read_u64(&mut self.r)? as usize,
        })
    }
}

/// [`EmbeddingStore`] backend speaking the wire protocol against a
/// remote daemon (e.g. a standalone `optimes serve` process).
///
/// Connections are pooled with a **per-connection in-flight request
/// slot**: the wire protocol is strictly request→response per socket, so
/// each RPC leases a whole connection for its duration (checked out of
/// the pool, returned afterwards) and concurrent callers — parallel
/// clients, the async pipeline's push/prefetch workers — each get their
/// own socket instead of serializing or interleaving frames on one. The
/// pool therefore grows to the peak number of *simultaneous* RPCs and no
/// further; [`in_flight`](TcpEmbeddingStore::in_flight) /
/// [`peak_in_flight`](TcpEmbeddingStore::peak_in_flight) expose the
/// gauge.
///
/// A failed RPC drops its connection and retries exactly once on a fresh
/// one; every op is an idempotent upsert/read, so re-sending is safe.
/// Caveat: if the daemon itself restarted (state lost), a retried *pull*
/// succeeds against the now-empty store and returns the contractual zero
/// rows — the session keeps running on a cold store rather than failing.
/// Restart the session too if the daemon's lifetime doesn't cover it.
pub struct TcpEmbeddingStore {
    addr: String,
    n_layers: usize,
    hidden: usize,
    /// Wire codec every pooled connection negotiates at open
    /// (DESIGN.md §11).
    codec_kind: CodecKind,
    /// Cached `bytes_per_row(hidden)` of the negotiated codec.
    codec_bpr: usize,
    /// Tenant namespace every pooled connection binds to at open
    /// (DESIGN.md §15); `None` = the daemon's root namespace.
    tenant: Option<String>,
    pool: Mutex<Vec<RemoteEmbClient>>,
    /// Encoded payload bytes this client wrote / read on the wire.
    /// These *replace* whatever the remote daemon's own store metered
    /// in [`stats`](EmbeddingStore::stats) — the socket is the wire
    /// boundary, and the daemon's numbers describe its far side.
    bytes_tx: AtomicUsize,
    bytes_rx: AtomicUsize,
    raw_tx: AtomicUsize,
    raw_rx: AtomicUsize,
    /// RPCs currently holding a connection lease.
    in_flight: AtomicUsize,
    /// Highest simultaneous lease count observed (== pool high-water
    /// mark: one socket per in-flight request).
    peak_in_flight: AtomicUsize,
    /// Reconnect-and-retry events (the transport's failover analogue;
    /// surfaced in [`StoreStats::failovers`] alongside any failovers the
    /// remote store itself reports).
    retries: AtomicUsize,
}

/// RAII lease on the store's in-flight gauge: constructed when an RPC
/// checks a connection out ([`TcpEmbeddingStore::enter_slot`]), released
/// (even on error/panic unwind) when the RPC finishes.
struct InFlightSlot<'a>(&'a TcpEmbeddingStore);

impl Drop for InFlightSlot<'_> {
    fn drop(&mut self) {
        self.0.in_flight.fetch_sub(1, Ordering::SeqCst);
    }
}

impl TcpEmbeddingStore {
    /// Connect to `addr` ("host:port"). The first connection is opened
    /// eagerly and an empty pull is exchanged as a geometry handshake, so
    /// a wrong address *or* a server with a different layer count/hidden
    /// width fails here (session build time), not mid-round.
    pub fn connect(addr: impl Into<String>, n_layers: usize, hidden: usize) -> Result<Self> {
        Self::connect_with_codec(addr, n_layers, hidden, CodecKind::Raw)
    }

    /// [`connect`](Self::connect) with a negotiated wire codec: every
    /// pooled connection (including reconnects) performs the CODEC
    /// handshake at open, so an unsupported codec fails here rather
    /// than mid-round.
    pub fn connect_with_codec(
        addr: impl Into<String>,
        n_layers: usize,
        hidden: usize,
        codec_kind: CodecKind,
    ) -> Result<Self> {
        Self::connect_opts(addr, n_layers, hidden, codec_kind, None)
    }

    /// [`connect_with_codec`](Self::connect_with_codec) plus an optional
    /// tenant namespace: every pooled connection (including reconnects)
    /// performs the TENANT handshake at open, so a bad name fails here
    /// rather than mid-round.
    pub fn connect_opts(
        addr: impl Into<String>,
        n_layers: usize,
        hidden: usize,
        codec_kind: CodecKind,
        tenant: Option<String>,
    ) -> Result<Self> {
        let codec_bpr = codec_kind.build().bytes_per_row(hidden);
        let store = Self {
            addr: addr.into(),
            n_layers,
            hidden,
            codec_kind,
            codec_bpr,
            tenant,
            pool: Mutex::new(Vec::new()),
            bytes_tx: AtomicUsize::new(0),
            bytes_rx: AtomicUsize::new(0),
            raw_tx: AtomicUsize::new(0),
            raw_rx: AtomicUsize::new(0),
            in_flight: AtomicUsize::new(0),
            peak_in_flight: AtomicUsize::new(0),
            retries: AtomicUsize::new(0),
        };
        let mut conn = store.open()?;
        let mut probe = Vec::new();
        conn.pull_into(&[], false, &mut probe)
            .with_context(|| format!("geometry handshake with {}", store.addr))?;
        store.pool_guard().push(conn);
        Ok(store)
    }

    /// Encoded payload bytes pushed / pulled over this store's sockets.
    pub fn wire_bytes(&self) -> (usize, usize) {
        (
            self.bytes_tx.load(Ordering::SeqCst),
            self.bytes_rx.load(Ordering::SeqCst),
        )
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// RPCs currently in flight (each holds one pooled connection).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Peak simultaneous in-flight RPCs over the store's lifetime — the
    /// connection pool's high-water mark.
    pub fn peak_in_flight(&self) -> usize {
        self.peak_in_flight.load(Ordering::SeqCst)
    }

    /// Reconnect-and-retry events absorbed so far.
    pub fn retries(&self) -> usize {
        self.retries.load(Ordering::SeqCst)
    }

    /// Scrape the remote daemon's metrics exposition (wire op=6).
    pub fn exposition(&self) -> Result<String> {
        self.with_conn(|c| c.statsx())
    }

    /// Acquire the in-flight slot for one RPC (RAII; see
    /// [`InFlightSlot`]).
    fn enter_slot(&self) -> InFlightSlot<'_> {
        let d = self.in_flight.fetch_add(1, Ordering::SeqCst) + 1;
        self.peak_in_flight.fetch_max(d, Ordering::SeqCst);
        InFlightSlot(self)
    }

    fn open(&self) -> Result<RemoteEmbClient> {
        RemoteEmbClient::connect_opts(
            self.addr.as_str(),
            self.n_layers,
            self.hidden,
            &self.codec_kind,
            self.tenant.as_deref(),
        )
        .with_context(|| format!("embedding store at {}", self.addr))
    }

    /// Lock the connection pool, recovering from poison: a panic in one
    /// worker mid-RPC must not cascade panics through every subsequent
    /// push/pull on other workers. Pooled connections from a poisoned
    /// pool may be mid-frame, so they are dropped — the next RPC opens
    /// fresh sockets (counted under `retries` only when an RPC actually
    /// retried; the clear itself is silent and safe).
    fn pool_guard(&self) -> std::sync::MutexGuard<'_, Vec<RemoteEmbClient>> {
        match self.pool.lock() {
            Ok(guard) => guard,
            Err(poisoned) => {
                self.pool.clear_poison();
                let mut guard = poisoned.into_inner();
                guard.clear();
                guard
            }
        }
    }

    /// Run `f` on a pooled connection; on failure, reconnect and retry
    /// once (a pooled connection may be stale after a daemon restart).
    /// If the retry fails too, the error chain names both failures, so a
    /// deterministic server-side rejection is not mistaken for a
    /// transport problem. The whole call holds one [`InFlightSlot`]: a
    /// connection serves exactly one request at a time.
    fn with_conn<R>(&self, mut f: impl FnMut(&mut RemoteEmbClient) -> Result<R>) -> Result<R> {
        let _slot = self.enter_slot();
        let pooled = self.pool_guard().pop();
        if let Some(mut conn) = pooled {
            match f(&mut conn) {
                Ok(r) => {
                    self.pool_guard().push(conn);
                    return Ok(r);
                }
                Err(first) => {
                    // drop the (possibly stale) connection, retry fresh
                    drop(conn);
                    self.retries.fetch_add(1, Ordering::SeqCst);
                    let mut fresh = self
                        .open()
                        .with_context(|| format!("reconnect after RPC failure ({first:#})"))?;
                    let r = f(&mut fresh)
                        .with_context(|| format!("retried after RPC failure ({first:#})"))?;
                    self.pool_guard().push(fresh);
                    return Ok(r);
                }
            }
        }
        let mut fresh = self.open()?;
        let r = f(&mut fresh)?;
        self.pool_guard().push(fresh);
        Ok(r)
    }
}

impl EmbeddingStore for TcpEmbeddingStore {
    fn n_layers(&self) -> usize {
        self.n_layers
    }

    fn hidden(&self) -> usize {
        self.hidden
    }

    fn push(&self, nodes: &[u32], per_layer: &[Vec<f32>]) -> Result<RpcRecord> {
        let rec = self.with_conn(|c| c.push(nodes, per_layer))?;
        self.bytes_tx
            .fetch_add(nodes.len() * per_layer.len() * self.codec_bpr, Ordering::SeqCst);
        self.raw_tx
            .fetch_add(nodes.len() * per_layer.len() * self.hidden * 4, Ordering::SeqCst);
        Ok(rec)
    }

    fn pull_into(
        &self,
        nodes: &[u32],
        on_demand: bool,
        out: &mut Vec<Vec<f32>>,
    ) -> Result<RpcRecord> {
        let rec = self.with_conn(|c| c.pull_into(nodes, on_demand, out))?;
        self.bytes_rx
            .fetch_add(nodes.len() * self.n_layers * self.codec_bpr, Ordering::SeqCst);
        self.raw_rx
            .fetch_add(nodes.len() * self.n_layers * self.hidden * 4, Ordering::SeqCst);
        Ok(rec)
    }

    fn stats(&self) -> Result<StoreStats> {
        let mut stats = self.with_conn(|c| c.stats())?;
        // the transport's own failovers ride along with the remote ones
        stats.failovers += self.retries.load(Ordering::SeqCst);
        // this socket is the wire boundary: report what *we* moved, not
        // what the daemon's store metered on its far side
        stats.bytes_tx = self.bytes_tx.load(Ordering::SeqCst);
        stats.bytes_rx = self.bytes_rx.load(Ordering::SeqCst);
        stats.raw_tx = self.raw_tx.load(Ordering::SeqCst);
        stats.raw_rx = self.raw_rx.load(Ordering::SeqCst);
        Ok(stats)
    }

    fn codec(&self) -> String {
        self.codec_kind.name()
    }

    fn describe(&self) -> String {
        let base = if self.codec_kind.is_raw() {
            format!("tcp({})", self.addr)
        } else {
            format!("tcp({}, {})", self.addr, self.codec_kind.name())
        };
        match &self.tenant {
            Some(t) => format!("tenant({t} over {base})"),
            None => base,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::embedding_server::EmbeddingServer;
    use crate::coordinator::netsim::NetConfig;

    fn daemon() -> (EmbServerDaemon, Arc<EmbeddingServer>) {
        let server = Arc::new(EmbeddingServer::new(2, 4, NetConfig::default()));
        let store = Arc::clone(&server) as Arc<dyn EmbeddingStore>;
        let d = EmbServerDaemon::start(store, "127.0.0.1:0").unwrap();
        (d, server)
    }

    fn rows(nodes: &[u32], h: usize, salt: f32) -> Vec<f32> {
        nodes
            .iter()
            .flat_map(|&n| (0..h).map(move |j| n as f32 + j as f32 * 0.25 + salt))
            .collect()
    }

    #[test]
    fn tcp_roundtrip_push_pull_stats() {
        let (d, _server) = daemon();
        let mut c = RemoteEmbClient::connect(d.addr, 2, 4).unwrap();
        let nodes = [5u32, 9, 1000];
        let l1 = rows(&nodes, 4, 0.0);
        let l2 = rows(&nodes, 4, 7.0);
        let rec = c.push(&nodes, &[l1.clone(), l2.clone()]).unwrap();
        assert_eq!(rec.rows, 3);
        let (got, rec) = c.pull(&[9, 5]).unwrap();
        assert_eq!(rec.kind, RpcKind::Pull);
        assert_eq!(&got[0][0..4], &l1[4..8]);
        assert_eq!(&got[0][4..8], &l1[0..4]);
        assert_eq!(&got[1][0..4], &l2[4..8]);
        let s = c.stats().unwrap();
        assert_eq!((s.nodes, s.rows), (3, 6));
        assert_eq!((s.failovers, s.epoch), (0, 0));
        d.shutdown();
    }

    #[test]
    fn tcp_missing_nodes_are_zero() {
        let (d, _server) = daemon();
        let mut c = RemoteEmbClient::connect(d.addr, 2, 4).unwrap();
        let (got, _) = c.pull(&[424242]).unwrap();
        assert!(got[0].iter().all(|&v| v == 0.0));
        d.shutdown();
    }

    #[test]
    fn tcp_concurrent_clients() {
        let (d, server) = daemon();
        let addr = d.addr;
        let mut handles = Vec::new();
        for t in 0..4u32 {
            handles.push(std::thread::spawn(move || {
                let mut c = RemoteEmbClient::connect(addr, 2, 4).unwrap();
                let nodes: Vec<u32> = (t * 1000..t * 1000 + 200).collect();
                for round in 0..10 {
                    let l = rows(&nodes, 4, round as f32);
                    c.push(&nodes, &[l.clone(), l.clone()]).unwrap();
                    let (got, _) = c.pull(&nodes).unwrap();
                    assert_eq!(got[0], l);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.stored_nodes(), 800);
        d.shutdown();
    }

    #[test]
    fn tcp_large_batch() {
        let (d, _server) = daemon();
        let mut c = RemoteEmbClient::connect(d.addr, 2, 4).unwrap();
        let nodes: Vec<u32> = (0..50_000).collect();
        let l = rows(&nodes, 4, 0.5);
        let rec = c.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        assert!(rec.bytes > 1_000_000);
        let (got, rec2) = c.pull(&nodes).unwrap();
        assert_eq!(got[0], l);
        assert!(rec2.time > 0.0);
        d.shutdown();
    }

    #[test]
    fn push_layer_mismatch_closes_cleanly() {
        let (d, _server) = daemon();
        let mut c = RemoteEmbClient::connect(d.addr, 3, 4).unwrap();
        let nodes = [1u32];
        // client claims 3 layers; server has 2 -> connection drops, the
        // next call errors rather than hanging
        let res = c
            .push(&nodes, &[vec![0.0; 4], vec![0.0; 4], vec![0.0; 4]])
            .and_then(|_| c.stats().map(|_| ()));
        assert!(res.is_err());
        d.shutdown();
    }

    #[test]
    fn tcp_store_connect_rejects_geometry_mismatch() {
        let (d, _server) = daemon(); // 2 layer DBs, hidden 4
        let err = TcpEmbeddingStore::connect(d.addr.to_string(), 3, 4)
            .err()
            .expect("layer mismatch must fail at connect");
        assert!(format!("{err:#}").contains("layer count"), "{err:#}");
        assert!(TcpEmbeddingStore::connect(d.addr.to_string(), 2, 8).is_err());
        assert!(TcpEmbeddingStore::connect(d.addr.to_string(), 2, 4).is_ok());
        d.shutdown();
    }

    #[test]
    fn daemon_serves_a_sharded_store() {
        // the daemon is store-agnostic: front a 3-shard compound with TCP
        let sharded: Arc<dyn EmbeddingStore> = Arc::new(
            crate::coordinator::store::ShardedStore::in_process(3, 2, 4, NetConfig::default()),
        );
        let d = EmbServerDaemon::start(Arc::clone(&sharded), "127.0.0.1:0").unwrap();
        let tcp = TcpEmbeddingStore::connect(d.addr.to_string(), 2, 4).unwrap();
        let nodes: Vec<u32> = (0..100).collect();
        let l = rows(&nodes, 4, 2.0);
        tcp.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        let (got, _) = tcp.pull(&nodes, false).unwrap();
        assert_eq!(got[0], l);
        let s = tcp.stats().unwrap();
        assert_eq!((s.nodes, s.rows, s.failovers, s.epoch), (100, 200, 0, 0));
        // wire meters: raw codec, 100 rows x 2 layers x 4 f32 each way
        assert_eq!((s.bytes_tx, s.bytes_rx), (100 * 2 * 16, 100 * 2 * 16));
        assert_eq!((s.raw_tx, s.raw_rx), (s.bytes_tx, s.bytes_rx));
        d.shutdown();
    }

    #[test]
    fn negotiated_codec_shapes_values_and_meters_fewer_bytes() {
        use crate::wire::CodecKind;
        let (d, server) = daemon(); // 2 layers, hidden 4
        let tcp =
            TcpEmbeddingStore::connect_with_codec(d.addr.to_string(), 2, 4, CodecKind::F16)
                .unwrap();
        assert_eq!(tcp.codec(), "f16");
        assert!(tcp.describe().contains("f16"), "{}", tcp.describe());
        let nodes = [1u32, 2, 3];
        // values exactly representable in f16 round-trip bit-perfectly
        let exact = vec![1.5f32, -2.0, 0.25, 8.0, 0.5, -1.0, 4.0, 0.0, 1.0, 2.0, 3.0, -0.5];
        tcp.push(&nodes, &[exact.clone(), exact.clone()]).unwrap();
        let (got, rec) = tcp.pull(&nodes, false).unwrap();
        assert_eq!(got[0], exact);
        // 2 B/element on the wire: record + meters both see it
        assert_eq!(rec.bytes, 3 * 2 * (4 * 2 + 4));
        let (wtx, wrx) = tcp.wire_bytes();
        assert_eq!((wtx, wrx), (3 * 2 * 8, 3 * 2 * 8));
        let s = tcp.stats().unwrap();
        assert_eq!((s.bytes_tx, s.raw_tx), (3 * 2 * 8, 3 * 2 * 16));
        assert!(s.compression_ratio() > 1.9);
        // the daemon stored *decoded* rows: a raw connection to the same
        // server reads the same values
        let mut raw = RemoteEmbClient::connect(d.addr, 2, 4).unwrap();
        let (via_raw, _) = raw.pull(&nodes).unwrap();
        assert_eq!(via_raw[0], exact);
        assert_eq!(server.stored_nodes(), 3);
        d.shutdown();
    }

    #[test]
    fn negotiated_codec_survives_reconnect() {
        use crate::wire::CodecKind;
        let (d, server) = daemon();
        let tcp =
            TcpEmbeddingStore::connect_with_codec(d.addr.to_string(), 2, 4, CodecKind::Int8)
                .unwrap();
        let nodes = [9u32];
        let l = rows(&nodes, 4, 0.0);
        tcp.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        // restart the daemon: the fresh pooled connection must
        // re-negotiate int8 before the retried RPC
        let addr = d.addr;
        d.shutdown();
        let mut d2 = None;
        for _ in 0..50 {
            match EmbServerDaemon::start(Arc::clone(&server) as Arc<dyn EmbeddingStore>, addr) {
                Ok(daemon) => {
                    d2 = Some(daemon);
                    break;
                }
                Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
            }
        }
        let d2 = d2.expect("rebind daemon address");
        let (got, _) = tcp.pull(&nodes, false).expect("reconnect with codec");
        for (a, b) in l.iter().zip(&got[0]) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
        assert!(tcp.retries() >= 1);
        d2.shutdown();
    }

    #[test]
    fn tcp_store_pools_and_reconnects() {
        let (d, server) = daemon();
        let tcp = TcpEmbeddingStore::connect(d.addr.to_string(), 2, 4).unwrap();
        let nodes = [1u32, 2, 3];
        let l = rows(&nodes, 4, 0.0);
        tcp.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        assert_eq!(tcp.stats().unwrap().nodes, 3);
        // restart the daemon on the same address: the pooled connection
        // goes stale and the next RPC must transparently reconnect
        let addr = d.addr;
        d.shutdown();
        let mut d2 = None;
        for _ in 0..50 {
            match EmbServerDaemon::start(Arc::clone(&server) as Arc<dyn EmbeddingStore>, addr) {
                Ok(daemon) => {
                    d2 = Some(daemon);
                    break;
                }
                Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
            }
        }
        let d2 = d2.expect("rebind daemon address");
        let stats = tcp.stats().expect("reconnect after daemon restart");
        assert_eq!(stats.nodes, 3);
        // the transparent reconnect is visible as a failover, both on
        // the store's own gauge and in the stats it reports
        assert!(tcp.retries() >= 1, "reconnect not counted");
        assert!(stats.failovers >= 1, "retry missing from stats");
        let (got, _) = tcp.pull(&nodes, false).unwrap();
        assert_eq!(got[0], l);
        d2.shutdown();
    }

    #[test]
    fn tcp_store_parallel_callers_use_distinct_connections() {
        let (d, server) = daemon();
        let tcp = Arc::new(TcpEmbeddingStore::connect(d.addr.to_string(), 2, 4).unwrap());
        let mut handles = Vec::new();
        for t in 0..4u32 {
            let tcp = Arc::clone(&tcp);
            handles.push(std::thread::spawn(move || {
                let nodes: Vec<u32> = (t * 500..t * 500 + 100).collect();
                let mut buf = Vec::new();
                for round in 0..5 {
                    let l = rows(&nodes, 4, round as f32);
                    tcp.push(&nodes, &[l.clone(), l.clone()]).unwrap();
                    tcp.pull_into(&nodes, false, &mut buf).unwrap();
                    assert_eq!(buf[0], l);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.stored_nodes(), 400);
        // every lease was returned; the gauge saw at least one RPC and
        // never exceeded the number of concurrent callers
        assert_eq!(tcp.in_flight(), 0);
        assert!(tcp.peak_in_flight() >= 1);
        assert!(tcp.peak_in_flight() <= 4);
        d.shutdown();
    }

    #[test]
    fn in_flight_slot_counts_a_single_rpc() {
        let (d, _server) = daemon();
        let tcp = TcpEmbeddingStore::connect(d.addr.to_string(), 2, 4).unwrap();
        assert_eq!(tcp.in_flight(), 0);
        tcp.push(&[1], &[vec![0.0; 4], vec![0.0; 4]]).unwrap();
        assert_eq!(tcp.in_flight(), 0, "lease leaked after a completed RPC");
        assert!(tcp.peak_in_flight() >= 1);
        d.shutdown();
    }

    #[test]
    fn accept_loop_reaps_finished_handlers() {
        let (d, _server) = daemon();
        // churn: 50 connect/use/disconnect cycles, strictly sequential
        for i in 0..50u32 {
            let mut c = RemoteEmbClient::connect(d.addr, 2, 4).unwrap();
            c.push(&[i], &[rows(&[i], 4, 0.0), rows(&[i], 4, 1.0)]).unwrap();
            drop(c);
        }
        // the sweep runs on the accept thread: give it a few iterations
        // to notice the hangups, then both gauges must hit zero
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let s = d.stats();
            if s.live_conns == 0 && s.handler_threads == 0 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "handler threads never reaped: {:?}",
                d.stats()
            );
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        let s = d.stats();
        assert_eq!(s.total_conns, 50);
        assert_eq!(s.rejected_conns, 0);
        assert!(s.peak_conns >= 1);
        d.shutdown();
    }

    #[test]
    fn over_cap_connection_gets_a_named_busy_error() {
        let server = Arc::new(EmbeddingServer::new(2, 4, NetConfig::default()));
        let d = EmbServerDaemon::start_with(
            Arc::clone(&server) as Arc<dyn EmbeddingStore>,
            "127.0.0.1:0",
            DaemonConfig {
                max_conns: 1,
                max_inflight: 0,
            },
        )
        .unwrap();
        // first client occupies the only slot (stats proves it's live)
        let mut held = RemoteEmbClient::connect(d.addr, 2, 4).unwrap();
        held.stats().unwrap();
        // second client must get a loud BUSY, not a hang or a bare I/O
        // error — poll briefly: the accept thread admits asynchronously
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        let err = loop {
            let mut probe = RemoteEmbClient::connect(d.addr, 2, 4).unwrap();
            match probe.stats() {
                Err(e) => break e,
                Ok(_) => {
                    // raced the slot (held conn not yet counted): retry
                    assert!(
                        std::time::Instant::now() < deadline,
                        "over-cap connection was never rejected"
                    );
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
            }
        };
        assert!(format!("{err:#}").contains("BUSY"), "{err:#}");
        assert!(d.stats().rejected_conns >= 1, "{:?}", d.stats());
        // the held connection keeps working at full service
        held.push(&[7], &[rows(&[7], 4, 0.0), rows(&[7], 4, 1.0)]).unwrap();
        let (got, _) = held.pull(&[7]).unwrap();
        assert_eq!(got[0], rows(&[7], 4, 0.0));
        d.shutdown();
    }

    #[test]
    fn statsx_exposition_scrapes_over_the_wire() {
        let (d, _server) = daemon();
        let mut c = RemoteEmbClient::connect(d.addr, 2, 4).unwrap();
        let nodes = [1u32, 2];
        c.push(&nodes, &[rows(&nodes, 4, 0.0), rows(&nodes, 4, 1.0)]).unwrap();
        c.pull(&nodes).unwrap();
        let text = c.statsx().unwrap();
        let parsed = crate::obs::parse_exposition(&text);
        assert_eq!(parsed["optimes_store_nodes"], 2.0);
        assert_eq!(parsed["optimes_daemon_rpc_pull_ns_count"], 1.0);
        assert_eq!(parsed["optimes_daemon_rpc_push_ns_count"], 1.0);
        assert!(parsed["optimes_daemon_rpc_pull_ns{quantile=\"0.99\"}"] > 0.0);
        assert!(parsed["optimes_daemon_live_conns"] >= 1.0);
        // the wire text matches the in-process render (modulo gauges
        // that move between scrapes; spot-check a histogram count)
        let local = crate::obs::parse_exposition(&d.exposition());
        assert_eq!(local["optimes_daemon_rpc_push_ns_count"], 1.0);
        // geometry-blind stats-only client works too
        let mut probe = RemoteEmbClient::connect(d.addr, 0, 0).unwrap();
        assert!(probe.statsx().unwrap().contains("optimes_daemon_rpc_pull_ns"));
        d.shutdown();
    }

    #[test]
    fn statsx_reports_per_tenant_rows() {
        let (d, _server) = daemon();
        let addr = d.addr.to_string();
        let alice = tenant_store(&addr, "alice");
        alice
            .push(&[1, 2, 3], &[rows(&[1, 2, 3], 4, 0.0), rows(&[1, 2, 3], 4, 1.0)])
            .unwrap();
        let parsed = crate::obs::parse_exposition(&alice.exposition().unwrap());
        assert_eq!(parsed["optimes_tenant_rows{tenant=\"alice\"}"], 6.0);
        assert_eq!(parsed["optimes_daemon_tenants"], 1.0);
        d.shutdown();
    }

    fn tenant_store(addr: &str, t: &str) -> TcpEmbeddingStore {
        let tenant = Some(t.to_string());
        TcpEmbeddingStore::connect_opts(addr.to_string(), 2, 4, CodecKind::Raw, tenant).unwrap()
    }

    #[test]
    fn tenant_handshake_isolates_sessions_on_one_daemon() {
        let (d, server) = daemon();
        let addr = d.addr.to_string();
        let alice = tenant_store(&addr, "alice");
        let bob = tenant_store(&addr, "bob");
        let nodes = [1u32, 2, 3];
        let la = rows(&nodes, 4, 10.0);
        let lb = rows(&nodes, 4, 20.0);
        alice.push(&nodes, &[la.clone(), la.clone()]).unwrap();
        bob.push(&nodes, &[lb.clone(), lb.clone()]).unwrap();
        // same ids, different values per tenant
        let mut buf = Vec::new();
        alice.pull_into(&nodes, false, &mut buf).unwrap();
        assert_eq!(buf[0], la);
        bob.pull_into(&nodes, false, &mut buf).unwrap();
        assert_eq!(buf[0], lb);
        // per-tenant stats are isolated
        assert_eq!(alice.stats().unwrap().nodes, 3);
        assert_eq!(bob.stats().unwrap().nodes, 3);
        // an untenanted connection sees the root namespace: the tenant
        // rows live at tagged ids, so ids 1..=3 are still zero there
        let root = TcpEmbeddingStore::connect(addr, 2, 4).unwrap();
        root.pull_into(&nodes, false, &mut buf).unwrap();
        assert!(buf[0].iter().all(|&v| v == 0.0));
        assert_eq!(d.stats().tenants, 2);
        assert_eq!(server.stored_nodes(), 6);
        assert!(alice.describe().starts_with("tenant(alice over tcp("));
        d.shutdown();
    }

    #[test]
    fn pool_lock_poison_recovers_instead_of_cascading() {
        let (d, _server) = daemon();
        let tcp = Arc::new(TcpEmbeddingStore::connect(d.addr.to_string(), 2, 4).unwrap());
        // poison the pool mutex: a worker panics while holding the lock
        let t2 = Arc::clone(&tcp);
        let _ = std::thread::spawn(move || {
            let _guard = t2.pool.lock().unwrap();
            panic!("worker dies holding the pool lock");
        })
        .join();
        // subsequent RPCs must succeed instead of cascading the panic
        tcp.push(&[3], &[rows(&[3], 4, 0.0), rows(&[3], 4, 1.0)]).unwrap();
        let mut buf = Vec::new();
        tcp.pull_into(&[3], false, &mut buf).unwrap();
        assert_eq!(buf[0], rows(&[3], 4, 0.0));
        assert_eq!(tcp.stats().unwrap().nodes, 1);
        d.shutdown();
    }
}
