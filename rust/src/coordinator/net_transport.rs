//! TCP transport for the embedding server: lets the KV store run as a
//! separate process (the paper deploys it as a Redis server on the
//! aggregation host, reached over 1 Gbps Ethernet by all clients).
//!
//! Wire protocol (little-endian, length-delimited):
//!
//! ```text
//! request  := op:u8 payload
//!   op=1 PULL  payload := n:u32 node_id*n
//!   op=2 PUSH  payload := n:u32 node_id*n layers:u32 (row:f32*hidden)*n per layer
//!   op=3 STATS payload := (empty)
//! response := status:u8 payload          (status 0 = ok)
//!   PULL  -> layers:u32 hidden:u32 (row:f32*hidden)*n per layer
//!   PUSH  -> (empty)
//!   STATS -> stored_nodes:u64 stored_rows:u64
//! ```
//!
//! All transfers are *batched* — one frame per pull/push phase, mirroring
//! the Redis pipelining the paper uses to amortize RPC overheads (§5.1).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::embedding_server::EmbeddingServer;
use super::metrics::{RpcKind, RpcRecord};

const OP_PULL: u8 = 1;
const OP_PUSH: u8 = 2;
const OP_STATS: u8 = 3;

fn write_u32(w: &mut impl Write, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes()).context("write u32")
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes()).context("write u64")
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("read u32")?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("read u64")?;
    Ok(u64::from_le_bytes(b))
}

fn write_f32s(w: &mut impl Write, data: &[f32]) -> Result<()> {
    // SAFETY: f32 slice viewed as bytes for the wire; endianness is LE on
    // every supported target (checked at server startup).
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    w.write_all(bytes).context("write f32s")
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut out = vec![0f32; n];
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, out.len() * 4)
    };
    r.read_exact(bytes).context("read f32s")?;
    Ok(out)
}

fn read_ids(r: &mut impl Read) -> Result<Vec<u32>> {
    let n = read_u32(r)? as usize;
    if n > 50_000_000 {
        bail!("absurd node count {n}");
    }
    let mut out = vec![0u32; n];
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, out.len() * 4)
    };
    r.read_exact(bytes).context("read ids")?;
    Ok(out)
}

/// Daemon wrapping an in-process [`EmbeddingServer`]: accepts connections
/// until `stop` is raised, one service thread per client (cross-silo
/// federations have few, long-lived clients).
pub struct EmbServerDaemon {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl EmbServerDaemon {
    pub fn start(server: Arc<EmbeddingServer>, bind: impl ToSocketAddrs) -> Result<Self> {
        let listener = TcpListener::bind(bind).context("bind")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("emb-server-accept".into())
            .spawn(move || {
                let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            stream.set_nodelay(true).ok();
                            stream.set_nonblocking(false).ok();
                            // bounded reads so service threads can notice
                            // the stop flag even with idle clients attached
                            stream
                                .set_read_timeout(Some(std::time::Duration::from_millis(100)))
                                .ok();
                            let server = Arc::clone(&server);
                            let stop = Arc::clone(&stop2);
                            conns.push(std::thread::spawn(move || {
                                let _ = serve_conn(server, stream, stop);
                            }));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })?;
        Ok(Self {
            addr,
            stop,
            accept_thread: Some(accept_thread),
        })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for EmbServerDaemon {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Serve one client connection until EOF or daemon stop.
fn serve_conn(
    server: Arc<EmbeddingServer>,
    stream: TcpStream,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let mut r = std::io::BufReader::new(stream.try_clone()?);
    let mut w = std::io::BufWriter::new(stream.try_clone()?);
    loop {
        let mut op = [0u8; 1];
        match r.read_exact(&mut op) {
            Ok(()) => {
                // a frame has started: switch to blocking reads for its body
                stream.set_read_timeout(None).ok();
            }
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        match op[0] {
            OP_PULL => {
                let nodes = read_ids(&mut r)?;
                let (per_layer, _) = server.pull(&nodes, false);
                w.write_all(&[0u8])?;
                write_u32(&mut w, per_layer.len() as u32)?;
                write_u32(&mut w, server.hidden as u32)?;
                for rows in &per_layer {
                    write_f32s(&mut w, rows)?;
                }
            }
            OP_PUSH => {
                let nodes = read_ids(&mut r)?;
                let layers = read_u32(&mut r)? as usize;
                if layers != server.n_layers() {
                    bail!("push layer count {layers} != {}", server.n_layers());
                }
                let mut per_layer = Vec::with_capacity(layers);
                for _ in 0..layers {
                    per_layer.push(read_f32s(&mut r, nodes.len() * server.hidden)?);
                }
                server.push(&nodes, &per_layer);
                w.write_all(&[0u8])?;
            }
            OP_STATS => {
                w.write_all(&[0u8])?;
                write_u64(&mut w, server.stored_nodes() as u64)?;
                write_u64(&mut w, server.stored_rows() as u64)?;
            }
            other => bail!("unknown op {other}"),
        }
        w.flush()?;
        stream
            .set_read_timeout(Some(std::time::Duration::from_millis(100)))
            .ok();
    }
}

/// Client-side handle speaking the wire protocol. API mirrors
/// [`EmbeddingServer`]; RPC records carry the *measured* wall time (the
/// network is real here, no cost model).
pub struct RemoteEmbClient {
    r: std::io::BufReader<TcpStream>,
    w: std::io::BufWriter<TcpStream>,
    pub hidden: usize,
    pub n_layers: usize,
}

impl RemoteEmbClient {
    pub fn connect(addr: impl ToSocketAddrs, n_layers: usize, hidden: usize) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connect")?;
        stream.set_nodelay(true).ok();
        Ok(Self {
            r: std::io::BufReader::new(stream.try_clone()?),
            w: std::io::BufWriter::new(stream),
            hidden,
            n_layers,
        })
    }

    fn check_status(&mut self) -> Result<()> {
        let mut st = [0u8; 1];
        self.r.read_exact(&mut st)?;
        if st[0] != 0 {
            bail!("server error status {}", st[0]);
        }
        Ok(())
    }

    pub fn pull(&mut self, nodes: &[u32]) -> Result<(Vec<Vec<f32>>, RpcRecord)> {
        let t0 = std::time::Instant::now();
        self.w.write_all(&[OP_PULL])?;
        write_u32(&mut self.w, nodes.len() as u32)?;
        let bytes = unsafe {
            std::slice::from_raw_parts(nodes.as_ptr() as *const u8, nodes.len() * 4)
        };
        self.w.write_all(bytes)?;
        self.w.flush()?;
        self.check_status()?;
        let layers = read_u32(&mut self.r)? as usize;
        let hidden = read_u32(&mut self.r)? as usize;
        if hidden != self.hidden {
            bail!("server hidden {hidden} != client {}", self.hidden);
        }
        let mut per_layer = Vec::with_capacity(layers);
        for _ in 0..layers {
            per_layer.push(read_f32s(&mut self.r, nodes.len() * hidden)?);
        }
        let payload = nodes.len() * layers * (hidden * 4 + 4);
        Ok((
            per_layer,
            RpcRecord {
                kind: RpcKind::Pull,
                rows: nodes.len(),
                bytes: payload,
                time: t0.elapsed().as_secs_f64(),
            },
        ))
    }

    pub fn push(&mut self, nodes: &[u32], per_layer: &[Vec<f32>]) -> Result<RpcRecord> {
        let t0 = std::time::Instant::now();
        self.w.write_all(&[OP_PUSH])?;
        write_u32(&mut self.w, nodes.len() as u32)?;
        let bytes = unsafe {
            std::slice::from_raw_parts(nodes.as_ptr() as *const u8, nodes.len() * 4)
        };
        self.w.write_all(bytes)?;
        write_u32(&mut self.w, per_layer.len() as u32)?;
        for rows in per_layer {
            write_f32s(&mut self.w, rows)?;
        }
        self.w.flush()?;
        self.check_status()?;
        let payload = nodes.len() * per_layer.len() * (self.hidden * 4 + 4);
        Ok(RpcRecord {
            kind: RpcKind::Push,
            rows: nodes.len(),
            bytes: payload,
            time: t0.elapsed().as_secs_f64(),
        })
    }

    pub fn stats(&mut self) -> Result<(usize, usize)> {
        self.w.write_all(&[OP_STATS])?;
        self.w.flush()?;
        self.check_status()?;
        Ok((read_u64(&mut self.r)? as usize, read_u64(&mut self.r)? as usize))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::netsim::NetConfig;

    fn daemon() -> (EmbServerDaemon, Arc<EmbeddingServer>) {
        let server = Arc::new(EmbeddingServer::new(2, 4, NetConfig::default()));
        let d = EmbServerDaemon::start(Arc::clone(&server), "127.0.0.1:0").unwrap();
        (d, server)
    }

    fn rows(nodes: &[u32], h: usize, salt: f32) -> Vec<f32> {
        nodes
            .iter()
            .flat_map(|&n| (0..h).map(move |j| n as f32 + j as f32 * 0.25 + salt))
            .collect()
    }

    #[test]
    fn tcp_roundtrip_push_pull_stats() {
        let (d, _server) = daemon();
        let mut c = RemoteEmbClient::connect(d.addr, 2, 4).unwrap();
        let nodes = [5u32, 9, 1000];
        let l1 = rows(&nodes, 4, 0.0);
        let l2 = rows(&nodes, 4, 7.0);
        let rec = c.push(&nodes, &[l1.clone(), l2.clone()]).unwrap();
        assert_eq!(rec.rows, 3);
        let (got, rec) = c.pull(&[9, 5]).unwrap();
        assert_eq!(rec.kind, RpcKind::Pull);
        assert_eq!(&got[0][0..4], &l1[4..8]);
        assert_eq!(&got[0][4..8], &l1[0..4]);
        assert_eq!(&got[1][0..4], &l2[4..8]);
        let (n, r) = c.stats().unwrap();
        assert_eq!((n, r), (3, 6));
        d.shutdown();
    }

    #[test]
    fn tcp_missing_nodes_are_zero() {
        let (d, _server) = daemon();
        let mut c = RemoteEmbClient::connect(d.addr, 2, 4).unwrap();
        let (got, _) = c.pull(&[424242]).unwrap();
        assert!(got[0].iter().all(|&v| v == 0.0));
        d.shutdown();
    }

    #[test]
    fn tcp_concurrent_clients() {
        let (d, server) = daemon();
        let addr = d.addr;
        let mut handles = Vec::new();
        for t in 0..4u32 {
            handles.push(std::thread::spawn(move || {
                let mut c = RemoteEmbClient::connect(addr, 2, 4).unwrap();
                let nodes: Vec<u32> = (t * 1000..t * 1000 + 200).collect();
                for round in 0..10 {
                    let l = rows(&nodes, 4, round as f32);
                    c.push(&nodes, &[l.clone(), l.clone()]).unwrap();
                    let (got, _) = c.pull(&nodes).unwrap();
                    assert_eq!(got[0], l);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.stored_nodes(), 800);
        d.shutdown();
    }

    #[test]
    fn tcp_large_batch() {
        let (d, _server) = daemon();
        let mut c = RemoteEmbClient::connect(d.addr, 2, 4).unwrap();
        let nodes: Vec<u32> = (0..50_000).collect();
        let l = rows(&nodes, 4, 0.5);
        let rec = c.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        assert!(rec.bytes > 1_000_000);
        let (got, rec2) = c.pull(&nodes).unwrap();
        assert_eq!(got[0], l);
        assert!(rec2.time > 0.0);
        d.shutdown();
    }

    #[test]
    fn push_layer_mismatch_closes_cleanly() {
        let (d, _server) = daemon();
        let mut c = RemoteEmbClient::connect(d.addr, 3, 4).unwrap();
        let nodes = [1u32];
        // client claims 3 layers; server has 2 -> connection drops, the
        // next call errors rather than hanging
        let res = c
            .push(&nodes, &[vec![0.0; 4], vec![0.0; 4], vec![0.0; 4]])
            .and_then(|_| c.stats().map(|_| ()));
        assert!(res.is_err());
        d.shutdown();
    }
}
