//! Resilience decorators for the embedding plane (DESIGN.md §10):
//!
//! * [`FaultStore`] wraps any [`EmbeddingStore`] and injects
//!   *deterministic, seedable* failures into its data-plane RPCs —
//!   error-on-the-Nth-call, error-every-Nth, latency spikes, seeded
//!   random flakiness, and full blackout (from call N, or flipped live
//!   through a [`FaultHandle`]). This is the substrate of the chaos
//!   suite (`tests/fault_tolerance.rs`) and of the CLI's `--fault-spec`
//!   flag: the same replicated deployment that must survive a dead
//!   shard in production is killed *reproducibly* in CI. `delay%N:S`
//!   spikes are charged to the **virtual clock** by default (added to
//!   the RPC's netsim time, not slept) so chaos tests don't burn real
//!   CI minutes; the standalone `serve` daemon — whose only clock is
//!   wall time — opts into real sleeps via
//!   [`FaultStore::with_real_delays`] / [`FaultSpec::wrap_shard_real`].
//! * [`SnapshotStore`] is the persistence-shaped decorator: it
//!   write-throughs every pushed row into an mmap-backed shadow slab
//!   (a [`RowSlab`] over an unlinked temp file — page cache, not heap)
//!   that can be [`dump`](SnapshotStore::dump)ed to a byte stream (via
//!   the safe LE [`codec`]) and [`restore`](SnapshotStore::restore)d
//!   into a fresh backend — so a restarted shard comes back warm and
//!   rejoins the replicated router via [`ShardedStore::rebalance`].
//!
//! Both decorators are value-transparent: [`FaultStore`] never corrupts
//! a payload (an injected fault is a clean `Err` or a delay), and
//! [`SnapshotStore`] round-trips rows bit-exactly (`to_le_bytes` all the
//! way down). Fault injection applies to `push`/`pull_into` only — the
//! `stats`/`describe`/`epoch` control plane stays reachable so tests and
//! operators can observe a store that is refusing data traffic.
//!
//! [`ShardedStore::rebalance`]: super::store::ShardedStore::rebalance

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use super::codec;
use super::metrics::RpcRecord;
use super::store::{EmbeddingStore, StoreStats};
use crate::storage::RowSlab;
use crate::util::rng::Rng;

/// One deterministic fault rule, applied per data-plane RPC (push/pull)
/// against the store's own 1-based call counter.
#[derive(Clone, Debug, PartialEq)]
pub enum Fault {
    /// Fail exactly the Nth RPC.
    ErrOn(usize),
    /// Fail every Nth RPC (N, 2N, ...).
    ErrEvery(usize),
    /// Fail every RPC from the Nth onward (a dead shard).
    BlackoutFrom(usize),
    /// Sleep `secs` before every Nth RPC (a latency spike).
    DelayEvery { every: usize, secs: f64 },
    /// Fail each RPC independently with probability `p`, derived from
    /// `(seed, call index)` — reproducible across runs and threads.
    Flaky { p: f64, seed: u64 },
}

fn parse_count(s: &str, what: &str) -> Result<usize> {
    let n: usize = s
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("{what} expects a positive integer, got {s:?}"))?;
    ensure!(n > 0, "{what} expects a positive integer, got 0");
    Ok(n)
}

impl Fault {
    /// Parse one fault term of the `--fault-spec` grammar:
    ///
    /// ```text
    /// fault := 'err@' N           fail exactly RPC N
    ///        | 'err%' N           fail every Nth RPC
    ///        | 'blackout'         fail every RPC
    ///        | 'blackout@' N      fail every RPC from N onward
    ///        | 'delay%' N ':' S   sleep S seconds before every Nth RPC
    ///        | 'flaky@' P [':' SEED]   fail with probability P (seeded)
    /// ```
    pub fn parse(s: &str) -> Result<Fault> {
        let s = s.trim();
        if let Some(n) = s.strip_prefix("err@") {
            return Ok(Fault::ErrOn(parse_count(n, "err@N")?));
        }
        if let Some(n) = s.strip_prefix("err%") {
            return Ok(Fault::ErrEvery(parse_count(n, "err%N")?));
        }
        if s == "blackout" {
            return Ok(Fault::BlackoutFrom(1));
        }
        if let Some(n) = s.strip_prefix("blackout@") {
            return Ok(Fault::BlackoutFrom(parse_count(n, "blackout@N")?));
        }
        if let Some(rest) = s.strip_prefix("delay%") {
            let (n, secs) = rest
                .split_once(':')
                .with_context(|| format!("delay fault {s:?} wants delay%N:SECONDS"))?;
            let secs: f64 = secs
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("delay seconds {secs:?} is not a number"))?;
            ensure!(secs >= 0.0 && secs.is_finite(), "delay seconds {secs} out of range");
            return Ok(Fault::DelayEvery {
                every: parse_count(n, "delay%N")?,
                secs,
            });
        }
        if let Some(rest) = s.strip_prefix("flaky@") {
            let (p, seed) = match rest.split_once(':') {
                Some((p, seed)) => (
                    p,
                    seed.trim()
                        .parse::<u64>()
                        .map_err(|_| anyhow::anyhow!("flaky seed {seed:?} is not an integer"))?,
                ),
                None => (rest, 0),
            };
            let p: f64 = p
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("flaky probability {p:?} is not a number"))?;
            ensure!((0.0..=1.0).contains(&p), "flaky probability {p} not in [0, 1]");
            return Ok(Fault::Flaky { p, seed });
        }
        bail!(
            "unknown fault {s:?} \
             (grammar: err@N | err%N | blackout[@N] | delay%N:SECS | flaky@P[:SEED])"
        )
    }
}

/// A parsed `--fault-spec`: which shard gets which [`Fault`]s.
///
/// Grammar (clauses separated by `;`):
///
/// ```text
/// spec   := clause (';' clause)*
/// clause := target '=' fault
/// target := 'shard' INDEX | '*'          (* = every shard)
/// ```
///
/// Example: `shard1=blackout@40;*=delay%10:0.005` kills shard 1 from its
/// 40th RPC onward and adds a 5 ms spike to every 10th RPC of every
/// shard.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    clauses: Vec<(Option<usize>, Fault)>,
}

impl FaultSpec {
    pub fn parse(spec: &str) -> Result<FaultSpec> {
        let mut clauses = Vec::new();
        for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let (target, fault) = clause.split_once('=').with_context(|| {
                format!("fault clause {clause:?} missing '=' (grammar: shardK=FAULT or *=FAULT)")
            })?;
            let target = target.trim();
            let shard = if target == "*" {
                None
            } else {
                let k = target.strip_prefix("shard").with_context(|| {
                    format!("fault target {target:?} (expected shardK or *)")
                })?;
                Some(k.trim().parse::<usize>().ok().with_context(|| {
                    format!("fault target {target:?}: bad shard index")
                })?)
            };
            let fault = Fault::parse(fault)
                .with_context(|| format!("in fault clause for {target:?}"))?;
            clauses.push((shard, fault));
        }
        Ok(FaultSpec { clauses })
    }

    pub fn is_empty(&self) -> bool {
        self.clauses.is_empty()
    }

    /// Faults that apply to shard `shard` (its own clauses plus `*`).
    pub fn faults_for(&self, shard: usize) -> Vec<Fault> {
        self.clauses
            .iter()
            .filter(|(t, _)| t.is_none() || *t == Some(shard))
            .map(|(_, f)| f.clone())
            .collect()
    }

    /// Highest shard index any clause names (None if only `*` clauses).
    pub fn max_shard(&self) -> Option<usize> {
        self.clauses.iter().filter_map(|(t, _)| *t).max()
    }

    /// Fail fast when a clause names a shard outside `0..shards`: a
    /// typo'd target would otherwise make a chaos run silently
    /// fault-free.
    pub fn validate_shards(&self, shards: usize) -> Result<()> {
        if let Some(max) = self.max_shard() {
            ensure!(
                max < shards,
                "fault spec targets shard{max}, but only {shards} shard(s) exist \
                 (indices 0..={})",
                shards.saturating_sub(1)
            );
        }
        Ok(())
    }

    /// Wrap `store` in a [`FaultStore`] labeled `shard{shard}` when any
    /// clause applies to that shard; hand it back untouched otherwise.
    /// The shared deployment helper behind `run --fault-spec`. Injected
    /// delays are charged to the virtual clock.
    pub fn wrap_shard(
        &self,
        shard: usize,
        store: Arc<dyn EmbeddingStore>,
    ) -> Arc<dyn EmbeddingStore> {
        self.wrap_shard_inner(shard, store, false)
    }

    /// Like [`wrap_shard`](Self::wrap_shard), but injected delays sleep
    /// real wall-clock time — for the standalone `serve` daemon, where
    /// wall time is the only clock a remote client can observe.
    pub fn wrap_shard_real(
        &self,
        shard: usize,
        store: Arc<dyn EmbeddingStore>,
    ) -> Arc<dyn EmbeddingStore> {
        self.wrap_shard_inner(shard, store, true)
    }

    fn wrap_shard_inner(
        &self,
        shard: usize,
        store: Arc<dyn EmbeddingStore>,
        real_delays: bool,
    ) -> Arc<dyn EmbeddingStore> {
        let faults = self.faults_for(shard);
        if faults.is_empty() {
            store
        } else {
            let fs = FaultStore::new(store, format!("shard{shard}"), faults);
            Arc::new(if real_delays { fs.with_real_delays() } else { fs })
        }
    }
}

struct FaultState {
    faults: Mutex<Vec<Fault>>,
    blackout: AtomicBool,
    calls: AtomicUsize,
    injected: AtomicUsize,
}

/// Shared live control of a [`FaultStore`]: tests and harnesses keep the
/// handle and flip faults mid-run ("kill shard k at round r") while the
/// store is owned by the session as an `Arc<dyn EmbeddingStore>`.
#[derive(Clone)]
pub struct FaultHandle(Arc<FaultState>);

impl FaultHandle {
    /// Kill (`true`) or revive (`false`) the store: while blacked out,
    /// every data-plane RPC fails.
    pub fn set_blackout(&self, on: bool) {
        self.0.blackout.store(on, Ordering::SeqCst);
    }

    pub fn is_blacked_out(&self) -> bool {
        self.0.blackout.load(Ordering::SeqCst)
    }

    /// Data-plane RPCs observed so far (faulted or not).
    pub fn calls(&self) -> usize {
        self.0.calls.load(Ordering::SeqCst)
    }

    /// Faults injected so far (errors only; delays don't count).
    pub fn injected(&self) -> usize {
        self.0.injected.load(Ordering::SeqCst)
    }

    /// Append a fault rule live.
    pub fn add_fault(&self, fault: Fault) {
        self.0.faults.lock().unwrap().push(fault);
    }

    /// Drop every static fault rule (the blackout switch is separate).
    pub fn clear_faults(&self) {
        self.0.faults.lock().unwrap().clear();
    }
}

/// Deterministic fault-injection decorator over any [`EmbeddingStore`]
/// (see the module docs). Construct with the faults parsed from a
/// `--fault-spec` clause, keep the [`FaultHandle`] to script failures
/// live, and hand the store itself to a session or a
/// [`ShardedStore`](super::store::ShardedStore) backend slot.
pub struct FaultStore {
    inner: Arc<dyn EmbeddingStore>,
    label: String,
    state: Arc<FaultState>,
    /// Sleep injected delays for real instead of charging them to the
    /// RPC's virtual time (only the `serve` daemon wants this).
    real_delays: bool,
}

impl FaultStore {
    pub fn new(
        inner: Arc<dyn EmbeddingStore>,
        label: impl Into<String>,
        faults: Vec<Fault>,
    ) -> Self {
        Self {
            inner,
            label: label.into(),
            state: Arc::new(FaultState {
                faults: Mutex::new(faults),
                blackout: AtomicBool::new(false),
                calls: AtomicUsize::new(0),
                injected: AtomicUsize::new(0),
            }),
            real_delays: false,
        }
    }

    /// Make injected `delay%N:S` faults sleep real wall-clock time. The
    /// default charges them to the RPC's virtual time instead, which is
    /// what every model-time (netsim) run wants; only the standalone
    /// `serve` daemon — observed by remote clients over real sockets —
    /// needs the sleep.
    pub fn with_real_delays(mut self) -> Self {
        self.real_delays = true;
        self
    }

    /// Live control handle (cheap clone of a shared state).
    pub fn handle(&self) -> FaultHandle {
        FaultHandle(Arc::clone(&self.state))
    }

    /// Count one data-plane RPC and apply the fault plan to it. Returns
    /// the virtual delay (seconds) to charge to the RPC's service time —
    /// 0.0 when there is none or when it was slept for real.
    fn intercept(&self) -> Result<f64> {
        let idx = self.state.calls.fetch_add(1, Ordering::SeqCst) + 1;
        if self.state.blackout.load(Ordering::SeqCst) {
            self.state.injected.fetch_add(1, Ordering::SeqCst);
            bail!("injected fault: {} is blacked out (rpc #{idx})", self.label);
        }
        let mut delay = 0.0f64;
        let mut fail = false;
        for f in self.state.faults.lock().unwrap().iter() {
            match *f {
                Fault::ErrOn(n) => fail |= idx == n,
                Fault::ErrEvery(n) => fail |= idx % n == 0,
                Fault::BlackoutFrom(n) => fail |= idx >= n,
                Fault::DelayEvery { every, secs } => {
                    if idx % every == 0 {
                        delay += secs;
                    }
                }
                Fault::Flaky { p, seed } => {
                    let mut rng = Rng::new(seed, idx as u64);
                    fail |= rng.chance(p);
                }
            }
        }
        if delay > 0.0 && self.real_delays {
            std::thread::sleep(std::time::Duration::from_secs_f64(delay));
        }
        if fail {
            self.state.injected.fetch_add(1, Ordering::SeqCst);
            bail!("injected fault: {} rpc #{idx}", self.label);
        }
        Ok(if self.real_delays { 0.0 } else { delay })
    }
}

impl EmbeddingStore for FaultStore {
    fn n_layers(&self) -> usize {
        self.inner.n_layers()
    }

    fn hidden(&self) -> usize {
        self.inner.hidden()
    }

    fn push(&self, nodes: &[u32], per_layer: &[Vec<f32>]) -> Result<RpcRecord> {
        let delay = self.intercept()?;
        let mut rec = self.inner.push(nodes, per_layer)?;
        rec.time += delay;
        Ok(rec)
    }

    fn pull_into(
        &self,
        nodes: &[u32],
        on_demand: bool,
        out: &mut Vec<Vec<f32>>,
    ) -> Result<RpcRecord> {
        let delay = self.intercept()?;
        let mut rec = self.inner.pull_into(nodes, on_demand, out)?;
        rec.time += delay;
        Ok(rec)
    }

    fn stats(&self) -> Result<StoreStats> {
        self.inner.stats()
    }

    fn epoch(&self) -> u64 {
        self.inner.epoch()
    }

    fn codec(&self) -> String {
        self.inner.codec()
    }

    fn describe(&self) -> String {
        format!("fault({} over {})", self.label, self.inner.describe())
    }
}

/// Snapshot file magic ("SNAP", little-endian).
const SNAP_MAGIC: u32 = 0x5350_414E;

/// Write-through persistence decorator: every pushed row is mirrored
/// into a shadow slab that [`dump`](SnapshotStore::dump) serializes
/// (sorted by id, bit-exact LE floats) and
/// [`restore`](SnapshotStore::restore) replays into a fresh backend as
/// one batched push. A restarted shard is rebuilt by `restore` and then
/// re-admitted to the replicated router via
/// [`ShardedStore::rebalance`](super::store::ShardedStore::rebalance),
/// which copies whatever it missed while down from the live replicas
/// (DESIGN.md §10).
///
/// The shadow lives in an mmap-backed [`RowSlab`] over an unlinked temp
/// file (DESIGN.md §13.4): one fixed-width row of
/// `n_layers * hidden` floats per mirrored node, so the dormant copy
/// sits in the page cache — where the kernel can write it back and
/// evict under pressure — instead of doubling the shard's heap. Only
/// the id → slot index stays on the heap.
pub struct SnapshotStore {
    inner: Arc<dyn EmbeddingStore>,
    shadow: Mutex<Shadow>,
}

/// node id -> slab row slot; rows are `n_layers * hidden` floats laid
/// out layer-major. The slab is created lazily on the first mirrored
/// push (so `new` stays infallible).
struct Shadow {
    index: HashMap<u32, usize>,
    slab: Option<RowSlab>,
}

impl SnapshotStore {
    pub fn new(inner: Arc<dyn EmbeddingStore>) -> Self {
        Self {
            inner,
            shadow: Mutex::new(Shadow {
                index: HashMap::new(),
                slab: None,
            }),
        }
    }

    /// Nodes currently mirrored in the shadow slab.
    pub fn shadow_nodes(&self) -> usize {
        self.shadow.lock().unwrap().index.len()
    }

    /// Serialize the shadow slab (geometry header + rows sorted by id).
    /// Returns the number of nodes written.
    pub fn dump(&self, w: &mut impl Write) -> Result<usize> {
        let shadow = self.shadow.lock().unwrap();
        let h = self.inner.hidden();
        let layers = self.inner.n_layers();
        codec::write_u32(w, SNAP_MAGIC)?;
        codec::write_u32(w, layers as u32)?;
        codec::write_u32(w, h as u32)?;
        codec::write_u64(w, shadow.index.len() as u64)?;
        let mut ids: Vec<u32> = shadow.index.keys().copied().collect();
        ids.sort_unstable();
        for id in &ids {
            codec::write_u32(w, *id)?;
            let slot = shadow.index[id];
            let row = shadow.slab.as_ref().expect("non-empty shadow has a slab").row(slot);
            for l in 0..layers {
                codec::write_f32s(w, &row[l * h..(l + 1) * h])?;
            }
        }
        Ok(ids.len())
    }

    /// [`dump`](SnapshotStore::dump) into a file.
    pub fn dump_to(&self, path: impl AsRef<std::path::Path>) -> Result<usize> {
        let path = path.as_ref();
        let file = std::fs::File::create(path)
            .with_context(|| format!("create snapshot {}", path.display()))?;
        let mut w = std::io::BufWriter::new(file);
        let n = self.dump(&mut w)?;
        w.flush().context("flush snapshot")?;
        Ok(n)
    }

    /// Rebuild a store from a snapshot: validates the geometry header
    /// against `inner`, replays every row into it as one batched push,
    /// and returns the decorator with its shadow warm.
    pub fn restore(r: &mut impl Read, inner: Arc<dyn EmbeddingStore>) -> Result<Self> {
        let magic = codec::read_u32(r)?;
        ensure!(magic == SNAP_MAGIC, "not a snapshot stream (magic {magic:#010x})");
        let n_layers = codec::read_u32(r)? as usize;
        let hidden = codec::read_u32(r)? as usize;
        ensure!(
            n_layers == inner.n_layers() && hidden == inner.hidden(),
            "snapshot geometry {n_layers}x{hidden} != store geometry {}x{}",
            inner.n_layers(),
            inner.hidden()
        );
        let count = codec::read_u64(r)? as usize;
        ensure!(count <= codec::MAX_WIRE_ELEMS, "absurd snapshot node count {count}");
        let mut nodes: Vec<u32> = Vec::with_capacity(count);
        let mut per_layer: Vec<Vec<f32>> =
            (0..n_layers).map(|_| Vec::with_capacity(count * hidden)).collect();
        let mut index = HashMap::with_capacity(count);
        let mut slab = RowSlab::new(n_layers * hidden).context("snapshot shadow slab")?;
        for _ in 0..count {
            let id = codec::read_u32(r)?;
            let slot = slab.alloc_row().context("snapshot shadow slab")?;
            for (l, dst) in per_layer.iter_mut().enumerate() {
                let row = codec::read_f32s(r, hidden)?;
                dst.extend_from_slice(&row);
                slab.row_mut(slot)[l * hidden..(l + 1) * hidden].copy_from_slice(&row);
            }
            nodes.push(id);
            index.insert(id, slot);
        }
        if !nodes.is_empty() {
            inner.push(&nodes, &per_layer).context("snapshot restore push")?;
        }
        Ok(Self {
            inner,
            shadow: Mutex::new(Shadow {
                index,
                slab: Some(slab),
            }),
        })
    }

    /// [`restore`](SnapshotStore::restore) from a file.
    pub fn restore_from(
        path: impl AsRef<std::path::Path>,
        inner: Arc<dyn EmbeddingStore>,
    ) -> Result<Self> {
        let path = path.as_ref();
        let file = std::fs::File::open(path)
            .with_context(|| format!("open snapshot {}", path.display()))?;
        Self::restore(&mut std::io::BufReader::new(file), inner)
    }
}

impl EmbeddingStore for SnapshotStore {
    fn n_layers(&self) -> usize {
        self.inner.n_layers()
    }

    fn hidden(&self) -> usize {
        self.inner.hidden()
    }

    fn push(&self, nodes: &[u32], per_layer: &[Vec<f32>]) -> Result<RpcRecord> {
        // forward first: a rejected push must not poison the shadow
        let rec = self.inner.push(nodes, per_layer)?;
        let h = self.inner.hidden();
        let mut shadow = self.shadow.lock().unwrap();
        if shadow.slab.is_none() {
            shadow.slab =
                Some(RowSlab::new(per_layer.len() * h).context("snapshot shadow slab")?);
        }
        let Shadow { index, slab } = &mut *shadow;
        let slab = slab.as_mut().expect("ensured just above");
        for (i, &node) in nodes.iter().enumerate() {
            let slot = match index.entry(node) {
                Entry::Occupied(e) => *e.get(),
                Entry::Vacant(e) => *e.insert(slab.alloc_row().context("snapshot shadow slab")?),
            };
            let row = slab.row_mut(slot);
            for (l, rows) in per_layer.iter().enumerate() {
                row[l * h..(l + 1) * h].copy_from_slice(&rows[i * h..(i + 1) * h]);
            }
        }
        Ok(rec)
    }

    fn pull_into(
        &self,
        nodes: &[u32],
        on_demand: bool,
        out: &mut Vec<Vec<f32>>,
    ) -> Result<RpcRecord> {
        self.inner.pull_into(nodes, on_demand, out)
    }

    fn stats(&self) -> Result<StoreStats> {
        self.inner.stats()
    }

    fn epoch(&self) -> u64 {
        self.inner.epoch()
    }

    fn codec(&self) -> String {
        self.inner.codec()
    }

    fn describe(&self) -> String {
        format!("snapshot({})", self.inner.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::embedding_server::EmbeddingServer;
    use crate::coordinator::netsim::NetConfig;

    fn server(h: usize) -> Arc<dyn EmbeddingStore> {
        Arc::new(EmbeddingServer::new(2, h, NetConfig::default()))
    }

    fn rows(nodes: &[u32], h: usize, salt: f32) -> Vec<f32> {
        nodes
            .iter()
            .flat_map(|&n| (0..h).map(move |j| n as f32 * 3.0 + j as f32 + salt))
            .collect()
    }

    // ---- fault spec grammar -----------------------------------------------

    #[test]
    fn fault_spec_parses_the_documented_grammar() {
        let spec = FaultSpec::parse("shard1=blackout@40; *=delay%10:0.005 ;shard0=err@3").unwrap();
        assert!(!spec.is_empty());
        assert_eq!(spec.max_shard(), Some(1));
        assert_eq!(
            spec.faults_for(1),
            vec![
                Fault::BlackoutFrom(40),
                Fault::DelayEvery { every: 10, secs: 0.005 }
            ]
        );
        assert_eq!(
            spec.faults_for(0),
            vec![
                Fault::DelayEvery { every: 10, secs: 0.005 },
                Fault::ErrOn(3)
            ]
        );
        assert_eq!(spec.faults_for(7), vec![Fault::DelayEvery { every: 10, secs: 0.005 }]);
        assert_eq!(FaultSpec::parse("").unwrap(), FaultSpec::default());
        assert_eq!(
            FaultSpec::parse("shard2=flaky@0.25:99").unwrap().faults_for(2),
            vec![Fault::Flaky { p: 0.25, seed: 99 }]
        );
        assert_eq!(
            FaultSpec::parse("*=err%7").unwrap().faults_for(0),
            vec![Fault::ErrEvery(7)]
        );
        assert_eq!(
            FaultSpec::parse("*=blackout").unwrap().faults_for(3),
            vec![Fault::BlackoutFrom(1)]
        );
    }

    #[test]
    fn fault_spec_rejects_malformed_input() {
        for bad in [
            "shard1",              // no '='
            "volume1=err@3",       // bad target
            "shardX=err@3",        // bad index
            "shard1=err@0",        // zero count
            "shard1=err@",         // empty count
            "shard1=explode",      // unknown fault
            "shard1=delay%5",      // missing seconds
            "shard1=delay%5:fast", // bad seconds
            "shard1=flaky@1.5",    // probability out of range
            "shard1=flaky@0.5:pi", // bad seed
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    // ---- fault store behavior ---------------------------------------------

    #[test]
    fn err_on_nth_rpc_fires_exactly_once() {
        let store = FaultStore::new(server(4), "shard0", vec![Fault::ErrOn(2)]);
        let handle = store.handle();
        let nodes = [1u32];
        let l = rows(&nodes, 4, 0.0);
        assert!(store.push(&nodes, &[l.clone(), l.clone()]).is_ok()); // rpc 1
        let err = store.push(&nodes, &[l.clone(), l.clone()]).unwrap_err(); // rpc 2
        assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
        assert!(store.push(&nodes, &[l.clone(), l.clone()]).is_ok()); // rpc 3
        assert_eq!(handle.calls(), 3);
        assert_eq!(handle.injected(), 1);
        // values were never corrupted
        let (got, _) = store.pull(&nodes, false).unwrap();
        assert_eq!(got[0], l);
    }

    #[test]
    fn blackout_handle_kills_and_revives() {
        let store = FaultStore::new(server(4), "shard3", Vec::new());
        let handle = store.handle();
        let nodes = [9u32];
        let l = rows(&nodes, 4, 1.0);
        store.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        handle.set_blackout(true);
        assert!(handle.is_blacked_out());
        assert!(store.pull(&nodes, false).is_err());
        assert!(store.push(&nodes, &[l.clone(), l.clone()]).is_err());
        // control plane stays reachable while data plane is dead
        assert_eq!(store.stats().unwrap().nodes, 1);
        assert!(store.describe().starts_with("fault(shard3 over "));
        handle.set_blackout(false);
        let (got, _) = store.pull(&nodes, false).unwrap();
        assert_eq!(got[0], l);
        assert_eq!(handle.injected(), 2);
    }

    #[test]
    fn flaky_faults_are_reproducible() {
        let run = |seed: u64| -> Vec<bool> {
            let store = FaultStore::new(server(4), "s", vec![Fault::Flaky { p: 0.5, seed }]);
            (0..32).map(|_| store.pull(&[1], false).is_ok()).collect()
        };
        let a = run(7);
        assert_eq!(a, run(7), "same seed must fail the same calls");
        assert!(a.iter().any(|&ok| ok) && a.iter().any(|&ok| !ok), "p=0.5 over 32 calls");
        assert_ne!(a, run(8), "different seeds should differ");
    }

    #[test]
    fn delay_fault_charges_virtual_time_by_default() {
        // a 5 s virtual spike must not sleep 5 real seconds
        let store = FaultStore::new(
            server(4),
            "s",
            vec![Fault::DelayEvery { every: 2, secs: 5.0 }],
        );
        let t0 = std::time::Instant::now();
        let (_, r1) = store.pull(&[1], false).unwrap(); // rpc 1: no delay
        let (_, r2) = store.pull(&[1], false).unwrap(); // rpc 2: delayed
        let wall = t0.elapsed().as_secs_f64();
        assert!(wall < 2.0, "virtual delay slept for real: {wall}s");
        assert!(
            r2.time >= r1.time + 5.0,
            "delay not charged to virtual time: {} vs {}",
            r2.time,
            r1.time
        );
        assert_eq!(store.handle().injected(), 0, "delays are not failures");
    }

    #[test]
    fn delay_fault_sleeps_for_real_when_asked() {
        let store = FaultStore::new(
            server(4),
            "s",
            vec![Fault::DelayEvery { every: 2, secs: 0.02 }],
        )
        .with_real_delays();
        let (_, r1) = store.pull(&[1], false).unwrap(); // rpc 1: no delay
        let t1 = std::time::Instant::now();
        let (_, r2) = store.pull(&[1], false).unwrap(); // rpc 2: delayed
        let slow = t1.elapsed();
        assert!(slow.as_secs_f64() >= 0.02, "delay not applied: {slow:?}");
        // the real sleep is not double-charged to virtual time
        assert!(
            (r2.time - r1.time).abs() < 0.01,
            "real delay leaked into virtual time: {} vs {}",
            r2.time,
            r1.time
        );
        assert_eq!(store.handle().injected(), 0);
    }

    #[test]
    fn fault_spec_parse_errors_name_the_offending_target() {
        let err = FaultSpec::parse("shard3=explode").unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains("shard3"), "{chain}");
        assert!(chain.contains("unknown fault"), "{chain}");
        let err = FaultSpec::parse("shard0=err@3;*=delay%5").unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains("\"*\""), "{chain}");
    }

    // ---- snapshot store ---------------------------------------------------

    #[test]
    fn snapshot_dump_restore_roundtrips_bit_exactly() {
        let h = 4;
        let snap = SnapshotStore::new(server(h));
        let nodes: Vec<u32> = vec![5, 1, 300, 77];
        let l1 = rows(&nodes, h, 0.0);
        let l2 = rows(&nodes, h, 0.25);
        snap.push(&nodes, &[l1.clone(), l2.clone()]).unwrap();
        // overwrite one node so the shadow tracks the latest row
        snap.push(&[77], &[vec![9.5; h], vec![-0.0; h]]).unwrap();
        assert_eq!(snap.shadow_nodes(), 4);

        let mut bytes = Vec::new();
        let n = snap.dump(&mut bytes).unwrap();
        assert_eq!(n, 4);

        let restored = SnapshotStore::restore(&mut &bytes[..], server(h)).unwrap();
        assert_eq!(restored.shadow_nodes(), 4);
        let (a, _) = snap.pull(&[1, 5, 77, 300, 42], false).unwrap();
        let (b, _) = restored.pull(&[1, 5, 77, 300, 42], false).unwrap();
        let bits = |v: &Vec<f32>| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a[0]), bits(&b[0]));
        assert_eq!(bits(&a[1]), bits(&b[1]));
        assert_eq!(restored.stats().unwrap().nodes, 4);
    }

    #[test]
    fn snapshot_restore_rejects_garbage_and_geometry_mismatch() {
        let snap = SnapshotStore::new(server(4));
        snap.push(&[1], &[vec![1.0; 4], vec![2.0; 4]]).unwrap();
        let mut bytes = Vec::new();
        snap.dump(&mut bytes).unwrap();
        // wrong geometry target
        let err = SnapshotStore::restore(&mut &bytes[..], server(8)).unwrap_err();
        assert!(format!("{err:#}").contains("geometry"), "{err:#}");
        // not a snapshot at all
        let junk = vec![0u8; 32];
        assert!(SnapshotStore::restore(&mut &junk[..], server(4)).is_err());
        // truncated stream
        let cut = &bytes[..bytes.len() - 3];
        assert!(SnapshotStore::restore(&mut &cut[..], server(4)).is_err());
    }

    #[test]
    fn snapshot_file_roundtrip() {
        let h = 4;
        let snap = SnapshotStore::new(server(h));
        let nodes: Vec<u32> = (0..50).collect();
        let l = rows(&nodes, h, 2.0);
        snap.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        let dir = std::env::temp_dir().join(format!("optimes_snap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("shard.snap");
        assert_eq!(snap.dump_to(&path).unwrap(), 50);
        let restored = SnapshotStore::restore_from(&path, server(h)).unwrap();
        let (got, _) = restored.pull(&nodes, false).unwrap();
        assert_eq!(got[0], l);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
