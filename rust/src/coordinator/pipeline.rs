//! The asynchronous push/pull pipeline over the embedding plane
//! (DESIGN.md §9).
//!
//! [`AsyncStoreHandle`] owns a small background worker pool (the shared
//! [`ThreadPool`] substrate of `util/pool.rs`) and turns store calls into
//! *tickets*: [`push_async`](AsyncStoreHandle::push_async) and
//! [`prefetch`](AsyncStoreHandle::prefetch) return immediately while the
//! RPC runs on a worker, and the caller joins the returned [`PushTicket`]
//! / [`PullTicket`] (via [`Ticket::wait`] or [`Ticket::try_take`])
//! wherever the result is actually needed. Works over *any*
//! [`EmbeddingStore`] backend — the in-process slab, the pooled TCP
//! client (each in-flight RPC leases its own connection), or a sharded
//! compound (whose sub-RPCs already fan out concurrently).
//!
//! This is what makes the paper's headline overlap (§1, §3) *real* rather
//! than only modeled: with `--pipeline on`, the ε−k push RPC runs while
//! the remaining training epochs execute (ticket joined at round end),
//! and the next round's initial pull is prefetched while the previous
//! round aggregates, validates, and broadcasts
//! (`trainer::run_round_pipelined` / `Session::run_round`). The *measured*
//! wall time of that overlap is recorded next to the virtual-time model
//! in [`OverlapMetrics`](super::metrics::OverlapMetrics).
//!
//! Pipelining never changes values: every ticket carries the exact rows a
//! synchronous call at the join point would have produced (the session
//! only issues a prefetch once the store has reached the state the
//! synchronous pull would read — see DESIGN.md §9 for the ordering
//! argument), so accuracy curves are bit-identical to `--pipeline off`
//! for a fixed seed (`tests/store_parity.rs`).
//!
//! [`ThreadPool`]: crate::util::pool::ThreadPool

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::Result;

use super::metrics::RpcRecord;
use super::store::{EmbeddingStore, StoreStats};
use crate::obs;
use crate::util::pool::ThreadPool;

/// Result of a completed asynchronous push.
#[derive(Debug)]
pub struct PushDone {
    /// The backend's RPC record (modeled virtual time in-process,
    /// measured wall time over TCP) — identical to what a synchronous
    /// `push` would have returned.
    pub rec: RpcRecord,
    /// Measured wall seconds from ticket issue to RPC completion
    /// (queue wait + store I/O).
    pub wall: f64,
    /// Routing epoch observed when the worker issued the RPC
    /// ([`EmbeddingStore::epoch`]). The rebalancing router guarantees
    /// the RPC itself ran entirely under one generation ≥ this value —
    /// exact unless a rebalance raced the ticket, in which case this is
    /// the lower bound.
    pub epoch: u64,
}

/// Result of a completed asynchronous pull.
#[derive(Debug)]
pub struct PullDone {
    /// Pulled rows, one row-major `[nodes, hidden]` tensor per layer —
    /// identical to what a synchronous `pull_into` would have produced.
    pub rows: Vec<Vec<f32>>,
    /// The backend's RPC record.
    pub rec: RpcRecord,
    /// Measured wall seconds from ticket issue to RPC completion.
    pub wall: f64,
    /// Routing epoch observed at RPC issue (see [`PushDone::epoch`]).
    pub epoch: u64,
}

enum SlotState<T> {
    Pending,
    Done(Result<T>),
    Taken,
}

/// One-shot completion slot shared between a worker and a ticket.
struct Slot<T> {
    state: Mutex<SlotState<T>>,
    cv: Condvar,
}

impl<T> Slot<T> {
    fn fulfil(&self, r: Result<T>) {
        *self.state.lock().unwrap() = SlotState::Done(r);
        self.cv.notify_all();
    }
}

/// Completion handle for one asynchronous store operation. Join it with
/// [`wait`](Ticket::wait) (blocking) or poll it with
/// [`try_take`](Ticket::try_take) (non-blocking). Dropping a ticket is
/// safe: the operation still completes on its worker, the result is
/// simply discarded.
pub struct Ticket<T> {
    slot: Arc<Slot<T>>,
}

/// Ticket for an asynchronous [`AsyncStoreHandle::push_async`].
pub type PushTicket = Ticket<PushDone>;

/// Ticket for an asynchronous [`AsyncStoreHandle::prefetch`].
pub type PullTicket = Ticket<PullDone>;

impl<T> Ticket<T> {
    fn new() -> (Self, Arc<Slot<T>>) {
        let slot = Arc::new(Slot {
            state: Mutex::new(SlotState::Pending),
            cv: Condvar::new(),
        });
        (
            Self {
                slot: Arc::clone(&slot),
            },
            slot,
        )
    }

    /// Block until the operation completes and take its result.
    pub fn wait(self) -> Result<T> {
        let _sp = obs::span("pipeline", "ticket_wait");
        let mut st = self.slot.state.lock().unwrap();
        loop {
            match std::mem::replace(&mut *st, SlotState::Taken) {
                SlotState::Done(r) => return r,
                SlotState::Pending => {
                    *st = SlotState::Pending;
                    st = self.slot.cv.wait(st).unwrap();
                }
                SlotState::Taken => unreachable!("ticket consumed twice"),
            }
        }
    }

    /// Non-blocking join: the result if the operation has completed, or
    /// the ticket back if it is still in flight.
    pub fn try_take(self) -> std::result::Result<Result<T>, Self> {
        {
            let mut st = self.slot.state.lock().unwrap();
            match std::mem::replace(&mut *st, SlotState::Taken) {
                SlotState::Done(r) => return Ok(r),
                prev => *st = prev,
            }
        }
        Err(self)
    }

    /// Has the operation completed (result still un-taken)?
    pub fn is_done(&self) -> bool {
        matches!(*self.slot.state.lock().unwrap(), SlotState::Done(_))
    }
}

/// A prefetched initial pull waiting to be consumed by the next
/// `run_round_pipelined` call of the same client. The pull set is kept
/// alongside the ticket so the consumer can verify the prefetch matches
/// the pull it is about to perform (and fall back to a synchronous pull
/// otherwise — e.g. after a dynamic-pruning re-sample).
pub struct PendingPull {
    /// Global vertex ids the prefetch requested, in request order.
    pub globals: Vec<u32>,
    pub ticket: PullTicket,
}

impl PendingPull {
    /// The ticket, if this prefetch was issued for exactly `globals`.
    pub fn into_matching(self, globals: &[u32]) -> Option<PullTicket> {
        if self.globals == globals {
            Some(self.ticket)
        } else {
            None
        }
    }
}

/// Current / peak number of queued-or-running async operations.
struct QueueGauge {
    cur: AtomicUsize,
    peak: AtomicUsize,
}

impl QueueGauge {
    /// Count an operation in, returning the RAII lease that counts it
    /// back out (dropped explicitly before the ticket is fulfilled so a
    /// woken waiter already sees the decremented depth; drop-on-unwind
    /// keeps the gauge exact even on unexpected panics).
    fn enter(gauge: &Arc<QueueGauge>) -> GaugeLease {
        let d = gauge.cur.fetch_add(1, Ordering::SeqCst) + 1;
        gauge.peak.fetch_max(d, Ordering::SeqCst);
        GaugeLease(Arc::clone(gauge))
    }
}

struct GaugeLease(Arc<QueueGauge>);

impl Drop for GaugeLease {
    fn drop(&mut self) {
        self.0.cur.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Asynchronous pipeline layer over any [`EmbeddingStore`]: a background
/// worker pool executes pushes and pulls submitted as tickets, so store
/// I/O overlaps training compute and aggregation in *real* wall time.
///
/// The handle is `Send + Sync`; parallel clients share one handle exactly
/// as they share the underlying `Arc<dyn EmbeddingStore>`. Dropping the
/// handle joins the workers after draining in-flight operations.
pub struct AsyncStoreHandle {
    store: Arc<dyn EmbeddingStore>,
    workers: ThreadPool,
    gauge: Arc<QueueGauge>,
}

impl AsyncStoreHandle {
    /// Pipeline over `store` with the default worker count (2: one push
    /// and one prefetch can fly concurrently per handle).
    pub fn new(store: Arc<dyn EmbeddingStore>) -> Self {
        Self::with_workers(store, 2)
    }

    /// Pipeline with an explicit I/O worker count (e.g. one per client
    /// for wide parallel federations).
    pub fn with_workers(store: Arc<dyn EmbeddingStore>, workers: usize) -> Self {
        Self {
            store,
            workers: ThreadPool::new(workers.max(1)),
            gauge: Arc::new(QueueGauge {
                cur: AtomicUsize::new(0),
                peak: AtomicUsize::new(0),
            }),
        }
    }

    /// The wrapped backend (for synchronous calls on the same store).
    pub fn store(&self) -> &Arc<dyn EmbeddingStore> {
        &self.store
    }

    /// Operations currently queued or running.
    pub fn queue_depth(&self) -> usize {
        self.gauge.cur.load(Ordering::SeqCst)
    }

    /// Highest queue depth observed over the handle's lifetime.
    pub fn peak_queue_depth(&self) -> usize {
        self.gauge.peak.load(Ordering::SeqCst)
    }

    /// Submit a batched upsert of all layers for `nodes` to the worker
    /// pool. `per_layer[l]` is row-major `[nodes.len(), hidden]`, exactly
    /// as [`EmbeddingStore::push`] takes it. Join the ticket where the
    /// round actually needs the RPC record.
    pub fn push_async(&self, nodes: Vec<u32>, per_layer: Vec<Vec<f32>>) -> PushTicket {
        let (ticket, slot) = Ticket::new();
        let store = Arc::clone(&self.store);
        let lease = QueueGauge::enter(&self.gauge);
        obs::event("pipeline", "push_issue", vec![("rows", nodes.len().to_string())]);
        let t0 = Instant::now();
        self.workers.execute(move || {
            let mut sp = obs::span("pipeline", "push_work");
            sp.push_attr("rows", nodes.len());
            let epoch = store.epoch();
            // catch panics so a misbehaving backend yields an Err ticket
            // instead of leaving the waiter blocked forever
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                store.push(&nodes, &per_layer)
            }))
            .unwrap_or_else(|_| Err(anyhow::anyhow!("async store push panicked")))
            .map(|rec| PushDone {
                rec,
                wall: t0.elapsed().as_secs_f64(),
                epoch,
            });
            drop(lease);
            drop(sp);
            slot.fulfil(r);
        });
        ticket
    }

    /// Submit a batched pull of all layers for `nodes` to the worker
    /// pool. The completed ticket owns the pulled rows (one tensor per
    /// layer), bit-identical to a synchronous `pull_into` against the
    /// same store state.
    pub fn prefetch(&self, nodes: Vec<u32>, on_demand: bool) -> PullTicket {
        let (ticket, slot) = Ticket::new();
        let store = Arc::clone(&self.store);
        let lease = QueueGauge::enter(&self.gauge);
        obs::event("pipeline", "pull_issue", vec![("rows", nodes.len().to_string())]);
        let t0 = Instant::now();
        self.workers.execute(move || {
            let mut sp = obs::span("pipeline", "pull_work");
            sp.push_attr("rows", nodes.len());
            let epoch = store.epoch();
            let mut rows = Vec::new();
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                store.pull_into(&nodes, on_demand, &mut rows)
            }))
            .unwrap_or_else(|_| Err(anyhow::anyhow!("async store pull panicked")))
            .map(|rec| PullDone {
                rows,
                rec,
                wall: t0.elapsed().as_secs_f64(),
                epoch,
            });
            drop(lease);
            drop(sp);
            slot.fulfil(r);
        });
        ticket
    }
}

/// Default for the session pipeline toggle, read from `OPTIMES_PIPELINE`
/// (`0` / `off` / `false` / `no` disable; anything else — or unset —
/// enables). The CLI's `run --pipeline on|off` flag writes this variable
/// so flag and env agree.
pub fn pipeline_default() -> bool {
    match std::env::var("OPTIMES_PIPELINE") {
        Ok(v) => !matches!(
            v.trim().to_ascii_lowercase().as_str(),
            "0" | "off" | "false" | "no"
        ),
        Err(_) => true,
    }
}

/// Wraps a store and *sleeps out* each RPC's virtual-time cost, turning
/// the [`NetConfig`](super::netsim::NetConfig) model into real wall time
/// (the in-process slab computes virtual RPC times but returns
/// instantly). Values and RPC records are unchanged, so sessions keep
/// bit-exact accuracy parity — only wall clock becomes link-shaped. Used
/// by `bench_roundtime`'s pipeline A/B and the overlap tests to measure
/// real overlap deterministically without a network.
pub struct ThrottledStore {
    inner: Arc<dyn EmbeddingStore>,
}

impl ThrottledStore {
    pub fn new(inner: Arc<dyn EmbeddingStore>) -> Self {
        Self { inner }
    }

    /// Sleep until at least `rec.time` wall seconds have passed since
    /// `t0`, then hand the record back.
    fn throttle(t0: Instant, rec: RpcRecord) -> RpcRecord {
        let elapsed = t0.elapsed().as_secs_f64();
        if rec.time > elapsed {
            std::thread::sleep(std::time::Duration::from_secs_f64(rec.time - elapsed));
        }
        rec
    }
}

impl EmbeddingStore for ThrottledStore {
    fn n_layers(&self) -> usize {
        self.inner.n_layers()
    }

    fn hidden(&self) -> usize {
        self.inner.hidden()
    }

    fn push(&self, nodes: &[u32], per_layer: &[Vec<f32>]) -> Result<RpcRecord> {
        let t0 = Instant::now();
        Ok(Self::throttle(t0, self.inner.push(nodes, per_layer)?))
    }

    fn pull_into(
        &self,
        nodes: &[u32],
        on_demand: bool,
        out: &mut Vec<Vec<f32>>,
    ) -> Result<RpcRecord> {
        let t0 = Instant::now();
        Ok(Self::throttle(t0, self.inner.pull_into(nodes, on_demand, out)?))
    }

    fn stats(&self) -> Result<StoreStats> {
        self.inner.stats()
    }

    fn epoch(&self) -> u64 {
        self.inner.epoch()
    }

    fn codec(&self) -> String {
        self.inner.codec()
    }

    fn describe(&self) -> String {
        format!("throttled({})", self.inner.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::embedding_server::EmbeddingServer;
    use crate::coordinator::netsim::NetConfig;

    fn handle(h: usize) -> AsyncStoreHandle {
        AsyncStoreHandle::new(Arc::new(EmbeddingServer::new(2, h, NetConfig::default())))
    }

    fn rows(nodes: &[u32], h: usize, salt: f32) -> Vec<f32> {
        nodes
            .iter()
            .flat_map(|&n| (0..h).map(move |j| n as f32 + j as f32 * 0.5 + salt))
            .collect()
    }

    #[test]
    fn async_push_then_prefetch_roundtrips() {
        let h = handle(4);
        let nodes = vec![3u32, 7, 11];
        let l1 = rows(&nodes, 4, 0.0);
        let l2 = rows(&nodes, 4, 9.0);
        let push = h.push_async(nodes.clone(), vec![l1.clone(), l2.clone()]);
        let done = push.wait().unwrap();
        assert_eq!(done.rec.rows, 3);
        assert!(done.wall >= 0.0);

        let pull = h.prefetch(vec![7u32, 3], false);
        let done = pull.wait().unwrap();
        assert_eq!(done.rec.rows, 2);
        assert_eq!(&done.rows[0][0..4], &l1[4..8]);
        assert_eq!(&done.rows[0][4..8], &l1[0..4]);
        assert_eq!(&done.rows[1][0..4], &l2[4..8]);
        assert_eq!(h.queue_depth(), 0);
        assert!(h.peak_queue_depth() >= 1);
    }

    #[test]
    fn try_take_returns_ticket_while_in_flight() {
        // throttle with a large latency so the op is reliably pending
        let net = NetConfig {
            latency: 0.15,
            ..NetConfig::default()
        };
        let store: Arc<dyn EmbeddingStore> =
            Arc::new(ThrottledStore::new(Arc::new(EmbeddingServer::new(2, 4, net))));
        let h = AsyncStoreHandle::new(store);
        let ticket = h.prefetch(vec![1u32, 2], true);
        assert!(!ticket.is_done());
        let ticket = match ticket.try_take() {
            Err(t) => t,
            Ok(_) => panic!("throttled op completed implausibly fast"),
        };
        let done = ticket.wait().unwrap();
        assert_eq!(done.rec.kind, crate::coordinator::metrics::RpcKind::PullOnDemand);
        // the throttled RPC's measured wall covers at least its latency
        assert!(done.wall >= 0.15, "wall {}", done.wall);
    }

    #[test]
    fn errors_propagate_through_tickets() {
        let h = handle(4);
        // wrong layer count: the sharded/slab store rejects the push
        let bad = h.push_async(vec![1u32], vec![vec![0.0; 4]; 3]);
        assert!(bad.wait().is_err());
        // handle still serves later operations
        let ok = h.push_async(vec![1u32], vec![vec![0.5; 4], vec![1.5; 4]]);
        assert!(ok.wait().is_ok());
    }

    #[test]
    fn pending_pull_matches_only_its_own_set() {
        let h = handle(4);
        let globals = vec![5u32, 9];
        let p = PendingPull {
            globals: globals.clone(),
            ticket: h.prefetch(globals.clone(), false),
        };
        assert!(p.into_matching(&[5, 9]).is_some());
        let p = PendingPull {
            globals,
            ticket: h.prefetch(vec![5u32, 9], false),
        };
        assert!(p.into_matching(&[9, 5]).is_none());
    }

    #[test]
    fn throttled_store_sleeps_virtual_time_without_changing_records() {
        let net = NetConfig {
            latency: 0.05,
            ..NetConfig::default()
        };
        let raw = Arc::new(EmbeddingServer::new(2, 4, net));
        let throttled = ThrottledStore::new(Arc::clone(&raw) as Arc<dyn EmbeddingStore>);
        let nodes = vec![1u32, 2];
        let l = rows(&nodes, 4, 0.0);
        let t0 = Instant::now();
        let rec = throttled.push(&nodes, &[l.clone(), l]).unwrap();
        let elapsed = t0.elapsed().as_secs_f64();
        assert!(elapsed >= rec.time, "slept {elapsed}, modeled {}", rec.time);
        assert!(rec.time >= 0.05);
        assert!(throttled.describe().starts_with("throttled("));
        assert_eq!(throttled.stats().unwrap().nodes, 2);
    }

    #[test]
    fn pipeline_env_default_semantics() {
        // do not mutate the env here (tests run in parallel); just pin
        // the unset default
        if std::env::var("OPTIMES_PIPELINE").is_err() {
            assert!(pipeline_default());
        }
    }
}
