//! Whole-session checkpointing: a versioned, checksummed on-disk bundle
//! from which a killed run resumes *bit-for-bit* (DESIGN.md §14).
//!
//! The bundle reuses the `GraphFile` container idiom from
//! [`storage::format`](crate::storage::format): fixed header, section
//! table, FNV-1a checksum per section plus one over the header+table, so
//! a flipped byte anywhere is a named error — never a panic, never a
//! silent partial resume. Sections:
//!
//! | # | name         | contents                                         |
//! |---|--------------|--------------------------------------------------|
//! | 0 | `config`     | identity strings + scalars, validated on resume  |
//! | 1 | `cursor`     | delay clock, pretrained flag                     |
//! | 2 | `model`      | global model parameters (bit-exact f32)          |
//! | 3 | `clients`    | per-client RNG streams, epoch cursors, optimizer |
//! | 4 | `membership` | the churn ledger (replayed onto the partition)   |
//! | 5 | `staleness`  | pending late updates + drop counter              |
//! | 6 | `metrics`    | completed-round curve prefix (accuracy etc.)     |
//! | 7 | `store`      | [`SnapshotStore`](super::resilience::SnapshotStore) dump |
//!
//! All floats travel as raw IEEE bits (`to_bits`/`from_bits`) — printing
//! and re-parsing decimal would break bit-parity. Checkpoints are written
//! at round boundaries only, where every push is joined and the in-flight
//! pipeline prefetch is value-transparent, so nothing transient needs to
//! be captured. Writes go to a temp file then `rename`, so a crash while
//! checkpointing leaves the previous bundle intact.

use std::fs;
use std::io::{Cursor, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use super::codec::{
    read_f32s, read_u32, read_u32s, read_u64, write_f32s, write_u32, write_u32s, write_u64,
};
use super::lifecycle::{MembershipChange, MembershipKind};
use super::metrics::{PhaseTimes, RoundMetrics, SessionMetrics};
use super::rounds::PendingSnapshot;
use crate::graph::Graph;
use crate::runtime::ModelState;
use crate::storage::format::Fnv64;

pub const MAGIC: [u8; 8] = *b"OPTMCKPT";
pub const VERSION: u32 = 1;
const ENDIAN_MARK: u32 = 0x0102_0304;
/// Bundle file name inside the checkpoint directory.
pub const CHECKPOINT_FILE: &str = "session.ckpt";

const N_SECTIONS: usize = 8;
const SECTION_NAMES: [&str; N_SECTIONS] = [
    "config",
    "cursor",
    "model",
    "clients",
    "membership",
    "staleness",
    "metrics",
    "store",
];
const HEADER_BYTES: usize = 56;
const TABLE_BYTES: usize = N_SECTIONS * 24;
const META_CHECKSUM_OFF: usize = HEADER_BYTES + TABLE_BYTES; // 248
const SECTIONS_START: usize = META_CHECKSUM_OFF + 8; // 256
const SECTION_ALIGN: usize = 64;

fn align_up(v: usize) -> usize {
    v.div_ceil(SECTION_ALIGN) * SECTION_ALIGN
}

/// Bundle path inside a checkpoint directory.
pub fn checkpoint_path(dir: &Path) -> PathBuf {
    dir.join(CHECKPOINT_FILE)
}

/// `OPTIMES_CHECKPOINT` = `DIR` or `DIR:EVERY` → checkpoint every `EVERY`
/// rounds into `DIR` (default every round). Warn-and-ignore on a bad
/// cadence, matching the other env knobs.
pub fn checkpoint_from_env() -> Option<(PathBuf, usize)> {
    parse_checkpoint_spec(&std::env::var("OPTIMES_CHECKPOINT").ok()?)
}

/// Parse a `DIR` / `DIR:EVERY` checkpoint spec (the `OPTIMES_CHECKPOINT`
/// grammar, also used by the CLI flags).
pub fn parse_checkpoint_spec(raw: &str) -> Option<(PathBuf, usize)> {
    let raw = raw.trim();
    if raw.is_empty() {
        return None;
    }
    if let Some((dir, every)) = raw.rsplit_once(':') {
        if let Ok(n) = every.parse::<usize>() {
            if n == 0 {
                crate::log!(Warn, "checkpoint cadence 0 is invalid; using 1");
                return Some((PathBuf::from(dir), 1));
            }
            return Some((PathBuf::from(dir), n));
        }
    }
    Some((PathBuf::from(raw), 1))
}

/// Structural fingerprint of the training graph, stored in the bundle and
/// verified on resume: resuming against a different dataset (or a
/// different scale of the same generator) must be a loud error, because
/// every partition id and vertex id in the bundle is meaningless on any
/// other graph.
pub fn graph_fingerprint(g: &Graph) -> u64 {
    let mut h = Fnv64::new();
    h.update(&(g.n as u64).to_le_bytes());
    h.update(&(g.out.m() as u64).to_le_bytes());
    h.update(&(g.feat_dim as u64).to_le_bytes());
    h.update(&(g.classes as u64).to_le_bytes());
    for v in 0..g.n {
        h.update(&(g.out.neighbors(v as u32).len() as u32).to_le_bytes());
    }
    for &v in &g.train_nodes {
        h.update(&v.to_le_bytes());
    }
    for &v in &g.test_nodes {
        h.update(&v.to_le_bytes());
    }
    h.digest()
}

/// Session identity captured at checkpoint time; every field is validated
/// against the resuming process before any state is applied.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointConfig {
    pub dataset: String,
    pub strategy: String,
    pub policy: String,
    pub partitioner: String,
    /// `store.codec()` of the checkpointed plane: a bundle written
    /// through int8 replays re-quantized rows, so resuming it into a raw
    /// plane would silently diverge — rejected instead.
    pub codec: String,
    /// Engine model kind (`gc`/`sage`) and sampling fanout, so `resume`
    /// can rebuild the identical engine without re-passing flags.
    pub model: String,
    pub fanout: usize,
    /// The scripted churn schedule (`ChurnSpec::spec_string`), so resume
    /// still fires the events scheduled after the checkpointed round.
    pub churn: String,
    pub seed: u64,
    /// Initial client count (round-0 membership; churn is in the ledger).
    pub clients: usize,
    /// Rounds planned when the checkpoint was written (informational —
    /// resume may extend).
    pub rounds: usize,
    pub epochs: usize,
    pub epoch_batches: usize,
    pub eval_batches: usize,
    /// Learning rate, bit-exact.
    pub lr: f32,
    pub staleness: usize,
    pub pipeline: bool,
    pub graph_fingerprint: u64,
}

/// Per-client resumable state: everything a [`Client`](super::Client)
/// mutates across rounds that survives a round boundary. Caches and pull
/// scratch are rebuilt (invalidated at round start anyway); the in-flight
/// pipeline prefetch is value-transparent and re-issued.
#[derive(Clone, Debug)]
pub struct ClientCheckpoint {
    pub id: usize,
    pub rng: [u64; 4],
    pub sampler_rng: [u64; 4],
    pub train_cursor: usize,
    pub train_order: Vec<u32>,
    /// OPP prefetch scores (serialized, not recomputed: a churn rebuild
    /// before the checkpoint may have re-scored this client).
    pub scores: Vec<f32>,
    pub prefetch_rows: Vec<u32>,
    pub state: ModelState,
}

/// Completed-round curve prefix: the fields of [`RoundMetrics`] that feed
/// reports and parity checks. Per-client traces are not serialized (the
/// report plane collapses them; documented limitation).
#[derive(Clone, Debug, Default)]
pub struct RoundCheckpoint {
    pub round: usize,
    pub accuracy: f64,
    pub val_loss: f64,
    pub round_time: f64,
    pub failovers: usize,
    pub bytes_tx: usize,
    pub bytes_rx: usize,
    pub quorum_wait: f64,
    pub stragglers_late: usize,
    pub stragglers_dropped: usize,
    pub stale_folded: usize,
    pub stale_weight_applied: f64,
    pub mean_phases: PhaseTimes,
    pub critical: PhaseTimes,
    pub active_clients: Vec<usize>,
}

impl RoundCheckpoint {
    pub fn from_metrics(r: &RoundMetrics) -> Self {
        Self {
            round: r.round,
            accuracy: r.accuracy,
            val_loss: r.val_loss,
            round_time: r.round_time,
            failovers: r.failovers,
            bytes_tx: r.bytes_tx,
            bytes_rx: r.bytes_rx,
            quorum_wait: r.quorum_wait,
            stragglers_late: r.stragglers_late,
            stragglers_dropped: r.stragglers_dropped,
            stale_folded: r.stale_folded,
            stale_weight_applied: r.stale_weight_applied,
            mean_phases: r.mean_phases,
            critical: r.critical,
            active_clients: r.active_clients.clone(),
        }
    }

    pub fn into_metrics(self) -> RoundMetrics {
        RoundMetrics {
            round: self.round,
            accuracy: self.accuracy,
            val_loss: self.val_loss,
            round_time: self.round_time,
            failovers: self.failovers,
            bytes_tx: self.bytes_tx,
            bytes_rx: self.bytes_rx,
            quorum_wait: self.quorum_wait,
            stragglers_late: self.stragglers_late,
            stragglers_dropped: self.stragglers_dropped,
            stale_folded: self.stale_folded,
            stale_weight_applied: self.stale_weight_applied,
            mean_phases: self.mean_phases,
            critical: self.critical,
            active_clients: self.active_clients,
            ..Default::default()
        }
    }
}

/// Session-level metric counters that ride along with the curve prefix.
#[derive(Clone, Debug, Default)]
pub struct MetricsCheckpoint {
    pub server_embeddings: usize,
    pub pull_candidates: usize,
    pub retained_remotes: usize,
    pub bytes_raw_tx: usize,
    pub bytes_raw_rx: usize,
    pub store_epoch: u64,
    pub rounds: Vec<RoundCheckpoint>,
}

impl MetricsCheckpoint {
    pub fn from_metrics(m: &SessionMetrics) -> Self {
        Self {
            server_embeddings: m.server_embeddings,
            pull_candidates: m.pull_candidates,
            retained_remotes: m.retained_remotes,
            bytes_raw_tx: m.bytes_raw_tx,
            bytes_raw_rx: m.bytes_raw_rx,
            store_epoch: m.store_epoch,
            rounds: m.rounds.iter().map(RoundCheckpoint::from_metrics).collect(),
        }
    }

    /// Overwrite the resumable parts of freshly-built session metrics.
    pub fn apply(self, m: &mut SessionMetrics) {
        m.server_embeddings = self.server_embeddings;
        m.pull_candidates = self.pull_candidates;
        m.retained_remotes = self.retained_remotes;
        m.bytes_raw_tx = self.bytes_raw_tx;
        m.bytes_raw_rx = self.bytes_raw_rx;
        m.store_epoch = self.store_epoch;
        m.rounds = self.rounds.into_iter().map(RoundCheckpoint::into_metrics).collect();
    }
}

/// The complete resumable session state at a round boundary.
#[derive(Clone, Debug)]
pub struct CheckpointBundle {
    pub config: CheckpointConfig,
    pub completed_rounds: usize,
    pub delay_clock: f64,
    pub pretrained: bool,
    /// Global model parameters.
    pub global: Vec<Vec<f32>>,
    pub clients: Vec<ClientCheckpoint>,
    /// Churn ledger, replayed verbatim onto a fresh round-0 partition.
    pub ledger: Vec<MembershipChange>,
    /// Staleness queue of the non-sync round policies.
    pub pending: Vec<PendingSnapshot>,
    pub dropped_total: usize,
    pub metrics: MetricsCheckpoint,
    /// Raw [`SnapshotStore`](super::resilience::SnapshotStore) dump;
    /// replayed as pushes through the resuming plane's own codec, so a
    /// quantizing wire re-quantizes identically.
    pub snapshot: Vec<u8>,
}

// ---- primitive helpers ---------------------------------------------------

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    write_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes()).context("write string")
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let n = read_u32(r)? as usize;
    ensure!(n <= 4096, "absurd string length {n} in checkpoint");
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf).context("read string")?;
    String::from_utf8(buf).context("checkpoint string is not utf-8")
}

fn write_f64(w: &mut impl Write, v: f64) -> Result<()> {
    write_u64(w, v.to_bits())
}

fn read_f64(r: &mut impl Read) -> Result<f64> {
    Ok(f64::from_bits(read_u64(r)?))
}

fn write_rng(w: &mut impl Write, s: [u64; 4]) -> Result<()> {
    for v in s {
        write_u64(w, v)?;
    }
    Ok(())
}

fn read_rng(r: &mut impl Read) -> Result<[u64; 4]> {
    let mut s = [0u64; 4];
    for v in s.iter_mut() {
        *v = read_u64(r)?;
    }
    Ok(s)
}

fn write_vecs(w: &mut impl Write, vs: &[Vec<f32>]) -> Result<()> {
    write_u32(w, vs.len() as u32)?;
    for v in vs {
        write_u32(w, v.len() as u32)?;
        write_f32s(w, v)?;
    }
    Ok(())
}

fn read_vecs(r: &mut impl Read) -> Result<Vec<Vec<f32>>> {
    let n = read_u32(r)? as usize;
    ensure!(n <= 1024, "absurd layer count {n} in checkpoint");
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let len = read_u32(r)? as usize;
        out.push(read_f32s(r, len)?);
    }
    Ok(out)
}

fn write_state(w: &mut impl Write, st: &ModelState) -> Result<()> {
    write_vecs(w, &st.params)?;
    write_vecs(w, &st.m)?;
    write_vecs(w, &st.v)?;
    write_u32(w, st.t.to_bits())
}

fn read_state(r: &mut impl Read) -> Result<ModelState> {
    Ok(ModelState {
        params: read_vecs(r)?,
        m: read_vecs(r)?,
        v: read_vecs(r)?,
        t: f32::from_bits(read_u32(r)?),
    })
}

fn write_phases(w: &mut impl Write, p: &PhaseTimes) -> Result<()> {
    for v in [p.pull, p.train, p.dyn_pull, p.push, p.push_hidden] {
        write_f64(w, v)?;
    }
    Ok(())
}

fn read_phases(r: &mut impl Read) -> Result<PhaseTimes> {
    Ok(PhaseTimes {
        pull: read_f64(r)?,
        train: read_f64(r)?,
        dyn_pull: read_f64(r)?,
        push: read_f64(r)?,
        push_hidden: read_f64(r)?,
    })
}

// ---- section encoders ----------------------------------------------------

impl CheckpointBundle {
    fn encode_config(&self) -> Result<Vec<u8>> {
        let mut w = Vec::new();
        let c = &self.config;
        write_str(&mut w, &c.dataset)?;
        write_str(&mut w, &c.strategy)?;
        write_str(&mut w, &c.policy)?;
        write_str(&mut w, &c.partitioner)?;
        write_str(&mut w, &c.codec)?;
        write_str(&mut w, &c.model)?;
        write_u32(&mut w, c.fanout as u32)?;
        write_str(&mut w, &c.churn)?;
        write_u64(&mut w, c.seed)?;
        write_u32(&mut w, c.clients as u32)?;
        write_u64(&mut w, c.rounds as u64)?;
        write_u32(&mut w, c.epochs as u32)?;
        write_u32(&mut w, c.epoch_batches as u32)?;
        write_u32(&mut w, c.eval_batches as u32)?;
        write_u32(&mut w, c.lr.to_bits())?;
        write_u32(&mut w, c.staleness as u32)?;
        write_u32(&mut w, c.pipeline as u32)?;
        write_u64(&mut w, c.graph_fingerprint)?;
        Ok(w)
    }

    fn decode_config(mut r: &[u8]) -> Result<CheckpointConfig> {
        Ok(CheckpointConfig {
            dataset: read_str(&mut r)?,
            strategy: read_str(&mut r)?,
            policy: read_str(&mut r)?,
            partitioner: read_str(&mut r)?,
            codec: read_str(&mut r)?,
            model: read_str(&mut r)?,
            fanout: read_u32(&mut r)? as usize,
            churn: read_str(&mut r)?,
            seed: read_u64(&mut r)?,
            clients: read_u32(&mut r)? as usize,
            rounds: read_u64(&mut r)? as usize,
            epochs: read_u32(&mut r)? as usize,
            epoch_batches: read_u32(&mut r)? as usize,
            eval_batches: read_u32(&mut r)? as usize,
            lr: f32::from_bits(read_u32(&mut r)?),
            staleness: read_u32(&mut r)? as usize,
            pipeline: read_u32(&mut r)? != 0,
            graph_fingerprint: read_u64(&mut r)?,
        })
    }

    fn encode_cursor(&self) -> Result<Vec<u8>> {
        let mut w = Vec::new();
        write_f64(&mut w, self.delay_clock)?;
        write_u32(&mut w, self.pretrained as u32)?;
        Ok(w)
    }

    fn encode_clients(&self) -> Result<Vec<u8>> {
        let mut w = Vec::new();
        write_u32(&mut w, self.clients.len() as u32)?;
        for c in &self.clients {
            write_u32(&mut w, c.id as u32)?;
            write_rng(&mut w, c.rng)?;
            write_rng(&mut w, c.sampler_rng)?;
            write_u64(&mut w, c.train_cursor as u64)?;
            write_u32(&mut w, c.train_order.len() as u32)?;
            write_u32s(&mut w, &c.train_order)?;
            write_u32(&mut w, c.scores.len() as u32)?;
            write_f32s(&mut w, &c.scores)?;
            write_u32(&mut w, c.prefetch_rows.len() as u32)?;
            write_u32s(&mut w, &c.prefetch_rows)?;
            write_state(&mut w, &c.state)?;
        }
        Ok(w)
    }

    fn decode_clients(mut r: &[u8]) -> Result<Vec<ClientCheckpoint>> {
        let n = read_u32(&mut r)? as usize;
        ensure!(n <= 65_536, "absurd client count {n} in checkpoint");
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let id = read_u32(&mut r)? as usize;
            let rng = read_rng(&mut r)?;
            let sampler_rng = read_rng(&mut r)?;
            let train_cursor = read_u64(&mut r)? as usize;
            let n_order = read_u32(&mut r)? as usize;
            let train_order = read_u32s(&mut r, n_order)?;
            let n_scores = read_u32(&mut r)? as usize;
            let scores = read_f32s(&mut r, n_scores)?;
            let n_pref = read_u32(&mut r)? as usize;
            let prefetch_rows = read_u32s(&mut r, n_pref)?;
            let state = read_state(&mut r)?;
            out.push(ClientCheckpoint {
                id,
                rng,
                sampler_rng,
                train_cursor,
                train_order,
                scores,
                prefetch_rows,
                state,
            });
        }
        Ok(out)
    }

    fn encode_membership(&self) -> Result<Vec<u8>> {
        let mut w = Vec::new();
        write_u32(&mut w, self.ledger.len() as u32)?;
        for ch in &self.ledger {
            write_u64(&mut w, ch.round as u64)?;
            let (tag, id) = match ch.kind {
                MembershipKind::Left(id) => (0u32, id),
                MembershipKind::Joined(id) => (1u32, id),
            };
            write_u32(&mut w, tag)?;
            write_u32(&mut w, id as u32)?;
            write_u32(&mut w, ch.moved.len() as u32)?;
            for &(v, from, to) in &ch.moved {
                write_u32(&mut w, v)?;
                write_u32(&mut w, from)?;
                write_u32(&mut w, to)?;
            }
        }
        Ok(w)
    }

    fn decode_membership(mut r: &[u8]) -> Result<Vec<MembershipChange>> {
        let n = read_u32(&mut r)? as usize;
        ensure!(n <= 1_000_000, "absurd ledger length {n} in checkpoint");
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let round = read_u64(&mut r)? as usize;
            let tag = read_u32(&mut r)?;
            let id = read_u32(&mut r)? as usize;
            let kind = match tag {
                0 => MembershipKind::Left(id),
                1 => MembershipKind::Joined(id),
                other => bail!("unknown membership kind tag {other} in checkpoint"),
            };
            let n_moved = read_u32(&mut r)? as usize;
            ensure!(
                n_moved <= 100_000_000,
                "absurd move count {n_moved} in checkpoint"
            );
            let mut moved = Vec::with_capacity(n_moved);
            for _ in 0..n_moved {
                let v = read_u32(&mut r)?;
                let from = read_u32(&mut r)?;
                let to = read_u32(&mut r)?;
                moved.push((v, from, to));
            }
            out.push(MembershipChange { round, kind, moved });
        }
        Ok(out)
    }

    fn encode_staleness(&self) -> Result<Vec<u8>> {
        let mut w = Vec::new();
        write_u32(&mut w, self.pending.len() as u32)?;
        for p in &self.pending {
            write_f64(&mut w, p.weight)?;
            write_u64(&mut w, p.round as u64)?;
            write_f64(&mut w, p.arrival)?;
            write_state(&mut w, &p.state)?;
        }
        write_u64(&mut w, self.dropped_total as u64)?;
        Ok(w)
    }

    fn decode_staleness(mut r: &[u8]) -> Result<(Vec<PendingSnapshot>, usize)> {
        let n = read_u32(&mut r)? as usize;
        ensure!(n <= 65_536, "absurd staleness queue length {n} in checkpoint");
        let mut pending = Vec::with_capacity(n);
        for _ in 0..n {
            let weight = read_f64(&mut r)?;
            let round = read_u64(&mut r)? as usize;
            let arrival = read_f64(&mut r)?;
            let state = read_state(&mut r)?;
            pending.push(PendingSnapshot {
                state,
                weight,
                round,
                arrival,
            });
        }
        let dropped_total = read_u64(&mut r)? as usize;
        Ok((pending, dropped_total))
    }

    fn encode_metrics(&self) -> Result<Vec<u8>> {
        let mut w = Vec::new();
        let m = &self.metrics;
        write_u64(&mut w, m.server_embeddings as u64)?;
        write_u64(&mut w, m.pull_candidates as u64)?;
        write_u64(&mut w, m.retained_remotes as u64)?;
        write_u64(&mut w, m.bytes_raw_tx as u64)?;
        write_u64(&mut w, m.bytes_raw_rx as u64)?;
        write_u64(&mut w, m.store_epoch)?;
        write_u32(&mut w, m.rounds.len() as u32)?;
        for r in &m.rounds {
            write_u64(&mut w, r.round as u64)?;
            write_f64(&mut w, r.accuracy)?;
            write_f64(&mut w, r.val_loss)?;
            write_f64(&mut w, r.round_time)?;
            write_u64(&mut w, r.failovers as u64)?;
            write_u64(&mut w, r.bytes_tx as u64)?;
            write_u64(&mut w, r.bytes_rx as u64)?;
            write_f64(&mut w, r.quorum_wait)?;
            write_u64(&mut w, r.stragglers_late as u64)?;
            write_u64(&mut w, r.stragglers_dropped as u64)?;
            write_u64(&mut w, r.stale_folded as u64)?;
            write_f64(&mut w, r.stale_weight_applied)?;
            write_phases(&mut w, &r.mean_phases)?;
            write_phases(&mut w, &r.critical)?;
            write_u32(&mut w, r.active_clients.len() as u32)?;
            for &id in &r.active_clients {
                write_u32(&mut w, id as u32)?;
            }
        }
        Ok(w)
    }

    fn decode_metrics(mut r: &[u8]) -> Result<MetricsCheckpoint> {
        let mut m = MetricsCheckpoint {
            server_embeddings: read_u64(&mut r)? as usize,
            pull_candidates: read_u64(&mut r)? as usize,
            retained_remotes: read_u64(&mut r)? as usize,
            bytes_raw_tx: read_u64(&mut r)? as usize,
            bytes_raw_rx: read_u64(&mut r)? as usize,
            store_epoch: read_u64(&mut r)?,
            rounds: Vec::new(),
        };
        let n = read_u32(&mut r)? as usize;
        ensure!(n <= 10_000_000, "absurd round count {n} in checkpoint");
        for _ in 0..n {
            let mut rc = RoundCheckpoint {
                round: read_u64(&mut r)? as usize,
                accuracy: read_f64(&mut r)?,
                val_loss: read_f64(&mut r)?,
                round_time: read_f64(&mut r)?,
                failovers: read_u64(&mut r)? as usize,
                bytes_tx: read_u64(&mut r)? as usize,
                bytes_rx: read_u64(&mut r)? as usize,
                quorum_wait: read_f64(&mut r)?,
                stragglers_late: read_u64(&mut r)? as usize,
                stragglers_dropped: read_u64(&mut r)? as usize,
                stale_folded: read_u64(&mut r)? as usize,
                stale_weight_applied: read_f64(&mut r)?,
                mean_phases: read_phases(&mut r)?,
                critical: read_phases(&mut r)?,
                active_clients: Vec::new(),
            };
            let n_active = read_u32(&mut r)? as usize;
            ensure!(
                n_active <= 65_536,
                "absurd active-client count {n_active} in checkpoint"
            );
            for _ in 0..n_active {
                rc.active_clients.push(read_u32(&mut r)? as usize);
            }
            m.rounds.push(rc);
        }
        Ok(m)
    }

    // ---- container ---------------------------------------------------------

    /// Serialize the bundle into the checksummed container.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let sections: [Vec<u8>; N_SECTIONS] = [
            self.encode_config()?,
            self.encode_cursor()?,
            {
                let mut w = Vec::new();
                write_vecs(&mut w, &self.global)?;
                w
            },
            self.encode_clients()?,
            self.encode_membership()?,
            self.encode_staleness()?,
            self.encode_metrics()?,
            self.snapshot.clone(),
        ];

        let mut header = Vec::with_capacity(HEADER_BYTES);
        header.extend_from_slice(&MAGIC);
        header.extend_from_slice(&VERSION.to_le_bytes());
        header.extend_from_slice(&ENDIAN_MARK.to_le_bytes());
        header.extend_from_slice(&(self.completed_rounds as u64).to_le_bytes());
        header.extend_from_slice(&self.config.seed.to_le_bytes());
        header.extend_from_slice(&self.config.graph_fingerprint.to_le_bytes());
        let flags = (self.pretrained as u64) | ((self.config.pipeline as u64) << 1);
        header.extend_from_slice(&flags.to_le_bytes());
        header.extend_from_slice(&0u64.to_le_bytes()); // reserved
        debug_assert_eq!(header.len(), HEADER_BYTES);

        let mut table = Vec::with_capacity(TABLE_BYTES);
        let mut offset = SECTIONS_START;
        let mut placed: Vec<(usize, &Vec<u8>)> = Vec::with_capacity(N_SECTIONS);
        for sec in &sections {
            let mut h = Fnv64::new();
            h.update(sec);
            table.extend_from_slice(&(offset as u64).to_le_bytes());
            table.extend_from_slice(&(sec.len() as u64).to_le_bytes());
            table.extend_from_slice(&h.digest().to_le_bytes());
            placed.push((offset, sec));
            offset = align_up(offset + sec.len());
        }
        debug_assert_eq!(table.len(), TABLE_BYTES);

        let mut meta = Fnv64::new();
        meta.update(&header);
        meta.update(&table);

        let mut out = vec![0u8; offset];
        out[..HEADER_BYTES].copy_from_slice(&header);
        out[HEADER_BYTES..META_CHECKSUM_OFF].copy_from_slice(&table);
        out[META_CHECKSUM_OFF..SECTIONS_START].copy_from_slice(&meta.digest().to_le_bytes());
        for (off, sec) in placed {
            out[off..off + sec.len()].copy_from_slice(sec);
        }
        Ok(out)
    }

    /// Parse and fully validate a serialized bundle. Every corruption —
    /// header, table, or any section — is a named error.
    pub fn from_bytes(bytes: &[u8]) -> Result<CheckpointBundle> {
        ensure!(
            bytes.len() >= SECTIONS_START,
            "checkpoint truncated ({} bytes, need at least {SECTIONS_START})",
            bytes.len()
        );
        let magic = &bytes[..8];
        ensure!(
            magic == MAGIC,
            "checkpoint: bad magic {:02x?} (expected {:02x?})",
            magic,
            MAGIC
        );
        let version = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
        ensure!(
            version == VERSION,
            "checkpoint: unsupported version {version} (this build reads version {VERSION})"
        );
        let endian = u32::from_le_bytes(bytes[12..16].try_into().expect("4 bytes"));
        ensure!(
            endian == ENDIAN_MARK,
            "checkpoint: endian marker {endian:#010x} does not match {ENDIAN_MARK:#010x}"
        );
        let completed_rounds =
            u64::from_le_bytes(bytes[16..24].try_into().expect("8 bytes")) as usize;
        let flags = u64::from_le_bytes(bytes[40..48].try_into().expect("8 bytes"));
        let pretrained = flags & 1 != 0;

        let stored_meta = u64::from_le_bytes(
            bytes[META_CHECKSUM_OFF..SECTIONS_START]
                .try_into()
                .expect("8 bytes"),
        );
        let mut meta = Fnv64::new();
        meta.update(&bytes[..META_CHECKSUM_OFF]);
        ensure!(
            meta.digest() == stored_meta,
            "checkpoint: header checksum mismatch (stored {stored_meta:#018x}, computed {:#018x})",
            meta.digest()
        );

        let mut secs: Vec<&[u8]> = Vec::with_capacity(N_SECTIONS);
        for (i, name) in SECTION_NAMES.iter().enumerate() {
            let e = HEADER_BYTES + i * 24;
            let off = u64::from_le_bytes(bytes[e..e + 8].try_into().expect("8 bytes")) as usize;
            let len =
                u64::from_le_bytes(bytes[e + 8..e + 16].try_into().expect("8 bytes")) as usize;
            let sum = u64::from_le_bytes(bytes[e + 16..e + 24].try_into().expect("8 bytes"));
            ensure!(
                off.checked_add(len).is_some_and(|end| end <= bytes.len()),
                "checkpoint: section \"{name}\" out of bounds (offset {off}, len {len}, file {})",
                bytes.len()
            );
            let sec = &bytes[off..off + len];
            let mut h = Fnv64::new();
            h.update(sec);
            ensure!(
                h.digest() == sum,
                "checkpoint: checksum mismatch in section \"{name}\" \
                 (stored {sum:#018x}, computed {:#018x})",
                h.digest()
            );
            secs.push(sec);
        }

        let config =
            Self::decode_config(secs[0]).context("checkpoint: section \"config\" malformed")?;
        let mut cur = secs[1];
        let delay_clock = read_f64(&mut cur).context("checkpoint: section \"cursor\" malformed")?;
        let cursor_pretrained =
            read_u32(&mut cur).context("checkpoint: section \"cursor\" malformed")? != 0;
        ensure!(
            cursor_pretrained == pretrained,
            "checkpoint: cursor/header pretrained flags disagree"
        );
        let global = read_vecs(&mut Cursor::new(secs[2]))
            .context("checkpoint: section \"model\" malformed")?;
        let clients =
            Self::decode_clients(secs[3]).context("checkpoint: section \"clients\" malformed")?;
        let ledger = Self::decode_membership(secs[4])
            .context("checkpoint: section \"membership\" malformed")?;
        let (pending, dropped_total) = Self::decode_staleness(secs[5])
            .context("checkpoint: section \"staleness\" malformed")?;
        let metrics =
            Self::decode_metrics(secs[6]).context("checkpoint: section \"metrics\" malformed")?;
        ensure!(
            metrics.rounds.len() == completed_rounds,
            "checkpoint: header says {completed_rounds} completed rounds but the metrics \
             section holds {}",
            metrics.rounds.len()
        );
        Ok(CheckpointBundle {
            config,
            completed_rounds,
            delay_clock,
            pretrained,
            global,
            clients,
            ledger,
            pending,
            dropped_total,
            metrics,
            snapshot: secs[7].to_vec(),
        })
    }

    /// Atomically write the bundle into `dir` (created if absent): temp
    /// file + rename, so a crash mid-write never clobbers the previous
    /// checkpoint.
    pub fn save(&self, dir: &Path) -> Result<PathBuf> {
        fs::create_dir_all(dir)
            .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
        let path = checkpoint_path(dir);
        let tmp = dir.join(format!("{CHECKPOINT_FILE}.tmp"));
        let bytes = self.to_bytes()?;
        fs::write(&tmp, &bytes).with_context(|| format!("write {}", tmp.display()))?;
        fs::rename(&tmp, &path)
            .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
        Ok(path)
    }

    /// Load and validate the bundle in `dir`.
    pub fn load(dir: &Path) -> Result<CheckpointBundle> {
        let path = checkpoint_path(dir);
        let bytes =
            fs::read(&path).with_context(|| format!("read checkpoint {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("checkpoint {}", path.display()))
    }
}

/// Replay a checkpointed snapshot dump into a fresh store plane,
/// returning the warm [`SnapshotStore`](super::resilience::SnapshotStore)
/// decorator (pushes route through the plane's own codec, so quantizing
/// wires re-quantize identically).
pub fn restore_snapshot(
    snapshot: &[u8],
    inner: Arc<dyn super::store::EmbeddingStore>,
) -> Result<super::resilience::SnapshotStore> {
    let mut r = Cursor::new(snapshot);
    super::resilience::SnapshotStore::restore(&mut r, inner)
        .context("checkpoint: section \"store\" did not replay")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny;

    fn tiny_state(seed: u64) -> ModelState {
        let mut rng = crate::util::rng::Rng::new(seed, 7);
        let mk = |n: usize, rng: &mut crate::util::rng::Rng| -> Vec<Vec<f32>> {
            (0..2).map(|_| (0..n).map(|_| rng.f32() - 0.5).collect()).collect()
        };
        ModelState {
            params: mk(6, &mut rng),
            m: mk(6, &mut rng),
            v: mk(6, &mut rng),
            t: 3.0,
        }
    }

    fn bundle() -> CheckpointBundle {
        let st = tiny_state(5);
        CheckpointBundle {
            config: CheckpointConfig {
                dataset: "tiny".into(),
                strategy: "OPP".into(),
                policy: "quorum:3".into(),
                partitioner: "metis".into(),
                codec: "int8".into(),
                model: "gc".into(),
                fanout: 3,
                churn: "leave@2:1,join@5".into(),
                seed: 42,
                clients: 4,
                rounds: 8,
                epochs: 2,
                epoch_batches: 4,
                eval_batches: 4,
                lr: 0.003,
                staleness: 2,
                pipeline: true,
                graph_fingerprint: 0xDEAD_BEEF,
            },
            completed_rounds: 2,
            delay_clock: 1.25,
            pretrained: true,
            global: st.params.clone(),
            clients: vec![ClientCheckpoint {
                id: 1,
                rng: [1, 2, 3, 4],
                sampler_rng: [5, 6, 7, 8],
                train_cursor: 9,
                train_order: vec![3, 1, 2],
                scores: vec![0.5, -0.25],
                prefetch_rows: vec![0, 2],
                state: st.clone(),
            }],
            ledger: vec![MembershipChange {
                round: 1,
                kind: MembershipKind::Left(2),
                moved: vec![(7, 2, 0), (9, 2, 1)],
            }],
            pending: vec![PendingSnapshot {
                state: st,
                weight: 2.0,
                round: 1,
                arrival: 0.75,
            }],
            dropped_total: 1,
            metrics: MetricsCheckpoint {
                server_embeddings: 10,
                pull_candidates: 20,
                retained_remotes: 15,
                bytes_raw_tx: 1000,
                bytes_raw_rx: 900,
                store_epoch: 3,
                rounds: vec![
                    RoundCheckpoint {
                        round: 0,
                        accuracy: 0.5,
                        val_loss: 1.25,
                        active_clients: vec![0, 1, 2, 3],
                        ..Default::default()
                    },
                    RoundCheckpoint {
                        round: 1,
                        accuracy: 0.625,
                        val_loss: 1.0,
                        active_clients: vec![0, 1, 3],
                        ..Default::default()
                    },
                ],
            },
            snapshot: vec![0xAB; 37],
        }
    }

    #[test]
    fn bundle_round_trips_bit_exact() {
        let b = bundle();
        let bytes = b.to_bytes().unwrap();
        let back = CheckpointBundle::from_bytes(&bytes).unwrap();
        assert_eq!(back.config, b.config);
        assert_eq!(back.completed_rounds, 2);
        assert_eq!(back.delay_clock.to_bits(), b.delay_clock.to_bits());
        assert!(back.pretrained);
        assert_eq!(back.global, b.global);
        assert_eq!(back.clients.len(), 1);
        let (c, c0) = (&back.clients[0], &b.clients[0]);
        assert_eq!((c.id, c.rng, c.sampler_rng), (c0.id, c0.rng, c0.sampler_rng));
        assert_eq!(c.train_order, c0.train_order);
        assert_eq!(c.scores, c0.scores);
        assert_eq!(c.state.params, c0.state.params);
        assert_eq!(c.state.v, c0.state.v);
        assert_eq!(back.ledger, b.ledger);
        assert_eq!(back.pending.len(), 1);
        assert_eq!(back.pending[0].weight.to_bits(), 2.0f64.to_bits());
        assert_eq!(back.dropped_total, 1);
        assert_eq!(back.metrics.rounds.len(), 2);
        assert_eq!(back.metrics.rounds[1].active_clients, vec![0, 1, 3]);
        assert_eq!(back.snapshot, b.snapshot);
        // re-serialization is byte-identical (stable format)
        assert_eq!(back.to_bytes().unwrap(), bytes);
    }

    #[test]
    fn every_section_detects_a_flipped_byte() {
        let b = bundle();
        let bytes = b.to_bytes().unwrap();
        for (i, name) in SECTION_NAMES.iter().enumerate() {
            let e = HEADER_BYTES + i * 24;
            let off = u64::from_le_bytes(bytes[e..e + 8].try_into().unwrap()) as usize;
            let len = u64::from_le_bytes(bytes[e + 8..e + 16].try_into().unwrap()) as usize;
            assert!(len > 0, "section {name} empty — probe has no byte to flip");
            for probe in [off, off + len - 1] {
                let mut corrupt = bytes.clone();
                corrupt[probe] ^= 0xFF;
                let err = CheckpointBundle::from_bytes(&corrupt)
                    .expect_err(&format!("flip at {probe} in {name} must fail"));
                let msg = format!("{err:#}");
                assert!(
                    msg.contains(&format!("section \"{name}\"")),
                    "{name}: {msg}"
                );
            }
        }
    }

    #[test]
    fn header_and_table_corruption_named() {
        let b = bundle();
        let bytes = b.to_bytes().unwrap();
        let cases: Vec<(usize, u8, &str)> = vec![
            (0, 0xFF, "bad magic"),
            (8, 0x7F, "unsupported version"),
            (12, 0x7F, "endian marker"),
            (30, 0xFF, "header checksum mismatch"), // header payload byte
            (HEADER_BYTES + 16, 0xFF, "header checksum mismatch"), // table byte
            (META_CHECKSUM_OFF, 0xFF, "header checksum mismatch"),
        ];
        for (off, mask, needle) in cases {
            let mut corrupt = bytes.clone();
            corrupt[off] ^= mask;
            let err = CheckpointBundle::from_bytes(&corrupt)
                .expect_err(&format!("flip at {off} must fail"));
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "offset {off}: {msg}");
        }
        let err = CheckpointBundle::from_bytes(&bytes[..100]).expect_err("truncated");
        assert!(format!("{err:#}").contains("truncated"));
    }

    #[test]
    fn save_load_round_trips_and_is_atomic() {
        let dir = std::env::temp_dir().join(format!("optimes-ckpt-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let b = bundle();
        let path = b.save(&dir).unwrap();
        assert!(path.ends_with(CHECKPOINT_FILE));
        assert!(!dir.join(format!("{CHECKPOINT_FILE}.tmp")).exists());
        let back = CheckpointBundle::load(&dir).unwrap();
        assert_eq!(back.config, b.config);
        // overwrite keeps the bundle readable
        let mut b2 = back.clone();
        b2.completed_rounds = 2; // unchanged count; tweak payload instead
        b2.delay_clock = 9.5;
        b2.save(&dir).unwrap();
        let again = CheckpointBundle::load(&dir).unwrap();
        assert_eq!(again.delay_clock.to_bits(), 9.5f64.to_bits());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn graph_fingerprint_distinguishes_graphs() {
        let a = graph_fingerprint(&tiny(71));
        let b = graph_fingerprint(&tiny(71));
        let c = graph_fingerprint(&tiny(72));
        assert_eq!(a, b, "fingerprint must be deterministic");
        assert_ne!(a, c, "different graphs must fingerprint differently");
    }

    #[test]
    fn checkpoint_spec_parses_dir_and_cadence() {
        assert_eq!(parse_checkpoint_spec(""), None);
        assert_eq!(parse_checkpoint_spec("  "), None);
        assert_eq!(
            parse_checkpoint_spec("/tmp/ck"),
            Some((PathBuf::from("/tmp/ck"), 1))
        );
        assert_eq!(
            parse_checkpoint_spec("/tmp/ck:4"),
            Some((PathBuf::from("/tmp/ck"), 4))
        );
        // cadence 0 is clamped to 1 with a warning
        assert_eq!(
            parse_checkpoint_spec("/tmp/ck:0"),
            Some((PathBuf::from("/tmp/ck"), 1))
        );
        // a path with a colon that is not a cadence stays a bare dir
        assert_eq!(
            parse_checkpoint_spec("/tmp/a:b"),
            Some((PathBuf::from("/tmp/a:b"), 1))
        );
        assert_eq!(
            checkpoint_path(Path::new("/tmp/x")).file_name().unwrap(),
            CHECKPOINT_FILE
        );
    }
}
