//! The embedding server: a sharded in-memory store holding the
//! `h^1..h^{L-1}` embeddings of every cross-client (push/pull) vertex,
//! with batched pipelined get/set RPCs (the paper implements this with
//! Redis + pipelining; we build the store ourselves, DESIGN.md §3).
//!
//! One logical database per layer (paper §5.1 "separate database for each
//! layer's embeddings to allow scoped updates"), each sharded across
//! `SHARDS` RwLock'd **slab arenas**: rows live contiguously in one
//! `Vec<f32>` per shard with a small id → slot index, instead of one heap
//! `Box<[f32]>` per vertex. This removes the per-row allocation on push,
//! keeps pulls streaming over contiguous memory, and lets [`pull_into`]
//! write directly into a caller-provided buffer (zero-alloc steady state
//! on both sides of the RPC). Batched calls take each shard lock once per
//! layer rather than once per row.
//!
//! Every call is one *batched* RPC whose cost is accounted through the
//! [`NetConfig`] model plus the measured in-memory service time (the small
//! real-time jitter keeps the Fig 12c fit realistic rather than exactly
//! R²=1).
//!
//! [`pull_into`]: EmbeddingServer::pull_into

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::RwLock;

use super::metrics::{RpcKind, RpcRecord};
use super::netsim::NetConfig;
use super::store::{EmbeddingStore, StoreStats};

const SHARDS: usize = 16;

/// One shard of a layer's slab arena: a dense contiguous row store plus
/// the id → slot index. Slots are append-only; overwrites reuse the slot.
#[derive(Default)]
struct SlabShard {
    index: HashMap<u32, u32>,
    rows: Vec<f32>,
}

impl SlabShard {
    /// Insert or overwrite the row for `id` (`src.len()` = hidden dim).
    fn upsert(&mut self, id: u32, src: &[f32]) {
        let h = src.len();
        let next = self.index.len() as u32;
        let slot = *self.index.entry(id).or_insert(next) as usize;
        let end = (slot + 1) * h;
        if self.rows.len() < end {
            self.rows.resize(end, 0.0);
        }
        self.rows[slot * h..end].copy_from_slice(src);
    }

    /// Row for `id`, if present.
    fn row(&self, id: u32, h: usize) -> Option<&[f32]> {
        self.index
            .get(&id)
            .map(|&s| &self.rows[s as usize * h..(s as usize + 1) * h])
    }
}

/// Embedding rows for one layer, slab-sharded by global vertex id.
struct LayerDb {
    shards: Vec<RwLock<SlabShard>>,
}

impl LayerDb {
    fn new() -> Self {
        Self {
            shards: (0..SHARDS).map(|_| RwLock::new(SlabShard::default())).collect(),
        }
    }

    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().index.len()).sum()
    }
}

/// Bucket `nodes` by shard as `groups[shard] = [(position in the batched
/// call, vertex id)]` and hand the buckets to `f`. The bucket buffers are
/// thread-local and reused across RPCs, so the batched hot path stays
/// allocation-free at steady state.
fn with_shard_groups<R>(nodes: &[u32], f: impl FnOnce(&[Vec<(usize, u32)>]) -> R) -> R {
    thread_local! {
        static GROUPS: std::cell::RefCell<Vec<Vec<(usize, u32)>>> =
            std::cell::RefCell::new(Vec::new());
    }
    GROUPS.with(|cell| {
        let mut groups = cell.borrow_mut();
        groups.resize_with(SHARDS, Vec::new);
        for g in groups.iter_mut() {
            g.clear();
        }
        for (i, &node) in nodes.iter().enumerate() {
            groups[(node as usize) & (SHARDS - 1)].push((i, node));
        }
        f(&groups)
    })
}

pub struct EmbeddingServer {
    /// `layers[l-1]` holds h^l rows.
    layers: Vec<LayerDb>,
    pub hidden: usize,
    pub net: NetConfig,
    pulls: AtomicUsize,
    pushes: AtomicUsize,
    /// Embedding-payload bytes received by pushes / served by pulls
    /// (raw f32 — this backend is the uncompressed plane; a codec layer
    /// wrapping it overrides these meters at the wire boundary).
    bytes_tx: AtomicUsize,
    bytes_rx: AtomicUsize,
}

impl EmbeddingServer {
    /// `n_layers` = L-1 hidden layers for an L-layer GNN.
    pub fn new(n_layers: usize, hidden: usize, net: NetConfig) -> Self {
        Self {
            layers: (0..n_layers).map(|_| LayerDb::new()).collect(),
            hidden,
            net,
            pulls: AtomicUsize::new(0),
            pushes: AtomicUsize::new(0),
            bytes_tx: AtomicUsize::new(0),
            bytes_rx: AtomicUsize::new(0),
        }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Batched push: store `h^l` rows for `nodes` (one call for all
    /// layers, like a pipelined Redis MSET). `per_layer[l-1]` is row-major
    /// `[nodes.len(), hidden]`. Each shard lock is taken once per layer.
    pub fn push(&self, nodes: &[u32], per_layer: &[Vec<f32>]) -> RpcRecord {
        assert_eq!(per_layer.len(), self.layers.len());
        let t0 = std::time::Instant::now();
        let h = self.hidden;
        with_shard_groups(nodes, |groups| {
            for (db, rows) in self.layers.iter().zip(per_layer) {
                assert_eq!(rows.len(), nodes.len() * h, "push rows shape");
                for (sid, group) in groups.iter().enumerate() {
                    if group.is_empty() {
                        continue;
                    }
                    let mut shard = db.shards[sid].write().unwrap();
                    for &(i, node) in group {
                        shard.upsert(node, &rows[i * h..(i + 1) * h]);
                    }
                }
            }
        });
        self.pushes.fetch_add(1, Ordering::Relaxed);
        self.bytes_tx
            .fetch_add(nodes.len() * self.layers.len() * h * 4, Ordering::Relaxed);
        let bytes = self.net.emb_bytes(nodes.len(), self.layers.len(), h);
        RpcRecord {
            kind: RpcKind::Push,
            rows: nodes.len(),
            bytes,
            time: self.net.time_for_bytes(bytes) + t0.elapsed().as_secs_f64(),
        }
    }

    /// Batched pull of all layers for `nodes`, written directly into the
    /// caller-provided buffer: `out` is resized to one `[nodes.len(),
    /// hidden]` row-major tensor per layer (reusing capacity), missing
    /// nodes yield zero rows (only possible before their owner's first
    /// push). This is the zero-alloc hot path; [`pull`] wraps it.
    ///
    /// [`pull`]: EmbeddingServer::pull
    pub fn pull_into(&self, nodes: &[u32], on_demand: bool, out: &mut Vec<Vec<f32>>) -> RpcRecord {
        let t0 = std::time::Instant::now();
        let h = self.hidden;
        let n_layers = self.layers.len();
        out.truncate(n_layers);
        out.resize_with(n_layers, Vec::new);
        with_shard_groups(nodes, |groups| {
            for (db, rows) in self.layers.iter().zip(out.iter_mut()) {
                rows.clear();
                rows.resize(nodes.len() * h, 0.0);
                for (sid, group) in groups.iter().enumerate() {
                    if group.is_empty() {
                        continue;
                    }
                    let shard = db.shards[sid].read().unwrap();
                    for &(i, node) in group {
                        if let Some(src) = shard.row(node, h) {
                            rows[i * h..(i + 1) * h].copy_from_slice(src);
                        }
                    }
                }
            }
        });
        self.pulls.fetch_add(1, Ordering::Relaxed);
        self.bytes_rx.fetch_add(nodes.len() * n_layers * h * 4, Ordering::Relaxed);
        let bytes = self.net.emb_bytes(nodes.len(), n_layers, h);
        RpcRecord {
            kind: if on_demand {
                RpcKind::PullOnDemand
            } else {
                RpcKind::Pull
            },
            rows: nodes.len(),
            bytes,
            time: self.net.time_for_bytes(bytes) + t0.elapsed().as_secs_f64(),
        }
    }

    /// Allocating wrapper around [`EmbeddingServer::pull_into`].
    pub fn pull(&self, nodes: &[u32], on_demand: bool) -> (Vec<Vec<f32>>, RpcRecord) {
        let mut out = Vec::new();
        let rec = self.pull_into(nodes, on_demand, &mut out);
        (out, rec)
    }

    /// Unique vertices stored (any layer) — the paper's "embeddings
    /// maintained at the embedding server" marker (Fig 2a / Fig 10).
    pub fn stored_nodes(&self) -> usize {
        self.layers.first().map(|db| db.len()).unwrap_or(0)
    }

    /// Total embedding rows across layers.
    pub fn stored_rows(&self) -> usize {
        self.layers.iter().map(|db| db.len()).sum()
    }

    /// In-memory footprint in bytes (rows * hidden * 4 + key overhead).
    pub fn memory_bytes(&self) -> usize {
        self.stored_rows() * (self.hidden * 4 + self.net.per_entry_overhead)
    }

    pub fn rpc_counts(&self) -> (usize, usize) {
        (
            self.pulls.load(Ordering::Relaxed),
            self.pushes.load(Ordering::Relaxed),
        )
    }
}

/// The in-process backend of the embedding plane: the trait surface
/// simply wraps the (infallible) inherent batched calls.
impl EmbeddingStore for EmbeddingServer {
    fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn hidden(&self) -> usize {
        self.hidden
    }

    fn push(&self, nodes: &[u32], per_layer: &[Vec<f32>]) -> anyhow::Result<RpcRecord> {
        Ok(EmbeddingServer::push(self, nodes, per_layer))
    }

    fn pull_into(
        &self,
        nodes: &[u32],
        on_demand: bool,
        out: &mut Vec<Vec<f32>>,
    ) -> anyhow::Result<RpcRecord> {
        Ok(EmbeddingServer::pull_into(self, nodes, on_demand, out))
    }

    fn stats(&self) -> anyhow::Result<StoreStats> {
        let tx = self.bytes_tx.load(Ordering::Relaxed);
        let rx = self.bytes_rx.load(Ordering::Relaxed);
        Ok(StoreStats {
            nodes: self.stored_nodes(),
            rows: self.stored_rows(),
            // the uncompressed plane: encoded == raw
            bytes_tx: tx,
            bytes_rx: rx,
            raw_tx: tx,
            raw_rx: rx,
            ..Default::default()
        })
    }

    fn describe(&self) -> String {
        "in-process".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn server() -> EmbeddingServer {
        EmbeddingServer::new(2, 4, NetConfig::default())
    }

    fn rows(nodes: &[u32], h: usize, salt: f32) -> Vec<f32> {
        nodes
            .iter()
            .flat_map(|&n| (0..h).map(move |j| n as f32 * 10.0 + j as f32 + salt))
            .collect()
    }

    #[test]
    fn push_then_pull_roundtrip() {
        let s = server();
        let nodes = [3u32, 7, 11];
        let l1 = rows(&nodes, 4, 0.0);
        let l2 = rows(&nodes, 4, 0.5);
        let rec = s.push(&nodes, &[l1.clone(), l2.clone()]);
        assert_eq!(rec.rows, 3);
        assert_eq!(rec.kind, RpcKind::Push);
        let (got, rec) = s.pull(&[7, 3], false);
        assert_eq!(rec.kind, RpcKind::Pull);
        assert_eq!(&got[0][0..4], &l1[4..8]); // node 7 row
        assert_eq!(&got[0][4..8], &l1[0..4]); // node 3 row
        assert_eq!(&got[1][0..4], &l2[4..8]);
        assert_eq!(s.stored_nodes(), 3);
        assert_eq!(s.stored_rows(), 6);
    }

    #[test]
    fn missing_nodes_are_zero() {
        let s = server();
        let (got, _) = s.pull(&[42], true);
        assert!(got[0].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn pull_into_reuses_and_overwrites_caller_buffer() {
        let s = server();
        let nodes = [2u32, 18]; // same shard (16 apart) and distinct slots
        s.push(&nodes, &[rows(&nodes, 4, 0.0), rows(&nodes, 4, 1.0)]);
        // dirty, wrongly-sized buffer must be fully overwritten
        let mut buf = vec![vec![9.9f32; 3], vec![9.9f32; 99], vec![1.0f32; 7]];
        let rec = s.pull_into(&[18, 5, 2], false, &mut buf);
        assert_eq!(rec.rows, 3);
        assert_eq!(buf.len(), 2);
        assert_eq!(buf[0].len(), 3 * 4);
        assert_eq!(&buf[0][0..4], &rows(&[18], 4, 0.0)[..]);
        assert!(buf[0][4..8].iter().all(|&v| v == 0.0)); // node 5 missing
        assert_eq!(&buf[0][8..12], &rows(&[2], 4, 0.0)[..]);
        assert_eq!(&buf[1][0..4], &rows(&[18], 4, 1.0)[..]);
        // second pull reuses the buffer without reallocating
        let cap = (buf[0].capacity(), buf[1].capacity());
        s.pull_into(&[2], false, &mut buf);
        assert_eq!(buf[0].len(), 4);
        assert!(buf[0].capacity() <= cap.0.max(4) && buf[0].capacity() >= 4);
        assert_eq!(&buf[0][0..4], &rows(&[2], 4, 0.0)[..]);
        assert!(buf[1].capacity() >= 4 && buf[1].capacity() <= cap.1);
    }

    #[test]
    fn overwrite_updates_in_place() {
        let s = server();
        let nodes = [5u32];
        s.push(&nodes, &[vec![1.0; 4], vec![2.0; 4]]);
        s.push(&nodes, &[vec![9.0; 4], vec![8.0; 4]]);
        let (got, _) = s.pull(&[5], false);
        assert_eq!(got[0], vec![9.0; 4]);
        assert_eq!(got[1], vec![8.0; 4]);
        assert_eq!(s.stored_nodes(), 1);
        // slot reuse: a re-push of the same node must not grow the slab
        assert_eq!(s.stored_rows(), 2);
    }

    #[test]
    fn rpc_time_scales_with_rows() {
        let s = server();
        let small: Vec<u32> = (0..10).collect();
        let large: Vec<u32> = (0..10_000).collect();
        s.push(&large, &[rows(&large, 4, 0.0), rows(&large, 4, 1.0)]);
        let (_, r_small) = s.pull(&small, false);
        let (_, r_large) = s.pull(&large, false);
        assert!(r_large.time > r_small.time);
        assert!(r_large.bytes > r_small.bytes * 500);
    }

    #[test]
    fn concurrent_push_pull_is_safe() {
        let s = Arc::new(server());
        let mut handles = Vec::new();
        for c in 0..8u32 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                let nodes: Vec<u32> = (c * 100..c * 100 + 50).collect();
                for _ in 0..20 {
                    s.push(&nodes, &[rows(&nodes, 4, 0.0), rows(&nodes, 4, 1.0)]);
                    let (got, _) = s.pull(&nodes, false);
                    // own rows are never torn: value matches the formula
                    assert_eq!(got[0][0], nodes[0] as f32 * 10.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.stored_nodes(), 8 * 50);
        let (pulls, pushes) = s.rpc_counts();
        assert_eq!(pulls, 160);
        assert_eq!(pushes, 160);
    }

    #[test]
    fn slab_store_survives_interleaved_push_pull_hammer() {
        // Writers race on a SHARED node set with per-writer row values;
        // readers assert every pulled row is internally consistent (all
        // `hidden` lanes agree), i.e. rows are never torn even while the
        // slab grows and slots are being overwritten.
        let h = 8;
        let s = Arc::new(EmbeddingServer::new(2, h, NetConfig::default()));
        let nodes: Vec<u32> = (0..128).collect();
        let mut handles = Vec::new();
        for w in 0..6u32 {
            let s = Arc::clone(&s);
            let nodes = nodes.clone();
            handles.push(std::thread::spawn(move || {
                for iter in 0..30 {
                    let v = (w * 1000 + iter) as f32;
                    let layer: Vec<f32> = vec![v; nodes.len() * h];
                    s.push(&nodes, &[layer.clone(), layer]);
                }
            }));
        }
        for _ in 0..4 {
            let s = Arc::clone(&s);
            let nodes = nodes.clone();
            handles.push(std::thread::spawn(move || {
                let mut buf = Vec::new();
                for _ in 0..60 {
                    s.pull_into(&nodes, false, &mut buf);
                    for layer in &buf {
                        for row in layer.chunks_exact(h) {
                            assert!(
                                row.iter().all(|&x| x == row[0]),
                                "torn row: {row:?}"
                            );
                        }
                    }
                }
            }));
        }
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(s.stored_nodes(), 128);
        assert_eq!(s.stored_rows(), 256);
        let (pulls, pushes) = s.rpc_counts();
        assert_eq!(pulls, 240);
        assert_eq!(pushes, 180);
    }
}
