//! The embedding server: a sharded in-memory KV store holding the
//! `h^1..h^{L-1}` embeddings of every cross-client (push/pull) vertex,
//! with batched pipelined get/set RPCs (the paper implements this with
//! Redis + pipelining; we build the store ourselves, DESIGN.md §3).
//!
//! One logical database per layer (paper §5.1 "separate database for each
//! layer's embeddings to allow scoped updates"), each sharded across
//! `SHARDS` RwLock'd hash maps keyed by global vertex id. Concurrent
//! clients push/pull in parallel; every call is one *batched* RPC whose
//! cost is accounted through the [`NetConfig`] model plus the measured
//! in-memory service time (the small real-time jitter keeps the Fig 12c
//! fit realistic rather than exactly R²=1).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::RwLock;

use super::metrics::{RpcKind, RpcRecord};
use super::netsim::NetConfig;

const SHARDS: usize = 16;

/// Embedding rows for one layer, keyed by global vertex id.
struct LayerDb {
    shards: Vec<RwLock<HashMap<u32, Box<[f32]>>>>,
}

impl LayerDb {
    fn new() -> Self {
        Self {
            shards: (0..SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
        }
    }

    #[inline]
    fn shard(&self, key: u32) -> &RwLock<HashMap<u32, Box<[f32]>>> {
        &self.shards[(key as usize) & (SHARDS - 1)]
    }

    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }
}

pub struct EmbeddingServer {
    /// `layers[l-1]` holds h^l rows.
    layers: Vec<LayerDb>,
    pub hidden: usize,
    pub net: NetConfig,
    pulls: AtomicUsize,
    pushes: AtomicUsize,
}

impl EmbeddingServer {
    /// `n_layers` = L-1 hidden layers for an L-layer GNN.
    pub fn new(n_layers: usize, hidden: usize, net: NetConfig) -> Self {
        Self {
            layers: (0..n_layers).map(|_| LayerDb::new()).collect(),
            hidden,
            net,
            pulls: AtomicUsize::new(0),
            pushes: AtomicUsize::new(0),
        }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Batched push: store `h^l` rows for `nodes` (one call for all
    /// layers, like a pipelined Redis MSET). `per_layer[l-1]` is row-major
    /// `[nodes.len(), hidden]`.
    pub fn push(&self, nodes: &[u32], per_layer: &[Vec<f32>]) -> RpcRecord {
        assert_eq!(per_layer.len(), self.layers.len());
        let t0 = std::time::Instant::now();
        let h = self.hidden;
        for (db, rows) in self.layers.iter().zip(per_layer) {
            assert_eq!(rows.len(), nodes.len() * h, "push rows shape");
            for (i, &node) in nodes.iter().enumerate() {
                let row: Box<[f32]> = rows[i * h..(i + 1) * h].into();
                db.shard(node).write().unwrap().insert(node, row);
            }
        }
        self.pushes.fetch_add(1, Ordering::Relaxed);
        let bytes = self.net.emb_bytes(nodes.len(), self.layers.len(), h);
        RpcRecord {
            kind: RpcKind::Push,
            rows: nodes.len(),
            bytes,
            time: self.net.time_for_bytes(bytes) + t0.elapsed().as_secs_f64(),
        }
    }

    /// Batched pull of all layers for `nodes`. Returns `out[l-1]` row-major
    /// `[nodes.len(), hidden]`; missing nodes yield zero rows (only
    /// possible before their owner's first push).
    pub fn pull(&self, nodes: &[u32], on_demand: bool) -> (Vec<Vec<f32>>, RpcRecord) {
        let t0 = std::time::Instant::now();
        let h = self.hidden;
        let mut out = Vec::with_capacity(self.layers.len());
        for db in &self.layers {
            let mut rows = vec![0f32; nodes.len() * h];
            for (i, &node) in nodes.iter().enumerate() {
                if let Some(row) = db.shard(node).read().unwrap().get(&node) {
                    rows[i * h..(i + 1) * h].copy_from_slice(row);
                }
            }
            out.push(rows);
        }
        self.pulls.fetch_add(1, Ordering::Relaxed);
        let bytes = self.net.emb_bytes(nodes.len(), self.layers.len(), h);
        let rec = RpcRecord {
            kind: if on_demand {
                RpcKind::PullOnDemand
            } else {
                RpcKind::Pull
            },
            rows: nodes.len(),
            bytes,
            time: self.net.time_for_bytes(bytes) + t0.elapsed().as_secs_f64(),
        };
        (out, rec)
    }

    /// Unique vertices stored (any layer) — the paper's "embeddings
    /// maintained at the embedding server" marker (Fig 2a / Fig 10).
    pub fn stored_nodes(&self) -> usize {
        self.layers.first().map(|db| db.len()).unwrap_or(0)
    }

    /// Total embedding rows across layers.
    pub fn stored_rows(&self) -> usize {
        self.layers.iter().map(|db| db.len()).sum()
    }

    /// In-memory footprint in bytes (rows * hidden * 4 + key overhead).
    pub fn memory_bytes(&self) -> usize {
        self.stored_rows() * (self.hidden * 4 + self.net.per_entry_overhead)
    }

    pub fn rpc_counts(&self) -> (usize, usize) {
        (
            self.pulls.load(Ordering::Relaxed),
            self.pushes.load(Ordering::Relaxed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn server() -> EmbeddingServer {
        EmbeddingServer::new(2, 4, NetConfig::default())
    }

    fn rows(nodes: &[u32], h: usize, salt: f32) -> Vec<f32> {
        nodes
            .iter()
            .flat_map(|&n| (0..h).map(move |j| n as f32 * 10.0 + j as f32 + salt))
            .collect()
    }

    #[test]
    fn push_then_pull_roundtrip() {
        let s = server();
        let nodes = [3u32, 7, 11];
        let l1 = rows(&nodes, 4, 0.0);
        let l2 = rows(&nodes, 4, 0.5);
        let rec = s.push(&nodes, &[l1.clone(), l2.clone()]);
        assert_eq!(rec.rows, 3);
        assert_eq!(rec.kind, RpcKind::Push);
        let (got, rec) = s.pull(&[7, 3], false);
        assert_eq!(rec.kind, RpcKind::Pull);
        assert_eq!(&got[0][0..4], &l1[4..8]); // node 7 row
        assert_eq!(&got[0][4..8], &l1[0..4]); // node 3 row
        assert_eq!(&got[1][0..4], &l2[4..8]);
        assert_eq!(s.stored_nodes(), 3);
        assert_eq!(s.stored_rows(), 6);
    }

    #[test]
    fn missing_nodes_are_zero() {
        let s = server();
        let (got, _) = s.pull(&[42], true);
        assert!(got[0].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn overwrite_updates_in_place() {
        let s = server();
        let nodes = [5u32];
        s.push(&nodes, &[vec![1.0; 4], vec![2.0; 4]]);
        s.push(&nodes, &[vec![9.0; 4], vec![8.0; 4]]);
        let (got, _) = s.pull(&[5], false);
        assert_eq!(got[0], vec![9.0; 4]);
        assert_eq!(got[1], vec![8.0; 4]);
        assert_eq!(s.stored_nodes(), 1);
    }

    #[test]
    fn rpc_time_scales_with_rows() {
        let s = server();
        let small: Vec<u32> = (0..10).collect();
        let large: Vec<u32> = (0..10_000).collect();
        s.push(&large, &[rows(&large, 4, 0.0), rows(&large, 4, 1.0)]);
        let (_, r_small) = s.pull(&small, false);
        let (_, r_large) = s.pull(&large, false);
        assert!(r_large.time > r_small.time);
        assert!(r_large.bytes > r_small.bytes * 500);
    }

    #[test]
    fn concurrent_push_pull_is_safe() {
        let s = Arc::new(server());
        let mut handles = Vec::new();
        for c in 0..8u32 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                let nodes: Vec<u32> = (c * 100..c * 100 + 50).collect();
                for _ in 0..20 {
                    s.push(&nodes, &[rows(&nodes, 4, 0.0), rows(&nodes, 4, 1.0)]);
                    let (got, _) = s.pull(&nodes, false);
                    // own rows are never torn: value matches the formula
                    assert_eq!(got[0][0], nodes[0] as f32 * 10.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.stored_nodes(), 8 * 50);
        let (pulls, pushes) = s.rpc_counts();
        assert_eq!(pulls, 160);
        assert_eq!(pushes, 160);
    }
}
