//! Strategy configuration: the paper's seven evaluated strategies plus the
//! scoring/fraction ablation variants of Fig 11 and the prefetch ablation
//! of Fig 12 (`OPP_T0`, `OPP_R25`, ...).
//!
//! Ladder (§5.2 "Metrics and Notations"):
//! * `D`   — default federated GNN (no embedding exchange; P_0)
//! * `E`   — EmbC baseline (all remote embeddings, synchronous push)
//! * `O`   — E + push overlap
//! * `P`   — uniform random pruning with retention limit (default P_4)
//! * `OP`  — O + P
//! * `OPP` — OP + scored pull prefetch (top-x%, default 25%, rest
//!   on-demand)
//! * `OPG` — OP + scored graph pruning (top-f%, default 25%, static)
//!
//! # Strategy-string grammar
//!
//! [`Strategy::parse`] accepts exactly this grammar (case-insensitive;
//! it is the same text a [`ParseStrategyError`] prints, kept verbatim in
//! [`STRATEGY_GRAMMAR`]):
//!
//! ```text
//! strategy := "D" | "E" | "O"                    the ladder's unparameterized rungs
//!           | "P" | "P"<i> | "P"<i>"dyn" | "Pinf"
//!           | "OP" | "OPP" | "OPG"
//!           | "OPP_" score pct                   scored-prefetch ablations
//!           | "OPG_" score pct                   scored-pruning ablations
//! score    := "T" | "R" | "D" | "B"              frequency | random | degree | bridge
//! pct      := number in 0..=100                  top percentage (decimals allowed)
//! <i>      := unsigned integer                   per-vertex retention limit
//! ```
//!
//! `P` alone means `P4` (the paper's default retention); the `dyn`
//! suffix re-samples the retained sets every round instead of once
//! offline; `Pinf` is an unlimited-retention alias of `E`.
//!
//! ```
//! use optimes::coordinator::{ScoreKind, Strategy};
//!
//! // the seven headline strategies parse to their canonical names
//! for name in ["D", "E", "O", "P", "OP", "OPP", "OPG"] {
//!     assert_eq!(Strategy::parse(name).unwrap().name, name);
//! }
//!
//! // P<i>: retention limit; the "dyn" suffix re-samples per round
//! let p2 = Strategy::parse("p2").unwrap();
//! assert_eq!(p2.retention, Some(2));
//! let p4dyn = Strategy::parse("P4dyn").unwrap();
//! assert!(p4dyn.dynamic_prune && p4dyn.retention == Some(4));
//!
//! // OPP_<score><pct>: prefetch the top pct% by the chosen score
//! let opp = Strategy::parse("OPP_B50").unwrap();
//! let pf = opp.prefetch.unwrap();
//! assert_eq!(pf.score, ScoreKind::Bridge);
//! assert!((pf.top_frac - 0.5).abs() < 1e-9);
//!
//! // anything else errors, naming the full grammar
//! let err = Strategy::parse("OPP_Q25").unwrap_err();
//! assert!(err.to_string().contains("OPP_<T|R|D|B><pct>"));
//! ```

use std::fmt;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoreKind {
    /// Paper §4.1.2 frequency score (default).
    Frequency,
    /// Uniform random scores (R25 ablation).
    Random,
    /// Degree centrality exchanged between owners (D25).
    Degree,
    /// Bridge centrality exchanged between owners (B25).
    Bridge,
}

impl ScoreKind {
    pub fn tag(&self) -> &'static str {
        match self {
            ScoreKind::Frequency => "T",
            ScoreKind::Random => "R",
            ScoreKind::Degree => "D",
            ScoreKind::Bridge => "B",
        }
    }
}

/// Full strategy configuration for one federated session.
#[derive(Clone, Debug, PartialEq)]
pub struct Strategy {
    /// Display name ("OPP", "OPG_R25", ...).
    pub name: String,
    /// Per-boundary-vertex retention limit (None = unlimited / P_inf;
    /// Some(0) = D).
    pub retention: Option<usize>,
    /// Overlap the push phase with the final training epoch (O-family).
    pub overlap_push: bool,
    /// Share remote embeddings at all (false only for D).
    pub share_embeddings: bool,
    /// OPP: prefetch the top-`frac` scoring pull nodes at round start and
    /// pull the rest on demand (one batched RPC per minibatch).
    pub prefetch: Option<PrefetchCfg>,
    /// OPG: statically expand with only the top-`frac` scoring pull nodes.
    pub scored_prune: Option<ScoredPruneCfg>,
    /// Re-sample the retention subsets each round instead of pruning once
    /// offline (the paper's §1 static-vs-dynamic pruning ablation;
    /// requires `retention`).
    pub dynamic_prune: bool,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefetchCfg {
    pub top_frac: f64,
    pub score: ScoreKind,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScoredPruneCfg {
    pub top_frac: f64,
    pub score: ScoreKind,
}

/// Default retention for the P-family (the paper uses P_4 everywhere
/// except the Fig 10 retention sweep).
pub const DEFAULT_RETENTION: usize = 4;
pub const DEFAULT_FRAC: f64 = 0.25;

/// The strategy-string grammar accepted by [`Strategy::parse`]
/// (case-insensitive).
pub const STRATEGY_GRAMMAR: &str =
    "D | E | O | P | P<i> | P<i>dyn | Pinf | OP | OPP | OPG | \
     OPP_<T|R|D|B><pct> | OPG_<T|R|D|B><pct>  (e.g. P2, P4dyn, OPP_T25, OPG_B50)";

/// A strategy string that matched none of [`STRATEGY_GRAMMAR`]'s rules.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParseStrategyError {
    input: String,
}

impl fmt::Display for ParseStrategyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown strategy {:?}; expected {STRATEGY_GRAMMAR}",
            self.input
        )
    }
}

impl std::error::Error for ParseStrategyError {}

impl Strategy {
    pub fn d() -> Self {
        Strategy {
            name: "D".into(),
            retention: Some(0),
            overlap_push: false,
            share_embeddings: false,
            prefetch: None,
            scored_prune: None,
            dynamic_prune: false,
        }
    }

    pub fn e() -> Self {
        Strategy {
            name: "E".into(),
            retention: None,
            overlap_push: false,
            share_embeddings: true,
            prefetch: None,
            scored_prune: None,
            dynamic_prune: false,
        }
    }

    pub fn o() -> Self {
        Strategy {
            name: "O".into(),
            overlap_push: true,
            ..Self::e()
        }
    }

    pub fn p(retention: usize) -> Self {
        Strategy {
            name: if retention == DEFAULT_RETENTION {
                "P".into()
            } else {
                format!("P{retention}")
            },
            retention: Some(retention),
            overlap_push: false,
            share_embeddings: retention > 0,
            prefetch: None,
            scored_prune: None,
            dynamic_prune: false,
        }
    }

    /// Dynamic-pruning variant of P_i: the retained subsets are
    /// re-sampled every round (paper §1 ablation).
    pub fn p_dynamic(retention: usize) -> Self {
        Strategy {
            name: format!("P{retention}dyn"),
            dynamic_prune: true,
            ..Self::p(retention)
        }
    }

    pub fn op() -> Self {
        Strategy {
            name: "OP".into(),
            overlap_push: true,
            ..Self::p(DEFAULT_RETENTION)
        }
    }

    pub fn opp() -> Self {
        Self::opp_with(DEFAULT_FRAC, ScoreKind::Frequency)
    }

    pub fn opp_with(frac: f64, score: ScoreKind) -> Self {
        let name = if (frac - DEFAULT_FRAC).abs() < 1e-9 && score == ScoreKind::Frequency {
            "OPP".to_string()
        } else {
            format!("OPP_{}{}", score.tag(), (frac * 100.0).round() as usize)
        };
        Strategy {
            name,
            prefetch: Some(PrefetchCfg {
                top_frac: frac,
                score,
            }),
            ..Self::op()
        }
    }

    pub fn opg() -> Self {
        Self::opg_with(DEFAULT_FRAC, ScoreKind::Frequency)
    }

    pub fn opg_with(frac: f64, score: ScoreKind) -> Self {
        let name = if (frac - DEFAULT_FRAC).abs() < 1e-9 && score == ScoreKind::Frequency {
            "OPG".to_string()
        } else {
            format!("OPG_{}{}", score.tag(), (frac * 100.0).round() as usize)
        };
        Strategy {
            name,
            scored_prune: Some(ScoredPruneCfg {
                top_frac: frac,
                score,
            }),
            ..Self::op()
        }
    }

    /// The seven headline strategies in paper order.
    pub fn ladder() -> Vec<Strategy> {
        vec![
            Self::d(),
            Self::e(),
            Self::o(),
            Self::p(DEFAULT_RETENTION),
            Self::op(),
            Self::opp(),
            Self::opg(),
        ]
    }

    /// Parse a strategy string against the grammar documented at the
    /// [module level](crate::coordinator::strategy) and in
    /// [`STRATEGY_GRAMMAR`] — `"D"`, `"E"`,
    /// `"O"`, `"P"`, `"P2"`, `"P4dyn"`, `"Pinf"`, `"OP"`, `"OPP"`,
    /// `"OPG"`, `"OPP_T0"`, `"OPG_B25"`, ... (case-insensitive). The
    /// error names the full grammar.
    ///
    /// ```
    /// use optimes::coordinator::Strategy;
    ///
    /// let s = Strategy::parse("opg_t75").unwrap();
    /// assert_eq!(s.name, "OPG_T75");
    /// assert!((s.scored_prune.unwrap().top_frac - 0.75).abs() < 1e-9);
    ///
    /// // the error converts into `anyhow::Error` via `?`
    /// fn pick(s: &str) -> anyhow::Result<Strategy> {
    ///     Ok(Strategy::parse(s)?)
    /// }
    /// assert!(pick("XYZ").is_err());
    /// ```
    pub fn parse(s: &str) -> Result<Strategy, ParseStrategyError> {
        Self::try_parse(s).ok_or_else(|| ParseStrategyError {
            input: s.to_string(),
        })
    }

    fn try_parse(s: &str) -> Option<Strategy> {
        let up = s.to_ascii_uppercase();
        match up.as_str() {
            "D" => return Some(Self::d()),
            "E" => return Some(Self::e()),
            "O" => return Some(Self::o()),
            "P" => return Some(Self::p(DEFAULT_RETENTION)),
            "OP" => return Some(Self::op()),
            "OPP" => return Some(Self::opp()),
            "OPG" => return Some(Self::opg()),
            _ => {}
        }
        if let Some(rest) = up.strip_prefix('P') {
            if let Some(core) = rest.strip_suffix("DYN") {
                if let Ok(i) = core.parse::<usize>() {
                    return Some(Self::p_dynamic(i));
                }
            }
            if let Ok(i) = rest.parse::<usize>() {
                return Some(Self::p(i));
            }
            if rest == "INF" {
                return Some(Strategy {
                    name: "Pinf".into(),
                    ..Self::e()
                });
            }
        }
        for (prefix, is_prefetch) in [("OPP_", true), ("OPG_", false)] {
            if let Some(rest) = up.strip_prefix(prefix) {
                let mut chars = rest.chars();
                let score = match chars.next()? {
                    'T' => ScoreKind::Frequency,
                    'R' => ScoreKind::Random,
                    'D' => ScoreKind::Degree,
                    'B' => ScoreKind::Bridge,
                    _ => return None,
                };
                let pct = chars.as_str().parse::<f64>().ok()?;
                if !(0.0..=100.0).contains(&pct) {
                    return None;
                }
                let frac = pct / 100.0;
                return Some(if is_prefetch {
                    Self::opp_with(frac, score)
                } else {
                    Self::opg_with(frac, score)
                });
            }
        }
        None
    }

    /// Does this strategy need per-client frequency/centrality scores?
    pub fn needs_scores(&self) -> bool {
        self.prefetch.is_some() || self.scored_prune.is_some()
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_has_paper_semantics() {
        let l = Strategy::ladder();
        assert_eq!(l.len(), 7);
        assert!(!l[0].share_embeddings); // D
        assert_eq!(l[0].retention, Some(0));
        assert!(l[1].share_embeddings && !l[1].overlap_push); // E
        assert!(l[2].overlap_push && l[2].retention.is_none()); // O
        assert_eq!(l[3].retention, Some(4)); // P
        assert!(l[4].overlap_push && l[4].retention == Some(4)); // OP
        assert!(l[5].prefetch.is_some()); // OPP
        assert!(l[6].scored_prune.is_some()); // OPG
    }

    #[test]
    fn parse_roundtrip() {
        for name in ["D", "E", "O", "P", "OP", "OPP", "OPG"] {
            let s = Strategy::parse(name).unwrap();
            assert_eq!(s.name, name);
        }
        let p2 = Strategy::parse("P2").unwrap();
        assert_eq!(p2.retention, Some(2));
        let t0 = Strategy::parse("OPP_T0").unwrap();
        assert_eq!(t0.prefetch.unwrap().top_frac, 0.0);
        let r25 = Strategy::parse("OPG_R25").unwrap();
        assert_eq!(r25.scored_prune.unwrap().score, ScoreKind::Random);
        let b25 = Strategy::parse("OPG_B25").unwrap();
        assert_eq!(b25.scored_prune.unwrap().score, ScoreKind::Bridge);
        let t75 = Strategy::parse("OPG_T75").unwrap();
        assert!((t75.scored_prune.unwrap().top_frac - 0.75).abs() < 1e-9);
        let p4dyn = Strategy::parse("p4dyn").unwrap();
        assert!(p4dyn.dynamic_prune && p4dyn.retention == Some(4));
        assert!(Strategy::parse("XYZ").is_err());
    }

    #[test]
    fn parse_error_names_the_grammar() {
        for bad in ["XYZ", "OPP_", "OPP_Q25", "OPG_T250", "P-1", ""] {
            let err = Strategy::parse(bad).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains(&format!("{bad:?}")), "{msg}");
            assert!(msg.contains("OPP_<T|R|D|B><pct>"), "{msg}");
        }
        // the error converts into anyhow::Error via `?`
        fn through_anyhow(s: &str) -> anyhow::Result<Strategy> {
            Ok(Strategy::parse(s)?)
        }
        assert!(through_anyhow("nope").is_err());
        assert!(through_anyhow("OPP").is_ok());
    }

    #[test]
    fn needs_scores() {
        assert!(!Strategy::e().needs_scores());
        assert!(Strategy::opp().needs_scores());
        assert!(Strategy::opg().needs_scores());
    }
}
