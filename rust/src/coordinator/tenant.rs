//! Multi-tenant namespaces over the embedding plane (DESIGN.md §15).
//!
//! [`TenantStore`] is a decorator in the [`FaultStore`]/`CodecStore`
//! family: it maps every vertex id into a tenant-private region of the
//! u32 id space by prefixing an 8-bit tenant tag onto the id's high
//! bits, so many concurrent federated sessions can share one physical
//! store (one daemon, one slab, one shard topology) without ever seeing
//! each other's rows. The mapping is pure arithmetic — no per-row
//! lookup table — so it composes with sharding, replication, codecs,
//! and snapshots unchanged, and the bucket spread of
//! [`ShardedStore`](super::store::ShardedStore) stays uniform (ids are
//! avalanche-hashed before routing).
//!
//! [`TenantRegistry`] is the daemon-side directory: the wire handshake
//! (`OP_TENANT`, `net_transport.rs`) resolves a session name to its
//! `TenantStore`, creating one with the next free tag on first arrival.
//! Tags are assigned in arrival order, so a fixed connection order is
//! reproducible; isolation never depends on *which* tag a tenant got,
//! only that tags are distinct.
//!
//! Per-tenant stats are isolated: each decorator meters its own logical
//! occupancy and traffic, so a tenant's `stats` RPC reports what *that
//! session* stored and moved — not the physical totals of the shared
//! plane (shared-plane health like failovers and the routing epoch is
//! still forwarded, since it affects every tenant).
//!
//! [`FaultStore`]: super::resilience::FaultStore

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use super::metrics::RpcRecord;
use super::store::{EmbeddingStore, StoreStats};

/// High bits of the u32 id space reserved for the tenant tag.
pub const TENANT_TAG_BITS: u32 = 8;

/// Distinct tenants one shared store can host (tags `1..=255`; tag 0 is
/// the untagged root namespace and is never assigned).
pub const MAX_TENANTS: usize = (1 << TENANT_TAG_BITS) - 1;

/// Exclusive upper bound on per-tenant vertex ids (2^24): ids at or
/// above this would collide with another tenant's tag prefix, so they
/// are rejected loudly instead of silently aliasing.
pub const TENANT_NODE_LIMIT: u32 = 1 << (32 - TENANT_TAG_BITS);

/// Longest accepted tenant name (bounds the wire handshake frame).
pub const MAX_TENANT_NAME: usize = 64;

/// Validate a tenant/session name: non-empty, bounded, and limited to
/// `[A-Za-z0-9._-]` so names embed cleanly in wire frames, file names,
/// and `describe()` strings.
pub fn validate_tenant_name(name: &str) -> Result<()> {
    ensure!(!name.is_empty(), "tenant name must not be empty");
    ensure!(
        name.len() <= MAX_TENANT_NAME,
        "tenant name {name:?} is {} bytes, max {MAX_TENANT_NAME}",
        name.len()
    );
    ensure!(
        name.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.')),
        "tenant name {name:?} may only contain [A-Za-z0-9._-]"
    );
    Ok(())
}

/// Namespace decorator: rewrites vertex ids as `(tag << 24) | id` on the
/// way into the inner store, giving this tenant a private 16M-id region
/// of the shared plane. See the module docs for the full contract.
pub struct TenantStore {
    inner: Arc<dyn EmbeddingStore>,
    name: String,
    tag: u32,
    /// This tenant's logical occupancy (tenant-local ids ever pushed).
    nodes: Mutex<HashSet<u32>>,
    /// This tenant's share of the wire traffic (encoded / raw-f32
    /// equivalent, from the [`RpcRecord`]s its own calls produced).
    bytes_tx: AtomicUsize,
    bytes_rx: AtomicUsize,
    raw_tx: AtomicUsize,
    raw_rx: AtomicUsize,
}

impl TenantStore {
    pub fn new(inner: Arc<dyn EmbeddingStore>, name: &str, tag: u32) -> Result<Self> {
        validate_tenant_name(name)?;
        ensure!(
            (1..=MAX_TENANTS as u32).contains(&tag),
            "tenant tag {tag} out of range 1..={MAX_TENANTS}"
        );
        Ok(Self {
            inner,
            name: name.to_string(),
            tag,
            nodes: Mutex::new(HashSet::new()),
            bytes_tx: AtomicUsize::new(0),
            bytes_rx: AtomicUsize::new(0),
            raw_tx: AtomicUsize::new(0),
            raw_rx: AtomicUsize::new(0),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn tag(&self) -> u32 {
        self.tag
    }

    /// Map tenant-local ids into this tenant's region of the shared id
    /// space, rejecting ids that would overflow into a neighbor's tag.
    fn map_ids(&self, nodes: &[u32]) -> Result<Vec<u32>> {
        nodes
            .iter()
            .map(|&n| {
                ensure!(
                    n < TENANT_NODE_LIMIT,
                    "node id {n} exceeds the per-tenant id space \
                     ({TENANT_NODE_LIMIT} ids with {TENANT_TAG_BITS} tag bits) \
                     for tenant {:?}",
                    self.name
                );
                Ok((self.tag << (32 - TENANT_TAG_BITS)) | n)
            })
            .collect()
    }

    /// Raw-f32 equivalent of a `rows`-row batch across all layers.
    fn raw_bytes(&self, rows: usize) -> usize {
        rows * self.inner.n_layers() * self.inner.hidden() * std::mem::size_of::<f32>()
    }
}

impl EmbeddingStore for TenantStore {
    fn n_layers(&self) -> usize {
        self.inner.n_layers()
    }

    fn hidden(&self) -> usize {
        self.inner.hidden()
    }

    fn push(&self, nodes: &[u32], per_layer: &[Vec<f32>]) -> Result<RpcRecord> {
        let mapped = self.map_ids(nodes)?;
        let rec = self.inner.push(&mapped, per_layer)?;
        self.bytes_tx.fetch_add(rec.bytes, Ordering::Relaxed);
        self.raw_tx.fetch_add(self.raw_bytes(nodes.len()), Ordering::Relaxed);
        let mut set = self.nodes.lock().unwrap();
        set.extend(nodes.iter().copied());
        Ok(rec)
    }

    fn pull_into(
        &self,
        nodes: &[u32],
        on_demand: bool,
        out: &mut Vec<Vec<f32>>,
    ) -> Result<RpcRecord> {
        let mapped = self.map_ids(nodes)?;
        let rec = self.inner.pull_into(&mapped, on_demand, out)?;
        self.bytes_rx.fetch_add(rec.bytes, Ordering::Relaxed);
        self.raw_rx.fetch_add(self.raw_bytes(nodes.len()), Ordering::Relaxed);
        Ok(rec)
    }

    fn stats(&self) -> Result<StoreStats> {
        // occupancy and traffic are this tenant's own; failovers and the
        // routing epoch are shared-plane health that affects every
        // tenant, so they forward from the physical store
        let shared = self.inner.stats()?;
        let nodes = self.nodes.lock().unwrap().len();
        Ok(StoreStats {
            nodes,
            rows: nodes * self.inner.n_layers(),
            failovers: shared.failovers,
            epoch: shared.epoch,
            bytes_tx: self.bytes_tx.load(Ordering::Relaxed),
            bytes_rx: self.bytes_rx.load(Ordering::Relaxed),
            raw_tx: self.raw_tx.load(Ordering::Relaxed),
            raw_rx: self.raw_rx.load(Ordering::Relaxed),
        })
    }

    fn epoch(&self) -> u64 {
        self.inner.epoch()
    }

    fn codec(&self) -> String {
        self.inner.codec()
    }

    fn describe(&self) -> String {
        format!("tenant({}#{} over {})", self.name, self.tag, self.inner.describe())
    }
}

/// Daemon-side tenant directory: one [`TenantStore`] per session name
/// over a shared base store, created on first arrival with the next
/// free tag (`1..=`[`MAX_TENANTS`], arrival order).
pub struct TenantRegistry {
    base: Arc<dyn EmbeddingStore>,
    tenants: Mutex<HashMap<String, Arc<TenantStore>>>,
}

impl TenantRegistry {
    pub fn new(base: Arc<dyn EmbeddingStore>) -> Self {
        Self {
            base,
            tenants: Mutex::new(HashMap::new()),
        }
    }

    /// The shared base store (what untagged connections serve from).
    pub fn base(&self) -> Arc<dyn EmbeddingStore> {
        Arc::clone(&self.base)
    }

    /// Resolve a session name to its namespace, registering it with the
    /// next free tag on first sight. Fails loudly on a malformed name
    /// or when all [`MAX_TENANTS`] tags are taken.
    pub fn resolve(&self, name: &str) -> Result<Arc<TenantStore>> {
        validate_tenant_name(name)?;
        let mut tenants = self.tenants.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(existing) = tenants.get(name) {
            return Ok(Arc::clone(existing));
        }
        ensure!(
            tenants.len() < MAX_TENANTS,
            "tenant registry full: {MAX_TENANTS} tenants already registered, \
             cannot admit {name:?}"
        );
        let tag = tenants.len() as u32 + 1;
        let store = Arc::new(TenantStore::new(Arc::clone(&self.base), name, tag)?);
        tenants.insert(name.to_string(), Arc::clone(&store));
        Ok(store)
    }

    /// Registered tenant count.
    pub fn len(&self) -> usize {
        self.tenants.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Registered names, sorted (for stable daemon status lines).
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .tenants
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .keys()
            .cloned()
            .collect();
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::embedding_server::EmbeddingServer;
    use crate::coordinator::netsim::NetConfig;

    const H: usize = 4;

    fn slab() -> Arc<dyn EmbeddingStore> {
        Arc::new(EmbeddingServer::new(2, H, NetConfig::default()))
    }

    fn rows(nodes: &[u32], salt: f32) -> Vec<f32> {
        nodes
            .iter()
            .flat_map(|&n| (0..H).map(move |j| n as f32 * 10.0 + j as f32 + salt))
            .collect()
    }

    #[test]
    fn name_validation() {
        validate_tenant_name("alice-1.prod_x").unwrap();
        assert!(validate_tenant_name("").is_err());
        assert!(validate_tenant_name("has space").is_err());
        assert!(validate_tenant_name("uni\u{e9}").is_err());
        assert!(validate_tenant_name(&"x".repeat(MAX_TENANT_NAME + 1)).is_err());
    }

    #[test]
    fn tenants_on_one_slab_are_isolated() {
        let base = slab();
        let a = TenantStore::new(Arc::clone(&base), "alice", 1).unwrap();
        let b = TenantStore::new(Arc::clone(&base), "bob", 2).unwrap();
        let nodes: Vec<u32> = (0..32).collect();
        a.push(&nodes, &[rows(&nodes, 0.0), rows(&nodes, 1.0)]).unwrap();
        b.push(&nodes, &[rows(&nodes, 5.0), rows(&nodes, 6.0)]).unwrap();

        // the SAME ids resolve to each tenant's own values
        let (got_a, _) = a.pull(&nodes, false).unwrap();
        let (got_b, _) = b.pull(&nodes, false).unwrap();
        assert_eq!(got_a[0], rows(&nodes, 0.0));
        assert_eq!(got_b[0], rows(&nodes, 5.0));

        // a third namespace sees zeros everywhere
        let c = TenantStore::new(Arc::clone(&base), "carol", 3).unwrap();
        let (got_c, _) = c.pull(&nodes, false).unwrap();
        assert!(got_c.iter().all(|l| l.iter().all(|&v| v == 0.0)));

        // and the untagged root namespace does too (tag regions are
        // disjoint from low untagged ids)
        let (got_root, _) = base.pull(&nodes, false).unwrap();
        assert!(got_root.iter().all(|l| l.iter().all(|&v| v == 0.0)));
    }

    #[test]
    fn per_tenant_stats_are_isolated() {
        let base = slab();
        let a = TenantStore::new(Arc::clone(&base), "alice", 1).unwrap();
        let b = TenantStore::new(Arc::clone(&base), "bob", 2).unwrap();
        let nodes: Vec<u32> = (0..10).collect();
        a.push(&nodes, &[rows(&nodes, 0.0), rows(&nodes, 1.0)]).unwrap();
        let sa = a.stats().unwrap();
        let sb = b.stats().unwrap();
        assert_eq!((sa.nodes, sa.rows), (10, 20));
        assert_eq!((sb.nodes, sb.rows), (0, 0));
        assert!(sa.raw_tx > 0 && sb.raw_tx == 0);
        // the physical store holds both tenants' rows
        assert_eq!(base.stats().unwrap().nodes, 10);
    }

    #[test]
    fn oversized_node_ids_are_rejected_loudly() {
        let a = TenantStore::new(slab(), "alice", 1).unwrap();
        let err = a
            .push(&[TENANT_NODE_LIMIT], &[vec![0.0; H], vec![0.0; H]])
            .err()
            .expect("id at the limit must be rejected");
        assert!(format!("{err:#}").contains("per-tenant id space"), "{err:#}");
        assert!(a.pull(&[u32::MAX], false).is_err());
        // the largest legal id round-trips
        let last = TENANT_NODE_LIMIT - 1;
        a.push(&[last], &[vec![7.0; H], vec![8.0; H]]).unwrap();
        let (got, _) = a.pull(&[last], false).unwrap();
        assert_eq!(got[0], vec![7.0; H]);
    }

    #[test]
    fn registry_assigns_tags_in_arrival_order() {
        let reg = TenantRegistry::new(slab());
        let a = reg.resolve("alice").unwrap();
        let b = reg.resolve("bob").unwrap();
        assert_eq!((a.tag(), b.tag()), (1, 2));
        // resolving again returns the same namespace, not a new tag
        let a2 = reg.resolve("alice").unwrap();
        assert_eq!(a2.tag(), 1);
        assert!(Arc::ptr_eq(&a, &a2));
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.names(), vec!["alice".to_string(), "bob".to_string()]);
        assert!(reg.resolve("bad name").is_err());
    }

    #[test]
    fn constructor_rejects_bad_tags_and_names() {
        assert!(TenantStore::new(slab(), "alice", 0).is_err());
        assert!(TenantStore::new(slab(), "alice", MAX_TENANTS as u32 + 1).is_err());
        assert!(TenantStore::new(slab(), "no/slash", 1).is_err());
        let t = TenantStore::new(slab(), "alice", 3).unwrap();
        assert_eq!(t.describe(), "tenant(alice#3 over in-process)");
        assert_eq!(t.codec(), "raw");
        assert_eq!(t.epoch(), 0);
    }
}
