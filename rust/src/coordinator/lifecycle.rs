//! Session run-state machine and elastic membership (DESIGN.md §14).
//!
//! A [`Session`](super::session::Session) moves through an explicit
//! [`RunState`]: **warmup** (offline phases + pre-training push) →
//! **rounds** (the federated loop) → **cooldown** (metrics finalized).
//! Membership is no longer fixed at session start: a [`ChurnSpec`]
//! schedules deterministic client joins/departures, applied at round
//! boundaries and recorded in a [`Membership`] ledger of
//! [`MembershipChange`] entries.
//!
//! Re-partitioning is *incremental* — no world re-partition on churn:
//!
//! * [`depart`] re-scores only the leaving client's vertices against the
//!   remaining partitions (most-internal-edges wins, smallest-part then
//!   smallest-id tie-breaks — the same gain rule as the `metis_lite`
//!   refinement sweep), so unaffected partitions keep their exact vertex
//!   sets and the untouched clients' state stays bit-identical.
//! * [`join_split`] grows the new client from a BFS half-split of the
//!   heaviest partition, keeping the split connected where the graph is.
//!
//! Every change records the exact `(vertex, from, to)` moves, so a
//! checkpoint resume replays the ledger verbatim
//! ([`Membership::apply`]) instead of re-deriving it, and property tests
//! can revert it ([`Membership::revert_last`]) back to the original
//! partition bit-for-bit.

use std::collections::{HashSet, VecDeque};

use anyhow::{bail, ensure, Context, Result};

use crate::graph::{Graph, Partition};

/// Explicit lifecycle state of a running session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunState {
    /// Offline phases done or in progress; pre-training push not yet
    /// complete.
    Warmup,
    /// Federated rounds are running.
    Rounds,
    /// The session finished (metrics handed back); no further rounds.
    Cooldown,
}

impl RunState {
    pub fn name(&self) -> &'static str {
        match self {
            RunState::Warmup => "warmup",
            RunState::Rounds => "rounds",
            RunState::Cooldown => "cooldown",
        }
    }
}

/// One scheduled membership event.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ChurnKind {
    /// Client `client` departs before the given round runs.
    Leave { client: usize },
    /// One new client joins before the given round runs (its id is
    /// assigned at apply time: the next unused partition id).
    Join,
}

/// A [`ChurnKind`] pinned to the round boundary it fires at.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ChurnEvent {
    /// Round index (0-based) whose boundary applies the event.
    pub round: usize,
    pub kind: ChurnKind,
}

/// Deterministic scripted join/leave schedule. Grammar (comma-separated,
/// whitespace-tolerant, case-insensitive):
///
/// ```text
/// leave@ROUND:CLIENT   client CLIENT departs before round ROUND
/// join@ROUND           one client joins before round ROUND
/// ```
///
/// The empty spec is structurally inert: a session configured with it is
/// bit-identical to one built before churn existed.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct ChurnSpec {
    /// Events in spec order (same-round events apply in written order).
    pub events: Vec<ChurnEvent>,
}

impl ChurnSpec {
    /// Parse the `leave@R:ID,join@R` grammar. Empty input is the empty
    /// (inert) spec.
    pub fn parse(s: &str) -> Result<ChurnSpec> {
        let mut events = Vec::new();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let lower = tok.to_ascii_lowercase();
            let (kind, rest) = lower.split_once('@').with_context(|| {
                format!("churn event {tok:?}: expected leave@ROUND:CLIENT or join@ROUND")
            })?;
            let kind = match kind {
                "leave" => {
                    let (round, client) = rest.split_once(':').with_context(|| {
                        format!("churn event {tok:?}: leave requires leave@ROUND:CLIENT")
                    })?;
                    let round: usize = round
                        .parse()
                        .with_context(|| format!("churn event {tok:?}: bad round"))?;
                    let client: usize = client
                        .parse()
                        .with_context(|| format!("churn event {tok:?}: bad client id"))?;
                    ChurnEvent {
                        round,
                        kind: ChurnKind::Leave { client },
                    }
                }
                "join" => {
                    if rest.contains(':') {
                        bail!("churn event {tok:?}: join takes only a round (join@ROUND)");
                    }
                    let round: usize = rest
                        .parse()
                        .with_context(|| format!("churn event {tok:?}: bad round"))?;
                    ChurnEvent {
                        round,
                        kind: ChurnKind::Join,
                    }
                }
                other => bail!("churn event {tok:?}: unknown kind {other:?} (leave|join)"),
            };
            events.push(kind);
        }
        Ok(ChurnSpec { events })
    }

    /// Churn schedule from `OPTIMES_CHURN` (default: empty). Unparseable
    /// values warn to stderr and fall back to no churn, like
    /// `OPTIMES_ROUND_POLICY`.
    pub fn from_env() -> ChurnSpec {
        match std::env::var("OPTIMES_CHURN") {
            Ok(v) if !v.is_empty() => match ChurnSpec::parse(&v) {
                Ok(spec) => spec,
                Err(e) => {
                    crate::log!(Warn, "OPTIMES_CHURN={v:?} invalid ({e:#}); ignoring");
                    ChurnSpec::default()
                }
            },
            _ => ChurnSpec::default(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Canonical spec string (round-trips through [`parse`](ChurnSpec::parse)).
    pub fn spec_string(&self) -> String {
        self.events
            .iter()
            .map(|e| match e.kind {
                ChurnKind::Leave { client } => format!("leave@{}:{}", e.round, client),
                ChurnKind::Join => format!("join@{}", e.round),
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Events firing at the boundary before `round`, in spec order.
    pub fn events_at(&self, round: usize) -> Vec<&ChurnEvent> {
        self.events.iter().filter(|e| e.round == round).collect()
    }
}

/// What a membership change did to the partition.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MembershipKind {
    /// Client departed; its vertices were re-assigned.
    Left(usize),
    /// Client joined with this id; it received a split of the heaviest
    /// partition.
    Joined(usize),
}

/// One ledger entry: the change plus the exact vertex moves it made, so
/// replay ([`Membership::apply`]) and revert
/// ([`Membership::revert_last`]) are bit-exact without re-deriving the
/// incremental re-partition.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MembershipChange {
    /// Round boundary the change applied at.
    pub round: usize,
    pub kind: MembershipKind,
    /// `(vertex, from_part, to_part)` for every vertex that moved.
    pub moved: Vec<(u32, u32, u32)>,
}

impl MembershipChange {
    /// Client id this change concerns.
    pub fn client(&self) -> usize {
        match self.kind {
            MembershipKind::Left(id) | MembershipKind::Joined(id) => id,
        }
    }
}

/// The session's membership ledger: which client ids are active, and the
/// ordered history of changes that produced the current partition from
/// the initial one.
#[derive(Clone, Debug, Default)]
pub struct Membership {
    active: Vec<usize>,
    ledger: Vec<MembershipChange>,
}

impl Membership {
    /// Fresh ledger over the initial `k` clients (ids `0..k`).
    pub fn new(k: usize) -> Membership {
        Membership {
            active: (0..k).collect(),
            ledger: Vec::new(),
        }
    }

    /// Active client ids, ascending.
    pub fn active(&self) -> &[usize] {
        &self.active
    }

    pub fn is_active(&self, id: usize) -> bool {
        self.active.binary_search(&id).is_ok()
    }

    /// Ordered history of applied changes.
    pub fn ledger(&self) -> &[MembershipChange] {
        &self.ledger
    }

    fn activate(&mut self, id: usize) {
        if let Err(pos) = self.active.binary_search(&id) {
            self.active.insert(pos, id);
        }
    }

    fn deactivate(&mut self, id: usize) {
        if let Ok(pos) = self.active.binary_search(&id) {
            self.active.remove(pos);
        }
    }

    /// Compute and record a departure at round boundary `round`: the
    /// leaving client's vertices are incrementally re-assigned via
    /// [`depart`]. Fails loudly if `client` is not active or is the last
    /// one standing.
    pub fn record_leave(
        &mut self,
        g: &Graph,
        part: &mut Partition,
        round: usize,
        client: usize,
    ) -> Result<&MembershipChange> {
        ensure!(
            self.is_active(client),
            "churn: client {client} is not active (active: {:?})",
            self.active
        );
        ensure!(
            self.active.len() >= 2,
            "churn: cannot remove the last active client {client}"
        );
        let remaining: Vec<usize> = self.active.iter().copied().filter(|&c| c != client).collect();
        let moved = depart(g, part, client, &remaining);
        self.deactivate(client);
        self.ledger.push(MembershipChange {
            round,
            kind: MembershipKind::Left(client),
            moved,
        });
        Ok(self.ledger.last().expect("just pushed"))
    }

    /// Compute and record a join at round boundary `round`: the new
    /// client (next unused partition id) receives a BFS half-split of
    /// the heaviest active partition via [`join_split`].
    pub fn record_join(
        &mut self,
        g: &Graph,
        part: &mut Partition,
        round: usize,
    ) -> Result<&MembershipChange> {
        ensure!(
            !self.active.is_empty(),
            "churn: cannot join into a session with no active clients"
        );
        let (new_id, moved) = join_split(g, part, &self.active);
        self.activate(new_id);
        self.ledger.push(MembershipChange {
            round,
            kind: MembershipKind::Joined(new_id),
            moved,
        });
        Ok(self.ledger.last().expect("just pushed"))
    }

    /// Re-apply a recorded change (checkpoint resume): replays the
    /// recorded moves verbatim instead of recomputing the incremental
    /// re-partition, so replay stays correct even if the re-partition
    /// heuristic evolves.
    pub fn apply(&mut self, part: &mut Partition, change: MembershipChange) {
        for &(v, _from, to) in &change.moved {
            part.assign[v as usize] = to;
        }
        match change.kind {
            MembershipKind::Left(id) => self.deactivate(id),
            MembershipKind::Joined(id) => {
                part.k = part.k.max(id + 1);
                self.activate(id);
            }
        }
        self.ledger.push(change);
    }

    /// Undo the most recent change, restoring the partition assignment
    /// and active set exactly. Returns the reverted entry.
    pub fn revert_last(&mut self, part: &mut Partition) -> Option<MembershipChange> {
        let change = self.ledger.pop()?;
        for &(v, from, _to) in &change.moved {
            part.assign[v as usize] = from;
        }
        match change.kind {
            MembershipKind::Left(id) => self.activate(id),
            MembershipKind::Joined(id) => {
                self.deactivate(id);
                if id + 1 == part.k {
                    part.k -= 1;
                }
            }
        }
        Some(change)
    }
}

/// Incrementally re-assign every vertex of a departing client: each one
/// goes to the `remaining` partition with the most neighbours (out + in,
/// counted against the evolving assignment so earlier moves attract
/// later ones), tie-broken by smaller current size then smaller part id.
/// Only the departing partition's vertices move; returns the
/// `(vertex, from, to)` list in ascending vertex order.
pub fn depart(
    g: &Graph,
    part: &mut Partition,
    client: usize,
    remaining: &[usize],
) -> Vec<(u32, u32, u32)> {
    assert!(!remaining.is_empty(), "depart needs a surviving partition");
    let mut sizes = part.sizes();
    let owned: Vec<u32> = (0..g.n as u32)
        .filter(|&v| part.assign[v as usize] == client as u32)
        .collect();
    let mut moved = Vec::with_capacity(owned.len());
    for v in owned {
        let mut best: Option<(usize, usize)> = None; // (part, neighbour count)
        for &p in remaining {
            let cnt = g
                .out
                .neighbors(v)
                .iter()
                .chain(g.inc.neighbors(v))
                .filter(|&&t| part.assign[t as usize] == p as u32)
                .count();
            let better = match best {
                None => true,
                Some((bp, bc)) => {
                    cnt > bc || (cnt == bc && (sizes[p], p) < (sizes[bp], bp))
                }
            };
            if better {
                best = Some((p, cnt));
            }
        }
        let (to, _) = best.expect("remaining is non-empty");
        part.assign[v as usize] = to as u32;
        sizes[to] += 1;
        moved.push((v, client as u32, to as u32));
    }
    moved
}

/// Split the heaviest active partition for a joining client: BFS-grow a
/// connected region of half its vertices (seeded from its smallest
/// vertex id; disconnected leftovers re-seed from the next smallest) and
/// hand that region to the new client id `part.k` (which grows by one).
/// Returns `(new_id, moves)`.
pub fn join_split(
    g: &Graph,
    part: &mut Partition,
    active: &[usize],
) -> (usize, Vec<(u32, u32, u32)>) {
    assert!(!active.is_empty(), "join_split needs an active partition");
    let new_id = part.k;
    part.k += 1;
    let sizes = part.sizes();
    let mut heavy = active[0];
    for &p in &active[1..] {
        if sizes[p] > sizes[heavy] {
            heavy = p;
        }
    }
    let members: Vec<u32> = (0..g.n as u32)
        .filter(|&v| part.assign[v as usize] == heavy as u32)
        .collect();
    let take = members.len() / 2;
    let mut moved = Vec::with_capacity(take);
    if take > 0 {
        let member_set: HashSet<u32> = members.iter().copied().collect();
        let mut visited: HashSet<u32> = HashSet::with_capacity(take);
        let mut queue: VecDeque<u32> = VecDeque::new();
        let mut seed_idx = 0usize;
        let mut picked: Vec<u32> = Vec::with_capacity(take);
        while picked.len() < take {
            if queue.is_empty() {
                while seed_idx < members.len() && visited.contains(&members[seed_idx]) {
                    seed_idx += 1;
                }
                let Some(&seed) = members.get(seed_idx) else {
                    break;
                };
                visited.insert(seed);
                queue.push_back(seed);
            }
            let v = queue.pop_front().expect("queue refilled above");
            picked.push(v);
            if picked.len() >= take {
                break;
            }
            for &t in g.out.neighbors(v).iter().chain(g.inc.neighbors(v)) {
                if member_set.contains(&t) && visited.insert(t) {
                    queue.push_back(t);
                }
            }
        }
        for v in picked {
            part.assign[v as usize] = new_id as u32;
            moved.push((v, heavy as u32, new_id as u32));
        }
        moved.sort_unstable();
    }
    (new_id, moved)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny;
    use crate::graph::partition::metis_lite;

    #[test]
    fn churn_grammar_round_trips() {
        let spec = ChurnSpec::parse(" leave@2:1 , join@4 ,LEAVE@5:0").unwrap();
        assert_eq!(spec.events.len(), 3);
        assert_eq!(
            spec.events[0],
            ChurnEvent {
                round: 2,
                kind: ChurnKind::Leave { client: 1 }
            }
        );
        assert_eq!(
            spec.events[1],
            ChurnEvent {
                round: 4,
                kind: ChurnKind::Join
            }
        );
        assert_eq!(spec.spec_string(), "leave@2:1,join@4,leave@5:0");
        assert_eq!(ChurnSpec::parse(&spec.spec_string()).unwrap(), spec);
        assert!(ChurnSpec::parse("").unwrap().is_empty());
        assert!(ChurnSpec::parse("  ").unwrap().is_empty());
        for bad in ["leave@2", "join@2:1", "nope@1", "leave@x:1", "leave@1:y", "join@", "@3"] {
            assert!(ChurnSpec::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn events_at_filters_by_round() {
        let spec = ChurnSpec::parse("leave@2:1,join@2,join@5").unwrap();
        assert_eq!(spec.events_at(2).len(), 2);
        assert_eq!(spec.events_at(5).len(), 1);
        assert!(spec.events_at(0).is_empty());
    }

    fn cover_ok(part: &Partition, g: &Graph, active: &[usize]) {
        let active: HashSet<usize> = active.iter().copied().collect();
        let sizes = part.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), g.n);
        for (v, &p) in part.assign.iter().enumerate() {
            assert!(
                active.contains(&(p as usize)),
                "vertex {v} assigned to inactive part {p}"
            );
        }
    }

    #[test]
    fn depart_moves_only_departed_vertices() {
        let g = tiny(21);
        let mut part = metis_lite(&g, 4, 7);
        let before = part.assign.clone();
        let mut mem = Membership::new(4);
        let change = mem.record_leave(&g, &mut part, 0, 2).unwrap().clone();
        assert_eq!(mem.active(), &[0, 1, 3]);
        cover_ok(&part, &g, mem.active());
        for (v, (&old, &new)) in before.iter().zip(&part.assign).enumerate() {
            if old == 2 {
                assert_ne!(new, 2, "vertex {v} still on departed client");
                assert!(change.moved.contains(&(v as u32, 2, new)));
            } else {
                assert_eq!(old, new, "vertex {v} moved but was not owned by 2");
            }
        }
    }

    #[test]
    fn join_splits_the_heaviest_partition() {
        let g = tiny(23);
        let mut part = metis_lite(&g, 3, 9);
        let sizes = part.sizes();
        let heavy = (0..3).max_by_key(|&p| sizes[p]).unwrap();
        let mut mem = Membership::new(3);
        let change = mem.record_join(&g, &mut part, 1).unwrap().clone();
        assert_eq!(change.kind, MembershipKind::Joined(3));
        assert_eq!(part.k, 4);
        assert_eq!(mem.active(), &[0, 1, 2, 3]);
        cover_ok(&part, &g, mem.active());
        assert_eq!(change.moved.len(), sizes[heavy] / 2);
        for &(_, from, to) in &change.moved {
            assert_eq!(from as usize, heavy);
            assert_eq!(to, 3);
        }
    }

    #[test]
    fn apply_and_revert_round_trip() {
        let g = tiny(25);
        let mut part = metis_lite(&g, 4, 11);
        let original = part.assign.clone();
        let mut mem = Membership::new(4);
        mem.record_leave(&g, &mut part, 0, 1).unwrap();
        mem.record_join(&g, &mut part, 2).unwrap();
        assert_eq!(mem.ledger().len(), 2);

        // replaying the ledger on a fresh partition reproduces it
        let mut replay = Partition {
            k: 4,
            assign: original.clone(),
        };
        let mut mem2 = Membership::new(4);
        for change in mem.ledger().to_vec() {
            mem2.apply(&mut replay, change);
        }
        assert_eq!(replay.assign, part.assign);
        assert_eq!(replay.k, part.k);
        assert_eq!(mem2.active(), mem.active());

        // reverting both changes restores the original exactly
        mem.revert_last(&mut part).unwrap();
        mem.revert_last(&mut part).unwrap();
        assert!(mem.revert_last(&mut part).is_none());
        assert_eq!(part.assign, original);
        assert_eq!(part.k, 4);
        assert_eq!(mem.active(), &[0, 1, 2, 3]);
    }

    #[test]
    fn last_client_cannot_leave() {
        let g = tiny(27);
        let mut part = metis_lite(&g, 2, 3);
        let mut mem = Membership::new(2);
        mem.record_leave(&g, &mut part, 0, 0).unwrap();
        let err = mem.record_leave(&g, &mut part, 1, 1).unwrap_err();
        assert!(format!("{err:#}").contains("last active client"), "{err:#}");
        let err = mem.record_leave(&g, &mut part, 1, 0).unwrap_err();
        assert!(format!("{err:#}").contains("not active"), "{err:#}");
    }
}
