//! Network cost model (substitute for the paper's 1 Gbps Ethernet testbed;
//! DESIGN.md §3).
//!
//! Every embedding-server RPC is accounted in **virtual time**:
//! `t = latency + bytes / bandwidth (+ measured in-memory service time)`.
//! Compute phases use measured wall time; round times compose the two
//! (see `metrics.rs`). This reproduces the paper's pull/train/push
//! breakdowns, whose shape depends only on the comm-bytes : compute-time
//! ratio, deterministically on a single host.

/// Link + serialization parameters.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Payload bandwidth in bytes/second (default: 1 Gbps).
    pub bandwidth: f64,
    /// Per-RPC latency in seconds (connection + framing + redis-style
    /// pipelined dispatch overhead).
    pub latency: f64,
    /// Key/entry overhead in bytes per embedding row (node id + lengths).
    pub per_entry_overhead: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            // The paper's testbed is 1 Gbps (125 MB/s) moving 100k-40M
            // embeddings per round against GPU-scale compute. Our graphs
            // are ~1000x smaller and the CPU-PJRT compute ~100x smaller,
            // so the default link is scaled to 20 MB/s (160 Mbps) to
            // preserve the paper's comm:compute round-time ratios
            // (DESIGN.md §3). Benches that sweep the link pass their own
            // config.
            bandwidth: 20_000_000.0,
            latency: 300e-6,
            per_entry_overhead: 16,
        }
    }
}

impl NetConfig {
    /// Virtual time to move `bytes` in one RPC.
    pub fn time_for_bytes(&self, bytes: usize) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }

    /// Payload bytes for `rows` embedding rows of `hidden` f32 across
    /// `layers` layer databases — the *raw* wire format (4 bytes per
    /// element). Defined via [`emb_bytes_metered`](Self::emb_bytes_metered)
    /// so the raw cost is just the metered cost of a raw payload.
    pub fn emb_bytes(&self, rows: usize, layers: usize, hidden: usize) -> usize {
        self.emb_bytes_metered(rows * layers * hidden * 4, rows, layers)
    }

    /// Wire bytes for an embedding RPC whose **metered encoded payload**
    /// is `payload` bytes covering `rows` rows across `layers` layers:
    /// the payload plus the per-entry key/length overhead. This is what
    /// the codec plane charges (DESIGN.md §11) — virtual network time
    /// responds to the negotiated wire codec instead of assuming every
    /// element crosses as a 4-byte float.
    pub fn emb_bytes_metered(&self, payload: usize, rows: usize, layers: usize) -> usize {
        payload + rows * layers * self.per_entry_overhead
    }

    /// Virtual time for an embedding transfer RPC.
    pub fn emb_time(&self, rows: usize, layers: usize, hidden: usize) -> f64 {
        self.time_for_bytes(self.emb_bytes(rows, layers, hidden))
    }

    /// Model-parameter transfer (used for the global model broadcast /
    /// upload accounting, a minor term).
    pub fn params_time(&self, numel: usize) -> f64 {
        self.time_for_bytes(numel * 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_arithmetic() {
        let n = NetConfig::default();
        // zero bytes still pays latency
        assert!(n.time_for_bytes(0) >= n.latency);
        // 20 MB at the default scaled link ~= 1 s
        let t = n.time_for_bytes(20_000_000);
        assert!((t - 1.0).abs() < 0.01, "{t}");
        // monotone in rows
        assert!(n.emb_time(1000, 2, 32) > n.emb_time(10, 2, 32));
        // bytes: 1000 rows * 2 layers * (128+16)
        assert_eq!(n.emb_bytes(1000, 2, 32), 1000 * 2 * 144);
    }

    #[test]
    fn metered_bytes_respond_to_encoded_payload() {
        let n = NetConfig::default();
        // a raw payload metered explicitly equals the raw formula
        assert_eq!(
            n.emb_bytes_metered(1000 * 2 * 32 * 4, 1000, 2),
            n.emb_bytes(1000, 2, 32)
        );
        // an int8-sized payload (8 + hidden per row) costs less wire
        // time than raw at the same row count — the codec moves the
        // cost model, not just the accounting
        let int8 = n.emb_bytes_metered(1000 * 2 * (8 + 32), 1000, 2);
        assert!(int8 < n.emb_bytes(1000, 2, 32));
        assert!(n.time_for_bytes(int8) < n.emb_time(1000, 2, 32));
        // overhead is still charged per entry
        assert_eq!(n.emb_bytes_metered(0, 10, 2), 10 * 2 * n.per_entry_overhead);
    }

    #[test]
    fn paperlike_magnitudes() {
        // A scaled Reddit push set (~3k embeddings x 2 layers x 144 B) on
        // the scaled link lands in the tens-of-ms range — the same
        // fraction of a round as the paper's 1.8 s on its testbed.
        let n = NetConfig::default();
        let t = n.emb_time(3_000, 2, 32);
        assert!(t > 0.01 && t < 0.1, "{t}");
    }
}
