//! Network cost model (substitute for the paper's 1 Gbps Ethernet testbed;
//! DESIGN.md §3).
//!
//! Every embedding-server RPC is accounted in **virtual time**:
//! `t = latency + bytes / bandwidth (+ measured in-memory service time)`.
//! Compute phases use measured wall time; round times compose the two
//! (see `metrics.rs`). This reproduces the paper's pull/train/push
//! breakdowns, whose shape depends only on the comm-bytes : compute-time
//! ratio, deterministically on a single host.
//!
//! [`ClientLatency`] extends the model with *per-client* heterogeneity:
//! a heavy-tailed (lognormal) per-round report delay, deterministic per
//! `(client, round)`, so straggler experiments (DESIGN.md §12) are
//! reproducible. It is off by default and enabled with
//! `--client-latency lognormal:MU:SIGMA[:SEED]` / `OPTIMES_CLIENT_LATENCY`.

use anyhow::{bail, Context, Result};

use crate::util::rng::Rng;

/// Default seed for the client-latency stream when the spec omits one.
const DEFAULT_LATENCY_SEED: u64 = 0x517A;

/// Per-client heavy-tailed report-delay model: client `c` in round `r`
/// reports `exp(mu + sigma * z)` virtual seconds after its compute
/// finishes, with `z` standard normal drawn from a stream keyed on
/// `(seed, client, round)` — deterministic regardless of scheduling.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClientLatency {
    /// Location of the underlying normal (log-seconds).
    pub mu: f64,
    /// Scale of the underlying normal; larger means heavier tail.
    pub sigma: f64,
    /// Stream seed (distinct seeds give independent straggler patterns).
    pub seed: u64,
}

impl ClientLatency {
    /// Parse `lognormal:MU:SIGMA[:SEED]`.
    pub fn parse(s: &str) -> Result<Self> {
        let mut parts = s.trim().split(':');
        let kind = parts.next().unwrap_or("");
        if kind != "lognormal" {
            bail!("unknown client latency {s:?} (expected lognormal:MU:SIGMA[:SEED])");
        }
        let mu: f64 = parts
            .next()
            .with_context(|| format!("client latency {s:?}: missing MU"))?
            .parse()
            .with_context(|| format!("client latency {s:?}: bad MU"))?;
        let sigma: f64 = parts
            .next()
            .with_context(|| format!("client latency {s:?}: missing SIGMA"))?
            .parse()
            .with_context(|| format!("client latency {s:?}: bad SIGMA"))?;
        if !mu.is_finite() || !sigma.is_finite() || sigma < 0.0 {
            bail!("client latency {s:?}: MU must be finite and SIGMA finite and >= 0");
        }
        let seed: u64 = match parts.next() {
            Some(t) => t
                .parse()
                .with_context(|| format!("client latency {s:?}: bad SEED"))?,
            None => DEFAULT_LATENCY_SEED,
        };
        if parts.next().is_some() {
            bail!("client latency {s:?}: too many fields for lognormal:MU:SIGMA[:SEED]");
        }
        Ok(Self { mu, sigma, seed })
    }

    /// Canonical spec string (round-trips through [`parse`](Self::parse)).
    pub fn spec_string(&self) -> String {
        format!("lognormal:{}:{}:{}", self.mu, self.sigma, self.seed)
    }

    /// Virtual report delay (seconds) for `client` in `round`.
    pub fn sample(&self, client: usize, round: usize) -> f64 {
        let mut rng = Rng::new(
            self.seed ^ 0x57A6_617E,
            ((client as u64) << 32) ^ round as u64,
        );
        (self.mu + self.sigma * rng.normal()).exp()
    }
}

/// Client latency model from `OPTIMES_CLIENT_LATENCY` (default: none).
/// Unparseable values warn to stderr and fall back to no injected latency.
pub fn client_latency_default() -> Option<ClientLatency> {
    match std::env::var("OPTIMES_CLIENT_LATENCY") {
        Ok(v) if !v.is_empty() => match ClientLatency::parse(&v) {
            Ok(l) => Some(l),
            Err(e) => {
                crate::log!(Warn, "OPTIMES_CLIENT_LATENCY={v:?} invalid ({e:#}); disabling");
                None
            }
        },
        _ => None,
    }
}

/// Link + serialization parameters.
#[derive(Clone, Copy, Debug)]
pub struct NetConfig {
    /// Payload bandwidth in bytes/second (default: 1 Gbps).
    pub bandwidth: f64,
    /// Per-RPC latency in seconds (connection + framing + redis-style
    /// pipelined dispatch overhead).
    pub latency: f64,
    /// Key/entry overhead in bytes per embedding row (node id + lengths).
    pub per_entry_overhead: usize,
    /// Optional per-client report-delay model (straggler injection). When
    /// `None` every client reports instantly and all round policies
    /// degenerate to the synchronous barrier.
    pub client_latency: Option<ClientLatency>,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            // The paper's testbed is 1 Gbps (125 MB/s) moving 100k-40M
            // embeddings per round against GPU-scale compute. Our graphs
            // are ~1000x smaller and the CPU-PJRT compute ~100x smaller,
            // so the default link is scaled to 20 MB/s (160 Mbps) to
            // preserve the paper's comm:compute round-time ratios
            // (DESIGN.md §3). Benches that sweep the link pass their own
            // config.
            bandwidth: 20_000_000.0,
            latency: 300e-6,
            per_entry_overhead: 16,
            client_latency: client_latency_default(),
        }
    }
}

impl NetConfig {
    /// Virtual time to move `bytes` in one RPC.
    pub fn time_for_bytes(&self, bytes: usize) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }

    /// Payload bytes for `rows` embedding rows of `hidden` f32 across
    /// `layers` layer databases — the *raw* wire format (4 bytes per
    /// element). Defined via [`emb_bytes_metered`](Self::emb_bytes_metered)
    /// so the raw cost is just the metered cost of a raw payload.
    pub fn emb_bytes(&self, rows: usize, layers: usize, hidden: usize) -> usize {
        self.emb_bytes_metered(rows * layers * hidden * 4, rows, layers)
    }

    /// Wire bytes for an embedding RPC whose **metered encoded payload**
    /// is `payload` bytes covering `rows` rows across `layers` layers:
    /// the payload plus the per-entry key/length overhead. This is what
    /// the codec plane charges (DESIGN.md §11) — virtual network time
    /// responds to the negotiated wire codec instead of assuming every
    /// element crosses as a 4-byte float.
    pub fn emb_bytes_metered(&self, payload: usize, rows: usize, layers: usize) -> usize {
        payload + rows * layers * self.per_entry_overhead
    }

    /// Virtual time for an embedding transfer RPC.
    pub fn emb_time(&self, rows: usize, layers: usize, hidden: usize) -> f64 {
        self.time_for_bytes(self.emb_bytes(rows, layers, hidden))
    }

    /// Model-parameter transfer (used for the global model broadcast /
    /// upload accounting, a minor term).
    pub fn params_time(&self, numel: usize) -> f64 {
        self.time_for_bytes(numel * 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_arithmetic() {
        let n = NetConfig::default();
        // zero bytes still pays latency
        assert!(n.time_for_bytes(0) >= n.latency);
        // 20 MB at the default scaled link ~= 1 s
        let t = n.time_for_bytes(20_000_000);
        assert!((t - 1.0).abs() < 0.01, "{t}");
        // monotone in rows
        assert!(n.emb_time(1000, 2, 32) > n.emb_time(10, 2, 32));
        // bytes: 1000 rows * 2 layers * (128+16)
        assert_eq!(n.emb_bytes(1000, 2, 32), 1000 * 2 * 144);
    }

    #[test]
    fn metered_bytes_respond_to_encoded_payload() {
        let n = NetConfig::default();
        // a raw payload metered explicitly equals the raw formula
        assert_eq!(
            n.emb_bytes_metered(1000 * 2 * 32 * 4, 1000, 2),
            n.emb_bytes(1000, 2, 32)
        );
        // an int8-sized payload (8 + hidden per row) costs less wire
        // time than raw at the same row count — the codec moves the
        // cost model, not just the accounting
        let int8 = n.emb_bytes_metered(1000 * 2 * (8 + 32), 1000, 2);
        assert!(int8 < n.emb_bytes(1000, 2, 32));
        assert!(n.time_for_bytes(int8) < n.emb_time(1000, 2, 32));
        // overhead is still charged per entry
        assert_eq!(n.emb_bytes_metered(0, 10, 2), 10 * 2 * n.per_entry_overhead);
    }

    #[test]
    fn paperlike_magnitudes() {
        // A scaled Reddit push set (~3k embeddings x 2 layers x 144 B) on
        // the scaled link lands in the tens-of-ms range — the same
        // fraction of a round as the paper's 1.8 s on its testbed.
        let n = NetConfig::default();
        let t = n.emb_time(3_000, 2, 32);
        assert!(t > 0.01 && t < 0.1, "{t}");
    }

    #[test]
    fn client_latency_parse_and_roundtrip() {
        let l = ClientLatency::parse("lognormal:-0.9:1.5:11").unwrap();
        assert_eq!(l, ClientLatency { mu: -0.9, sigma: 1.5, seed: 11 });
        assert_eq!(ClientLatency::parse(&l.spec_string()).unwrap(), l);
        // seed is optional
        let d = ClientLatency::parse("lognormal:0:1").unwrap();
        assert_eq!(d.seed, DEFAULT_LATENCY_SEED);
        for bad in [
            "", "uniform:0:1", "lognormal", "lognormal:0", "lognormal:x:1",
            "lognormal:0:-1", "lognormal:0:1:z", "lognormal:0:1:2:3",
        ] {
            assert!(ClientLatency::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn client_latency_is_deterministic_per_client_round() {
        let l = ClientLatency::parse("lognormal:-1:1.2:7").unwrap();
        for c in 0..4 {
            for r in 0..4 {
                let a = l.sample(c, r);
                assert!(a.is_finite() && a > 0.0);
                assert_eq!(a, l.sample(c, r), "sample not deterministic");
            }
        }
        // different clients / rounds see different delays
        assert_ne!(l.sample(0, 0), l.sample(1, 0));
        assert_ne!(l.sample(0, 0), l.sample(0, 1));
    }

    #[test]
    fn client_latency_has_a_heavy_tail() {
        let l = ClientLatency { mu: 0.0, sigma: 1.5, seed: 3 };
        let xs: Vec<f64> = (0..2000).map(|i| l.sample(i % 50, i / 50)).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2];
        let p95 = sorted[sorted.len() * 95 / 100];
        // lognormal(0, 1.5): median e^0 = 1, p95 ~ e^{1.645*1.5} ~ 11.8
        assert!((median - 1.0).abs() < 0.3, "median={median}");
        assert!(p95 > 5.0 * median, "p95={p95} median={median}");
    }
}
