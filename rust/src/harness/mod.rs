//! Bench/figure harness: engine + store factories, session caching,
//! ASCII tables, and one generator per paper table/figure (see
//! DESIGN.md §6).
//!
//! Environment knobs (all optional):
//! * `OPTIMES_ENGINE=ref|pjrt` — force the compute engine (default: PJRT
//!   when `artifacts/manifest.json` exists, RefEngine otherwise).
//! * `OPTIMES_SCALE=n` — dataset shrink divisor (default 2 for benches).
//! * `OPTIMES_ROUNDS=n` — override federated rounds per session.
//! * `OPTIMES_FRESH=1` — ignore the session cache under `reports/`.
//! * `OPTIMES_SERVER=host:port[,host:port...]` — back sessions by remote
//!   embedding stores over TCP (several addresses = hash-sharded).
//! * `OPTIMES_SHARDS=n` — back sessions by an n-way sharded in-process
//!   store (ignored when `OPTIMES_SERVER` is set).
//! * `OPTIMES_REPLICAS=r` — keep r extra replicas of every embedding row
//!   across the sharded backends (`run --replicas`; needs more shards
//!   than replicas; DESIGN.md §10). Results are bit-identical to r=0.
//! * `OPTIMES_FAULT_SPEC=spec` — wrap each shard backend in a
//!   deterministic fault injector (`run --fault-spec`; grammar in
//!   [`FaultSpec`], e.g. `shard1=blackout@40;*=delay%10:0.005`).
//! * `OPTIMES_PIPELINE=off` — disable the asynchronous push/pull
//!   pipeline over the store (default on; DESIGN.md §9). Results are
//!   bit-identical either way, only wall clock changes.
//! * `OPTIMES_WIRE_CODEC=raw|f16|bf16|int8|topk:K[,delta[:EPS]]` — run
//!   the embedding plane under a wire codec (`run --wire-codec`;
//!   DESIGN.md §11): TCP backends negotiate it per connection, model
//!   backends round-trip values through it, and `bytes_tx`/`bytes_rx`
//!   meter the encoded payload. Default `raw` (today's format).
//! * `OPTIMES_ROUND_POLICY=sync|quorum:K[:SLACK]|deadline:SECS` — the
//!   round-advancement policy (`run --round-policy`; DESIGN.md §12).
//!   With zero injected latency every policy matches sync bit-exactly.
//! * `OPTIMES_STALENESS=S` — bounded-staleness window for late updates
//!   under non-sync policies (`run --staleness`; default 2).
//! * `OPTIMES_CLIENT_LATENCY=lognormal:MU:SIGMA[:SEED]` — per-client
//!   heavy-tailed report delays, deterministic per (client, round)
//!   (`run --client-latency`; stragglers for the policies to tolerate).
//! * `OPTIMES_GRAPH_BACKEND=ram|mmap` — serve the graph's bulk arrays
//!   from heap `Vec`s or from mapped `GraphFile` pages (`run
//!   --graph-backend`; DESIGN.md §13). Accuracy curves are bit-identical
//!   either way, only peak RSS changes.
//! * `OPTIMES_PARTITIONER=metis|hash|ldg` — how the graph is split
//!   across clients (`run --partitioner`; DESIGN.md §13.3). `ldg` is the
//!   streaming greedy pass that also works straight off a `GraphFile`.
//! * `OPTIMES_CHURN=leave@R:C,join@R,...` — scripted elastic membership
//!   (`run --churn`; DESIGN.md §14): client departures/joins applied
//!   deterministically at round boundaries. Empty (the default) is
//!   bit-identical to a session without the churn plane.
//! * `OPTIMES_CHECKPOINT=DIR[:EVERY]` — write a resumable whole-session
//!   checkpoint bundle into `DIR` every `EVERY` rounds (`run
//!   --checkpoint`; default every round). `optimes resume DIR` continues
//!   it bit-for-bit (DESIGN.md §14).
//! * `OPTIMES_TENANT=NAME` — bind the session to a named namespace on
//!   the embedding plane (`run --tenant`; DESIGN.md §15). Many sessions
//!   share one daemon, each seeing only its own rows and stats.
//! * `OPTIMES_REPLICA_SELECT=primary|fastest` — replica read policy of
//!   sharded stores (`run --replica-select`; DESIGN.md §15). `fastest`
//!   (default) routes each read to the lowest-EWMA-latency owner.
//! * `OPTIMES_TRACE=FILE` — record a span timeline of the run and write
//!   it to `FILE` as Chrome/Perfetto `trace_event` JSON (`run --trace`;
//!   DESIGN.md §16). Tracing is a pure observer: results are
//!   bit-identical with it on or off (`tests/observability.rs`).
//! * `OPTIMES_LOG=error|warn|info|debug` — stderr diagnostic level for
//!   [`log!`](crate::log) sites (`run --log`; default `info`).
//! * `OPTIMES_TRACE_CAP=n` — tracer ring capacity in spans (default
//!   65536; oldest spans are overwritten beyond that).

pub mod figures;
pub mod report;

use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use crate::coordinator::metrics::RoundMetrics;
use crate::coordinator::{
    sharded_desc, EmbeddingServer, EmbeddingStore, FaultSpec, NetConfig, RoundObserver,
    SessionBuilder, SessionConfig, SessionMetrics, ShardedStore, Strategy, TcpEmbeddingStore,
    TenantStore,
};
use crate::graph::datasets::{self, DatasetPreset};
use crate::graph::Graph;
use crate::runtime::{Manifest, ModelGeom, ModelKind, PjrtEngine, RefEngine, StepEngine};
use crate::wire::{self, CodecSpec};

pub fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

/// Repo-root perf trajectory file (EXPERIMENTS.md §Perf).
pub fn bench_json_path() -> std::path::PathBuf {
    std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_micro.json"))
}

/// Merge one bench's section into `BENCH_micro.json`, preserving the
/// other sections so `micro_substrates` and `bench_roundtime` can each
/// record their numbers independently.
pub fn record_bench_section(section: &str, payload: crate::util::json::JsonObj) {
    use crate::util::json::{Json, JsonObj};
    let path = bench_json_path();
    let existing = std::fs::read_to_string(&path).ok();
    let parsed = existing
        .as_deref()
        .and_then(|t| Json::parse(t).ok())
        .and_then(|j| j.as_obj().cloned());
    if let (Some(text), None) = (&existing, &parsed) {
        if !text.trim().is_empty() {
            crate::log!(
                Warn,
                "{} exists but is not a JSON object; its previous \
                 sections will be replaced",
                path.display()
            );
        }
    }
    let mut root = parsed.unwrap_or_default();
    let mut meta = JsonObj::new();
    meta.set(
        "regenerate",
        "cargo bench --bench micro_substrates && cargo bench --bench bench_roundtime \
         && cargo bench --bench loadgen",
    );
    root.set("_meta", meta);
    root.set(section, payload);
    if let Err(e) = std::fs::write(&path, Json::Obj(root).to_string_pretty()) {
        crate::log!(Warn, "could not write {}: {e}", path.display());
    }
}

pub fn reports_dir() -> std::path::PathBuf {
    let d = std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/reports"));
    let _ = std::fs::create_dir_all(&d);
    d
}

fn env_usize(name: &str) -> Option<usize> {
    std::env::var(name).ok().and_then(|v| v.parse().ok())
}

pub fn dataset_scale() -> usize {
    env_usize("OPTIMES_SCALE").unwrap_or(2).max(1)
}

pub fn rounds_override() -> Option<usize> {
    env_usize("OPTIMES_ROUNDS")
}

/// Default RefEngine geometry for a fanout (mirrors `DEFAULT_CONFIGS`).
pub fn default_geom(model: ModelKind, fanout: usize) -> ModelGeom {
    let batch = match fanout {
        10 => 8,
        15 => 4,
        _ => 32,
    };
    ModelGeom {
        model,
        layers: 3,
        feat: 32,
        hidden: 32,
        classes: 16,
        batch,
        fanout,
        push_batch: 64,
    }
}

/// Engine name actually in use ("pjrt" or "ref") for table footers.
pub fn engine_kind() -> &'static str {
    match std::env::var("OPTIMES_ENGINE").as_deref() {
        Ok("ref") => "ref",
        Ok("pjrt") => "pjrt",
        _ => {
            if artifacts_dir().join("manifest.json").exists() {
                "pjrt"
            } else {
                "ref"
            }
        }
    }
}

/// Build the compute engine for (model, fanout).
pub fn make_engine(model: ModelKind, fanout: usize) -> Result<Arc<dyn StepEngine>> {
    match engine_kind() {
        "pjrt" => {
            let manifest = Manifest::load(artifacts_dir())
                .map_err(|e| anyhow!("artifacts missing (run `make artifacts`): {e}"))?;
            manifest.validate()?;
            Ok(Arc::new(PjrtEngine::start(&manifest, model, fanout)?))
        }
        _ => Ok(Arc::new(RefEngine::new(default_geom(model, fanout)))),
    }
}

/// Load a dataset preset at the harness scale.
pub fn load_dataset(name: &str) -> Result<(DatasetPreset, Graph)> {
    datasets::load(name, dataset_scale()).ok_or_else(|| anyhow!("unknown dataset {name}"))
}

/// The embedding-plane backend selected by the environment knobs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StoreSpec {
    /// Default: one fresh in-process slab server per session.
    InProcess,
    /// Remote TCP stores; >1 address means hash-sharding across them.
    Tcp(Vec<String>),
    /// N-way sharded in-process store.
    ShardedInProcess(usize),
}

/// Replication factor of the embedding plane (`OPTIMES_REPLICAS`,
/// default 0 = the classic unreplicated partition).
pub fn store_replicas() -> usize {
    env_usize("OPTIMES_REPLICAS").unwrap_or(0)
}

/// Parse `OPTIMES_FAULT_SPEC` (empty spec when unset).
pub fn fault_spec() -> Result<FaultSpec> {
    match std::env::var("OPTIMES_FAULT_SPEC") {
        Ok(s) if !s.trim().is_empty() => FaultSpec::parse(&s),
        _ => Ok(FaultSpec::default()),
    }
}

/// Parse `OPTIMES_WIRE_CODEC` (plain raw when unset; DESIGN.md §11).
pub fn wire_codec_spec() -> Result<CodecSpec> {
    wire::spec_from_env()
}

/// Tenant namespace of the session (`OPTIMES_TENANT`; `None` = the
/// classic single-session store, DESIGN.md §15).
pub fn tenant() -> Option<String> {
    match std::env::var("OPTIMES_TENANT") {
        Ok(t) if !t.trim().is_empty() => Some(t.trim().to_string()),
        _ => None,
    }
}

/// Read `OPTIMES_SERVER` / `OPTIMES_SHARDS` into a [`StoreSpec`].
pub fn store_spec() -> StoreSpec {
    if let Ok(s) = std::env::var("OPTIMES_SERVER") {
        let addrs: Vec<String> = s
            .split(',')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect();
        if !addrs.is_empty() {
            return StoreSpec::Tcp(addrs);
        }
    }
    if let Some(n) = env_usize("OPTIMES_SHARDS") {
        if n > 1 {
            return StoreSpec::ShardedInProcess(n);
        }
    }
    StoreSpec::InProcess
}

/// Human-readable description of the active store backend + shard count
/// (the `optimes info` line). The strings deliberately match what
/// [`EmbeddingStore::describe`] reports into `SessionMetrics`, so `info`
/// and the session reports never disagree about the backend. (Under
/// `OPTIMES_FAULT_SPEC`, the faulted shards additionally carry a
/// `fault(..)` wrapper in the session's own describe string.)
pub fn store_desc() -> String {
    let codec = wire_codec_spec().unwrap_or_default();
    let ten = tenant();
    let tcp_inner = |addr: &str| {
        let base = if codec.codec.is_raw() {
            format!("tcp({addr})")
        } else {
            format!("tcp({addr}, {})", codec.codec.name())
        };
        // TCP tenancy is negotiated per connection: the wrapper shows up
        // on each backend, inside any sharded composition
        match &ten {
            Some(t) => format!("tenant({t} over {base})"),
            None => base,
        }
    };
    let base = match store_spec() {
        StoreSpec::InProcess => "in-process".into(),
        StoreSpec::Tcp(addrs) if addrs.len() == 1 && store_replicas() == 0 => tcp_inner(&addrs[0]),
        StoreSpec::Tcp(addrs) => sharded_desc(addrs.len(), &tcp_inner(&addrs[0]), store_replicas()),
        StoreSpec::ShardedInProcess(n) => sharded_desc(n, "in-process", store_replicas()),
    };
    // TCP backends carry the codec on the wire; model backends get the
    // CodecStore wrapper — mirror `make_store`'s composition exactly
    let desc = if matches!(store_spec(), StoreSpec::Tcp(_)) {
        CodecSpec {
            codec: crate::wire::CodecKind::Raw,
            delta: codec.delta,
        }
        .wrapped_desc(base)
    } else {
        codec.wrapped_desc(base)
    };
    // in-process tenancy is a client-side decorator around the whole
    // composition — mirror `make_store` exactly
    match (&ten, store_spec()) {
        (Some(t), StoreSpec::InProcess | StoreSpec::ShardedInProcess(_)) => {
            format!("tenant({t}#1 over {desc})")
        }
        _ => desc,
    }
}

/// Number of embedding-plane shards (backend count) the active
/// [`StoreSpec`] fans out over.
pub fn store_shards() -> usize {
    match store_spec() {
        StoreSpec::InProcess => 1,
        StoreSpec::Tcp(addrs) => addrs.len(),
        StoreSpec::ShardedInProcess(n) => n,
    }
}

/// Build the embedding store for the active [`StoreSpec`] at the given
/// engine geometry, honoring `OPTIMES_REPLICAS` (replicated routing)
/// and `OPTIMES_FAULT_SPEC` (per-shard fault injection).
pub fn make_store(geom: &ModelGeom, net: NetConfig) -> Result<Arc<dyn EmbeddingStore>> {
    let (n_layers, hidden) = (geom.layers - 1, geom.hidden);
    let replicas = store_replicas();
    let spec = fault_spec()?;
    let wire_spec = wire_codec_spec()?;
    let ten = tenant();
    let store: Arc<dyn EmbeddingStore> = match store_spec() {
        StoreSpec::InProcess => {
            ensure!(
                replicas == 0,
                "OPTIMES_REPLICAS={replicas} needs a sharded store \
                 (--shards N with N > replicas, or multiple --server addresses)"
            );
            spec.validate_shards(1)?;
            let base = spec.wrap_shard(0, Arc::new(EmbeddingServer::new(n_layers, hidden, net)));
            wire_spec.wrap_store(base, net)
        }
        StoreSpec::Tcp(addrs) => {
            spec.validate_shards(addrs.len())?;
            // the codec rides the wire itself (per-connection CODEC
            // handshake); only the delta combinator wraps client-side
            let backends: Vec<Arc<dyn EmbeddingStore>> = addrs
                .iter()
                .enumerate()
                .map(|(i, a)| {
                    TcpEmbeddingStore::connect_opts(
                        a.as_str(),
                        n_layers,
                        hidden,
                        wire_spec.codec.clone(),
                        ten.clone(),
                    )
                    .map(|s| spec.wrap_shard(i, Arc::new(s)))
                })
                .collect::<Result<_>>()?;
            let base: Arc<dyn EmbeddingStore> = if backends.len() == 1 && replicas == 0 {
                backends.into_iter().next().expect("one backend")
            } else {
                Arc::new(ShardedStore::replicated(backends, replicas)?)
            };
            wire_spec.wrap_delta(base)
        }
        StoreSpec::ShardedInProcess(n) => {
            spec.validate_shards(n)?;
            let backends: Vec<Arc<dyn EmbeddingStore>> = (0..n)
                .map(|i| spec.wrap_shard(i, Arc::new(EmbeddingServer::new(n_layers, hidden, net))))
                .collect();
            wire_spec.wrap_store(Arc::new(ShardedStore::replicated(backends, replicas)?), net)
        }
    };
    // TCP tenancy already rode the per-connection handshake above;
    // in-process sessions get the client-side namespace decorator
    let store = match (&ten, store_spec()) {
        (Some(t), StoreSpec::InProcess | StoreSpec::ShardedInProcess(_)) => {
            Arc::new(TenantStore::new(store, t, 1)?) as Arc<dyn EmbeddingStore>
        }
        _ => store,
    };
    Ok(store)
}

/// Streams per-round progress of harness-driven sessions to stderr at
/// `info` level (the tables still render from the final metrics on
/// stdout; `OPTIMES_LOG=warn` silences the stream).
struct ProgressObserver {
    key: String,
    total: usize,
}

impl RoundObserver for ProgressObserver {
    fn on_round(&mut self, r: &RoundMetrics) {
        crate::log!(
            Info,
            "  [{}] round {:>2}/{} acc {:5.2}%  time {:.3}s",
            self.key,
            r.round + 1,
            self.total,
            r.accuracy * 100.0,
            r.round_time
        );
    }
}

/// Default session config for a (preset, strategy) pair at bench scale.
pub fn bench_config(p: &DatasetPreset, strategy: Strategy, clients: usize) -> SessionConfig {
    SessionConfig {
        dataset: p.name.to_string(),
        clients,
        strategy,
        rounds: rounds_override().unwrap_or(16),
        epochs: 3,
        lr: 0.01,
        epoch_batches: p.epoch_batches,
        eval_batches: 16,
        seed: 42,
        parallel_clients: false,
        ..Default::default()
    }
}

/// Run (or reload from `reports/sessions/`) one session.
pub fn cached_session(
    key: &str,
    g: &Graph,
    cfg: &SessionConfig,
    engine: &Arc<dyn StepEngine>,
) -> Result<SessionMetrics> {
    let dir = reports_dir().join("sessions");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("{key}.json"));
    let fresh = std::env::var("OPTIMES_FRESH").is_ok();
    if !fresh && path.exists() {
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Some(m) = report::session_from_json(&text) {
                return Ok(m);
            }
        }
    }
    let store = make_store(engine.geom(), cfg.net)?;
    let m = SessionBuilder::new(cfg.clone())
        .store(store)
        .observer(Box::new(ProgressObserver {
            key: key.to_string(),
            total: cfg.rounds,
        }))
        .build(g, Arc::clone(engine))?
        .run()?;
    let _ = std::fs::write(&path, report::session_to_json(&m).to_string_pretty());
    Ok(m)
}

/// Cache key for a session: dataset/strategy/model/geometry/knobs.
pub fn session_key(
    dataset: &str,
    strategy: &str,
    model: ModelKind,
    fanout: usize,
    clients: usize,
    rounds: usize,
) -> String {
    // non-raw wire codecs shape values, so they get their own cache
    // slot; the raw default keeps the historical key unchanged
    let wire = wire_codec_spec().map(|s| s.spec_string()).unwrap_or_else(|_| "raw".into());
    let suffix = if wire == "raw" {
        String::new()
    } else {
        format!("_w{}", wire.replace(':', "-").replace(',', "+"))
    };
    // non-sync round policies and injected client latency change the
    // curve, so they get their own cache slots; the sync/no-latency
    // default keeps the historical key unchanged
    let policy = crate::coordinator::round_policy_default().name();
    let psuffix = if policy == "sync" {
        String::new()
    } else {
        format!("_p{}", policy.replace(':', "-"))
    };
    let lsuffix = match crate::coordinator::client_latency_default() {
        Some(l) => format!("_l{}", l.spec_string().replace(':', "-")),
        None => String::new(),
    };
    // a non-default partitioner changes the curve; a non-default graph
    // backend doesn't, but gets its own slot anyway so backend-parity
    // runs never read each other's caches
    let partitioner = crate::graph::PartitionerKind::from_env();
    let ksuffix = if partitioner == crate::graph::PartitionerKind::default() {
        String::new()
    } else {
        format!("_k{}", partitioner.name())
    };
    let backend = crate::storage::GraphBackend::from_env();
    let bsuffix = if backend == crate::storage::GraphBackend::default() {
        String::new()
    } else {
        format!("_g{}", backend.name())
    };
    // a churn schedule changes the curve; the empty default keeps the
    // historical key unchanged
    let churn = crate::coordinator::ChurnSpec::from_env();
    let csuffix = if churn.is_empty() {
        String::new()
    } else {
        format!("_c{}", churn.spec_string().replace(':', "-").replace(',', "+"))
    };
    // tenancy doesn't change the curve, but namespaced sessions get
    // their own slot so multi-tenant runs never read each other's caches
    let tsuffix = match tenant() {
        Some(t) => format!("_t{t}"),
        None => String::new(),
    };
    format!(
        "{dataset}_{strategy}_{}_k{fanout}_c{clients}_r{rounds}_s{}_{}\
         {suffix}{psuffix}{lsuffix}{ksuffix}{bsuffix}{csuffix}{tsuffix}",
        model.as_str(),
        dataset_scale(),
        engine_kind()
    )
}

// ---------------------------------------------------------------------------
// ASCII tables
// ---------------------------------------------------------------------------

/// Fixed-width table printer for bench output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String], widths: &[usize]| {
            out.push('|');
            for (c, w) in cells.iter().zip(widths) {
                out.push_str(&format!(" {c:<w$} |"));
            }
            out.push('\n');
        };
        line(&mut out, &self.headers, &widths);
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row, &widths);
        }
        out
    }

    pub fn print(&self, title: &str) {
        println!("\n== {title} ==");
        print!("{}", self.render());
    }
}

pub fn fmt_f(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

pub fn fmt_pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

pub fn fmt_opt_time(t: Option<f64>) -> String {
    match t {
        Some(t) => format!("{t:.2}s"),
        None => "—".into(),
    }
}

/// Human-readable byte count (B / KB / MB / GB, decimal units).
pub fn fmt_bytes(b: usize) -> String {
    let b = b as f64;
    if b < 1e3 {
        format!("{b:.0} B")
    } else if b < 1e6 {
        format!("{:.1} KB", b / 1e3)
    } else if b < 1e9 {
        format!("{:.2} MB", b / 1e6)
    } else {
        format!("{:.2} GB", b / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "strategy", "x"]);
        t.row(vec!["1".into(), "OPP".into(), "2.50s".into()]);
        t.row(vec!["22".into(), "D".into(), "—".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // compare display width in chars (cells may contain multi-byte
        // glyphs like the em-dash)
        let w = lines[0].chars().count();
        assert!(lines.iter().all(|l| l.chars().count() == w));
    }

    #[test]
    fn default_geoms_match_artifact_family() {
        let g = default_geom(ModelKind::Gc, 5);
        assert_eq!((g.batch, g.fanout), (32, 5));
        let g = default_geom(ModelKind::Gc, 10);
        assert_eq!((g.batch, g.fanout), (8, 10));
        let g = default_geom(ModelKind::Gc, 15);
        assert_eq!((g.batch, g.fanout), (4, 15));
    }

    #[test]
    fn session_key_distinguishes_configs() {
        let a = session_key("reddit-s", "E", ModelKind::Gc, 5, 4, 16);
        let b = session_key("reddit-s", "E", ModelKind::Sage, 5, 4, 16);
        let c = session_key("reddit-s", "OP", ModelKind::Gc, 5, 4, 16);
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn bytes_format_is_compact() {
        assert_eq!(fmt_bytes(0), "0 B");
        assert_eq!(fmt_bytes(999), "999 B");
        assert_eq!(fmt_bytes(12_300), "12.3 KB");
        assert_eq!(fmt_bytes(4_560_000), "4.56 MB");
        assert_eq!(fmt_bytes(7_890_000_000), "7.89 GB");
    }
}
