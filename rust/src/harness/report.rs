//! SessionMetrics <-> JSON (report persistence + the session cache).
//!
//! The serialized form keeps everything the figure generators consume:
//! round traces (times, phase breakdowns, accuracy), session-level remote
//! stats, and the flattened RPC records (Fig 12). Per-client traces are
//! collapsed — the cache stores the aggregate view.

use crate::coordinator::metrics::{
    ClientRoundMetrics, OverlapMetrics, PhaseTimes, RoundMetrics, RpcKind, RpcRecord,
    SessionMetrics,
};
use crate::util::json::{Json, JsonObj};

fn phases_json(p: &PhaseTimes) -> Json {
    let mut o = JsonObj::new();
    o.set("pull", p.pull)
        .set("train", p.train)
        .set("dyn_pull", p.dyn_pull)
        .set("push", p.push)
        .set("push_hidden", p.push_hidden);
    Json::Obj(o)
}

fn phases_from(j: &Json) -> PhaseTimes {
    PhaseTimes {
        pull: j.at("pull").as_f64().unwrap_or(0.0),
        train: j.at("train").as_f64().unwrap_or(0.0),
        dyn_pull: j.at("dyn_pull").as_f64().unwrap_or(0.0),
        push: j.at("push").as_f64().unwrap_or(0.0),
        push_hidden: j.at("push_hidden").as_f64().unwrap_or(0.0),
    }
}

fn kind_tag(k: RpcKind) -> f64 {
    match k {
        RpcKind::Pull => 0.0,
        RpcKind::PullOnDemand => 1.0,
        RpcKind::Push => 2.0,
    }
}

fn kind_from(v: f64) -> RpcKind {
    match v as usize {
        0 => RpcKind::Pull,
        1 => RpcKind::PullOnDemand,
        _ => RpcKind::Push,
    }
}

pub fn session_to_json(m: &SessionMetrics) -> Json {
    let mut o = JsonObj::new();
    o.set("strategy", m.strategy.as_str());
    o.set("dataset", m.dataset.as_str());
    o.set("store_backend", m.store_backend.as_str());
    o.set("wire_codec", m.wire_codec.as_str());
    o.set("round_policy", m.round_policy.as_str());
    o.set("pipelined", m.pipelined);
    o.set("store_epoch", m.store_epoch);
    o.set("bytes_raw_tx", m.bytes_raw_tx);
    o.set("bytes_raw_rx", m.bytes_raw_rx);
    o.set("n_clients", m.n_clients);
    o.set("server_embeddings", m.server_embeddings);
    o.set("pull_candidates", m.pull_candidates);
    o.set("retained_remotes", m.retained_remotes);
    // aggregate measured pipeline overlap (per-client traces collapse)
    o.set("overlap", m.overlap_stats().to_json());
    let rounds: Vec<Json> = m
        .rounds
        .iter()
        .map(|r| {
            let mut ro = JsonObj::new();
            let active: Vec<Json> = r
                .active_clients
                .iter()
                .map(|&c| Json::Num(c as f64))
                .collect();
            ro.set("round", r.round)
                .set("round_time", r.round_time)
                .set("active_clients", Json::Arr(active))
                .set("accuracy", r.accuracy)
                .set("val_loss", r.val_loss)
                .set("failovers", r.failovers)
                .set("bytes_tx", r.bytes_tx)
                .set("bytes_rx", r.bytes_rx)
                .set("quorum_wait", r.quorum_wait)
                .set("stragglers_late", r.stragglers_late)
                .set("stragglers_dropped", r.stragglers_dropped)
                .set("stale_folded", r.stale_folded)
                .set("stale_weight_applied", r.stale_weight_applied)
                .set("mean_phases", phases_json(&r.mean_phases))
                .set("critical", phases_json(&r.critical));
            Json::Obj(ro)
        })
        .collect();
    o.set("rounds", Json::Arr(rounds));
    // flattened rpc tuples [kind, rows, time, bytes, client]: keyed by
    // the *stable* client id so per-client attribution survives elastic
    // membership (a mid-run departure leaves ids sparse, never shifted)
    let mut rpcs: Vec<Json> = Vec::new();
    for r in &m.rounds {
        for c in &r.clients {
            for rec in &c.rpcs {
                rpcs.push(Json::Arr(vec![
                    Json::Num(kind_tag(rec.kind)),
                    Json::Num(rec.rows as f64),
                    Json::Num(rec.time),
                    Json::Num(rec.bytes as f64),
                    Json::Num(c.client as f64),
                ]));
            }
        }
    }
    o.set("rpcs", Json::Arr(rpcs));
    Json::Obj(o)
}

pub fn session_from_json(text: &str) -> Option<SessionMetrics> {
    let j = Json::parse(text).ok()?;
    let mut m = SessionMetrics {
        strategy: j.at("strategy").as_str()?.to_string(),
        dataset: j.at("dataset").as_str()?.to_string(),
        store_backend: j
            .at("store_backend")
            .as_str()
            .unwrap_or_default()
            .to_string(),
        wire_codec: j.at("wire_codec").as_str().unwrap_or("raw").to_string(),
        round_policy: j.at("round_policy").as_str().unwrap_or("sync").to_string(),
        pipelined: j.at("pipelined").as_bool().unwrap_or(false),
        store_epoch: j.at("store_epoch").as_usize().unwrap_or(0) as u64,
        bytes_raw_tx: j.at("bytes_raw_tx").as_usize().unwrap_or(0),
        bytes_raw_rx: j.at("bytes_raw_rx").as_usize().unwrap_or(0),
        n_clients: j.at("n_clients").as_usize()?,
        server_embeddings: j.at("server_embeddings").as_usize().unwrap_or(0),
        pull_candidates: j.at("pull_candidates").as_usize().unwrap_or(0),
        retained_remotes: j.at("retained_remotes").as_usize().unwrap_or(0),
        ..Default::default()
    };
    for rj in j.at("rounds").as_arr()? {
        m.rounds.push(RoundMetrics {
            round: rj.at("round").as_usize().unwrap_or(0),
            round_time: rj.at("round_time").as_f64().unwrap_or(0.0),
            accuracy: rj.at("accuracy").as_f64().unwrap_or(0.0),
            val_loss: rj.at("val_loss").as_f64().unwrap_or(0.0),
            failovers: rj.at("failovers").as_usize().unwrap_or(0),
            bytes_tx: rj.at("bytes_tx").as_usize().unwrap_or(0),
            bytes_rx: rj.at("bytes_rx").as_usize().unwrap_or(0),
            quorum_wait: rj.at("quorum_wait").as_f64().unwrap_or(0.0),
            stragglers_late: rj.at("stragglers_late").as_usize().unwrap_or(0),
            stragglers_dropped: rj.at("stragglers_dropped").as_usize().unwrap_or(0),
            stale_folded: rj.at("stale_folded").as_usize().unwrap_or(0),
            stale_weight_applied: rj.at("stale_weight_applied").as_f64().unwrap_or(0.0),
            mean_phases: phases_from(rj.at("mean_phases")),
            critical: phases_from(rj.at("critical")),
            active_clients: rj
                .at("active_clients")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_usize())
                .collect(),
            clients: Vec::new(),
        });
    }
    // re-attach the flattened RPC records grouped by stable client id on
    // the first round so `SessionMetrics::rpcs()` keeps working and
    // per-client attribution survives the cache round-trip (pre-churn
    // reports without the 5th tuple element collapse to client 0)
    let mut by_client: std::collections::BTreeMap<usize, Vec<RpcRecord>> =
        std::collections::BTreeMap::new();
    for t in j.at("rpcs").as_arr().unwrap_or(&[]) {
        let rec = (|| {
            Some((
                RpcRecord {
                    kind: kind_from(t.idx(0).as_f64()?),
                    rows: t.idx(1).as_usize()?,
                    time: t.idx(2).as_f64()?,
                    bytes: t.idx(3).as_usize().unwrap_or(0),
                },
                t.idx(4).as_usize().unwrap_or(0),
            ))
        })();
        if let Some((rec, client)) = rec {
            by_client.entry(client).or_default().push(rec);
        }
    }
    // re-attach the aggregate overlap stats to the same synthetic client
    // so `SessionMetrics::overlap_stats()` survives the cache round-trip
    let ovj = j.at("overlap");
    let overlap = OverlapMetrics {
        pipelined: ovj.at("pipelined").as_bool().unwrap_or(false),
        push_wall: ovj.at("push_wall").as_f64().unwrap_or(0.0),
        push_wait: ovj.at("push_wait").as_f64().unwrap_or(0.0),
        pull_wall: ovj.at("pull_wall").as_f64().unwrap_or(0.0),
        pull_wait: ovj.at("pull_wait").as_f64().unwrap_or(0.0),
        overlap_saved: ovj.at("overlap_saved").as_f64().unwrap_or(0.0),
        push_bytes: ovj.at("push_bytes").as_usize().unwrap_or(0),
        pull_bytes: ovj.at("pull_bytes").as_usize().unwrap_or(0),
        queue_peak: ovj.at("queue_peak").as_usize().unwrap_or(0),
        store_epoch: ovj.at("store_epoch").as_usize().unwrap_or(0) as u64,
    };
    if !by_client.is_empty() || overlap.pipelined {
        if m.rounds.is_empty() {
            m.rounds.push(RoundMetrics::default());
        }
        if by_client.is_empty() {
            by_client.insert(0, Vec::new());
        }
        // the aggregate overlap rides on the first synthetic entry only,
        // so summing across clients stays correct
        let mut overlap = Some(overlap);
        for (client, rpcs) in by_client {
            m.rounds[0].clients.push(ClientRoundMetrics {
                client,
                rpcs,
                overlap: overlap.take().unwrap_or_default(),
                ..Default::default()
            });
        }
    }
    Some(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_json_roundtrip() {
        let mut m = SessionMetrics {
            strategy: "OPP".into(),
            dataset: "reddit-s".into(),
            store_backend: "tcp(10.0.0.2:7070)".into(),
            wire_codec: "int8".into(),
            round_policy: "quorum:3:0.1".into(),
            store_epoch: 2,
            bytes_raw_tx: 9000,
            bytes_raw_rx: 4000,
            n_clients: 4,
            server_embeddings: 123,
            pull_candidates: 500,
            retained_remotes: 400,
            ..Default::default()
        };
        for i in 0..3 {
            let mut r = RoundMetrics {
                round: i,
                round_time: 1.5 + i as f64,
                accuracy: 0.5 + 0.1 * i as f64,
                val_loss: 2.0 - 0.1 * i as f64,
                failovers: 3 + i,
                bytes_tx: 1000 * (i + 1),
                bytes_rx: 300 * (i + 1),
                quorum_wait: 0.05 * i as f64,
                stragglers_late: i,
                stragglers_dropped: i / 2,
                stale_folded: i,
                stale_weight_applied: 0.5 * i as f64,
                ..Default::default()
            };
            r.mean_phases.pull = 0.2;
            r.mean_phases.train = 1.0;
            r.clients.push(ClientRoundMetrics {
                client: 0,
                rpcs: vec![RpcRecord {
                    kind: RpcKind::PullOnDemand,
                    rows: 40 + i,
                    bytes: 100,
                    time: 0.01,
                }],
                overlap: OverlapMetrics {
                    pipelined: true,
                    push_wall: 0.5,
                    push_wait: 0.1,
                    overlap_saved: 0.4,
                    push_bytes: 77,
                    queue_peak: 2,
                    ..Default::default()
                },
                ..Default::default()
            });
            m.rounds.push(r);
        }
        let text = session_to_json(&m).to_string_pretty();
        let back = session_from_json(&text).unwrap();
        assert_eq!(back.strategy, "OPP");
        assert_eq!(back.rounds.len(), 3);
        assert!((back.rounds[2].accuracy - 0.7).abs() < 1e-9);
        assert!((back.median_round_time() - m.median_round_time()).abs() < 1e-9);
        assert_eq!(back.rpcs(RpcKind::PullOnDemand).len(), 3);
        assert_eq!(back.server_embeddings, 123);
        assert_eq!(back.store_backend, "tcp(10.0.0.2:7070)");
        assert_eq!(back.store_epoch, 2);
        assert_eq!(back.rounds[1].failovers, 4);
        assert_eq!(back.total_failovers(), 5);
        // the wire-compression plane survives the roundtrip too
        assert_eq!(back.wire_codec, "int8");
        assert_eq!(back.rounds[1].bytes_tx, 2000);
        assert_eq!((back.total_bytes_tx(), back.total_bytes_rx()), (3000, 900));
        assert_eq!((back.bytes_raw_tx, back.bytes_raw_rx), (9000, 4000));
        assert!((back.wire_ratio() - 13000.0 / 3900.0).abs() < 1e-9);
        // derived metrics survive the roundtrip
        assert!((back.peak_accuracy() - m.peak_accuracy()).abs() < 1e-9);
        // aggregate measured overlap survives too
        let (a, b) = (m.overlap_stats(), back.overlap_stats());
        assert!(b.pipelined);
        assert!((a.push_wall - b.push_wall).abs() < 1e-9);
        assert!((a.overlap_saved - b.overlap_saved).abs() < 1e-9);
        assert_eq!(a.queue_peak, b.queue_peak);
        assert_eq!(a.push_bytes, b.push_bytes);
        assert_eq!(b.push_bytes, 3 * 77);
        // straggler accounting (DESIGN.md §12) survives the roundtrip
        assert_eq!(back.round_policy, "quorum:3:0.1");
        assert_eq!(back.rounds[2].stragglers_late, 2);
        assert_eq!(back.total_stragglers_late(), 3);
        assert_eq!(back.total_stragglers_dropped(), 1);
        assert_eq!(back.total_stale_folded(), 3);
        assert!((back.total_stale_weight() - 1.5).abs() < 1e-9);
        assert!((back.total_quorum_wait() - 0.15).abs() < 1e-9);
    }

    #[test]
    fn report_survives_mid_run_departure() {
        // elastic membership: client 2 leaves after round 0, a new
        // client 4 joins for round 2 — per-client fields stay keyed by
        // stable id, never by position (DESIGN.md §14)
        let mut m = SessionMetrics {
            strategy: "E".into(),
            dataset: "tiny".into(),
            n_clients: 4,
            ..Default::default()
        };
        let rosters: [&[usize]; 3] = [&[0, 1, 2, 3], &[0, 1, 3], &[0, 1, 3, 4]];
        for (i, roster) in rosters.iter().enumerate() {
            let mut r = RoundMetrics {
                round: i,
                accuracy: 0.4 + 0.1 * i as f64,
                active_clients: roster.to_vec(),
                ..Default::default()
            };
            for &id in roster.iter() {
                r.clients.push(ClientRoundMetrics {
                    client: id,
                    rpcs: vec![RpcRecord {
                        kind: RpcKind::Pull,
                        rows: 10 + id,
                        bytes: 40,
                        time: 0.01,
                    }],
                    ..Default::default()
                });
            }
            m.rounds.push(r);
        }
        let text = session_to_json(&m).to_string_pretty();
        let back = session_from_json(&text).unwrap();
        assert_eq!(back.rounds.len(), 3);
        assert_eq!(back.rounds[0].active_clients, vec![0, 1, 2, 3]);
        assert_eq!(back.rounds[1].active_clients, vec![0, 1, 3]);
        assert_eq!(back.rounds[2].active_clients, vec![0, 1, 3, 4]);
        // all 11 rpc records survive, grouped by stable client id
        assert_eq!(back.rpcs(RpcKind::Pull).len(), 11);
        let groups = &back.rounds[0].clients;
        let ids: Vec<usize> = groups.iter().map(|c| c.client).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        // client 2 appears in exactly one round; client 0 in all three
        let count =
            |id: usize| groups.iter().find(|c| c.client == id).unwrap().rpcs.len();
        assert_eq!(count(2), 1);
        assert_eq!(count(0), 3);
        assert_eq!(count(4), 1);
        // rows carry the id stamp through the round-trip
        assert!(groups
            .iter()
            .find(|c| c.client == 4)
            .unwrap()
            .rpcs
            .iter()
            .all(|r| r.rows == 14));
    }
}
