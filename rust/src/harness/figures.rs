//! One generator per paper table/figure (DESIGN.md §6). Each prints the
//! paper-style rows and writes `reports/<id>.json`. Absolute numbers come
//! from the scaled testbed; the reproduction target is the *shape* (who
//! wins, by roughly what factor, where crossovers fall).

use anyhow::Result;

use super::{
    bench_config, cached_session, fmt_opt_time, fmt_pct, load_dataset, make_engine,
    reports_dir, session_key, Table,
};
use crate::coordinator::metrics::{paper_target_accuracy, RpcKind, SessionMetrics};
use crate::coordinator::{ScoreKind, Strategy};
use crate::graph::scoring;
use crate::graph::subgraph::{build_all, Prune};
use crate::graph::partition::metis_lite;
use crate::runtime::ModelKind;
use crate::util::json::{Json, JsonObj};
use crate::util::stats;

const ALL_DATASETS: [&str; 4] = ["arxiv-s", "reddit-s", "products-s", "papers-s"];

fn write_report(name: &str, j: &Json) {
    let path = reports_dir().join(format!("{name}.json"));
    let _ = std::fs::write(&path, j.to_string_pretty());
    crate::log!(Info, "[report] wrote {}", path.display());
}

/// Run the given strategies on a dataset (cached).
pub fn ladder_sessions(
    dataset: &str,
    model: ModelKind,
    fanout: usize,
    strategies: &[Strategy],
    clients_override: Option<usize>,
) -> Result<Vec<SessionMetrics>> {
    let (p, g) = load_dataset(dataset)?;
    let clients = clients_override.unwrap_or(p.default_clients);
    let engine = make_engine(model, fanout)?;
    let mut out = Vec::with_capacity(strategies.len());
    for s in strategies {
        let cfg = bench_config(&p, s.clone(), clients);
        let key = session_key(dataset, &s.name, model, fanout, clients, cfg.rounds);
        out.push(cached_session(&key, &g, &cfg, &engine)?);
    }
    Ok(out)
}

fn tta_table(title: &str, sessions: &[SessionMetrics]) -> (Table, f64) {
    let refs: Vec<&SessionMetrics> = sessions.iter().collect();
    let target = paper_target_accuracy(&refs);
    let mut t = Table::new(&["strategy", "peak acc", "TTA(s)", "median round(s)"]);
    for m in sessions {
        t.row(vec![
            m.strategy.clone(),
            fmt_pct(m.peak_accuracy()),
            fmt_opt_time(m.time_to_accuracy(target)),
            format!("{:.3}", m.median_round_time()),
        ]);
    }
    t.print(&format!("{title} (target acc {:.1}%)", target * 100.0));
    (t, target)
}

fn sessions_json(sessions: &[SessionMetrics], target: f64) -> Json {
    Json::Arr(
        sessions
            .iter()
            .map(|m| {
                let mut o = JsonObj::new();
                o.set("strategy", m.strategy.as_str())
                    .set("dataset", m.dataset.as_str())
                    .set("peak_accuracy", m.peak_accuracy())
                    .set("tta", m.time_to_accuracy(target).unwrap_or(-1.0))
                    .set("median_round_time", m.median_round_time())
                    .set("server_embeddings", m.server_embeddings);
                let p = m.median_phases();
                let mut ph = JsonObj::new();
                ph.set("pull", p.pull)
                    .set("train", p.train)
                    .set("dyn_pull", p.dyn_pull)
                    .set("push", p.push);
                o.set("median_phases", ph);
                // measured (real) pipeline overlap, next to the virtual
                // push_hidden model — DESIGN.md §9
                let ov = m.overlap_stats();
                if ov.pipelined {
                    o.set("overlap", ov.to_json());
                }
                o.set("smoothed_accuracy", m.smoothed_accuracies());
                o.set(
                    "round_times",
                    m.rounds.iter().map(|r| r.round_time).collect::<Vec<_>>(),
                );
                Json::Obj(o)
            })
            .collect(),
    )
}

// ---------------------------------------------------------------------------
// Table 1 — dataset statistics
// ---------------------------------------------------------------------------

pub fn table1() -> Result<Json> {
    let mut t = Table::new(&[
        "graph", "paper", "|V|", "|E|", "feat", "classes", "avg in-deg", "train verts",
        "paper |V|", "paper |E|", "paper deg",
    ]);
    let mut arr = Vec::new();
    for name in ALL_DATASETS {
        let (p, g) = load_dataset(name)?;
        let mut o = JsonObj::new();
        o.set("name", name)
            .set("v", g.n)
            .set("e", g.out.m())
            .set("avg_in_deg", g.avg_in_degree())
            .set("train", g.train_nodes.len());
        arr.push(Json::Obj(o));
        t.row(vec![
            name.into(),
            p.paper_name.into(),
            format!("{}", g.n),
            format!("{}", g.out.m()),
            format!("{}", g.feat_dim),
            format!("{}", g.classes),
            format!("{:.1}", g.avg_in_degree()),
            format!("{}", g.train_nodes.len()),
            p.paper_v.into(),
            p.paper_e.into(),
            format!("{:.1}", p.paper_avg_deg),
        ]);
    }
    t.print("Table 1 — graph datasets (scaled)");
    let j = Json::Arr(arr);
    write_report("table1", &j);
    Ok(j)
}

// ---------------------------------------------------------------------------
// Fig 2a — remote-vertex fraction + embeddings maintained
// ---------------------------------------------------------------------------

pub fn fig2a() -> Result<Json> {
    let mut t = Table::new(&[
        "graph", "clients", "pull candidates", "% vertices remote", "emb stored (E)",
        "emb stored (OPG)", "reduction",
    ]);
    let mut arr = Vec::new();
    for name in ALL_DATASETS {
        let (p, g) = load_dataset(name)?;
        let part = metis_lite(&g, p.default_clients, 42);
        let full = build_all(&g, &part, &Prune::None, 42);
        let candidates: usize = full.iter().map(|s| s.pull_candidates).sum();
        let stored_e: usize = full.iter().map(|s| s.n_remote()).sum();
        // OPG: per-client frequency-scored top-25%
        let prunes: Vec<Prune> = full
            .iter()
            .map(|sub| Prune::TopFrac {
                frac: 0.25,
                scores: scoring::frequency_scores_global(sub, 3, 768, 42),
            })
            .collect();
        let pruned = crate::graph::subgraph::build_all_per_client(&g, &part, &prunes, 42);
        let stored_opg: usize = pruned.iter().map(|s| s.n_remote()).sum();
        let frac = candidates as f64 / g.n as f64;
        let mut o = JsonObj::new();
        o.set("name", name)
            .set("remote_fraction", frac)
            .set("stored_e", stored_e)
            .set("stored_opg", stored_opg);
        arr.push(Json::Obj(o));
        t.row(vec![
            name.into(),
            format!("{}", p.default_clients),
            format!("{candidates}"),
            fmt_pct(frac),
            format!("{stored_e}"),
            format!("{stored_opg}"),
            format!("{:.1}x", stored_e as f64 / stored_opg.max(1) as f64),
        ]);
    }
    t.print("Fig 2a — remote vertices & embeddings maintained");
    let j = Json::Arr(arr);
    write_report("fig2a", &j);
    Ok(j)
}

// ---------------------------------------------------------------------------
// Fig 2b — headline TTA (Products)
// ---------------------------------------------------------------------------

pub fn fig2b() -> Result<Json> {
    let strategies = vec![Strategy::d(), Strategy::e(), Strategy::opp()];
    let sessions = ladder_sessions("products-s", ModelKind::Gc, 5, &strategies, None)?;
    let (_, target) = tta_table("Fig 2b — headline time-to-accuracy, products-s", &sessions);
    let j = sessions_json(&sessions, target);
    write_report("fig2b", &j);
    Ok(j)
}

// ---------------------------------------------------------------------------
// Fig 6 — TTA + peak accuracy, all datasets, GraphConv
// Fig 7 — median round time + phase breakdown (same sessions)
// Fig 8 — accuracy convergence (same sessions)
// ---------------------------------------------------------------------------

pub fn fig6(model: ModelKind, datasets: &[&str]) -> Result<Json> {
    let mut all = JsonObj::new();
    for name in datasets {
        let sessions = ladder_sessions(name, model, 5, &Strategy::ladder(), None)?;
        let (_, target) = tta_table(
            &format!("Fig 6 — {name} ({})", model.as_str()),
            &sessions,
        );
        all.set(*name, sessions_json(&sessions, target));
    }
    let j = Json::Obj(all);
    write_report(&format!("fig6_{}", model.as_str()), &j);
    Ok(j)
}

pub fn fig7(model: ModelKind, datasets: &[&str]) -> Result<Json> {
    let mut all = JsonObj::new();
    for name in datasets {
        let sessions = ladder_sessions(name, model, 5, &Strategy::ladder(), None)?;
        let mut t = Table::new(&[
            "strategy", "round(s)", "pull", "train", "dyn pull", "push", "push hidden",
            "saved/round (real)",
        ]);
        for m in &sessions {
            let p = m.median_phases();
            // per-round mean so the real column is comparable to the
            // per-round virtual columns beside it
            let saved = m.overlap_stats().overlap_saved / m.rounds.len().max(1) as f64;
            t.row(vec![
                m.strategy.clone(),
                format!("{:.3}", m.median_round_time()),
                format!("{:.3}", p.pull),
                format!("{:.3}", p.train),
                format!("{:.3}", p.dyn_pull),
                format!("{:.3}", p.push),
                format!("{:.3}", p.push_hidden),
                format!("{:.3}", saved),
            ]);
        }
        t.print(&format!(
            "Fig 7 — median round breakdown, {name} ({})",
            model.as_str()
        ));
        all.set(*name, sessions_json(&sessions, 0.0));
    }
    let j = Json::Obj(all);
    write_report(&format!("fig7_{}", model.as_str()), &j);
    Ok(j)
}

pub fn fig8(model: ModelKind, datasets: &[&str]) -> Result<Json> {
    let mut all = JsonObj::new();
    for name in datasets {
        let sessions = ladder_sessions(name, model, 5, &Strategy::ladder(), None)?;
        println!("\n== Fig 8 — convergence (5-round moving avg), {name} ==");
        for m in &sessions {
            let series: Vec<String> = m
                .smoothed_accuracies()
                .iter()
                .map(|a| format!("{:.2}", a * 100.0))
                .collect();
            println!("{:>6}: {}", m.strategy, series.join(" "));
        }
        all.set(*name, sessions_json(&sessions, 0.0));
    }
    let j = Json::Obj(all);
    write_report("fig8", &j);
    Ok(j)
}

/// Fig 9 — SAGEConv: TTA/accuracy + round breakdowns on 3 graphs.
pub fn fig9() -> Result<Json> {
    let datasets = ["reddit-s", "products-s", "arxiv-s"];
    fig6(ModelKind::Sage, &datasets)?;
    fig7(ModelKind::Sage, &datasets)
}

// ---------------------------------------------------------------------------
// Fig 10 — retention-limit sweep
// ---------------------------------------------------------------------------

pub fn fig10() -> Result<Json> {
    let mut all = JsonObj::new();
    for name in ["reddit-s", "products-s", "arxiv-s"] {
        let mut strategies = vec![Strategy::parse("P0").unwrap()];
        for i in [2usize, 4, 8] {
            strategies.push(Strategy::p(i));
        }
        strategies.push(Strategy::parse("Pinf").unwrap());
        let sessions = ladder_sessions(name, ModelKind::Gc, 5, &strategies, None)?;
        let mut t = Table::new(&[
            "retention", "peak acc", "round(s)", "pull", "train", "push", "emb stored",
        ]);
        for m in &sessions {
            let p = m.median_phases();
            t.row(vec![
                m.strategy.clone(),
                fmt_pct(m.peak_accuracy()),
                format!("{:.3}", m.median_round_time()),
                format!("{:.3}", p.pull),
                format!("{:.3}", p.train),
                format!("{:.3}", p.push),
                format!("{}", m.server_embeddings),
            ]);
        }
        t.print(&format!("Fig 10 — retention sweep (P_i), {name}"));
        all.set(name, sessions_json(&sessions, 0.0));
    }
    let j = Json::Obj(all);
    write_report("fig10", &j);
    Ok(j)
}

// ---------------------------------------------------------------------------
// Fig 11 — scoring ablation (Reddit, GC + SAGE)
// ---------------------------------------------------------------------------

pub fn fig11() -> Result<Json> {
    let mut all = JsonObj::new();
    for model in [ModelKind::Gc, ModelKind::Sage] {
        let strategies = vec![
            Strategy::e(),
            Strategy::opg_with(0.25, ScoreKind::Random),
            Strategy::opg_with(0.05, ScoreKind::Frequency),
            Strategy::opg_with(0.25, ScoreKind::Frequency),
            Strategy::opg_with(0.50, ScoreKind::Frequency),
            Strategy::opg_with(0.75, ScoreKind::Frequency),
            Strategy::opg_with(0.25, ScoreKind::Bridge),
            Strategy::opg_with(0.25, ScoreKind::Degree),
        ];
        let sessions = ladder_sessions("reddit-s", model, 5, &strategies, None)?;
        let (_, target) = tta_table(
            &format!("Fig 11 — scoring ablation, reddit-s ({})", model.as_str()),
            &sessions,
        );
        all.set(model.as_str(), sessions_json(&sessions, target));
    }
    let j = Json::Obj(all);
    write_report("fig11", &j);
    Ok(j)
}

// ---------------------------------------------------------------------------
// Fig 12 — pull-phase analysis (Products)
// ---------------------------------------------------------------------------

pub fn fig12() -> Result<Json> {
    let strategies = vec![
        Strategy::opp_with(0.0, ScoreKind::Frequency),  // OPP_T0
        Strategy::opp_with(0.25, ScoreKind::Frequency), // OPP_T25
        Strategy::opp_with(0.25, ScoreKind::Random),    // OPP_R25
    ];
    let sessions = ladder_sessions("products-s", ModelKind::Gc, 5, &strategies, None)?;
    let mut all = JsonObj::new();

    // 12a/12b: nodes per dynamic-pull RPC, its service time, and the
    // bytes each strategy actually put on the wire (pulls + pushes, as
    // metered by the active codec — DESIGN.md §11), so the paper's
    // network-cost comparison is reproducible from bytes, not only time
    let mut t = Table::new(&[
        "strategy",
        "dyn RPCs",
        "nodes/RPC p25",
        "median",
        "p75",
        "time/RPC median(ms)",
        "wire KB/RPC",
        "wire total",
    ]);
    for m in &sessions {
        let recs = m.rpcs(RpcKind::PullOnDemand);
        let rows: Vec<f64> = recs.iter().map(|r| r.rows as f64).collect();
        let times: Vec<f64> = recs.iter().map(|r| r.time * 1e3).collect();
        let wire_kb: Vec<f64> = recs.iter().map(|r| r.bytes as f64 / 1e3).collect();
        let total_bytes: usize = m
            .rpcs(RpcKind::Pull)
            .iter()
            .chain(m.rpcs(RpcKind::PullOnDemand).iter())
            .chain(m.rpcs(RpcKind::Push).iter())
            .map(|r| r.bytes)
            .sum();
        let rs = stats::summarize(&rows);
        let ts = stats::summarize(&times);
        let ws = stats::summarize(&wire_kb);
        t.row(vec![
            m.strategy.clone(),
            format!("{}", recs.len()),
            format!("{:.0}", rs.p25),
            format!("{:.0}", rs.median),
            format!("{:.0}", rs.p75),
            format!("{:.2}", ts.median),
            format!("{:.1}", ws.median),
            crate::harness::fmt_bytes(total_bytes),
        ]);
        let mut o = JsonObj::new();
        o.set("nodes_per_rpc", rows)
            .set("rpc_times_ms", times)
            .set("rpc_wire_kb", wire_kb)
            .set("wire_total_bytes", total_bytes);
        all.set(format!("dist_{}", m.strategy), o);
    }
    t.print("Fig 12a/12b — dynamic pull RPCs, products-s");

    // 12c: nodes/RPC vs service-time fit
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for m in &sessions {
        for r in m.rpcs(RpcKind::PullOnDemand) {
            xs.push(r.rows as f64);
            ys.push(r.time * 1e3);
        }
    }
    if let Some(fit) = stats::linfit(&xs, &ys) {
        println!(
            "\nFig 12c — fit: time_ms = {:.3} + {:.5} * nodes (R^2 = {:.3}, n = {})",
            fit.intercept,
            fit.slope,
            fit.r2,
            xs.len()
        );
        let mut o = JsonObj::new();
        o.set("intercept", fit.intercept)
            .set("slope", fit.slope)
            .set("r2", fit.r2)
            .set("n", xs.len());
        all.set("fit", o);
    }

    // 12d: total pull time vs minibatch count (T0 vs T25)
    let (p, g) = load_dataset("products-s")?;
    let engine = make_engine(ModelKind::Gc, 5)?;
    let mut t = Table::new(&["batches/epoch", "T0 total pull(s)", "T25 total pull(s)"]);
    let mut d = Vec::new();
    for eb in [4usize, 8, 16, 32] {
        let mut row = vec![format!("{eb}")];
        let mut vals = JsonObj::new();
        vals.set("batches", eb);
        for s in [
            Strategy::opp_with(0.0, ScoreKind::Frequency),
            Strategy::opp_with(0.25, ScoreKind::Frequency),
        ] {
            let mut cfg = bench_config(&p, s.clone(), p.default_clients);
            cfg.epoch_batches = eb;
            cfg.rounds = 4;
            let key = format!(
                "{}_eb{eb}",
                session_key("products-s", &s.name, ModelKind::Gc, 5, p.default_clients, 4)
            );
            let m = cached_session(&key, &g, &cfg, &engine)?;
            let ph = m.median_phases();
            let total_pull = ph.pull + ph.dyn_pull;
            row.push(format!("{total_pull:.3}"));
            vals.set(format!("pull_{}", s.name), total_pull);
        }
        d.push(Json::Obj(vals));
        t.row(row);
    }
    t.print("Fig 12d — total pull time vs minibatches/epoch, products-s");
    all.set("pull_vs_batches", Json::Arr(d));

    let j = Json::Obj(all);
    write_report("fig12", &j);
    Ok(j)
}

// ---------------------------------------------------------------------------
// Fig 13 — client scaling (4/6/8)
// ---------------------------------------------------------------------------

pub fn fig13() -> Result<Json> {
    let strategies = vec![
        Strategy::d(),
        Strategy::e(),
        Strategy::o(),
        Strategy::opp(),
        Strategy::opg(),
    ];
    let mut all = JsonObj::new();
    for name in ["reddit-s", "products-s"] {
        let mut per_ds = JsonObj::new();
        for clients in [4usize, 6, 8] {
            let sessions =
                ladder_sessions(name, ModelKind::Gc, 5, &strategies, Some(clients))?;
            let (_, target) = tta_table(
                &format!("Fig 13 — {name}, {clients} clients"),
                &sessions,
            );
            per_ds.set(format!("c{clients}"), sessions_json(&sessions, target));
        }
        all.set(name, per_ds);
    }
    let j = Json::Obj(all);
    write_report("fig13", &j);
    Ok(j)
}

// ---------------------------------------------------------------------------
// Fig 14 — fanout sweep (Reddit)
// ---------------------------------------------------------------------------

pub fn fig14() -> Result<Json> {
    let strategies = vec![
        Strategy::e(),
        Strategy::op(),
        Strategy::opp(),
        Strategy::opg(),
    ];
    let mut all = JsonObj::new();
    for fanout in [5usize, 10, 15] {
        let sessions = ladder_sessions("reddit-s", ModelKind::Gc, fanout, &strategies, None)?;
        let (_, target) = tta_table(&format!("Fig 14 — reddit-s, fanout {fanout}"), &sessions);
        all.set(format!("k{fanout}"), sessions_json(&sessions, target));
    }
    let j = Json::Obj(all);
    write_report("fig14", &j);
    Ok(j)
}

// ---------------------------------------------------------------------------
// Fig straggler — TTA under heavy-tailed client latency, sync vs quorum vs
// deadline (DESIGN.md §12)
// ---------------------------------------------------------------------------

pub fn fig_straggler() -> Result<Json> {
    let dataset = "reddit-s";
    let latency = crate::coordinator::ClientLatency::parse("lognormal:-1.6:1.5:7")?;
    let (p, g) = load_dataset(dataset)?;
    let clients = p.default_clients;
    let engine = make_engine(ModelKind::Gc, 5)?;
    let policies = [
        crate::coordinator::RoundPolicySpec::Sync,
        crate::coordinator::RoundPolicySpec::Quorum {
            k: (clients * 3 + 3) / 4,
            slack: 0.05,
        },
        crate::coordinator::RoundPolicySpec::Deadline { budget: 0.5 },
    ];
    let mut sessions = Vec::with_capacity(policies.len());
    for spec in &policies {
        let mut cfg = bench_config(&p, Strategy::e(), clients);
        cfg.round_policy = spec.clone();
        cfg.net.client_latency = Some(latency);
        let key = format!(
            "{}_straggler_{}",
            session_key(dataset, "E", ModelKind::Gc, 5, clients, cfg.rounds),
            spec.name().replace(':', "-")
        );
        sessions.push(cached_session(&key, &g, &cfg, &engine)?);
    }
    let refs: Vec<&SessionMetrics> = sessions.iter().collect();
    let target = paper_target_accuracy(&refs);
    let mut t = Table::new(&[
        "policy", "peak acc", "TTA(s)", "median round(s)", "late", "folded", "dropped",
        "quorum wait(s)",
    ]);
    let mut arr = Vec::new();
    for m in &sessions {
        t.row(vec![
            m.round_policy.clone(),
            fmt_pct(m.peak_accuracy()),
            fmt_opt_time(m.time_to_accuracy(target)),
            format!("{:.3}", m.median_round_time()),
            format!("{}", m.total_stragglers_late()),
            format!("{}", m.total_stale_folded()),
            format!("{}", m.total_stragglers_dropped()),
            format!("{:.3}", m.total_quorum_wait()),
        ]);
        let mut o = JsonObj::new();
        o.set("policy", m.round_policy.as_str())
            .set("peak_accuracy", m.peak_accuracy())
            .set("tta", m.time_to_accuracy(target).unwrap_or(-1.0))
            .set("median_round_time", m.median_round_time())
            .set("stragglers_late", m.total_stragglers_late())
            .set("stale_folded", m.total_stale_folded())
            .set("stragglers_dropped", m.total_stragglers_dropped())
            .set("stale_weight_applied", m.total_stale_weight())
            .set("quorum_wait", m.total_quorum_wait())
            .set("smoothed_accuracy", m.smoothed_accuracies())
            .set(
                "round_times",
                m.rounds.iter().map(|r| r.round_time).collect::<Vec<_>>(),
            );
        arr.push(Json::Obj(o));
    }
    t.print(&format!(
        "Fig straggler — TTA under {} client latency, {dataset} (target acc {:.1}%)",
        latency.spec_string(),
        target * 100.0
    ));
    let mut all = JsonObj::new();
    all.set("dataset", dataset)
        .set("client_latency", latency.spec_string())
        .set("target_accuracy", target)
        .set("sessions", Json::Arr(arr));
    let j = Json::Obj(all);
    write_report("fig_straggler", &j);
    Ok(j)
}

/// Run every table/figure (the `optimes fig all` path).
pub fn run_all() -> Result<()> {
    table1()?;
    fig2a()?;
    fig2b()?;
    fig6(ModelKind::Gc, &ALL_DATASETS)?;
    fig7(ModelKind::Gc, &ALL_DATASETS)?;
    fig8(ModelKind::Gc, &ALL_DATASETS)?;
    fig9()?;
    fig10()?;
    fig11()?;
    fig12()?;
    fig13()?;
    fig14()?;
    fig_straggler()?;
    Ok(())
}

/// Dispatch by figure id ("table1", "2a", "6", "9", ...).
pub fn run_figure(id: &str) -> Result<()> {
    match id {
        "table1" | "t1" => table1().map(|_| ()),
        "2a" => fig2a().map(|_| ()),
        "2b" => fig2b().map(|_| ()),
        "6" => fig6(ModelKind::Gc, &ALL_DATASETS).map(|_| ()),
        "7" => fig7(ModelKind::Gc, &ALL_DATASETS).map(|_| ()),
        "8" => fig8(ModelKind::Gc, &ALL_DATASETS).map(|_| ()),
        "9" => fig9().map(|_| ()),
        "10" => fig10().map(|_| ()),
        "11" => fig11().map(|_| ()),
        "12" => fig12().map(|_| ()),
        "13" => fig13().map(|_| ()),
        "14" => fig14().map(|_| ()),
        "straggler" => fig_straggler().map(|_| ()),
        "all" => run_all(),
        other => anyhow::bail!(
            "unknown figure id {other:?} (try: table1, 2a, 2b, 6..14, straggler, all)"
        ),
    }
}
