//! Unified observability plane (DESIGN.md §16): span tracing, a
//! wire-scrapeable metrics registry, and leveled logging — all
//! zero-dependency and, above all, **pure observers**: with every knob
//! enabled, accuracy curves and checkpoint bytes are bit-identical to a
//! run with the plane disabled (`tests/observability.rs`).
//!
//! Three pillars:
//!
//! * [`trace`] — a thread-safe, ring-buffered [`Tracer`] with RAII
//!   [`SpanGuard`]s (name, start/end wall-ns, tid, key=value attrs)
//!   instrumented through the hot seams (session rounds/phases, trainer
//!   epochs/batches, pipeline tickets, sharded fan-outs, per-RPC server
//!   handling, churn/checkpoint events). Default-off; `OPTIMES_TRACE=FILE`
//!   (or `run --trace FILE`) enables it and exports Chrome/Perfetto
//!   `trace_event` JSON so a whole federated round renders as a timeline.
//! * [`metrics`] — a [`Registry`] of named counters, gauges, and
//!   log-bucketed [`Histogram`]s (lock-free atomics; p50/p99/p999 with
//!   mergeable buckets), rendered as a Prometheus-style text exposition
//!   by the daemon's wire op=6 `STATSX` and the `optimes stats` CLI.
//! * [`log!`] — leveled stderr diagnostics (`OPTIMES_LOG=
//!   error|warn|info|debug`, default `info`) replacing the ad-hoc
//!   `eprintln!` sites, so noisy paths are silenceable and greppable.
//!   User-facing report output (tables, figures) stays on `println!`.
//!
//! # Determinism contract
//!
//! Nothing in this module reads or seeds an RNG, reorders work, or feeds
//! a value back into training. Disabled, a span is one relaxed atomic
//! load; enabled, it is a clock read plus a ring-buffer append under a
//! mutex. Either way the observed computation is untouched.

pub mod metrics;
pub mod trace;

pub use metrics::{parse_exposition, registry, Counter, Gauge, Histogram, Registry};
pub use trace::{event, flush, span, tracer, SpanGuard, SpanRecord, Tracer};

// `#[macro_export]` hoists the macro to the crate root; re-export it
// here so call sites read `obs::log!(...)`.
pub use crate::log;

/// Severity of one [`log!`] line, ordered `Error < Warn < Info < Debug`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum LogLevel {
    Error,
    Warn,
    Info,
    Debug,
}

impl LogLevel {
    pub fn name(self) -> &'static str {
        match self {
            LogLevel::Error => "error",
            LogLevel::Warn => "warn",
            LogLevel::Info => "info",
            LogLevel::Debug => "debug",
        }
    }

    /// Parse a level name (case-insensitive, whitespace-tolerant).
    pub fn parse(s: &str) -> Option<LogLevel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(LogLevel::Error),
            "warn" | "warning" => Some(LogLevel::Warn),
            "info" => Some(LogLevel::Info),
            "debug" => Some(LogLevel::Debug),
            _ => None,
        }
    }
}

/// The process log threshold: `OPTIMES_LOG` (default `info`), read once.
/// An unparseable value falls back to the default — the logging plane
/// must never abort the program it observes.
pub fn log_level() -> LogLevel {
    static LEVEL: std::sync::OnceLock<LogLevel> = std::sync::OnceLock::new();
    *LEVEL.get_or_init(|| {
        std::env::var("OPTIMES_LOG")
            .ok()
            .and_then(|v| LogLevel::parse(&v))
            .unwrap_or(LogLevel::Info)
    })
}

/// Whether a line at `lvl` passes the process threshold.
pub fn log_enabled(lvl: LogLevel) -> bool {
    lvl <= log_level()
}

/// Leveled stderr diagnostic: `obs::log!(Warn, "shard {id} slow")`.
/// Levels are the [`LogLevel`] variants; lines below the `OPTIMES_LOG`
/// threshold cost one lazy-initialized comparison and format nothing.
#[macro_export]
macro_rules! log {
    ($lvl:ident, $($arg:tt)*) => {{
        let lvl = $crate::obs::LogLevel::$lvl;
        if $crate::obs::log_enabled(lvl) {
            eprintln!("[{}] {}", lvl.name(), format_args!($($arg)*));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_and_parse() {
        assert!(LogLevel::Error < LogLevel::Warn);
        assert!(LogLevel::Warn < LogLevel::Info);
        assert!(LogLevel::Info < LogLevel::Debug);
        assert_eq!(LogLevel::parse("WARN"), Some(LogLevel::Warn));
        assert_eq!(LogLevel::parse(" warning "), Some(LogLevel::Warn));
        assert_eq!(LogLevel::parse("debug"), Some(LogLevel::Debug));
        assert_eq!(LogLevel::parse("loud"), None);
        assert_eq!(LogLevel::Info.name(), "info");
    }

    #[test]
    fn log_macro_compiles_at_every_level() {
        // smoke: the macro expands for each variant and formats args
        crate::log!(Error, "e {}", 1);
        crate::log!(Warn, "w {}", 2);
        crate::log!(Info, "i {}", 3);
        crate::log!(Debug, "d {}", 4);
    }
}
