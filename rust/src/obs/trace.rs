//! Ring-buffered span tracing with Chrome/Perfetto `trace_event` export
//! (DESIGN.md §16.1).
//!
//! A [`Tracer`] holds a fixed-capacity ring of completed [`SpanRecord`]s
//! (bounded memory under any span flood — old spans are overwritten,
//! never the allocator stressed). Spans are recorded by RAII
//! [`SpanGuard`]s: [`span`] stamps the start clock, the guard's `Drop`
//! stamps the end and appends one record. Instant markers (churn,
//! checkpoints, ticket issues) go through [`event`].
//!
//! Disabled (the default), every instrumentation site costs exactly one
//! relaxed atomic load. `OPTIMES_TRACE=FILE` (or `run --trace FILE`)
//! enables the global tracer; [`flush`] exports the ring as a JSON array
//! of balanced `B`/`E` `trace_event`s (plus `i` instants) that
//! `chrome://tracing` and <https://ui.perfetto.dev> render as a timeline.
//! Sessions flush when they finish, so test runs under `OPTIMES_TRACE`
//! leave a valid trace behind without extra plumbing (the write is
//! temp-file + rename, so a concurrent reader never sees a torn file).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::{Json, JsonObj};

/// Default ring capacity (events). `OPTIMES_TRACE_CAP` overrides.
pub const DEFAULT_CAPACITY: usize = 1 << 16;

/// Nanoseconds since the process's tracing clock started (first use).
/// Monotonic — Perfetto timelines need ordering, not calendar time.
pub fn now_ns() -> u64 {
    static T0: OnceLock<Instant> = OnceLock::new();
    T0.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Small stable integer id of the calling thread (1-based, assigned on
/// first use; `std::thread::ThreadId` has no stable integer surface).
pub fn current_tid() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
    }
    TID.with(|c| {
        let mut v = c.get();
        if v == 0 {
            v = NEXT.fetch_add(1, Ordering::Relaxed);
            c.set(v);
        }
        v
    })
}

/// One completed span (or instant marker) in the ring.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    /// Span name (`round`, `push_ticket`, `rpc_pull`, ...).
    pub name: &'static str,
    /// Category (`session`, `trainer`, `pipeline`, `store`, `net`, ...).
    pub cat: &'static str,
    /// Start wall-ns ([`now_ns`] clock).
    pub start_ns: u64,
    /// End wall-ns; equals `start_ns` for instants.
    pub end_ns: u64,
    /// Recording thread ([`current_tid`]).
    pub tid: u64,
    /// key=value attributes (exported under `args`).
    pub args: Vec<(&'static str, String)>,
    /// Instant marker (exported as one `ph:"i"` event) vs full span
    /// (exported as a balanced `B`/`E` pair).
    pub instant: bool,
}

struct Ring {
    buf: Vec<SpanRecord>,
    /// Next overwrite position once `buf` reached capacity.
    head: usize,
    dropped: u64,
}

/// Thread-safe, fixed-capacity span sink.
pub struct Tracer {
    enabled: AtomicBool,
    capacity: usize,
    ring: Mutex<Ring>,
}

impl Tracer {
    pub fn new(capacity: usize) -> Tracer {
        Tracer {
            enabled: AtomicBool::new(false),
            capacity: capacity.max(1),
            ring: Mutex::new(Ring {
                buf: Vec::new(),
                head: 0,
                dropped: 0,
            }),
        }
    }

    /// The fast-path check every instrumentation site performs.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Events currently buffered (≤ capacity).
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events overwritten by ring wrap-around so far.
    pub fn dropped(&self) -> u64 {
        self.ring.lock().unwrap().dropped
    }

    /// Append one record, overwriting the oldest past capacity.
    pub fn record(&self, rec: SpanRecord) {
        let mut ring = self.ring.lock().unwrap();
        if ring.buf.len() < self.capacity {
            ring.buf.push(rec);
        } else {
            let head = ring.head;
            ring.buf[head] = rec;
            ring.head = (head + 1) % self.capacity;
            ring.dropped += 1;
        }
    }

    /// Snapshot of the buffered records in chronological start order
    /// (the ring is left untouched, so later flushes see later spans).
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let ring = self.ring.lock().unwrap();
        let mut out = ring.buf.clone();
        out.sort_by_key(|r| (r.start_ns, r.end_ns, r.tid));
        out
    }

    /// Export the ring as a Chrome/Perfetto `trace_event` JSON array:
    /// one balanced `B`/`E` pair per span, one `i` event per instant,
    /// `ts` in microseconds. Events are ordered so that per-thread
    /// nesting is well-formed even under timestamp ties (parent `B`
    /// before child `B`, child `E` before parent `E`).
    pub fn export_json(&self) -> String {
        // (ts_ns, rank, anti_tie, record_idx, is_begin)
        // rank: E=0 < B=1 < i=2 at equal ts; anti_tie orders same-ts
        // same-kind events by span extent (see sort key comment below).
        let records = self.snapshot();
        let mut order: Vec<(u64, u8, u64, usize, bool)> = Vec::new();
        for (i, r) in records.iter().enumerate() {
            if r.instant {
                order.push((r.start_ns, 2, 0, i, false));
            } else {
                // a zero-duration span would otherwise sort its E (rank 0)
                // before its own B (rank 1); nudge the close to +1ns —
                // invisible at µs display granularity, keeps nesting sane
                let end_ns = r.end_ns.max(r.start_ns.saturating_add(1));
                // same-ts B ties: the span that ends later is the parent
                // and must open first → sort by descending end.
                order.push((r.start_ns, 1, u64::MAX - end_ns, i, true));
                // same-ts E ties: the span that started later is the
                // child and must close first → sort by descending start.
                order.push((end_ns, 0, u64::MAX - r.start_ns, i, false));
            }
        }
        order.sort_unstable();
        let mut events = Vec::with_capacity(order.len());
        for &(ts_ns, _, _, i, is_begin) in &order {
            let r = &records[i];
            let mut obj = JsonObj::new();
            obj.set("name", r.name);
            obj.set("cat", r.cat);
            let ph = if r.instant {
                "i"
            } else if is_begin {
                "B"
            } else {
                "E"
            };
            obj.set("ph", ph);
            obj.set("ts", ts_ns as f64 / 1e3);
            obj.set("pid", 1.0);
            obj.set("tid", r.tid as f64);
            if r.instant {
                obj.set("s", "t");
            }
            // args ride only the opening (or instant) event
            if (is_begin || r.instant) && !r.args.is_empty() {
                let mut args = JsonObj::new();
                for (k, v) in &r.args {
                    args.set(*k, v.as_str());
                }
                obj.set("args", args);
            }
            events.push(Json::Obj(obj));
        }
        Json::Arr(events).to_string_compact()
    }

    /// Write the export atomically (temp file + rename). The temp name is
    /// unique per flush, not just per process: parallel test threads that
    /// share one `OPTIMES_TRACE` path flush concurrently, and two flushes
    /// writing the same temp file would garble each other's rename.
    pub fn flush_to(&self, path: &std::path::Path) -> std::io::Result<()> {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let tmp = path.with_extension(format!(
            "tmp.{}.{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, self.export_json())?;
        std::fs::rename(&tmp, path)
    }
}

/// Trace output path from `OPTIMES_TRACE` (None = tracing off).
pub fn trace_path() -> Option<std::path::PathBuf> {
    static PATH: OnceLock<Option<std::path::PathBuf>> = OnceLock::new();
    PATH.get_or_init(|| match std::env::var("OPTIMES_TRACE") {
        Ok(p) if !p.trim().is_empty() => Some(std::path::PathBuf::from(p.trim())),
        _ => None,
    })
    .clone()
}

/// The process-global tracer, enabled iff `OPTIMES_TRACE` names a file
/// (capacity from `OPTIMES_TRACE_CAP`, default [`DEFAULT_CAPACITY`]).
pub fn tracer() -> &'static Tracer {
    static T: OnceLock<Tracer> = OnceLock::new();
    T.get_or_init(|| {
        let cap = std::env::var("OPTIMES_TRACE_CAP")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(DEFAULT_CAPACITY);
        let t = Tracer::new(cap);
        if trace_path().is_some() {
            t.set_enabled(true);
        }
        t
    })
}

/// Export the global tracer to the `OPTIMES_TRACE` file (no-op when
/// tracing is off). Called by `Session::finish` and the CLI, so every
/// traced run — including test suites — leaves a valid timeline behind.
pub fn flush() {
    if let Some(path) = trace_path() {
        if tracer().enabled() {
            if let Err(e) = tracer().flush_to(&path) {
                crate::log!(Warn, "trace flush to {} failed: {e}", path.display());
            }
        }
    }
}

/// RAII span over the global tracer: records `[start, drop]` with the
/// calling thread's tid. Dead (free) when tracing is disabled.
#[must_use = "a span measures the scope it is bound to; dropping it immediately records nothing useful"]
pub struct SpanGuard {
    name: &'static str,
    cat: &'static str,
    start_ns: u64,
    args: Vec<(&'static str, String)>,
    live: bool,
}

impl SpanGuard {
    /// Attach a key=value attribute (builder style). Free when dead.
    pub fn attr(mut self, key: &'static str, value: impl std::fmt::Display) -> SpanGuard {
        self.push_attr(key, value);
        self
    }

    /// Attach an attribute to an already-bound span. Free when dead.
    pub fn push_attr(&mut self, key: &'static str, value: impl std::fmt::Display) {
        if self.live {
            self.args.push((key, value.to_string()));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.live {
            tracer().record(SpanRecord {
                name: self.name,
                cat: self.cat,
                start_ns: self.start_ns,
                end_ns: now_ns(),
                tid: current_tid(),
                args: std::mem::take(&mut self.args),
                instant: false,
            });
        }
    }
}

/// Open a span on the global tracer. One relaxed load when disabled.
pub fn span(cat: &'static str, name: &'static str) -> SpanGuard {
    let live = tracer().enabled();
    SpanGuard {
        name,
        cat,
        start_ns: if live { now_ns() } else { 0 },
        args: Vec::new(),
        live,
    }
}

/// Record an instant marker (churn applied, checkpoint written, ticket
/// issued). `attrs` are only materialized when tracing is enabled — pass
/// owned strings from a pre-checked `tracer().enabled()` branch or cheap
/// literals.
pub fn event(cat: &'static str, name: &'static str, attrs: Vec<(&'static str, String)>) {
    let t = tracer();
    if !t.enabled() {
        return;
    }
    let now = now_ns();
    t.record(SpanRecord {
        name,
        cat,
        start_ns: now,
        end_ns: now,
        tid: current_tid(),
        args: attrs,
        instant: true,
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(name: &'static str, start: u64, end: u64, tid: u64) -> SpanRecord {
        SpanRecord {
            name,
            cat: "test",
            start_ns: start,
            end_ns: end,
            tid,
            args: Vec::new(),
            instant: false,
        }
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let t = Tracer::new(8);
        for i in 0..100u64 {
            t.record(rec("s", i, i + 1, 1));
        }
        assert_eq!(t.len(), 8);
        assert_eq!(t.dropped(), 92);
        // survivors are the newest 8, chronologically ordered
        let snap = t.snapshot();
        let starts: Vec<u64> = snap.iter().map(|r| r.start_ns).collect();
        assert_eq!(starts, (92..100).collect::<Vec<u64>>());
    }

    #[test]
    fn export_balances_b_and_e_under_ties() {
        let t = Tracer::new(64);
        // parent [10, 50] and child [10, 50] on one thread: ties on both
        // ends — export must still nest (outer B, inner B, inner E,
        // outer E is impossible to distinguish; what matters is a valid
        // bracket sequence), plus a disjoint span ending exactly where
        // another begins (E before B at the shared ts).
        t.record(rec("parent", 10, 50, 1));
        t.record(rec("child", 10, 50, 1));
        t.record(rec("before", 0, 10, 1));
        t.record(rec("inner", 20, 30, 1));
        let json = t.export_json();
        let parsed = Json::parse(&json).unwrap();
        let events = parsed.as_arr().unwrap();
        let (mut b, mut e, mut depth) = (0, 0, 0i64);
        for ev in events {
            match ev.at("ph").as_str().unwrap() {
                "B" => {
                    b += 1;
                    depth += 1;
                }
                "E" => {
                    e += 1;
                    depth -= 1;
                    assert!(depth >= 0, "E without matching B: {json}");
                }
                other => panic!("unexpected phase {other}"),
            }
        }
        assert_eq!(b, 4);
        assert_eq!(e, 4);
        assert_eq!(depth, 0, "unbalanced trace: {json}");
        // ts is microseconds
        assert_eq!(events[0].at("ts").as_f64().unwrap(), 0.0);
        assert_eq!(events[0].at("name").as_str().unwrap(), "before");
    }

    #[test]
    fn instants_export_with_args() {
        let t = Tracer::new(8);
        t.record(SpanRecord {
            name: "churn",
            cat: "session",
            start_ns: 5,
            end_ns: 5,
            tid: 2,
            args: vec![("client", "3".to_string())],
            instant: true,
        });
        let parsed = Json::parse(&t.export_json()).unwrap();
        let ev = parsed.idx(0);
        assert_eq!(ev.at("ph").as_str(), Some("i"));
        assert_eq!(ev.at("args").at("client").as_str(), Some("3"));
    }

    #[test]
    fn dead_spans_record_nothing() {
        let t = Tracer::new(8);
        assert!(!t.enabled());
        // the global tracer is disabled by default in tests (no
        // OPTIMES_TRACE): guards and events must be no-ops
        {
            let mut s = span("test", "noop").attr("k", 1);
            s.push_attr("k2", 2);
        }
        event("test", "noop", Vec::new());
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn tid_is_stable_per_thread_and_distinct_across() {
        let a = current_tid();
        assert_eq!(a, current_tid());
        let b = std::thread::spawn(current_tid).join().unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn flush_to_writes_parseable_json() {
        let t = Tracer::new(8);
        t.record(rec("s", 1, 2, 1));
        let dir = std::env::temp_dir().join(format!("optimes-trace-{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("out.json");
        t.flush_to(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).unwrap().as_arr().unwrap().len() == 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
