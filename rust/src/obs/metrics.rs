//! Metrics registry: named counters, gauges, and log-bucketed
//! histograms over lock-free atomics, with a Prometheus-style text
//! exposition (DESIGN.md §16.2).
//!
//! A [`Registry`] is instantiable (the embedding daemon owns one per
//! process-visible instance; [`registry`] is the process-global default)
//! and renders every registered metric as `name value` lines that the
//! daemon's wire op=6 `STATSX` serves and `optimes stats` prints.
//! [`parse_exposition`] is the matching reader — one source of truth for
//! both directions, pinned by a round-trip test.
//!
//! [`Histogram`] buckets are logarithmic with 16 linear sub-buckets per
//! octave (HDR-style): values 0..16 get exact buckets, larger values land
//! in a bucket of width `2^(octave-4)`, so any reported quantile is off
//! by at most one bucket width (≤ 1/16 relative). Buckets are plain
//! atomic counts — mergeable across worker-local histograms
//! ([`Histogram::merge_from`]), which is what `benches/loadgen.rs` uses
//! instead of collecting raw samples under a mutex.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotone event count.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed level (queue depths, live connections).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn sub(&self, d: i64) {
        self.0.fetch_sub(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Sub-buckets per octave (16 → ≤ 1/16 relative quantile error).
const SUB: u64 = 16;
/// Values below `SUB` get exact unit buckets.
const LINEAR_MAX: u64 = SUB;
/// Bucket count: 16 exact + 60 octaves (2^4 .. 2^63) × 16 sub-buckets.
pub const HIST_BUCKETS: usize = (LINEAR_MAX + (63 - 4 + 1) * SUB) as usize;

/// Bucket index of a recorded value.
pub fn bucket_of(v: u64) -> usize {
    if v < LINEAR_MAX {
        return v as usize;
    }
    let octave = 63 - v.leading_zeros() as u64; // ≥ 4
    let sub = (v >> (octave - 4)) & (SUB - 1);
    ((octave - 4 + 1) * SUB + sub) as usize
}

/// Smallest value mapped to bucket `i`.
pub fn bucket_lo(i: usize) -> u64 {
    let i = i as u64;
    if i < LINEAR_MAX {
        return i;
    }
    let octave = i / SUB - 1 + 4;
    let sub = i % SUB;
    (SUB + sub) << (octave - 4)
}

/// Width of bucket `i` (every value in the bucket is within this of
/// [`bucket_lo`]); the quantile error bound.
pub fn bucket_width(i: usize) -> u64 {
    if (i as u64) < LINEAR_MAX {
        1
    } else {
        1u64 << (i as u64 / SUB - 1)
    }
}

/// Largest value mapped to bucket `i`.
pub fn bucket_hi(i: usize) -> u64 {
    bucket_lo(i) + (bucket_width(i) - 1)
}

/// Lock-free log-bucketed histogram of non-negative integer samples
/// (latencies are recorded as nanoseconds via [`record_secs`]).
///
/// [`record_secs`]: Histogram::record_secs
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a duration in seconds as integer nanoseconds (negative or
    /// non-finite inputs clamp to 0; overflow saturates).
    pub fn record_secs(&self, secs: f64) {
        let ns = (secs.max(0.0) * 1e9) as u64; // float→int casts saturate
        self.record(ns);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Raw bucket counts (index ↔ [`bucket_lo`]/[`bucket_hi`]).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Fold another histogram's buckets into this one (bucket-wise adds:
    /// associative and commutative, so worker-local histograms merge in
    /// any order to the same result).
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            let n = theirs.load(Ordering::Relaxed);
            if n > 0 {
                mine.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum.fetch_add(other.sum(), Ordering::Relaxed);
    }

    /// Quantile upper bound: the largest value of the bucket holding the
    /// `q`-th sample (so the true quantile is within one bucket width
    /// below the reported value). Monotone in `q`; 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return bucket_hi(i);
            }
        }
        bucket_hi(HIST_BUCKETS - 1)
    }

    /// [`quantile`](Histogram::quantile) of ns samples, in milliseconds.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        self.quantile(q) as f64 / 1e6
    }
}

/// A registered metric (shared handles: callers keep the `Arc` hot-path
/// side, the registry renders the same cells at scrape time).
#[derive(Clone)]
pub enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// Named metrics with a text exposition. Registration is get-or-create:
/// re-registering a name returns the existing handle (and panics if the
/// kind changed — that is a caller bug, like a geometry violation).
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::new())))
        {
            Metric::Counter(c) => Arc::clone(c),
            _ => panic!("metric {name:?} already registered with a different kind"),
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::new())))
        {
            Metric::Gauge(g) => Arc::clone(g),
            _ => panic!("metric {name:?} already registered with a different kind"),
        }
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new())))
        {
            Metric::Histogram(h) => Arc::clone(h),
            _ => panic!("metric {name:?} already registered with a different kind"),
        }
    }

    /// Registered names, sorted (exposition order).
    pub fn names(&self) -> Vec<String> {
        self.metrics.lock().unwrap().keys().cloned().collect()
    }

    /// Snapshot of every registered metric.
    pub fn snapshot(&self) -> Vec<(String, Metric)> {
        self.metrics
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    /// Prometheus-style text exposition: `# TYPE` comments plus one
    /// `name value` line per cell. Histograms render as summaries —
    /// `name{quantile="0.5|0.99|0.999"}`, `name_sum`, `name_count` —
    /// compact enough to scrape per round, parseable by
    /// [`parse_exposition`].
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, metric) in self.snapshot() {
            match metric {
                Metric::Counter(c) => {
                    out.push_str(&format!("# TYPE {name} counter\n{name} {}\n", c.get()));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", g.get()));
                }
                Metric::Histogram(h) => {
                    out.push_str(&format!("# TYPE {name} summary\n"));
                    for (label, q) in [("0.5", 0.5), ("0.99", 0.99), ("0.999", 0.999)] {
                        out.push_str(&format!(
                            "{name}{{quantile=\"{label}\"}} {}\n",
                            h.quantile(q)
                        ));
                    }
                    out.push_str(&format!("{name}_sum {}\n", h.sum()));
                    out.push_str(&format!("{name}_count {}\n", h.count()));
                }
            }
        }
        out
    }
}

/// Parse a [`Registry::render`] exposition back into `name → value`
/// (quantile lines keep their `{quantile="..."}` suffix as part of the
/// key). Ignores comments and blank/malformed lines — scraping must
/// never fail on a well-meaning exposition.
pub fn parse_exposition(text: &str) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some((name, value)) = line.rsplit_once(' ') {
            if let Ok(v) = value.parse::<f64>() {
                out.insert(name.to_string(), v);
            }
        }
    }
    out
}

/// The process-global registry (session-side metrics; the embedding
/// daemon keeps its own instance so co-located daemons never collide).
pub fn registry() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_geometry_is_consistent() {
        for v in (0..4096u64).chain([u64::MAX, u64::MAX - 1, 1 << 40, (1 << 40) + 12345]) {
            let i = bucket_of(v);
            assert!(i < HIST_BUCKETS, "v={v} → bucket {i}");
            assert!(
                bucket_lo(i) <= v && v <= bucket_hi(i),
                "v={v} outside bucket {i} [{}, {}]",
                bucket_lo(i),
                bucket_hi(i)
            );
        }
        // buckets tile the line: hi(i) + 1 == lo(i+1)
        for i in 0..HIST_BUCKETS - 1 {
            assert_eq!(bucket_hi(i) + 1, bucket_lo(i + 1), "gap after bucket {i}");
        }
        assert_eq!(bucket_hi(HIST_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn histogram_quantiles_bound_exact_percentiles() {
        let h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.sum(), 500_500);
        for (q, exact) in [(0.5, 500u64), (0.99, 990), (0.999, 999)] {
            let got = h.quantile(q);
            let i = bucket_of(exact);
            assert!(
                got >= exact && got <= bucket_hi(i).max(exact) + bucket_width(i),
                "q={q}: got {got}, exact {exact}"
            );
        }
        assert_eq!(h.quantile(0.0), h.quantile(1e-9));
        assert!(h.quantile(1.0) >= 1000);
    }

    #[test]
    fn histogram_merge_matches_single() {
        let (a, b, merged) = (Histogram::new(), Histogram::new(), Histogram::new());
        for v in 0..500u64 {
            a.record(v * 3);
            merged.record(v * 3);
        }
        for v in 0..500u64 {
            b.record(v * 7 + 1);
            merged.record(v * 7 + 1);
        }
        let folded = Histogram::new();
        folded.merge_from(&a);
        folded.merge_from(&b);
        assert_eq!(folded.bucket_counts(), merged.bucket_counts());
        assert_eq!(folded.count(), merged.count());
        assert_eq!(folded.sum(), merged.sum());
        for q in [0.1, 0.5, 0.9, 0.99, 0.999] {
            assert_eq!(folded.quantile(q), merged.quantile(q));
        }
    }

    #[test]
    fn record_secs_clamps_and_converts() {
        let h = Histogram::new();
        h.record_secs(1e-6); // 1000 ns
        h.record_secs(-5.0); // clamps to 0
        h.record_secs(f64::NAN); // clamps to 0
        assert_eq!(h.count(), 3);
        assert!(h.quantile(1.0) >= 1000);
        assert_eq!(h.quantile(0.1), 0);
    }

    #[test]
    fn registry_get_or_create_shares_cells() {
        let r = Registry::new();
        r.counter("a_total").inc();
        r.counter("a_total").add(2);
        assert_eq!(r.counter("a_total").get(), 3);
        r.gauge("b_level").set(-4);
        assert_eq!(r.gauge("b_level").get(), -4);
        r.histogram("c_ns").record(100);
        assert_eq!(r.histogram("c_ns").count(), 1);
        assert_eq!(r.names(), vec!["a_total", "b_level", "c_ns"]);
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn registry_rejects_kind_changes() {
        let r = Registry::new();
        r.counter("x").inc();
        let _ = r.gauge("x");
    }

    #[test]
    fn exposition_round_trips() {
        let r = Registry::new();
        r.counter("optimes_reqs_total").add(17);
        r.gauge("optimes_live").set(3);
        let h = r.histogram("optimes_lat_ns");
        for v in [10u64, 200, 3000, 40000] {
            h.record(v);
        }
        let text = r.render();
        let parsed = parse_exposition(&text);
        assert_eq!(parsed["optimes_reqs_total"], 17.0);
        assert_eq!(parsed["optimes_live"], 3.0);
        assert_eq!(parsed["optimes_lat_ns_count"], 4.0);
        assert_eq!(parsed["optimes_lat_ns_sum"], 43210.0);
        for q in ["0.5", "0.99", "0.999"] {
            let key = format!("optimes_lat_ns{{quantile=\"{q}\"}}");
            assert_eq!(parsed[&key], h.quantile(q.parse().unwrap()) as f64);
        }
        // every registered metric surfaces in the exposition
        for name in r.names() {
            assert!(
                parsed.keys().any(|k| k.starts_with(&name)),
                "{name} missing from exposition:\n{text}"
            );
        }
    }
}
