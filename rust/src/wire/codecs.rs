//! The codec backends of the wire-compression plane (DESIGN.md §11):
//! [`RawF32`] (today's format, the oracle), [`F16`]/[`Bf16`] truncation,
//! [`Int8`] per-row affine quantization, and [`TopK`] sparsification.
//!
//! Every codec is **strictly row-granular**: encoding a row depends only
//! on that row's values, never on its neighbours in the batch. That is
//! what lets a sharded deployment slice a batch across backends (each
//! with its own negotiated connection codec) without changing a single
//! decoded value — the property `tests/store_parity.rs` pins with its
//! codec parity matrix.
//!
//! All multi-byte fields are little-endian via `to_le_bytes` /
//! `from_le_bytes`, like the rest of the wire path (no unsafe
//! transmutes). The f16/bf16 converters are hand-rolled (the offline
//! registry carries no `half` crate) with round-to-nearest-even and
//! NaN/Inf preservation.

use anyhow::{ensure, Result};

use super::RowCodec;

// ---------------------------------------------------------------------------
// scalar converters
// ---------------------------------------------------------------------------

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even. Preserves sign,
/// Inf, and NaN-ness (payload truncated to the top 10 bits, forced
/// non-zero so a NaN never collapses into Inf).
pub fn f32_to_f16_bits(value: f32) -> u16 {
    let x = value.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let mut exp = ((x >> 23) & 0xFF) as i32;
    let man = x & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf / NaN
        let nan_man = (man >> 13) as u16 & 0x03FF;
        return sign | 0x7C00 | nan_man | u16::from(man != 0 && nan_man == 0);
    }
    exp -= 112; // re-bias 127 → 15
    if exp >= 0x1F {
        return sign | 0x7C00; // overflow → Inf
    }
    if exp <= 0 {
        if exp < -10 {
            return sign; // underflow → signed zero
        }
        // subnormal: add the implicit bit, shift out with RNE
        let man = man | 0x0080_0000;
        let shift = (14 - exp) as u32; // 14..=24
        let half_man = man >> shift;
        let round_bit = 1u32 << (shift - 1);
        let rem = man & ((1u32 << shift) - 1);
        let mut h = half_man as u16;
        if rem > round_bit || (rem == round_bit && (half_man & 1) == 1) {
            h += 1; // may carry into the smallest normal — still correct
        }
        return sign | h;
    }
    // normal: round the 23-bit mantissa to 10 bits, RNE; a mantissa
    // overflow carries into the exponent (and possibly to Inf), which is
    // exactly the IEEE behaviour
    let mut h = (((exp as u32) << 10) as u16) | ((man >> 13) as u16);
    let rem = man & 0x1FFF;
    if rem > 0x1000 || (rem == 0x1000 && (h & 1) == 1) {
        h = h.wrapping_add(1);
    }
    sign | h
}

/// IEEE 754 binary16 bits → f32 (exact: every f16 value is
/// f32-representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // signed zero
        } else {
            // subnormal: normalize into an f32 normal
            let b = 31 - man.leading_zeros(); // top set bit, 0..=9
            let exp_f = b + 103; // value = 1.x × 2^(b-24); b-24+127
            let man_f = (man << (23 - b)) & 0x007F_FFFF;
            sign | (exp_f << 23) | man_f
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13) // Inf / NaN
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// f32 → bfloat16 bits (top 16 bits, round-to-nearest-even; NaN kept
/// NaN by forcing a mantissa bit after truncation).
pub fn f32_to_bf16_bits(value: f32) -> u16 {
    let bits = value.to_bits();
    if value.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lower = bits & 0xFFFF;
    let mut upper = (bits >> 16) as u16;
    // RNE on the dropped 16 bits; a carry may roll into the exponent
    // (up to Inf), matching IEEE rounding
    if lower > 0x8000 || (lower == 0x8000 && (upper & 1) == 1) {
        upper = upper.wrapping_add(1);
    }
    upper
}

/// bfloat16 bits → f32 (exact).
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

// ---------------------------------------------------------------------------
// codecs
// ---------------------------------------------------------------------------

fn check_encoded_len(bytes: &[u8], n_rows: usize, per_row: usize, what: &str) -> Result<()> {
    ensure!(
        bytes.len() == n_rows * per_row,
        "{what}: encoded payload is {} bytes, {n_rows} row(s) x {per_row} B/row = {} expected",
        bytes.len(),
        n_rows * per_row
    );
    Ok(())
}

/// The identity codec: packed little-endian f32, exactly today's wire
/// format. Bit-exact (NaN payloads and signed zeros survive), and the
/// accounting oracle every other codec's ratio is measured against.
pub struct RawF32;

impl RowCodec for RawF32 {
    fn name(&self) -> String {
        "raw".into()
    }

    fn bytes_per_row(&self, hidden: usize) -> usize {
        hidden * 4
    }

    fn lossless(&self) -> bool {
        true
    }

    fn is_identity(&self) -> bool {
        true
    }

    fn encode_rows(&self, rows: &[f32], _hidden: usize, out: &mut Vec<u8>) {
        out.clear();
        out.reserve(rows.len() * 4);
        for v in rows {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn decode_rows(
        &self,
        bytes: &[u8],
        n_rows: usize,
        hidden: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        check_encoded_len(bytes, n_rows, self.bytes_per_row(hidden), "raw")?;
        out.clear();
        out.reserve(n_rows * hidden);
        out.extend(
            bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().expect("4-byte chunk"))),
        );
        Ok(())
    }
}

/// IEEE binary16 truncation: 2 bytes/element, ~11 bits of mantissa.
/// Lossy but *idempotent* — re-encoding a decoded payload is bit-exact,
/// so the push→store→pull double round-trip settles after one hop.
pub struct F16;

impl RowCodec for F16 {
    fn name(&self) -> String {
        "f16".into()
    }

    fn bytes_per_row(&self, hidden: usize) -> usize {
        hidden * 2
    }

    fn lossless(&self) -> bool {
        false
    }

    fn encode_rows(&self, rows: &[f32], _hidden: usize, out: &mut Vec<u8>) {
        out.clear();
        out.reserve(rows.len() * 2);
        for v in rows {
            out.extend_from_slice(&f32_to_f16_bits(*v).to_le_bytes());
        }
    }

    fn decode_rows(
        &self,
        bytes: &[u8],
        n_rows: usize,
        hidden: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        check_encoded_len(bytes, n_rows, self.bytes_per_row(hidden), "f16")?;
        out.clear();
        out.reserve(n_rows * hidden);
        out.extend(
            bytes
                .chunks_exact(2)
                .map(|b| f16_bits_to_f32(u16::from_le_bytes(b.try_into().expect("2-byte chunk")))),
        );
        Ok(())
    }
}

/// bfloat16 truncation: 2 bytes/element, f32 exponent range with 8 bits
/// of mantissa. Idempotent like [`F16`].
pub struct Bf16;

impl RowCodec for Bf16 {
    fn name(&self) -> String {
        "bf16".into()
    }

    fn bytes_per_row(&self, hidden: usize) -> usize {
        hidden * 2
    }

    fn lossless(&self) -> bool {
        false
    }

    fn encode_rows(&self, rows: &[f32], _hidden: usize, out: &mut Vec<u8>) {
        out.clear();
        out.reserve(rows.len() * 2);
        for v in rows {
            out.extend_from_slice(&f32_to_bf16_bits(*v).to_le_bytes());
        }
    }

    fn decode_rows(
        &self,
        bytes: &[u8],
        n_rows: usize,
        hidden: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        check_encoded_len(bytes, n_rows, self.bytes_per_row(hidden), "bf16")?;
        out.clear();
        out.reserve(n_rows * hidden);
        out.extend(
            bytes
                .chunks_exact(2)
                .map(|b| bf16_bits_to_f32(u16::from_le_bytes(b.try_into().expect("2-byte chunk")))),
        );
        Ok(())
    }
}

/// Per-row affine int8 quantization: each row carries an 8-byte header
/// (`zero_point: f32` = the row minimum, `scale: f32` = span / 255)
/// followed by one u8 per element. The worst-case reconstruction error
/// is `scale / 2 = (max − min) / 510` per element — the bound
/// `coordinator_props.rs` pins. Rows are expected to be finite
/// (embedding rows always are); non-finite inputs saturate through the
/// `as` cast rather than invoking UB.
pub struct Int8;

impl RowCodec for Int8 {
    fn name(&self) -> String {
        "int8".into()
    }

    fn bytes_per_row(&self, hidden: usize) -> usize {
        8 + hidden
    }

    fn lossless(&self) -> bool {
        false
    }

    fn encode_rows(&self, rows: &[f32], hidden: usize, out: &mut Vec<u8>) {
        assert!(hidden > 0 && rows.len() % hidden == 0, "int8: ragged row batch");
        out.clear();
        out.reserve(rows.len() / hidden * self.bytes_per_row(hidden));
        for row in rows.chunks_exact(hidden) {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for &v in row {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let span = hi - lo;
            let scale = if span > 0.0 && span.is_finite() {
                span / 255.0
            } else {
                0.0
            };
            out.extend_from_slice(&lo.to_le_bytes());
            out.extend_from_slice(&scale.to_le_bytes());
            if scale == 0.0 {
                let start = out.len();
                out.resize(start + hidden, 0);
            } else {
                for &v in row {
                    // saturating float→int cast: NaN → 0, out-of-range clamps
                    out.push(((v - lo) / scale + 0.5) as u8);
                }
            }
        }
    }

    fn decode_rows(
        &self,
        bytes: &[u8],
        n_rows: usize,
        hidden: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        check_encoded_len(bytes, n_rows, self.bytes_per_row(hidden), "int8")?;
        out.clear();
        out.reserve(n_rows * hidden);
        for enc in bytes.chunks_exact(self.bytes_per_row(hidden)) {
            let lo = f32::from_le_bytes(enc[0..4].try_into().expect("4-byte header"));
            let scale = f32::from_le_bytes(enc[4..8].try_into().expect("4-byte header"));
            for &q in &enc[8..] {
                out.push(lo + q as f32 * scale);
            }
        }
        Ok(())
    }
}

/// Top-K magnitude sparsification: each row keeps its K
/// largest-magnitude elements as `(index: u16, value: f32)` pairs
/// (indices ascending; ties broken toward the lower index, so the
/// selection is deterministic) and the server densifies the rest to
/// zero. Fixed `6·min(K, hidden)` bytes per row — no per-row header.
pub struct TopK {
    pub k: usize,
}

impl TopK {
    fn k_eff(&self, hidden: usize) -> usize {
        self.k.min(hidden)
    }
}

impl RowCodec for TopK {
    fn name(&self) -> String {
        format!("topk:{}", self.k)
    }

    fn bytes_per_row(&self, hidden: usize) -> usize {
        6 * self.k_eff(hidden)
    }

    fn lossless(&self) -> bool {
        false
    }

    fn encode_rows(&self, rows: &[f32], hidden: usize, out: &mut Vec<u8>) {
        assert!(hidden > 0 && rows.len() % hidden == 0, "topk: ragged row batch");
        assert!(hidden <= u16::MAX as usize, "topk: hidden exceeds u16 indices");
        let k = self.k_eff(hidden);
        out.clear();
        out.reserve(rows.len() / hidden * self.bytes_per_row(hidden));
        let mut order: Vec<u16> = Vec::with_capacity(hidden);
        let mut kept: Vec<u16> = Vec::with_capacity(k);
        for row in rows.chunks_exact(hidden) {
            order.clear();
            order.extend(0..hidden as u16);
            // |v| of non-negative floats orders like its bit pattern
            // (NaN sorts above Inf), so the key is total and the sort
            // deterministic: magnitude descending, index ascending
            order.sort_unstable_by(|&a, &b| {
                let ka = row[a as usize].abs().to_bits();
                let kb = row[b as usize].abs().to_bits();
                kb.cmp(&ka).then(a.cmp(&b))
            });
            kept.clear();
            kept.extend_from_slice(&order[..k]);
            kept.sort_unstable();
            for &idx in &kept {
                out.extend_from_slice(&idx.to_le_bytes());
                out.extend_from_slice(&row[idx as usize].to_le_bytes());
            }
        }
    }

    fn decode_rows(
        &self,
        bytes: &[u8],
        n_rows: usize,
        hidden: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        check_encoded_len(bytes, n_rows, self.bytes_per_row(hidden), "topk")?;
        out.clear();
        out.resize(n_rows * hidden, 0.0);
        let per_row = self.bytes_per_row(hidden);
        for (r, enc) in bytes.chunks_exact(per_row).enumerate() {
            for pair in enc.chunks_exact(6) {
                let idx = u16::from_le_bytes(pair[0..2].try_into().expect("2-byte index")) as usize;
                ensure!(idx < hidden, "topk: index {idx} out of range (hidden {hidden})");
                let val = f32::from_le_bytes(pair[2..6].try_into().expect("4-byte value"));
                out[r * hidden + idx] = val;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(codec: &dyn RowCodec, rows: &[f32], hidden: usize) -> Vec<f32> {
        let mut bytes = Vec::new();
        codec.encode_rows(rows, hidden, &mut bytes);
        assert_eq!(bytes.len(), rows.len() / hidden * codec.bytes_per_row(hidden));
        let mut out = Vec::new();
        codec.decode_rows(&bytes, rows.len() / hidden, hidden, &mut out).unwrap();
        assert_eq!(out.len(), rows.len());
        out
    }

    #[test]
    fn raw_is_bit_exact_including_specials() {
        let rows = vec![
            1.5f32,
            -0.0,
            f32::NEG_INFINITY,
            f32::from_bits(0x7FC0_1234), // NaN with payload
            3.25,
            -7.0,
            0.125,
            1e-30,
        ];
        let back = roundtrip(&RawF32, &rows, 4);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&rows), bits(&back));
    }

    #[test]
    fn f16_known_values_and_idempotence() {
        // exactly representable values survive bit-for-bit
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 0.25] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v)).to_bits(), v.to_bits(), "{v}");
        }
        // canonical bit patterns
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF);
        assert_eq!(f32_to_f16_bits(1e6), 0x7C00); // overflow → Inf
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7C00);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // smallest half subnormal and underflow-to-zero
        assert_eq!(f16_bits_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f32_to_f16_bits(2.0f32.powi(-26)), 0x0000);
        // idempotence: a second trip is bit-exact
        for v in [1.0e-3f32, 3.14159, -123.456, 2.0e-5, 7.5e4, -9.9e-8] {
            let once = f16_bits_to_f32(f32_to_f16_bits(v));
            let twice = f16_bits_to_f32(f32_to_f16_bits(once));
            assert_eq!(once.to_bits(), twice.to_bits(), "{v}");
        }
    }

    #[test]
    fn f16_rounds_to_nearest_even() {
        // 1 + 2^-11 sits exactly between 1.0 and the next f16 (1 + 2^-10):
        // RNE picks the even mantissa (1.0)
        assert_eq!(f32_to_f16_bits(1.0 + 2.0f32.powi(-11)), 0x3C00);
        // just above the midpoint rounds up
        assert_eq!(f32_to_f16_bits(1.0 + 2.0f32.powi(-11) + 2.0f32.powi(-20)), 0x3C01);
        // 1 + 3·2^-11 is midway between 0x3C01 and 0x3C02: even is 0x3C02
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 2.0f32.powi(-11)), 0x3C02);
    }

    #[test]
    fn bf16_known_values_and_idempotence() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 2.0, 1.0e30, -1.0e-30] {
            assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(v)).to_bits(), v.to_bits(), "{v}");
        }
        assert_eq!(f32_to_bf16_bits(1.0), 0x3F80);
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        assert_eq!(f32_to_bf16_bits(f32::INFINITY), 0x7F80);
        for v in [3.14159f32, -0.007, 12345.678, 1.0e-20] {
            let once = bf16_bits_to_f32(f32_to_bf16_bits(v));
            let twice = bf16_bits_to_f32(f32_to_bf16_bits(once));
            assert_eq!(once.to_bits(), twice.to_bits(), "{v}");
        }
    }

    #[test]
    fn int8_error_stays_within_the_stated_bound() {
        let hidden = 16;
        let rows: Vec<f32> = (0..4 * hidden)
            .map(|i| ((i * 37 % 101) as f32 - 50.0) * 0.173)
            .collect();
        let back = roundtrip(&Int8, &rows, hidden);
        for (row, dec) in rows.chunks_exact(hidden).zip(back.chunks_exact(hidden)) {
            let lo = row.iter().copied().fold(f32::INFINITY, f32::min);
            let hi = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let bound = (hi - lo) / 510.0 * 1.001 + 1e-7;
            for (a, b) in row.iter().zip(dec) {
                assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
            }
        }
    }

    #[test]
    fn int8_constant_row_is_exact() {
        let rows = vec![4.25f32; 8];
        let back = roundtrip(&Int8, &rows, 8);
        assert_eq!(back, rows);
        // and the extremes of a varying row are exact too (q=0 and q=255)
        let rows = vec![-3.0f32, 0.1, 0.2, 5.0];
        let back = roundtrip(&Int8, &rows, 4);
        assert_eq!(back[0], -3.0);
        assert!((back[3] - 5.0).abs() < 1e-5);
    }

    #[test]
    fn topk_keeps_the_largest_magnitudes_exactly() {
        let hidden = 8;
        let rows = vec![0.1f32, -9.0, 0.2, 3.0, -0.05, 7.5, 0.0, -2.0];
        let codec = TopK { k: 3 };
        let back = roundtrip(&codec, &rows, hidden);
        // kept: |−9| (idx 1), |7.5| (idx 5), |3| (idx 3); rest zero
        assert_eq!(back, vec![0.0, -9.0, 0.0, 3.0, 0.0, 7.5, 0.0, 0.0]);
        // ties break toward the lower index, deterministically
        let rows = vec![1.0f32, -1.0, 1.0, 0.5];
        let codec = TopK { k: 2 };
        let back = roundtrip(&codec, &rows, 4);
        assert_eq!(back, vec![1.0, -1.0, 0.0, 0.0]);
    }

    #[test]
    fn topk_clamps_k_to_hidden() {
        let codec = TopK { k: 100 };
        assert_eq!(codec.bytes_per_row(4), 24);
        let rows = vec![1.0f32, 2.0, 3.0, 4.0];
        let back = roundtrip(&codec, &rows, 4);
        assert_eq!(back, rows);
    }

    #[test]
    fn decode_rejects_wrong_payload_sizes() {
        let mut out = Vec::new();
        assert!(RawF32.decode_rows(&[0u8; 7], 1, 2, &mut out).is_err());
        assert!(F16.decode_rows(&[0u8; 3], 1, 2, &mut out).is_err());
        assert!(Int8.decode_rows(&[0u8; 9], 1, 2, &mut out).is_err());
        assert!(TopK { k: 1 }.decode_rows(&[0u8; 5], 1, 2, &mut out).is_err());
        // topk with an out-of-range index is data corruption, not a panic
        let codec = TopK { k: 1 };
        let mut bytes = Vec::new();
        codec.encode_rows(&[1.0, 2.0], 2, &mut bytes);
        bytes[0] = 0xFF;
        bytes[1] = 0xFF;
        assert!(codec.decode_rows(&bytes, 1, 2, &mut out).is_err());
    }

    #[test]
    fn bytes_per_row_matches_encode_output() {
        let hidden = 32;
        let rows: Vec<f32> = (0..3 * hidden).map(|i| i as f32 * 0.37 - 11.0).collect();
        let codecs: Vec<Box<dyn RowCodec>> = vec![
            Box::new(RawF32),
            Box::new(F16),
            Box::new(Bf16),
            Box::new(Int8),
            Box::new(TopK { k: 7 }),
        ];
        for c in &codecs {
            let mut bytes = Vec::new();
            c.encode_rows(&rows, hidden, &mut bytes);
            assert_eq!(bytes.len(), 3 * c.bytes_per_row(hidden), "{}", c.name());
        }
        // the compression ratios the acceptance criteria lean on
        assert_eq!(RawF32.bytes_per_row(hidden), 128);
        assert_eq!(Int8.bytes_per_row(hidden), 40); // 3.2x
        assert_eq!(TopK { k: 7 }.bytes_per_row(hidden), 42); // 3.05x
        assert_eq!(F16.bytes_per_row(hidden), 64); // 2x
    }
}
