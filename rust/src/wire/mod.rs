//! The embedding wire-compression plane (DESIGN.md §11).
//!
//! OptimES's headline lever is shrinking the bytes that move
//! boundary-vertex embeddings through the server — yet until this
//! subsystem every row crossed the wire as raw little-endian f32, so
//! the axis the paper cares most about was neither reduced nor
//! measured. This module makes it both:
//!
//! * [`RowCodec`] — encode/decode a batch of embedding rows with exact
//!   per-row size accounting. Backends in [`codecs`]: [`RawF32`] (the
//!   oracle), [`F16`]/[`Bf16`] truncation, [`Int8`] per-row affine
//!   quantization, [`TopK`] sparsification. All strictly row-granular,
//!   so sharding a batch never changes decoded values.
//! * [`DeltaStore`] ([`delta`]) — push only rows changed since the last
//!   acknowledged push, versioned against the router's epoch.
//! * [`CodecStore`] — the metering decorator for model-time backends:
//!   values round-trip through the codec exactly as they would over a
//!   real wire, `StoreStats::bytes_tx`/`bytes_rx` meter the encoded
//!   payload, and the netsim virtual time is charged from those
//!   *metered* bytes instead of assuming 4-byte floats.
//! * [`CodecSpec`] — the `--wire-codec` grammar
//!   (`raw|f16|bf16|int8|topk:K[,delta[:EPS]]`, env
//!   `OPTIMES_WIRE_CODEC`) plus the wrap helpers the harness and tests
//!   share.
//!
//! The TCP transport negotiates the codec per connection with a wire
//! handshake op instead of using [`CodecStore`] — see
//! `coordinator/net_transport.rs`; both paths produce identical decoded
//! values (`tests/store_parity.rs` pins the matrix).

pub mod codecs;
pub mod delta;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::metrics::{RpcKind, RpcRecord};
use crate::coordinator::netsim::NetConfig;
use crate::coordinator::store::{EmbeddingStore, StoreStats};

pub use codecs::{Bf16, F16, Int8, RawF32, TopK};
pub use delta::DeltaStore;

/// Encode/decode one batch of embedding rows, with exact size
/// accounting.
///
/// # Contract
///
/// * `encode_rows` consumes row-major `[n, hidden]` floats and fills
///   `out` (cleared first) with exactly `n * bytes_per_row(hidden)`
///   bytes; `decode_rows` inverts it into `n * hidden` floats. Both
///   sides compute the payload length from the row count, so encoded
///   streams need no extra framing.
/// * Encoding is **row-granular**: a row's bytes depend only on that
///   row. Slicing a batch across shards and re-merging decoded rows is
///   therefore value-identical to encoding the whole batch.
/// * Lossy codecs must be *idempotent*: re-encoding a decoded payload
///   is bit-exact, so the push→store→pull double round-trip settles
///   after one hop and every backend (in-process decorator, TCP
///   handshake, sharded compound) serves the same bits.
pub trait RowCodec: Send + Sync {
    /// Grammar name (`raw`, `f16`, `bf16`, `int8`, `topk:K`) — what the
    /// wire handshake sends and reports display.
    fn name(&self) -> String;

    /// Exact encoded bytes per row of width `hidden`.
    fn bytes_per_row(&self, hidden: usize) -> usize;

    /// Does decode(encode(x)) reproduce x bit-for-bit for every input?
    fn lossless(&self) -> bool;

    /// Is this the identity (raw) codec? Identity paths skip the
    /// encode/decode round-trip entirely.
    fn is_identity(&self) -> bool {
        false
    }

    /// Encode `rows` (row-major `[n, hidden]`) into `out` (cleared).
    fn encode_rows(&self, rows: &[f32], hidden: usize, out: &mut Vec<u8>);

    /// Decode exactly `n_rows * hidden` floats from `bytes` into `out`
    /// (cleared). Fails on malformed payloads, never panics on wire
    /// data.
    fn decode_rows(
        &self,
        bytes: &[u8],
        n_rows: usize,
        hidden: usize,
        out: &mut Vec<f32>,
    ) -> Result<()>;
}

/// The codec half of a [`CodecSpec`]: which [`RowCodec`] to run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CodecKind {
    Raw,
    F16,
    Bf16,
    Int8,
    TopK(usize),
}

impl CodecKind {
    /// Parse one codec term of the `--wire-codec` grammar:
    ///
    /// ```text
    /// codec := 'raw' | 'f16' | 'bf16' | 'int8' | 'topk:' K
    /// ```
    pub fn parse(s: &str) -> Result<CodecKind> {
        let s = s.trim();
        match s {
            "raw" => Ok(CodecKind::Raw),
            "f16" => Ok(CodecKind::F16),
            "bf16" => Ok(CodecKind::Bf16),
            "int8" => Ok(CodecKind::Int8),
            _ => {
                if let Some(k) = s.strip_prefix("topk:") {
                    let k: usize = k.trim().parse().map_err(|_| {
                        anyhow::anyhow!("topk:K expects a positive integer, got {k:?}")
                    })?;
                    ensure!(k > 0, "topk:K expects a positive integer, got 0");
                    return Ok(CodecKind::TopK(k));
                }
                bail!("unknown wire codec {s:?} (grammar: raw | f16 | bf16 | int8 | topk:K)")
            }
        }
    }

    /// Instantiate the codec.
    pub fn build(&self) -> Arc<dyn RowCodec> {
        match self {
            CodecKind::Raw => Arc::new(RawF32),
            CodecKind::F16 => Arc::new(F16),
            CodecKind::Bf16 => Arc::new(Bf16),
            CodecKind::Int8 => Arc::new(Int8),
            CodecKind::TopK(k) => Arc::new(TopK { k: *k }),
        }
    }

    pub fn is_raw(&self) -> bool {
        matches!(self, CodecKind::Raw)
    }

    /// Grammar name (matches the built codec's `RowCodec::name`).
    pub fn name(&self) -> String {
        match self {
            CodecKind::Raw => "raw".into(),
            CodecKind::F16 => "f16".into(),
            CodecKind::Bf16 => "bf16".into(),
            CodecKind::Int8 => "int8".into(),
            CodecKind::TopK(k) => format!("topk:{k}"),
        }
    }
}

/// A parsed `--wire-codec` / `OPTIMES_WIRE_CODEC` value: the codec plus
/// the optional delta combinator.
///
/// Grammar:
///
/// ```text
/// spec  := codec [',' delta]
/// codec := 'raw' | 'f16' | 'bf16' | 'int8' | 'topk:' K
/// delta := 'delta' [':' EPS]          (EPS >= 0; default 0 = exact)
/// ```
///
/// Examples: `raw`, `int8`, `topk:8`, `raw,delta`, `int8,delta:0.001`.
#[derive(Clone, Debug, PartialEq)]
pub struct CodecSpec {
    pub codec: CodecKind,
    /// `Some(eps)` enables the delta combinator (`eps = 0` → exact
    /// change detection).
    pub delta: Option<f32>,
}

impl Default for CodecSpec {
    fn default() -> Self {
        Self {
            codec: CodecKind::Raw,
            delta: None,
        }
    }
}

impl CodecSpec {
    pub fn parse(s: &str) -> Result<CodecSpec> {
        let s = s.trim();
        ensure!(!s.is_empty(), "empty wire-codec spec (grammar: CODEC[,delta[:EPS]])");
        let (codec_part, delta_part) = match s.split_once(',') {
            Some((c, d)) => (c, Some(d.trim())),
            None => (s, None),
        };
        let codec = CodecKind::parse(codec_part)?;
        let delta = match delta_part {
            None => None,
            Some("delta") => Some(0.0),
            Some(d) => {
                let eps = d.strip_prefix("delta:").with_context(|| {
                    format!("wire-codec combinator {d:?} (grammar: CODEC[,delta[:EPS]])")
                })?;
                let eps: f32 = eps
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("delta epsilon {eps:?} is not a number"))?;
                ensure!(
                    eps >= 0.0 && eps.is_finite(),
                    "delta epsilon {eps} must be finite and >= 0"
                );
                Some(eps)
            }
        };
        Ok(CodecSpec { codec, delta })
    }

    /// Is this the default plane (raw, no delta — i.e. no wrapping at
    /// all)?
    pub fn is_plain(&self) -> bool {
        self.codec.is_raw() && self.delta.is_none()
    }

    /// Canonical spec string (parses back to `self`).
    pub fn spec_string(&self) -> String {
        let mut s = self.codec.name();
        match self.delta {
            Some(eps) if eps > 0.0 => s.push_str(&format!(",delta:{eps}")),
            Some(_) => s.push_str(",delta"),
            None => {}
        }
        s
    }

    /// Wrap a model-time store (in-process slab / sharded compound) in
    /// the codec + delta layers this spec asks for. Raw-no-delta specs
    /// hand the store back untouched. `net` prices the metered bytes.
    pub fn wrap_store(
        &self,
        store: Arc<dyn EmbeddingStore>,
        net: NetConfig,
    ) -> Arc<dyn EmbeddingStore> {
        let mut store = store;
        if !self.codec.is_raw() {
            store = Arc::new(CodecStore::new(store, self.codec.build(), net));
        }
        self.wrap_delta(store)
    }

    /// Apply only the delta combinator (for transports that already
    /// carry the codec on the wire, i.e. TCP backends).
    pub fn wrap_delta(&self, store: Arc<dyn EmbeddingStore>) -> Arc<dyn EmbeddingStore> {
        match self.delta {
            Some(eps) => Arc::new(DeltaStore::new(store, eps)),
            None => store,
        }
    }

    /// The `describe()` string `wrap_store` would produce over a store
    /// described as `inner` — shared with `harness::store_desc` so
    /// `optimes info` and session reports never drift apart.
    pub fn wrapped_desc(&self, inner: String) -> String {
        let mut d = inner;
        if !self.codec.is_raw() {
            d = format!("wire({} over {d})", self.codec.name());
        }
        if let Some(eps) = self.delta {
            let eps = if eps > 0.0 {
                format!("eps {eps}")
            } else {
                "exact".into()
            };
            d = format!("delta({eps} over {d})");
        }
        d
    }
}

impl std::fmt::Display for CodecSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.spec_string())
    }
}

/// Parse `OPTIMES_WIRE_CODEC` (default: plain raw).
pub fn spec_from_env() -> Result<CodecSpec> {
    match std::env::var("OPTIMES_WIRE_CODEC") {
        Ok(s) if !s.trim().is_empty() => CodecSpec::parse(&s).context("OPTIMES_WIRE_CODEC"),
        _ => Ok(CodecSpec::default()),
    }
}

/// Wrap `store` per the environment spec (panics on a malformed env —
/// the CLI validates it up front; tests use the default otherwise).
pub fn wrap_from_env(store: Arc<dyn EmbeddingStore>, net: NetConfig) -> Arc<dyn EmbeddingStore> {
    let spec = spec_from_env().expect("OPTIMES_WIRE_CODEC");
    spec.wrap_store(store, net)
}

/// The codec boundary for model-time backends: values round-trip
/// through the codec exactly as they would over a real wire (pushes are
/// encoded→decoded before reaching the inner store, pulls on the way
/// back out), the encoded payload is metered into
/// [`StoreStats::bytes_tx`]/[`bytes_rx`], and each RPC's virtual time
/// is recharged from the *metered* bytes via
/// [`NetConfig::emb_bytes_metered`] — so the netsim cost model responds
/// to the codec choice instead of assuming 4-byte floats.
///
/// The TCP transport does not need this decorator (it encodes on the
/// socket and meters what it actually wrote); compose it over the
/// in-process slab or a sharded compound, with [`DeltaStore`] outside
/// if the spec asks for delta pushes.
///
/// [`bytes_rx`]: StoreStats::bytes_rx
pub struct CodecStore {
    inner: Arc<dyn EmbeddingStore>,
    codec: Arc<dyn RowCodec>,
    net: NetConfig,
    bytes_tx: AtomicUsize,
    bytes_rx: AtomicUsize,
    raw_tx: AtomicUsize,
    raw_rx: AtomicUsize,
}

/// Reusable per-thread codec scratch (encode buffer + decoded-layer
/// arena), so steady-state RPCs through [`CodecStore`] allocate nothing
/// — mirroring the per-connection `enc_buf` of the TCP path.
fn with_codec_scratch<R>(f: impl FnOnce(&mut Vec<u8>, &mut Vec<Vec<f32>>) -> R) -> R {
    thread_local! {
        static SCRATCH: std::cell::RefCell<(Vec<u8>, Vec<Vec<f32>>)> =
            std::cell::RefCell::new((Vec::new(), Vec::new()));
    }
    SCRATCH.with(|cell| {
        let mut s = cell.borrow_mut();
        let (bytes, arena) = &mut *s;
        f(bytes, arena)
    })
}

impl CodecStore {
    pub fn new(inner: Arc<dyn EmbeddingStore>, codec: Arc<dyn RowCodec>, net: NetConfig) -> Self {
        Self {
            inner,
            codec,
            net,
            bytes_tx: AtomicUsize::new(0),
            bytes_rx: AtomicUsize::new(0),
            raw_tx: AtomicUsize::new(0),
            raw_rx: AtomicUsize::new(0),
        }
    }

    /// Encoded payload bytes pushed / pulled so far.
    pub fn wire_bytes(&self) -> (usize, usize) {
        (
            self.bytes_tx.load(Ordering::Relaxed),
            self.bytes_rx.load(Ordering::Relaxed),
        )
    }

    /// Re-price an inner RPC record under the codec and meter it.
    ///
    /// The inner plane already modeled this RPC at raw width — for a
    /// sharded compound that is `time = max` over concurrent sub-RPCs
    /// and `bytes = sum` over every *physical* copy (replicas included).
    /// Both structures must survive the codec, so instead of recomputing
    /// time from the byte total (which would charge a sharded transfer
    /// as if one link carried everything), the bytes-dependent excess of
    /// the inner time is *scaled* by the compression ratio: a
    /// row-granular codec shrinks every sub-payload by the same factor,
    /// so `latency + (t − latency) · ratio` reproduces the max-over-
    /// shards model exactly (modulo the µs measured-service term). The
    /// meters are scaled to physical copies via `rec.bytes / raw frame`
    /// (≈ R+1 for replicated pushes, 1 otherwise), so codec runs and
    /// raw runs count replication amplification identically.
    fn recharge(&self, rec: &mut RpcRecord, rows: usize, layers: usize, codec_wall: f64) {
        if rows == 0 {
            return; // empty RPCs keep the inner record verbatim
        }
        let h = self.inner.hidden();
        let payload = rows * layers * self.codec.bytes_per_row(h);
        let raw_payload = rows * layers * h * 4;
        let raw_frame = self.net.emb_bytes(rows, layers, h);
        let copies = if raw_frame > 0 && rec.bytes > 0 {
            rec.bytes as f64 / raw_frame as f64
        } else {
            1.0
        };
        let phys = |x: usize| (x as f64 * copies).round() as usize;
        let metered = phys(self.net.emb_bytes_metered(payload, rows, layers));
        let ratio = if rec.bytes > 0 {
            metered as f64 / rec.bytes as f64
        } else {
            1.0
        };
        let tx = matches!(rec.kind, RpcKind::Push);
        let (enc_gauge, raw_gauge) = if tx {
            (&self.bytes_tx, &self.raw_tx)
        } else {
            (&self.bytes_rx, &self.raw_rx)
        };
        enc_gauge.fetch_add(phys(payload), Ordering::Relaxed);
        raw_gauge.fetch_add(phys(raw_payload), Ordering::Relaxed);
        rec.time = self.net.latency + (rec.time - self.net.latency).max(0.0) * ratio + codec_wall;
        rec.bytes = metered;
    }
}

impl EmbeddingStore for CodecStore {
    fn n_layers(&self) -> usize {
        self.inner.n_layers()
    }

    fn hidden(&self) -> usize {
        self.inner.hidden()
    }

    fn push(&self, nodes: &[u32], per_layer: &[Vec<f32>]) -> Result<RpcRecord> {
        let h = self.inner.hidden();
        let (n, layers) = (nodes.len(), per_layer.len());
        let (mut rec, codec_wall) = if self.codec.is_identity() || n == 0 {
            (self.inner.push(nodes, per_layer)?, 0.0)
        } else {
            // the wire round-trip: the server stores what the client's
            // encoded payload decodes to, exactly like the TCP path
            // (scratch reused per thread — zero-alloc steady state)
            with_codec_scratch(|bytes, arena| -> Result<(RpcRecord, f64)> {
                let t0 = Instant::now();
                arena.truncate(layers);
                while arena.len() < layers {
                    arena.push(Vec::new());
                }
                for (rows, out) in per_layer.iter().zip(arena.iter_mut()) {
                    self.codec.encode_rows(rows, h, bytes);
                    self.codec.decode_rows(bytes, n, h, out)?;
                }
                let wall = t0.elapsed().as_secs_f64();
                Ok((self.inner.push(nodes, &arena[..layers])?, wall))
            })?
        };
        self.recharge(&mut rec, n, layers, codec_wall);
        Ok(rec)
    }

    fn pull_into(
        &self,
        nodes: &[u32],
        on_demand: bool,
        out: &mut Vec<Vec<f32>>,
    ) -> Result<RpcRecord> {
        let h = self.inner.hidden();
        let mut rec = self.inner.pull_into(nodes, on_demand, out)?;
        let (n, layers) = (nodes.len(), out.len());
        let mut codec_wall = 0.0;
        if !self.codec.is_identity() && n > 0 {
            with_codec_scratch(|bytes, _| -> Result<()> {
                let t0 = Instant::now();
                for rows in out.iter_mut() {
                    self.codec.encode_rows(rows, h, bytes);
                    self.codec.decode_rows(bytes, n, h, rows)?;
                }
                codec_wall = t0.elapsed().as_secs_f64();
                Ok(())
            })?;
        }
        self.recharge(&mut rec, n, layers, codec_wall);
        Ok(rec)
    }

    fn stats(&self) -> Result<StoreStats> {
        // this decorator *is* the wire boundary: its meters replace
        // whatever the inner store accounted for hops that don't exist
        let mut st = self.inner.stats()?;
        st.bytes_tx = self.bytes_tx.load(Ordering::Relaxed);
        st.bytes_rx = self.bytes_rx.load(Ordering::Relaxed);
        st.raw_tx = self.raw_tx.load(Ordering::Relaxed);
        st.raw_rx = self.raw_rx.load(Ordering::Relaxed);
        Ok(st)
    }

    fn epoch(&self) -> u64 {
        self.inner.epoch()
    }

    fn codec(&self) -> String {
        self.codec.name()
    }

    fn describe(&self) -> String {
        format!("wire({} over {})", self.codec.name(), self.inner.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::embedding_server::EmbeddingServer;

    fn server(h: usize) -> Arc<dyn EmbeddingStore> {
        Arc::new(EmbeddingServer::new(2, h, NetConfig::default()))
    }

    #[test]
    fn spec_grammar_parses_and_roundtrips() {
        for (s, codec, delta) in [
            ("raw", CodecKind::Raw, None),
            ("f16", CodecKind::F16, None),
            ("bf16", CodecKind::Bf16, None),
            ("int8", CodecKind::Int8, None),
            ("topk:8", CodecKind::TopK(8), None),
            ("raw,delta", CodecKind::Raw, Some(0.0)),
            ("int8,delta:0.001", CodecKind::Int8, Some(0.001)),
            (" topk:4 , delta ", CodecKind::TopK(4), Some(0.0)),
        ] {
            let spec = CodecSpec::parse(s).unwrap_or_else(|e| panic!("{s}: {e:#}"));
            assert_eq!(spec.codec, codec, "{s}");
            assert_eq!(spec.delta, delta, "{s}");
            // canonical form re-parses to the same spec
            assert_eq!(CodecSpec::parse(&spec.spec_string()).unwrap(), spec, "{s}");
        }
        assert!(CodecSpec::parse("raw").unwrap().is_plain());
        assert!(!CodecSpec::parse("raw,delta").unwrap().is_plain());
        assert!(!CodecSpec::parse("f16").unwrap().is_plain());
    }

    #[test]
    fn spec_grammar_rejects_malformed_input() {
        for bad in [
            "",
            "gzip",
            "topk",
            "topk:0",
            "topk:x",
            "int8,delta:-1",
            "int8,delta:fast",
            "int8,zeta",
            "raw,delta:inf",
        ] {
            assert!(CodecSpec::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn plain_spec_wraps_nothing() {
        let spec = CodecSpec::default();
        let store = spec.wrap_store(server(4), NetConfig::default());
        assert_eq!(store.describe(), "in-process");
        assert_eq!(store.codec(), "raw");
        assert_eq!(spec.wrapped_desc("in-process".into()), "in-process");
    }

    #[test]
    fn wrapped_desc_matches_wrap_store() {
        for s in ["int8", "raw,delta", "topk:4,delta:0.5", "f16,delta"] {
            let spec = CodecSpec::parse(s).unwrap();
            let store = spec.wrap_store(server(4), NetConfig::default());
            assert_eq!(store.describe(), spec.wrapped_desc("in-process".into()), "{s}");
        }
    }

    #[test]
    fn codec_store_meters_and_recharges_virtual_time() {
        let net = NetConfig::default();
        let spec = CodecSpec::parse("int8").unwrap();
        let store = spec.wrap_store(server(8), net);
        let nodes: Vec<u32> = (0..100).collect();
        let rows: Vec<f32> = (0..nodes.len() * 8).map(|i| i as f32 * 0.03).collect();
        let rec = store.push(&nodes, &[rows.clone(), rows.clone()]).unwrap();
        // int8 at hidden 8: 16 B/row vs 32 raw
        let payload = 100 * 2 * 16;
        assert_eq!(rec.bytes, net.emb_bytes_metered(payload, 100, 2));
        let raw_rec_bytes = net.emb_bytes(100, 2, 8);
        assert!(rec.bytes < raw_rec_bytes, "{} !< {raw_rec_bytes}", rec.bytes);

        let (got, pull_rec) = store.pull(&nodes, false).unwrap();
        assert_eq!(pull_rec.bytes, net.emb_bytes_metered(payload, 100, 2));
        // values went through the quantizer: close, not exact
        for (a, b) in rows.iter().zip(&got[0]) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
        let st = store.stats().unwrap();
        assert_eq!((st.bytes_tx, st.bytes_rx), (payload, payload));
        assert_eq!((st.raw_tx, st.raw_rx), (100 * 2 * 32, 100 * 2 * 32));
        assert!(st.compression_ratio() > 1.9, "{}", st.compression_ratio());
        assert_eq!(st.nodes, 100);
    }

    #[test]
    fn identity_codec_store_is_value_transparent() {
        let spec = CodecSpec {
            codec: CodecKind::Raw,
            delta: None,
        };
        // force the decorator on despite is_plain, via explicit build
        let store = CodecStore::new(server(4), spec.codec.build(), NetConfig::default());
        let nodes = [1u32, 2];
        let rows = vec![1.5f32, -0.0, f32::INFINITY, 3.25, 0.0, 1.0, 2.0, 4.5];
        store.push(&nodes, &[rows.clone(), rows.clone()]).unwrap();
        let (got, _) = store.pull(&nodes, false).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&rows), bits(&got[0]));
        let st = store.stats().unwrap();
        assert_eq!(st.bytes_tx, 2 * 2 * 16);
        assert_eq!(st.raw_tx, st.bytes_tx);
    }

    #[test]
    fn sharding_under_the_codec_matches_a_single_backend() {
        use crate::coordinator::store::ShardedStore;
        // row-granular codecs: slicing the batch across shards must not
        // change a single decoded value
        let spec = CodecSpec::parse("int8").unwrap();
        let single = spec.wrap_store(server(8), NetConfig::default());
        let sharded = spec.wrap_store(
            Arc::new(ShardedStore::in_process(4, 2, 8, NetConfig::default())),
            NetConfig::default(),
        );
        let nodes: Vec<u32> = (0..137).collect();
        let rows: Vec<f32> = (0..nodes.len() * 8).map(|i| (i as f32).sin() * 9.0).collect();
        single.push(&nodes, &[rows.clone(), rows.clone()]).unwrap();
        sharded.push(&nodes, &[rows.clone(), rows.clone()]).unwrap();
        let (a, _) = single.pull(&nodes, false).unwrap();
        let (b, _) = sharded.pull(&nodes, false).unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a[0]), bits(&b[0]));
        assert_eq!(bits(&a[1]), bits(&b[1]));
    }
}
