//! The [`DeltaStore`] combinator: push only the rows that changed since
//! the client's last *acknowledged* push (DESIGN.md §11).
//!
//! A federated client re-pushes its whole boundary set every round, but
//! many rows barely move between rounds (and between the pre-training
//! push and round 1 some do not move at all). `DeltaStore` keeps the
//! last acknowledged copy of every pushed row plus a per-node **version
//! vector**, compares each incoming push against it, and forwards only
//! the rows whose change exceeds the ε threshold (ε = 0 compares bit
//! patterns, so skipping is value-exact and `raw` vs `raw+delta` runs
//! are bit-identical — the acceptance criterion).
//!
//! # Versions vs routing epochs
//!
//! The delta cache is only valid while the rows it skipped are still
//! *resident* wherever the inner store routes reads. Two server-side
//! mechanisms cover that:
//!
//! * Skipped rows were acknowledged by an earlier push, so a replicated
//!   [`ShardedStore`](crate::coordinator::ShardedStore) holds them on
//!   every owner; its quarantine/failover machinery serves them through
//!   faults exactly as it serves re-pushed rows (the blackout parity
//!   test in `tests/fault_tolerance.rs`).
//! * A [`rebalance`](crate::coordinator::ShardedStore::rebalance)
//!   migrates rows by *logical occupancy* — everything ever pushed,
//!   including delta-skipped rows — so routing changes preserve them.
//!   Still, the delta layer treats a routing-epoch bump as a barrier:
//!   when the *server-reported* epoch moves (`stats().epoch`, which
//!   travels over TCP where the local [`EmbeddingStore::epoch`]
//!   accessor cannot), the cache is dropped and the next push resyncs
//!   in full. That keeps delta correct even for out-of-protocol rejoins
//!   (a shard re-admitted with lost state) at the cost of one full push
//!   per rebalance.
//!
//! The per-node version counter is bumped on every accepted changed-row
//! push; [`DeltaStore::version_of`] exposes it so tests (and a future
//! anti-entropy repair) can compare client and server generations.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::coordinator::metrics::{RpcKind, RpcRecord};
use crate::coordinator::store::{EmbeddingStore, StoreStats};

struct DeltaState {
    /// node → last acknowledged rows, concatenated per layer
    /// (`layers * hidden` floats).
    last: HashMap<u32, Vec<f32>>,
    /// node → push version (bumped on every accepted changed push).
    versions: HashMap<u32, u64>,
    /// Routing epoch the cache is valid under.
    epoch: u64,
}

/// Delta-push decorator over any [`EmbeddingStore`] (see module docs).
///
/// Pulls pass straight through. Pushes are filtered to changed rows;
/// the returned [`RpcRecord`] keeps the *logical* row count (what the
/// caller asked to push) while `bytes`/`time` reflect only what
/// actually moved — so `embeddings_pushed` accounting stays comparable
/// across codecs while the wire meters show the savings.
///
/// The state lock is held across the inner push so an acknowledgement
/// and its cache update are atomic; parallel clients push disjoint node
/// sets, so the serialization this adds is bounded by the store call
/// itself.
pub struct DeltaStore {
    inner: Arc<dyn EmbeddingStore>,
    eps: f32,
    state: Mutex<DeltaState>,
    rows_skipped: AtomicUsize,
    /// Raw-f32 bytes the skipped rows would have cost — added to
    /// `StoreStats::raw_tx` so compression ratios credit the delta.
    skipped_raw: AtomicUsize,
}

impl DeltaStore {
    /// Wrap `inner`; `eps = 0` skips only bit-identical rows, `eps > 0`
    /// also skips rows whose every element moved by at most ε (lossy:
    /// the store then serves the previous value).
    pub fn new(inner: Arc<dyn EmbeddingStore>, eps: f32) -> Self {
        assert!(eps >= 0.0 && eps.is_finite(), "delta epsilon must be finite and >= 0");
        let epoch = Self::routing_epoch_of(inner.as_ref());
        Self {
            inner,
            eps,
            state: Mutex::new(DeltaState {
                last: HashMap::new(),
                versions: HashMap::new(),
                epoch,
            }),
            rows_skipped: AtomicUsize::new(0),
            skipped_raw: AtomicUsize::new(0),
        }
    }

    /// The inner plane's routing epoch as the *server* reports it.
    /// `EmbeddingStore::epoch()` is a cheap local accessor — a TCP
    /// client always answers 0 because the remote epoch only travels in
    /// `stats()` — so the barrier consults `stats().epoch` (one small
    /// control-plane RPC per push; pushes happen once per round per
    /// client). A store whose control plane is currently unreachable
    /// reports the larger of the two sources, falling back to the local
    /// accessor rather than failing the push.
    fn routing_epoch_of(inner: &dyn EmbeddingStore) -> u64 {
        let local = inner.epoch();
        match inner.stats() {
            Ok(st) => st.epoch.max(local),
            Err(_) => local,
        }
    }

    pub fn eps(&self) -> f32 {
        self.eps
    }

    /// Rows elided from pushes so far.
    pub fn rows_skipped(&self) -> usize {
        self.rows_skipped.load(Ordering::Relaxed)
    }

    /// Push version of `node` (0 if never pushed through this store).
    pub fn version_of(&self, node: u32) -> u64 {
        self.state.lock().unwrap().versions.get(&node).copied().unwrap_or(0)
    }

    /// Routing epoch the delta cache is currently valid under.
    pub fn cache_epoch(&self) -> u64 {
        self.state.lock().unwrap().epoch
    }

    /// Has `node` an acknowledged row cached (i.e. eligible to be
    /// skipped)?
    pub fn is_cached(&self, node: u32) -> bool {
        self.state.lock().unwrap().last.contains_key(&node)
    }

    /// Does the cached copy differ from the candidate rows of batch
    /// position `i` beyond ε?
    fn changed(&self, old: &[f32], per_layer: &[Vec<f32>], i: usize, h: usize) -> bool {
        for (l, rows) in per_layer.iter().enumerate() {
            let new = &rows[i * h..(i + 1) * h];
            let prev = &old[l * h..(l + 1) * h];
            for (a, b) in new.iter().zip(prev) {
                let moved = if self.eps == 0.0 {
                    a.to_bits() != b.to_bits()
                } else {
                    (a - b).abs() > self.eps
                };
                if moved {
                    return true;
                }
            }
        }
        false
    }
}

impl EmbeddingStore for DeltaStore {
    fn n_layers(&self) -> usize {
        self.inner.n_layers()
    }

    fn hidden(&self) -> usize {
        self.inner.hidden()
    }

    fn push(&self, nodes: &[u32], per_layer: &[Vec<f32>]) -> Result<RpcRecord> {
        if nodes.is_empty() {
            return self.inner.push(nodes, per_layer);
        }
        let h = self.inner.hidden();
        let layers = per_layer.len();
        // routing-generation barrier: a rebalance under our feet drops
        // the cache, so the next push resyncs in full (module docs).
        // The epoch comes from the server side (`stats().epoch`) so the
        // barrier also fires across a TCP transport, whose local
        // `epoch()` accessor is always 0.
        let epoch = Self::routing_epoch_of(self.inner.as_ref());
        let mut state = self.state.lock().unwrap();
        if epoch != state.epoch {
            state.last.clear();
            state.epoch = epoch;
        }
        let changed: Vec<usize> = (0..nodes.len())
            .filter(|&i| match state.last.get(&nodes[i]) {
                None => true,
                Some(old) => self.changed(old, per_layer, i, h),
            })
            .collect();
        let skipped = nodes.len() - changed.len();
        let mut rec = if changed.len() == nodes.len() {
            self.inner.push(nodes, per_layer)?
        } else if changed.is_empty() {
            // nothing moved: the store already holds every row
            RpcRecord {
                kind: RpcKind::Push,
                rows: 0,
                bytes: 0,
                time: 0.0,
            }
        } else {
            let sub_nodes: Vec<u32> = changed.iter().map(|&i| nodes[i]).collect();
            let sub_layers: Vec<Vec<f32>> = per_layer
                .iter()
                .map(|rows| {
                    let mut v = Vec::with_capacity(changed.len() * h);
                    for &i in &changed {
                        v.extend_from_slice(&rows[i * h..(i + 1) * h]);
                    }
                    v
                })
                .collect();
            self.inner.push(&sub_nodes, &sub_layers)?
        };
        // acknowledged: record the pushed rows and bump their versions
        for &i in &changed {
            let node = nodes[i];
            let entry = state.last.entry(node).or_default();
            entry.clear();
            entry.reserve(layers * h);
            for rows in per_layer {
                entry.extend_from_slice(&rows[i * h..(i + 1) * h]);
            }
            *state.versions.entry(node).or_insert(0) += 1;
        }
        drop(state);
        if skipped > 0 {
            self.rows_skipped.fetch_add(skipped, Ordering::Relaxed);
            self.skipped_raw.fetch_add(skipped * layers * h * 4, Ordering::Relaxed);
        }
        // logical accounting: the caller pushed the whole batch
        rec.rows = nodes.len();
        Ok(rec)
    }

    fn pull_into(
        &self,
        nodes: &[u32],
        on_demand: bool,
        out: &mut Vec<Vec<f32>>,
    ) -> Result<RpcRecord> {
        self.inner.pull_into(nodes, on_demand, out)
    }

    fn stats(&self) -> Result<StoreStats> {
        let mut st = self.inner.stats()?;
        // credit the elided rows to the raw baseline so ratios reflect
        // what a delta-less run would have moved
        st.raw_tx += self.skipped_raw.load(Ordering::Relaxed);
        Ok(st)
    }

    fn epoch(&self) -> u64 {
        self.inner.epoch()
    }

    fn codec(&self) -> String {
        if self.eps > 0.0 {
            format!("{}+delta:{}", self.inner.codec(), self.eps)
        } else {
            format!("{}+delta", self.inner.codec())
        }
    }

    fn describe(&self) -> String {
        let eps = if self.eps > 0.0 {
            format!("eps {}", self.eps)
        } else {
            "exact".into()
        };
        format!("delta({eps} over {})", self.inner.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::embedding_server::EmbeddingServer;
    use crate::coordinator::netsim::NetConfig;
    use crate::coordinator::store::ShardedStore;

    fn server(h: usize) -> Arc<EmbeddingServer> {
        Arc::new(EmbeddingServer::new(2, h, NetConfig::default()))
    }

    fn rows(nodes: &[u32], h: usize, salt: f32) -> Vec<f32> {
        nodes
            .iter()
            .flat_map(|&n| (0..h).map(move |j| n as f32 * 2.0 + j as f32 + salt))
            .collect()
    }

    #[test]
    fn identical_repush_is_skipped_entirely() {
        let h = 4;
        let inner = server(h);
        let delta = DeltaStore::new(Arc::clone(&inner) as Arc<dyn EmbeddingStore>, 0.0);
        let nodes = [3u32, 7, 11];
        let l1 = rows(&nodes, h, 0.0);
        let l2 = rows(&nodes, h, 9.0);
        let rec = delta.push(&nodes, &[l1.clone(), l2.clone()]).unwrap();
        assert_eq!(rec.rows, 3);
        assert!(rec.bytes > 0);
        assert_eq!(delta.rows_skipped(), 0);

        // bit-identical re-push: nothing crosses, logical rows intact
        let rec = delta.push(&nodes, &[l1.clone(), l2.clone()]).unwrap();
        assert_eq!(rec.rows, 3);
        assert_eq!(rec.bytes, 0);
        assert_eq!(rec.time, 0.0);
        assert_eq!(delta.rows_skipped(), 3);
        let (_, pushes) = inner.rpc_counts();
        assert_eq!(pushes, 1, "skipped push still reached the server");
        // values unchanged and versions bumped once
        let (got, _) = delta.pull(&nodes, false).unwrap();
        assert_eq!(got[0], l1);
        assert_eq!(delta.version_of(3), 1);
        assert_eq!(delta.version_of(999), 0);
    }

    #[test]
    fn partial_change_pushes_only_the_changed_rows() {
        let h = 4;
        let inner = server(h);
        let delta = DeltaStore::new(Arc::clone(&inner) as Arc<dyn EmbeddingStore>, 0.0);
        let nodes = [1u32, 2, 3, 4];
        let l1 = rows(&nodes, h, 0.0);
        let l2 = rows(&nodes, h, 1.0);
        delta.push(&nodes, &[l1.clone(), l2.clone()]).unwrap();

        // mutate only node 3 (batch position 2) in layer 2
        let mut l2b = l2.clone();
        l2b[2 * h] += 5.0;
        delta.push(&nodes, &[l1.clone(), l2b.clone()]).unwrap();
        assert_eq!(delta.rows_skipped(), 3);
        assert_eq!(delta.version_of(3), 2);
        assert_eq!(delta.version_of(1), 1);
        // the store holds the new value for 3, old values elsewhere
        let (got, _) = delta.pull(&nodes, false).unwrap();
        assert_eq!(got[0], l1);
        assert_eq!(got[1], l2b);
    }

    #[test]
    fn eps_threshold_suppresses_small_changes() {
        let h = 2;
        let inner = server(h);
        let delta = DeltaStore::new(Arc::clone(&inner) as Arc<dyn EmbeddingStore>, 0.1);
        let nodes = [5u32];
        delta.push(&nodes, &[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        // moves of <= eps are absorbed (store keeps the old row)...
        delta.push(&nodes, &[vec![1.05, 2.0], vec![3.0, 4.0]]).unwrap();
        assert_eq!(delta.rows_skipped(), 1);
        let (got, _) = delta.pull(&nodes, false).unwrap();
        assert_eq!(got[0], vec![1.0, 2.0]);
        // ...a move beyond eps goes through
        delta.push(&nodes, &[vec![1.5, 2.0], vec![3.0, 4.0]]).unwrap();
        let (got, _) = delta.pull(&nodes, false).unwrap();
        assert_eq!(got[0], vec![1.5, 2.0]);
    }

    #[test]
    fn epoch_bump_forces_a_full_resync() {
        let h = 4;
        let sharded = Arc::new(
            ShardedStore::in_process_replicated(3, 1, 2, h, NetConfig::default()).unwrap(),
        );
        let delta = DeltaStore::new(Arc::clone(&sharded) as Arc<dyn EmbeddingStore>, 0.0);
        let nodes: Vec<u32> = (0..40).collect();
        let l = rows(&nodes, h, 0.0);
        delta.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        delta.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        assert_eq!(delta.rows_skipped(), 40);
        assert_eq!(delta.cache_epoch(), 0);

        // a rebalance bumps the routing epoch: the next push resyncs
        sharded.rebalance(sharded.map()).unwrap();
        let before = delta.rows_skipped();
        delta.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        assert_eq!(delta.rows_skipped(), before, "post-rebalance push must not skip");
        assert_eq!(delta.cache_epoch(), 1);
        // and the cache is warm again afterwards
        delta.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        assert_eq!(delta.rows_skipped(), before + 40);
    }

    #[test]
    fn epoch_barrier_fires_across_tcp() {
        use crate::coordinator::net_transport::{EmbServerDaemon, TcpEmbeddingStore};
        let h = 4;
        let sharded = Arc::new(
            ShardedStore::in_process_replicated(3, 1, 2, h, NetConfig::default()).unwrap(),
        );
        let daemon = EmbServerDaemon::start(
            Arc::clone(&sharded) as Arc<dyn EmbeddingStore>,
            "127.0.0.1:0",
        )
        .unwrap();
        let tcp: Arc<dyn EmbeddingStore> =
            Arc::new(TcpEmbeddingStore::connect(daemon.addr.to_string(), 2, h).unwrap());
        let delta = DeltaStore::new(tcp, 0.0);
        let nodes: Vec<u32> = (0..20).collect();
        let l = rows(&nodes, h, 0.0);
        delta.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        delta.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        assert_eq!(delta.rows_skipped(), 20);
        // a rebalance BEHIND the daemon bumps the remote routing epoch;
        // the barrier must fire even though the TCP client's local
        // `epoch()` accessor stays 0 (the epoch travels in stats)
        sharded.rebalance(sharded.map()).unwrap();
        let before = delta.rows_skipped();
        delta.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        assert_eq!(delta.rows_skipped(), before, "post-rebalance push must not skip");
        assert_eq!(delta.cache_epoch(), 1);
        daemon.shutdown();
    }

    #[test]
    fn skipped_rows_credit_the_raw_baseline() {
        let h = 4;
        let delta = DeltaStore::new(server(h) as Arc<dyn EmbeddingStore>, 0.0);
        let nodes = [1u32, 2];
        let l = rows(&nodes, h, 0.0);
        delta.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        let tx_after_first = delta.stats().unwrap();
        delta.push(&nodes, &[l.clone(), l.clone()]).unwrap();
        let st = delta.stats().unwrap();
        // encoded tx did not move (nothing crossed), but the raw
        // baseline grew by the skipped rows' f32 cost
        assert_eq!(st.bytes_tx, tx_after_first.bytes_tx);
        assert_eq!(st.raw_tx, tx_after_first.raw_tx + 2 * 2 * h * 4);
    }

    #[test]
    fn describe_and_codec_name_the_combinator() {
        let exact = DeltaStore::new(server(4) as Arc<dyn EmbeddingStore>, 0.0);
        assert_eq!(exact.codec(), "raw+delta");
        assert!(exact.describe().starts_with("delta(exact over "));
        let eps = DeltaStore::new(server(4) as Arc<dyn EmbeddingStore>, 0.5);
        assert_eq!(eps.codec(), "raw+delta:0.5");
        assert!(eps.describe().contains("eps 0.5"));
    }
}
