//! `optimes` — the L3 coordinator CLI (leader entrypoint).
//!
//! ```text
//! optimes info                         # datasets, artifacts, engine
//! optimes run   --dataset reddit-s --strategy OPP [--rounds 16]
//!               [--model gc|sage] [--clients N] [--fanout 5|10|15]
//!               [--epochs 3] [--lr 0.01] [--engine ref|pjrt]
//!               [--scale N] [--seed S] [--report out.json]
//! optimes sweep --dataset reddit-s --strategies D,E,OP,OPP,OPG
//! optimes fig   <table1|2a|2b|6|7|8|9|10|11|12|13|14|all>
//! optimes smoke                        # PJRT round-trip health check
//! ```

use std::sync::Arc;

use anyhow::{bail, Result};

use optimes::coordinator::metrics::paper_target_accuracy;
use optimes::coordinator::{SessionConfig, SessionMetrics, Strategy};
use optimes::graph::datasets;
use optimes::harness::{self, figures};
use optimes::runtime::{Manifest, ModelKind};
use optimes::util::cli::Args;

fn main() {
    let args = Args::parse_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match dispatch(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    // --engine / --scale / --rounds flags map onto the harness env knobs
    if let Some(e) = args.get("engine") {
        std::env::set_var("OPTIMES_ENGINE", e);
    }
    if let Some(s) = args.get("scale") {
        std::env::set_var("OPTIMES_SCALE", s);
    }
    if let Some(r) = args.get("rounds") {
        std::env::set_var("OPTIMES_ROUNDS", r);
    }
    match cmd {
        "info" => info(),
        "run" => run(args),
        "sweep" => sweep(args),
        "fig" => {
            let id = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            figures::run_figure(id)
        }
        "smoke" => smoke(),
        "emb-server" => emb_server(args),
        "help" | _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
optimes — federated GNN training with remote embeddings (OptimES reproduction)

commands:
  info                       show datasets, artifacts, engine
  run    --dataset D --strategy S [--model gc|sage] [--clients N]
         [--rounds R] [--epochs E] [--lr LR] [--fanout K]
         [--engine ref|pjrt] [--scale N] [--seed S] [--report FILE]
  sweep  --dataset D --strategies D,E,O,P,OP,OPP,OPG
  fig    table1|2a|2b|6|7|8|9|10|11|12|13|14|all
  smoke  PJRT artifact health check
  emb-server --listen ADDR [--layers 2] [--hidden 32]
         run the embedding server as a standalone TCP daemon
";

fn info() -> Result<()> {
    println!("engine: {}", harness::engine_kind());
    println!("dataset scale: 1/{}", harness::dataset_scale());
    match Manifest::load(harness::artifacts_dir()) {
        Ok(m) => {
            println!("artifacts: {} entrypoints", m.entrypoints.len());
            for e in &m.entrypoints {
                println!(
                    "  {} (B={}, K={}, {} inputs)",
                    e.name,
                    e.geom.batch,
                    e.geom.fanout,
                    e.inputs.len()
                );
            }
        }
        Err(e) => println!("artifacts: not built ({e})"),
    }
    println!("datasets:");
    for p in datasets::presets() {
        println!(
            "  {:11} ~{} paper={} clients={} batches/epoch={}",
            p.name, p.gen.n, p.paper_name, p.default_clients, p.epoch_batches
        );
    }
    Ok(())
}

fn parse_model(args: &Args) -> Result<ModelKind> {
    match args.str_or("model", "gc") {
        "gc" => Ok(ModelKind::Gc),
        "sage" => Ok(ModelKind::Sage),
        other => bail!("unknown model {other:?}"),
    }
}

fn session_summary(m: &SessionMetrics) {
    println!(
        "\n[{} / {}] peak accuracy {:.2}%  median round {:.3}s  total {:.1}s",
        m.dataset,
        m.strategy,
        m.peak_accuracy() * 100.0,
        m.median_round_time(),
        m.total_time()
    );
    let p = m.median_phases();
    println!(
        "  phases: pull {:.3}s | train {:.3}s | dyn-pull {:.3}s | push {:.3}s (hidden {:.3}s)",
        p.pull, p.train, p.dyn_pull, p.push, p.push_hidden
    );
    println!(
        "  remotes: {} candidates -> {} retained; {} embeddings at server",
        m.pull_candidates, m.retained_remotes, m.server_embeddings
    );
    let accs: Vec<String> = m
        .smoothed_accuracies()
        .iter()
        .map(|a| format!("{:.1}", a * 100.0))
        .collect();
    println!("  smoothed accuracy: {}", accs.join(" "));
}

fn run(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "reddit-s").to_string();
    let strategy = Strategy::parse(args.str_or("strategy", "OPP"))
        .ok_or_else(|| anyhow::anyhow!("bad --strategy"))?;
    let model = parse_model(args)?;
    let fanout = args.usize_or("fanout", 5);
    let (p, g) = harness::load_dataset(&dataset)?;
    let clients = args.usize_or("clients", p.default_clients);
    let engine = harness::make_engine(model, fanout)?;
    let cfg = SessionConfig {
        dataset: dataset.clone(),
        clients,
        strategy,
        rounds: args.usize_or("rounds", 16),
        epochs: args.usize_or("epochs", 3),
        lr: args.f64_or("lr", 0.01) as f32,
        epoch_batches: args.usize_or("epoch-batches", p.epoch_batches),
        eval_batches: args.usize_or("eval-batches", 16),
        seed: args.u64_or("seed", 42),
        parallel_clients: !args.flag("sequential"),
        ..Default::default()
    };
    println!(
        "running {dataset} / {} on {} engine, {} clients, {} rounds ...",
        cfg.strategy.name,
        harness::engine_kind(),
        clients,
        cfg.rounds
    );
    let m = optimes::coordinator::run_session(&g, &cfg, Arc::clone(&engine))?;
    session_summary(&m);
    if let Some(path) = args.get("report") {
        std::fs::write(path, optimes::harness::report::session_to_json(&m).to_string_pretty())?;
        println!("report written to {path}");
    }
    Ok(())
}

fn sweep(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "reddit-s").to_string();
    let names = args
        .list("strategies")
        .unwrap_or_else(|| vec!["D", "E", "O", "P", "OP", "OPP", "OPG"].iter().map(|s| s.to_string()).collect());
    let strategies: Vec<Strategy> = names
        .iter()
        .map(|n| Strategy::parse(n).ok_or_else(|| anyhow::anyhow!("bad strategy {n:?}")))
        .collect::<Result<_>>()?;
    let model = parse_model(args)?;
    let sessions = figures::ladder_sessions(&dataset, model, args.usize_or("fanout", 5), &strategies, args.get("clients").map(|c| c.parse().unwrap()))?;
    let refs: Vec<&SessionMetrics> = sessions.iter().collect();
    let target = paper_target_accuracy(&refs);
    for m in &sessions {
        println!(
            "{:8} peak={:.2}% TTA={} round={:.3}s",
            m.strategy,
            m.peak_accuracy() * 100.0,
            harness::fmt_opt_time(m.time_to_accuracy(target)),
            m.median_round_time()
        );
    }
    Ok(())
}

fn smoke() -> Result<()> {
    let manifest = Manifest::load(harness::artifacts_dir())?;
    manifest.validate()?;
    let v = optimes::runtime::pjrt::run_smoke(&manifest)?;
    println!("smoke artifact: {v:?} (expect [5, 5, 9, 9])");
    anyhow::ensure!(v == vec![5.0, 5.0, 9.0, 9.0], "smoke mismatch");
    println!("OK");
    Ok(())
}

fn emb_server(args: &Args) -> Result<()> {
    use optimes::coordinator::net_transport::EmbServerDaemon;
    use optimes::coordinator::{EmbeddingServer, NetConfig};
    let listen = args.str_or("listen", "127.0.0.1:7070").to_string();
    let layers = args.usize_or("layers", 2);
    let hidden = args.usize_or("hidden", 32);
    let server = Arc::new(EmbeddingServer::new(layers, hidden, NetConfig::default()));
    let daemon = EmbServerDaemon::start(Arc::clone(&server), listen.as_str())?;
    println!(
        "embedding server listening on {} ({} layer DBs, hidden {})",
        daemon.addr, layers, hidden
    );
    println!("press ctrl-c to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(5));
        let (nodes, rows) = (server.stored_nodes(), server.stored_rows());
        let (pulls, pushes) = server.rpc_counts();
        println!("stored {nodes} nodes / {rows} rows; rpcs: {pulls} pulls {pushes} pushes");
    }
}
