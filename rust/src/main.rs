//! `optimes` — the L3 coordinator CLI (leader entrypoint).
//!
//! ```text
//! optimes info  [--graph FILE]         # datasets, artifacts, engine, store
//! optimes run   --dataset reddit-s --strategy OPP [--rounds 16]
//!               [--model gc|sage] [--clients N] [--fanout 5|10|15]
//!               [--epochs 3] [--lr 0.01] [--engine ref|pjrt]
//!               [--server host:port[,host:port...]] [--shards N]
//!               [--pipeline on|off] [--agg fedavg|uniform|trimmed[:k]]
//!               [--graph FILE] [--graph-backend ram|mmap]
//!               [--partitioner metis|hash|ldg]
//!               [--churn \"leave@4:2,join@9\"] [--checkpoint DIR [--checkpoint-every N]]
//!               [--scale N] [--seed S] [--report out.json]
//! optimes resume DIR [--rounds R]          # continue a checkpointed session
//! optimes build-graph --out FILE [--dataset D] [--n N] [--seed S]
//! optimes sweep --dataset reddit-s --strategies D,E,OP,OPP,OPG
//! optimes fig   <table1|2a|2b|6|7|8|9|10|11|12|13|14|all>
//! optimes serve --port 7070 [--layers 2] [--hidden 32] [--shards N]
//! optimes stats host:port              # scrape a daemon's metrics (op=6)
//! optimes smoke                        # PJRT round-trip health check
//! ```

use std::sync::Arc;

use anyhow::{bail, Result};

use optimes::coordinator::metrics::paper_target_accuracy;
use optimes::coordinator::{
    aggregation, ClientLatency, DaemonConfig, EmbServerDaemon, EmbeddingServer, EmbeddingStore,
    FaultSpec, NetConfig, ReplicaSelect, RoundMetrics, RoundObserver, RoundPolicySpec,
    SessionBuilder, SessionConfig, SessionMetrics, ShardedStore, Strategy,
};
use optimes::graph::datasets;
use optimes::harness::{self, figures};
use optimes::runtime::{Manifest, ModelKind};
use optimes::util::cli::Args;

fn main() {
    let args = Args::parse_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match dispatch(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            optimes::obs::log!(Error, "{e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    // --engine / --scale / --rounds / --server / --shards flags map onto
    // the harness env knobs
    if let Some(e) = args.get("engine") {
        std::env::set_var("OPTIMES_ENGINE", e);
    }
    if let Some(s) = args.get("scale") {
        std::env::set_var("OPTIMES_SCALE", s);
    }
    if let Some(r) = args.get("rounds") {
        std::env::set_var("OPTIMES_ROUNDS", r);
    }
    if let Some(s) = args.get("server") {
        std::env::set_var("OPTIMES_SERVER", s);
    }
    if let Some(s) = args.get("shards") {
        std::env::set_var("OPTIMES_SHARDS", s);
    }
    if let Some(r) = args.get("replicas") {
        std::env::set_var("OPTIMES_REPLICAS", r);
    }
    if let Some(f) = args.get("fault-spec") {
        // validate up front so a typo fails before any training work
        FaultSpec::parse(f)?;
        std::env::set_var("OPTIMES_FAULT_SPEC", f);
    }
    if let Some(c) = args.get("wire-codec") {
        // validate up front so a typo fails before any training work
        optimes::wire::CodecSpec::parse(c)?;
        std::env::set_var("OPTIMES_WIRE_CODEC", c);
    }
    if let Some(p) = args.get("pipeline") {
        match p.to_ascii_lowercase().as_str() {
            "on" | "off" | "1" | "0" | "true" | "false" | "yes" | "no" => {
                std::env::set_var("OPTIMES_PIPELINE", p)
            }
            other => bail!("--pipeline expects on|off, got {other:?}"),
        }
    }
    if let Some(p) = args.get("round-policy") {
        // validate up front so a typo fails before any training work
        RoundPolicySpec::parse(p)?;
        std::env::set_var("OPTIMES_ROUND_POLICY", p);
    }
    if let Some(s) = args.get("staleness") {
        let _: usize = s
            .parse()
            .map_err(|_| anyhow::anyhow!("--staleness expects an integer, got {s:?}"))?;
        std::env::set_var("OPTIMES_STALENESS", s);
    }
    if let Some(l) = args.get("client-latency") {
        // validate up front so a typo fails before any training work
        ClientLatency::parse(l)?;
        std::env::set_var("OPTIMES_CLIENT_LATENCY", l);
    }
    if let Some(b) = args.get("graph-backend") {
        // validate up front so a typo fails before any training work
        optimes::storage::GraphBackend::parse(b)?;
        std::env::set_var("OPTIMES_GRAPH_BACKEND", b);
    }
    if let Some(p) = args.get("partitioner") {
        // validate up front so a typo fails before any training work
        optimes::graph::PartitionerKind::parse(p)?;
        std::env::set_var("OPTIMES_PARTITIONER", p);
    }
    if let Some(c) = args.get("churn") {
        // validate up front so a typo fails before any training work
        optimes::coordinator::ChurnSpec::parse(c)?;
        std::env::set_var("OPTIMES_CHURN", c);
    }
    if let Some(t) = args.get("tenant") {
        // validate up front so a typo fails before any training work
        optimes::coordinator::validate_tenant_name(t)?;
        std::env::set_var("OPTIMES_TENANT", t);
    }
    if let Some(t) = args.get("trace") {
        anyhow::ensure!(!t.trim().is_empty(), "--trace expects a file path");
        std::env::set_var("OPTIMES_TRACE", t);
    }
    if let Some(l) = args.get("log") {
        // validate up front so a typo fails before any training work
        optimes::obs::LogLevel::parse(l)
            .ok_or_else(|| anyhow::anyhow!("--log expects error|warn|info|debug, got {l:?}"))?;
        std::env::set_var("OPTIMES_LOG", l);
    }
    if let Some(s) = args.get("replica-select") {
        // validate up front so a typo fails before any training work
        ReplicaSelect::parse(s)?;
        std::env::set_var("OPTIMES_REPLICA_SELECT", s);
    }
    if let Some(dir) = args.get("checkpoint") {
        let spec = match args.get("checkpoint-every") {
            Some(n) => {
                let _: usize = n.parse().map_err(|_| {
                    anyhow::anyhow!("--checkpoint-every expects an integer, got {n:?}")
                })?;
                format!("{dir}:{n}")
            }
            None => dir.to_string(),
        };
        std::env::set_var("OPTIMES_CHECKPOINT", spec);
    } else if args.get("checkpoint-every").is_some() && cmd != "resume" {
        bail!("--checkpoint-every needs --checkpoint DIR");
    }
    match cmd {
        "info" => info(args),
        "run" => run(args),
        "resume" => resume(args),
        "build-graph" => build_graph(args),
        "sweep" => sweep(args),
        "fig" => {
            let id = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("all");
            figures::run_figure(id)
        }
        "smoke" => smoke(),
        "serve" | "emb-server" => serve(args),
        "stats" => stats_cmd(args),
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = "\
optimes — federated GNN training with remote embeddings (OptimES reproduction)

commands:
  info                       show datasets, artifacts, engine, store backend
  run    --dataset D --strategy S [--model gc|sage] [--clients N]
         [--rounds R] [--epochs E] [--lr LR] [--fanout K]
         [--engine ref|pjrt] [--scale N] [--seed S] [--report FILE]
         [--server HOST:PORT[,HOST:PORT...]]   use remote embedding store(s)
         [--shards N]                          shard the in-process store
         [--replicas R]                        keep R replicas per row (needs shards > R)
         [--fault-spec SPEC]                   inject deterministic store faults,
                                               e.g. \"shard1=blackout@40;*=delay%10:0.005\"
         [--wire-codec C]                      embedding wire codec:
                                               raw|f16|bf16|int8|topk:K[,delta[:EPS]]
         [--pipeline on|off]                   async push/pull pipeline (default on)
         [--agg fedavg|uniform|trimmed[:k]]    aggregation rule
         [--round-policy P]                    round advancement:
                                               sync|quorum:K[:SLACK]|deadline:SECS
         [--staleness S]                       fold updates up to S rounds stale (default 2)
         [--client-latency L]                  injected per-client delay,
                                               e.g. lognormal:-0.9:1.5[:SEED]
         [--graph FILE]                        train on a prebuilt GraphFile
         [--graph-backend ram|mmap]            serve graph arrays from heap or
                                               mapped pages (default ram)
         [--partitioner metis|hash|ldg]        client split algorithm (default metis)
         [--churn SPEC]                        scripted elastic membership,
                                               e.g. \"leave@4:2,join@9\"
         [--checkpoint DIR]                    write a resumable checkpoint bundle
         [--checkpoint-every N]                checkpoint cadence in rounds (default 1)
         [--tenant NAME]                       bind this session to a namespace on a
                                               shared embedding daemon
         [--replica-select primary|fastest]    replica read policy (default fastest)
         [--trace FILE]                        export a Chrome/Perfetto span timeline
                                               of the run (OPTIMES_TRACE)
         [--log LEVEL]                         stderr diagnostics threshold:
                                               error|warn|info|debug (OPTIMES_LOG)
  resume DIR [--rounds R] [--sequential] [--pipeline on|off] [--report FILE]
         [--engine ref|pjrt] [--scale N] [--checkpoint-every N]
         continue a checkpointed session; with identical flags the resumed
         accuracy curve is bit-for-bit the uninterrupted one
  build-graph --out FILE [--dataset D] [--n N] [--seed S] [--avg-degree A]
         [--scale N]        stream a synthetic graph to an on-disk GraphFile
                            without materializing it in RAM
  sweep  --dataset D --strategies D,E,O,P,OP,OPP,OPG
  fig    table1|2a|2b|6|7|8|9|10|11|12|13|14|all
  serve  --port 7070 [--listen ADDR] [--layers 2] [--hidden 32] [--shards N]
         [--replicas R] [--fault-spec SPEC]
         [--max-conns N] [--max-inflight N]    admission caps (0 = unlimited);
                                               over-cap work gets a loud BUSY
         run the embedding store as a standalone TCP daemon (multi-tenant:
         clients pick a namespace with --tenant / OPTIMES_TENANT)
  stats  HOST:PORT           scrape a live daemon's metrics exposition (wire
                             op=6 STATSX): service gauges, per-tenant rows,
                             RPC latency histograms
  smoke  PJRT artifact health check
  info   [--graph FILE]      also inspect a GraphFile's header + sections
";

fn info(args: &Args) -> Result<()> {
    println!("engine: {}", harness::engine_kind());
    println!(
        "store backend: {} [{} shard(s), {} replica(s)]",
        harness::store_desc(),
        harness::store_shards(),
        harness::store_replicas()
    );
    if let Ok(spec) = std::env::var("OPTIMES_FAULT_SPEC") {
        if !spec.trim().is_empty() {
            println!("fault injection: {spec} (OPTIMES_FAULT_SPEC)");
        }
    }
    println!(
        "wire codec: {} (OPTIMES_WIRE_CODEC; raw|f16|bf16|int8|topk:K[,delta[:EPS]])",
        harness::wire_codec_spec()?
    );
    println!(
        "pipeline: {}",
        if optimes::coordinator::pipeline_default() {
            "on (async push/pull; OPTIMES_PIPELINE=off disables)"
        } else {
            "off (synchronous store calls)"
        }
    );
    println!(
        "round policy: {} (OPTIMES_ROUND_POLICY; sync|quorum:K[:SLACK]|deadline:SECS)",
        optimes::coordinator::round_policy_default().name()
    );
    if let Some(l) = optimes::coordinator::client_latency_default() {
        println!("client latency: {} (OPTIMES_CLIENT_LATENCY)", l.spec_string());
    }
    println!(
        "graph backend: {} (OPTIMES_GRAPH_BACKEND; ram|mmap)",
        optimes::storage::GraphBackend::from_env().name()
    );
    println!(
        "partitioner: {} (OPTIMES_PARTITIONER; metis|hash|ldg)",
        optimes::graph::PartitionerKind::from_env().name()
    );
    let churn = optimes::coordinator::ChurnSpec::from_env();
    if !churn.is_empty() {
        println!("churn schedule: {} (OPTIMES_CHURN)", churn.spec_string());
    }
    if let Some((dir, every)) = optimes::coordinator::checkpoint_from_env() {
        println!(
            "checkpointing: every {} round(s) into {} (OPTIMES_CHECKPOINT; DIR[:EVERY])",
            every,
            dir.display()
        );
    }
    println!(
        "log level: {} (OPTIMES_LOG; error|warn|info|debug)",
        optimes::obs::log_level().name()
    );
    match optimes::obs::trace::trace_path() {
        Some(p) => println!("trace: {} (OPTIMES_TRACE)", p.display()),
        None => println!("trace: off (OPTIMES_TRACE=FILE enables Perfetto export)"),
    }
    println!("dataset scale: 1/{}", harness::dataset_scale());
    if let Some(path) = args.get("graph") {
        let gi = optimes::storage::format::read_info(std::path::Path::new(path))?;
        println!(
            "graph file {path}: v{} n={} m={} feat_dim={} classes={} train={} test={} \
             ({} bytes)",
            gi.version, gi.n, gi.m, gi.feat_dim, gi.classes, gi.train_count, gi.test_count,
            gi.file_len
        );
        for (idx, sec) in gi.sections.iter().enumerate() {
            println!(
                "  {:12} off={:>14} len={:>14} fnv={:#018x}",
                optimes::storage::format::SECTION_NAMES[idx],
                sec.offset,
                sec.byte_len,
                sec.checksum
            );
        }
    }
    match Manifest::load(harness::artifacts_dir()) {
        Ok(m) => {
            println!("artifacts: {} entrypoints", m.entrypoints.len());
            for e in &m.entrypoints {
                println!(
                    "  {} (B={}, K={}, {} inputs)",
                    e.name,
                    e.geom.batch,
                    e.geom.fanout,
                    e.inputs.len()
                );
            }
        }
        Err(e) => println!("artifacts: not built ({e})"),
    }
    println!("datasets:");
    for p in datasets::presets() {
        println!(
            "  {:11} ~{} paper={} clients={} batches/epoch={}",
            p.name, p.gen.n, p.paper_name, p.default_clients, p.epoch_batches
        );
    }
    Ok(())
}

fn parse_model(args: &Args) -> Result<ModelKind> {
    match args.str_or("model", "gc") {
        "gc" => Ok(ModelKind::Gc),
        "sage" => Ok(ModelKind::Sage),
        other => bail!("unknown model {other:?}"),
    }
}

fn session_summary(m: &SessionMetrics) {
    println!(
        "\n[{} / {}] peak accuracy {:.2}%  median round {:.3}s  total {:.1}s",
        m.dataset,
        m.strategy,
        m.peak_accuracy() * 100.0,
        m.median_round_time(),
        m.total_time()
    );
    let p = m.median_phases();
    println!(
        "  phases: pull {:.3}s | train {:.3}s | dyn-pull {:.3}s | push {:.3}s (hidden {:.3}s)",
        p.pull, p.train, p.dyn_pull, p.push, p.push_hidden
    );
    println!(
        "  remotes: {} candidates -> {} retained; {} embeddings at server",
        m.pull_candidates, m.retained_remotes, m.server_embeddings
    );
    if m.total_failovers() > 0 || m.store_epoch > 0 {
        println!(
            "  resilience: {} failover/retry event(s) absorbed, routing epoch {}",
            m.total_failovers(),
            m.store_epoch
        );
    }
    let (tx, rx) = (m.total_bytes_tx(), m.total_bytes_rx());
    if tx + rx + m.bytes_raw_tx + m.bytes_raw_rx > 0 {
        println!(
            "  wire: codec {}, {} tx / {} rx on the wire (raw {}, {:.2}x compression)",
            if m.wire_codec.is_empty() {
                "raw"
            } else {
                m.wire_codec.as_str()
            },
            harness::fmt_bytes(tx),
            harness::fmt_bytes(rx),
            harness::fmt_bytes(m.bytes_raw_tx + m.bytes_raw_rx),
            m.wire_ratio()
        );
    }
    if !m.round_policy.is_empty() && m.round_policy != "sync" {
        println!(
            "  stragglers: policy {}, {} late / {} folded / {} dropped, quorum wait {:.3}s",
            m.round_policy,
            m.total_stragglers_late(),
            m.total_stale_folded(),
            m.total_stragglers_dropped(),
            m.total_quorum_wait()
        );
    }
    let ov = m.overlap_stats();
    if ov.pipelined {
        println!(
            "  pipeline: push_wall {:.3}s / stalled {:.3}s, prefetch {:.3}s / stalled {:.3}s, \
             overlap saved {:.3}s (real), queue depth <= {}",
            ov.push_wall, ov.push_wait, ov.pull_wall, ov.pull_wait, ov.overlap_saved, ov.queue_peak
        );
    }
    let accs: Vec<String> = m
        .smoothed_accuracies()
        .iter()
        .map(|a| format!("{:.1}", a * 100.0))
        .collect();
    println!("  smoothed accuracy: {}", accs.join(" "));
}

/// Streams one line per federated round as the session runs.
struct CliRoundPrinter {
    total: usize,
}

impl RoundObserver for CliRoundPrinter {
    fn on_round(&mut self, r: &RoundMetrics) {
        let p = &r.mean_phases;
        let wire = if r.bytes_tx + r.bytes_rx > 0 {
            format!(
                "  wire {}↑ {}↓",
                harness::fmt_bytes(r.bytes_tx),
                harness::fmt_bytes(r.bytes_rx)
            )
        } else {
            String::new()
        };
        let stragglers = if r.stragglers_late + r.stale_folded + r.stragglers_dropped > 0 {
            format!(
                "  late {} fold {} drop {}",
                r.stragglers_late, r.stale_folded, r.stragglers_dropped
            )
        } else {
            String::new()
        };
        println!(
            "round {:>2}/{}: acc {:5.2}%  time {:.3}s  (pull {:.3} + train {:.3} + dyn {:.3} + push {:.3}){wire}{stragglers}",
            r.round + 1,
            self.total,
            r.accuracy * 100.0,
            r.round_time,
            p.pull,
            p.train,
            p.dyn_pull,
            p.push
        );
    }
}

fn run(args: &Args) -> Result<()> {
    let strategy = Strategy::parse(args.str_or("strategy", "OPP"))?;
    let model = parse_model(args)?;
    let fanout = args.usize_or("fanout", 5);
    // --graph FILE trains on a prebuilt GraphFile (opened on the active
    // backend) instead of generating a preset dataset
    let (dataset, default_clients, default_batches, g) = match args.get("graph") {
        Some(path) => {
            let backend = optimes::storage::GraphBackend::from_env();
            let g =
                optimes::storage::GraphStore::open(std::path::Path::new(path), backend)?;
            println!(
                "loaded {path}: n={} m={} feat_dim={} classes={} ({} backend)",
                g.n,
                g.out.m(),
                g.feat_dim,
                g.classes,
                backend.name()
            );
            (path.to_string(), 4, 16, g)
        }
        None => {
            let dataset = args.str_or("dataset", "reddit-s").to_string();
            let (p, g) = harness::load_dataset(&dataset)?;
            (dataset, p.default_clients, p.epoch_batches, g)
        }
    };
    let clients = args.usize_or("clients", default_clients);
    let engine = harness::make_engine(model, fanout)?;
    let aggregator = aggregation::parse_aggregator(args.str_or("agg", "fedavg"))?;
    let cfg = SessionConfig {
        dataset: dataset.clone(),
        clients,
        strategy,
        rounds: args.usize_or("rounds", 16),
        epochs: args.usize_or("epochs", 3),
        lr: args.f64_or("lr", 0.01) as f32,
        epoch_batches: args.usize_or("epoch-batches", default_batches),
        eval_batches: args.usize_or("eval-batches", 16),
        seed: args.u64_or("seed", 42),
        parallel_clients: !args.flag("sequential"),
        ..Default::default()
    };
    let store = harness::make_store(engine.geom(), cfg.net)?;
    println!(
        "running {dataset} / {} on {} engine, {} clients, {} rounds, store {}, \
         pipeline {}, agg {}, policy {} ...",
        cfg.strategy.name,
        harness::engine_kind(),
        clients,
        cfg.rounds,
        store.describe(),
        if cfg.pipeline { "on" } else { "off" },
        aggregator.name(),
        cfg.round_policy.name()
    );
    let total = cfg.rounds;
    let m = SessionBuilder::new(cfg)
        .store(store)
        .aggregator(aggregator)
        .observer(Box::new(CliRoundPrinter { total }))
        .build(&g, Arc::clone(&engine))?
        .run()?;
    session_summary(&m);
    optimes::obs::flush();
    if let Some(path) = args.get("report") {
        std::fs::write(path, optimes::harness::report::session_to_json(&m).to_string_pretty())?;
        println!("report written to {path}");
    }
    if let Some(path) = optimes::obs::trace::trace_path() {
        println!("trace written to {} (open in ui.perfetto.dev)", path.display());
    }
    Ok(())
}

/// Continue a killed session from its checkpoint directory. The bundle
/// carries the full session identity (dataset, strategy, seed, churn
/// schedule, hyperparameters), so only the directory is required; with
/// the same engine/scale env the resumed accuracy curve is bit-for-bit
/// the curve the uninterrupted run would have produced.
fn resume(args: &Args) -> Result<()> {
    let dir = args
        .positional
        .get(1)
        .cloned()
        .or_else(|| args.get("dir").map(str::to_string))
        .ok_or_else(|| {
            anyhow::anyhow!("resume needs a checkpoint directory: optimes resume DIR [--rounds R]")
        })?;
    let dir = std::path::PathBuf::from(dir);
    let bundle = optimes::coordinator::CheckpointBundle::load(&dir)?;
    let c = bundle.config.clone();
    println!(
        "resuming {} / {} from {} ({} of {} round(s) done, seed {}, {} client(s))",
        c.dataset,
        c.strategy,
        dir.display(),
        bundle.completed_rounds,
        c.rounds,
        c.seed,
        c.clients
    );
    let model = ModelKind::parse(&c.model)?;
    // the dataset field is either a preset name or a GraphFile path,
    // mirroring `run --graph`; the bundle's graph fingerprint catches a
    // stale path or a wrong --scale loudly at build time
    let g = match datasets::preset(&c.dataset) {
        Some(_) => harness::load_dataset(&c.dataset)?.1,
        None => {
            let p = std::path::Path::new(&c.dataset);
            anyhow::ensure!(
                p.exists(),
                "checkpoint dataset {:?} is neither a preset nor a graph file",
                c.dataset
            );
            optimes::storage::GraphStore::open(p, optimes::storage::GraphBackend::from_env())?
        }
    };
    let engine = harness::make_engine(model, c.fanout)?;
    let rounds = args.usize_or("rounds", c.rounds);
    anyhow::ensure!(
        rounds > bundle.completed_rounds,
        "checkpoint already has {} completed round(s) — pass --rounds R with R > {}",
        bundle.completed_rounds,
        bundle.completed_rounds
    );
    let mut cfg = SessionConfig {
        dataset: c.dataset.clone(),
        clients: c.clients,
        strategy: Strategy::parse(&c.strategy)?,
        rounds,
        epochs: c.epochs,
        lr: c.lr,
        epoch_batches: c.epoch_batches,
        eval_batches: c.eval_batches,
        seed: c.seed,
        parallel_clients: !args.flag("sequential"),
        round_policy: RoundPolicySpec::parse(&c.policy)?,
        staleness: c.staleness,
        partitioner: optimes::graph::PartitionerKind::parse(&c.partitioner)?,
        churn: optimes::coordinator::ChurnSpec::parse(&c.churn)?,
        ..Default::default()
    };
    if args.get("pipeline").is_none() {
        // pipeline state is boundary-transparent (not resume identity),
        // but default to what the checkpointed run used
        cfg.pipeline = c.pipeline;
    }
    let aggregator = aggregation::parse_aggregator(args.str_or("agg", "fedavg"))?;
    let store = harness::make_store(engine.geom(), cfg.net)?;
    let total = cfg.rounds;
    let every = args.usize_or("checkpoint-every", 1).max(1);
    let m = SessionBuilder::new(cfg)
        .store(store)
        .aggregator(aggregator)
        .observer(Box::new(CliRoundPrinter { total }))
        .resume(&dir)
        .checkpoints(&dir, every) // keep the bundle current as we go
        .build(&g, Arc::clone(&engine))?
        .run()?;
    session_summary(&m);
    optimes::obs::flush();
    if let Some(path) = args.get("report") {
        std::fs::write(path, optimes::harness::report::session_to_json(&m).to_string_pretty())?;
        println!("report written to {path}");
    }
    Ok(())
}

/// Stream a synthetic dataset straight to an on-disk `GraphFile` — the
/// out-of-core entry point: the edge list and feature matrix never
/// exist in RAM, so this builds graphs far larger than memory.
fn build_graph(args: &Args) -> Result<()> {
    use optimes::graph::generate::{generate_to_file, GenParams};
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("build-graph needs --out FILE"))?;
    let mut gen = match args.get("dataset") {
        Some(name) => {
            datasets::preset(name)
                .ok_or_else(|| {
                    anyhow::anyhow!("unknown dataset preset {name:?} (see `optimes info`)")
                })?
                .gen
        }
        None => GenParams::default(),
    };
    let scale = args.usize_or("scale", 1).max(1);
    gen.n /= scale;
    if let Some(n) = args.get("n") {
        gen.n = n
            .parse()
            .map_err(|_| anyhow::anyhow!("--n expects an integer, got {n:?}"))?;
    }
    if let Some(d) = args.get("avg-degree") {
        gen.avg_degree = d
            .parse()
            .map_err(|_| anyhow::anyhow!("--avg-degree expects a number, got {d:?}"))?;
    }
    gen.seed = args.u64_or("seed", gen.seed);
    anyhow::ensure!(gen.n > 0, "graph would have no vertices (n/scale = 0)");
    let t0 = std::time::Instant::now();
    let gi = generate_to_file(&gen, std::path::Path::new(out))?;
    println!(
        "wrote {out}: n={} m={} feat_dim={} classes={} train={} test={} \
         ({} bytes in {:.1}s)",
        gi.n,
        gi.m,
        gi.feat_dim,
        gi.classes,
        gi.train_count,
        gi.test_count,
        gi.file_len,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn sweep(args: &Args) -> Result<()> {
    let dataset = args.str_or("dataset", "reddit-s").to_string();
    let names = args.list("strategies").unwrap_or_else(|| {
        ["D", "E", "O", "P", "OP", "OPP", "OPG"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    });
    let strategies: Vec<Strategy> = names
        .iter()
        .map(|n| Ok(Strategy::parse(n)?))
        .collect::<Result<_>>()?;
    let model = parse_model(args)?;
    let sessions = figures::ladder_sessions(
        &dataset,
        model,
        args.usize_or("fanout", 5),
        &strategies,
        args.get("clients").map(|c| c.parse().unwrap()),
    )?;
    let refs: Vec<&SessionMetrics> = sessions.iter().collect();
    let target = paper_target_accuracy(&refs);
    for m in &sessions {
        println!(
            "{:8} peak={:.2}% TTA={} round={:.3}s",
            m.strategy,
            m.peak_accuracy() * 100.0,
            harness::fmt_opt_time(m.time_to_accuracy(target)),
            m.median_round_time()
        );
    }
    Ok(())
}

fn smoke() -> Result<()> {
    let manifest = Manifest::load(harness::artifacts_dir())?;
    manifest.validate()?;
    let v = optimes::runtime::pjrt::run_smoke(&manifest)?;
    println!("smoke artifact: {v:?} (expect [5, 5, 9, 9])");
    anyhow::ensure!(v == vec![5.0, 5.0, 9.0, 9.0], "smoke mismatch");
    println!("OK");
    Ok(())
}

/// Standalone embedding-store daemon: the paper's deployment shape, where
/// every training process reaches the store over the network.
fn serve(args: &Args) -> Result<()> {
    use std::io::Write;
    let listen = match args.get("listen") {
        Some(a) => a.to_string(),
        None => format!("127.0.0.1:{}", args.usize_or("port", 7070)),
    };
    let layers = args.usize_or("layers", 2);
    let hidden = args.usize_or("hidden", 32);
    let shards = args.usize_or("shards", 1);
    let replicas = args.usize_or("replicas", 0);
    let spec = match args.get("fault-spec") {
        Some(s) => FaultSpec::parse(s)?,
        None => FaultSpec::default(),
    };
    spec.validate_shards(shards.max(1))?;
    let store: Arc<dyn EmbeddingStore> = if shards > 1 {
        let backends: Vec<Arc<dyn EmbeddingStore>> = (0..shards)
            .map(|i| {
                let slab = EmbeddingServer::new(layers, hidden, NetConfig::default());
                // a real daemon serves real clients: injected delays must
                // actually stall the socket, not just a virtual clock
                spec.wrap_shard_real(i, Arc::new(slab))
            })
            .collect();
        Arc::new(ShardedStore::replicated(backends, replicas)?)
    } else {
        anyhow::ensure!(replicas == 0, "--replicas needs --shards > 1");
        let slab = EmbeddingServer::new(layers, hidden, NetConfig::default());
        spec.wrap_shard_real(0, Arc::new(slab))
    };
    let config = DaemonConfig {
        max_conns: args.usize_or("max-conns", 0),
        max_inflight: args.usize_or("max-inflight", 0),
    };
    let daemon = EmbServerDaemon::start_with(Arc::clone(&store), listen.as_str(), config)?;
    println!(
        "embedding store listening on {} ({layers} layer DBs, hidden {hidden}, backend {})",
        daemon.addr,
        store.describe()
    );
    let cap = |n: usize| {
        if n == 0 {
            "unlimited".to_string()
        } else {
            n.to_string()
        }
    };
    println!(
        "admission control: max-conns {}, max-inflight {}",
        cap(config.max_conns),
        cap(config.max_inflight)
    );
    println!("press ctrl-c to stop");
    // explicit flush: the bound address must reach a piped parent
    // (`optimes run --server` scripts, the spawned-process test) promptly
    std::io::stdout().flush().ok();
    loop {
        std::thread::sleep(std::time::Duration::from_secs(5));
        // the periodic stats line is rendered from the same exposition
        // wire op=6 serves — one source of truth for service telemetry
        let m = optimes::obs::parse_exposition(&daemon.exposition());
        let g = |k: &str| m.get(k).copied().unwrap_or(0.0) as i64;
        println!(
            "stored {} nodes / {} rows | conns {} live / {} rejected | inflight {} | \
             tenants {} | rpc p99 pull {:.3}ms push {:.3}ms",
            g("optimes_store_nodes"),
            g("optimes_store_rows"),
            g("optimes_daemon_live_conns"),
            g("optimes_daemon_rejected_conns"),
            g("optimes_daemon_inflight"),
            g("optimes_daemon_tenants"),
            g("optimes_daemon_rpc_pull_ns{quantile=\"0.99\"}") as f64 / 1e6,
            g("optimes_daemon_rpc_push_ns{quantile=\"0.99\"}") as f64 / 1e6,
        );
    }
}

/// Scrape a live daemon's metrics exposition (wire op=6 STATSX) and
/// print it verbatim — `optimes stats host:port | grep rpc`.
fn stats_cmd(args: &Args) -> Result<()> {
    let addr = args
        .positional
        .get(1)
        .cloned()
        .or_else(|| args.get("addr").map(str::to_string))
        .ok_or_else(|| anyhow::anyhow!("stats needs an address: optimes stats HOST:PORT"))?;
    // geometry-blind connection: STATSX needs no layer/hidden agreement
    let mut c = optimes::coordinator::RemoteEmbClient::connect(addr.as_str(), 0, 0)?;
    print!("{}", c.statsx()?);
    Ok(())
}
