//! PJRT execution engine: loads the AOT HLO-text artifacts and runs them
//! on the CPU PJRT client.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so the
//! client + compiled executables live on a dedicated **engine host
//! thread**; coordinator threads talk to it through a channel-backed
//! [`PjrtEngine`] handle that implements [`StepEngine`] and is `Send +
//! Sync`. Requests are served FIFO — which also models the paper's
//! observed contention between concurrent push-embedding computation and
//! the final training epoch on a shared accelerator (§5.4).

use std::sync::mpsc;
use std::thread;

use anyhow::{anyhow, bail, Context, Result};

use super::engine::{Batch, ModelState, StepEngine, StepStats};
use super::manifest::{Entrypoint, Kind, Manifest, ModelGeom, ModelKind, TensorSpec};

enum Request {
    Train {
        state: ModelState,
        batch: Batch,
        lr: f32,
        reply: mpsc::Sender<Result<(ModelState, StepStats)>>,
    },
    Eval {
        state: ModelState,
        batch: Batch,
        reply: mpsc::Sender<Result<StepStats>>,
    },
    Embed {
        state: ModelState,
        batch: Batch,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    Shutdown,
}

/// `Send + Sync` handle to the engine host thread.
pub struct PjrtEngine {
    geom: ModelGeom,
    tx: mpsc::Sender<Request>,
    _host: HostGuard,
}

struct HostGuard {
    handle: Option<thread::JoinHandle<()>>,
    tx: mpsc::Sender<Request>,
}

impl Drop for HostGuard {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl PjrtEngine {
    /// Compile the (train, eval, embed) entrypoints for `(model, fanout)`
    /// from `manifest` on a fresh host thread.
    pub fn start(manifest: &Manifest, model: ModelKind, fanout: usize) -> Result<Self> {
        let train = manifest
            .find(model, Kind::Train, fanout)
            .ok_or_else(|| anyhow!("no train entrypoint for {model:?} k={fanout}"))?
            .clone();
        let eval = manifest
            .find(model, Kind::Eval, fanout)
            .ok_or_else(|| anyhow!("no eval entrypoint"))?
            .clone();
        let embed = manifest
            .find(model, Kind::Embed, fanout)
            .ok_or_else(|| anyhow!("no embed entrypoint"))?
            .clone();
        let geom = train.geom;
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = thread::Builder::new()
            .name("pjrt-host".into())
            .spawn(move || host_main(train, eval, embed, rx, ready_tx))
            .context("spawn pjrt host")?;
        ready_rx
            .recv()
            .context("pjrt host died during startup")??;
        Ok(Self {
            geom,
            tx: tx.clone(),
            _host: HostGuard {
                handle: Some(handle),
                tx,
            },
        })
    }

    fn send(&self, req: Request) -> Result<()> {
        self.tx
            .send(req)
            .map_err(|_| anyhow!("pjrt host thread is gone"))
    }
}

impl StepEngine for PjrtEngine {
    fn geom(&self) -> &ModelGeom {
        &self.geom
    }

    fn train_step(&self, state: &mut ModelState, batch: &Batch, lr: f32) -> Result<StepStats> {
        let (reply, rx) = mpsc::channel();
        let moved = std::mem::replace(state, ModelState::zeros(&self.geom));
        self.send(Request::Train {
            state: moved,
            batch: batch.clone(),
            lr,
            reply,
        })?;
        let (new_state, stats) = rx.recv().map_err(|_| anyhow!("pjrt host dropped reply"))??;
        *state = new_state;
        Ok(stats)
    }

    fn evaluate(&self, state: &ModelState, batch: &Batch) -> Result<StepStats> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Eval {
            state: state.clone(),
            batch: batch.clone(),
            reply,
        })?;
        rx.recv().map_err(|_| anyhow!("pjrt host dropped reply"))?
    }

    fn embed(&self, state: &ModelState, batch: &Batch) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Embed {
            state: state.clone(),
            batch: batch.clone(),
            reply,
        })?;
        rx.recv().map_err(|_| anyhow!("pjrt host dropped reply"))?
    }
}

// ---------------------------------------------------------------------------
// Host thread
// ---------------------------------------------------------------------------

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    ep: Entrypoint,
    client: xla::PjRtClient,
}

fn compile(client: &xla::PjRtClient, ep: &Entrypoint) -> Result<Compiled> {
    let proto = xla::HloModuleProto::from_text_file(&ep.file)
        .map_err(|e| anyhow!("loading {}: {e:?}", ep.file.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {}: {e:?}", ep.name))?;
    Ok(Compiled {
        exe,
        ep: ep.clone(),
        client: client.clone(),
    })
}

fn host_main(
    train: Entrypoint,
    eval: Entrypoint,
    embed: Entrypoint,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<Result<()>>,
) {
    let setup = (|| -> Result<(Compiled, Compiled, Compiled)> {
        // On low-core hosts the Eigen intra-op pool costs more than it
        // buys (dispatch + spin overhead): -22%/-35% on train/eval step
        // latency with it disabled on a 1-core box (EXPERIMENTS.md §Perf).
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        if cores <= 2 && !std::env::var("XLA_FLAGS").map(|f| f.contains("multi_thread_eigen")).unwrap_or(false)
        {
            let prev = std::env::var("XLA_FLAGS").unwrap_or_default();
            std::env::set_var(
                "XLA_FLAGS",
                format!("--xla_cpu_multi_thread_eigen=false {prev}"),
            );
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok((
            compile(&client, &train)?,
            compile(&client, &eval)?,
            compile(&client, &embed)?,
        ))
    })();
    let (train_c, eval_c, embed_c) = match setup {
        Ok(t) => {
            let _ = ready.send(Ok(()));
            t
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        match req {
            Request::Train {
                state,
                batch,
                lr,
                reply,
            } => {
                let _ = reply.send(run_train(&train_c, state, &batch, lr));
            }
            Request::Eval {
                state,
                batch,
                reply,
            } => {
                let _ = reply.send(run_eval(&eval_c, &state, &batch));
            }
            Request::Embed {
                state,
                batch,
                reply,
            } => {
                let _ = reply.send(run_embed(&embed_c, &state, &batch));
            }
            Request::Shutdown => break,
        }
    }
}

// ---------------------------------------------------------------------------
// Marshaling
// ---------------------------------------------------------------------------

/// Single-copy host->device transfer. We marshal straight to
/// `PjRtBuffer`s and run via `execute_b`: the crate's literal-based
/// `execute` leaks every input device buffer it creates
/// (`buffer.release()` without a matching delete in `xla_rs.cc`), ~1.1 MB
/// per training step (§Perf — found via RSS bisection; `execute_b`
/// borrows caller-owned buffers which free on Drop).
fn buf_f32(client: &xla::PjRtClient, data: &[f32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer(data, shape, None)
        .map_err(|e| anyhow!("buffer f32 {shape:?}: {e:?}"))
}

fn buf_i32(client: &xla::PjRtClient, data: &[i32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer(data, shape, None)
        .map_err(|e| anyhow!("buffer i32 {shape:?}: {e:?}"))
}

fn check_len(spec: &TensorSpec, len: usize) -> Result<()> {
    if spec.numel() != len {
        bail!(
            "input {}: expected {} elements ({:?}), got {len}",
            spec.name,
            spec.numel(),
            spec.shape
        );
    }
    Ok(())
}

/// Push params (+optionally m, v) in canonical order.
fn push_params(
    client: &xla::PjRtClient,
    lits: &mut Vec<xla::PjRtBuffer>,
    specs: &[TensorSpec],
    state: &ModelState,
    with_opt: bool,
) -> Result<usize> {
    let np = state.params.len();
    let mut idx = 0;
    for p in &state.params {
        check_len(&specs[idx], p.len())?;
        lits.push(buf_f32(client, p, &specs[idx].shape)?);
        idx += 1;
    }
    if with_opt {
        for m in &state.m {
            check_len(&specs[idx], m.len())?;
            lits.push(buf_f32(client, m, &specs[idx].shape)?);
            idx += 1;
        }
        for v in &state.v {
            check_len(&specs[idx], v.len())?;
            lits.push(buf_f32(client, v, &specs[idx].shape)?);
            idx += 1;
        }
        debug_assert_eq!(idx, 3 * np);
    }
    Ok(idx)
}

/// Push the block tensors (x, adj*, msk*, rmask*, cache*) in manifest order.
fn push_blocks(
    client: &xla::PjRtClient,
    lits: &mut Vec<xla::PjRtBuffer>,
    specs: &[TensorSpec],
    mut idx: usize,
    batch: &Batch,
) -> Result<usize> {
    check_len(&specs[idx], batch.x.len())?;
    lits.push(buf_f32(client, &batch.x, &specs[idx].shape)?);
    idx += 1;
    for a in batch.adj.iter() {
        check_len(&specs[idx], a.len())?;
        lits.push(buf_i32(client, a, &specs[idx].shape)?);
        idx += 1;
    }
    for m in &batch.msk {
        check_len(&specs[idx], m.len())?;
        lits.push(buf_f32(client, m, &specs[idx].shape)?);
        idx += 1;
    }
    for r in &batch.rmask {
        check_len(&specs[idx], r.len())?;
        lits.push(buf_f32(client, r, &specs[idx].shape)?);
        idx += 1;
    }
    for c in &batch.cache {
        check_len(&specs[idx], c.len())?;
        lits.push(buf_f32(client, c, &specs[idx].shape)?);
        idx += 1;
    }
    Ok(idx)
}

fn execute(c: &Compiled, lits: &[xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
    if lits.len() != c.ep.inputs.len() {
        bail!(
            "{}: marshaled {} inputs, entrypoint takes {}",
            c.ep.name,
            lits.len(),
            c.ep.inputs.len()
        );
    }
    let bufs = c
        .exe
        .execute_b::<xla::PjRtBuffer>(lits)
        .map_err(|e| anyhow!("{} execute: {e:?}", c.ep.name))?;
    let out = bufs[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal: {e:?}"))?;
    // lowered with return_tuple=True
    out.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
}

fn scalar_f32(l: &xla::Literal) -> Result<f32> {
    l.to_vec::<f32>()
        .map_err(|e| anyhow!("scalar: {e:?}"))
        .map(|v| v[0])
}

fn run_train(
    c: &Compiled,
    mut state: ModelState,
    batch: &Batch,
    lr: f32,
) -> Result<(ModelState, StepStats)> {
    let specs = &c.ep.inputs;
    let np = state.params.len();
    let mut lits = Vec::with_capacity(specs.len());
    let mut idx = push_params(&c.client, &mut lits, specs, &state, true)?;
    state.t += 1.0;
    lits.push(buf_f32(&c.client, &[state.t], &[])?);
    idx += 1;
    lits.push(buf_f32(&c.client, &[lr], &[])?);
    idx += 1;
    idx = push_blocks(&c.client, &mut lits, specs, idx, batch)?;
    check_len(&specs[idx], batch.labels.len())?;
    lits.push(buf_i32(&c.client, &batch.labels, &specs[idx].shape)?);
    idx += 1;
    check_len(&specs[idx], batch.lmask.len())?;
    lits.push(buf_f32(&c.client, &batch.lmask, &specs[idx].shape)?);
    let outs = execute(c, &lits)?;
    if outs.len() != 3 * np + 3 {
        bail!("train: expected {} outputs, got {}", 3 * np + 3, outs.len());
    }
    for (i, o) in outs[..np].iter().enumerate() {
        state.params[i] = o.to_vec::<f32>().map_err(|e| anyhow!("out p{i}: {e:?}"))?;
    }
    for (i, o) in outs[np..2 * np].iter().enumerate() {
        state.m[i] = o.to_vec::<f32>().map_err(|e| anyhow!("out m{i}: {e:?}"))?;
    }
    for (i, o) in outs[2 * np..3 * np].iter().enumerate() {
        state.v[i] = o.to_vec::<f32>().map_err(|e| anyhow!("out v{i}: {e:?}"))?;
    }
    let stats = StepStats {
        loss: scalar_f32(&outs[3 * np])?,
        correct: scalar_f32(&outs[3 * np + 1])?,
        total: scalar_f32(&outs[3 * np + 2])?,
    };
    Ok((state, stats))
}

fn run_eval(c: &Compiled, state: &ModelState, batch: &Batch) -> Result<StepStats> {
    let specs = &c.ep.inputs;
    let mut lits = Vec::with_capacity(specs.len());
    let mut idx = push_params(&c.client, &mut lits, specs, state, false)?;
    idx = push_blocks(&c.client, &mut lits, specs, idx, batch)?;
    check_len(&specs[idx], batch.labels.len())?;
    lits.push(buf_i32(&c.client, &batch.labels, &specs[idx].shape)?);
    idx += 1;
    check_len(&specs[idx], batch.lmask.len())?;
    lits.push(buf_f32(&c.client, &batch.lmask, &specs[idx].shape)?);
    let outs = execute(c, &lits)?;
    Ok(StepStats {
        loss: scalar_f32(&outs[0])?,
        correct: scalar_f32(&outs[1])?,
        total: scalar_f32(&outs[2])?,
    })
}

fn run_embed(c: &Compiled, state: &ModelState, batch: &Batch) -> Result<Vec<Vec<f32>>> {
    let specs = &c.ep.inputs;
    let mut lits = Vec::with_capacity(specs.len());
    let idx = push_params(&c.client, &mut lits, specs, state, false)?;
    push_blocks(&c.client, &mut lits, specs, idx, batch)?;
    let outs = execute(c, &lits)?;
    outs.iter()
        .enumerate()
        .map(|(i, o)| o.to_vec::<f32>().map_err(|e| anyhow!("embed out {i}: {e:?}")))
        .collect()
}

/// Run the tiny smoke artifact (fn(x,y)=x@y+2): startup health check.
pub fn run_smoke(manifest: &Manifest) -> Result<Vec<f32>> {
    let file = manifest
        .smoke_file
        .as_ref()
        .ok_or_else(|| anyhow!("manifest has no smoke artifact"))?;
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?;
    let proto = xla::HloModuleProto::from_text_file(file).map_err(|e| anyhow!("{e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).map_err(|e| anyhow!("{e:?}"))?;
    let x = buf_f32(&client, &[1.0, 2.0, 3.0, 4.0], &[2, 2])?;
    let y = buf_f32(&client, &[1.0, 1.0, 1.0, 1.0], &[2, 2])?;
    let out = exe
        .execute_b::<xla::PjRtBuffer>(&[x, y])
        .map_err(|e| anyhow!("{e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("{e:?}"))?;
    let t = out.to_tuple1().map_err(|e| anyhow!("{e:?}"))?;
    t.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        Manifest::load(dir).ok()
    }

    #[test]
    fn smoke_artifact_runs() {
        let Some(m) = manifest() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let v = run_smoke(&m).unwrap();
        assert_eq!(v, vec![5.0, 5.0, 9.0, 9.0]);
    }
}
