//! `StepEngine`: the compute interface between the L3 coordinator and the
//! AOT-compiled model. Two implementations:
//!
//! * [`crate::runtime::pjrt::PjrtEngine`] — loads `artifacts/*.hlo.txt`
//!   (jax-lowered GraphConv/SAGE with the Pallas kernels inlined) and runs
//!   them on the PJRT CPU client. The production path.
//! * [`crate::runtime::refengine::RefEngine`] — a pure-Rust analytic
//!   forward/backward/Adam oracle used in tests (no artifacts required)
//!   and to cross-check PJRT numerics.

use anyhow::Result;

use super::manifest::ModelGeom;
use crate::graph::sampler::SharedAdj;
use crate::util::rng::Rng;

/// Model parameters + Adam optimizer state, flat canonical order.
#[derive(Clone, Debug)]
pub struct ModelState {
    pub params: Vec<Vec<f32>>,
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// 1-based Adam step counter (as f32 for the HLO input).
    pub t: f32,
}

impl ModelState {
    /// Glorot-uniform init matching `python/compile/model.py::init_params`
    /// in distribution (not bitwise — jax PRNG differs; cross-checks use
    /// explicitly shared weights).
    pub fn init(geom: &ModelGeom, seed: u64) -> Self {
        let mut rng = Rng::new(seed, 0x1817);
        let params = geom
            .param_shapes()
            .iter()
            .map(|shape| {
                if shape.len() == 2 {
                    let (fi, fo) = (shape[0], shape[1]);
                    let limit = (6.0 / (fi + fo) as f64).sqrt();
                    (0..fi * fo)
                        .map(|_| ((rng.f64() * 2.0 - 1.0) * limit) as f32)
                        .collect()
                } else {
                    vec![0f32; shape[0]]
                }
            })
            .collect::<Vec<_>>();
        let zeros: Vec<Vec<f32>> = geom
            .param_shapes()
            .iter()
            .map(|s| vec![0f32; s.iter().product()])
            .collect();
        Self {
            params,
            m: zeros.clone(),
            v: zeros,
            t: 0.0,
        }
    }

    pub fn zeros(geom: &ModelGeom) -> Self {
        let zeros: Vec<Vec<f32>> = geom
            .param_shapes()
            .iter()
            .map(|s| vec![0f32; s.iter().product()])
            .collect();
        Self {
            params: zeros.clone(),
            m: zeros.clone(),
            v: zeros,
            t: 0.0,
        }
    }

    /// Total scalar parameter count.
    pub fn numel(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }
}

/// A fully-assembled padded minibatch in the AOT tensor layout. `depth` is
/// L for train/eval, L-1 for embed; `width` is the root row count.
#[derive(Clone, Debug)]
pub struct Batch {
    pub depth: usize,
    pub width: usize,
    /// `[s_depth, F]` features (deepest level).
    pub x: Vec<f32>,
    /// `adj[d]` is `[s_d, K]` i32 into level d+1. Geometry-constant, so it
    /// is shared (refcounted) across every minibatch rather than cloned.
    pub adj: SharedAdj,
    /// `msk[d]` is `[s_d, K]`.
    pub msk: Vec<Vec<f32>>,
    /// `rmask[l-1]` is `[s_{depth-l}]` for hidden layer l.
    pub rmask: Vec<Vec<f32>>,
    /// `cache[l-1]` is `[s_{depth-l}, H]` cached remote h^l.
    pub cache: Vec<Vec<f32>>,
    /// `[width]`; empty for embed batches.
    pub labels: Vec<i32>,
    pub lmask: Vec<f32>,
}

impl Default for Batch {
    fn default() -> Self {
        Self {
            depth: 0,
            width: 0,
            x: Vec::new(),
            adj: Vec::<Vec<i32>>::new().into(),
            msk: Vec::new(),
            rmask: Vec::new(),
            cache: Vec::new(),
            labels: Vec::new(),
            lmask: Vec::new(),
        }
    }
}

/// Scalar results of a train/eval step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    pub loss: f32,
    pub correct: f32,
    pub total: f32,
}

impl StepStats {
    pub fn accuracy(&self) -> f64 {
        if self.total > 0.0 {
            self.correct as f64 / self.total as f64
        } else {
            0.0
        }
    }
}

/// The compute interface. All methods take `&self`; engines are shared
/// across client threads (`Send + Sync`) — PJRT executions are internally
/// synchronized, the RefEngine is stateless.
pub trait StepEngine: Send + Sync {
    fn geom(&self) -> &ModelGeom;

    /// One minibatch: forward + backward + Adam. Mutates `state` in place
    /// and returns the pre-update loss/accuracy scalars.
    fn train_step(&self, state: &mut ModelState, batch: &Batch, lr: f32) -> Result<StepStats>;

    /// Forward-only evaluation on a labelled batch.
    fn evaluate(&self, state: &ModelState, batch: &Batch) -> Result<StepStats>;

    /// Compute `h^1..h^{L-1}` for a push batch (depth L-1). Returns one
    /// `[push_batch, H]` row-major tensor per hidden layer.
    fn embed(&self, state: &ModelState, batch: &Batch) -> Result<Vec<Vec<f32>>>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ModelKind;

    fn geom() -> ModelGeom {
        ModelGeom {
            model: ModelKind::Gc,
            layers: 3,
            feat: 8,
            hidden: 8,
            classes: 4,
            batch: 4,
            fanout: 2,
            push_batch: 4,
        }
    }

    #[test]
    fn model_state_shapes() {
        let g = geom();
        let s = ModelState::init(&g, 1);
        assert_eq!(s.params.len(), 6);
        assert_eq!(s.params[0].len(), 64);
        assert_eq!(s.params[5].len(), 4);
        assert_eq!(s.numel(), 64 + 8 + 64 + 8 + 32 + 4);
        // weights nonzero, biases zero
        assert!(s.params[0].iter().any(|&x| x != 0.0));
        assert!(s.params[1].iter().all(|&x| x == 0.0));
        assert!(s.m.iter().flatten().all(|&x| x == 0.0));
    }

    #[test]
    fn init_deterministic() {
        let g = geom();
        let a = ModelState::init(&g, 5);
        let b = ModelState::init(&g, 5);
        assert_eq!(a.params, b.params);
        let c = ModelState::init(&g, 6);
        assert_ne!(a.params, c.params);
    }
}
