//! Runtime bridge: manifest-driven loading and execution of the AOT
//! artifacts (PJRT), plus a pure-Rust reference engine (with tiled
//! parallel kernels) for artifact-free tests and numerics cross-checks.

pub mod engine;
pub mod kernels;
pub mod manifest;
pub mod refengine;

#[cfg(feature = "pjrt")]
pub mod pjrt;

/// Stub when built without the `pjrt` feature (the offline registry may
/// not carry the `xla` crate): keeps the public API shape so callers and
/// tests compile; construction fails at runtime with a pointer to
/// `OPTIMES_ENGINE=ref`.
#[cfg(not(feature = "pjrt"))]
pub mod pjrt {
    use anyhow::{bail, Result};

    use super::engine::{Batch, ModelState, StepEngine, StepStats};
    use super::manifest::{Manifest, ModelGeom, ModelKind};

    pub struct PjrtEngine {
        geom: ModelGeom,
    }

    impl PjrtEngine {
        pub fn start(_manifest: &Manifest, _model: ModelKind, _fanout: usize) -> Result<Self> {
            bail!(
                "optimes was built without the `pjrt` feature; set \
                 OPTIMES_ENGINE=ref or rebuild with `--features pjrt` \
                 (requires the vendored `xla` crate, see rust/Cargo.toml)"
            )
        }
    }

    impl StepEngine for PjrtEngine {
        fn geom(&self) -> &ModelGeom {
            &self.geom
        }

        fn train_step(&self, _s: &mut ModelState, _b: &Batch, _lr: f32) -> Result<StepStats> {
            bail!("pjrt feature disabled")
        }

        fn evaluate(&self, _s: &ModelState, _b: &Batch) -> Result<StepStats> {
            bail!("pjrt feature disabled")
        }

        fn embed(&self, _s: &ModelState, _b: &Batch) -> Result<Vec<Vec<f32>>> {
            bail!("pjrt feature disabled")
        }
    }

    /// Artifact smoke test (real implementation in the `pjrt` feature).
    pub fn run_smoke(_m: &Manifest) -> Result<Vec<f32>> {
        bail!("pjrt feature disabled")
    }
}

pub use engine::{Batch, ModelState, StepEngine, StepStats};
pub use manifest::{Kind, Manifest, ModelGeom, ModelKind};
pub use pjrt::PjrtEngine;
pub use refengine::RefEngine;
