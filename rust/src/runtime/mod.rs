//! Runtime bridge: manifest-driven loading and execution of the AOT
//! artifacts (PJRT), plus a pure-Rust reference engine for artifact-free
//! tests and numerics cross-checks.

pub mod engine;
pub mod manifest;
pub mod pjrt;
pub mod refengine;

pub use engine::{Batch, ModelState, StepEngine, StepStats};
pub use manifest::{Kind, Manifest, ModelGeom, ModelKind};
pub use pjrt::PjrtEngine;
pub use refengine::RefEngine;
