//! Pure-Rust reference engine: analytic GraphConv/SAGEConv forward,
//! backward, and Adam over the padded block layout.
//!
//! This duplicates the L2 JAX semantics exactly (same op order as
//! `python/compile/kernels/ref.py` + `model.py`) so that:
//! * every coordinator test can run without building artifacts, and
//! * integration tests can cross-check PJRT numerics bit-for-bit-ish
//!   (<= 1e-4 abs) against an independent implementation.

use anyhow::{ensure, Result};

use super::engine::{Batch, ModelState, StepEngine, StepStats};
use super::kernels::{matmul_acc, matmul_at_b, matmul_b_wt};
use super::manifest::{ModelGeom, ModelKind};

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

pub struct RefEngine {
    geom: ModelGeom,
}

impl RefEngine {
    pub fn new(geom: ModelGeom) -> Self {
        Self { geom }
    }
}

/// Residuals captured per layer during forward (for backward).
struct LayerRes {
    /// masked-mean over children `[s_out, d_in]`
    mean: Vec<f32>,
    /// clamped valid-child count `[s_out]`
    cnt: Vec<f32>,
    /// relu input positivity `[s_out, d_out]` (empty when no relu)
    zpos: Vec<bool>,
    s_out: usize,
    d_in: usize,
    d_out: usize,
}

struct Forward {
    /// `h[0]` = x over the deepest level; `h[l]` = layer-l output
    /// (post-substitution) over its level.
    h: Vec<Vec<f32>>,
    res: Vec<LayerRes>,
}

impl RefEngine {
    fn layer_dims(&self, l: usize) -> (usize, usize) {
        let g = &self.geom;
        let d_in = if l == 1 { g.feat } else { g.hidden };
        let d_out = if l == g.layers { g.classes } else { g.hidden };
        (d_in, d_out)
    }

    /// Flat parameter index of layer l's weight mats + bias.
    fn pidx(&self, l: usize) -> usize {
        (l - 1) * (self.geom.model.mats_per_layer() + 1)
    }

    fn forward(&self, state: &ModelState, batch: &Batch) -> Result<Forward> {
        let g = &self.geom;
        let k = g.fanout;
        let depth = batch.depth;
        ensure!(depth <= g.layers && depth >= 1, "bad depth {depth}");
        let mut h: Vec<Vec<f32>> = vec![batch.x.clone()];
        let mut res = Vec::with_capacity(depth);
        for l in 1..=depth {
            let lvl = depth - l;
            let (d_in, d_out) = self.layer_dims(l);
            let s_out = batch.adj[lvl].len() / k;
            let h_prev = h.last().unwrap();
            ensure!(
                h_prev.len() >= s_out * d_in,
                "layer {l}: prev level too small"
            );
            // masked mean over sampled children
            let mut mean = vec![0f32; s_out * d_in];
            let mut cnt = vec![0f32; s_out];
            for i in 0..s_out {
                let mut c = 0f32;
                let row = &mut mean[i * d_in..(i + 1) * d_in];
                for j in 0..k {
                    let m = batch.msk[lvl][i * k + j];
                    if m == 0.0 {
                        continue;
                    }
                    c += m;
                    let child = batch.adj[lvl][i * k + j] as usize;
                    let cr = &h_prev[child * d_in..(child + 1) * d_in];
                    for (o, &v) in row.iter_mut().zip(cr) {
                        *o += m * v;
                    }
                }
                let cc = c.max(1.0);
                cnt[i] = cc;
                for o in row.iter_mut() {
                    *o /= cc;
                }
            }
            // transform
            let mut z = vec![0f32; s_out * d_out];
            let p = self.pidx(l);
            match g.model {
                ModelKind::Gc => {
                    // (self + mean) @ W
                    let mut agg = mean.clone();
                    for i in 0..s_out * d_in {
                        agg[i] += h_prev[i];
                    }
                    matmul_acc(&agg, &state.params[p], &mut z, s_out, d_in, d_out);
                }
                ModelKind::Sage => {
                    matmul_acc(&h_prev[..s_out * d_in], &state.params[p], &mut z, s_out, d_in, d_out);
                    matmul_acc(&mean, &state.params[p + 1], &mut z, s_out, d_in, d_out);
                }
            }
            let bias = &state.params[p + g.model.mats_per_layer()];
            for i in 0..s_out {
                for (zc, &bv) in z[i * d_out..(i + 1) * d_out].iter_mut().zip(bias) {
                    *zc += bv;
                }
            }
            // activation (all but the model's last layer)
            let relu = l < g.layers;
            let mut zpos = Vec::new();
            if relu {
                zpos = z.iter().map(|&v| v > 0.0).collect();
                for v in z.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
            }
            // remote substitution
            if l - 1 < batch.rmask.len() {
                let r = &batch.rmask[l - 1];
                let c = &batch.cache[l - 1];
                ensure!(r.len() == s_out, "rmask size");
                for i in 0..s_out {
                    let ri = r[i];
                    if ri != 0.0 {
                        for d in 0..d_out {
                            z[i * d_out + d] =
                                (1.0 - ri) * z[i * d_out + d] + ri * c[i * d_out + d];
                        }
                    }
                }
            }
            res.push(LayerRes {
                mean,
                cnt,
                zpos,
                s_out,
                d_in,
                d_out,
            });
            h.push(z);
        }
        Ok(Forward { h, res })
    }

    /// Masked softmax cross-entropy over the root level.
    fn loss_grad(
        &self,
        logits: &[f32],
        labels: &[i32],
        lmask: &[f32],
    ) -> (StepStats, Vec<f32>) {
        let c = self.geom.classes;
        let n = labels.len();
        let total: f32 = lmask.iter().sum();
        let denom = total.max(1.0);
        let mut loss = 0f32;
        let mut correct = 0f32;
        let mut grad = vec![0f32; n * c];
        for i in 0..n {
            let row = &logits[i * c..(i + 1) * c];
            let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0f32;
            for &v in row {
                sum += (v - maxv).exp();
            }
            let lse = maxv + sum.ln();
            let y = labels[i] as usize;
            let m = lmask[i];
            if m != 0.0 {
                loss += m * (lse - row[y]);
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap_or(0);
                if argmax == y {
                    correct += m;
                }
            }
            let gr = &mut grad[i * c..(i + 1) * c];
            for (j, g) in gr.iter_mut().enumerate() {
                let p = (row[j] - lse).exp();
                let ind = if j == y { 1.0 } else { 0.0 };
                *g = (p - ind) * m / denom;
            }
        }
        (
            StepStats {
                loss: loss / denom,
                correct,
                total,
            },
            grad,
        )
    }

    /// Backward pass producing flat param grads (canonical order).
    fn backward(
        &self,
        state: &ModelState,
        batch: &Batch,
        fwd: &Forward,
        g_logits: Vec<f32>,
    ) -> Vec<Vec<f32>> {
        let g = &self.geom;
        let k = g.fanout;
        let depth = batch.depth;
        let mut grads: Vec<Vec<f32>> = state.params.iter().map(|p| vec![0f32; p.len()]).collect();
        let mut g_out = g_logits; // grad wrt layer `depth` output
        for l in (1..=depth).rev() {
            let lvl = depth - l;
            let r = &fwd.res[l - 1];
            let (s_out, d_in, d_out) = (r.s_out, r.d_in, r.d_out);
            let h_prev = &fwd.h[l - 1];
            // substitution: d out / d computed = (1 - rmask)
            if l - 1 < batch.rmask.len() {
                let rm = &batch.rmask[l - 1];
                for i in 0..s_out {
                    if rm[i] != 0.0 {
                        let f = 1.0 - rm[i];
                        for d in 0..d_out {
                            g_out[i * d_out + d] *= f;
                        }
                    }
                }
            }
            // relu
            if !r.zpos.is_empty() {
                for (gv, &pos) in g_out.iter_mut().zip(&r.zpos) {
                    if !pos {
                        *gv = 0.0;
                    }
                }
            }
            let g_z = g_out;
            let p = self.pidx(l);
            let s_in = h_prev.len() / d_in;
            let mut g_h_prev = vec![0f32; s_in * d_in];
            let mut g_mean = vec![0f32; s_out * d_in];
            match g.model {
                ModelKind::Gc => {
                    let mut agg = r.mean.clone();
                    for i in 0..s_out * d_in {
                        agg[i] += h_prev[i];
                    }
                    matmul_at_b(&agg, &g_z, &mut grads[p], s_out, d_in, d_out);
                    // g_agg = g_z W^T; feeds both self and mean paths
                    let mut g_agg = vec![0f32; s_out * d_in];
                    matmul_b_wt(&g_z, &state.params[p], &mut g_agg, s_out, d_in, d_out);
                    g_h_prev[..s_out * d_in].copy_from_slice(&g_agg);
                    g_mean.copy_from_slice(&g_agg);
                }
                ModelKind::Sage => {
                    matmul_at_b(&h_prev[..s_out * d_in], &g_z, &mut grads[p], s_out, d_in, d_out);
                    matmul_at_b(&r.mean, &g_z, &mut grads[p + 1], s_out, d_in, d_out);
                    matmul_b_wt(&g_z, &state.params[p], &mut g_h_prev[..s_out * d_in], s_out, d_in, d_out);
                    matmul_b_wt(&g_z, &state.params[p + 1], &mut g_mean, s_out, d_in, d_out);
                }
            }
            // bias grad
            {
                let gb = &mut grads[p + g.model.mats_per_layer()];
                for i in 0..s_out {
                    for (b, &gv) in gb.iter_mut().zip(&g_z[i * d_out..(i + 1) * d_out]) {
                        *b += gv;
                    }
                }
            }
            // scatter mean grads into children: g_child += msk/cnt * g_mean
            for i in 0..s_out {
                let gm = &g_mean[i * d_in..(i + 1) * d_in];
                let inv = 1.0 / r.cnt[i];
                for j in 0..k {
                    let m = batch.msk[lvl][i * k + j];
                    if m == 0.0 {
                        continue;
                    }
                    let child = batch.adj[lvl][i * k + j] as usize;
                    let cr = &mut g_h_prev[child * d_in..(child + 1) * d_in];
                    for (o, &gv) in cr.iter_mut().zip(gm) {
                        *o += m * inv * gv;
                    }
                }
            }
            g_out = g_h_prev;
        }
        grads
    }

    fn adam(&self, state: &mut ModelState, grads: &[Vec<f32>], lr: f32) {
        state.t += 1.0;
        let b1t = ADAM_B1.powf(state.t);
        let b2t = ADAM_B2.powf(state.t);
        for ((p, m), (v, g)) in state
            .params
            .iter_mut()
            .zip(state.m.iter_mut())
            .zip(state.v.iter_mut().zip(grads))
        {
            for i in 0..p.len() {
                m[i] = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * g[i];
                v[i] = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * g[i] * g[i];
                let mhat = m[i] / (1.0 - b1t);
                let vhat = v[i] / (1.0 - b2t);
                p[i] -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
            }
        }
    }
}

impl StepEngine for RefEngine {
    fn geom(&self) -> &ModelGeom {
        &self.geom
    }

    fn train_step(&self, state: &mut ModelState, batch: &Batch, lr: f32) -> Result<StepStats> {
        ensure!(batch.depth == self.geom.layers, "train batch depth");
        let fwd = self.forward(state, batch)?;
        let logits = fwd.h.last().unwrap();
        let (stats, g_logits) = self.loss_grad(logits, &batch.labels, &batch.lmask);
        let grads = self.backward(state, batch, &fwd, g_logits);
        self.adam(state, &grads, lr);
        Ok(stats)
    }

    fn evaluate(&self, state: &ModelState, batch: &Batch) -> Result<StepStats> {
        let fwd = self.forward(state, batch)?;
        let logits = fwd.h.last().unwrap();
        let (stats, _) = self.loss_grad(logits, &batch.labels, &batch.lmask);
        Ok(stats)
    }

    fn embed(&self, state: &ModelState, batch: &Batch) -> Result<Vec<Vec<f32>>> {
        let depth = self.geom.layers - 1;
        ensure!(batch.depth == depth, "embed batch depth");
        let fwd = self.forward(state, batch)?;
        let p = self.geom.push_batch;
        let h = self.geom.hidden;
        Ok((1..=depth)
            .map(|l| fwd.h[l][..p * h].to_vec())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn geom() -> ModelGeom {
        ModelGeom {
            model: ModelKind::Gc,
            layers: 3,
            feat: 8,
            hidden: 8,
            classes: 4,
            batch: 4,
            fanout: 2,
            push_batch: 4,
        }
    }

    /// Random fully-local batch with the constant tree adjacency.
    fn rand_batch(g: &ModelGeom, depth: usize, width: usize, seed: u64) -> Batch {
        let mut rng = Rng::new(seed, 0xBA7);
        let k = g.fanout;
        let mut adj = Vec::new();
        let mut msk = Vec::new();
        let mut s = width;
        let mut sizes = vec![width];
        for _ in 0..depth {
            adj.push((0..s * k).map(|e| (s + e) as i32).collect::<Vec<i32>>());
            msk.push((0..s * k).map(|_| if rng.chance(0.8) { 1.0 } else { 0.0 }).collect());
            s += s * k;
            sizes.push(s);
        }
        let deepest = *sizes.last().unwrap();
        let x = (0..deepest * g.feat).map(|_| rng.normal() as f32).collect();
        let n_sub = if depth == g.layers { g.layers - 1 } else { depth - 1 };
        let rmask = (1..=n_sub)
            .map(|l| {
                let lvl = depth - l;
                (0..sizes[lvl]).map(|_| if rng.chance(0.2) { 1.0 } else { 0.0 }).collect()
            })
            .collect::<Vec<Vec<f32>>>();
        let cache = (1..=n_sub)
            .map(|l| {
                let lvl = depth - l;
                (0..sizes[lvl] * g.hidden).map(|_| rng.normal() as f32).collect()
            })
            .collect();
        let labels = (0..width).map(|_| rng.below(g.classes) as i32).collect();
        let lmask = vec![1.0; width];
        Batch {
            depth,
            width,
            x,
            adj: adj.into(),
            msk,
            rmask,
            cache,
            labels,
            lmask,
        }
    }

    #[test]
    fn train_reduces_loss_on_fixed_batch() {
        for model in [ModelKind::Gc, ModelKind::Sage] {
            let mut g = geom();
            g.model = model;
            let eng = RefEngine::new(g);
            let mut st = ModelState::init(&g, 3);
            let batch = rand_batch(&g, 3, 4, 7);
            let first = eng.train_step(&mut st, &batch, 0.01).unwrap().loss;
            let mut last = first;
            for _ in 0..60 {
                last = eng.train_step(&mut st, &batch, 0.01).unwrap().loss;
            }
            assert!(last < first * 0.5, "{model:?}: {first} -> {last}");
            assert!(last.is_finite());
        }
    }

    #[test]
    fn numeric_gradient_check() {
        // finite-difference check on a few weights for both models
        for model in [ModelKind::Gc, ModelKind::Sage] {
            let mut g = geom();
            g.model = model;
            let eng = RefEngine::new(g);
            let st = ModelState::init(&g, 5);
            let batch = rand_batch(&g, 3, 4, 9);
            let fwd = eng.forward(&st, &batch).unwrap();
            let (_, g_logits) =
                eng.loss_grad(fwd.h.last().unwrap(), &batch.labels, &batch.lmask);
            let grads = eng.backward(&st, &batch, &fwd, g_logits);
            let eps = 3e-3_f32;
            let mut checked = 0;
            for pi in 0..st.params.len() {
                for wi in [0usize, st.params[pi].len() / 2] {
                    let mut plus = st.clone();
                    plus.params[pi][wi] += eps;
                    let lp = eng.evaluate(&plus, &batch).unwrap().loss;
                    let mut minus = st.clone();
                    minus.params[pi][wi] -= eps;
                    let lm = eng.evaluate(&minus, &batch).unwrap().loss;
                    let fd = (lp - lm) / (2.0 * eps);
                    let an = grads[pi][wi];
                    assert!(
                        (fd - an).abs() < 2e-2 * (1.0 + fd.abs().max(an.abs())),
                        "{model:?} p{pi}[{wi}]: fd={fd} analytic={an}"
                    );
                    checked += 1;
                }
            }
            assert!(checked >= 12);
        }
    }

    #[test]
    fn remote_substitution_blocks_gradient() {
        // If every level-1 and level-2 row is remote, parameter grads of
        // layer 1 must be zero (its compute is fully overridden).
        let g = geom();
        let eng = RefEngine::new(g);
        let st = ModelState::init(&g, 4);
        let mut batch = rand_batch(&g, 3, 4, 11);
        for r in batch.rmask.iter_mut() {
            r.iter_mut().for_each(|v| *v = 1.0);
        }
        let fwd = eng.forward(&st, &batch).unwrap();
        let (_, g_logits) = eng.loss_grad(fwd.h.last().unwrap(), &batch.labels, &batch.lmask);
        let grads = eng.backward(&st, &batch, &fwd, g_logits);
        // layer-1 W grad: index 0
        assert!(grads[0].iter().all(|&v| v == 0.0));
        assert!(grads[1].iter().all(|&v| v == 0.0));
        // layer-3 grads must be nonzero
        assert!(grads[4].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn embed_outputs_have_expected_shapes_and_match_forward() {
        let g = geom();
        let eng = RefEngine::new(g);
        let st = ModelState::init(&g, 6);
        let batch = rand_batch(&g, 2, g.push_batch, 13);
        let outs = eng.embed(&st, &batch).unwrap();
        assert_eq!(outs.len(), 2);
        for o in &outs {
            assert_eq!(o.len(), g.push_batch * g.hidden);
        }
        let fwd = eng.forward(&st, &batch).unwrap();
        assert_eq!(outs[0], fwd.h[1][..g.push_batch * g.hidden].to_vec());
        assert_eq!(outs[1], fwd.h[2][..g.push_batch * g.hidden].to_vec());
    }

    #[test]
    fn eval_is_pure() {
        let g = geom();
        let eng = RefEngine::new(g);
        let st = ModelState::init(&g, 8);
        let batch = rand_batch(&g, 3, 4, 15);
        let a = eng.evaluate(&st, &batch).unwrap();
        let b = eng.evaluate(&st, &batch).unwrap();
        assert_eq!(a.loss, b.loss);
        assert_eq!(a.correct, b.correct);
    }
}
