//! Register-blocked, cache-tiled, thread-parallel matmul kernels for the
//! reference engine's hot path (DESIGN.md §3).
//!
//! Three primitives, matching the analytic forward/backward of
//! [`super::refengine`]:
//!
//! * [`matmul_acc`]  — `out[n,do] += a[n,di] @ w[di,do]` (forward transform)
//! * [`matmul_at_b`] — `gw[di,do] += a[n,di]^T @ g[n,do]` (weight grads)
//! * [`matmul_b_wt`] — `out[n,di] += g[n,do] @ w[di,do]^T` (input grads)
//!
//! Design:
//! * **Register blocking.** Inner loops are unrolled 8-wide (axpy) or use
//!   4 independent accumulators (dot) so LLVM vectorizes without
//!   fast-math. `matmul_acc` additionally processes row *pairs* so each
//!   streamed `w` row is used twice per load.
//! * **Cache tiling.** Reductions are blocked (`KB`/`RB`) so the streamed
//!   operand stays L1/L2-resident across a tile instead of being re-read
//!   from memory per output row.
//! * **Row-tile parallelism.** Output rows are partitioned into disjoint
//!   tiles dispatched on the shared [`crate::util::pool`] ThreadPool once
//!   the multiply-accumulate count crosses [`par_min_macs`]. Tiles write
//!   disjoint output ranges, so results are bitwise-identical to the
//!   serial path regardless of thread count.
//!
//! Reduction order is preserved for `matmul_acc` / `matmul_at_b` (bitwise
//! vs the oracle); `matmul_b_wt` uses a 4-accumulator dot, so it agrees
//! within f32 reassociation error (property-tested to 1e-5 relative).
//!
//! The pre-tiling scalar loops are kept verbatim in [`naive`] as the
//! correctness oracle for property tests and the A/B micro-bench, and can
//! be forced at runtime ([`set_force_naive`] or `OPTIMES_NAIVE_KERNELS=1`)
//! so `benches/bench_roundtime.rs` can measure end-to-end speedup.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::util::pool::{self, SendPtr};

/// Reduction-dimension block for `matmul_acc`: bounds the slice of `w`
/// streamed per pass so it stays cache-hot across a row pair.
const KB: usize = 64;
/// Row block for `matmul_at_b`: bounds the slice of `g` re-read per
/// output row so it stays L2-resident.
const RB: usize = 64;
/// Default minimum multiply-accumulate count before tiles are dispatched
/// to the thread pool (below this, spawn/steal overhead dominates).
const DEFAULT_PAR_MIN_MACS: usize = 1 << 20;

static PAR_MIN_MACS: AtomicUsize = AtomicUsize::new(DEFAULT_PAR_MIN_MACS);
/// 0 = unset (defer to `OPTIMES_NAIVE_KERNELS`), 1 = naive, 2 = tiled.
static FORCE_NAIVE: AtomicUsize = AtomicUsize::new(0);

/// Current parallel-dispatch threshold in multiply-accumulates.
pub fn par_min_macs() -> usize {
    PAR_MIN_MACS.load(Ordering::Relaxed)
}

/// Override the parallel-dispatch threshold; returns the previous value.
/// `0` forces every call through the pool (used by tests/benches to
/// exercise the parallel path on small shapes).
pub fn set_par_min_macs(v: usize) -> usize {
    PAR_MIN_MACS.swap(v, Ordering::Relaxed)
}

/// Route all kernels through the scalar [`naive`] oracle (A/B benching).
/// The explicit setter is authoritative: it overrides the
/// `OPTIMES_NAIVE_KERNELS` env var in both directions, so A/B harnesses
/// can't be silently poisoned by ambient environment.
pub fn set_force_naive(on: bool) {
    FORCE_NAIVE.store(if on { 1 } else { 2 }, Ordering::Relaxed);
}

fn force_naive() -> bool {
    static ENV: OnceLock<bool> = OnceLock::new();
    match FORCE_NAIVE.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            *ENV.get_or_init(|| {
                std::env::var("OPTIMES_NAIVE_KERNELS")
                    .map(|v| v != "0")
                    .unwrap_or(false)
            })
        }
    }
}

/// `y += s * x`, 8-wide unrolled. `x.len() == y.len()`.
#[inline]
fn axpy(s: f32, x: &[f32], y: &mut [f32]) {
    let mut yc = y.chunks_exact_mut(8);
    let mut xc = x.chunks_exact(8);
    for (yy, xx) in (&mut yc).zip(&mut xc) {
        yy[0] += s * xx[0];
        yy[1] += s * xx[1];
        yy[2] += s * xx[2];
        yy[3] += s * xx[3];
        yy[4] += s * xx[4];
        yy[5] += s * xx[5];
        yy[6] += s * xx[6];
        yy[7] += s * xx[7];
    }
    for (yy, xx) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yy += s * xx;
    }
}

/// `y0 += s0 * x; y1 += s1 * x` — row-pair axpy sharing each load of `x`.
#[inline]
fn axpy2(s0: f32, s1: f32, x: &[f32], y0: &mut [f32], y1: &mut [f32]) {
    let mut y0c = y0.chunks_exact_mut(4);
    let mut y1c = y1.chunks_exact_mut(4);
    let mut xc = x.chunks_exact(4);
    while let ((Some(a), Some(b)), Some(xx)) =
        (((&mut y0c).next(), (&mut y1c).next()), (&mut xc).next())
    {
        a[0] += s0 * xx[0];
        a[1] += s0 * xx[1];
        a[2] += s0 * xx[2];
        a[3] += s0 * xx[3];
        b[0] += s1 * xx[0];
        b[1] += s1 * xx[1];
        b[2] += s1 * xx[2];
        b[3] += s1 * xx[3];
    }
    let y0r = y0c.into_remainder();
    let y1r = y1c.into_remainder();
    for (i, xx) in xc.remainder().iter().enumerate() {
        y0r[i] += s0 * xx;
        y1r[i] += s1 * xx;
    }
}

/// 4-accumulator dot product (vectorizable; reassociates the reduction).
#[inline]
fn dot(x: &[f32], y: &[f32]) -> f32 {
    let mut xc = x.chunks_exact(4);
    let mut yc = y.chunks_exact(4);
    let mut acc = [0f32; 4];
    for (xx, yy) in (&mut xc).zip(&mut yc) {
        acc[0] += xx[0] * yy[0];
        acc[1] += xx[1] * yy[1];
        acc[2] += xx[2] * yy[2];
        acc[3] += xx[3] * yy[3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (xx, yy) in xc.remainder().iter().zip(yc.remainder()) {
        s += xx * yy;
    }
    s
}

/// Rows-per-tile for dispatching `n` rows across the shared pool.
fn tile_rows(n: usize) -> usize {
    let t = pool::global().threads().max(1);
    // ~2 tiles per worker for load balance, at least 8 rows per tile
    n.div_ceil(2 * t).max(8)
}

fn should_par(n: usize, macs: usize) -> bool {
    macs >= par_min_macs().max(1) && n >= 2 && pool::global().threads() > 1
}

/// `out[r,:] += a[r,:] @ w` for row-major `a [n,di]`, `w [di,do]`.
/// Bitwise-equal to [`naive::matmul_acc`] for any thread count.
pub fn matmul_acc(a: &[f32], w: &[f32], out: &mut [f32], n: usize, di: usize, dout: usize) {
    assert!(
        a.len() >= n * di && w.len() >= di * dout && out.len() >= n * dout,
        "matmul_acc shape mismatch"
    );
    if force_naive() {
        return naive::matmul_acc(a, w, out, n, di, dout);
    }
    if !should_par(n, n * di * dout) {
        return acc_rows(&a[..n * di], w, &mut out[..n * dout], di, dout);
    }
    let out_ptr = SendPtr(out.as_mut_ptr());
    let out_ptr = &out_ptr;
    pool::global().run_chunks(n, tile_rows(n), move |r0, r1| {
        // SAFETY: row ranges are disjoint across tiles.
        let o =
            unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(r0 * dout), (r1 - r0) * dout) };
        acc_rows(&a[r0 * di..r1 * di], w, o, di, dout);
    });
}

/// Serial row-pair + k-blocked body of [`matmul_acc`]. `a`/`out` are
/// already sliced to the tile's rows.
fn acc_rows(a: &[f32], w: &[f32], out: &mut [f32], di: usize, dout: usize) {
    let n = if di == 0 { 0 } else { a.len() / di };
    let mut r = 0;
    while r + 2 <= n {
        let a0 = &a[r * di..(r + 1) * di];
        let a1 = &a[(r + 1) * di..(r + 2) * di];
        let (o0, o1) = out[r * dout..(r + 2) * dout].split_at_mut(dout);
        let mut k0 = 0;
        while k0 < di {
            let k1 = (k0 + KB).min(di);
            for i in k0..k1 {
                let (v0, v1) = (a0[i], a1[i]);
                let wr = &w[i * dout..(i + 1) * dout];
                if v0 != 0.0 && v1 != 0.0 {
                    axpy2(v0, v1, wr, o0, o1);
                } else if v0 != 0.0 {
                    axpy(v0, wr, o0);
                } else if v1 != 0.0 {
                    axpy(v1, wr, o1);
                }
            }
            k0 = k1;
        }
        r += 2;
    }
    if r < n {
        let ar = &a[r * di..(r + 1) * di];
        let or = &mut out[r * dout..(r + 1) * dout];
        for (i, &av) in ar.iter().enumerate() {
            if av != 0.0 {
                axpy(av, &w[i * dout..(i + 1) * dout], or);
            }
        }
    }
}

/// `gw += a^T g` for `a [n,di]`, `g [n,do]`. Parallel over `gw` rows
/// (the `di` dimension); bitwise-equal to [`naive::matmul_at_b`].
pub fn matmul_at_b(a: &[f32], g: &[f32], gw: &mut [f32], n: usize, di: usize, dout: usize) {
    assert!(
        a.len() >= n * di && g.len() >= n * dout && gw.len() >= di * dout,
        "matmul_at_b shape mismatch"
    );
    if force_naive() {
        return naive::matmul_at_b(a, g, gw, n, di, dout);
    }
    if !should_par(di, n * di * dout) {
        return atb_rows(a, g, &mut gw[..di * dout], 0, n, di, dout);
    }
    let gw_ptr = SendPtr(gw.as_mut_ptr());
    let gw_ptr = &gw_ptr;
    pool::global().run_chunks(di, tile_rows(di), move |i0, i1| {
        // SAFETY: gw row ranges are disjoint across tiles.
        let rows =
            unsafe { std::slice::from_raw_parts_mut(gw_ptr.0.add(i0 * dout), (i1 - i0) * dout) };
        atb_rows(a, g, rows, i0, n, di, dout);
    });
}

/// Body of [`matmul_at_b`] for `gw` rows `i0..i0 + rows.len()/dout`,
/// r-blocked so the streamed `g` block stays cache-resident while every
/// output row in the tile consumes it.
fn atb_rows(a: &[f32], g: &[f32], rows: &mut [f32], i0: usize, n: usize, di: usize, dout: usize) {
    let n_rows = if dout == 0 { 0 } else { rows.len() / dout };
    let mut r0 = 0;
    while r0 < n {
        let r1 = (r0 + RB).min(n);
        for ri in 0..n_rows {
            let i = i0 + ri;
            let row = &mut rows[ri * dout..(ri + 1) * dout];
            for r in r0..r1 {
                let av = a[r * di + i];
                if av != 0.0 {
                    axpy(av, &g[r * dout..(r + 1) * dout], row);
                }
            }
        }
        r0 = r1;
    }
}

/// `out[r,:] += g[r,:] @ w^T` for `g [n,do]`, `w [di,do]`. Parallel over
/// output rows; the 4-accumulator dot reassociates the `do` reduction, so
/// results match [`naive::matmul_b_wt`] to f32 rounding (not bitwise).
pub fn matmul_b_wt(g: &[f32], w: &[f32], out: &mut [f32], n: usize, di: usize, dout: usize) {
    assert!(
        g.len() >= n * dout && w.len() >= di * dout && out.len() >= n * di,
        "matmul_b_wt shape mismatch"
    );
    if force_naive() {
        return naive::matmul_b_wt(g, w, out, n, di, dout);
    }
    if !should_par(n, n * di * dout) {
        return bwt_rows(&g[..n * dout], w, &mut out[..n * di], di, dout);
    }
    let out_ptr = SendPtr(out.as_mut_ptr());
    let out_ptr = &out_ptr;
    pool::global().run_chunks(n, tile_rows(n), move |r0, r1| {
        // SAFETY: row ranges are disjoint across tiles.
        let o = unsafe { std::slice::from_raw_parts_mut(out_ptr.0.add(r0 * di), (r1 - r0) * di) };
        bwt_rows(&g[r0 * dout..r1 * dout], w, o, di, dout);
    });
}

/// Serial body of [`matmul_b_wt`]; `g`/`out` already sliced to the tile.
fn bwt_rows(g: &[f32], w: &[f32], out: &mut [f32], di: usize, dout: usize) {
    let n = if dout == 0 { 0 } else { g.len() / dout };
    for r in 0..n {
        let gr = &g[r * dout..(r + 1) * dout];
        let or = &mut out[r * di..(r + 1) * di];
        for (i, ov) in or.iter_mut().enumerate() {
            *ov += dot(gr, &w[i * dout..(i + 1) * dout]);
        }
    }
}

/// The pre-tiling scalar kernels, kept verbatim from the seed engine as
/// the correctness oracle for property tests and the A/B micro-bench.
pub mod naive {
    /// `out[r,:] += a[r,:] @ w` for row-major `a [n,di]`, `w [di,do]`.
    pub fn matmul_acc(a: &[f32], w: &[f32], out: &mut [f32], n: usize, di: usize, dout: usize) {
        for r in 0..n {
            let ar = &a[r * di..(r + 1) * di];
            let or = &mut out[r * dout..(r + 1) * dout];
            for (i, &av) in ar.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let wr = &w[i * dout..(i + 1) * dout];
                for (o, &wv) in or.iter_mut().zip(wr) {
                    *o += av * wv;
                }
            }
        }
    }

    /// `gw += a^T g` for `a [n,di]`, `g [n,do]`.
    pub fn matmul_at_b(a: &[f32], g: &[f32], gw: &mut [f32], n: usize, di: usize, dout: usize) {
        for r in 0..n {
            let ar = &a[r * di..(r + 1) * di];
            let gr = &g[r * dout..(r + 1) * dout];
            for (i, &av) in ar.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let row = &mut gw[i * dout..(i + 1) * dout];
                for (o, &gv) in row.iter_mut().zip(gr) {
                    *o += av * gv;
                }
            }
        }
    }

    /// `out[r,:] += g[r,:] @ w^T` for `g [n,do]`, `w [di,do]`.
    pub fn matmul_b_wt(g: &[f32], w: &[f32], out: &mut [f32], n: usize, di: usize, dout: usize) {
        for r in 0..n {
            let gr = &g[r * dout..(r + 1) * dout];
            let or = &mut out[r * di..(r + 1) * di];
            for i in 0..di {
                let wr = &w[i * dout..(i + 1) * dout];
                let mut acc = 0f32;
                for (gv, wv) in gr.iter().zip(wr) {
                    acc += gv * wv;
                }
                or[i] += acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    /// One random case: shapes (odd ones included), inputs with planted
    /// zeros (the kernels skip zero scalars), and a nonzero initial `out`
    /// so the `+=` contract is covered.
    #[derive(Debug)]
    struct Case {
        n: usize,
        di: usize,
        dout: usize,
        a: Vec<f32>,
        b: Vec<f32>,
        out0: Vec<f32>,
    }

    fn gen_case(g: &mut crate::util::proptest::Gen<'_>, a_len: fn(&Case) -> usize) -> Case {
        let n = g.int(1, 40);
        let di = g.int(1, 45);
        let dout = g.int(1, 37);
        let mut c = Case {
            n,
            di,
            dout,
            a: Vec::new(),
            b: Vec::new(),
            out0: Vec::new(),
        };
        let mk = |rng: &mut Rng, len: usize| -> Vec<f32> {
            (0..len)
                .map(|_| {
                    if rng.chance(0.15) {
                        0.0
                    } else {
                        rng.normal() as f32
                    }
                })
                .collect()
        };
        c.a = mk(g.rng, n * di.max(dout));
        c.b = mk(g.rng, di * dout);
        c.out0 = mk(g.rng, a_len(&c));
        c
    }

    fn close(x: &[f32], y: &[f32], tol: f32) -> Result<(), String> {
        for (i, (a, b)) in x.iter().zip(y).enumerate() {
            let lim = tol * (1.0 + a.abs().max(b.abs()));
            if (a - b).abs() > lim {
                return Err(format!("elem {i}: {a} vs {b}"));
            }
        }
        Ok(())
    }

    #[test]
    fn tiled_matmul_acc_matches_oracle() {
        check(
            "matmul_acc~oracle",
            80,
            |g| gen_case(g, |c| c.n * c.dout),
            |c| {
                let mut tiled = c.out0.clone();
                let mut ref_out = c.out0.clone();
                matmul_acc(&c.a, &c.b, &mut tiled, c.n, c.di, c.dout);
                naive::matmul_acc(&c.a, &c.b, &mut ref_out, c.n, c.di, c.dout);
                prop_assert!(tiled == ref_out, "acc not bitwise: {:?}", close(&tiled, &ref_out, 0.0));
                Ok(())
            },
        );
    }

    #[test]
    fn tiled_matmul_at_b_matches_oracle() {
        check(
            "matmul_at_b~oracle",
            80,
            |g| gen_case(g, |c| c.di * c.dout),
            |c| {
                let gmat: Vec<f32> = c.a.iter().map(|v| v * 0.5 + 0.1).collect();
                let mut tiled = c.out0.clone();
                let mut ref_out = c.out0.clone();
                matmul_at_b(&c.a, &gmat, &mut tiled, c.n, c.di, c.dout);
                naive::matmul_at_b(&c.a, &gmat, &mut ref_out, c.n, c.di, c.dout);
                prop_assert!(tiled == ref_out, "at_b not bitwise: {:?}", close(&tiled, &ref_out, 0.0));
                Ok(())
            },
        );
    }

    #[test]
    fn tiled_matmul_b_wt_matches_oracle_within_tolerance() {
        check(
            "matmul_b_wt~oracle",
            80,
            |g| gen_case(g, |c| c.n * c.di),
            |c| {
                let mut tiled = c.out0.clone();
                let mut ref_out = c.out0.clone();
                matmul_b_wt(&c.a, &c.b, &mut tiled, c.n, c.di, c.dout);
                naive::matmul_b_wt(&c.a, &c.b, &mut ref_out, c.n, c.di, c.dout);
                if let Err(e) = close(&tiled, &ref_out, 1e-5) {
                    return Err(format!("b_wt drift: {e}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn parallel_path_is_bitwise_equal_to_serial() {
        // Force every call through the pool and compare against the
        // serial tiled path on shapes too small to auto-parallelize.
        let mut rng = Rng::new(0xD15BA7C4, 1);
        for &(n, di, dout) in &[(63usize, 17usize, 9usize), (128, 33, 31), (200, 64, 48)] {
            let a: Vec<f32> = (0..n * di.max(dout)).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..di * dout).map(|_| rng.normal() as f32).collect();

            let run = |f: &dyn Fn(&mut [f32]), len: usize| -> (Vec<f32>, Vec<f32>) {
                let mut serial = vec![0.1f32; len];
                f(&mut serial);
                let old = set_par_min_macs(0);
                let mut par = vec![0.1f32; len];
                f(&mut par);
                set_par_min_macs(old);
                (serial, par)
            };

            let (s, p) = run(&|o| matmul_acc(&a, &b, o, n, di, dout), n * dout);
            assert_eq!(s, p, "acc parallel != serial ({n}x{di}x{dout})");
            let (s, p) = run(&|o| matmul_at_b(&a, &a, o, n, di, dout), di * dout);
            assert_eq!(s, p, "at_b parallel != serial ({n}x{di}x{dout})");
            let (s, p) = run(&|o| matmul_b_wt(&a, &b, o, n, di, dout), n * di);
            assert_eq!(s, p, "b_wt parallel != serial ({n}x{di}x{dout})");
        }
    }

    #[test]
    fn known_small_product() {
        // [1 2; 3 4] @ [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let w = [5.0, 6.0, 7.0, 8.0];
        let mut out = [0f32; 4];
        matmul_acc(&a, &w, &mut out, 2, 2, 2);
        assert_eq!(out, [19.0, 22.0, 43.0, 50.0]);
        // a^T @ w = [26 30; 38 44]
        let mut gw = [0f32; 4];
        matmul_at_b(&a, &w, &mut gw, 2, 2, 2);
        assert_eq!(gw, [26.0, 30.0, 38.0, 44.0]);
        // a @ w^T = [17 23; 39 53]
        let mut bt = [0f32; 4];
        matmul_b_wt(&a, &w, &mut bt, 2, 2, 2);
        assert_eq!(bt, [17.0, 23.0, 39.0, 53.0]);
    }

    #[test]
    fn accumulates_into_existing_output() {
        let a = [1.0f32, 1.0];
        let w = [2.0f32, 3.0];
        let mut out = [10.0f32];
        // 1x2 @ 2x1: 1*2 + 1*3 = 5, += onto 10
        matmul_acc(&a, &w, &mut out, 1, 2, 1);
        assert_eq!(out, [15.0]);
    }
}
