//! `artifacts/manifest.json` model: the AOT contract written by
//! `python/compile/aot.py` and validated here at startup.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::graph::BlockDims;
use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype {other:?}"),
        }
    }

    pub fn size(&self) -> usize {
        4
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: j
                .at("name")
                .as_str()
                .ok_or_else(|| anyhow!("spec missing name"))?
                .to_string(),
            dtype: Dtype::parse(j.at("dtype").as_str().unwrap_or(""))?,
            shape: j
                .at("shape")
                .as_arr()
                .ok_or_else(|| anyhow!("spec missing shape"))?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?,
        })
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kind {
    Train,
    Eval,
    Embed,
}

impl Kind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "train" => Ok(Kind::Train),
            "eval" => Ok(Kind::Eval),
            "embed" => Ok(Kind::Embed),
            other => bail!("unknown entrypoint kind {other:?}"),
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Gc,
    Sage,
}

impl ModelKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "gc" => Ok(ModelKind::Gc),
            "sage" => Ok(ModelKind::Sage),
            other => bail!("unknown model {other:?}"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ModelKind::Gc => "gc",
            ModelKind::Sage => "sage",
        }
    }

    /// Weight matrices per layer (SAGE has self + neigh).
    pub fn mats_per_layer(&self) -> usize {
        match self {
            ModelKind::Gc => 1,
            ModelKind::Sage => 2,
        }
    }
}

/// One AOT entrypoint (an HLO file plus its flat I/O contract).
#[derive(Clone, Debug)]
pub struct Entrypoint {
    pub name: String,
    pub file: PathBuf,
    pub kind: Kind,
    pub model: ModelKind,
    pub geom: ModelGeom,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Static model geometry; mirrors `ModelConfig` in `python/compile/config.py`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelGeom {
    pub model: ModelKind,
    pub layers: usize,
    pub feat: usize,
    pub hidden: usize,
    pub classes: usize,
    pub batch: usize,
    pub fanout: usize,
    pub push_batch: usize,
}

impl ModelGeom {
    pub fn dims(&self) -> BlockDims {
        BlockDims {
            layers: self.layers,
            fanout: self.fanout,
            batch: self.batch,
            feat: self.feat,
            hidden: self.hidden,
            classes: self.classes,
            push_batch: self.push_batch,
        }
    }

    /// Canonical flat parameter shapes (must match Python's param_specs).
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        let mut shapes = Vec::new();
        let mut d_in = self.feat;
        for l in 0..self.layers {
            let d_out = if l == self.layers - 1 {
                self.classes
            } else {
                self.hidden
            };
            for _ in 0..self.model.mats_per_layer() {
                shapes.push(vec![d_in, d_out]);
            }
            shapes.push(vec![d_out]);
            d_in = d_out;
        }
        shapes
    }

    pub fn n_params(&self) -> usize {
        self.param_shapes().len()
    }

    pub fn level_size(&self, d: usize) -> usize {
        self.batch * (self.fanout + 1).pow(d as u32)
    }

    pub fn embed_level_size(&self, d: usize) -> usize {
        self.push_batch * (self.fanout + 1).pow(d as u32)
    }
}

/// Parsed manifest: all entrypoints plus the smoke artifact.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entrypoints: Vec<Entrypoint>,
    pub smoke_file: Option<PathBuf>,
    by_name: HashMap<String, usize>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        if j.at("version").as_usize() != Some(1) {
            bail!("unsupported manifest version");
        }
        let mut entrypoints = Vec::new();
        let mut by_name = HashMap::new();
        for ep in j
            .at("entrypoints")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing entrypoints"))?
        {
            let cfg = ep.at("config");
            let model = ModelKind::parse(ep.at("model").as_str().unwrap_or(""))?;
            let geom = ModelGeom {
                model,
                layers: cfg.at("layers").as_usize().context("layers")?,
                feat: cfg.at("feat").as_usize().context("feat")?,
                hidden: cfg.at("hidden").as_usize().context("hidden")?,
                classes: cfg.at("classes").as_usize().context("classes")?,
                batch: cfg.at("batch").as_usize().context("batch")?,
                fanout: cfg.at("fanout").as_usize().context("fanout")?,
                push_batch: cfg.at("push_batch").as_usize().context("push_batch")?,
            };
            let name = ep
                .at("name")
                .as_str()
                .ok_or_else(|| anyhow!("entrypoint missing name"))?
                .to_string();
            let e = Entrypoint {
                file: dir.join(ep.at("file").as_str().unwrap_or("")),
                kind: Kind::parse(ep.at("kind").as_str().unwrap_or(""))?,
                model,
                geom,
                inputs: ep
                    .at("inputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::parse)
                    .collect::<Result<_>>()?,
                outputs: ep
                    .at("outputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::parse)
                    .collect::<Result<_>>()?,
                name: name.clone(),
            };
            by_name.insert(name, entrypoints.len());
            entrypoints.push(e);
        }
        let smoke_file = j
            .at("smoke")
            .at("file")
            .as_str()
            .map(|f| dir.join(f));
        Ok(Self {
            dir,
            entrypoints,
            smoke_file,
            by_name,
        })
    }

    pub fn get(&self, name: &str) -> Option<&Entrypoint> {
        self.by_name.get(name).map(|&i| &self.entrypoints[i])
    }

    /// Find the entrypoint for a (model, kind, fanout) triple.
    pub fn find(&self, model: ModelKind, kind: Kind, fanout: usize) -> Option<&Entrypoint> {
        self.entrypoints
            .iter()
            .find(|e| e.model == model && e.kind == kind && e.geom.fanout == fanout)
    }

    /// Sanity-check every entrypoint's declared I/O against the geometry
    /// (catches Python/Rust contract drift at startup, not mid-round).
    pub fn validate(&self) -> Result<()> {
        for e in &self.entrypoints {
            let g = &e.geom;
            let expect_inputs = match e.kind {
                Kind::Train => 3 * g.n_params() + 2 + 1 + 2 * g.layers + 2 * (g.layers - 1) + 2,
                Kind::Eval => g.n_params() + 1 + 2 * g.layers + 2 * (g.layers - 1) + 2,
                Kind::Embed => {
                    let depth = g.layers - 1;
                    g.n_params() + 1 + 2 * depth + 2 * (depth - 1)
                }
            };
            if e.inputs.len() != expect_inputs {
                bail!(
                    "{}: expected {} inputs, manifest has {}",
                    e.name,
                    expect_inputs,
                    e.inputs.len()
                );
            }
            // params prefix must match canonical shapes
            for (spec, shape) in e.inputs.iter().zip(g.param_shapes()) {
                if spec.shape != shape {
                    bail!("{}: param {} shape {:?} != {:?}", e.name, spec.name, spec.shape, shape);
                }
            }
            if !e.file.exists() {
                bail!("{}: missing HLO file {}", e.name, e.file.display());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    #[test]
    fn loads_and_validates_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        m.validate().unwrap();
        assert!(m.entrypoints.len() >= 12);
        let e = m.find(ModelKind::Gc, Kind::Train, 5).unwrap();
        assert_eq!(e.geom.layers, 3);
        assert_eq!(e.geom.batch, 32);
        // x input is [s_L, F]
        let x = e.inputs.iter().find(|s| s.name == "x").unwrap();
        assert_eq!(x.shape, vec![32 * 6 * 6 * 6, 32]);
        assert!(m.get(&e.name).is_some());
        assert!(m.smoke_file.is_some());
    }

    #[test]
    fn param_shapes_gc_vs_sage() {
        let mut g = ModelGeom {
            model: ModelKind::Gc,
            layers: 3,
            feat: 32,
            hidden: 32,
            classes: 16,
            batch: 32,
            fanout: 5,
            push_batch: 64,
        };
        assert_eq!(g.n_params(), 6);
        assert_eq!(g.param_shapes()[0], vec![32, 32]);
        assert_eq!(g.param_shapes()[4], vec![32, 16]);
        g.model = ModelKind::Sage;
        assert_eq!(g.n_params(), 9);
        assert_eq!(g.param_shapes()[1], vec![32, 32]);
        assert_eq!(g.param_shapes()[8], vec![16]);
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Manifest::load("/nonexistent/dir").is_err());
    }
}
