//! `GraphFile`: the versioned, checksummed on-disk CSR format
//! (DESIGN.md §13.1).
//!
//! Layout (all integers little-endian, written via the same
//! `to_le_bytes` discipline as `coordinator/codec.rs` — no `unsafe` on
//! the write path):
//!
//! ```text
//! offset   0  magic        8 B   "OPTMGRPH"
//!          8  version      u32   1
//!         12  endian mark  u32   0x0102_0304
//!         16  n            u64   vertex count
//!         24  m            u64   edge count (per direction)
//!         32  feat_dim     u32
//!         36  classes      u32
//!         40  train_count  u64
//!         48  test_count   u64
//!         56  section table: 8 × { offset u64, byte_len u64, fnv1a64 u64 }
//!        248  meta checksum u64  fnv1a64 over bytes [0, 248)
//!        256  sections, each 64-byte aligned, zero-padded between:
//!             out_offsets  (n+1)·u32      out_targets  m·u32
//!             in_offsets   (n+1)·u32      in_targets   m·u32
//!             features     n·feat_dim·f32 labels       n·u16
//!             train        train_count·u32  test       test_count·u32
//! ```
//!
//! 64-byte section alignment makes typed `&[u32]`/`&[f32]`/`&[u16]`
//! views straight into mapped pages sound; offsets stay `u32` to match
//! the in-RAM `Csr` (so the format caps at ~4.29 B edges per direction,
//! plenty above the paper's Papers100M at 1.8 B).
//!
//! The reader never trusts the file: magic / endian / version / header
//! checksum / section bounds are verified before any section is parsed,
//! and every section checksum is verified with a bounded streaming read
//! *before* the mmap backend maps the file (page cache, not process
//! RSS). Both backends then route the assembled graph through
//! [`Graph::validate`]. Corruption fails with a named error, never a
//! panic.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::graph::csr::{Csr, Graph};

use super::mmap::Mmap;
use super::slab::Slab;

pub const MAGIC: [u8; 8] = *b"OPTMGRPH";
pub const VERSION: u32 = 1;
pub const ENDIAN_MARK: u32 = 0x0102_0304;

const HEADER_BYTES: u64 = 56;
const TABLE_BYTES: u64 = 8 * 24;
const META_CHECKSUM_OFF: u64 = HEADER_BYTES + TABLE_BYTES; // 248
const SECTIONS_START: u64 = 256;
const SECTION_ALIGN: u64 = 64;

pub const N_SECTIONS: usize = 8;
pub const SECTION_NAMES: [&str; N_SECTIONS] = [
    "out_offsets",
    "out_targets",
    "in_offsets",
    "in_targets",
    "features",
    "labels",
    "train",
    "test",
];

/// 64-bit FNV-1a, the format's checksum (fast, dependency-free; this
/// guards against corruption and truncation, not adversaries).
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        self.0 = h;
    }

    pub fn digest(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Fnv64 {
        Fnv64::new()
    }
}

/// One section-table entry.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Section {
    pub offset: u64,
    pub byte_len: u64,
    pub checksum: u64,
}

/// Parsed, bounds-checked header of a `GraphFile`.
#[derive(Clone, Debug)]
pub struct GraphFileInfo {
    pub version: u32,
    pub n: usize,
    pub m: usize,
    pub feat_dim: usize,
    pub classes: usize,
    pub train_count: usize,
    pub test_count: usize,
    pub file_len: u64,
    pub sections: [Section; N_SECTIONS],
}

impl GraphFileInfo {
    /// Element count of section `idx`, derived from the header counts.
    pub fn elems(&self, idx: usize) -> usize {
        match idx {
            0 | 2 => self.n + 1,
            1 | 3 => self.m,
            4 => self.n * self.feat_dim,
            5 => self.n,
            6 => self.train_count,
            7 => self.test_count,
            _ => unreachable!("section index {idx}"),
        }
    }

    fn elem_size(idx: usize) -> u64 {
        if idx == 5 {
            2
        } else {
            4
        }
    }
}

fn align_up(v: u64) -> u64 {
    v.div_ceil(SECTION_ALIGN) * SECTION_ALIGN
}

// ---------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------

/// Parse and fully bounds-check the header + section table. Every
/// failure is a named error (magic / endian / version / checksum /
/// section bounds); nothing here reads section payloads.
pub fn read_info(path: &Path) -> Result<GraphFileInfo> {
    let mut file =
        File::open(path).with_context(|| format!("open GraphFile {}", path.display()))?;
    let file_len = file
        .metadata()
        .with_context(|| format!("stat {}", path.display()))?
        .len();
    if file_len < SECTIONS_START {
        bail!(
            "GraphFile {}: truncated header ({file_len} bytes, need {SECTIONS_START})",
            path.display()
        );
    }
    let mut head = [0u8; SECTIONS_START as usize];
    file.read_exact(&mut head)
        .with_context(|| format!("read GraphFile header {}", path.display()))?;

    let get_u32 = |off: usize| u32::from_le_bytes(head[off..off + 4].try_into().expect("4 bytes"));
    let get_u64 = |off: usize| u64::from_le_bytes(head[off..off + 8].try_into().expect("8 bytes"));

    if head[..8] != MAGIC {
        bail!(
            "GraphFile {}: bad magic {:02x?} (expected {:02x?})",
            path.display(),
            &head[..8],
            MAGIC
        );
    }
    let version = get_u32(8);
    let endian = get_u32(12);
    if endian != ENDIAN_MARK {
        bail!(
            "GraphFile {}: endian marker {endian:#010x} does not match {ENDIAN_MARK:#010x} \
             (file written on a different-endian host?)",
            path.display()
        );
    }
    if version != VERSION {
        bail!(
            "GraphFile {}: unsupported version {version} (this build reads version {VERSION})",
            path.display()
        );
    }
    let mut meta = Fnv64::new();
    meta.update(&head[..META_CHECKSUM_OFF as usize]);
    let stored_meta = get_u64(META_CHECKSUM_OFF as usize);
    if meta.digest() != stored_meta {
        bail!(
            "GraphFile {}: header checksum mismatch (stored {stored_meta:#018x}, \
             computed {:#018x})",
            path.display(),
            meta.digest()
        );
    }

    let n = get_u64(16);
    let m = get_u64(24);
    let feat_dim = get_u32(32) as u64;
    let classes = get_u32(36) as u64;
    let train_count = get_u64(40);
    let test_count = get_u64(48);
    ensure!(
        n <= u32::MAX as u64 && m <= u32::MAX as u64,
        "GraphFile {}: n={n} / m={m} exceed the u32 offset format",
        path.display()
    );
    let feats = n.checked_mul(feat_dim).with_context(|| {
        format!("GraphFile {}: feature section size overflows", path.display())
    })?;
    ensure!(
        feats <= usize::MAX as u64 / 8,
        "GraphFile {}: feature section ({feats} values) exceeds addressable memory",
        path.display()
    );
    ensure!(
        train_count <= n && test_count <= n,
        "GraphFile {}: split counts ({train_count}/{test_count}) exceed n={n}",
        path.display()
    );

    let mut info = GraphFileInfo {
        version,
        n: n as usize,
        m: m as usize,
        feat_dim: feat_dim as usize,
        classes: classes as usize,
        train_count: train_count as usize,
        test_count: test_count as usize,
        file_len,
        sections: [Section::default(); N_SECTIONS],
    };

    let mut expected_off = SECTIONS_START;
    for idx in 0..N_SECTIONS {
        let base = HEADER_BYTES as usize + idx * 24;
        let sec = Section {
            offset: get_u64(base),
            byte_len: get_u64(base + 8),
            checksum: get_u64(base + 16),
        };
        let expect_len = info.elems(idx) as u64 * GraphFileInfo::elem_size(idx);
        if sec.byte_len != expect_len {
            bail!(
                "GraphFile {}: section {} length {} disagrees with header geometry \
                 (expected {expect_len})",
                path.display(),
                SECTION_NAMES[idx],
                sec.byte_len
            );
        }
        if sec.offset != expected_off {
            bail!(
                "GraphFile {}: section {} offset {} out of place (expected {expected_off})",
                path.display(),
                SECTION_NAMES[idx],
                sec.offset
            );
        }
        let end = sec
            .offset
            .checked_add(sec.byte_len)
            .with_context(|| format!("section {} end overflows", SECTION_NAMES[idx]))?;
        if end > file_len {
            bail!(
                "GraphFile {}: section {} bounds [{}, {end}) exceed file length {file_len} \
                 (truncated?)",
                path.display(),
                SECTION_NAMES[idx],
                sec.offset
            );
        }
        expected_off = if idx + 1 == N_SECTIONS {
            end
        } else {
            align_up(end)
        };
        info.sections[idx] = sec;
    }
    if expected_off != file_len {
        bail!(
            "GraphFile {}: file length {file_len} disagrees with section table end \
             {expected_off} (truncated or trailing bytes)",
            path.display()
        );
    }
    Ok(info)
}

/// Verify every section checksum with a bounded streaming read (a 1 MiB
/// scratch buffer — file bytes pass through the page cache, not the
/// process heap, so this is safe to run on files far larger than RAM).
pub fn verify_checksums(path: &Path, info: &GraphFileInfo) -> Result<()> {
    let mut file =
        File::open(path).with_context(|| format!("open GraphFile {}", path.display()))?;
    let mut buf = vec![0u8; 1 << 20];
    for (idx, sec) in info.sections.iter().enumerate() {
        file.seek(SeekFrom::Start(sec.offset))
            .with_context(|| format!("seek to section {}", SECTION_NAMES[idx]))?;
        let mut fnv = Fnv64::new();
        let mut left = sec.byte_len;
        while left > 0 {
            let take = left.min(buf.len() as u64) as usize;
            file.read_exact(&mut buf[..take])
                .with_context(|| format!("read section {}", SECTION_NAMES[idx]))?;
            fnv.update(&buf[..take]);
            left -= take as u64;
        }
        if fnv.digest() != sec.checksum {
            bail!(
                "GraphFile {}: checksum mismatch in section {} (stored {:#018x}, \
                 computed {:#018x})",
                path.display(),
                SECTION_NAMES[idx],
                sec.checksum,
                fnv.digest()
            );
        }
    }
    Ok(())
}

/// Chunked LE readers for section payloads. Lengths are pre-validated
/// against the file size by [`read_info`], so no `MAX_WIRE_ELEMS`-style
/// cap applies (sections legitimately exceed the wire ceiling).
fn read_u32_vec(r: &mut impl Read, n: usize) -> Result<Vec<u32>> {
    let mut out = Vec::with_capacity(n);
    let mut buf = [0u8; 4096];
    let mut left = n;
    while left > 0 {
        let take = left.min(1024);
        let bytes = &mut buf[..take * 4];
        r.read_exact(bytes).context("read u32 section")?;
        out.extend(
            bytes
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes(b.try_into().expect("4-byte chunk"))),
        );
        left -= take;
    }
    Ok(out)
}

fn read_f32_vec(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(n);
    let mut buf = [0u8; 4096];
    let mut left = n;
    while left > 0 {
        let take = left.min(1024);
        let bytes = &mut buf[..take * 4];
        r.read_exact(bytes).context("read f32 section")?;
        out.extend(
            bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().expect("4-byte chunk"))),
        );
        left -= take;
    }
    Ok(out)
}

fn read_u16_vec(r: &mut impl Read, n: usize) -> Result<Vec<u16>> {
    let mut out = Vec::with_capacity(n);
    let mut buf = [0u8; 4096];
    let mut left = n;
    while left > 0 {
        let take = left.min(2048);
        let bytes = &mut buf[..take * 2];
        r.read_exact(bytes).context("read u16 section")?;
        out.extend(
            bytes
                .chunks_exact(2)
                .map(|b| u16::from_le_bytes(b.try_into().expect("2-byte chunk"))),
        );
        left -= take;
    }
    Ok(out)
}

fn seek_reader(file: &mut File, off: u64) -> Result<BufReader<&mut File>> {
    file.seek(SeekFrom::Start(off)).context("seek to section")?;
    Ok(BufReader::with_capacity(1 << 20, file))
}

/// Load every section eagerly into heap `Vec`s (the `ram` backend).
/// Decodes via `from_le_bytes`, so this path works on any endianness.
pub fn load_ram(path: &Path, info: &GraphFileInfo) -> Result<Graph> {
    let mut file =
        File::open(path).with_context(|| format!("open GraphFile {}", path.display()))?;
    let mut sec_u32 = |file: &mut File, idx: usize| -> Result<Vec<u32>> {
        let off = info.sections[idx].offset;
        read_u32_vec(&mut seek_reader(file, off)?, info.elems(idx))
            .with_context(|| format!("section {}", SECTION_NAMES[idx]))
    };
    let out_offsets = sec_u32(&mut file, 0)?;
    let out_targets = sec_u32(&mut file, 1)?;
    let in_offsets = sec_u32(&mut file, 2)?;
    let in_targets = sec_u32(&mut file, 3)?;
    let features = read_f32_vec(
        &mut seek_reader(&mut file, info.sections[4].offset)?,
        info.elems(4),
    )
    .context("section features")?;
    let labels = read_u16_vec(
        &mut seek_reader(&mut file, info.sections[5].offset)?,
        info.elems(5),
    )
    .context("section labels")?;
    let train_nodes = sec_u32(&mut file, 6)?;
    let test_nodes = sec_u32(&mut file, 7)?;
    Ok(Graph {
        n: info.n,
        out: Csr {
            offsets: out_offsets.into(),
            targets: out_targets.into(),
        },
        inc: Csr {
            offsets: in_offsets.into(),
            targets: in_targets.into(),
        },
        feat_dim: info.feat_dim,
        classes: info.classes,
        features: features.into(),
        labels: labels.into(),
        train_nodes,
        test_nodes,
    })
}

/// Map the file and serve bulk sections straight from mapped pages (the
/// `mmap` backend). Requires a little-endian host — the typed views are
/// the on-disk bytes. Splits stay eager `Vec`s (small, and consumers
/// shuffle them).
pub fn load_mmap(path: &Path, info: &GraphFileInfo) -> Result<Graph> {
    if !cfg!(target_endian = "little") {
        bail!(
            "GraphFile {}: the mmap backend serves raw little-endian pages and this host is \
             big-endian; use OPTIMES_GRAPH_BACKEND=ram (which byte-swaps on read)",
            path.display()
        );
    }
    let map = Mmap::open(path)?;
    ensure!(
        map.len() as u64 == info.file_len,
        "GraphFile {}: file changed size during open",
        path.display()
    );
    let seg_u32 = |idx: usize| -> Result<Slab<u32>> {
        let sec = &info.sections[idx];
        Ok(Slab::Mapped(map.segment::<u32>(
            sec.offset as usize,
            info.elems(idx),
        )?))
    };
    let out = Csr {
        offsets: seg_u32(0)?,
        targets: seg_u32(1)?,
    };
    let inc = Csr {
        offsets: seg_u32(2)?,
        targets: seg_u32(3)?,
    };
    let features =
        Slab::Mapped(map.segment::<f32>(info.sections[4].offset as usize, info.elems(4))?);
    let labels =
        Slab::Mapped(map.segment::<u16>(info.sections[5].offset as usize, info.elems(5))?);
    let train_nodes = map
        .segment::<u32>(info.sections[6].offset as usize, info.elems(6))?
        .as_slice()
        .to_vec();
    let test_nodes = map
        .segment::<u32>(info.sections[7].offset as usize, info.elems(7))?
        .as_slice()
        .to_vec();
    Ok(Graph {
        n: info.n,
        out,
        inc,
        feat_dim: info.feat_dim,
        classes: info.classes,
        features,
        labels,
        train_nodes,
        test_nodes,
    })
}

// ---------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------

/// Streaming `GraphFile` writer: sections are written strictly in file
/// order, each fed incrementally (so multi-GB sections never exist in
/// RAM), checksummed on the fly; `finish` seeks back and stamps the
/// header + section table.
pub struct GraphFileWriter {
    w: BufWriter<File>,
    pos: u64,
    n: u64,
    m: u64,
    feat_dim: u32,
    classes: u32,
    train_count: u64,
    test_count: u64,
    sections: [Section; N_SECTIONS],
    cur: usize,
    fnv: Fnv64,
    written: u64,
}

impl GraphFileWriter {
    pub fn create(
        path: &Path,
        n: usize,
        m: usize,
        feat_dim: usize,
        classes: usize,
        train_count: usize,
        test_count: usize,
    ) -> Result<GraphFileWriter> {
        ensure!(
            n <= u32::MAX as usize && m <= u32::MAX as usize,
            "graph too large for the u32 offset format (n={n}, m={m})"
        );
        let file =
            File::create(path).with_context(|| format!("create GraphFile {}", path.display()))?;
        let mut w = BufWriter::with_capacity(1 << 20, file);
        w.write_all(&[0u8; SECTIONS_START as usize])
            .context("reserve GraphFile header")?;
        Ok(GraphFileWriter {
            w,
            pos: SECTIONS_START,
            n: n as u64,
            m: m as u64,
            feat_dim: feat_dim as u32,
            classes: classes as u32,
            train_count: train_count as u64,
            test_count: test_count as u64,
            sections: [Section::default(); N_SECTIONS],
            cur: 0,
            fnv: Fnv64::new(),
            written: 0,
        })
    }

    fn info_counts(&self) -> GraphFileInfo {
        GraphFileInfo {
            version: VERSION,
            n: self.n as usize,
            m: self.m as usize,
            feat_dim: self.feat_dim as usize,
            classes: self.classes as usize,
            train_count: self.train_count as usize,
            test_count: self.test_count as usize,
            file_len: 0,
            sections: self.sections,
        }
    }

    fn expected_len(&self, idx: usize) -> u64 {
        self.info_counts().elems(idx) as u64 * GraphFileInfo::elem_size(idx)
    }

    /// Begin section `idx`; sections must be begun in order 0..8.
    pub fn begin_section(&mut self, idx: usize) -> Result<()> {
        ensure!(
            idx == self.cur && idx < N_SECTIONS,
            "GraphFile writer: begin_section({idx}) out of order (expected {})",
            self.cur
        );
        let aligned = align_up(self.pos);
        if aligned > self.pos {
            let pad = [0u8; SECTION_ALIGN as usize];
            self.w
                .write_all(&pad[..(aligned - self.pos) as usize])
                .context("write section padding")?;
            self.pos = aligned;
        }
        self.sections[idx].offset = self.pos;
        self.fnv = Fnv64::new();
        self.written = 0;
        Ok(())
    }

    fn raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.w.write_all(bytes).context("write section payload")?;
        self.fnv.update(bytes);
        self.pos += bytes.len() as u64;
        self.written += bytes.len() as u64;
        Ok(())
    }

    pub fn put_u32s(&mut self, data: &[u32]) -> Result<()> {
        let mut buf = [0u8; 4096];
        for chunk in data.chunks(1024) {
            let bytes = &mut buf[..chunk.len() * 4];
            for (b, v) in bytes.chunks_exact_mut(4).zip(chunk) {
                b.copy_from_slice(&v.to_le_bytes());
            }
            self.raw(bytes)?;
        }
        Ok(())
    }

    pub fn put_f32s(&mut self, data: &[f32]) -> Result<()> {
        let mut buf = [0u8; 4096];
        for chunk in data.chunks(1024) {
            let bytes = &mut buf[..chunk.len() * 4];
            for (b, v) in bytes.chunks_exact_mut(4).zip(chunk) {
                b.copy_from_slice(&v.to_le_bytes());
            }
            self.raw(bytes)?;
        }
        Ok(())
    }

    pub fn put_u16s(&mut self, data: &[u16]) -> Result<()> {
        let mut buf = [0u8; 4096];
        for chunk in data.chunks(2048) {
            let bytes = &mut buf[..chunk.len() * 2];
            for (b, v) in bytes.chunks_exact_mut(2).zip(chunk) {
                b.copy_from_slice(&v.to_le_bytes());
            }
            self.raw(bytes)?;
        }
        Ok(())
    }

    /// Close the current section, checking its fed length against the
    /// header geometry.
    pub fn end_section(&mut self) -> Result<()> {
        ensure!(self.cur < N_SECTIONS, "GraphFile writer: no open section");
        let expect = self.expected_len(self.cur);
        ensure!(
            self.written == expect,
            "GraphFile writer: section {} got {} bytes, geometry says {expect}",
            SECTION_NAMES[self.cur],
            self.written
        );
        self.sections[self.cur].byte_len = self.written;
        self.sections[self.cur].checksum = self.fnv.digest();
        self.cur += 1;
        Ok(())
    }

    /// Convenience: a whole section from one slice.
    pub fn section_u32s(&mut self, idx: usize, data: &[u32]) -> Result<()> {
        self.begin_section(idx)?;
        self.put_u32s(data)?;
        self.end_section()
    }

    /// Stamp the header + section table and flush. Returns the parsed
    /// info (as a reader would see it).
    pub fn finish(mut self) -> Result<GraphFileInfo> {
        ensure!(
            self.cur == N_SECTIONS,
            "GraphFile writer: finish() with only {} of {N_SECTIONS} sections written",
            self.cur
        );
        let file_len = self.pos;
        let mut head = [0u8; SECTIONS_START as usize];
        head[..8].copy_from_slice(&MAGIC);
        head[8..12].copy_from_slice(&VERSION.to_le_bytes());
        head[12..16].copy_from_slice(&ENDIAN_MARK.to_le_bytes());
        head[16..24].copy_from_slice(&self.n.to_le_bytes());
        head[24..32].copy_from_slice(&self.m.to_le_bytes());
        head[32..36].copy_from_slice(&self.feat_dim.to_le_bytes());
        head[36..40].copy_from_slice(&self.classes.to_le_bytes());
        head[40..48].copy_from_slice(&self.train_count.to_le_bytes());
        head[48..56].copy_from_slice(&self.test_count.to_le_bytes());
        for (idx, sec) in self.sections.iter().enumerate() {
            let base = HEADER_BYTES as usize + idx * 24;
            head[base..base + 8].copy_from_slice(&sec.offset.to_le_bytes());
            head[base + 8..base + 16].copy_from_slice(&sec.byte_len.to_le_bytes());
            head[base + 16..base + 24].copy_from_slice(&sec.checksum.to_le_bytes());
        }
        let mut meta = Fnv64::new();
        meta.update(&head[..META_CHECKSUM_OFF as usize]);
        head[META_CHECKSUM_OFF as usize..].copy_from_slice(&meta.digest().to_le_bytes());

        self.w.flush().context("flush GraphFile sections")?;
        let file = self.w.get_mut();
        file.seek(SeekFrom::Start(0)).context("seek to header")?;
        file.write_all(&head).context("write GraphFile header")?;
        file.flush().context("flush GraphFile header")?;

        let mut info = self.info_counts();
        info.file_len = file_len;
        Ok(info)
    }
}

/// Serialize an in-RAM [`Graph`] to `path` in one pass.
pub fn write_graph_file(path: &Path, g: &Graph) -> Result<GraphFileInfo> {
    let mut w = GraphFileWriter::create(
        path,
        g.n,
        g.out.m(),
        g.feat_dim,
        g.classes,
        g.train_nodes.len(),
        g.test_nodes.len(),
    )?;
    ensure!(
        g.out.m() == g.inc.m(),
        "graph edge directions disagree ({} vs {})",
        g.out.m(),
        g.inc.m()
    );
    w.section_u32s(0, &g.out.offsets)?;
    w.section_u32s(1, &g.out.targets)?;
    w.section_u32s(2, &g.inc.offsets)?;
    w.section_u32s(3, &g.inc.targets)?;
    w.begin_section(4)?;
    w.put_f32s(&g.features)?;
    w.end_section()?;
    w.begin_section(5)?;
    w.put_u16s(&g.labels)?;
    w.end_section()?;
    w.section_u32s(6, &g.train_nodes)?;
    w.section_u32s(7, &g.test_nodes)?;
    w.finish()
}

/// Open a `GraphFile` with the requested backend: full header + checksum
/// verification, then `Graph::validate` on the assembled graph (both
/// backends — the satellite contract).
pub fn load_graph_file(path: &Path, backend: super::GraphBackend) -> Result<Graph> {
    let info = read_info(path)?;
    verify_checksums(path, &info)?;
    let g = match backend {
        super::GraphBackend::Ram => load_ram(path, &info)?,
        super::GraphBackend::Mmap => load_mmap(path, &info)?,
    };
    g.validate()
        .map_err(|e| anyhow::anyhow!("GraphFile {}: invalid graph: {e}", path.display()))?;
    Ok(g)
}

// ---------------------------------------------------------------------
// External-memory edge scatter (for the streaming generator)
// ---------------------------------------------------------------------

/// Scatters a stream of `(dst, src)` pairs into CSR target order without
/// holding the edge list in RAM: pairs are staged per destination-range
/// bucket, spilled to unlinked temp files, and each bucket is finalized
/// with an in-RAM counting sort over a contiguous ~`target_bytes` slice
/// of the targets section. Per-destination arrival order is preserved
/// (spill append order), so the result is bit-identical to
/// `Csr::from_edges` fed the same pair sequence.
pub struct EdgeScatter {
    offsets: Vec<u32>,
    bounds: Vec<u32>,
    staging: Vec<Vec<(u32, u32)>>,
    spill: Vec<Option<File>>,
    flush_at: usize,
}

impl EdgeScatter {
    /// `offsets`: the (n+1) CSR offsets of the destination direction.
    /// `target_bytes`: soft cap on per-bucket finalize RAM.
    pub fn new(offsets: Vec<u32>, target_bytes: usize) -> EdgeScatter {
        let n = offsets.len().saturating_sub(1);
        let per_bucket = (target_bytes / 4).max(1) as u64;
        let mut bounds = vec![0u32];
        let mut start_edges = 0u64;
        for v in 0..n {
            let upto = offsets[v + 1] as u64;
            if upto - start_edges > per_bucket && u64::from(offsets[v]) > start_edges {
                bounds.push(v as u32);
                start_edges = offsets[v] as u64;
            }
        }
        bounds.push(n as u32);
        let buckets = bounds.len() - 1;
        EdgeScatter {
            offsets,
            bounds,
            staging: vec![Vec::new(); buckets],
            spill: (0..buckets).map(|_| None).collect(),
            flush_at: 64 * 1024,
        }
    }

    fn bucket_of(&self, dst: u32) -> usize {
        // bounds[b] <= dst < bounds[b+1]
        self.bounds.partition_point(|&b| b <= dst) - 1
    }

    pub fn push(&mut self, dst: u32, src: u32) -> Result<()> {
        let b = self.bucket_of(dst);
        self.staging[b].push((dst, src));
        if self.staging[b].len() >= self.flush_at {
            self.flush_bucket(b)?;
        }
        Ok(())
    }

    fn flush_bucket(&mut self, b: usize) -> Result<()> {
        if self.staging[b].is_empty() {
            return Ok(());
        }
        let mut pairs = std::mem::take(&mut self.staging[b]);
        if self.spill[b].is_none() {
            self.spill[b] = Some(super::mmap::anon_temp_file("scatter")?);
        }
        let file = self.spill[b].as_mut().expect("spill file just ensured");
        let mut w = BufWriter::with_capacity(1 << 16, file);
        for &(d, s) in &pairs {
            w.write_all(&d.to_le_bytes()).context("spill scatter pair")?;
            w.write_all(&s.to_le_bytes()).context("spill scatter pair")?;
        }
        w.flush().context("flush scatter spill")?;
        drop(w);
        // Hand the (now empty) buffer back so its capacity is reused.
        pairs.clear();
        self.staging[b] = pairs;
        Ok(())
    }

    /// Finalize bucket-by-bucket in destination order, invoking `sink`
    /// with each contiguous, CSR-ordered targets chunk exactly once.
    pub fn finalize(mut self, sink: &mut dyn FnMut(&[u32]) -> Result<()>) -> Result<()> {
        for b in 0..self.bounds.len() - 1 {
            self.flush_bucket(b)?;
            let lo = self.bounds[b] as usize;
            let hi = self.bounds[b + 1] as usize;
            let base = self.offsets[lo];
            let len = (self.offsets[hi] - base) as usize;
            let mut chunk = vec![0u32; len];
            let mut cursor: Vec<u32> = self.offsets[lo..hi].to_vec();
            if let Some(mut file) = self.spill[b].take() {
                // Rewind: the handle's position is at the end after writes.
                file.seek(SeekFrom::Start(0))
                    .context("rewind scatter spill")?;
                let mut r = BufReader::with_capacity(1 << 16, file);
                let mut pair = [0u8; 8];
                loop {
                    match r.read_exact(&mut pair) {
                        Ok(()) => {}
                        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
                        Err(e) => return Err(e).context("read scatter spill"),
                    }
                    let d = u32::from_le_bytes(pair[..4].try_into().expect("4 bytes"));
                    let s = u32::from_le_bytes(pair[4..].try_into().expect("4 bytes"));
                    let c = &mut cursor[d as usize - lo];
                    chunk[(*c - base) as usize] = s;
                    *c += 1;
                }
            }
            sink(&chunk)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{generate, GenParams};
    use crate::storage::GraphBackend;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("optimes-fmt-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_ram_and_mmap_bit_exact() {
        let g = generate(&GenParams {
            n: 300,
            ..GenParams::default()
        });
        let path = tmp("roundtrip.graph");
        let info = write_graph_file(&path, &g).unwrap();
        assert_eq!(info.n, 300);
        for backend in [GraphBackend::Ram, GraphBackend::Mmap] {
            let h = load_graph_file(&path, backend).unwrap();
            assert_eq!(g.out.offsets, h.out.offsets);
            assert_eq!(g.out.targets, h.out.targets);
            assert_eq!(g.inc.offsets, h.inc.offsets);
            assert_eq!(g.inc.targets, h.inc.targets);
            assert_eq!(g.features, h.features);
            assert_eq!(g.labels, h.labels);
            assert_eq!(g.train_nodes, h.train_nodes);
            assert_eq!(g.test_nodes, h.test_nodes);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn edge_scatter_matches_from_edges() {
        let g = generate(&GenParams {
            n: 200,
            ..GenParams::default()
        });
        // Rebuild the inc targets from the out edge stream with a tiny
        // bucket budget to force multi-bucket spills.
        let mut scatter = EdgeScatter::new(g.inc.offsets.to_vec(), 256);
        for v in 0..g.n as u32 {
            for &t in g.out.neighbors(v) {
                scatter.push(t, v).unwrap();
            }
        }
        let mut rebuilt: Vec<u32> = Vec::new();
        scatter
            .finalize(&mut |chunk| {
                rebuilt.extend_from_slice(chunk);
                Ok(())
            })
            .unwrap();
        assert_eq!(&rebuilt[..], &g.inc.targets[..]);
    }

    #[test]
    fn writer_rejects_geometry_mismatch() {
        let path = tmp("badgeom.graph");
        let mut w = GraphFileWriter::create(&path, 3, 2, 1, 1, 0, 0).unwrap();
        // out_offsets needs 4 entries; feed 3.
        w.begin_section(0).unwrap();
        w.put_u32s(&[0, 1, 2]).unwrap();
        assert!(w.end_section().is_err());
        let _ = std::fs::remove_file(&path);
    }
}
