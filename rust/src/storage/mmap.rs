//! Minimal mmap wrapper — the only `unsafe` in the storage plane.
//!
//! The offline build vendors no `libc`/`memmap2`, so on unix we declare
//! the two syscall wrappers we need (`mmap`/`munmap`) directly; they are
//! libc symbols that std already links. Everywhere else (`cfg(not(unix))`)
//! the "mapping" is a heap buffer read from the file, which keeps the API
//! total at the cost of residency — the portability note lives in
//! DESIGN.md §13.
//!
//! Two capabilities are exposed:
//!
//! * [`Mmap`]: a read-only, `MAP_SHARED` mapping of a whole file, with
//!   typed [`Segment`] views over 64-byte-aligned sections. Pages fault
//!   in lazily, so opening a multi-GB `GraphFile` costs near-zero RSS
//!   until neighborhoods are actually touched.
//! * [`MmapMut`]: a growable read-write mapping over an (unlinked) temp
//!   file, used by the snapshot shadow slab so dormant embedding copies
//!   live in the page cache instead of the heap.

use std::fs::File;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

#[cfg(unix)]
mod sys {
    use core::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const PROT_WRITE: i32 = 2;
    pub const MAP_SHARED: i32 = 1;

    pub fn map_failed() -> *mut c_void {
        usize::MAX as *mut c_void
    }

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

/// The bytes behind a read-only mapping: a real kernel mapping on unix,
/// a heap buffer elsewhere. Dropping the last `Arc` unmaps/frees.
enum Region {
    #[cfg(unix)]
    Mapped { ptr: *mut u8, len: usize },
    // u64-backed so typed segment views stay aligned on the fallback
    // path (a Vec<u8> only guarantees 1-byte alignment).
    Heap { buf: Vec<u64>, len: usize },
}

// SAFETY: the mapped region is read-only for its entire lifetime (mapped
// PROT_READ) and the pointer is never handed out mutably, so shared
// access from multiple threads is sound.
unsafe impl Send for Region {}
unsafe impl Sync for Region {}

impl Region {
    fn as_slice(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            // SAFETY: `ptr` is a live PROT_READ mapping of exactly `len`
            // bytes (or dangling-aligned when len == 0), owned by `self`.
            Region::Mapped { ptr, len } => unsafe {
                if *len == 0 {
                    &[]
                } else {
                    std::slice::from_raw_parts(*ptr, *len)
                }
            },
            // SAFETY: viewing `len` bytes of a u64 buffer holding at
            // least that many (alignment only ever loosens, 8 → 1).
            Region::Heap { buf, len } => unsafe {
                std::slice::from_raw_parts(buf.as_ptr().cast::<u8>(), *len)
            },
        }
    }
}

impl Drop for Region {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Region::Mapped { ptr, len } = self {
            if *len > 0 {
                // SAFETY: `ptr`/`len` came from a successful mmap and are
                // unmapped exactly once, here.
                unsafe { sys::munmap(ptr.cast(), *len) };
            }
        }
    }
}

/// A read-only mapping of an entire file.
#[derive(Clone)]
pub struct Mmap {
    region: Arc<Region>,
}

impl Mmap {
    /// Map `path` read-only. On non-unix targets this reads the file into
    /// a heap buffer instead (same API, eager residency).
    pub fn open(path: &Path) -> Result<Mmap> {
        let mut file =
            File::open(path).with_context(|| format!("open {} for mapping", path.display()))?;
        let len = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len();
        if len > usize::MAX as u64 {
            bail!("{} is too large to map on this platform", path.display());
        }
        Self::from_file(&mut file, len as usize, path)
    }

    #[cfg(unix)]
    fn from_file(file: &mut File, len: usize, path: &Path) -> Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            return Ok(Mmap {
                region: Arc::new(Region::Heap {
                    buf: Vec::new(),
                    len: 0,
                }),
            });
        }
        // SAFETY: fd is open for reading; a PROT_READ MAP_SHARED mapping
        // of `len` bytes at offset 0 is valid for any regular file of at
        // least that length. Failure is reported as MAP_FAILED, checked
        // below.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::map_failed() {
            bail!("mmap of {} ({} bytes) failed", path.display(), len);
        }
        Ok(Mmap {
            region: Arc::new(Region::Mapped {
                ptr: ptr.cast(),
                len,
            }),
        })
    }

    #[cfg(not(unix))]
    fn from_file(file: &mut File, len: usize, path: &Path) -> Result<Mmap> {
        use std::io::Read;
        let mut buf = vec![0u64; len.div_ceil(8)];
        // SAFETY: filling `len` bytes of a zeroed u64 buffer that holds
        // at least that many.
        let bytes = unsafe { std::slice::from_raw_parts_mut(buf.as_mut_ptr().cast::<u8>(), len) };
        file.read_exact(bytes)
            .with_context(|| format!("read {} (mmap fallback)", path.display()))?;
        Ok(Mmap {
            region: Arc::new(Region::Heap { buf, len }),
        })
    }

    pub fn len(&self) -> usize {
        self.region.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        self.region.as_slice()
    }

    /// A typed view of `count` elements of `T` starting at `byte_off`.
    /// Fails (never panics) if the range is out of bounds or misaligned
    /// for `T` — the `GraphFile` writer 64-byte-aligns every section
    /// precisely so these views are sound.
    pub fn segment<T: Pod>(&self, byte_off: usize, count: usize) -> Result<Segment<T>> {
        let elem = std::mem::size_of::<T>();
        let byte_len = count.checked_mul(elem).context("segment size overflows")?;
        let end = byte_off
            .checked_add(byte_len)
            .context("segment end overflows")?;
        if end > self.len() {
            bail!(
                "segment [{byte_off}, {end}) out of bounds for {}-byte mapping",
                self.len()
            );
        }
        let base = self.region.as_slice().as_ptr() as usize;
        if (base + byte_off) % std::mem::align_of::<T>() != 0 {
            bail!("segment at byte offset {byte_off} is misaligned for element size {elem}");
        }
        Ok(Segment {
            region: Arc::clone(&self.region),
            byte_off,
            count,
            _marker: std::marker::PhantomData,
        })
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mmap").field("len", &self.len()).finish()
    }
}

/// Marker for element types that are plain bytes in file order: any bit
/// pattern is a valid value and the on-disk little-endian layout matches
/// the in-memory layout on LE hosts (the format reader enforces the LE
/// host check before handing out segments).
///
/// Sealed: only the primitives the `GraphFile` sections use.
pub trait Pod: Copy + sealed::Sealed + 'static {}

mod sealed {
    pub trait Sealed {}
    impl Sealed for u8 {}
    impl Sealed for u16 {}
    impl Sealed for u32 {}
    impl Sealed for f32 {}
}

impl Pod for u8 {}
impl Pod for u16 {}
impl Pod for u32 {}
impl Pod for f32 {}

/// A typed, bounds-checked window into an [`Mmap`]. Cloning is cheap
/// (bumps the region's refcount); the region outlives every segment.
#[derive(Clone)]
pub struct Segment<T: Pod> {
    region: Arc<Region>,
    byte_off: usize,
    count: usize,
    _marker: std::marker::PhantomData<T>,
}

impl<T: Pod> Segment<T> {
    pub fn as_slice(&self) -> &[T] {
        if self.count == 0 {
            return &[];
        }
        let bytes = self.region.as_slice();
        // SAFETY: construction checked bounds and alignment; `T: Pod`
        // guarantees every bit pattern is a valid `T`; the region is
        // immutable and kept alive by our Arc.
        unsafe {
            std::slice::from_raw_parts(bytes.as_ptr().add(self.byte_off).cast::<T>(), self.count)
        }
    }
}

impl<T: Pod + std::fmt::Debug> std::fmt::Debug for Segment<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Segment").field("count", &self.count).finish()
    }
}

/// A growable read-write mapping over `file` (typically an unlinked temp
/// file): `MAP_SHARED`, so stores land in the page cache and the kernel
/// may write dirty pages back instead of holding them resident. On
/// non-unix targets this degrades to a heap buffer.
pub struct MmapMut {
    file: File,
    state: MutState,
}

enum MutState {
    #[cfg(unix)]
    Mapped { ptr: *mut u8, len: usize },
    // u64-backed so byte views handed to `RowSlab` are 8-byte aligned
    // even on the heap fallback path.
    Heap { buf: Vec<u64>, len: usize },
}

// SAFETY: `MmapMut` hands out `&mut [u8]` only through `&mut self`, so
// the usual borrow rules serialize access to the mapped bytes.
unsafe impl Send for MmapMut {}

impl MmapMut {
    /// Wrap `file` (resized to `len` bytes, zero-filled by the kernel).
    pub fn with_len(file: File, len: usize) -> Result<MmapMut> {
        let mut m = MmapMut {
            file,
            state: MutState::Heap {
                buf: Vec::new(),
                len: 0,
            },
        };
        m.grow_to(len)?;
        Ok(m)
    }

    pub fn len(&self) -> usize {
        match &self.state {
            #[cfg(unix)]
            MutState::Mapped { len, .. } => *len,
            MutState::Heap { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_slice(&self) -> &[u8] {
        match &self.state {
            #[cfg(unix)]
            // SAFETY: live mapping of exactly `len` bytes; see grow_to.
            MutState::Mapped { ptr, len } => unsafe {
                if *len == 0 {
                    &[]
                } else {
                    std::slice::from_raw_parts(*ptr, *len)
                }
            },
            // SAFETY: viewing `len` bytes of a u64 buffer holding at
            // least that many (alignment only ever loosens, 8 → 1).
            MutState::Heap { buf, len } => unsafe {
                std::slice::from_raw_parts(buf.as_ptr().cast::<u8>(), *len)
            },
        }
    }

    pub fn as_mut_slice(&mut self) -> &mut [u8] {
        match &mut self.state {
            #[cfg(unix)]
            // SAFETY: live PROT_READ|PROT_WRITE mapping; &mut self gives
            // exclusive access.
            MutState::Mapped { ptr, len } => unsafe {
                if *len == 0 {
                    &mut []
                } else {
                    std::slice::from_raw_parts_mut(*ptr, *len)
                }
            },
            // SAFETY: as in `as_slice`, plus exclusivity via &mut self.
            MutState::Heap { buf, len } => unsafe {
                std::slice::from_raw_parts_mut(buf.as_mut_ptr().cast::<u8>(), *len)
            },
        }
    }

    /// Grow the region to `new_len` bytes (no-op if already at least as
    /// large). Existing bytes are preserved; new bytes read as zero. On
    /// unix this is munmap → `ftruncate` (via `File::set_len`) → remap,
    /// so callers must not hold slices across a grow (the borrow checker
    /// enforces this: `grow_to` takes `&mut self`).
    pub fn grow_to(&mut self, new_len: usize) -> Result<()> {
        if new_len <= self.len() {
            return Ok(());
        }
        self.file
            .set_len(new_len as u64)
            .context("grow slab backing file")?;
        self.remap(new_len)
    }

    #[cfg(unix)]
    fn remap(&mut self, new_len: usize) -> Result<()> {
        use std::os::unix::io::AsRawFd;
        if let MutState::Mapped { ptr, len } = &self.state {
            if *len > 0 {
                // SAFETY: unmapping the mapping we created in a prior
                // remap; the state is replaced immediately below.
                unsafe { sys::munmap(ptr.cast::<core::ffi::c_void>(), *len) };
            }
        }
        // SAFETY: fd is open read-write and the file was just extended
        // to `new_len` bytes; MAP_FAILED is checked below.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                new_len,
                sys::PROT_READ | sys::PROT_WRITE,
                sys::MAP_SHARED,
                self.file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::map_failed() {
            bail!("mmap (rw, {new_len} bytes) failed for snapshot slab");
        }
        self.state = MutState::Mapped {
            ptr: ptr.cast(),
            len: new_len,
        };
        Ok(())
    }

    #[cfg(not(unix))]
    fn remap(&mut self, new_len: usize) -> Result<()> {
        if let MutState::Heap { buf, len } = &mut self.state {
            buf.resize(new_len.div_ceil(8), 0);
            *len = new_len;
        }
        Ok(())
    }
}

impl Drop for MmapMut {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let MutState::Mapped { ptr, len } = &self.state {
            if *len > 0 {
                // SAFETY: unmapping our own mapping exactly once.
                unsafe { sys::munmap(ptr.cast::<core::ffi::c_void>(), *len) };
            }
        }
    }
}

impl std::fmt::Debug for MmapMut {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MmapMut").field("len", &self.len()).finish()
    }
}

/// Create an anonymous temp file under `std::env::temp_dir()`. On unix
/// the path is unlinked immediately after opening, so the bytes vanish
/// with the last fd even on crash; elsewhere the named file persists
/// until deleted by the caller or the OS temp cleaner.
pub fn anon_temp_file(tag: &str) -> Result<File> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let pid = std::process::id();
    let path = std::env::temp_dir().join(format!("optimes-{tag}-{pid}-{seq}.tmp"));
    let file = std::fs::OpenOptions::new()
        .read(true)
        .write(true)
        .create_new(true)
        .open(&path)
        .with_context(|| format!("create temp file {}", path.display()))?;
    #[cfg(unix)]
    let _ = std::fs::remove_file(&path);
    Ok(file)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn map_roundtrip_and_typed_segment() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("optimes-mmap-test-{}.bin", std::process::id()));
        {
            let mut f = File::create(&path).unwrap();
            let vals: Vec<u32> = (0..64).collect();
            for v in &vals {
                f.write_all(&v.to_le_bytes()).unwrap();
            }
        }
        let m = Mmap::open(&path).unwrap();
        assert_eq!(m.len(), 256);
        let seg: Segment<u32> = m.segment(0, 64).unwrap();
        assert_eq!(seg.as_slice()[0], 0);
        assert_eq!(seg.as_slice()[63], 63);
        // Out-of-bounds and misaligned requests fail without panicking.
        assert!(m.segment::<u32>(0, 65).is_err());
        assert!(m.segment::<u32>(2, 1).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mmap_mut_grows_and_preserves() {
        let f = anon_temp_file("mmaptest").unwrap();
        let mut m = MmapMut::with_len(f, 8).unwrap();
        m.as_mut_slice()[..4].copy_from_slice(&[1, 2, 3, 4]);
        m.grow_to(4096).unwrap();
        assert_eq!(&m.as_slice()[..4], &[1, 2, 3, 4]);
        assert_eq!(m.as_slice()[4095], 0);
        m.as_mut_slice()[4095] = 7;
        assert_eq!(m.as_slice()[4095], 7);
    }
}
