//! Out-of-core graph plane (DESIGN.md §13).
//!
//! Four pieces turn "graphs fit in RAM" from an architecture assumption
//! into a per-run choice:
//!
//! * [`format`]: the versioned, checksummed on-disk CSR (`GraphFile`)
//!   with a streaming two-pass writer and corruption-naming reader;
//! * [`mmap`] + [`slab`]: the `Slab<T>` seam that lets `Csr`/`Graph`
//!   bulk arrays be served from mapped pages instead of the heap;
//! * [`stream_partition`]: hash and linear-deterministic-greedy
//!   partitioners that assign a graph client-by-client from one
//!   adjacency pass, no in-RAM CSR required;
//! * [`GraphStore`]: the loading seam — every consumer (`partition`,
//!   `sampler`, `subgraph`, trainer, figure harness) sees a plain
//!   [`Graph`] and cannot tell the backends apart except by RSS.
//!
//! Backend selection: `OPTIMES_GRAPH_BACKEND=ram|mmap` (or CLI
//! `run --graph-backend`). `ram` decodes sections into heap `Vec`s via
//! `from_le_bytes` (works on any host endianness); `mmap` serves the
//! file's little-endian pages directly and therefore refuses big-endian
//! hosts with a named error. Accuracy curves are bit-identical across
//! backends — the store-parity CI matrix enforces it.

pub mod format;
pub mod mmap;
pub mod slab;
pub mod stream_partition;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::csr::Graph;

pub use format::{load_graph_file, write_graph_file, GraphFileInfo, GraphFileWriter};
pub use slab::{RowSlab, Slab};
pub use stream_partition::{
    hash_partition_n, ldg_partition, ldg_partition_file, ldg_partition_graph, FileVertexStream,
    GraphVertexStream, VertexStream,
};

/// Which medium serves a graph's bulk arrays.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum GraphBackend {
    #[default]
    Ram,
    Mmap,
}

impl GraphBackend {
    pub fn parse(s: &str) -> Result<GraphBackend> {
        match s {
            "ram" => Ok(GraphBackend::Ram),
            "mmap" => Ok(GraphBackend::Mmap),
            other => bail!("unknown graph backend {other:?} (expected ram|mmap)"),
        }
    }

    /// Resolve from `OPTIMES_GRAPH_BACKEND` (default `ram`). Panics on
    /// an unparseable value — a typo silently falling back to `ram`
    /// would fake backend parity in the CI matrix.
    pub fn from_env() -> GraphBackend {
        match std::env::var("OPTIMES_GRAPH_BACKEND") {
            Ok(v) => GraphBackend::parse(&v).expect("OPTIMES_GRAPH_BACKEND"),
            Err(_) => GraphBackend::Ram,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            GraphBackend::Ram => "ram",
            GraphBackend::Mmap => "mmap",
        }
    }
}

/// The loading seam over `GraphFile`s (tentpole (b) of DESIGN.md §13).
pub struct GraphStore;

impl GraphStore {
    /// Open a `GraphFile` with full verification (header, checksums,
    /// `Graph::validate`) on the requested backend.
    pub fn open(path: &Path, backend: GraphBackend) -> Result<Graph> {
        format::load_graph_file(path, backend)
    }

    /// Serialize a graph to `path`.
    pub fn save(path: &Path, g: &Graph) -> Result<GraphFileInfo> {
        format::write_graph_file(path, g)
    }

    /// Re-home an in-RAM graph onto the requested backend. `Ram` is a
    /// no-op; `Mmap` round-trips through a temp `GraphFile` (unlinked
    /// after opening on unix) so the result is served from mapped pages
    /// — this is how `OPTIMES_GRAPH_BACKEND=mmap` routes generated
    /// datasets through the on-disk format.
    pub fn adopt(g: Graph, backend: GraphBackend) -> Result<Graph> {
        match backend {
            GraphBackend::Ram => Ok(g),
            GraphBackend::Mmap => {
                use std::sync::atomic::{AtomicU64, Ordering};
                static SEQ: AtomicU64 = AtomicU64::new(0);
                let seq = SEQ.fetch_add(1, Ordering::Relaxed);
                let pid = std::process::id();
                let path = std::env::temp_dir().join(format!("optimes-adopt-{pid}-{seq}.graph"));
                Self::save(&path, &g).context("write temp GraphFile for mmap adoption")?;
                let mapped = Self::open(&path, GraphBackend::Mmap)
                    .context("reopen temp GraphFile mmap-backed")?;
                // Unlink immediately: the mapping keeps the bytes alive
                // on unix; on other targets the fallback already copied.
                let _ = std::fs::remove_file(&path);
                Ok(mapped)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{generate, GenParams};

    #[test]
    fn backend_parse_and_names() {
        assert_eq!(GraphBackend::parse("ram").unwrap(), GraphBackend::Ram);
        assert_eq!(GraphBackend::parse("mmap").unwrap(), GraphBackend::Mmap);
        assert!(GraphBackend::parse("tape").is_err());
        assert_eq!(GraphBackend::default().name(), "ram");
    }

    #[test]
    fn adopt_mmap_serves_identical_graph_from_pages() {
        let g = generate(&GenParams {
            n: 250,
            ..GenParams::default()
        });
        let m = GraphStore::adopt(g.clone(), GraphBackend::Mmap).unwrap();
        assert!(m.is_mapped());
        assert!(!g.is_mapped());
        assert_eq!(g.out.offsets, m.out.offsets);
        assert_eq!(g.out.targets, m.out.targets);
        assert_eq!(g.inc.targets, m.inc.targets);
        assert_eq!(g.features, m.features);
        assert_eq!(g.labels, m.labels);
        assert_eq!(g.train_nodes, m.train_nodes);
        assert_eq!(g.test_nodes, m.test_nodes);
        m.validate().unwrap();
    }
}
