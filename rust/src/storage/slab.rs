//! `Slab<T>`: the storage seam behind `Csr`/`Graph` bulk fields.
//!
//! Every bulk array in the graph plane (`offsets`, `targets`, `features`,
//! `labels`) is a `Slab<T>`, which is either an owned heap `Vec<T>` (the
//! `ram` backend — exactly the pre-seam representation) or a typed view
//! into a shared read-only mapping of a `GraphFile` (the `mmap` backend).
//! `Deref<Target = [T]>` keeps every existing call site — indexing,
//! slicing, `.len()`, `.iter()` — compiling unchanged, and `PartialEq`
//! compares element-wise so parity tests can `assert_eq!` across
//! backends.
//!
//! `RowSlab` is the second, mutable half of the seam: a fixed-row-width
//! `f32` arena over a growable [`MmapMut`], used by the snapshot store's
//! shadow copy (DESIGN.md §13.4).

use std::ops::Deref;

use anyhow::Result;

use super::mmap::{anon_temp_file, MmapMut, Pod, Segment};

/// Backing storage for a bulk array: heap-owned or mmap-backed.
pub enum Slab<T: Pod> {
    Ram(Vec<T>),
    Mapped(Segment<T>),
}

impl<T: Pod> Slab<T> {
    pub fn as_slice(&self) -> &[T] {
        match self {
            Slab::Ram(v) => v,
            Slab::Mapped(seg) => seg.as_slice(),
        }
    }

    /// True when served from mapped pages rather than the heap.
    pub fn is_mapped(&self) -> bool {
        matches!(self, Slab::Mapped(_))
    }

    /// Materialize into an owned `Vec` (copies when mapped).
    pub fn to_vec(&self) -> Vec<T> {
        self.as_slice().to_vec()
    }
}

impl<T: Pod> Deref for Slab<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Pod> From<Vec<T>> for Slab<T> {
    fn from(v: Vec<T>) -> Slab<T> {
        Slab::Ram(v)
    }
}

impl<T: Pod> Default for Slab<T> {
    fn default() -> Slab<T> {
        Slab::Ram(Vec::new())
    }
}

impl<T: Pod> Clone for Slab<T> {
    fn clone(&self) -> Slab<T> {
        match self {
            Slab::Ram(v) => Slab::Ram(v.clone()),
            // Segments are Arc-backed views; cloning shares the mapping.
            Slab::Mapped(seg) => Slab::Mapped(seg.clone()),
        }
    }
}

impl<T: Pod + PartialEq> PartialEq for Slab<T> {
    fn eq(&self, other: &Slab<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Pod + std::fmt::Debug> std::fmt::Debug for Slab<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = if self.is_mapped() { "mapped" } else { "ram" };
        write!(f, "Slab<{kind}>(len={})", self.len())
    }
}

/// Fixed-row-width `f32` arena over a growable mmap region (heap buffer
/// on non-unix targets). Rows are allocated append-only; the caller maps
/// node ids to row slots. Backed by an unlinked temp file, so the bytes
/// are reclaimed by the OS on drop or crash.
pub struct RowSlab {
    map: MmapMut,
    width: usize,
    rows: usize,
}

impl RowSlab {
    /// An empty slab whose rows hold `width` f32 values each.
    pub fn new(width: usize) -> Result<RowSlab> {
        let file = anon_temp_file("snapslab")?;
        // Start with one page so the first grow is cheap.
        let map = MmapMut::with_len(file, 4096)?;
        Ok(RowSlab {
            map,
            width: width.max(1),
            rows: 0,
        })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Allocate one zeroed row, returning its slot index.
    pub fn alloc_row(&mut self) -> Result<usize> {
        let slot = self.rows;
        let need = (slot + 1) * self.width * 4;
        if need > self.map.len() {
            // Double (min one page) to amortize remaps.
            let target = need.next_power_of_two().max(4096);
            self.map.grow_to(target)?;
        }
        self.rows += 1;
        Ok(slot)
    }

    pub fn row(&self, slot: usize) -> &[f32] {
        assert!(slot < self.rows, "row slot {slot} out of bounds");
        let bytes = &self.map.as_slice()[slot * self.width * 4..(slot + 1) * self.width * 4];
        // SAFETY: the region starts page-aligned (mmap or Vec<u8> of a
        // fresh allocation is at least 4-aligned on every supported
        // target) and rows are whole multiples of 4 bytes; any bit
        // pattern is a valid f32.
        unsafe { std::slice::from_raw_parts(bytes.as_ptr().cast::<f32>(), self.width) }
    }

    pub fn row_mut(&mut self, slot: usize) -> &mut [f32] {
        assert!(slot < self.rows, "row slot {slot} out of bounds");
        let w = self.width;
        let bytes = &mut self.map.as_mut_slice()[slot * w * 4..(slot + 1) * w * 4];
        // SAFETY: as in `row`, plus exclusive access via &mut self.
        unsafe { std::slice::from_raw_parts_mut(bytes.as_mut_ptr().cast::<f32>(), w) }
    }
}

impl std::fmt::Debug for RowSlab {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RowSlab")
            .field("rows", &self.rows)
            .field("width", &self.width)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slab_derefs_like_a_vec() {
        let s: Slab<u32> = vec![3, 1, 4, 1, 5].into();
        assert_eq!(s.len(), 5);
        assert_eq!(s[2], 4);
        assert_eq!(&s[1..3], &[1, 4]);
        assert_eq!(s.iter().sum::<u32>(), 14);
        let t = s.clone();
        assert_eq!(s, t);
        assert!(!s.is_mapped());
    }

    #[test]
    fn row_slab_allocates_and_persists_rows() {
        let mut slab = RowSlab::new(8).unwrap();
        for i in 0..100 {
            let slot = slab.alloc_row().unwrap();
            assert_eq!(slot, i);
            slab.row_mut(slot).fill(i as f32);
        }
        for i in 0..100 {
            assert_eq!(slab.row(i)[7], i as f32);
        }
        assert_eq!(slab.rows(), 100);
    }
}
