//! Streaming k-way partitioners (DESIGN.md §13.3).
//!
//! `metis_lite` needs the whole CSR in RAM (BFS seeding + frontier
//! growth + a refinement sweep). These partitioners instead consume a
//! single ordered pass over per-vertex adjacency — the [`VertexStream`]
//! trait — so a `GraphFile` far larger than RAM can be assigned
//! client-by-client with O(n + k) state (the assignment vector itself):
//!
//! * [`hash_partition_n`]: uniform random assignment, the max-cut
//!   baseline. Identical stream to `partition::hash_partition` (which
//!   now delegates here), so existing ablations are unchanged.
//! * [`ldg_partition`]: linear deterministic greedy — each vertex joins
//!   the part holding most of its already-seen neighbours, damped by a
//!   fill factor `(1 - size/cap)`; the capacity cap bounds imbalance by
//!   construction, and ties break by a seed-shuffled part order, then
//!   by current size, so the result is a pure function of (stream,
//!   k, seed).

use anyhow::{ensure, Context, Result};
use std::fs::File;
use std::io::{BufReader, Read, Seek, SeekFrom};
use std::path::Path;

use crate::graph::csr::Graph;
use crate::graph::partition::Partition;
use crate::util::rng::Rng;

use super::format::{read_info, GraphFileInfo};

/// One ordered pass over vertices 0..n with out- and in-neighbour lists.
pub trait VertexStream {
    fn n(&self) -> usize;

    /// Visit every vertex in ascending id order. The slices are only
    /// valid for the duration of the callback.
    fn for_each_vertex(
        &mut self,
        f: &mut dyn FnMut(u32, &[u32], &[u32]) -> Result<()>,
    ) -> Result<()>;
}

/// In-RAM adapter: any loaded [`Graph`] (either backend) is a stream.
pub struct GraphVertexStream<'a> {
    pub g: &'a Graph,
}

impl VertexStream for GraphVertexStream<'_> {
    fn n(&self) -> usize {
        self.g.n
    }

    fn for_each_vertex(
        &mut self,
        f: &mut dyn FnMut(u32, &[u32], &[u32]) -> Result<()>,
    ) -> Result<()> {
        for v in 0..self.g.n as u32 {
            f(v, self.g.out.neighbors(v), self.g.inc.neighbors(v))?;
        }
        Ok(())
    }
}

/// Sequentially streams a `GraphFile`'s adjacency sections through small
/// reusable buffers — peak RSS is independent of graph size. The header
/// is bounds-checked on open; payload integrity is the caller's call
/// (`verify_checksums` is a separate pass).
pub struct FileVertexStream {
    info: GraphFileInfo,
    path: std::path::PathBuf,
}

impl FileVertexStream {
    pub fn open(path: &Path) -> Result<FileVertexStream> {
        let info = read_info(path)?;
        Ok(FileVertexStream {
            info,
            path: path.to_path_buf(),
        })
    }

    pub fn info(&self) -> &GraphFileInfo {
        &self.info
    }

    fn reader(&self, section: usize) -> Result<BufReader<File>> {
        let mut file = File::open(&self.path)
            .with_context(|| format!("open GraphFile {}", self.path.display()))?;
        file.seek(SeekFrom::Start(self.info.sections[section].offset))
            .context("seek to section")?;
        Ok(BufReader::with_capacity(1 << 20, file))
    }
}

fn next_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("read adjacency stream")?;
    Ok(u32::from_le_bytes(b))
}

fn read_targets(r: &mut impl Read, deg: usize, buf: &mut Vec<u32>) -> Result<()> {
    buf.clear();
    for _ in 0..deg {
        buf.push(next_u32(r)?);
    }
    Ok(())
}

impl VertexStream for FileVertexStream {
    fn n(&self) -> usize {
        self.info.n
    }

    fn for_each_vertex(
        &mut self,
        f: &mut dyn FnMut(u32, &[u32], &[u32]) -> Result<()>,
    ) -> Result<()> {
        let mut out_off = self.reader(0)?;
        let mut out_tgt = self.reader(1)?;
        let mut in_off = self.reader(2)?;
        let mut in_tgt = self.reader(3)?;
        let mut prev_out = next_u32(&mut out_off)?;
        let mut prev_in = next_u32(&mut in_off)?;
        let mut out_buf = Vec::new();
        let mut in_buf = Vec::new();
        for v in 0..self.info.n as u32 {
            let next_out = next_u32(&mut out_off)?;
            let next_in = next_u32(&mut in_off)?;
            ensure!(
                next_out >= prev_out && next_in >= prev_in,
                "GraphFile {}: offsets section not monotone at vertex {v}",
                self.path.display()
            );
            read_targets(&mut out_tgt, (next_out - prev_out) as usize, &mut out_buf)?;
            read_targets(&mut in_tgt, (next_in - prev_in) as usize, &mut in_buf)?;
            prev_out = next_out;
            prev_in = next_in;
            f(v, &out_buf, &in_buf)?;
        }
        Ok(())
    }
}

/// Uniform random assignment over `n` vertices — needs no adjacency at
/// all. Same rng stream as the historical `hash_partition`, so results
/// are unchanged for in-RAM callers.
pub fn hash_partition_n(n: usize, k: usize, seed: u64) -> Partition {
    let mut rng = Rng::new(seed, 0x44A5);
    let assign = (0..n).map(|_| rng.below(k) as u32).collect();
    Partition { k, assign }
}

/// Linear deterministic greedy over one adjacency pass.
pub fn ldg_partition(stream: &mut dyn VertexStream, k: usize, seed: u64) -> Result<Partition> {
    let n = stream.n();
    ensure!(k >= 1 && n >= k, "ldg: need n >= k >= 1 (n={n}, k={k})");
    // Same slack as metis_lite, so imbalance tolerances line up.
    let cap = n.div_ceil(k) + (n / k / 20).max(1);
    let mut rng = Rng::new(seed, 0x4C44);
    let mut tie_order: Vec<u32> = (0..k as u32).collect();
    rng.shuffle(&mut tie_order);

    const UNASSIGNED: u32 = u32::MAX;
    let mut assign = vec![UNASSIGNED; n];
    let mut sizes = vec![0usize; k];
    let mut counts = vec![0u64; k];
    stream.for_each_vertex(&mut |v, out, inc| {
        for c in counts.iter_mut() {
            *c = 0;
        }
        for &t in out.iter().chain(inc.iter()) {
            ensure!((t as usize) < n, "ldg: edge target {t} out of range (n={n})");
            let a = assign[t as usize];
            if a != UNASSIGNED {
                counts[a as usize] += 1;
            }
        }
        let mut best: Option<usize> = None;
        let mut best_score = f64::NEG_INFINITY;
        for &p in &tie_order {
            let p = p as usize;
            if sizes[p] >= cap {
                continue;
            }
            let fill = 1.0 - sizes[p] as f64 / cap as f64;
            let score = counts[p] as f64 * fill;
            let better = match best {
                None => true,
                // Ties (including the all-zero cold start) go to the
                // emptier part, then to seed-shuffled order.
                Some(b) => score > best_score || (score == best_score && sizes[p] < sizes[b]),
            };
            if better {
                best = Some(p);
                best_score = score;
            }
        }
        let p = best.expect("capacity k*cap > n leaves an open part");
        assign[v as usize] = p as u32;
        sizes[p] += 1;
        Ok(())
    })?;
    Ok(Partition { k, assign })
}

/// LDG over an in-RAM graph (used by the session seam).
pub fn ldg_partition_graph(g: &Graph, k: usize, seed: u64) -> Result<Partition> {
    ldg_partition(&mut GraphVertexStream { g }, k, seed)
}

/// LDG straight off a `GraphFile`, never materializing the CSR.
pub fn ldg_partition_file(path: &Path, k: usize, seed: u64) -> Result<Partition> {
    ldg_partition(&mut FileVertexStream::open(path)?, k, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny;
    use crate::graph::partition::hash_partition;
    use crate::storage::format::write_graph_file;

    #[test]
    fn ldg_is_deterministic_and_balanced() {
        let g = tiny(11);
        for k in [2, 4] {
            let a = ldg_partition_graph(&g, k, 9).unwrap();
            let b = ldg_partition_graph(&g, k, 9).unwrap();
            assert_eq!(a.assign, b.assign);
            assert!(a.imbalance() < 1.15, "imbalance {}", a.imbalance());
            assert_eq!(a.sizes().iter().sum::<usize>(), g.n);
        }
    }

    #[test]
    fn ldg_beats_hash_on_communities() {
        let g = tiny(12);
        let ldg = ldg_partition_graph(&g, 4, 5).unwrap();
        let hash = hash_partition(&g, 4, 5);
        assert!(
            ldg.cut_fraction(&g) < hash.cut_fraction(&g),
            "ldg {} vs hash {}",
            ldg.cut_fraction(&g),
            hash.cut_fraction(&g)
        );
    }

    #[test]
    fn file_stream_matches_graph_stream() {
        let g = tiny(13);
        let path =
            std::env::temp_dir().join(format!("optimes-ldgstream-{}.graph", std::process::id()));
        write_graph_file(&path, &g).unwrap();
        let from_graph = ldg_partition_graph(&g, 3, 7).unwrap();
        let from_file = ldg_partition_file(&path, 3, 7).unwrap();
        assert_eq!(from_graph.assign, from_file.assign);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn hash_partition_n_matches_graph_hash() {
        let g = tiny(14);
        let a = hash_partition(&g, 4, 3);
        let b = hash_partition_n(g.n, 4, 3);
        assert_eq!(a.assign, b.assign);
    }
}
