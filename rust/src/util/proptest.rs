//! Mini property-testing substrate (no proptest crate offline).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` generated inputs
//! and, on failure, retries the failing seed with progressively "smaller"
//! regenerations (seeded shrink-lite): the generator receives a size hint it
//! can use to produce smaller cases, and the smallest failing case is
//! reported. This is deliberately simple but gives the coordinator
//! invariants real randomized coverage with reproducible failures.

use super::rng::Rng;

/// Generation context handed to case generators.
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
    /// Size hint in [1, 100]; shrink passes re-run with smaller sizes.
    pub size: usize,
}

impl<'a> Gen<'a> {
    /// Integer in [lo, hi] scaled by the size hint (inclusive bounds).
    pub fn int_scaled(&mut self, lo: usize, hi: usize) -> usize {
        let span = hi.saturating_sub(lo);
        let scaled = (span * self.size) / 100;
        lo + self.rng.below(scaled + 1)
    }

    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }
}

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run a property over `cases` random cases. Panics (failing the enclosing
/// test) with the seed, case index, and message of the smallest failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut generate: impl FnMut(&mut Gen) -> T,
    mut prop: impl FnMut(&T) -> PropResult,
) {
    let base_seed = 0xC0FFEE_u64 ^ name.len() as u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed, 0);
        let mut gen = Gen {
            rng: &mut rng,
            size: 100,
        };
        let input = generate(&mut gen);
        if let Err(msg) = prop(&input) {
            // shrink-lite: re-generate from the same seed at smaller sizes
            // and keep the smallest size that still fails.
            let mut smallest: Option<(usize, T, String)> = None;
            for size in [50usize, 25, 12, 6, 3, 1] {
                let mut rng = Rng::new(seed, 0);
                let mut gen = Gen {
                    rng: &mut rng,
                    size,
                };
                let candidate = generate(&mut gen);
                if let Err(m) = prop(&candidate) {
                    smallest = Some((size, candidate, m));
                }
            }
            match smallest {
                Some((size, small, m)) => panic!(
                    "property '{name}' failed (case {case}, seed {seed:#x}).\n\
                     original: {msg}\n\
                     shrunk (size {size}): {m}\n\
                     shrunk input: {small:#?}"
                ),
                None => panic!(
                    "property '{name}' failed (case {case}, seed {seed:#x}): {msg}\n\
                     input: {input:#?}"
                ),
            }
        }
    }
}

/// Convenience assertion helpers for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            "sum-commutes",
            50,
            |g| (g.int(0, 100), g.int(0, 100)),
            |&(a, b)| {
                count += 1;
                prop_assert!(a + b == b + a, "not commutative");
                Ok(())
            },
        );
        assert!(count >= 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check(
            "always-fails",
            10,
            |g| g.int_scaled(0, 1000),
            |&x| {
                prop_assert!(x > 10_000, "x={x} too small");
                Ok(())
            },
        );
    }

    #[test]
    fn size_hint_scales_generation() {
        let mut rng = Rng::new(1, 0);
        let mut g = Gen {
            rng: &mut rng,
            size: 1,
        };
        for _ in 0..100 {
            assert!(g.int_scaled(0, 1000) <= 10);
        }
    }
}
